//! # What it demonstrates
//!
//! The edge deployment shape from the paper's conclusion ("real-time,
//! low-energy text summarization on edge devices"): the thread-based
//! summarization service under bursty load, with Ising solves routed
//! through the shared device pool hosting the **adaptive solver
//! portfolio** and the fleet-wide **warm-start cache** — so a repeated
//! burst of the same documents gets cheaper, not just batched, and
//! overload is answered with backpressure instead of collapse.
//!
//!     cargo run --release --example edge_service
//!
//! # Expected output
//!
//! Three bursts with throughput lines, then the combined metrics report:
//!
//!   * burst 1 (cold): all 12 requests complete; the portfolio line shows
//!     routes on the static backend and a populated cache (entries > 0);
//!   * burst 2 (repeat of burst 1's documents): completes faster — the
//!     metrics report shows nonzero cache exact/warm hit percentages;
//!   * burst 3 (overload): some requests rejected (backpressure), the
//!     rest complete; final `service metrics:` line includes
//!     `pool: ...` and `portfolio: routes ... cache ...` sections,
//!     followed by `shut down cleanly`.

use std::time::Instant;

use cobi_es::config::Settings;
use cobi_es::corpus::benchmark_set;
use cobi_es::service::Service;

fn main() -> anyhow::Result<()> {
    let mut settings = Settings::default();
    settings.service.workers = 3;
    settings.service.queue_depth = 16;
    settings.pipeline.solver = "cobi".into();
    settings.pipeline.iterations = 4;
    // route solves through the adaptive portfolio + warm-start cache
    settings.portfolio.enabled = true;
    settings.portfolio.policy = "static".into();
    settings.portfolio.cache = true;
    settings.sched.devices = 2;

    println!(
        "edge service: {} workers, queue depth {}, portfolio ({} policy, warm cache on)",
        settings.service.workers, settings.service.queue_depth, settings.portfolio.policy
    );
    let svc = Service::start(&settings)?;
    let set = benchmark_set("cnn_dm_20")?;

    // burst 1: sustainable load, cold cache
    let t0 = Instant::now();
    let tickets: Vec<_> = (0..12)
        .filter_map(|i| svc.submit(set.documents[i % 20].clone()).ok())
        .collect();
    let accepted1 = tickets.len();
    let mut ok = 0;
    for t in tickets {
        ok += t.wait().is_ok() as usize;
    }
    let wall1 = t0.elapsed().as_secs_f64();
    println!(
        "\nburst 1 (cold):   {accepted1} accepted, {ok} completed in {wall1:.2}s \
         ({:.1} docs/s)",
        ok as f64 / wall1
    );

    // burst 2: the SAME documents again — the warm-start cache's target
    // workload (identical doc ids => identical quantized instances =>
    // exact hits; same-size windows => warm hits)
    let t0 = Instant::now();
    let tickets: Vec<_> = (0..12)
        .filter_map(|i| svc.submit(set.documents[i % 20].clone()).ok())
        .collect();
    let mut ok2 = 0;
    for t in tickets {
        ok2 += t.wait().is_ok() as usize;
    }
    let wall2 = t0.elapsed().as_secs_f64();
    println!(
        "burst 2 (repeat): {ok2} completed in {wall2:.2}s ({:.1} docs/s) — \
         reuse should beat burst 1",
        ok2 as f64 / wall2
    );

    // burst 3: overload — expect backpressure rejections, not collapse
    let t0 = Instant::now();
    let mut accepted3 = 0;
    let mut rejected = 0;
    let mut tickets = Vec::new();
    for i in 0..200 {
        match svc.submit(set.documents[i % 20].clone()) {
            Ok(t) => {
                accepted3 += 1;
                tickets.push(t);
            }
            Err(_) => rejected += 1,
        }
    }
    let mut ok3 = 0;
    for t in tickets {
        ok3 += t.wait().is_ok() as usize;
    }
    let wall3 = t0.elapsed().as_secs_f64();
    println!(
        "burst 3 (overload): {accepted3} accepted, {rejected} rejected \
         (backpressure), {ok3} completed in {wall3:.2}s"
    );

    let metrics = svc.metrics();
    println!("\nservice metrics: {}", metrics.report());
    if let Some(p) = &metrics.portfolio {
        println!(
            "cache reuse: {} lookups, {:.0}% exact, {:.0}% warm, {} entries",
            p.cache.lookups,
            p.cache.exact_rate() * 100.0,
            p.cache.warm_rate() * 100.0,
            p.cache.entries,
        );
    }
    svc.shutdown();
    println!("shut down cleanly");
    Ok(())
}
