//! Edge service demo: run the thread-based summarization service under a
//! bursty request load, reporting latency percentiles, throughput and
//! backpressure behaviour — the deployment scenario of the paper's
//! conclusion ("real-time, low-energy text summarization on edge
//! devices").
//!
//!     cargo run --release --example edge_service

use std::time::Instant;

use cobi_es::config::Settings;
use cobi_es::corpus::benchmark_set;
use cobi_es::service::Service;

fn main() -> anyhow::Result<()> {
    let mut settings = Settings::default();
    settings.service.workers = 3;
    settings.service.queue_depth = 16;
    settings.pipeline.solver = "cobi".into();
    settings.pipeline.iterations = 4;

    println!(
        "edge service: {} workers, queue depth {}, COBI-simulated solver",
        settings.service.workers, settings.service.queue_depth
    );
    let svc = Service::start(&settings)?;
    let set = benchmark_set("cnn_dm_20")?;

    // burst 1: sustainable load
    let t0 = Instant::now();
    let tickets: Vec<_> = (0..12)
        .filter_map(|i| svc.submit(set.documents[i % 20].clone()).ok())
        .collect();
    let accepted1 = tickets.len();
    let mut ok = 0;
    for t in tickets {
        ok += t.wait().is_ok() as usize;
    }
    let wall1 = t0.elapsed().as_secs_f64();
    println!(
        "\nburst 1: {accepted1} accepted, {ok} completed in {wall1:.2}s \
         ({:.1} docs/s)",
        ok as f64 / wall1
    );

    // burst 2: overload — expect backpressure rejections, not collapse
    let t0 = Instant::now();
    let mut accepted2 = 0;
    let mut rejected = 0;
    let mut tickets = Vec::new();
    for i in 0..200 {
        match svc.submit(set.documents[i % 20].clone()) {
            Ok(t) => {
                accepted2 += 1;
                tickets.push(t);
            }
            Err(_) => rejected += 1,
        }
    }
    let mut ok2 = 0;
    for t in tickets {
        ok2 += t.wait().is_ok() as usize;
    }
    let wall2 = t0.elapsed().as_secs_f64();
    println!(
        "burst 2 (overload): {accepted2} accepted, {rejected} rejected \
         (backpressure), {ok2} completed in {wall2:.2}s"
    );

    println!("\nservice metrics: {}", svc.metrics().report());
    svc.shutdown();
    println!("shut down cleanly");
    Ok(())
}
