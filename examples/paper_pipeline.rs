//! # What it demonstrates
//!
//! END-TO-END DRIVER (EXPERIMENTS.md §End-to-end): proves all three
//! layers compose on a real small workload.
//!
//!     make artifacts && cargo run --release --example paper_pipeline
//!
//! Path exercised: synthetic CNN/DM-style articles -> rust tokenizer ->
//! **encoder.hlo** (L2 transformer, PJRT) -> **cosine.hlo** (L1 Pallas
//! kernel) -> improved Ising formulation -> decomposition (P=20, Q=10) ->
//! stochastic int14 quantization -> **anneal.hlo** (L1 oscillator kernel
//! under lax.scan = the COBI chip simulation) -> iterative refinement ->
//! summary. Python never runs; only the AOT artifacts do.
//!
//! # Expected output
//!
//! A layer-handshake banner (artifact names + platform), then the
//! paper's headline metrics for COBI vs Tabu vs brute force on the
//! benchmark articles: normalized objective (Eq. 13), TTS (Eq. 15) and
//! ETS (Eq. 16) — COBI should match Tabu's quality at a fraction of the
//! modeled energy. Requires the AOT artifacts: without `make artifacts`
//! (or `COBI_ES_ARTIFACTS`), it exits early with a descriptive error —
//! use `examples/quickstart.rs` for the artifact-free path.

use cobi_es::cobi::CobiDevice;
use cobi_es::config::Settings;
use cobi_es::corpus::benchmark_set;
use cobi_es::decompose::{decompose, stage_count, DecomposeParams};
use cobi_es::embed::Embedder;
use cobi_es::experiments::fig78::{brute_evals, BRUTE_EVAL_TIME_S};
use cobi_es::ising::{exact_bounds, EsProblem, Formulation};
use cobi_es::metrics::tts::{tts_ets, TimingModel};
use cobi_es::quant::{Precision, Rounding};
use cobi_es::refine::{refine, RefineConfig};
use cobi_es::runtime::{ArtifactRuntime, EncoderPipeline};
use cobi_es::solvers::tabu::TabuSolver;
use cobi_es::solvers::IsingSolver;
use cobi_es::util::rng::Pcg32;
use cobi_es::util::stats::mean;

fn sub_problem(p: &EsProblem, window: &[usize], target: usize) -> EsProblem {
    cobi_es::experiments::fig5::sub_problem(p, window, target)
}

fn main() -> anyhow::Result<()> {
    let settings = Settings::default();
    let t_all = std::time::Instant::now();

    // ---- layer handshake -------------------------------------------------
    let rt = ArtifactRuntime::open_default().map_err(|e| {
        anyhow::anyhow!("{e}\nrun `make artifacts` first — this driver requires the AOT path")
    })?;
    println!("artifacts: {:?}", rt.graph_names());
    let mut encoder = EncoderPipeline::new(&rt)?;

    let set = benchmark_set("cnn_dm_20")?;
    let docs = &set.documents[..10];
    let params = DecomposeParams::paper_default();
    let stages = stage_count(20, &params);
    let r_max = 8usize;
    let threshold = settings.timing.success_threshold;

    println!(
        "workload: {} docs x 20 sentences -> M=6 | decomposition {} stages | \
         int14, stochastic rounding, improved formulation\n",
        docs.len(),
        stages
    );

    // ---- per-document: AOT embeddings -> workflow ------------------------
    let mut norm_cobi = Vec::new();
    let mut norm_tabu = Vec::new();
    let mut fs_cobi: Vec<Option<usize>> = Vec::new();
    let mut fs_tabu: Vec<Option<usize>> = Vec::new();
    let mut device_stats_total = 0u64;

    for (d, doc) in docs.iter().enumerate() {
        // L2+L1 through PJRT: encoder.hlo + cosine.hlo
        let scores = encoder.scores(&doc.sentences)?;
        let problem = EsProblem {
            mu: scores.mu,
            beta: scores.beta,
            lambda: settings.pipeline.lambda,
            m: 6,
        };
        let bounds = exact_bounds(&problem);

        // COBI (anneal.hlo through PJRT) and Tabu, with increasing budgets
        for which in ["cobi", "tabu"] {
            let mut best = f64::NEG_INFINITY;
            let mut first: Option<usize> = None;
            for r in 1..=r_max {
                let cfg = RefineConfig {
                    formulation: Formulation::Improved,
                    precision: Precision::CobiInt,
                    rounding: Rounding::Stochastic,
                    iterations: r,
                };
                let mut rng = Pcg32::new(0xE2E, (d * 100 + r) as u64);
                let mut solver: Box<dyn IsingSolver> = match which {
                    "cobi" => {
                        let dev = CobiDevice::hlo(settings.cobi.clone(), d as u64 ^ 0xE2E, &rt)?;
                        Box::new(dev)
                    }
                    _ => Box::new(TabuSolver::seeded(d as u64 ^ 0x7AB)),
                };
                let result = decompose(problem.n(), &params, |w, t| {
                    let sub = sub_problem(&problem, w, t);
                    Ok(refine(&sub, &cfg, solver.as_mut(), &mut rng)?.result.selected)
                })?;
                let v = bounds.normalize(problem.objective(&result.selected));
                best = best.max(v);
                if first.is_none() && best >= threshold {
                    first = Some(r * stages);
                }
                if which == "cobi" {
                    device_stats_total += (stages * r) as u64;
                }
            }
            if which == "cobi" {
                norm_cobi.push(best);
                fs_cobi.push(first);
            } else {
                norm_tabu.push(best);
                fs_tabu.push(first);
            }
        }
        println!(
            "  doc {d:>2}: cobi {:.3} | tabu {:.3}",
            norm_cobi[d], norm_tabu[d]
        );
    }

    // ---- headline metrics -------------------------------------------------
    let t = &settings.timing;
    let m_cobi = TimingModel::cobi(t, settings.cobi.solve_time_s, settings.cobi.power_w);
    let m_tabu = TimingModel::software(t, t.tabu_time_s);
    let cobi = tts_ets(&fs_cobi, r_max * stages, &m_cobi, t.p_target);
    let tabu = tts_ets(&fs_tabu, r_max * stages, &m_tabu, t.p_target);
    let tts_brute = brute_evals(20, &params) as f64 * BRUTE_EVAL_TIME_S;
    let ets_brute = tts_brute * t.cpu_power_w;

    println!("\n==== headline (paper Figs 6-8 shape) ====");
    println!(
        "mean normalized objective: COBI {:.3} | Tabu {:.3}  (paper: 0.928 vs 0.935)",
        mean(&norm_cobi),
        mean(&norm_tabu)
    );
    println!(
        "TTS  @0.9: COBI {:.2} ms | Tabu {:.2} ms | brute {:.2} ms  \
         (COBI speedup vs brute: {:.1}x; paper: 3.1x)",
        cobi.tts_s * 1e3,
        tabu.tts_s * 1e3,
        tts_brute * 1e3,
        tts_brute / cobi.tts_s
    );
    println!(
        "ETS  @0.9: COBI {:.4} mJ | Tabu {:.3} mJ | brute {:.3} mJ  \
         (reduction vs Tabu: {:.0}x; paper: ~300x)",
        cobi.ets_j * 1e3,
        tabu.ets_j * 1e3,
        ets_brute * 1e3,
        tabu.ets_j / cobi.ets_j
    );
    println!(
        "\n{} HLO anneal solves executed through PJRT; wall time {:.1}s",
        device_stats_total,
        t_all.elapsed().as_secs_f64()
    );
    println!("all three layers composed: tokenizer -> encoder.hlo -> cosine.hlo -> anneal.hlo");
    Ok(())
}
