//! # What it demonstrates
//!
//! Batch-summarizing a stream of synthetic news articles and comparing
//! solver quality vs modeled hardware cost — the paper intro's
//! motivating workload ("news digests ... real-time inference in
//! resource-constrained environments").
//!
//!     cargo run --release --example news_digest
//!
//! # Expected output
//!
//! One table row per solver (cobi, tabu, random) over the 20-document
//! cnn_dm_20 set: mean normalized objective (cobi/tabu ≈ 0.9+, random
//! clearly lower), ROUGE-1/ROUGE-L against the planted references, and
//! the modeled ms/doc and mJ/doc from the paper's timing model — COBI's
//! energy column is orders of magnitude below Tabu's, which is the
//! paper's headline claim. A final line restates the model constants.

use cobi_es::config::{CobiConfig, PipelineConfig, TimingConfig};
use cobi_es::corpus::benchmark_set;
use cobi_es::ising::exact_bounds;
use cobi_es::metrics::rouge_all;
use cobi_es::metrics::tts::TimingModel;
use cobi_es::pipeline::EsPipeline;
use cobi_es::util::stats::mean;

fn main() -> anyhow::Result<()> {
    let set = benchmark_set("cnn_dm_20")?;
    let timing = TimingConfig::default();
    let cobi_cfg = CobiConfig::default();

    println!(
        "digest over {} articles x {} sentences, M = {}\n",
        set.documents.len(),
        set.doc_len(),
        set.summary_len
    );
    println!(
        "{:<8} {:>10} {:>9} {:>9} {:>12} {:>12}",
        "solver", "norm.obj", "ROUGE-1", "ROUGE-L", "model ms/doc", "model mJ/doc"
    );

    for solver in ["cobi", "tabu", "random"] {
        let cfg = PipelineConfig {
            solver: solver.into(),
            iterations: 8,
            ..Default::default()
        };
        let mut pipeline = EsPipeline::from_config(&cfg, &cobi_cfg, None)?;
        let mut norms = Vec::new();
        let mut r1 = Vec::new();
        let mut rl = Vec::new();
        let mut solves_total = 0usize;
        for doc in &set.documents {
            let summary = pipeline.summarize(doc)?;
            let problem = pipeline.problem_for(doc)?;
            let bounds = exact_bounds(&problem);
            norms.push(bounds.normalize(summary.objective));
            let reference: String = doc
                .reference
                .iter()
                .map(|&k| doc.sentences[k].clone())
                .collect::<Vec<_>>()
                .join(" ");
            let r = rouge_all(&summary.text(), &reference);
            r1.push(r.rouge1);
            rl.push(r.rouge_l);
            solves_total += summary.total_solves;
        }
        // modeled per-document hardware cost (Eq. 16 components)
        let solves_per_doc = solves_total as f64 / set.documents.len() as f64;
        let model = match solver {
            "cobi" => TimingModel::cobi(&timing, cobi_cfg.solve_time_s, cobi_cfg.power_w),
            _ => TimingModel::software(&timing, timing.tabu_time_s),
        };
        let (ms, mj) = if solver == "random" {
            (0.0, 0.0) // no Ising hardware in the loop
        } else {
            (
                solves_per_doc * model.iter_time_s() * 1e3,
                solves_per_doc * model.iter_energy_j() * 1e3,
            )
        };
        println!(
            "{:<8} {:>10.3} {:>9.3} {:>9.3} {:>12.2} {:>12.3}",
            solver,
            mean(&norms),
            mean(&r1),
            mean(&rl),
            ms,
            mj
        );
    }
    println!(
        "\n(model: COBI {} µs/solve @ {} mW; Tabu {} ms/solve @ {} W; Eq. 16)",
        cobi_cfg.solve_time_s * 1e6,
        cobi_cfg.power_w * 1e3,
        timing.tabu_time_s * 1e3,
        timing.cpu_power_w
    );
    Ok(())
}
