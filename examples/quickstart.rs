//! # What it demonstrates
//!
//! The simplest possible end-to-end run: summarize one document on the
//! simulated COBI device. Builds a 20-sentence synthetic news document,
//! runs the full paper workflow (improved Ising formulation ->
//! decomposition -> stochastic rounding -> COBI solves -> refinement)
//! and scores the result against the exact optimum. Start here.
//!
//!     cargo run --release --example quickstart
//!
//! # Expected output
//!
//! The numbered input sentences, the 6 selected summary sentences, then
//! a quality line — `objective ... -> normalized X (exact optimum ...)`
//! with X typically ≥ 0.9 — and a cost line (`1 decomposition stages,
//! 10 COBI solves, ... ms wall`). Deterministic for a fixed seed.

use cobi_es::config::{CobiConfig, PipelineConfig};
use cobi_es::corpus::Generator;
use cobi_es::ising::exact_bounds;
use cobi_es::pipeline::EsPipeline;

fn main() -> anyhow::Result<()> {
    // 1. a document (swap in Document::from_text for your own)
    let mut generator = Generator::with_seed(2026);
    let doc = generator.document("quickstart", 20);
    println!("document ({} sentences):", doc.len());
    for (i, s) in doc.sentences.iter().enumerate() {
        println!("  {i:>2}. {s}");
    }

    // 2. the pipeline: COBI device simulation, paper defaults
    //    (P=20, Q=10, M=6, int14, stochastic rounding, 10 iterations)
    let cfg = PipelineConfig::default();
    let mut pipeline = EsPipeline::from_config(&cfg, &CobiConfig::default(), None)?;

    // 3. summarize
    let t0 = std::time::Instant::now();
    let summary = pipeline.summarize(&doc)?;
    let wall = t0.elapsed();

    println!("\nsummary (sentences {:?}):", summary.selected);
    for s in &summary.sentences {
        println!("  - {s}");
    }

    // 4. how good is it? normalize against the exact optimum (Eq. 13)
    let problem = pipeline.problem_for(&doc)?;
    let bounds = exact_bounds(&problem);
    println!(
        "\nobjective {:.4} -> normalized {:.3} (exact optimum {:.4})",
        summary.objective,
        bounds.normalize(summary.objective),
        bounds.max
    );
    println!(
        "{} decomposition stages, {} COBI solves, {:.1} ms wall",
        summary.stages,
        summary.total_solves,
        wall.as_secs_f64() * 1e3
    );
    Ok(())
}
