//! # What it demonstrates
//!
//! The resilience subsystem keeping summaries correct on degraded
//! hardware: the same bench_10 documents summarized three ways —
//!
//!   1. a **clean** COBI device (the baseline);
//!   2. the same device with a seeded fault model injecting **2% stuck
//!      oscillators** (plus mild coupling drift) and NO mitigation: the
//!      raw readout degrades;
//!   3. the faulty device wrapped in the `ResilientSolver` —
//!      replication-3 energy-verified voting + greedy spin-repair —
//!      which recovers the clean summaries.
//!
//! Every fault draw derives from the request seed (DESIGN.md decision
//! #16), so all three runs are byte-reproducible.
//!
//!     cargo run --release --example degraded_device
//!
//! # Expected output
//!
//! One line per document with the normalized objective of each run, then
//! a summary block: the `faulty` column dips below `clean` on some
//! documents, the `resilient` column matches (or beats) `clean`, and the
//! resilience counter line shows replicated solves, vote disagreements,
//! repairs, and the injected fault totals.

use cobi_es::config::{FaultConfig, ResilienceConfig, Settings};
use cobi_es::corpus::benchmark_set;
use cobi_es::embed::{Embedder, HashEmbedder};
use cobi_es::ising::{exact_bounds, EsProblem};
use cobi_es::resilience::{FaultModel, ResilienceShared, ResilientSolver};
use cobi_es::sched::pool::PoolSolver;
use cobi_es::sched::{doc_seed, summarize_sequential};

fn main() -> anyhow::Result<()> {
    let settings = Settings::default();
    let fault = FaultConfig {
        enabled: true,
        stuck_rate: 0.02,
        drift_rate: 0.01,
        ..Default::default()
    };
    let resilience = ResilienceConfig {
        enabled: true,
        replication: 3,
        ..Default::default()
    };

    let clean_device =
        || cobi_es::cobi::CobiDevice::native(settings.cobi.clone(), 0);
    let faulty_device = || {
        let mut d = clean_device();
        d.set_fault_model(FaultModel::new(&fault));
        d
    };
    let shared = ResilienceShared::new();
    let mut clean: Box<dyn PoolSolver> = Box::new(clean_device());
    let mut faulty: Box<dyn PoolSolver> = Box::new(faulty_device());
    let mut resilient: Box<dyn PoolSolver> = {
        // the wrapped device feeds the shared fault counters, so the
        // final report shows what was actually injected
        let mut inner = faulty_device();
        inner.share_fault_counters(shared.faults.clone());
        Box::new(ResilientSolver::new(
            Box::new(inner),
            &resilience,
            shared.clone(),
        ))
    };

    println!(
        "degraded device demo: {:.0}% stuck oscillators, {:.0}% coupling drift, \
         replication {} voting\n",
        fault.stuck_rate * 100.0,
        fault.drift_rate * 100.0,
        resilience.replication,
    );
    println!("{:<10} {:>8} {:>8} {:>10}", "document", "clean", "faulty", "resilient");

    let set = benchmark_set("bench_10")?;
    let mut embedder = HashEmbedder::new();
    let mut sums = [0.0f64; 3];
    for doc in &set.documents {
        let scores = embedder.scores(&doc.sentences)?;
        let problem = EsProblem {
            mu: scores.mu,
            beta: scores.beta,
            lambda: settings.pipeline.lambda,
            m: set.summary_len,
        };
        let bounds = exact_bounds(&problem);
        let mut cfg = settings.pipeline.clone();
        cfg.iterations = 4;
        cfg.summary_len = set.summary_len;
        cfg.seed = doc_seed(cfg.seed, &doc.id);

        let norm = |solver: &mut Box<dyn PoolSolver>| -> anyhow::Result<f64> {
            let summary = summarize_sequential(doc, &cfg, solver.as_mut())?;
            Ok(bounds.normalize(summary.objective))
        };
        let c = norm(&mut clean)?;
        let f = norm(&mut faulty)?;
        let r = norm(&mut resilient)?;
        sums[0] += c;
        sums[1] += f;
        sums[2] += r;
        println!("{:<10} {c:>8.4} {f:>8.4} {r:>10.4}", doc.id);
    }

    let n = set.documents.len() as f64;
    println!(
        "\nmean normalized objective: clean {:.4} | faulty {:.4} | resilient {:.4}",
        sums[0] / n,
        sums[1] / n,
        sums[2] / n,
    );
    let m = shared.snapshot();
    println!("{}", m.report());
    if sums[2] >= sums[0] - 1e-6 {
        println!("voting + spin-repair recovered the clean quality.");
    } else {
        println!(
            "voting recovered {:.4} of the {:.4} clean baseline.",
            sums[2] / n,
            sums[0] / n
        );
    }
    Ok(())
}
