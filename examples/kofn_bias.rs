//! # What it demonstrates
//!
//! k-of-n generality: the paper's bias-shift (Eq. 10-12) applied beyond
//! summarization — facility dispersion (vehicle-routing flavoured [14])
//! and influence-style seed selection [15]. For each workload it
//! formulates original vs improved, quantizes to the COBI int14 grid,
//! solves on the simulated device and reports the normalized objective —
//! the §III-B robustness story on non-ES problems.
//!
//!     cargo run --release --example kofn_bias
//!
//! # Expected output
//!
//! Two sections (facility dispersion, influence seed selection), each
//! with one line per formulation: the improved (bias-shift) row shows a
//! markedly smaller median |h-J| imbalance and an equal-or-better mean
//! normalized objective than the original row, followed by a one-line
//! takeaway about surviving 5-bit quantization.

use cobi_es::cobi::CobiDevice;
use cobi_es::config::CobiConfig;
use cobi_es::ising::kofn::{facility_dispersion, influence_seeds, KofnProblem};
use cobi_es::ising::{exact_bounds, selected_indices};
use cobi_es::quant::{quantize, Precision, Rounding};
use cobi_es::refine::repair_selection;
use cobi_es::solvers::IsingSolver;
use cobi_es::util::rng::Pcg32;
use cobi_es::util::stats::{mean, median_f32};

fn evaluate(name: &str, problems: &[KofnProblem]) {
    println!("\n== {name} ({} instances, k-of-n on COBI int14) ==", problems.len());
    for improved in [false, true] {
        let mut norms = Vec::new();
        let mut imbalance = Vec::new();
        for (idx, p) in problems.iter().enumerate() {
            let es = p.as_es();
            let bounds = exact_bounds(&es);
            let ising = p.formulate(improved);
            imbalance.push(
                (median_f32(&ising.h) - median_f32(&ising.upper_couplings())).abs() as f64,
            );
            let mut best = f64::NEG_INFINITY;
            let mut rng = Pcg32::seeded(900 + idx as u64);
            let mut dev = CobiDevice::native(CobiConfig::default(), 40 + idx as u64);
            for _ in 0..8 {
                let inst = quantize(&ising, Precision::CobiInt, Rounding::Stochastic, &mut rng);
                let solved = dev.solve(&inst);
                let sel = repair_selection(&es, selected_indices(&solved.spins));
                best = best.max(bounds.normalize(es.objective(&sel)));
            }
            norms.push(best);
        }
        println!(
            "  {:<22} mean normalized objective {:.3} | median |h-J| imbalance {:.2}",
            if improved { "improved (bias shift)" } else { "original" },
            mean(&norms),
            mean(&imbalance),
        );
    }
}

fn main() {
    let mut rng = Pcg32::seeded(1);
    let dispersion: Vec<KofnProblem> =
        (0..6).map(|_| facility_dispersion(&mut rng, 18, 5)).collect();
    evaluate("facility dispersion", &dispersion);

    let mut rng = Pcg32::seeded(2);
    let influence: Vec<KofnProblem> =
        (0..6).map(|_| influence_seeds(&mut rng, 16, 4, 128)).collect();
    evaluate("influence seed selection", &influence);

    println!("\nthe bias shift collapses the h/J scale gap on any k-of-n \
              selection QUBO, which is what survives 5-bit quantization.");
}
