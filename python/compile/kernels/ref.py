"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its oracle to float32 tolerance under pytest (see
python/tests/test_kernels.py). They are also small enough to read as the
mathematical specification of each kernel.
"""

import jax.numpy as jnp

__all__ = [
    "cosine_matrix_ref",
    "relevance_ref",
    "oscillator_step_ref",
    "energy_batch_ref",
]


def cosine_matrix_ref(emb):
    """All-pairs cosine similarity of row vectors.

    Args:
      emb: f32[n, d] sentence embeddings (not necessarily normalized).

    Returns:
      f32[n, n] with C[i, j] = cos(e_i, e_j)  (paper Eq. 2).
    """
    norms = jnp.sqrt(jnp.sum(emb * emb, axis=-1, keepdims=True))
    unit = emb / jnp.maximum(norms, 1e-12)
    return unit @ unit.T


def relevance_ref(emb, mask):
    """Relevance scores mu_i = cos(e_i, mean(e_doc))  (paper Eq. 1).

    Args:
      emb:  f32[n, d] embeddings.
      mask: f32[n] 1.0 for real sentences, 0.0 for padding; the document
            mean embedding is taken over real sentences only.

    Returns:
      f32[n] relevance scores (padding rows get the cosine against the mean
      too; the caller masks them out).
    """
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    doc = jnp.sum(emb * mask[:, None], axis=0) / denom
    doc_n = doc / jnp.maximum(jnp.linalg.norm(doc), 1e-12)
    norms = jnp.sqrt(jnp.sum(emb * emb, axis=-1))
    unit = emb / jnp.maximum(norms, 1e-12)[:, None]
    return unit @ doc_n


def oscillator_step_ref(phase, j_mat, h_vec, k_c, k_s, dt, noise):
    """One explicit-Euler step of the coupled-oscillator (COBI) dynamics.

    Generalized Kuramoto network with second-harmonic injection locking
    (SHIL), the standard behavioural model for ring-oscillator Ising
    machines [Lo et al., Nat. Electronics 2023]:

        dphi_i/dt = +k_c * ( sum_j J_ij sin(phi_i - phi_j) + h_i sin(phi_i) )
                    -k_s * sin(2 phi_i) + noise_i

    This is gradient descent on the phase Lyapunov function
        E(phi) = sum_{i<j} J_ij cos(phi_i - phi_j) + sum_i h_i cos(phi_i),
    which at SHIL-binarized fixed points (phi in {0, pi}, s_i = cos phi_i)
    equals the Ising Hamiltonian H(s) — so the network settles into low-H
    configurations.

    The pairwise sum uses sin(a-b) = sin a cos b - cos a sin b so the O(n^2)
    interaction becomes two dense mat-vecs (J @ cos phi, J @ sin phi) — the
    MXU-friendly form the Pallas kernel tiles.

    The local field h couples each spin to a virtual reference oscillator
    pinned at phase 0, the usual trick for mapping Ising h terms onto
    phase hardware.

    Args:
      phase: f32[n] oscillator phases (radians).
      j_mat: f32[n, n] symmetric coupling matrix, zero diagonal.
      h_vec: f32[n] local fields.
      k_c:   coupling strength (scalar).
      k_s:   SHIL (binarization) strength (scalar, annealed 0 -> max).
      dt:    Euler step.
      noise: f32[n] additive phase noise for this step.

    Returns:
      f32[n] updated phases, wrapped to (-pi, pi].
    """
    s = jnp.sin(phase)
    c = jnp.cos(phase)
    # sum_j J_ij sin(phi_i - phi_j) = s_i * (J c)_i - c_i * (J s)_i
    coupling = s * (j_mat @ c) - c * (j_mat @ s)
    local = h_vec * s
    dphi = k_c * (coupling + local) - k_s * jnp.sin(2.0 * phase) + noise
    out = phase + dt * dphi
    # wrap to (-pi, pi] to keep trig arguments well-conditioned over long runs
    return jnp.mod(out + jnp.pi, 2.0 * jnp.pi) - jnp.pi


def energy_batch_ref(j_mat, h_vec, spins):
    """Ising energies for a batch of spin configurations (paper Eq. 4).

        H(s) = sum_i h_i s_i + sum_{i != j} J_ij s_i s_j

    J is symmetric with zero diagonal and stores each pair in both (i,j)
    and (j,i), so the pair sum equals s^T J s.

    Args:
      j_mat: f32[n, n].
      h_vec: f32[n].
      spins: f32[b, n] entries in {-1, +1} (padding spins frozen at -1 with
             zero couplings contribute a constant the caller ignores).

    Returns:
      f32[b] energies.
    """
    pair = jnp.einsum("bi,ij,bj->b", spins, j_mat, spins)
    local = spins @ h_vec
    return local + pair
