"""Pallas kernel: batched Ising energy evaluation.

Used by the iterative-refinement loop (L3 refine::) to score candidate spin
configurations under the floating-point Hamiltonian in one shot, instead of
b sequential O(n^2) evaluations on the CPU hot path.

TPU mapping: H(s) = h.s + s^T J s is computed per batch tile as one
(block_b, n) @ (n, n) MXU matmul followed by a row-wise fused
multiply-reduce on the VPU. J (n = 64 -> 16 KiB) stays VMEM-resident across
the whole batch grid.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["energy_batch"]


def _energy_kernel(j_ref, h_ref, s_ref, out_ref):
    """Energies for one (block_b, n) tile of spin configurations."""
    j_mat = j_ref[...]
    h_vec = h_ref[...]
    s = s_ref[...]
    # (block_b, n) @ (n, n) -> (block_b, n) on the MXU.
    sj = jax.lax.dot_general(
        s,
        j_mat,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    pair = jnp.sum(sj * s, axis=-1)
    local = jnp.sum(s * h_vec[None, :], axis=-1)
    out_ref[...] = local + pair


def energy_batch(j_mat, h_vec, spins, *, block_b: int = 32, interpret=True):
    """Batched Ising energies: (f32[n,n], f32[n], f32[b,n]) -> f32[b].

    Matches ref.energy_batch_ref. b must be a multiple of block_b (callers
    pad with copies of row 0 and drop the tail).
    """
    b, n = spins.shape
    if j_mat.shape != (n, n) or h_vec.shape != (n,):
        raise ValueError("inconsistent energy shapes")
    if b % block_b != 0:
        raise ValueError(f"batch {b} not a multiple of block_b={block_b}")
    return pl.pallas_call(
        _energy_kernel,
        grid=(b // block_b,),
        in_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),  # J resident across grid
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((block_b, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=interpret,
    )(j_mat, h_vec, spins)
