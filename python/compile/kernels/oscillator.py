"""Pallas kernel: coupled-oscillator (COBI) phase-update step.

This is the L1 hot-spot of the *solver* half of the pipeline: one explicit
Euler step of the generalized-Kuramoto + SHIL dynamics that model the COBI
ring-oscillator array (see kernels/ref.py:oscillator_step_ref for the
mathematical specification and DESIGN.md §Substitutions for why this is the
right behavioural model of the chip).

TPU mapping: the pairwise term sum_j J_ij sin(phi_i - phi_j) is rewritten
as  s .* (J @ c) - c .* (J @ s)  with s = sin(phi), c = cos(phi), so the
kernel is two dense mat-vecs plus elementwise VPU work. At the COBI problem
size (n = 64 after padding) the whole J matrix is a single 16 KiB VMEM tile,
so the kernel runs as one block and the *time* loop lives at L2 as a
lax.scan over this kernel (python/compile/model.py:cobi_anneal). For larger
n the grid tiles J by rows (block_n x n), accumulating partial mat-vecs.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["oscillator_step"]


def _step_kernel(phase_ref, j_ref, h_ref, kparams_ref, noise_ref, out_ref):
    """Single-block phase update for n <= MAX_SINGLE_BLOCK spins.

    kparams packs (k_c, k_s, dt) as f32[3]; scalars travel as a tiny vector
    so the HLO signature stays all-tensor (friendlier for the rust runtime).
    """
    phase = phase_ref[...]
    j_mat = j_ref[...]
    h_vec = h_ref[...]
    kp = kparams_ref[...]
    noise = noise_ref[...]
    k_c, k_s, dt = kp[0], kp[1], kp[2]

    s = jnp.sin(phase)
    c = jnp.cos(phase)
    # Two MXU mat-vecs: J @ cos(phi), J @ sin(phi).
    jc = j_mat @ c
    js = j_mat @ s
    coupling = s * jc - c * js
    local = h_vec * s
    # +k_c: gradient *descent* on the phase Lyapunov function whose
    # binarized fixed points carry the Ising energy (see ref.py).
    dphi = k_c * (coupling + local) - k_s * jnp.sin(2.0 * phase) + noise
    out = phase + dt * dphi
    out_ref[...] = jnp.mod(out + jnp.pi, 2.0 * jnp.pi) - jnp.pi


def oscillator_step(phase, j_mat, h_vec, kparams, noise, *, interpret=True):
    """One dynamics step: (f32[n], f32[n,n], f32[n], f32[3], f32[n]) -> f32[n].

    Matches ref.oscillator_step_ref(phase, J, h, kp[0], kp[1], kp[2], noise).
    n = 64 is the COBI-padded problem size; the single-block layout keeps
    J, phases and trig intermediates resident in VMEM for the whole step.
    """
    n = phase.shape[0]
    if j_mat.shape != (n, n) or h_vec.shape != (n,) or noise.shape != (n,):
        raise ValueError("inconsistent oscillator shapes")
    return pl.pallas_call(
        _step_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(phase, j_mat, h_vec, kparams, noise)
