"""Pallas kernel: tiled all-pairs cosine-similarity matrix.

This is the L1 hot-spot of the *embedding* half of the pipeline: given a
batch of sentence embeddings it produces the dense redundancy matrix
beta_ij = cos(e_i, e_j) (paper Eq. 2) that every Ising formulation consumes.

TPU mapping (DESIGN.md §Hardware-Adaptation): the kernel normalizes rows
once into VMEM scratch and then walks the (M, N) output grid in
(block_m, block_n) tiles, each tile a (block_m, d) @ (d, block_n) MXU
matmul. On GPU the paper's SBERT stack would have hit cuBLAS; here the
BlockSpec expresses the HBM->VMEM schedule explicitly.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, and correctness (vs ref.cosine_matrix_ref) is the build-time
contract. Real-TPU perf is estimated analytically in EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref  # noqa: F401  (documentation cross-ref)

__all__ = ["cosine_matrix", "normalize_rows"]


def _normalize_kernel(emb_ref, out_ref):
    """Row-normalize a (block_m, d) tile: u_i = e_i / max(||e_i||, eps)."""
    block = emb_ref[...]
    sq = jnp.sum(block * block, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(jnp.maximum(sq, 1e-24))
    out_ref[...] = block * inv


def normalize_rows(emb, *, block_m: int = 64, interpret: bool = True):
    """L2-normalize each row of emb: f32[n, d] -> f32[n, d].

    n must be a multiple of block_m (callers pad; padding rows are zero and
    normalize to zero, matching the eps-guarded reference).
    """
    n, d = emb.shape
    if n % block_m != 0:
        raise ValueError(f"n={n} not a multiple of block_m={block_m}")
    return pl.pallas_call(
        _normalize_kernel,
        grid=(n // block_m,),
        in_specs=[pl.BlockSpec((block_m, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_m, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=interpret,
    )(emb)


def _gram_kernel(a_ref, b_ref, out_ref):
    """One (block_m, block_n) output tile of U @ U^T.

    a_ref: (block_m, d) row tile of the normalized embeddings.
    b_ref: (block_n, d) column tile (same matrix, different rows).
    """
    a = a_ref[...]
    b = b_ref[...]
    out_ref[...] = jax.lax.dot_general(
        a,
        b,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def cosine_matrix(
    emb,
    *,
    block_m: int = 64,
    block_n: int = 64,
    interpret: bool = True,
):
    """All-pairs cosine similarity: f32[n, d] -> f32[n, n].

    Two-stage Pallas pipeline: row normalization (VPU) then a tiled Gram
    matmul (MXU). Matches ref.cosine_matrix_ref to f32 tolerance.
    """
    n, d = emb.shape
    if n % block_m != 0 or n % block_n != 0:
        raise ValueError(f"n={n} must tile by ({block_m}, {block_n})")
    unit = normalize_rows(emb, block_m=block_m, interpret=interpret)
    grid = (n // block_m, n // block_n)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(unit, unit)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def cosine_matrix_jit(emb, block_m: int = 64, block_n: int = 64):
    """jit wrapper used by the AOT path and tests."""
    return cosine_matrix(emb, block_m=block_m, block_n=block_n)
