"""L2 — the JAX compute graphs AOT-compiled for the Rust coordinator.

Four graphs, each lowered to an HLO-text artifact by aot.py:

  encode_batch : tokens i32[B, T]                 -> emb f32[B, D]
  cosine_graph : emb f32[B, D], mask f32[B]       -> (mu f32[B], beta f32[B, B])
  cobi_anneal  : J f32[N, N], h f32[N],
                 phase0 f32[N], noise f32[S, N]   -> spins f32[N]
  energy_batch : J f32[N, N], h f32[N], s f32[C,N]-> e f32[C]

Shapes are static (PJRT AOT requires it): B=128 sentences, T=32 tokens,
D=64 embedding dims, N=64 COBI-padded spins, S=256 anneal steps, C=32
candidate configurations per energy batch. Rust pads/crops to these.

The sentence encoder is the paper's Sentence-BERT *substitute* (DESIGN.md
§Substitutions): a deterministically-initialized hashed-token transformer.
Its weights are constants folded into the HLO, so the artifact is fully
self-contained — no checkpoint, no Python at run time.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import cosine_sim, energy, oscillator
from .kernels import ref as kref

# ---------------------------------------------------------------------------
# Static dimensions (must match rust/src/runtime/artifacts.rs)
# ---------------------------------------------------------------------------
MAX_SENTENCES = 128   # B: encoder/cosine batch
MAX_TOKENS = 32       # T: tokens per sentence (hash-padded)
VOCAB = 4096          # hashed vocabulary size (FNV-1a mod VOCAB, 0 = pad)
EMBED_DIM = 64        # D
N_SPINS = 64          # N: COBI problem size after padding (device has 59)
ANNEAL_STEPS = 256    # S: Euler steps per hardware solve
ENERGY_BATCH = 32     # C: candidates per energy_batch call

# Default dynamics constants for the annealer; calibrated so that 10..20
# spin instances reach the ground state with probability well inside
# (0.3, 0.95) per run — the "handful of retries" regime the paper reports
# for COBI. The rust device model passes these in at run time (kparams), so
# recalibration never requires re-AOT.
K_COUPLING = 2.0
K_SHIL_MAX = 1.5
DT = 0.05

_PARAM_SEED = 42


# ---------------------------------------------------------------------------
# Encoder parameters (deterministic, folded into the artifact as constants)
# ---------------------------------------------------------------------------
def encoder_params():
    """Deterministically-initialized encoder weights.

    One transformer block (single-head self-attention + GELU MLP) over a
    hashed-token embedding table. Scaled-orthogonal-ish gaussian init; the
    *statistics* of the resulting cosine geometry are what matter (dense,
    all-pairs-positive similarities like SBERT), not trained quality.

    Deliberately NOT cached: under jit-tracing the draws stage into the
    graph (threefry ops -> constant-folded by XLA at compile time), and a
    cache would leak tracers into later eager calls. Threefry is
    deterministic, so eager and traced paths agree bit-for-bit.
    """
    key = jax.random.PRNGKey(_PARAM_SEED)
    ks = jax.random.split(key, 8)
    d = EMBED_DIM
    scale = d ** -0.5
    return {
        "tok": jax.random.normal(ks[0], (VOCAB, d)) * 1.0,
        "pos": jax.random.normal(ks[1], (MAX_TOKENS, d)) * 0.3,
        "wq": jax.random.normal(ks[2], (d, d)) * scale,
        "wk": jax.random.normal(ks[3], (d, d)) * scale,
        "wv": jax.random.normal(ks[4], (d, d)) * scale,
        "wo": jax.random.normal(ks[5], (d, d)) * scale,
        "w1": jax.random.normal(ks[6], (d, 2 * d)) * scale,
        "w2": jax.random.normal(ks[7], (2 * d, d)) * (2 * d) ** -0.5,
    }


def _layer_norm(x, eps=1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps)


def _attention(x, mask, p):
    """Single-head masked self-attention over one sentence. x: [T, D]."""
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    logits = (q @ k.T) * (EMBED_DIM ** -0.5)
    neg = jnp.finfo(jnp.float32).min
    logits = jnp.where(mask[None, :] > 0, logits, neg)
    att = jax.nn.softmax(logits, axis=-1)
    # Rows attending over fully-masked keys produce uniform garbage; zero
    # them via the query-side mask at pooling time instead.
    return (att @ v) @ p["wo"]


def _encode_sentence(tokens, p):
    """tokens i32[T] -> embedding f32[D] (masked mean over token states)."""
    mask = (tokens > 0).astype(jnp.float32)
    x = p["tok"][tokens] + p["pos"]
    x = x + _attention(_layer_norm(x), mask, p)
    h = _layer_norm(x)
    x = x + jax.nn.gelu(h @ p["w1"]) @ p["w2"]
    x = _layer_norm(x)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    pooled = jnp.sum(x * mask[:, None], axis=0) / denom
    return pooled


def encode_batch(tokens):
    """tokens i32[B, T] -> emb f32[B, D]. Padding sentences (all-zero token
    rows) produce near-zero embeddings the cosine graph's eps guards absorb."""
    p = encoder_params()
    return (jax.vmap(lambda t: _encode_sentence(t, p))(tokens),)


def cosine_graph(emb, mask):
    """(emb f32[B, D], mask f32[B]) -> (mu f32[B], beta f32[B, B]).

    mu via the pure-jnp relevance reference (a handful of FLOPs), beta via
    the tiled Pallas cosine kernel — the quadratic hot-spot.
    """
    mu = kref.relevance_ref(emb, mask)
    beta = cosine_sim.cosine_matrix(emb, block_m=64, block_n=64)
    return (mu, beta)


def cobi_anneal(j_mat, h_vec, phase0, noise, kparams):
    """Full COBI solve: anneal the oscillator network, read out spins.

    lax.scan over the L1 Pallas step kernel with a linear SHIL ramp
    (k_s: 0 -> K_SHIL_MAX) and the externally-supplied per-step phase noise
    (Rust owns the RNG so runs are reproducible from the coordinator side).

    The Hamiltonian is scale-normalized internally (argmin is invariant to
    positive scaling), so one (K_COUPLING, DT) calibration covers every
    problem regardless of coefficient magnitude — the same role the
    programmable coupling DAC range plays on the real chip.

    Returns spins f32[N] in {-1, +1}: s_i = sign(cos(phi_i)).
    """
    steps = noise.shape[0]
    scale = jnp.maximum(jnp.max(jnp.abs(j_mat)), jnp.max(jnp.abs(h_vec)))
    scale = jnp.maximum(scale, 1e-12)
    j_mat = j_mat / scale
    h_vec = h_vec / scale
    k_c, ks_max, dt = kparams[0], kparams[1], kparams[2]
    ramp = (jnp.arange(steps, dtype=jnp.float32) / jnp.float32(steps)) * ks_max

    def body(phase, inputs):
        k_s, step_noise = inputs
        kp = jnp.stack([k_c, k_s, dt])
        nxt = oscillator.oscillator_step(phase, j_mat, h_vec, kp, step_noise)
        return nxt, ()

    final, _ = jax.lax.scan(body, phase0, (ramp, noise))
    spins = jnp.where(jnp.cos(final) >= 0.0, 1.0, -1.0).astype(jnp.float32)
    return (spins,)


def energy_batch(j_mat, h_vec, spins):
    """Batched FP Ising energies via the L1 energy kernel."""
    return (energy.energy_batch(j_mat, h_vec, spins, block_b=ENERGY_BATCH),)


# Batched anneal: ANNEAL_BATCH independent instances per PJRT dispatch.
# The refinement loop solves one quantized instance per iteration; those
# instances are independent, so the rust coordinator batches them into a
# single call — one dispatch instead of ANNEAL_BATCH (the §Perf L3 win).
ANNEAL_BATCH = 8


def cobi_anneal_batch(j_mats, h_vecs, phase0s, noises, kparams):
    """vmap of cobi_anneal over a leading batch axis.

    Shapes: j f32[B,N,N], h f32[B,N], phase0 f32[B,N], noise f32[B,S,N],
    kparams f32[3] (shared) -> spins f32[B,N].
    """
    fn = lambda j, h, p, nz: cobi_anneal(j, h, p, nz, kparams)[0]
    return (jax.vmap(fn)(j_mats, h_vecs, phase0s, noises),)


# ---------------------------------------------------------------------------
# Example-argument specs for AOT lowering (aot.py) and tests
# ---------------------------------------------------------------------------
def abstract_inputs(name):
    f32 = jnp.float32
    i32 = jnp.int32
    B, T, D = MAX_SENTENCES, MAX_TOKENS, EMBED_DIM
    N, S, C = N_SPINS, ANNEAL_STEPS, ENERGY_BATCH
    sd = jax.ShapeDtypeStruct
    specs = {
        "encoder": (sd((B, T), i32),),
        "cosine": (sd((B, D), f32), sd((B,), f32)),
        "anneal": (
            sd((N, N), f32),
            sd((N,), f32),
            sd((N,), f32),
            sd((S, N), f32),
            sd((3,), f32),
        ),
        "anneal_batch": (
            sd((ANNEAL_BATCH, N, N), f32),
            sd((ANNEAL_BATCH, N), f32),
            sd((ANNEAL_BATCH, N), f32),
            sd((ANNEAL_BATCH, S, N), f32),
            sd((3,), f32),
        ),
        "energy": (sd((N, N), f32), sd((N,), f32), sd((C, N), f32)),
    }
    return specs[name]


GRAPHS = {
    "encoder": encode_batch,
    "cosine": cosine_graph,
    "anneal": cobi_anneal,
    "anneal_batch": cobi_anneal_batch,
    "energy": energy_batch,
}
