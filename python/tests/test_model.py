"""L2 model invariants: encoder geometry, cosine graph, anneal behaviour."""

import itertools

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

F32 = np.float32


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(123)
    t = rng.integers(1, model.VOCAB, size=(model.MAX_SENTENCES,
                                           model.MAX_TOKENS)).astype(np.int32)
    # vary sentence lengths: zero-pad tails
    for i in range(model.MAX_SENTENCES):
        ln = rng.integers(4, model.MAX_TOKENS)
        t[i, ln:] = 0
    # last 28 rows are padding sentences
    t[100:] = 0
    return t


@pytest.fixture(scope="module")
def emb(tokens):
    return np.asarray(model.encode_batch(jnp.asarray(tokens))[0])


class TestEncoder:
    def test_shapes_and_finite(self, emb):
        assert emb.shape == (model.MAX_SENTENCES, model.EMBED_DIM)
        assert np.all(np.isfinite(emb))

    def test_deterministic(self, tokens, emb):
        again = np.asarray(model.encode_batch(jnp.asarray(tokens))[0])
        np.testing.assert_array_equal(emb, again)

    def test_distinct_sentences_distinct_embeddings(self, emb):
        # no two real sentences should collapse to the same vector
        real = emb[:100]
        norms = np.linalg.norm(real, axis=1)
        assert np.all(norms > 1e-3)
        gram = (real / norms[:, None]) @ (real / norms[:, None]).T
        off = gram - np.eye(100)
        assert np.max(off) < 0.999, "two sentences embedded identically"

    def test_token_permutation_changes_embedding(self):
        """Attention + positions: order must matter."""
        t = np.zeros((model.MAX_SENTENCES, model.MAX_TOKENS), np.int32)
        t[0, :6] = [5, 9, 13, 101, 7, 3]
        t[1, :6] = [3, 7, 101, 13, 9, 5]
        e = np.asarray(model.encode_batch(jnp.asarray(t))[0])
        assert np.linalg.norm(e[0] - e[1]) > 1e-3

    def test_sbert_like_positive_similarity(self, emb):
        """Substitution fidelity: like SBERT news embeddings, same-document
        sentence pairs should be mostly positively correlated (dense beta)."""
        beta = np.asarray(ref.cosine_matrix_ref(jnp.asarray(emb[:100])))
        frac_pos = float((beta > 0).mean())
        assert frac_pos > 0.9


class TestCosineGraph:
    def test_outputs(self, emb):
        mask = np.zeros(model.MAX_SENTENCES, F32)
        mask[:100] = 1.0
        mu, beta = model.cosine_graph(jnp.asarray(emb), jnp.asarray(mask))
        mu, beta = np.asarray(mu), np.asarray(beta)
        assert mu.shape == (model.MAX_SENTENCES,)
        assert beta.shape == (model.MAX_SENTENCES, model.MAX_SENTENCES)
        assert np.all(np.abs(mu[:100]) <= 1 + 1e-5)
        np.testing.assert_allclose(np.diag(beta)[:100], 1.0, atol=1e-4)

    def test_matches_refs(self, emb):
        mask = np.ones(model.MAX_SENTENCES, F32)
        mu, beta = model.cosine_graph(jnp.asarray(emb), jnp.asarray(mask))
        np.testing.assert_allclose(
            np.asarray(mu),
            np.asarray(ref.relevance_ref(jnp.asarray(emb), jnp.asarray(mask))),
            rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(beta),
            np.asarray(ref.cosine_matrix_ref(jnp.asarray(emb))),
            rtol=1e-5, atol=1e-5)


def pad_ising(j, h):
    n = len(h)
    J = np.zeros((model.N_SPINS, model.N_SPINS), F32)
    H = np.zeros(model.N_SPINS, F32)
    J[:n, :n] = j
    H[:n] = h
    return J, H


def exact_ground(j, h):
    n = len(h)
    return min(
        float(h @ s + s @ j @ s)
        for bits in itertools.product([-1.0, 1.0], repeat=n)
        for s in [np.array(bits, F32)]
    )


class TestAnneal:
    KP = jnp.asarray([model.K_COUPLING, model.K_SHIL_MAX, model.DT], jnp.float32)

    def _run(self, J, H, seed):
        rng = np.random.default_rng(seed)
        ph = rng.uniform(-np.pi, np.pi, model.N_SPINS).astype(F32)
        noise = (rng.standard_normal((model.ANNEAL_STEPS, model.N_SPINS))
                 * 0.1).astype(F32)
        out = model.cobi_anneal(jnp.asarray(J), jnp.asarray(H),
                                jnp.asarray(ph), jnp.asarray(noise), self.KP)[0]
        return np.asarray(out)

    def test_output_is_binary(self):
        rng = np.random.default_rng(0)
        j = rng.standard_normal((model.N_SPINS, model.N_SPINS)).astype(F32)
        j = (j + j.T) / 2
        np.fill_diagonal(j, 0)
        h = rng.standard_normal(model.N_SPINS).astype(F32)
        s = self._run(j, h, 1)
        assert set(np.unique(s)).issubset({-1.0, 1.0})

    def test_ferromagnet_aligns(self):
        """Strong uniform negative coupling (J<0 favours alignment in our
        minimization convention): all real spins end up equal."""
        n = 8
        j = -np.ones((n, n), F32) * 2.0
        np.fill_diagonal(j, 0)
        J, H = pad_ising(j, np.zeros(n, F32))
        hits = 0
        for seed in range(6):
            s = self._run(J, H, seed)[:n]
            if abs(float(np.sum(s))) == n:
                hits += 1
        assert hits >= 5

    def test_field_polarizes(self):
        """Large negative h_i -> spin +1 (minimizes h_i s_i)."""
        n = 6
        h = np.array([-3, -3, -3, 3, 3, 3], F32)
        J, H = pad_ising(np.zeros((n, n), F32), h)
        s = self._run(J, H, 3)[:n]
        assert np.all(s[:3] == 1.0) and np.all(s[3:] == -1.0)

    def test_ground_state_hit_rate_in_retry_regime(self):
        """DESIGN.md decision #3: mean per-run ground-state probability over
        random 10-spin glass instances must sit in (0.25, 0.98) —
        stochastic like the chip (hard instances may dip low), good enough
        to converge with a handful of retries."""
        n = 10
        total_hits, total_runs = 0, 0
        for inst_seed in (1, 2, 3, 42):
            rng = np.random.default_rng(inst_seed)
            j = rng.standard_normal((n, n)).astype(F32)
            j = (j + j.T) / 2
            np.fill_diagonal(j, 0)
            h = rng.standard_normal(n).astype(F32)
            best = exact_ground(j, h)
            J, H = pad_ising(j, h)
            for seed in range(10):
                s = self._run(J, H, seed)[:n]
                e = float(h @ s + s @ j @ s)
                total_hits += abs(e - best) < 1e-3
                total_runs += 1
        rate = total_hits / total_runs
        assert 0.25 <= rate <= 0.98, f"mean hit rate {rate}"

    def test_scale_invariance(self):
        """Internal normalization: scaling (J, h) by 37x must not change
        the solved configuration for the same noise stream."""
        rng = np.random.default_rng(9)
        n = 8
        j = rng.standard_normal((n, n)).astype(F32)
        j = (j + j.T) / 2
        np.fill_diagonal(j, 0)
        h = rng.standard_normal(n).astype(F32)
        J1, H1 = pad_ising(j, h)
        J2, H2 = pad_ising(j * 37.0, h * 37.0)
        s1 = self._run(J1, H1, 5)
        s2 = self._run(J2, H2, 5)
        np.testing.assert_array_equal(s1, s2)


class TestEnergyGraph:
    def test_matches_ref(self):
        rng = np.random.default_rng(2)
        j = rng.standard_normal((model.N_SPINS, model.N_SPINS)).astype(F32)
        j = (j + j.T) / 2
        np.fill_diagonal(j, 0)
        h = rng.standard_normal(model.N_SPINS).astype(F32)
        s = np.where(rng.uniform(size=(model.ENERGY_BATCH, model.N_SPINS)) > .5,
                     1.0, -1.0).astype(F32)
        got = model.energy_batch(jnp.asarray(j), jnp.asarray(h), jnp.asarray(s))[0]
        want = ref.energy_batch_ref(jnp.asarray(j), jnp.asarray(h), jnp.asarray(s))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-3)


class TestAnnealBatch:
    def test_batch_rows_match_single(self):
        rng = np.random.default_rng(77)
        B, N, S = model.ANNEAL_BATCH, model.N_SPINS, model.ANNEAL_STEPS
        j = rng.standard_normal((B, N, N)).astype(F32)
        j = (j + j.transpose(0, 2, 1)) / 2
        h = rng.standard_normal((B, N)).astype(F32)
        p0 = rng.uniform(-np.pi, np.pi, (B, N)).astype(F32)
        nz = (rng.standard_normal((B, S, N)) * 0.1).astype(F32)
        kp = jnp.asarray([model.K_COUPLING, model.K_SHIL_MAX, model.DT],
                         jnp.float32)
        batch = np.asarray(model.cobi_anneal_batch(
            jnp.asarray(j), jnp.asarray(h), jnp.asarray(p0), jnp.asarray(nz),
            kp)[0])
        assert batch.shape == (B, N)
        assert set(np.unique(batch)).issubset({-1.0, 1.0})
        for b in (0, B - 1):
            single = np.asarray(model.cobi_anneal(
                jnp.asarray(j[b]), jnp.asarray(h[b]), jnp.asarray(p0[b]),
                jnp.asarray(nz[b]), kp)[0])
            np.testing.assert_array_equal(batch[b], single)
