"""AOT path: lowering produces loadable HLO text + consistent manifest."""

import os
import struct

import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def tmp_artifacts(tmp_path_factory):
    d = tmp_path_factory.mktemp("artifacts")
    aot.main(["--out-dir", str(d), "--graphs", "energy,anneal"])
    return str(d)


class TestLowering:
    @pytest.mark.parametrize("name", list(model.GRAPHS))
    def test_lowers_to_hlo_text(self, name):
        text = aot.to_hlo_text(aot.lower_graph(name))
        assert text.startswith("HloModule"), text[:80]
        assert "ENTRY" in text
        # 64-bit-id safety: parser-visible ids must be reassigned small ints;
        # presence of ROOT marks a complete module.
        assert "ROOT" in text

    def test_manifest_round_trip(self, tmp_artifacts):
        lines = [l for l in open(os.path.join(tmp_artifacts, "manifest.txt"))
                 if not l.startswith("#")]
        entries = [l.split() for l in lines if l.strip()]
        names = {e[0] for e in entries}
        assert names == {"energy", "anneal"}
        en_in = [e for e in entries if e[0] == "energy" and e[2] == "in"]
        assert [e[5] for e in en_in] == ["64x64", "64", "32x64"]

    def test_artifact_files_exist(self, tmp_artifacts):
        for n in ("energy", "anneal"):
            p = os.path.join(tmp_artifacts, f"{n}.hlo.txt")
            assert os.path.getsize(p) > 1000


class TestTestVectors:
    def _parse(self, path):
        with open(path, "rb") as f:
            raw = f.read()
        off = 0
        (n,) = struct.unpack_from("<I", raw, off); off += 4
        arrays = []
        for _ in range(n):
            kind, dt, rank = struct.unpack_from("<III", raw, off); off += 12
            dims = struct.unpack_from(f"<{rank}I", raw, off); off += 4 * rank
            count = int(np.prod(dims)) if rank else 1
            dtype = np.int32 if dt == 1 else np.float32
            arr = np.frombuffer(raw, dtype, count, off).reshape(dims)
            off += count * 4
            arrays.append((kind, arr))
        assert off == len(raw)
        return arrays

    def test_energy_testvec_consistent(self, tmp_artifacts):
        arrays = self._parse(os.path.join(tmp_artifacts, "testvec_energy.bin"))
        ins = [a for k, a in arrays if k == 0]
        outs = [a for k, a in arrays if k == 1]
        assert len(ins) == 3 and len(outs) == 1
        j, h, s = ins
        # recompute expected energies in numpy and compare to stored outputs
        want = s @ h + np.einsum("bi,ij,bj->b", s, j, s)
        np.testing.assert_allclose(outs[0], want, rtol=1e-3, atol=1e-2)

    def test_anneal_testvec_output_binary(self, tmp_artifacts):
        arrays = self._parse(os.path.join(tmp_artifacts, "testvec_anneal.bin"))
        outs = [a for k, a in arrays if k == 1]
        assert len(outs) == 1
        assert set(np.unique(outs[0])).issubset({-1.0, 1.0})
