"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/values; fixed-seed cases pin the tolerances.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cosine_sim, energy, oscillator, ref

jax.config.update("jax_platform_name", "cpu")

F32 = np.float32


def rand_emb(rng, n, d, scale=1.0):
    return (rng.standard_normal((n, d)) * scale).astype(F32)


def rand_ising(rng, n):
    j = rng.standard_normal((n, n)).astype(F32)
    j = (j + j.T) / 2
    np.fill_diagonal(j, 0.0)
    h = rng.standard_normal(n).astype(F32)
    return j, h


# ---------------------------------------------------------------------------
# cosine_sim
# ---------------------------------------------------------------------------
class TestCosine:
    @pytest.mark.parametrize("n,d,bm,bn", [(128, 64, 64, 64), (64, 64, 32, 64),
                                           (128, 32, 64, 32), (64, 16, 16, 16)])
    def test_matches_ref(self, n, d, bm, bn):
        rng = np.random.default_rng(n * 1000 + d)
        emb = rand_emb(rng, n, d)
        got = cosine_sim.cosine_matrix(jnp.asarray(emb), block_m=bm, block_n=bn)
        want = ref.cosine_matrix_ref(jnp.asarray(emb))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_diagonal_is_one(self):
        rng = np.random.default_rng(7)
        emb = rand_emb(rng, 64, 64)
        got = np.asarray(cosine_sim.cosine_matrix(jnp.asarray(emb)))
        np.testing.assert_allclose(np.diag(got), np.ones(64), atol=1e-5)

    def test_symmetric(self):
        rng = np.random.default_rng(8)
        emb = rand_emb(rng, 64, 32)
        got = np.asarray(cosine_sim.cosine_matrix(jnp.asarray(emb), block_n=32,
                                                  block_m=32))
        np.testing.assert_allclose(got, got.T, atol=1e-5)

    def test_range_bounded(self):
        rng = np.random.default_rng(9)
        emb = rand_emb(rng, 64, 64, scale=10.0)
        got = np.asarray(cosine_sim.cosine_matrix(jnp.asarray(emb)))
        assert np.all(got <= 1.0 + 1e-4) and np.all(got >= -1.0 - 1e-4)

    def test_zero_rows_safe(self):
        """Padding rows are zero vectors; kernel must not produce NaN."""
        rng = np.random.default_rng(10)
        emb = rand_emb(rng, 64, 64)
        emb[40:] = 0.0
        got = np.asarray(cosine_sim.cosine_matrix(jnp.asarray(emb)))
        assert np.all(np.isfinite(got))
        assert np.allclose(got[40:, :], 0.0, atol=1e-6)

    def test_bad_tiling_raises(self):
        emb = jnp.zeros((60, 64), jnp.float32)
        with pytest.raises(ValueError):
            cosine_sim.cosine_matrix(emb, block_m=64, block_n=64)

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           n=st.sampled_from([32, 64, 128]),
           d=st.sampled_from([16, 32, 64]),
           scale=st.floats(0.01, 100.0))
    def test_property_matches_ref(self, seed, n, d, scale):
        rng = np.random.default_rng(seed)
        emb = rand_emb(rng, n, d, scale)
        got = cosine_sim.cosine_matrix(jnp.asarray(emb), block_m=32, block_n=32)
        want = ref.cosine_matrix_ref(jnp.asarray(emb))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# relevance (mu) reference invariants
# ---------------------------------------------------------------------------
class TestRelevance:
    def test_masked_mean_excludes_padding(self):
        rng = np.random.default_rng(3)
        emb = rand_emb(rng, 16, 8)
        mask = np.ones(16, F32)
        mask[10:] = 0.0
        got = np.asarray(ref.relevance_ref(jnp.asarray(emb), jnp.asarray(mask)))
        doc = emb[:10].mean(axis=0)
        doc /= np.linalg.norm(doc)
        want = (emb / np.linalg.norm(emb, axis=1, keepdims=True)) @ doc
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_identical_sentences_mu_one(self):
        v = np.ones((4, 8), F32)
        mu = np.asarray(ref.relevance_ref(jnp.asarray(v), jnp.ones(4, F32)))
        np.testing.assert_allclose(mu, 1.0, atol=1e-6)


# ---------------------------------------------------------------------------
# oscillator
# ---------------------------------------------------------------------------
class TestOscillator:
    @pytest.mark.parametrize("n", [16, 64])
    def test_matches_ref(self, n):
        rng = np.random.default_rng(n)
        j, h = rand_ising(rng, n)
        ph = rng.uniform(-np.pi, np.pi, n).astype(F32)
        noise = (rng.standard_normal(n) * 0.1).astype(F32)
        kp = jnp.asarray([2.0, 1.0, 0.05], jnp.float32)
        got = oscillator.oscillator_step(jnp.asarray(ph), jnp.asarray(j),
                                         jnp.asarray(h), kp, jnp.asarray(noise))
        want = ref.oscillator_step_ref(jnp.asarray(ph), jnp.asarray(j),
                                       jnp.asarray(h), 2.0, 1.0, 0.05,
                                       jnp.asarray(noise))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_output_wrapped(self):
        rng = np.random.default_rng(5)
        j, h = rand_ising(rng, 32)
        ph = rng.uniform(-np.pi, np.pi, 32).astype(F32)
        kp = jnp.asarray([5.0, 5.0, 1.0], jnp.float32)  # huge step
        got = np.asarray(oscillator.oscillator_step(
            jnp.asarray(ph), jnp.asarray(j), jnp.asarray(h), kp,
            jnp.zeros(32, jnp.float32)))
        assert np.all(got <= np.pi + 1e-6) and np.all(got > -np.pi - 1e-6)

    def test_zero_dynamics_fixed_point(self):
        """k_c = k_s = 0, no noise -> phases unchanged."""
        rng = np.random.default_rng(6)
        j, h = rand_ising(rng, 16)
        ph = rng.uniform(-np.pi, np.pi, 16).astype(F32)
        kp = jnp.asarray([0.0, 0.0, 0.05], jnp.float32)
        got = np.asarray(oscillator.oscillator_step(
            jnp.asarray(ph), jnp.asarray(j), jnp.asarray(h), kp,
            jnp.zeros(16, jnp.float32)))
        np.testing.assert_allclose(got, ph, atol=1e-6)

    def test_binarized_state_is_shil_fixed_point(self):
        """phi in {0, pi} is a fixed point of the SHIL term."""
        ph = np.array([0.0, np.pi] * 8, F32)
        j = np.zeros((16, 16), F32)
        h = np.zeros(16, F32)
        kp = jnp.asarray([0.0, 3.0, 0.05], jnp.float32)
        got = np.asarray(oscillator.oscillator_step(
            jnp.asarray(ph), jnp.asarray(j), jnp.asarray(h), kp,
            jnp.zeros(16, jnp.float32)))
        # sin(2*0) = sin(2*pi) = 0 -> no movement (up to wrap of pi itself)
        np.testing.assert_allclose(np.cos(got), np.cos(ph), atol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([8, 32, 64]),
           k_c=st.floats(0.0, 5.0), k_s=st.floats(0.0, 5.0),
           dt=st.floats(0.001, 0.2))
    def test_property_matches_ref(self, seed, n, k_c, k_s, dt):
        rng = np.random.default_rng(seed)
        j, h = rand_ising(rng, n)
        ph = rng.uniform(-np.pi, np.pi, n).astype(F32)
        noise = (rng.standard_normal(n) * 0.05).astype(F32)
        kp = jnp.asarray([k_c, k_s, dt], jnp.float32)
        got = oscillator.oscillator_step(jnp.asarray(ph), jnp.asarray(j),
                                         jnp.asarray(h), kp, jnp.asarray(noise))
        want = ref.oscillator_step_ref(jnp.asarray(ph), jnp.asarray(j),
                                       jnp.asarray(h), k_c, k_s, dt,
                                       jnp.asarray(noise))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# energy
# ---------------------------------------------------------------------------
class TestEnergy:
    @pytest.mark.parametrize("b,n,bb", [(32, 64, 32), (64, 64, 32), (32, 16, 16)])
    def test_matches_ref(self, b, n, bb):
        rng = np.random.default_rng(b + n)
        j, h = rand_ising(rng, n)
        s = np.where(rng.uniform(size=(b, n)) > 0.5, 1.0, -1.0).astype(F32)
        got = energy.energy_batch(jnp.asarray(j), jnp.asarray(h),
                                  jnp.asarray(s), block_b=bb)
        want = ref.energy_batch_ref(jnp.asarray(j), jnp.asarray(h),
                                    jnp.asarray(s))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-3)

    def test_flip_symmetry_zero_field(self):
        """H(s) == H(-s) when h = 0."""
        rng = np.random.default_rng(11)
        j, _ = rand_ising(rng, 32)
        h = np.zeros(32, F32)
        s = np.where(rng.uniform(size=(32, 32)) > 0.5, 1.0, -1.0).astype(F32)
        e1 = np.asarray(energy.energy_batch(jnp.asarray(j), jnp.asarray(h),
                                            jnp.asarray(s)))
        e2 = np.asarray(energy.energy_batch(jnp.asarray(j), jnp.asarray(h),
                                            jnp.asarray(-s)))
        np.testing.assert_allclose(e1, e2, rtol=1e-4, atol=1e-3)

    def test_single_spin_exact(self):
        """n=2 analytic check: H = h0 s0 + h1 s1 + 2 J01 s0 s1."""
        j = np.zeros((64, 64), F32)
        j[0, 1] = j[1, 0] = 0.5
        h = np.zeros(64, F32)
        h[0], h[1] = 1.0, -2.0
        s = -np.ones((32, 64), F32)
        s[0, 0], s[0, 1] = 1.0, 1.0   # H = 1 - 2 + 1 = 0
        s[1, 0], s[1, 1] = 1.0, -1.0  # H = 1 + 2 - 1 = 2
        got = np.asarray(energy.energy_batch(jnp.asarray(j), jnp.asarray(h),
                                             jnp.asarray(s)))
        assert abs((got[0] - got[2]) - (0.0 - (-1 + 2 + 1))) < 1e-4
        assert abs((got[1] - got[2]) - (2.0 - 2.0)) < 1e-4

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([16, 32, 64]))
    def test_property_matches_ref(self, seed, n):
        rng = np.random.default_rng(seed)
        j, h = rand_ising(rng, n)
        s = np.where(rng.uniform(size=(32, n)) > 0.5, 1.0, -1.0).astype(F32)
        got = energy.energy_batch(jnp.asarray(j), jnp.asarray(h),
                                  jnp.asarray(s), block_b=32)
        want = ref.energy_batch_ref(jnp.asarray(j), jnp.asarray(h),
                                    jnp.asarray(s))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-3)
