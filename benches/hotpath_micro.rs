//! Microbenchmarks of the L3 hot paths — the measurement side of the
//! EXPERIMENTS.md §Perf loop. Each case is one logical operation on
//! paper-sized inputs (n = 20 problems, 64-spin padded device instances).

use cobi_es::cobi::CobiDevice;
use cobi_es::config::CobiConfig;
use cobi_es::ising::{formulate, EsProblem, Formulation, Ising};
use cobi_es::quant::{quantize, Precision, Rounding};
use cobi_es::solvers::oscillator::{anneal, OscillatorConfig, OscillatorSolver};
use cobi_es::solvers::tabu::TabuSolver;
use cobi_es::solvers::{brute, exact, IsingSolver};
use cobi_es::util::bench::{black_box, Bencher};
use cobi_es::util::rng::Pcg32;

fn random_es(seed: u64, n: usize, m: usize) -> EsProblem {
    let mut rng = Pcg32::seeded(seed);
    let mu: Vec<f32> = (0..n).map(|_| rng.range_f32(0.3, 0.95)).collect();
    let mut beta = vec![0.0f32; n * n];
    for i in 0..n {
        for j in (i + 1)..n {
            let b = rng.range_f32(0.2, 0.9);
            beta[i * n + j] = b;
            beta[j * n + i] = b;
        }
    }
    EsProblem { mu, beta, lambda: 0.6, m }
}

fn main() {
    let mut b = Bencher::new();
    let p20 = random_es(1, 20, 6);
    let p100 = random_es(2, 100, 6);
    let es = formulate(&p20, Formulation::Improved);
    let mut rng = Pcg32::seeded(3);
    let quantized = quantize(&es.ising, Precision::CobiInt, Rounding::Stochastic, &mut rng);
    let padded: Ising = quantized.padded(64);

    // formulation + quantization (per refinement iteration)
    b.bench("formulate/improved n=20", || {
        black_box(formulate(&p20, Formulation::Improved));
    });
    let mut qrng = Pcg32::seeded(4);
    b.bench("quantize/stochastic int14 n=20", || {
        black_box(quantize(&es.ising, Precision::CobiInt, Rounding::Stochastic, &mut qrng));
    });

    // objective evaluation (the 18.9 µs/iteration term of Eq. 15)
    let sel = [0usize, 3, 7, 11, 15, 19];
    b.bench("objective/eval n=20 M=6", || {
        black_box(p20.objective(&sel));
    });

    // solvers
    let mut tabu = TabuSolver::seeded(5);
    b.bench("tabu/solve n=20 int14", || {
        black_box(tabu.solve(&quantized));
    });
    let mut osc = OscillatorSolver::seeded(6);
    b.bench("oscillator/solve n=20 (unpadded)", || {
        black_box(osc.solve(&quantized));
    });
    let cfg = OscillatorConfig::default();
    let mut dev_rng = Pcg32::seeded(7);
    let mut phase0 = vec![0.0f32; 64];
    for p in phase0.iter_mut() {
        *p = dev_rng.range_f32(-3.14, 3.14);
    }
    let mut noise = vec![0.0f32; cfg.steps * 64];
    dev_rng.fill_normal(&mut noise, 0.1);
    b.bench("oscillator/anneal 64-spin padded (256 steps)", || {
        black_box(anneal(&padded, &cfg, &phase0, &noise));
    });
    let mut device = CobiDevice::native(CobiConfig::default(), 8);
    b.bench("cobi-device/program_and_solve n=20", || {
        black_box(device.program_and_solve(&quantized).unwrap());
    });

    // exact ground truth (Eq. 13 bounds)
    b.bench("exact/bnb-max n=20 M=6", || {
        black_box(exact::solve_max(&p20));
    });
    b.bench("exact/bnb-max n=100 M=6", || {
        black_box(exact::solve_max(&p100));
    });
    b.bench("brute/enumerate n=20 M=6 (38760 subsets)", || {
        black_box(brute::solve(&p20));
    });

    println!("\n{} cases measured", b.results.len());
}
