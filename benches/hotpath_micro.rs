//! Microbenchmarks of the L3 hot paths — the measurement side of the
//! EXPERIMENTS.md §Perf loop. Each case is one logical operation on
//! paper-sized inputs (n = 20 problems, 64-spin padded device instances).
//!
//! The `*-int` cases measure the integer-domain solve pipeline (ISSUE 3:
//! `QuantIsing` + `quantize_into` + integer `SolverKernel` loops) against
//! their `f32`/`f64` twins on the SAME quantized instance — the outputs
//! are bit-identical (pinned by unit tests), so the ratio is pure kernel
//! speed. Set `COBI_BENCH_RECORD=1` to overwrite `BENCH_hotpath.json`
//! with the measured medians and ratios.

use cobi_es::cobi::CobiDevice;
use cobi_es::config::CobiConfig;
use cobi_es::ising::{formulate, EsProblem, Formulation, Ising, QuantIsing};
use cobi_es::quant::{quantize, quantize_into, Precision, Rounding};
use cobi_es::refine::{refine, refine_batched, RefineConfig};
use cobi_es::solvers::oscillator::{anneal, OscillatorConfig, OscillatorSolver};
use cobi_es::solvers::snowball::{SnowballConfig, SnowballSolver};
use cobi_es::solvers::tabu::TabuSolver;
use cobi_es::solvers::{brute, exact, IsingSolver, QuantSolve};
use cobi_es::util::bench::{black_box, Bencher};
use cobi_es::util::rng::Pcg32;

fn random_es(seed: u64, n: usize, m: usize) -> EsProblem {
    let mut rng = Pcg32::seeded(seed);
    let mu: Vec<f32> = (0..n).map(|_| rng.range_f32(0.3, 0.95)).collect();
    let mut beta = vec![0.0f32; n * n];
    for i in 0..n {
        for j in (i + 1)..n {
            let b = rng.range_f32(0.2, 0.9);
            beta[i * n + j] = b;
            beta[j * n + i] = b;
        }
    }
    EsProblem { mu, beta, lambda: 0.6, m }
}

fn median_s(b: &Bencher, name: &str) -> f64 {
    b.results
        .iter()
        .find(|r| r.name == name)
        .map(|r| r.median.as_secs_f64())
        .unwrap_or(f64::NAN)
}

fn main() {
    let mut b = Bencher::new();
    let p20 = random_es(1, 20, 6);
    let p100 = random_es(2, 100, 6);
    let p64 = random_es(9, 64, 8);
    let es = formulate(&p20, Formulation::Improved);
    let es64 = formulate(&p64, Formulation::Improved);
    let mut rng = Pcg32::seeded(3);
    let quantized = quantize(&es.ising, Precision::CobiInt, Rounding::Stochastic, &mut rng);
    let quantized64 = quantize(&es64.ising, Precision::CobiInt, Rounding::Stochastic, &mut rng);
    let padded: Ising = quantized.padded(64);
    let mut qint = QuantIsing::default();
    assert!(qint.try_copy_from(&quantized));
    let mut qint64 = QuantIsing::default();
    assert!(qint64.try_copy_from(&quantized64));

    // formulation + quantization (per refinement iteration)
    b.bench("formulate/improved n=20", || {
        black_box(formulate(&p20, Formulation::Improved));
    });
    let mut qrng = Pcg32::seeded(4);
    b.bench("quantize/stochastic int14 n=20", || {
        black_box(quantize(&es.ising, Precision::CobiInt, Rounding::Stochastic, &mut qrng));
    });
    let mut qrng_int = Pcg32::seeded(4);
    let mut qbuf = QuantIsing::default();
    b.bench("quantize_into/stochastic int14 n=20 (int)", || {
        black_box(quantize_into(
            &es.ising,
            Precision::CobiInt,
            Rounding::Stochastic,
            &mut qrng_int,
            &mut qbuf,
        ));
    });

    // objective evaluation (the 18.9 µs/iteration term of Eq. 15)
    let sel = [0usize, 3, 7, 11, 15, 19];
    b.bench("objective/eval n=20 M=6", || {
        black_box(p20.objective(&sel));
    });

    // solvers — f64 reference kernel vs integer kernel, same instance,
    // bit-identical outputs
    let mut tabu_f = TabuSolver::seeded(5);
    b.bench("tabu/solve n=20 int14 (f64 kernel)", || {
        black_box(tabu_f.solve_reference_f64(&quantized));
    });
    let mut tabu_i = TabuSolver::seeded(5);
    let mut spins_out: Vec<i8> = Vec::new();
    b.bench("tabu/solve n=20 int14 (int kernel)", || {
        black_box(tabu_i.solve_quant_into(&qint, &mut spins_out));
    });
    let mut tabu_f64_64 = TabuSolver::seeded(5);
    b.bench("tabu/solve n=64 int14 (f64 kernel)", || {
        black_box(tabu_f64_64.solve_reference_f64(&quantized64));
    });
    let mut tabu_i64 = TabuSolver::seeded(5);
    b.bench("tabu/solve n=64 int14 (int kernel)", || {
        black_box(tabu_i64.solve_quant_into(&qint64, &mut spins_out));
    });

    // snowball — sharded parallel-spin MCMC: f64 vs integer kernel on
    // the same instance (bit-identical outputs), then 1 vs 8 worker
    // threads on the same logical schedule (results identical too, so
    // the thread ratio is pure wall-clock scaling)
    let mut snow_f = SnowballSolver::seeded(5);
    b.bench("snowball/solve n=64 int14 (f64 kernel)", || {
        black_box(snow_f.solve_reference_f64(&quantized64));
    });
    let mut snow_i = SnowballSolver::seeded(5);
    b.bench("snowball/solve n=64 int14 (int kernel)", || {
        black_box(snow_i.solve_quant_into(&qint64, &mut spins_out));
    });
    let mut snow_t1 = SnowballSolver::new(
        5,
        SnowballConfig {
            threads: 1,
            ..Default::default()
        },
    );
    b.bench("snowball/solve n=64 int14 (1 thread)", || {
        black_box(snow_t1.solve(&quantized64));
    });
    let mut snow_t8 = SnowballSolver::new(
        5,
        SnowballConfig {
            threads: 8,
            ..Default::default()
        },
    );
    b.bench("snowball/solve n=64 int14 (8 threads)", || {
        black_box(snow_t8.solve(&quantized64));
    });

    // one full refinement run (quantize → solve → repair → score,
    // 4 iterations): the batched f32 path vs the integer fast path
    let refine_cfg = RefineConfig {
        formulation: Formulation::Improved,
        precision: Precision::CobiInt,
        rounding: Rounding::Stochastic,
        iterations: 4,
    };
    let mut refine_solver_f = TabuSolver::seeded(6);
    let mut refine_rng_f = Pcg32::seeded(7);
    b.bench("refine/tabu n=20 x4 (f32 batch path)", || {
        black_box(
            refine_batched(&p20, &refine_cfg, &mut refine_solver_f, &mut refine_rng_f).unwrap(),
        );
    });
    let mut refine_solver_i = TabuSolver::seeded(6);
    let mut refine_rng_i = Pcg32::seeded(7);
    b.bench("refine/tabu n=20 x4 (int fast path)", || {
        black_box(refine(&p20, &refine_cfg, &mut refine_solver_i, &mut refine_rng_i).unwrap());
    });

    let mut osc = OscillatorSolver::seeded(6);
    b.bench("oscillator/solve n=20 (unpadded)", || {
        black_box(osc.solve(&quantized));
    });
    let cfg = OscillatorConfig::default();
    let mut dev_rng = Pcg32::seeded(7);
    let mut phase0 = vec![0.0f32; 64];
    for p in phase0.iter_mut() {
        *p = dev_rng.range_f32(-3.14, 3.14);
    }
    let mut noise = vec![0.0f32; cfg.steps * 64];
    dev_rng.fill_normal(&mut noise, 0.1);
    b.bench("oscillator/anneal 64-spin padded (256 steps)", || {
        black_box(anneal(&padded, &cfg, &phase0, &noise));
    });
    let mut device = CobiDevice::native(CobiConfig::default(), 8);
    b.bench("cobi-device/program_and_solve n=20", || {
        black_box(device.program_and_solve(&quantized).unwrap());
    });

    // exact ground truth (Eq. 13 bounds)
    b.bench("exact/bnb-max n=20 M=6", || {
        black_box(exact::solve_max(&p20));
    });
    b.bench("exact/bnb-max n=100 M=6", || {
        black_box(exact::solve_max(&p100));
    });
    b.bench("brute/enumerate n=20 M=6 (38760 subsets)", || {
        black_box(brute::solve(&p20));
    });

    println!("\n{} cases measured", b.results.len());

    // ---- integer-vs-f32 record (BENCH_hotpath.json) -------------------
    let quant_f = median_s(&b, "quantize/stochastic int14 n=20");
    let quant_i = median_s(&b, "quantize_into/stochastic int14 n=20 (int)");
    let tabu20_f = median_s(&b, "tabu/solve n=20 int14 (f64 kernel)");
    let tabu20_i = median_s(&b, "tabu/solve n=20 int14 (int kernel)");
    let tabu64_f = median_s(&b, "tabu/solve n=64 int14 (f64 kernel)");
    let tabu64_i = median_s(&b, "tabu/solve n=64 int14 (int kernel)");
    let refine_f = median_s(&b, "refine/tabu n=20 x4 (f32 batch path)");
    let refine_i = median_s(&b, "refine/tabu n=20 x4 (int fast path)");
    let ratio = |f: f64, i: f64| f / i;
    println!(
        "\nint-vs-f32 speedups: quantize {:.2}x | tabu n=20 {:.2}x | tabu n=64 {:.2}x | refine {:.2}x",
        ratio(quant_f, quant_i),
        ratio(tabu20_f, tabu20_i),
        ratio(tabu64_f, tabu64_i),
        ratio(refine_f, refine_i),
    );
    let snow_f64 = median_s(&b, "snowball/solve n=64 int14 (f64 kernel)");
    let snow_int = median_s(&b, "snowball/solve n=64 int14 (int kernel)");
    let snow_1t = median_s(&b, "snowball/solve n=64 int14 (1 thread)");
    let snow_8t = median_s(&b, "snowball/solve n=64 int14 (8 threads)");
    println!(
        "snowball n=64: int-vs-f64 {:.2}x | 8-vs-1 threads {:.2}x (same bytes out)",
        ratio(snow_f64, snow_int),
        ratio(snow_1t, snow_8t),
    );
    let json = format!(
        r#"{{
  "bench": "hotpath_micro",
  "status": "recorded",
  "note": "medians in microseconds; ratio = f32-or-f64 path / integer path on the same quantized instance (outputs bit-identical)",
  "quantize_n20": {{ "f32_us": {:.3}, "int_us": {:.3}, "ratio": {:.3} }},
  "tabu_n20": {{ "f64_us": {:.3}, "int_us": {:.3}, "ratio": {:.3} }},
  "tabu_n64": {{ "f64_us": {:.3}, "int_us": {:.3}, "ratio": {:.3} }},
  "refine_tabu_n20_x4": {{ "f32_path_us": {:.3}, "int_path_us": {:.3}, "ratio": {:.3} }}
}}"#,
        quant_f * 1e6,
        quant_i * 1e6,
        ratio(quant_f, quant_i),
        tabu20_f * 1e6,
        tabu20_i * 1e6,
        ratio(tabu20_f, tabu20_i),
        tabu64_f * 1e6,
        tabu64_i * 1e6,
        ratio(tabu64_f, tabu64_i),
        refine_f * 1e6,
        refine_i * 1e6,
        ratio(refine_f, refine_i),
    );
    println!("\n{json}");
    if std::env::var("COBI_BENCH_RECORD").is_ok() {
        std::fs::write("BENCH_hotpath.json", format!("{json}\n")).expect("write baseline");
        println!("recorded baseline to BENCH_hotpath.json");
    }
}
