//! Bench: adaptive solver portfolio + fleet-wide warm-start cache vs the
//! PR-1 device pool — the reuse story (repeated documents should get
//! cheaper, not just batched).
//!
//! All three configurations run the SAME repeated-document workload
//! (`bench_10`, the full set re-submitted `ROUNDS` times with *identical*
//! ids — the cache's target shape) through the full `Service`, one round
//! at a time so later rounds actually see the cache the earlier rounds
//! populated:
//!
//!   * pool:       the PR-1 baseline — shared `DevicePool`, plain COBI
//!     devices, no portfolio layer;
//!   * portfolio-cold: `[portfolio] policy = "static"` + cache disabled —
//!     must match the baseline's work (byte-identity is pinned by tests;
//!     here it bounds the routing layer's overhead);
//!   * portfolio-warm: cache enabled — round 2+ requests exact-hit
//!     (identical quantized instances), same-size windows warm-hit, so
//!     docs/sec should beat the baseline.
//!
//! Prints a human summary plus a JSON record; set COBI_BENCH_RECORD=1 to
//! (over)write the committed baseline `BENCH_portfolio.json` with fresh
//! numbers (see that file for the schema).

use std::time::Instant;

use cobi_es::config::Settings;
use cobi_es::corpus::benchmark_set;
use cobi_es::service::{Service, ServiceMetrics};

const ROUNDS: usize = 3;
const WORKERS: usize = 4;
const DEVICES: usize = 4;
const ITERATIONS: usize = 4;

fn base_settings() -> Settings {
    let mut s = Settings::default();
    s.pipeline.solver = "cobi".into();
    s.pipeline.iterations = ITERATIONS;
    s.pipeline.summary_len = 3; // bench_10 documents have 10 sentences
    s.service.workers = WORKERS;
    s.service.queue_depth = 256;
    s.sched.devices = DEVICES;
    s
}

/// Run the repeated-document workload; returns (wall_s, docs, metrics).
/// Rounds are submitted with a barrier between them so round r+1 can
/// reuse what round r cached.
fn run_workload(settings: &Settings) -> (f64, usize, ServiceMetrics) {
    let svc = Service::start(settings).expect("service start");
    let set = benchmark_set("bench_10").expect("benchmark set");
    let t0 = Instant::now();
    let mut docs = 0usize;
    for _ in 0..ROUNDS {
        let tickets: Vec<_> = set
            .documents
            .iter()
            .map(|d| svc.submit(d.clone()).expect("queue_depth covers the workload"))
            .collect();
        for t in tickets {
            t.wait().expect("summarize");
            docs += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = svc.metrics();
    svc.shutdown();
    (wall, docs, m)
}

fn main() {
    let mut pool = base_settings();
    pool.portfolio.enabled = false;
    let (pool_wall, docs, pool_m) = run_workload(&pool);
    let pool_rate = docs as f64 / pool_wall;
    println!("pool (PR-1 baseline):  {docs} docs in {pool_wall:.2}s = {pool_rate:.1} docs/s");
    println!("  {}", pool_m.report());

    let mut cold = base_settings();
    cold.portfolio.enabled = true;
    cold.portfolio.cache = false;
    let (cold_wall, _, cold_m) = run_workload(&cold);
    let cold_rate = docs as f64 / cold_wall;
    println!("portfolio-cold:        {docs} docs in {cold_wall:.2}s = {cold_rate:.1} docs/s");
    println!("  {}", cold_m.report());

    let mut warm = base_settings();
    warm.portfolio.enabled = true;
    warm.portfolio.cache = true;
    let (warm_wall, _, warm_m) = run_workload(&warm);
    let warm_rate = docs as f64 / warm_wall;
    println!("portfolio-warm:        {docs} docs in {warm_wall:.2}s = {warm_rate:.1} docs/s");
    println!("  {}", warm_m.report());

    let p = warm_m.portfolio.as_ref().expect("portfolio telemetry");
    let exact_rate = p.cache.exact_rate();
    let warm_hit_rate = p.cache.warm_rate();
    let speedup = pool_wall / warm_wall;
    println!(
        "speedup vs pool {speedup:.2}x | cache exact {:.0}% warm {:.0}% ({} lookups, {} entries)",
        exact_rate * 100.0,
        warm_hit_rate * 100.0,
        p.cache.lookups,
        p.cache.entries,
    );
    assert!(
        p.cache.exact_hits > 0,
        "repeated rounds produced no exact cache hits"
    );
    assert!(
        p.cache.warm_hits > 0,
        "same-size windows produced no warm cache hits"
    );

    let json = format!(
        r#"{{
  "bench": "portfolio",
  "status": "recorded",
  "workload": {{
    "set": "bench_10",
    "rounds": {ROUNDS},
    "documents": {docs},
    "repeated_ids": true,
    "solver": "cobi-native",
    "iterations": {ITERATIONS},
    "workers": {WORKERS},
    "devices": {DEVICES}
  }},
  "pool_baseline": {{ "wall_s": {pool_wall:.4}, "docs_per_s": {pool_rate:.2} }},
  "portfolio_cold": {{ "wall_s": {cold_wall:.4}, "docs_per_s": {cold_rate:.2} }},
  "portfolio_warm": {{
    "wall_s": {warm_wall:.4},
    "docs_per_s": {warm_rate:.2},
    "cache_exact_rate": {exact_rate:.3},
    "cache_warm_rate": {warm_hit_rate:.3},
    "cache_entries": {entries}
  }},
  "speedup_vs_pool": {speedup:.3}
}}"#,
        entries = p.cache.entries,
    );
    println!("\n{json}");
    if std::env::var("COBI_BENCH_RECORD").is_ok() {
        std::fs::write("BENCH_portfolio.json", format!("{json}\n")).expect("write baseline");
        println!("recorded baseline to BENCH_portfolio.json");
    }
}
