//! Bench: regenerate Fig 6 (COBI vs Tabu vs random accuracy + ablation).

use cobi_es::config::Settings;
use cobi_es::experiments::{run, Scale};
use cobi_es::util::bench::Bencher;

fn scale() -> Scale {
    if std::env::var("COBI_BENCH_FULL").is_ok() { Scale::Full } else { Scale::Quick }
}

fn main() {
    let settings = Settings::default();
    let mut b = Bencher::new();
    let mut reports = Vec::new();
    b.bench_once("experiment/fig6", || {
        reports = run("fig6", scale(), &settings).unwrap();
    });
    for r in &reports {
        println!("\n{}", r.to_markdown());
    }
}
