//! Bench: pooled-batched vs per-worker-sequential service throughput —
//! the `sched` subsystem's reason to exist (ROADMAP: batching is the
//! scaling story).
//!
//! Both paths run the SAME ≥20-document workload (`cnn_dm_20`, repeated
//! `rounds` times with distinct ids) through the full `Service`:
//!
//!   * sequential: `[sched] enabled = false` — each worker owns a private
//!     `EsPipeline` + solver and solves its document's subproblems inline,
//!     one at a time (the pre-sched architecture);
//!   * pooled: the shared `DevicePool` — workers run embed/quantize and
//!     submit whole DAG levels, devices coalesce requests across all
//!     in-flight documents into batched dispatches.
//!
//! Prints a human summary plus a JSON record; set COBI_BENCH_RECORD=1 to
//! (over)write the committed baselines `BENCH_sched.json` (pooled vs
//! sequential), `BENCH_decompose.json` (window vs tree level
//! parallelism), and `BENCH_snowball.json` (snowball worker-thread
//! scaling) with fresh numbers (see those files for the schemas).
//!
//! ## Decompose strategy matrix (the window-vs-tree cases)
//!
//! The tree plan's advantage is SAME-LEVEL PARALLELISM: on an
//! N-sentence document the window plan's level k offers `len/P` full
//! windows and leaves a `len mod P` tail idle, while the tree plan
//! carves `ceil(len/P)` balanced leaves covering every sentence — wider
//! levels, no idle tail, O(log N) depth. The matrix runs the SAME
//! `xsum_100` workload through the full pooled service under
//! `strategy = "window"` and `strategy = "tree"` and reports docs/s plus
//! the pool's occupancy/coalescing counters; deeper queues per level is
//! the mechanism, so coalescing is the number to watch.

use std::time::Instant;

use cobi_es::config::Settings;
use cobi_es::corpus::benchmark_set;
use cobi_es::decompose::Strategy;
use cobi_es::service::{Service, ServiceMetrics};

const ROUNDS: usize = 3; // 3 x 20 = 60 documents per path
const WORKERS: usize = 4;
const DEVICES: usize = 4;
const ITERATIONS: usize = 4;

fn base_settings() -> Settings {
    let mut s = Settings::default();
    s.pipeline.solver = "cobi".into();
    s.pipeline.iterations = ITERATIONS;
    s.service.workers = WORKERS;
    s.service.queue_depth = 256;
    s
}

/// Run the whole workload through a Service; returns (wall_s, metrics).
fn run_workload(settings: &Settings) -> (f64, ServiceMetrics) {
    run_workload_on(settings, "cnn_dm_20", ROUNDS)
}

/// As [`run_workload`], on an explicit benchmark set repeated `rounds`
/// times with distinct document ids.
fn run_workload_on(settings: &Settings, set_name: &str, rounds: usize) -> (f64, ServiceMetrics) {
    let svc = Service::start(settings).expect("service start");
    let set = benchmark_set(set_name).expect("benchmark set");
    let t0 = Instant::now();
    let mut tickets = Vec::with_capacity(rounds * set.documents.len());
    for r in 0..rounds {
        for doc in &set.documents {
            let mut d = doc.clone();
            d.id = format!("{}-r{r}", d.id);
            tickets.push(svc.submit(d).expect("queue_depth covers the workload"));
        }
    }
    for t in tickets {
        t.wait().expect("summarize");
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = svc.metrics();
    svc.shutdown();
    (wall, m)
}

/// The window-vs-tree matrix on long documents (see module docs);
/// returns the JSON fragment for `BENCH_decompose.json`.
fn bench_decompose_strategies() -> String {
    const SET: &str = "xsum_100";
    const STRAT_ROUNDS: usize = 1; // 20 x 100-sentence docs per strategy
    let docs = STRAT_ROUNDS * 20;
    let mut fragments = Vec::new();
    for strategy in [Strategy::Window, Strategy::Tree] {
        let mut s = base_settings();
        s.pipeline.strategy = strategy;
        s.sched.devices = DEVICES;
        let (wall, m) = run_workload_on(&s, SET, STRAT_ROUNDS);
        let rate = docs as f64 / wall;
        println!(
            "strategy {strategy}: {docs} x 100-sentence docs in {wall:.2}s = {rate:.1} docs/s"
        );
        println!("  {}", m.report());
        fragments.push(format!(
            r#"    "{strategy}": {{
      "wall_s": {wall:.4},
      "docs_per_s": {rate:.2},
      "batch_occupancy": {occ:.3},
      "coalescing": {coal:.3},
      "utilization": {util:.3}
    }}"#,
            occ = m.pool.batch_occupancy(),
            coal = m.pool.coalescing(),
            util = m.pool.utilization(),
        ));
    }
    format!(
        r#"{{
  "bench": "decompose_strategies",
  "status": "recorded",
  "workload": {{
    "set": "{SET}",
    "documents": {docs},
    "solver": "cobi-native",
    "iterations": {ITERATIONS},
    "workers": {WORKERS},
    "devices": {DEVICES}
  }},
  "strategies": {{
{fragments}
  }}
}}"#,
        fragments = fragments.join(",\n"),
    )
}

/// Snowball worker-thread matrix on the pooled service: the SAME
/// `xsum_100` workload with `[solvers.snowball] threads = 1` vs `8`.
/// Logical asynchrony makes the outputs byte-identical across thread
/// counts, so the docs/s ratio is pure wall-clock scaling; returns the
/// JSON fragment for `BENCH_snowball.json`.
fn bench_snowball_threads() -> String {
    const SET: &str = "xsum_100";
    const SNOW_ROUNDS: usize = 1; // 20 x 100-sentence docs per thread count
    let docs = SNOW_ROUNDS * 20;
    let mut fragments = Vec::new();
    let mut walls = Vec::new();
    for threads in [1usize, 8] {
        let mut s = base_settings();
        s.pipeline.solver = "snowball".into();
        s.sched.devices = DEVICES;
        s.solvers.snowball.threads = threads;
        let (wall, m) = run_workload_on(&s, SET, SNOW_ROUNDS);
        let rate = docs as f64 / wall;
        println!(
            "snowball threads={threads}: {docs} x 100-sentence docs in {wall:.2}s = {rate:.1} docs/s"
        );
        println!("  {}", m.report());
        walls.push(wall);
        fragments.push(format!(
            r#"    "t{threads}": {{
      "wall_s": {wall:.4},
      "docs_per_s": {rate:.2},
      "batch_occupancy": {occ:.3},
      "utilization": {util:.3}
    }}"#,
            occ = m.pool.batch_occupancy(),
            util = m.pool.utilization(),
        ));
    }
    let speedup = walls[0] / walls[1];
    println!("snowball 8-vs-1 thread speedup {speedup:.2}x (same bytes out)");
    format!(
        r#"{{
  "bench": "snowball_threads",
  "status": "recorded",
  "workload": {{
    "set": "{SET}",
    "documents": {docs},
    "solver": "snowball",
    "iterations": {ITERATIONS},
    "workers": {WORKERS},
    "devices": {DEVICES}
  }},
  "threads": {{
{fragments}
  }},
  "speedup_8v1": {speedup:.3}
}}"#,
        fragments = fragments.join(",\n"),
    )
}

fn main() {
    let docs = ROUNDS * 20;

    let mut seq = base_settings();
    seq.sched.enabled = false;
    let (seq_wall, seq_m) = run_workload(&seq);
    let seq_rate = docs as f64 / seq_wall;
    println!(
        "sequential (per-worker): {docs} docs in {seq_wall:.2}s = {seq_rate:.1} docs/s"
    );
    println!("  {}", seq_m.report());

    let mut pooled = base_settings();
    pooled.sched.enabled = true;
    pooled.sched.devices = DEVICES;
    let (pool_wall, pool_m) = run_workload(&pooled);
    let pool_rate = docs as f64 / pool_wall;
    println!(
        "pooled (shared devices): {docs} docs in {pool_wall:.2}s = {pool_rate:.1} docs/s"
    );
    println!("  {}", pool_m.report());

    let speedup = seq_wall / pool_wall;
    println!(
        "speedup {speedup:.2}x | occupancy {:.2} | coalesce {:.2} | util {:.0}%",
        pool_m.pool.batch_occupancy(),
        pool_m.pool.coalescing(),
        pool_m.pool.utilization() * 100.0
    );
    assert!(
        pool_m.pool.batch_occupancy() > 1.0,
        "pool ran but batch occupancy was {:.2} (no amortization)",
        pool_m.pool.batch_occupancy()
    );

    let json = format!(
        r#"{{
  "bench": "sched_pool",
  "status": "recorded",
  "workload": {{
    "set": "cnn_dm_20",
    "documents": {docs},
    "solver": "cobi-native",
    "iterations": {ITERATIONS},
    "workers": {WORKERS}
  }},
  "sequential": {{ "wall_s": {seq_wall:.4}, "docs_per_s": {seq_rate:.2} }},
  "pooled": {{
    "wall_s": {pool_wall:.4},
    "docs_per_s": {pool_rate:.2},
    "devices": {DEVICES},
    "batch_occupancy": {occ:.3},
    "coalescing": {coal:.3},
    "utilization": {util:.3}
  }},
  "speedup": {speedup:.3}
}}"#,
        occ = pool_m.pool.batch_occupancy(),
        coal = pool_m.pool.coalescing(),
        util = pool_m.pool.utilization(),
    );
    println!("\n{json}");
    if std::env::var("COBI_BENCH_RECORD").is_ok() {
        std::fs::write("BENCH_sched.json", format!("{json}\n")).expect("write baseline");
        println!("recorded baseline to BENCH_sched.json");
    }

    println!("\n-- decompose strategy matrix (window vs tree) --");
    let decompose_json = bench_decompose_strategies();
    println!("\n{decompose_json}");
    if std::env::var("COBI_BENCH_RECORD").is_ok() {
        std::fs::write("BENCH_decompose.json", format!("{decompose_json}\n"))
            .expect("write baseline");
        println!("recorded baseline to BENCH_decompose.json");
    }

    println!("\n-- snowball worker-thread matrix (1 vs 8) --");
    let snowball_json = bench_snowball_threads();
    println!("\n{snowball_json}");
    if std::env::var("COBI_BENCH_RECORD").is_ok() {
        std::fs::write("BENCH_snowball.json", format!("{snowball_json}\n"))
            .expect("write baseline");
        println!("recorded baseline to BENCH_snowball.json");
    }
}
