//! Bench: regenerate Figs 2-3 (rounding schemes vs iterations).

use cobi_es::config::Settings;
use cobi_es::experiments::{run, Scale};
use cobi_es::util::bench::Bencher;

fn scale() -> Scale {
    if std::env::var("COBI_BENCH_FULL").is_ok() { Scale::Full } else { Scale::Quick }
}

fn main() {
    let settings = Settings::default();
    let mut b = Bencher::new();
    for (id, label) in [("fig2", "experiment/fig2 (20-sent)"), ("fig3", "experiment/fig3 (10-sent)")] {
        let mut reports = Vec::new();
        b.bench_once(label, || {
            reports = run(id, scale(), &settings).unwrap();
        });
        for r in &reports {
            println!("\n{}", r.to_markdown());
        }
    }
}
