//! Bench: the cost of resilience — quality delta and added latency of
//! replicated voting solves on a degraded COBI device.
//!
//! Matrix: fault rate {clean, 1%, 5% stuck oscillators} × replication
//! {1, 3} (clean runs once, unreplicated — the baseline every delta is
//! against). Each cell runs the full `Service` over the bench_10 set
//! (pool of COBI-native devices carrying the `resilience::fault` model)
//! and records wall-clock, docs/sec, mean summary objective, and the
//! resilience counters.
//!
//! Expected shape: replication 3 roughly triples device solves (latency
//! up), holds the mean objective at the clean baseline under faults, and
//! the replication-1 fault rows show the quality decay that justifies
//! the layer.
//!
//! Prints a human summary plus a JSON record; set COBI_BENCH_RECORD=1 to
//! (over)write the committed baseline `BENCH_resilience.json`.

use std::time::Instant;

use cobi_es::config::Settings;
use cobi_es::corpus::benchmark_set;
use cobi_es::service::Service;

const WORKERS: usize = 4;
const DEVICES: usize = 2;
const ITERATIONS: usize = 4;

fn settings(stuck: f64, replication: usize) -> Settings {
    let mut s = Settings::default();
    s.pipeline.solver = "cobi".into();
    s.pipeline.iterations = ITERATIONS;
    s.pipeline.summary_len = 3; // bench_10 documents have 10 sentences
    s.service.workers = WORKERS;
    s.service.queue_depth = 256;
    s.sched.devices = DEVICES;
    if stuck > 0.0 {
        s.resilience.fault.enabled = true;
        s.resilience.fault.stuck_rate = stuck as f32;
        s.resilience.fault.drift_rate = (stuck * 0.4) as f32;
        s.resilience.fault.burst_rate = stuck as f32;
    }
    if replication > 1 {
        s.resilience.enabled = true;
        s.resilience.replication = replication;
    }
    s
}

struct Cell {
    label: String,
    wall_s: f64,
    docs_per_s: f64,
    mean_objective: f64,
    replica_solves: u64,
    disagreements: u64,
    repairs: u64,
}

fn run_cell(label: &str, s: &Settings) -> Cell {
    let svc = Service::start(s).expect("service start");
    let set = benchmark_set("bench_10").expect("benchmark set");
    let t0 = Instant::now();
    let tickets: Vec<_> = set
        .documents
        .iter()
        .map(|d| svc.submit(d.clone()).expect("queue depth covers the set"))
        .collect();
    let mut total_objective = 0.0f64;
    let mut docs = 0usize;
    for t in tickets {
        let summary = t.wait().expect("summarize");
        total_objective += summary.objective;
        docs += 1;
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let m = svc.metrics();
    let r = m.resilience.clone().unwrap_or_default();
    svc.shutdown();
    let cell = Cell {
        label: label.to_string(),
        wall_s,
        docs_per_s: docs as f64 / wall_s,
        mean_objective: total_objective / docs as f64,
        replica_solves: r.replica_solves,
        disagreements: r.vote_disagreements,
        repairs: r.repairs,
    };
    println!(
        "{:<16} {:>7.3}s  {:>6.1} docs/s  mean-obj {:.4}  replicas={} disagree={} repairs={}",
        cell.label,
        cell.wall_s,
        cell.docs_per_s,
        cell.mean_objective,
        cell.replica_solves,
        cell.disagreements,
        cell.repairs,
    );
    cell
}

fn main() {
    let clean = run_cell("clean", &settings(0.0, 1));
    let f1_r1 = run_cell("1%-repl1", &settings(0.01, 1));
    let f1_r3 = run_cell("1%-repl3", &settings(0.01, 3));
    let f5_r1 = run_cell("5%-repl1", &settings(0.05, 1));
    let f5_r3 = run_cell("5%-repl3", &settings(0.05, 3));

    let delta = |c: &Cell| c.mean_objective - clean.mean_objective;
    let latency = |c: &Cell| c.wall_s / clean.wall_s;
    println!(
        "\nquality delta vs clean: 1%/r1 {:+.4} | 1%/r3 {:+.4} | 5%/r1 {:+.4} | 5%/r3 {:+.4}",
        delta(&f1_r1),
        delta(&f1_r3),
        delta(&f5_r1),
        delta(&f5_r3),
    );
    println!(
        "latency factor vs clean: 1%/r3 {:.2}x | 5%/r3 {:.2}x",
        latency(&f1_r3),
        latency(&f5_r3),
    );
    assert!(
        f5_r3.replica_solves > f5_r1.replica_solves,
        "replication recorded no extra solves"
    );

    let cell_json = |c: &Cell| {
        format!(
            r#"{{ "wall_s": {:.4}, "docs_per_s": {:.2}, "mean_objective": {:.6}, "quality_delta_vs_clean": {:.6}, "replica_solves": {}, "disagreements": {}, "repairs": {} }}"#,
            c.wall_s,
            c.docs_per_s,
            c.mean_objective,
            delta(c),
            c.replica_solves,
            c.disagreements,
            c.repairs,
        )
    };
    let json = format!(
        r#"{{
  "bench": "resilience",
  "status": "recorded",
  "workload": {{
    "set": "bench_10",
    "documents": 10,
    "solver": "cobi-native",
    "iterations": {ITERATIONS},
    "workers": {WORKERS},
    "devices": {DEVICES},
    "drift_rate": "0.4 x stuck rate",
    "burst_rate": "stuck rate"
  }},
  "clean": {},
  "fault_1pct_repl1": {},
  "fault_1pct_repl3": {},
  "fault_5pct_repl1": {},
  "fault_5pct_repl3": {}
}}"#,
        cell_json(&clean),
        cell_json(&f1_r1),
        cell_json(&f1_r3),
        cell_json(&f5_r1),
        cell_json(&f5_r3),
    );
    println!("\n{json}");
    if std::env::var("COBI_BENCH_RECORD").is_ok() {
        std::fs::write("BENCH_resilience.json", format!("{json}\n")).expect("write baseline");
        println!("recorded baseline to BENCH_resilience.json");
    }
}
