//! Property tests over the repo's structural invariants, driven by the
//! in-tree mini driver (`util::proptest`) — sized cases with shrinking,
//! so failures report a minimal counterexample plus a replay call.
//!
//! Three invariant families (ISSUE 7):
//!
//! * integer/f64 energy agreement: `QuantIsing`'s i64 energy equals
//!   `Ising`'s f64 energy EXACTLY on integer-valued instances;
//! * decomposition coverage: every strategy's full reduction touches
//!   every active sentence (covered by a window or surviving verbatim),
//!   strictly shrinks per level, terminates, and ends in one final
//!   M-selection unit over the whole remaining list — no idle tail;
//! * repair: `repair_selection` always returns exactly M valid, unique,
//!   ascending indices, whatever the solver handed it.
//!
//! Plus the k-of-n platform invariants (ISSUE 9):
//!
//! * any random (n, k, relevance, redundancy) instance lowers to the
//!   Eq. 10 QUBO penalty structure exactly;
//! * repaired k-of-n selections are exactly-k, unique and ascending;
//! * the Eq. 12 `kofn_bias` is invariant under candidate relabeling.

use cobi_es::decompose::{DecomposePlan, DecomposeParams, Strategy};
use cobi_es::ising::kofn::KofnProblem;
use cobi_es::ising::{kofn_bias, EsProblem, Ising, QuantIsing};
use cobi_es::prop_assert;
use cobi_es::refine::repair_selection;
use cobi_es::util::proptest::{check_sized, DEFAULT_CASES};
use cobi_es::util::rng::Pcg32;

/// Random integer-valued Ising (coefficients in [-7, 7], the quantized
/// shape every pool instance has).
fn integer_ising(rng: &mut Pcg32, n: usize) -> Ising {
    let mut ising = Ising::new(n);
    for i in 0..n {
        ising.h[i] = rng.below(15) as f32 - 7.0;
        for j in (i + 1)..n {
            ising.set_pair(i, j, rng.below(15) as f32 - 7.0);
        }
    }
    ising
}

fn random_spins(rng: &mut Pcg32, n: usize) -> Vec<i8> {
    (0..n).map(|_| if rng.bernoulli(0.5) { 1 } else { -1 }).collect()
}

#[test]
fn quant_ising_energy_agrees_exactly_with_f64() {
    check_sized("quant-energy-agreement", 0x1A, DEFAULT_CASES, 48, |rng, n| {
        let ising = integer_ising(rng, n);
        let mut q = QuantIsing::default();
        prop_assert!(q.try_copy_from(&ising), "integer instance must quantize (n={n})");
        for _ in 0..4 {
            let spins = random_spins(rng, n);
            let fp = ising.energy(&spins);
            let int = q.energy(&spins) as f64;
            prop_assert!(
                fp.to_bits() == int.to_bits(),
                "energies disagree on n={n}: f64 {fp} vs i64 {int}"
            );
        }
        Ok(())
    });
}

/// Drive one full reduction under `plan`, checking every level's carving
/// invariants; selections keep each window's first `target` sentences
/// (which sentences win is the solver's business, not the plan's).
fn simulate_reduction(plan: &DecomposePlan, n: usize) -> Result<(), String> {
    let m = plan.params().m;
    let mut active: Vec<usize> = (0..n).collect();
    // every non-final level removes at least one sentence, so a reduction
    // of N sentences finishes within N+1 levels
    for level in 0..=n {
        let units = plan.carve(&active, level);
        prop_assert!(!units.is_empty(), "no units for {} active at level {level}", active.len());
        if units[0].is_final {
            // termination: ONE final unit selecting M from the WHOLE
            // remaining list — every survivor is offered, no idle tail
            prop_assert!(units.len() == 1, "final level has {} units", units.len());
            prop_assert!(units[0].target == m, "final target {} != M {m}", units[0].target);
            prop_assert!(
                units[0].window == active,
                "final unit covers {} of {} survivors",
                units[0].window.len(),
                active.len()
            );
            return Ok(());
        }
        // every window is a set of distinct active sentences, and every
        // target is satisfiable
        let active_set: std::collections::BTreeSet<usize> = active.iter().copied().collect();
        let mut covered = std::collections::BTreeSet::new();
        for u in &units {
            prop_assert!(
                u.target <= u.window.len(),
                "target {} exceeds window {}",
                u.target,
                u.window.len()
            );
            for &i in &u.window {
                prop_assert!(active_set.contains(&i), "window holds non-active sentence {i}");
                prop_assert!(covered.insert(i), "sentence {i} carved into two windows");
            }
        }
        // next level: selected sentences of covered windows + every
        // uncovered survivor, in document order — nothing is dropped
        let mut selected = std::collections::BTreeSet::new();
        for u in &units {
            selected.extend(u.window.iter().take(u.target).copied());
        }
        let next: Vec<usize> = active
            .iter()
            .copied()
            .filter(|i| !covered.contains(i) || selected.contains(i))
            .collect();
        prop_assert!(
            next.len() < active.len(),
            "level {level} did not shrink ({} -> {})",
            active.len(),
            next.len()
        );
        active = next;
    }
    Err(format!("reduction did not terminate in {} levels", n + 1))
}

#[test]
fn every_strategy_covers_every_sentence_and_terminates() {
    check_sized("decompose-coverage", 0xDC, DEFAULT_CASES, 240, |rng, size| {
        // random valid params: P >= 2, 1 <= Q < P, 1 <= M <= Q
        let p = 2 + rng.below(24) as usize;
        let q = 1 + rng.below(p as u32 - 1) as usize;
        let m = 1 + rng.below(q as u32) as usize;
        let params = DecomposeParams { p, q, m };
        params.validate().map_err(|e| e.to_string())?;
        let n = m + size; // documents always hold at least M sentences
        for strategy in [Strategy::Window, Strategy::Tree, Strategy::Streaming] {
            let plan = DecomposePlan::new(strategy, &params).map_err(|e| e.to_string())?;
            simulate_reduction(&plan, n)
                .map_err(|e| format!("{strategy} P={p} Q={q} M={m} N={n}: {e}"))?;
        }
        Ok(())
    });
}

/// Random extractive-summarization problem with n sentences, target m.
fn random_problem(rng: &mut Pcg32, n: usize, m: usize) -> EsProblem {
    let mu: Vec<f32> = (0..n).map(|_| rng.range_f32(0.1, 0.95)).collect();
    let mut beta = vec![0.0f32; n * n];
    for i in 0..n {
        for j in (i + 1)..n {
            let b = rng.range_f32(0.1, 0.9);
            beta[i * n + j] = b;
            beta[j * n + i] = b;
        }
    }
    EsProblem { mu, beta, lambda: 0.6, m }
}

#[test]
fn repair_always_returns_exactly_m_valid_selections() {
    check_sized("repair-k-of-n", 0x3E, DEFAULT_CASES, 40, |rng, size| {
        let n = 1 + size;
        let m = 1 + rng.below(n as u32) as usize;
        let p = random_problem(rng, n, m);
        // a solver's raw selection can be any subset: empty, too small,
        // too large, or already perfect
        let selected: Vec<usize> = (0..n).filter(|_| rng.bernoulli(0.4)).collect();
        let repaired = repair_selection(&p, selected);
        prop_assert!(
            repaired.len() == m,
            "repair returned {} of m={m} (n={n})",
            repaired.len()
        );
        prop_assert!(repaired.iter().all(|&i| i < n), "index out of range (n={n})");
        prop_assert!(
            repaired.windows(2).all(|w| w[0] < w[1]),
            "selections not strictly ascending: {repaired:?}"
        );
        Ok(())
    });
}

/// Random k-of-n instance: relevance values + symmetric redundancy
/// costs with a zero diagonal (the shape every platform workload emits).
fn random_kofn(rng: &mut Pcg32, n: usize, k: usize) -> KofnProblem {
    let value: Vec<f32> = (0..n).map(|_| rng.range_f32(0.1, 0.95)).collect();
    let mut cost = vec![0.0f32; n * n];
    for i in 0..n {
        for j in (i + 1)..n {
            let c = rng.range_f32(0.05, 0.9);
            cost[i * n + j] = c;
            cost[j * n + i] = c;
        }
    }
    KofnProblem { value, cost, k }
}

#[test]
fn kofn_qubo_has_the_eq10_penalty_structure() {
    check_sized("kofn-qubo-structure", 0x4F, DEFAULT_CASES, 24, |rng, size| {
        let n = 2 + size;
        let k = 1 + rng.below(n as u32 - 1) as usize;
        let p = random_kofn(rng, n, k);
        let gamma = p.gamma();
        let bias = rng.range_f32(-1.0, 1.0);
        let q = p.qubo(bias);
        for i in 0..n {
            let want = -(p.value[i] + bias) - 2.0 * gamma * k as f32 + gamma;
            prop_assert!(
                (q.linear[i] - want).abs() <= 1e-4,
                "linear[{i}] = {} != {want} (n={n} k={k})",
                q.linear[i]
            );
            prop_assert!(
                q.quad[i * n + i] == 0.0,
                "diagonal quad[{i},{i}] must stay zero"
            );
            for j in 0..n {
                if j != i {
                    let want = p.cost[i * n + j] + gamma;
                    prop_assert!(
                        (q.quad[i * n + j] - want).abs() <= 1e-4,
                        "quad[{i},{j}] = {} != {want} (n={n} k={k})",
                        q.quad[i * n + j]
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn kofn_repair_returns_exactly_k_unique_ascending() {
    check_sized("kofn-repair", 0x5B, DEFAULT_CASES, 32, |rng, size| {
        let n = 2 + size;
        let k = 1 + rng.below(n as u32 - 1) as usize;
        let p = random_kofn(rng, n, k).as_es();
        // raw solver output can be any subset, including infeasible ones
        let selected: Vec<usize> = (0..n).filter(|_| rng.bernoulli(0.35)).collect();
        let repaired = repair_selection(&p, selected);
        prop_assert!(repaired.len() == k, "repair returned {} of k={k}", repaired.len());
        prop_assert!(repaired.iter().all(|&i| i < n), "index out of range (n={n})");
        prop_assert!(
            repaired.windows(2).all(|w| w[0] < w[1]),
            "not strictly ascending/unique: {repaired:?}"
        );
        Ok(())
    });
}

#[test]
fn kofn_bias_is_invariant_under_candidate_relabeling() {
    check_sized("kofn-bias-permutation", 0x6C, DEFAULT_CASES, 24, |rng, size| {
        let n = 2 + size;
        let k = 1 + rng.below(n as u32 - 1) as usize;
        let p = random_kofn(rng, n, k);
        // random permutation (seeded Fisher–Yates) relabeling the items
        let mut perm: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = rng.below(i as u32 + 1) as usize;
            perm.swap(i, j);
        }
        let mut value = vec![0.0f32; n];
        let mut cost = vec![0.0f32; n * n];
        for i in 0..n {
            value[perm[i]] = p.value[i];
            for j in 0..n {
                cost[perm[i] * n + perm[j]] = p.cost[i * n + j];
            }
        }
        let permuted = KofnProblem { value, cost, k };
        let (a, _) = p.qubo(0.0).to_ising();
        let (b, _) = permuted.qubo(0.0).to_ising();
        let (ba, bb) = (kofn_bias(&a), kofn_bias(&b));
        prop_assert!(
            ba.to_bits() == bb.to_bits(),
            "bias not permutation-invariant: {ba} vs {bb} (n={n} k={k})"
        );
        Ok(())
    });
}
