//! Integration tests for the resilience subsystem: byte-reproducibility
//! of faulty runs across pool shapes, quality preservation under
//! replicated voting, and service-level telemetry.
//!
//! The `full_tier1_slice_with_resilience_enabled` test is env-gated
//! (COBI_ES_RESILIENCE_FULL=1, set by CI) and re-runs a slice of the
//! tier-1 service paths with `[resilience] enabled = true`, so the fault
//! path cannot rot silently while staying cheap for local `cargo test`.

use cobi_es::config::Settings;
use cobi_es::corpus::benchmark_set;
use cobi_es::embed::{Embedder, HashEmbedder};
use cobi_es::ising::{exact_bounds, EsProblem};
use cobi_es::pipeline::Summary;
use cobi_es::sched::{doc_seed, summarize_with_pool, DevicePool};
use cobi_es::service::Service;

/// COBI settings with a seeded fault model and the resilience layer on.
fn faulty_settings(stuck: f32, replication: usize) -> Settings {
    let mut s = Settings::default();
    s.pipeline.solver = "cobi".into();
    s.pipeline.iterations = 4;
    s.resilience.fault.enabled = stuck > 0.0;
    s.resilience.fault.stuck_rate = stuck;
    s.resilience.fault.drift_rate = 0.02;
    s.resilience.fault.burst_rate = 0.05;
    s.resilience.enabled = replication > 1;
    s.resilience.replication = replication;
    s
}

fn pooled_summary(s: &Settings, doc_idx: usize) -> Summary {
    let set = benchmark_set("bench_10").unwrap();
    let doc = &set.documents[doc_idx];
    let pool = DevicePool::start(s, None).unwrap();
    let mut cfg = s.pipeline.clone();
    cfg.summary_len = set.summary_len;
    cfg.seed = doc_seed(cfg.seed, &doc.id);
    let mut client = pool.client(cfg.seed);
    let summary = summarize_with_pool(doc, &cfg, &mut client).unwrap();
    drop(client);
    pool.shutdown();
    summary
}

#[test]
fn faulty_voting_run_is_byte_reproducible_across_pool_shapes() {
    // acceptance pin: a seeded FaultModel (5% stuck, 2% drift) with
    // replication-3 voting produces byte-identical summaries on a
    // 1-device no-coalesce pool and a 4-device coalescing pool — fault
    // draws derive from request seeds, never from device identity
    let mut s1 = faulty_settings(0.05, 3);
    s1.sched.devices = 1;
    s1.sched.max_coalesce = 1;
    s1.sched.linger_us = 0;
    let mut s4 = faulty_settings(0.05, 3);
    s4.sched.devices = 4;
    s4.sched.max_coalesce = 8;
    s4.sched.linger_us = 2_000;
    for doc_idx in [0, 3] {
        let a = pooled_summary(&s1, doc_idx);
        let b = pooled_summary(&s4, doc_idx);
        assert_eq!(a.selected, b.selected, "doc {doc_idx}");
        assert_eq!(a.sentences, b.sentences, "doc {doc_idx}");
        assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "doc {doc_idx}");
    }
}

#[test]
fn voting_holds_bench10_quality_at_the_clean_baseline() {
    // acceptance pin: under 5% stuck + 2% drift faults with replication-3
    // voting, bench_10 summary quality stays at the clean run's level —
    // per document within a 0.03 normalized-objective band, and on
    // average no more than 0.005 below clean (energy-vote winners can
    // legitimately differ from clean solves, so exact equality is not
    // the invariant)
    let set = benchmark_set("bench_10").unwrap();
    let clean = faulty_settings(0.0, 1);
    let faulty = faulty_settings(0.05, 3);

    let mut embedder = HashEmbedder::new();
    let mut clean_mean = 0.0f64;
    let mut faulty_mean = 0.0f64;
    for (idx, doc) in set.documents.iter().enumerate() {
        let scores = embedder.scores(&doc.sentences).unwrap();
        let problem = EsProblem {
            mu: scores.mu,
            beta: scores.beta,
            lambda: clean.pipeline.lambda,
            m: set.summary_len,
        };
        let bounds = exact_bounds(&problem);
        let c = bounds.normalize(pooled_summary(&clean, idx).objective);
        let f = bounds.normalize(pooled_summary(&faulty, idx).objective);
        assert!(
            f >= c - 0.03,
            "doc {idx}: faulty+voting {f:.4} fell below clean {c:.4}"
        );
        clean_mean += c;
        faulty_mean += f;
    }
    let n = set.documents.len() as f64;
    assert!(
        faulty_mean / n >= clean_mean / n - 0.005,
        "mean quality degraded: faulty {:.4} vs clean {:.4}",
        faulty_mean / n,
        clean_mean / n
    );
}

#[test]
fn service_reports_resilience_and_fault_counters() {
    let mut s = faulty_settings(0.2, 2);
    s.service.workers = 2;
    let svc = Service::start(&s).unwrap();
    assert!(svc.is_pooled());
    let set = benchmark_set("bench_10").unwrap();
    let tickets: Vec<_> = set.documents[..4]
        .iter()
        .map(|d| svc.submit(d.clone()).unwrap())
        .collect();
    for t in tickets {
        assert_eq!(t.wait().unwrap().selected.len(), 3);
    }
    let m = svc.metrics();
    assert_eq!(m.completed, 4);
    let r = m.resilience.expect("resilience telemetry");
    assert_eq!(r.requests, 4, "one pool request per bench_10 document");
    assert_eq!(r.replica_solves, 4 * 2 * s.pipeline.iterations as u64);
    assert!(r.faults.any(), "20% stuck rate must inject faults");
    let report = m.report();
    assert!(report.contains("resilience:"), "{report}");
    assert!(report.contains("faults solves="), "{report}");
    svc.shutdown();
}

#[test]
fn fault_injection_without_the_resilience_layer_still_counts() {
    // faults can be enabled standalone (the degradation-measurement
    // shape the fault-sweep experiment uses): no wrapper, but the
    // counters still surface through the pool
    let s = faulty_settings(0.3, 1);
    let svc = Service::start(&s).unwrap();
    let set = benchmark_set("bench_10").unwrap();
    let t = svc.submit(set.documents[0].clone()).unwrap();
    t.wait().unwrap();
    let m = svc.metrics();
    let r = m.resilience.expect("fault counters surface without the wrapper");
    assert_eq!(r.requests, 0, "no resilient wrapper, no replication counters");
    assert!(r.faults.any());
    svc.shutdown();
}

#[test]
fn no_pool_workers_still_apply_the_fault_model() {
    // regression: the local (no-pool) worker route must go through the
    // same resilience/fault wiring as the pooled route — a `--no-pool
    // --fault-stuck` service must not silently serve clean summaries
    let summaries = |stuck: f32| -> Vec<Vec<usize>> {
        let mut s = faulty_settings(stuck, 1);
        s.sched.enabled = false; // force SolveRoute::Local
        s.service.workers = 1; // one worker => one deterministic seed
        let svc = Service::start(&s).unwrap();
        assert!(!svc.is_pooled());
        let set = benchmark_set("bench_10").unwrap();
        let tickets: Vec<_> = set
            .documents
            .iter()
            .map(|d| svc.submit(d.clone()).unwrap())
            .collect();
        let out: Vec<Vec<usize>> = tickets
            .into_iter()
            .map(|t| t.wait().unwrap().selected)
            .collect();
        if stuck > 0.0 {
            // the service-owned counter block makes no-pool fault
            // telemetry visible in ::STATS:: too
            let m = svc.metrics();
            let r = m.resilience.expect("no-pool resilience telemetry");
            assert!(r.faults.any(), "no fault injections counted");
            assert!(m.report().contains("faults solves="), "{}", m.report());
        }
        svc.shutdown();
        out
    };
    let clean = summaries(0.0);
    let heavy = summaries(0.8); // 80% stuck: outputs cannot all survive
    assert_eq!(clean.len(), 10);
    assert!(
        clean.iter().zip(&heavy).any(|(c, f)| c != f),
        "local-route faults had no effect on any of 10 documents"
    );
}

#[test]
fn full_tier1_slice_with_resilience_enabled() {
    // env-gated (CI sets COBI_ES_RESILIENCE_FULL=1): re-run a tier-1
    // service slice with the resilience layer on across strategies and
    // solvers; unset, a single smoke pass keeps the path alive locally
    let full = std::env::var("COBI_ES_RESILIENCE_FULL").is_ok();
    let strategies: &[cobi_es::decompose::Strategy] = if full {
        &[
            cobi_es::decompose::Strategy::Window,
            cobi_es::decompose::Strategy::Tree,
            cobi_es::decompose::Strategy::Streaming,
        ]
    } else {
        &[cobi_es::decompose::Strategy::Window]
    };
    let solvers: &[&str] = if full { &["cobi", "tabu"] } else { &["cobi"] };
    let set_name = if full { "cnn_dm_20" } else { "bench_10" };
    let docs = if full { 6 } else { 2 };

    let set = benchmark_set(set_name).unwrap();
    for &solver in solvers {
        for &strategy in strategies {
            let mut s = faulty_settings(0.05, 2);
            s.pipeline.solver = solver.into();
            s.pipeline.strategy = strategy;
            s.service.workers = 2;
            let svc = Service::start(&s).unwrap();
            let tickets: Vec<_> = set.documents[..docs]
                .iter()
                .map(|d| svc.submit(d.clone()).unwrap())
                .collect();
            for t in tickets {
                let summary = t.wait().unwrap();
                assert_eq!(
                    summary.selected.len(),
                    set.summary_len,
                    "{solver}/{strategy}"
                );
            }
            let m = svc.metrics();
            assert_eq!(m.completed, docs as u64, "{solver}/{strategy}");
            assert!(m.resilience.is_some(), "{solver}/{strategy}");
            svc.shutdown();
        }
    }
}
