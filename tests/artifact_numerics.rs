//! Rust <-> JAX numerical cross-checks through the PJRT runtime.
//!
//! Requires `artifacts/` (run `make artifacts` first); each test fails
//! loudly if the artifacts are missing, because silent skips would let
//! the three-layer contract rot.
//!
//! Compiled only with `--features pjrt` (DESIGN.md §Substitutions): the
//! default offline build has no PJRT backend, so `ArtifactRuntime::open`
//! is a stub that always errors — gating the whole file keeps "fail
//! loudly when artifacts are missing" for pjrt builds without making the
//! native-only tier-1 run fail by construction.
#![cfg(feature = "pjrt")]

use std::path::Path;

use cobi_es::cobi::{CobiDevice, PADDED_SPINS};
use cobi_es::config::CobiConfig;
use cobi_es::embed::{Embedder, HashEmbedder};
use cobi_es::ising::Ising;
use cobi_es::quant::{quantize, Precision, Rounding};
use cobi_es::runtime::artifacts::{Arg, ArtifactRuntime};
use cobi_es::runtime::{testvec, EncoderPipeline};
use cobi_es::solvers::exact::ising_ground_exhaustive;
use cobi_es::util::rng::Pcg32;

fn runtime() -> ArtifactRuntime {
    let dir = std::env::var("COBI_ES_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    ArtifactRuntime::open(Path::new(&dir)).expect(
        "artifacts/ missing — run `make artifacts` before `cargo test` \
         (the Makefile test target does this)",
    )
}

fn assert_allclose(got: &[f32], want: &[f32], atol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    let mut worst = 0.0f32;
    for (g, w) in got.iter().zip(want) {
        worst = worst.max((g - w).abs());
    }
    assert!(worst <= atol, "{what}: max abs err {worst} > {atol}");
}

#[test]
fn energy_artifact_matches_jax_testvector() {
    let rt = runtime();
    let exe = rt.executable("energy").unwrap();
    let tv = testvec::load(Path::new(
        &format!("{}/testvec_energy.bin", artifacts_dir()),
    ))
    .unwrap();
    let j = tv.inputs[0].as_f32().unwrap();
    let h = tv.inputs[1].as_f32().unwrap();
    let s = tv.inputs[2].as_f32().unwrap();
    let want = tv.outputs[0].as_f32().unwrap();
    let outs = exe.run(&[Arg::F32(j), Arg::F32(h), Arg::F32(s)]).unwrap();
    assert_allclose(&outs[0], want, 1e-2, "energy");
}

fn artifacts_dir() -> String {
    std::env::var("COBI_ES_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

#[test]
fn anneal_artifact_matches_jax_testvector() {
    // identical inputs -> identical spins (XLA CPU is deterministic for a
    // fixed artifact; this pins rust-side input marshalling)
    let rt = runtime();
    let exe = rt.executable("anneal").unwrap();
    let tv = testvec::load(Path::new(
        &format!("{}/testvec_anneal.bin", artifacts_dir()),
    ))
    .unwrap();
    let args: Vec<Arg> = tv.inputs.iter().map(|a| Arg::F32(a.as_f32().unwrap())).collect();
    let want = tv.outputs[0].as_f32().unwrap();
    let outs = exe.run(&args).unwrap();
    assert_allclose(&outs[0], want, 0.0, "anneal spins");
}

#[test]
fn encoder_artifact_matches_jax_testvector() {
    let rt = runtime();
    let exe = rt.executable("encoder").unwrap();
    let tv = testvec::load(Path::new(
        &format!("{}/testvec_encoder.bin", artifacts_dir()),
    ))
    .unwrap();
    let toks = tv.inputs[0].as_i32().unwrap();
    let want = tv.outputs[0].as_f32().unwrap();
    let outs = exe.run(&[Arg::I32(toks)]).unwrap();
    assert_allclose(&outs[0], want, 2e-4, "encoder embeddings");
}

#[test]
fn cosine_artifact_matches_jax_testvector() {
    let rt = runtime();
    let exe = rt.executable("cosine").unwrap();
    let tv = testvec::load(Path::new(
        &format!("{}/testvec_cosine.bin", artifacts_dir()),
    ))
    .unwrap();
    let emb = tv.inputs[0].as_f32().unwrap();
    let mask = tv.inputs[1].as_f32().unwrap();
    let outs = exe.run(&[Arg::F32(emb), Arg::F32(mask)]).unwrap();
    assert_allclose(&outs[0], tv.outputs[0].as_f32().unwrap(), 1e-4, "mu");
    assert_allclose(&outs[1], tv.outputs[1].as_f32().unwrap(), 1e-4, "beta");
}

#[test]
fn hlo_and_native_cobi_backends_agree_statistically() {
    // chaotic dynamics diverge bitwise across math libraries; the CONTRACT
    // is statistical: on a quantized instance, best-of-8 energies from the
    // two backends must land within a small relative gap
    let rt = runtime();
    let mut rng = Pcg32::seeded(17);
    let n = 16;
    let mut ising = Ising::new(n);
    for i in 0..n {
        ising.h[i] = rng.range_f32(-3.0, 3.0);
        for j in (i + 1)..n {
            ising.set_pair(i, j, rng.range_f32(-1.0, 1.0));
        }
    }
    let inst = quantize(&ising, Precision::CobiInt, Rounding::Deterministic, &mut rng);
    let (ground, _, _) = ising_ground_exhaustive(&inst);

    let cfg = CobiConfig::default();
    let mut native = CobiDevice::native(cfg.clone(), 5);
    let mut hlo = CobiDevice::hlo(cfg, 5, &rt).unwrap();
    let best = |dev: &mut CobiDevice| -> f64 {
        (0..8)
            .map(|_| dev.program_and_solve(&inst).unwrap().energy)
            .fold(f64::INFINITY, f64::min)
    };
    let bn = best(&mut native);
    let bh = best(&mut hlo);
    let span = ground.abs().max(1.0);
    assert!(
        (bn - bh).abs() / span < 0.15,
        "native best {bn} vs hlo best {bh} (ground {ground})"
    );
    // both should be within 20% of ground on this small instance
    assert!((bn - ground) / span < 0.2, "native {bn} vs ground {ground}");
    assert!((bh - ground) / span < 0.2, "hlo {bh} vs ground {ground}");
}

#[test]
fn encoder_pipeline_produces_dense_positive_scores() {
    let rt = runtime();
    let mut enc = EncoderPipeline::new(&rt).unwrap();
    let set = cobi_es::corpus::benchmark_set("cnn_dm_20").unwrap();
    let doc = &set.documents[0];
    let s = enc.scores(&doc.sentences).unwrap();
    assert_eq!(s.n(), 20);
    // SBERT-like geometry through the AOT path too
    let n = s.n();
    for i in 0..n {
        assert!(s.mu[i].is_finite());
        assert!(s.mu[i].abs() <= 1.0 + 1e-4);
        for j in 0..n {
            if i != j {
                assert!(
                    s.beta[i * n + j].abs() > 1e-6,
                    "zero beta at ({i},{j}): dense coupling violated"
                );
            } else {
                assert_eq!(s.beta[i * n + j], 0.0);
            }
        }
    }
}

#[test]
fn aot_and_native_embedders_agree_on_redundancy_structure() {
    // different embedding models, same *structure*: the most-redundant
    // pairs under the AOT encoder should correlate with the hash
    // embedder's (rank correlation over pairs > 0)
    let rt = runtime();
    let mut aot = EncoderPipeline::new(&rt).unwrap();
    let mut native = HashEmbedder::new();
    let set = cobi_es::corpus::benchmark_set("cnn_dm_20").unwrap();
    let doc = &set.documents[1];
    let a = aot.scores(&doc.sentences).unwrap();
    let b = native.scores(&doc.sentences).unwrap();
    let n = a.n();
    let mut pairs: Vec<(f32, f32)> = Vec::new();
    for i in 0..n {
        for j in (i + 1)..n {
            pairs.push((a.beta[i * n + j], b.beta[i * n + j]));
        }
    }
    // Pearson over pairs
    let (ma, mb) = (
        pairs.iter().map(|p| p.0 as f64).sum::<f64>() / pairs.len() as f64,
        pairs.iter().map(|p| p.1 as f64).sum::<f64>() / pairs.len() as f64,
    );
    let (mut num, mut da, mut db) = (0.0f64, 0.0f64, 0.0f64);
    for (x, y) in &pairs {
        let (x, y) = (*x as f64 - ma, *y as f64 - mb);
        num += x * y;
        da += x * x;
        db += y * y;
    }
    let corr = num / (da.sqrt() * db.sqrt());
    assert!(
        corr > 0.2,
        "AOT and native redundancy structure uncorrelated: r = {corr:.3}"
    );
}

#[test]
fn artifact_manifest_covers_all_graphs() {
    let rt = runtime();
    let names = rt.graph_names();
    for want in ["anneal", "cosine", "encoder", "energy"] {
        assert!(names.contains(&want.to_string()), "missing {want}");
    }
    // spot-check padded spin dimension agreement
    let spec = rt.spec("anneal").unwrap();
    assert_eq!(spec.inputs[0].dims, vec![PADDED_SPINS, PADDED_SPINS]);
}

#[test]
fn anneal_batch_artifact_matches_jax_testvector() {
    let rt = runtime();
    let exe = rt.executable("anneal_batch").unwrap();
    let tv = testvec::load(Path::new(&format!(
        "{}/testvec_anneal_batch.bin",
        artifacts_dir()
    )))
    .unwrap();
    let args: Vec<Arg> = tv
        .inputs
        .iter()
        .map(|a| Arg::F32(a.as_f32().unwrap()))
        .collect();
    let want = tv.outputs[0].as_f32().unwrap();
    let outs = exe.run(&args).unwrap();
    assert_allclose(&outs[0], want, 0.0, "anneal_batch spins");
}

#[test]
fn batched_device_dispatch_matches_instance_count() {
    // solve_batch over 11 instances: chunks of 8 through anneal_batch,
    // results per instance, stats charged per solve
    let rt = runtime();
    let mut rng = Pcg32::seeded(33);
    let mut instances = Vec::new();
    for k in 0..11 {
        let mut ising = Ising::new(12);
        for i in 0..12 {
            ising.h[i] = rng.range_f32(-3.0, 3.0);
            for j in (i + 1)..12 {
                ising.set_pair(i, j, rng.range_f32(-1.0, 1.0));
            }
        }
        let q = quantize(&ising, Precision::CobiInt, Rounding::Deterministic, &mut rng);
        instances.push(q);
        let _ = k;
    }
    let refs: Vec<&Ising> = instances.iter().collect();
    let mut dev = CobiDevice::hlo(CobiConfig::default(), 9, &rt).unwrap();
    let results = dev.program_and_solve_batch(&refs).unwrap();
    assert_eq!(results.len(), 11);
    for (inst, r) in instances.iter().zip(&results) {
        assert_eq!(r.spins.len(), 12);
        assert!((inst.energy(&r.spins) - r.energy).abs() < 1e-6);
    }
    assert_eq!(dev.stats().solves, 11);
}
