//! Cross-workload conformance suite (ISSUE 9).
//!
//! Every registered workload — ES, diverse retrieval, facility
//! dispersion — routed through the generic k-of-n platform must
//! produce byte-identical selections whether solved inline, on a
//! 1-device pool, or on a 4-device pool, under both the window and
//! tree decomposition strategies, and with the replication-1
//! resilience wrapper interposed. Feasibility (exactly k unique
//! ascending indices naming real candidates) and name stability
//! (`problem.workload()` round-trips through the registry) ride along.
//!
//! Setting `COBI_ES_WORKLOAD_SMOKE=1` additionally drives one
//! `::WORKLOAD retrieval::` request through a real TCP server — the
//! end-to-end service route for a non-ES workload.

use cobi_es::config::Settings;
use cobi_es::corpus::{benchmark_set, workload_requests};
use cobi_es::decompose::Strategy;
use cobi_es::pipeline::Summary;
use cobi_es::sched::DevicePool;
use cobi_es::workload::es::EsWorkload;
use cobi_es::workload::{
    problem_from_request, resolve, select_inline, select_with_pool, KOfNProblem, WORKLOADS,
};

/// Pinned problems per workload exercised by each check — more than one
/// so distinct salted seeds actually flow through the pool.
const TAKE: usize = 2;

/// Conformance settings: the deterministic tabu backend at a low
/// iteration count, on both the inline path and the pool devices
/// (non-portfolio, so byte-identity holds with the warm-start cache out
/// of the picture).
fn base_settings() -> Settings {
    let mut s = Settings::default();
    s.pipeline.solver = "tabu".into();
    s.pipeline.iterations = 3;
    s.sched.backend = "tabu".into();
    s
}

/// First `take` pinned problems of a registered workload: bench_10
/// documents for ES, the pinned request corpus for everything else.
fn problems_for(workload: &str, settings: &Settings, take: usize) -> Vec<Box<dyn KOfNProblem>> {
    match workload {
        "es" => {
            let set = benchmark_set("bench_10").unwrap();
            let k = set.summary_len;
            set.documents
                .into_iter()
                .take(take)
                .map(|d| Box::new(EsWorkload::new(d, k)) as Box<dyn KOfNProblem>)
                .collect()
        }
        _ => workload_requests(workload)
            .unwrap()
            .into_iter()
            .take(take)
            .map(|r| problem_from_request(workload, &r.id, &r.lines, &settings.workload).unwrap())
            .collect(),
    }
}

fn assert_same(got: &Summary, want: &Summary, ctx: &str) {
    assert_eq!(got.selected, want.selected, "{ctx}: selected indices differ");
    assert_eq!(got.sentences, want.sentences, "{ctx}: selected candidates differ");
    assert_eq!(
        got.objective.to_bits(),
        want.objective.to_bits(),
        "{ctx}: objective differs ({} vs {})",
        got.objective,
        want.objective
    );
}

#[test]
fn registry_names_are_stable_and_round_trip() {
    // the registry is part of the wire protocol (`::WORKLOAD <name>::`)
    // and the cache-tag scheme — renames are breaking changes
    assert_eq!(WORKLOADS, ["es", "retrieval", "dispersion"]);
    let s = base_settings();
    for &w in WORKLOADS.iter() {
        assert_eq!(resolve(w), Some(w), "registry name '{w}' must round-trip");
        for p in problems_for(w, &s, TAKE) {
            assert_eq!(p.workload(), w, "problem {} reports a foreign workload", p.id());
        }
    }
    assert_eq!(resolve("not-a-workload"), None);
}

#[test]
fn every_workload_selects_exactly_k_real_candidates() {
    let s = base_settings();
    for &w in WORKLOADS.iter() {
        for p in problems_for(w, &s, TAKE) {
            let cands = p.candidates();
            let k = p.k();
            let ctx = format!("{w}/{}", p.id());
            let sum = select_inline(p.as_ref(), &s, None).unwrap();
            assert_eq!(sum.selected.len(), k, "{ctx}: not exactly k");
            assert!(
                sum.selected.windows(2).all(|pair| pair[0] < pair[1]),
                "{ctx}: indices not strictly ascending: {:?}",
                sum.selected
            );
            assert!(
                sum.selected.iter().all(|&i| i < cands.len()),
                "{ctx}: index out of range: {:?}",
                sum.selected
            );
            assert_eq!(sum.sentences.len(), k, "{ctx}: candidate list length");
            for (&i, sel) in sum.selected.iter().zip(&sum.sentences) {
                assert_eq!(&cands[i], sel, "{ctx}: selection names a wrong candidate");
            }
        }
    }
}

#[test]
fn selections_are_byte_identical_across_pool_shapes_and_strategies() {
    for strategy in [Strategy::Window, Strategy::Tree] {
        let mut s = base_settings();
        s.pipeline.strategy = strategy;
        let mut one = s.clone();
        one.sched.devices = 1;
        let mut four = s.clone();
        four.sched.devices = 4;
        let pool1 = DevicePool::start(&one, None).unwrap();
        let pool4 = DevicePool::start(&four, None).unwrap();
        for &w in WORKLOADS.iter() {
            for p in problems_for(w, &s, TAKE) {
                let ctx = format!("{w}/{} ({strategy})", p.id());
                let inline = select_inline(p.as_ref(), &s, None).unwrap();
                let on_one = {
                    let h = pool1.handle();
                    select_with_pool(p.as_ref(), &s.pipeline, &h).unwrap()
                };
                let on_four = {
                    let h = pool4.handle();
                    select_with_pool(p.as_ref(), &s.pipeline, &h).unwrap()
                };
                assert_same(&on_one, &inline, &format!("{ctx}: 1-device pool vs inline"));
                assert_same(&on_four, &inline, &format!("{ctx}: 4-device pool vs inline"));
            }
        }
        pool1.shutdown();
        pool4.shutdown();
    }
}

#[test]
fn replication_one_resilience_is_a_byte_transparent_wrapper() {
    // replica_seed(s, 0) == s and voting over one verified replica is
    // the identity, so resilience at replication 1 (spin repair off)
    // must not perturb any workload's bytes
    let plain = base_settings();
    let mut res = base_settings();
    res.resilience.enabled = true;
    res.resilience.replication = 1;
    res.resilience.repair = false;
    res.resilience.calibrate = false;
    for &w in WORKLOADS.iter() {
        for p in problems_for(w, &plain, TAKE) {
            let ctx = format!("{w}/{}: replication-1 resilience", p.id());
            let bare = select_inline(p.as_ref(), &plain, None).unwrap();
            let wrapped = select_inline(p.as_ref(), &res, None).unwrap();
            assert_same(&wrapped, &bare, &ctx);
        }
    }
}

#[test]
fn workload_smoke_serves_a_retrieval_request_over_tcp() {
    // env-gated end-to-end smoke (CI runs it with
    // COBI_ES_WORKLOAD_SMOKE=1): one `::WORKLOAD retrieval::` request
    // through a real listener, checked against the pinned corpus shape
    if std::env::var("COBI_ES_WORKLOAD_SMOKE").is_err() {
        return;
    }
    use cobi_es::service::tcp::{select_remote, TcpServer};
    use cobi_es::service::Service;
    use std::sync::Arc;

    let mut settings = base_settings();
    settings.service.workers = 1;
    settings.pipeline.iterations = 2;
    let svc = Arc::new(Service::start(&settings).unwrap());
    let server = TcpServer::start(svc, 0).unwrap();

    let req = &workload_requests("retrieval").unwrap()[0];
    let lines: Vec<&str> = req.lines.iter().map(String::as_str).collect();
    let selected = select_remote(server.addr, "retrieval", &lines).unwrap();
    assert_eq!(selected.len(), settings.workload.retrieval_k);
    for s in &selected {
        assert!(
            lines[1..].contains(&s.as_str()),
            "selected line is not a candidate passage: {s}"
        );
    }
    // the service route is seeded end to end: an identical request
    // selects identically (the TCP path derives its own request id, so
    // determinism — not id-keyed byte equality with the corpus run —
    // is the contract here)
    let again = select_remote(server.addr, "retrieval", &lines).unwrap();
    assert_eq!(selected, again, "TCP workload route is not deterministic");
    server.stop();
}
