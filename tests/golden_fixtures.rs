//! Golden selection fixtures (ISSUE 9): byte-for-byte regression pins
//! for every registered workload.
//!
//! Each fixture under `tests/fixtures/` holds one line per pinned
//! request — `<id> selected=[..] objective=<6dp>` — computed with the
//! deterministic tabu backend. The test recomputes the block and diffs
//! it against the committed file byte for byte, so ANY drift in seeds,
//! lowering, decomposition, or solver order fails loudly.
//!
//! Lifecycle:
//!
//! * `COBI_ES_BLESS=1 cargo test --test golden_fixtures` recomputes
//!   every fixture and overwrites the files (commit the diff);
//! * a fixture whose first line is `UNBLESSED` has never been blessed
//!   on a real toolchain — the test then computes the block twice and
//!   asserts self-consistency instead of file equality;
//! * otherwise the recomputed block must match the file exactly.

use std::fmt::Write as _;
use std::path::PathBuf;

use cobi_es::config::Settings;
use cobi_es::corpus::{benchmark_set, workload_requests};
use cobi_es::workload::es::EsWorkload;
use cobi_es::workload::{problem_from_request, select_inline, KOfNProblem};

/// Sentinel first line marking a fixture that still needs blessing on a
/// machine with a Rust toolchain (`COBI_ES_BLESS=1`).
const UNBLESSED: &str = "UNBLESSED";

/// Fixture settings: deterministic tabu backend, low iteration count.
/// Changing these regenerates different goldens — bless after editing.
fn golden_settings() -> Settings {
    let mut s = Settings::default();
    s.pipeline.solver = "tabu".into();
    s.pipeline.iterations = 3;
    s.sched.backend = "tabu".into();
    s
}

fn fixture_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name)
}

/// One fixture line: stable id, selected indices, 6dp objective.
fn line(id: &str, sum: &cobi_es::pipeline::Summary) -> String {
    format!("{id} selected={:?} objective={:.6}", sum.selected, sum.objective)
}

/// Recompute the full fixture block for one workload.
fn compute(workload: &str) -> String {
    let s = golden_settings();
    let mut out = String::new();
    match workload {
        "es" => {
            let set = benchmark_set("bench_10").unwrap();
            let k = set.summary_len;
            for doc in set.documents {
                let id = doc.id.clone();
                let p = EsWorkload::new(doc, k);
                let sum = select_inline(&p, &s, None).unwrap();
                writeln!(out, "{}", line(&id, &sum)).unwrap();
            }
        }
        _ => {
            for r in workload_requests(workload).unwrap() {
                let p = problem_from_request(workload, &r.id, &r.lines, &s.workload).unwrap();
                let sum = select_inline(p.as_ref(), &s, None).unwrap();
                writeln!(out, "{}", line(p.id(), &sum)).unwrap();
            }
        }
    }
    out
}

/// Diff the recomputed block for `workload` against `fixture`, honoring
/// the bless/UNBLESSED lifecycle described in the module docs.
fn check(workload: &str, fixture: &str) {
    let path = fixture_path(fixture);
    let got = compute(workload);
    assert!(!got.is_empty(), "{workload}: empty fixture block");
    if std::env::var("COBI_ES_BLESS").is_ok() {
        std::fs::write(&path, &got)
            .unwrap_or_else(|e| panic!("blessing {}: {e}", path.display()));
        return;
    }
    let want = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e} (run with COBI_ES_BLESS=1)", path.display()));
    if want.lines().next() == Some(UNBLESSED) {
        // never blessed on a real toolchain: pin self-consistency so the
        // selection path is at least deterministic within this build
        let again = compute(workload);
        assert_eq!(got, again, "{workload}: recomputation is not deterministic");
        eprintln!(
            "note: {} is unblessed — run COBI_ES_BLESS=1 cargo test --test golden_fixtures \
             and commit the result",
            path.display()
        );
        return;
    }
    assert_eq!(
        got,
        want,
        "{workload}: selections drifted from {} — if intentional, rebless with COBI_ES_BLESS=1",
        path.display()
    );
}

#[test]
fn golden_es_bench_10() {
    check("es", "golden_es_bench_10.txt");
}

#[test]
fn golden_retrieval() {
    check("retrieval", "golden_retrieval.txt");
}

#[test]
fn golden_dispersion() {
    check("dispersion", "golden_dispersion.txt");
}

#[test]
fn fixture_lines_are_well_formed_when_blessed() {
    // cheap schema check on committed fixtures (skipped while unblessed):
    // every line is `<id> selected=[..] objective=<float>`
    for fixture in ["golden_es_bench_10.txt", "golden_retrieval.txt", "golden_dispersion.txt"] {
        let path = fixture_path(fixture);
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
        if text.lines().next() == Some(UNBLESSED) {
            continue;
        }
        for l in text.lines() {
            let ok = l.contains(" selected=[") && l.contains("] objective=");
            assert!(ok, "{fixture}: malformed line: {l}");
            let obj = l.rsplit("objective=").next().unwrap();
            assert!(obj.parse::<f64>().is_ok(), "{fixture}: bad objective in: {l}");
        }
    }
}
