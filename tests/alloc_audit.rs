//! Allocation audit for the integer refinement fast path.
//!
//! The claim (DESIGN.md §Perf, ISSUE 3 acceptance): once a solver's
//! scratch and the refine-local buffers are warm, the steady-state
//! quantize → solve → repair → score loop performs ZERO heap allocations
//! per iteration. Measuring "per iteration" from outside `refine` without
//! instrumenting it: run the same subproblem with 2 and with 40
//! iterations on a warmed solver — if iterations allocate nothing, the
//! two calls perform exactly the same number of allocations (all of it
//! per-call setup: formulation, trace vectors, buffer creation).
//!
//! The counter is a process-global atomic, so a concurrently allocating
//! harness thread could inflate either measurement; the test therefore
//! takes the minimum delta over several repeats (background noise only
//! ever adds). This file holds exactly one #[test] so no sibling test
//! thread allocates concurrently.
//!
//! The same test also pins the ISSUE 6 observability contract: with
//! tracing compiled in but DISABLED (the `[obs]` default), consulting
//! the obs handle on the warmed path allocates nothing —
//! `ObsShared::start_request` bails before any allocation, so the
//! measured count stays EQUAL to the untraced run.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocations_during<R>(f: impl FnOnce() -> R) -> (u64, R) {
    let before = ALLOCATIONS.load(Ordering::Relaxed);
    let out = f();
    (ALLOCATIONS.load(Ordering::Relaxed) - before, out)
}

#[test]
fn steady_state_refine_iterations_do_not_allocate() {
    use cobi_es::ising::EsProblem;
    use cobi_es::refine::{refine, RefineConfig};
    use cobi_es::solvers::greedy::GreedyDescent;
    use cobi_es::solvers::sa::SaSolver;
    use cobi_es::solvers::tabu::TabuSolver;
    use cobi_es::solvers::IsingSolver;
    use cobi_es::util::rng::Pcg32;

    let p = {
        let mut rng = Pcg32::seeded(5);
        let n = 20;
        let mu: Vec<f32> = (0..n).map(|_| rng.range_f32(0.3, 0.95)).collect();
        let mut beta = vec![0.0f32; n * n];
        for i in 0..n {
            for j in (i + 1)..n {
                let b = rng.range_f32(0.2, 0.9);
                beta[i * n + j] = b;
                beta[j * n + i] = b;
            }
        }
        EsProblem { mu, beta, lambda: 0.6, m: 6 }
    };
    let cfg_short = RefineConfig { iterations: 2, ..Default::default() };
    let cfg_long = RefineConfig { iterations: 40, ..Default::default() };

    let solvers: [(&str, Box<dyn IsingSolver>); 3] = [
        ("tabu", Box::new(TabuSolver::seeded(9))),
        ("sa", Box::new(SaSolver::seeded(9))),
        ("greedy", Box::new(GreedyDescent::new())),
    ];
    for (name, mut solver) in solvers {
        let mut rng = Pcg32::seeded(11);
        // warm the solver-owned scratch (first call sizes every buffer)
        refine(&p, &cfg_short, solver.as_mut(), &mut rng).unwrap();

        let mut min_delta = u64::MAX;
        for _ in 0..5 {
            let (short, _) =
                allocations_during(|| refine(&p, &cfg_short, solver.as_mut(), &mut rng).unwrap());
            let (long, _) =
                allocations_during(|| refine(&p, &cfg_long, solver.as_mut(), &mut rng).unwrap());
            min_delta = min_delta.min(long.saturating_sub(short));
        }
        assert_eq!(
            min_delta, 0,
            "{name}: 38 extra refinement iterations allocated {min_delta} times \
             (per-iteration work must reuse scratch buffers)"
        );
    }

    // Tracing compiled in but disabled: the obs handle is constructed
    // OUTSIDE the measured region (its Arcs allocate once), then the
    // disabled fast path is probed directly...
    let obs = cobi_es::obs::ObsShared::disabled();
    // the ISSUE 10 flight recorder rides the same handle and the same
    // contract: off by default, and consulting it costs nothing
    assert!(!obs.recorder().enabled(), "recorder must default off");
    let (probe, _) = allocations_during(|| {
        for _ in 0..256 {
            assert!(obs.start_request("alloc-audit").is_none());
            assert!(!obs.recorder().enabled());
        }
    });
    assert_eq!(probe, 0, "disabled start_request must not allocate");

    // ...and woven into the warmed refine loop, where the allocation
    // count must stay EQUAL to the untraced runs above (delta still 0).
    let mut solver = TabuSolver::seeded(9);
    let mut rng = Pcg32::seeded(11);
    refine(&p, &cfg_short, &mut solver, &mut rng).unwrap();
    let mut min_delta = u64::MAX;
    for _ in 0..5 {
        let (short, _) = allocations_during(|| {
            assert!(obs.start_request("alloc-audit").is_none());
            refine(&p, &cfg_short, &mut solver, &mut rng).unwrap()
        });
        let (long, _) = allocations_during(|| {
            assert!(obs.start_request("alloc-audit").is_none());
            refine(&p, &cfg_long, &mut solver, &mut rng).unwrap()
        });
        min_delta = min_delta.min(long.saturating_sub(short));
    }
    assert_eq!(
        min_delta, 0,
        "disabled tracing perturbed the zero-alloc refine path \
         ({min_delta} extra allocations)"
    );
}
