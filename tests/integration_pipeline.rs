//! Integration: full pipeline across solvers and benchmark sets, service
//! behaviour under load, experiment drivers end-to-end.

use cobi_es::config::{CobiConfig, PipelineConfig, Settings};
use cobi_es::corpus::benchmark_set;
use cobi_es::experiments::{self, Scale};
use cobi_es::ising::exact_bounds;
use cobi_es::metrics::rouge_all;
use cobi_es::pipeline::EsPipeline;
use cobi_es::service::Service;

fn pipeline(solver: &str, iterations: usize, seed: u64) -> EsPipeline {
    let cfg = PipelineConfig {
        solver: solver.into(),
        iterations,
        seed,
        ..Default::default()
    };
    EsPipeline::from_config(&cfg, &CobiConfig::default(), None).unwrap()
}

#[test]
fn all_solvers_produce_valid_summaries() {
    let set = benchmark_set("cnn_dm_20").unwrap();
    let doc = &set.documents[0];
    for solver in ["cobi", "tabu", "sa", "snowball", "brute", "exact", "random"] {
        let mut p = pipeline(solver, 3, 1);
        let s = p.summarize(doc).unwrap();
        assert_eq!(s.selected.len(), 6, "{solver}");
        assert!(s.selected.iter().all(|&i| i < doc.len()), "{solver}");
        assert!(s.objective.is_finite(), "{solver}");
        assert_eq!(s.sentences.len(), 6, "{solver}");
    }
}

#[test]
fn solver_quality_ordering_holds_on_average() {
    // exact >= tabu-refined >= random, averaged over documents
    let set = benchmark_set("cnn_dm_20").unwrap();
    let mut sums = [0.0f64; 3];
    for (i, doc) in set.documents.iter().take(5).enumerate() {
        let mut pe = pipeline("exact", 1, i as u64);
        let problem = pe.problem_for(doc).unwrap();
        let bounds = exact_bounds(&problem);
        let solvers = ["exact", "tabu", "random"];
        for (k, solver) in solvers.iter().enumerate() {
            let mut p = pipeline(solver, 5, i as u64 + 100);
            let s = p.summarize(doc).unwrap();
            sums[k] += bounds.normalize(s.objective);
        }
    }
    assert!(sums[0] >= sums[1] - 0.25, "exact {} vs tabu {}", sums[0], sums[1]);
    assert!(sums[1] > sums[2], "tabu {} vs random {}", sums[1], sums[2]);
}

#[test]
fn summaries_overlap_reference_key_facts() {
    // extrinsic check: high normalized objective should mean real overlap
    // with the generator's designated key-fact sentences
    let set = benchmark_set("cnn_dm_20").unwrap();
    let mut rouge1_sum = 0.0;
    let mut n = 0;
    for (i, doc) in set.documents.iter().take(5).enumerate() {
        let mut p = pipeline("tabu", 5, i as u64);
        let s = p.summarize(doc).unwrap();
        let reference: String = doc
            .reference
            .iter()
            .map(|&k| doc.sentences[k].clone())
            .collect::<Vec<_>>()
            .join(" ");
        let r = rouge_all(&s.text(), &reference);
        rouge1_sum += r.rouge1;
        n += 1;
    }
    let mean = rouge1_sum / n as f64;
    assert!(mean > 0.3, "mean ROUGE-1 vs key facts too low: {mean:.3}");
}

#[test]
fn deterministic_given_seed_across_pipeline() {
    let set = benchmark_set("cnn_dm_20").unwrap();
    let doc = &set.documents[3];
    let a = pipeline("cobi", 4, 42).summarize(doc).unwrap();
    let b = pipeline("cobi", 4, 42).summarize(doc).unwrap();
    assert_eq!(a.selected, b.selected);
    let c = pipeline("cobi", 4, 43).summarize(doc).unwrap();
    // different seed usually differs; don't assert inequality (may
    // coincide), but objective must still be valid
    assert!(c.objective.is_finite());
}

#[test]
fn hundred_sentence_documents_decompose_and_solve() {
    let set = benchmark_set("xsum_100").unwrap();
    let doc = &set.documents[0];
    assert_eq!(doc.len(), 100);
    let mut p = pipeline("cobi", 2, 7);
    let s = p.summarize(doc).unwrap();
    assert_eq!(s.selected.len(), 6);
    assert_eq!(s.stages, 9); // 100 -> ... -> 20 -> final
}

#[test]
fn service_under_concurrent_load() {
    let mut settings = Settings::default();
    settings.service.workers = 3;
    settings.service.queue_depth = 64;
    settings.pipeline.solver = "tabu".into();
    settings.pipeline.iterations = 2;
    let svc = Service::start(&settings).unwrap();
    let set = benchmark_set("cnn_dm_20").unwrap();
    let tickets: Vec<_> = (0..12)
        .map(|i| svc.submit(set.documents[i % 20].clone()).unwrap())
        .collect();
    for t in tickets {
        let s = t.wait().unwrap();
        assert_eq!(s.selected.len(), 6);
    }
    let m = svc.metrics();
    assert_eq!(m.completed, 12);
    assert_eq!(m.failed, 0);
    let lat = m.latency_summary();
    assert!(lat.solve_p50 > 0.0);
    svc.shutdown();
}

#[test]
fn experiments_registry_runs_every_id_quick() {
    let settings = Settings::default();
    for id in ["fig1", "fig3", "supp-optima"] {
        let reports = experiments::run(id, Scale::Quick, &settings).unwrap();
        assert!(!reports.is_empty(), "{id}");
        for r in &reports {
            assert!(!r.rows.is_empty(), "{id}: empty report");
            let md = r.to_markdown();
            assert!(md.contains("###"), "{id}");
        }
    }
}

#[test]
fn config_round_trip_through_file() {
    let dir = std::env::temp_dir().join("cobi_es_cfg_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cobi-es.toml");
    std::fs::write(
        &path,
        "[pipeline]\nsolver = \"sa\"\niterations = 9\n[cobi]\nnoise_amp = 0.07\n",
    )
    .unwrap();
    let s = Settings::load(&path).unwrap();
    assert_eq!(s.pipeline.solver, "sa");
    assert_eq!(s.pipeline.iterations, 9);
    assert!((s.cobi.noise_amp - 0.07).abs() < 1e-6);
    std::fs::remove_file(&path).ok();
}
