//! Flight-recorder contract tests (ISSUE 10): record → replay is
//! byte-identical on clean fleets regardless of pool shape, and a
//! fault injected at record time is triaged to the exact divergent
//! DAG node when the recording is replayed against a clean config.
//!
//! `serve_binary_replay_smoke` is env-gated (COBI_ES_REPLAY_SMOKE=1,
//! set by CI): it drives the REAL `cobi-es` binary — `serve
//! --record-out …`, a TCP summarize burst, a `::REPLAY 1::` admin
//! frame, then `cobi-es replay <file>` over the flushed JSONL — and
//! requires a zero-divergence exit. The ungated tests cover the same
//! path in-process for plain `cargo test`.

use cobi_es::config::Settings;
use cobi_es::corpus::benchmark_set;
use cobi_es::obs::replay::first_divergence;
use cobi_es::obs::{replay_record, RequestRecord};
use cobi_es::service::Service;

/// Quiet-defaults recording settings: fast tabu solves, recorder on.
fn recording_settings(devices: usize) -> Settings {
    let mut s = Settings::default();
    s.service.workers = 1;
    s.sched.devices = devices;
    s.pipeline.solver = "tabu".into();
    s.pipeline.iterations = 2;
    s.pipeline.summary_len = 3;
    s.obs.record_enabled = true;
    s
}

/// Serve the first `n` bench_10 documents through a recording service
/// (submitted sequentially so ring ids are stable) and return the ring.
fn record_bench10(settings: &Settings, n: usize) -> Vec<RequestRecord> {
    let svc = Service::start(settings).unwrap();
    let set = benchmark_set("bench_10").unwrap();
    for doc in set.documents.iter().take(n) {
        svc.submit(doc.clone()).unwrap().wait().unwrap();
    }
    let recs = svc.obs().recorder().snapshot();
    svc.shutdown();
    recs
}

#[test]
fn bench10_records_replay_identical_across_pool_shapes() {
    // the determinism contract, extended to provenance: the SAME ten
    // documents recorded under a 1-device and a 4-device pool produce
    // byte-identical JSONL, and every record replays 10/10 identical
    let s1 = recording_settings(1);
    let s4 = recording_settings(4);
    let recs1 = record_bench10(&s1, 10);
    let recs4 = record_bench10(&s4, 10);
    assert_eq!(recs1.len(), 10);
    assert_eq!(recs4.len(), 10);
    for (a, b) in recs1.iter().zip(&recs4) {
        assert_eq!(a.to_jsonl(), b.to_jsonl(), "pool shape leaked into {}", a.doc_id);
    }
    let mut identical = 0;
    for rec in &recs1 {
        assert!(!rec.nodes.is_empty(), "pooled ES records carry node taps");
        let report = replay_record(rec, &s1).unwrap();
        assert!(report.identical, "{}", report.verdict_line());
        assert!(report.first_divergence.is_none());
        assert!(report.config_diff.is_empty());
        identical += 1;
    }
    assert_eq!(identical, 10, "replay-audit headline: 10/10 byte-identical");
}

#[test]
fn recorded_fault_is_triaged_to_the_exact_divergent_node() {
    // record one document on a fleet with stuck oscillators injected
    // into the COBI device, then replay the recording against a CLEAN
    // config: triage must name the first DAG node the fault flipped —
    // computed independently here by diffing against a clean recording
    // of the same document (which, by the determinism contract, is
    // exactly what the replay re-executes)
    let mut faulty = recording_settings(1);
    faulty.pipeline.solver = "cobi".into();
    faulty.resilience.fault.enabled = true;
    faulty.resilience.fault.stuck_rate = 1.0;
    let mut clean = recording_settings(1);
    clean.pipeline.solver = "cobi".into();

    let faulty_recs = record_bench10(&faulty, 1);
    let clean_recs = record_bench10(&clean, 1);
    let (faulty_rec, clean_rec) = (&faulty_recs[0], &clean_recs[0]);
    assert_eq!(faulty_rec.doc_id, clean_rec.doc_id);
    assert_eq!(faulty_rec.seed, clean_rec.seed, "seeding is fault-independent");
    let expected = first_divergence(&faulty_rec.nodes, &clean_rec.nodes)
        .expect("a fully stuck device must perturb some node");

    let report = replay_record(faulty_rec, &clean).unwrap();
    let d = report
        .first_divergence
        .as_ref()
        .expect("triage must name a divergent node");
    assert_eq!(d.index, expected.index, "{}", report.verdict_line());
    assert_eq!((d.level, d.slot), (expected.level, expected.slot));
    assert_eq!(d.node_seed, expected.node_seed);
    assert!(d.recorded_energy.is_finite());
    assert!(d.replayed_energy.is_finite());
    let line = report.verdict_line();
    assert!(
        line.contains(&format!("first_node=({},{})", expected.level, expected.slot)),
        "{line}"
    );
    // the config diff names the knob that separates the two fleets
    assert!(
        report.config_diff.iter().any(|c| c.key == "fault_enabled"),
        "{line}"
    );

    // control: the same faulty recording replayed under the SAME faulty
    // environment is identical again — divergence is environmental, not
    // nondeterminism
    let report = replay_record(faulty_rec, &faulty).unwrap();
    assert!(report.identical, "{}", report.verdict_line());
    assert!(report.first_divergence.is_none());
}

#[test]
fn recorder_ring_is_bounded_and_counts_overwrites() {
    let mut s = recording_settings(1);
    s.obs.record_capacity = 3;
    let recs = record_bench10(&s, 5);
    assert_eq!(recs.len(), 3, "ring holds at most record_capacity entries");
    let ids: Vec<u64> = recs.iter().map(|r| r.id).collect();
    assert_eq!(ids, [3, 4, 5], "oldest records evicted first");
}

/// Kills the child even when an assertion panics mid-test.
struct KillOnDrop(std::process::Child);

impl Drop for KillOnDrop {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

#[test]
fn serve_binary_replay_smoke() {
    // env-gated (CI sets COBI_ES_REPLAY_SMOKE=1): the shipped binary's
    // record → flush → replay loop, end to end
    if std::env::var("COBI_ES_REPLAY_SMOKE").is_err() {
        return;
    }
    use cobi_es::service::tcp::{replay_remote, summarize_remote};

    let path = std::env::temp_dir().join(format!(
        "cobi-es-replay-smoke-{}.jsonl",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);

    let child = std::process::Command::new(env!("CARGO_BIN_EXE_cobi-es"))
        .args([
            "serve",
            "--port",
            "0",
            "--record-out",
            path.to_str().unwrap(),
            "--solver",
            "tabu",
            "--iterations",
            "2",
            "--workers",
            "1",
        ])
        .stdout(std::process::Stdio::piped())
        .spawn()
        .expect("spawning cobi-es serve");
    let mut child = KillOnDrop(child);

    let stdout = child.0.stdout.take().expect("piped stdout");
    let mut reader = std::io::BufReader::new(stdout);
    let addr: std::net::SocketAddr = loop {
        use std::io::BufRead;
        let mut line = String::new();
        let n = reader.read_line(&mut line).expect("reading serve stdout");
        assert!(n > 0, "serve exited before printing its listen address");
        if let Some(rest) = line.strip_prefix("listening on ") {
            break rest
                .split_whitespace()
                .next()
                .expect("address after 'listening on'")
                .parse()
                .expect("parseable listen address");
        }
    };

    let set = benchmark_set("bench_10").unwrap();
    for doc in set.documents.iter().take(3) {
        summarize_remote(addr, &doc.text()).unwrap();
    }
    // the live ring answers admin replays while the serve loop runs
    let verdict = replay_remote(addr, 1).unwrap();
    assert!(verdict.contains("verdict=identical"), "{verdict}");

    // the serve loop flushes records every 500ms — wait for all three
    let mut lines = 0;
    for _ in 0..40 {
        std::thread::sleep(std::time::Duration::from_millis(250));
        if path.exists() {
            lines = std::fs::read_to_string(&path)
                .unwrap()
                .lines()
                .filter(|l| !l.trim().is_empty())
                .count();
            if lines >= 3 {
                break;
            }
        }
    }
    assert_eq!(lines, 3, "records not flushed to {} within 10s", path.display());
    drop(child);

    // the replay subcommand exits 0 only when every replay is identical
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_cobi-es"))
        .args(["replay", path.to_str().unwrap(), "--all"])
        .output()
        .expect("running cobi-es replay");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        out.status.success(),
        "replay diverged:\n{stdout}\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(stdout.contains("replayed 3: 3 identical, 0 diverged"), "{stdout}");
    std::fs::remove_file(&path).unwrap();
}
