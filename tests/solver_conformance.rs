//! Cross-solver conformance suite: one parameterized harness runs every
//! Ising backend — tabu, simulated annealing, greedy descent, the exact
//! enumeration facade, the native COBI device, and the Snowball sharded
//! solver — through the SAME contract checks (ISSUE 7):
//!
//! * stable names (the routing layer keys on them);
//! * the batching contract: `solve_batch` is byte-identical to solving
//!   the instances one at a time on a fresh same-seeded solver;
//! * warm starts: a supplied ground state comes back unchanged from
//!   every hint-capable backend;
//! * the tie-break rule: exactly tied flips resolve to the lowest index;
//! * domain equivalence: the integer kernels are bit-identical to the
//!   `f64` reference kernels on quantized instances;
//! * the exact facade returns the certified exhaustive ground state;
//! * reported energies match the instance's own energy function.
//!
//! These checks are what make the portfolio's routing decisions invisible
//! in the output bytes: any backend that passes can be substituted for
//! any other under a static policy without changing which spins tie-break
//! where.

use cobi_es::cobi::CobiDevice;
use cobi_es::config::CobiConfig;
use cobi_es::ising::{Ising, QuantIsing};
use cobi_es::solvers::exact::{ising_ground_exhaustive, ExactIsingSolver};
use cobi_es::solvers::greedy::GreedyDescent;
use cobi_es::solvers::sa::SaSolver;
use cobi_es::solvers::snowball::SnowballSolver;
use cobi_es::solvers::tabu::{TabuConfig, TabuSolver};
use cobi_es::solvers::{IsingSolver, QuantSolve};
use cobi_es::util::rng::Pcg32;

/// Random integer-valued spin glass (coefficients in [-7, 7]) — the
/// shape every quantized pool instance has, built through the public
/// API only (the crate's internal `testutil` is not exported).
fn quantized_glass(seed: u64, n: usize) -> Ising {
    let mut rng = Pcg32::seeded(seed);
    let mut ising = Ising::new(n);
    for i in 0..n {
        ising.h[i] = rng.below(15) as f32 - 7.0;
        for j in (i + 1)..n {
            ising.set_pair(i, j, rng.below(15) as f32 - 7.0);
        }
    }
    ising
}

/// One row of the conformance table: how to build the backend, plus the
/// capabilities the harness may exercise on it.
struct Backend {
    /// The stable routing name the built solver must report.
    name: &'static str,
    /// Whether `solve_from` is expected to preserve a supplied ground
    /// state (the COBI device ignores hints — its anneal starts from
    /// device phase noise).
    ground_hint: bool,
    /// Largest instance the backend accepts (the exact facade caps
    /// enumeration; the COBI array has 59 usable spins).
    max_n: usize,
    /// Build a fresh solver from a seed (seed-free backends ignore it).
    make: fn(u64) -> Box<dyn IsingSolver>,
}

fn backends() -> Vec<Backend> {
    vec![
        Backend {
            name: "tabu",
            ground_hint: true,
            max_n: usize::MAX,
            make: |s| Box::new(TabuSolver::seeded(s)),
        },
        Backend {
            name: "sa",
            ground_hint: true,
            max_n: usize::MAX,
            make: |s| Box::new(SaSolver::seeded(s)),
        },
        Backend {
            name: "greedy",
            ground_hint: true,
            max_n: usize::MAX,
            make: |_| Box::new(GreedyDescent::new()),
        },
        Backend {
            name: "exact",
            ground_hint: true,
            max_n: 20,
            make: |_| Box::new(ExactIsingSolver::new(20)),
        },
        Backend {
            name: "cobi",
            ground_hint: false,
            max_n: 59,
            make: |s| Box::new(CobiDevice::native(CobiConfig::default(), s)),
        },
        Backend {
            name: "snowball",
            ground_hint: true,
            max_n: usize::MAX,
            make: |s| Box::new(SnowballSolver::seeded(s)),
        },
    ]
}

#[test]
fn backend_names_are_stable() {
    for b in backends() {
        assert_eq!((b.make)(1).name(), b.name, "routing keys on these names");
    }
}

#[test]
fn batch_equals_sequential_for_every_backend() {
    let instances: Vec<Ising> = (0..3).map(|k| quantized_glass(100 + k, 12)).collect();
    let refs: Vec<&Ising> = instances.iter().collect();
    for b in backends() {
        assert!(12 <= b.max_n);
        let batched = (b.make)(7).solve_batch(&refs);
        let mut seq = (b.make)(7);
        assert_eq!(batched.len(), instances.len(), "{}: one result per instance", b.name);
        for (i, inst) in instances.iter().enumerate() {
            let one = seq.solve(inst);
            assert_eq!(batched[i].spins, one.spins, "{} instance {i}", b.name);
            assert_eq!(
                batched[i].energy.to_bits(),
                one.energy.to_bits(),
                "{} instance {i}: batched energy drifted",
                b.name
            );
        }
    }
}

#[test]
fn ground_state_hints_survive_every_hint_capable_backend() {
    // unique ground state: h = [1, -1, 1], no couplings -> [-1, 1, -1]
    // at energy -3; nothing beats it strictly and ties keep the earlier
    // (warm) result, so the hint must come back unchanged
    let mut ising = Ising::new(3);
    ising.h = vec![1.0, -1.0, 1.0];
    let ground = vec![-1i8, 1, -1];
    for b in backends().into_iter().filter(|b| b.ground_hint) {
        let r = (b.make)(3).solve_from(&ising, &ground);
        assert_eq!(r.spins, ground, "{} lost a supplied ground state", b.name);
        assert!((r.energy + 3.0).abs() < 1e-9, "{}: energy {}", b.name, r.energy);
    }
}

#[test]
fn tied_flips_resolve_to_the_lowest_index() {
    // 2-spin ferromagnet probed from (+1, -1): flipping either spin
    // gains exactly the same energy, so the documented rule (lowest
    // index wins) lands in (-1, -1) — never (+1, +1). Exercised on the
    // two scan-based backends whose every move is an argmin over flips.
    let mut ising = Ising::new(2);
    ising.set_pair(0, 1, -1.0);
    let g = GreedyDescent::new().solve_from(&ising, &[1, -1]);
    assert_eq!(g.spins, vec![-1, -1], "greedy broke the tie upward");
    let mut tabu = TabuSolver::new(
        1,
        TabuConfig {
            restarts: 1,
            ..Default::default()
        },
    );
    let t = tabu.solve_from(&ising, &[1, -1]);
    assert_eq!(t.spins, vec![-1, -1], "tabu broke the tie upward");
}

/// Pin one backend's integer kernel to its `f64` reference kernel on a
/// quantized instance: same seed, same instance, bit-identical spins and
/// energy. (Concrete types: `solve_reference_f64` is an inherent method,
/// not part of the object-safe trait.)
macro_rules! pin_quant_equivalence {
    ($name:literal, $make:expr, $inst:expr) => {{
        let inst: &Ising = $inst;
        let mut q = QuantIsing::default();
        assert!(q.try_copy_from(inst), "glass must be integer-valued");
        let reference = $make.solve_reference_f64(inst);
        let mut spins = Vec::new();
        let energy = $make.solve_quant_into(&q, &mut spins);
        assert_eq!(reference.spins, spins, "{}: integer kernel diverged", $name);
        assert_eq!(
            reference.energy.to_bits(),
            energy.to_bits(),
            "{}: integer energy diverged",
            $name
        );
    }};
}

#[test]
fn integer_kernels_match_the_f64_reference_bit_for_bit() {
    // n=18 keeps snowball in uniform-sweep mode; n=30 crosses its focus
    // threshold so both selection modes are pinned
    for inst in [quantized_glass(42, 18), quantized_glass(43, 30)] {
        pin_quant_equivalence!("tabu", TabuSolver::seeded(9), &inst);
        pin_quant_equivalence!("sa", SaSolver::seeded(9), &inst);
        pin_quant_equivalence!("greedy", GreedyDescent::new(), &inst);
        pin_quant_equivalence!("snowball", SnowballSolver::seeded(9), &inst);
    }
}

#[test]
fn exact_backend_returns_the_certified_ground_state() {
    for (seed, n) in [(50u64, 8usize), (51, 10), (52, 12)] {
        let inst = quantized_glass(seed, n);
        let r = ExactIsingSolver::new(20).solve(&inst);
        let (ground_energy, ground_spins, _) = ising_ground_exhaustive(&inst);
        assert_eq!(r.spins, ground_spins, "n={n}");
        assert_eq!(r.energy.to_bits(), ground_energy.to_bits(), "n={n}");
    }
}

#[test]
fn reported_energy_matches_the_instance_energy() {
    let inst = quantized_glass(17, 12);
    for b in backends() {
        let r = (b.make)(5).solve(&inst);
        assert_eq!(r.spins.len(), inst.n, "{}", b.name);
        assert!(r.spins.iter().all(|&s| s == 1 || s == -1), "{}", b.name);
        assert!(
            (inst.energy(&r.spins) - r.energy).abs() < 1e-6,
            "{} reported {} but the instance scores {}",
            b.name,
            r.energy,
            inst.energy(&r.spins)
        );
    }
}
