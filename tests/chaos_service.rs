//! Service-level chaos harness (ISSUE 8): protocol fuzzing, slow-loris
//! reaping, mid-request disconnects, deadline storms, breaker
//! quarantine storms and graceful drain.
//!
//! The contract under test: the service answers or sheds EVERY request
//! (no hung connections, no lost replies), hostile traffic never wedges
//! a worker or leaks an in-flight counter, and every admitted request
//! that survives produces bytes identical to a quiet run — chaos may
//! reject work, it may never change an answer.
//!
//! `service_chaos_storm_drains_clean_and_replays_identically` is
//! env-gated (COBI_ES_CHAOS=1, set by CI) at full scale; unset, a
//! scaled-down pass keeps the storm path alive for plain `cargo test`.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use cobi_es::config::Settings;
use cobi_es::corpus::benchmark_set;
use cobi_es::pipeline::Summary;
use cobi_es::prop_assert;
use cobi_es::sched::breaker::State;
use cobi_es::sched::{doc_seed, summarize_with_pool, DevicePool};
use cobi_es::service::tcp::{
    summarize_remote, TcpServer, BATCH_MARKER, CHUNK_MARKER, DRAIN_MARKER, EOF_MARKER,
    STREAM_MARKER,
};
use cobi_es::service::{Deadline, DeadlineExceeded, Service, SubmitOptions};
use cobi_es::util::proptest;

/// Fast tabu-backed settings shared by the chaos scenarios.
fn chaos_settings() -> Settings {
    let mut s = Settings::default();
    s.service.workers = 2;
    s.service.queue_depth = 16;
    s.pipeline.solver = "tabu".into();
    s.pipeline.iterations = 2;
    s.pipeline.summary_len = 3;
    s
}

fn serve(settings: &Settings) -> (Arc<Service>, TcpServer) {
    let svc = Arc::new(Service::start(settings).unwrap());
    let server = TcpServer::start(svc.clone(), 0).unwrap();
    (svc, server)
}

/// Shut a shared service down once its connection handlers let go.
fn shutdown_arc(svc: Arc<Service>) {
    let mut svc = Some(svc);
    for _ in 0..500 {
        match Arc::try_unwrap(svc.take().unwrap()) {
            Ok(owned) => {
                owned.shutdown();
                return;
            }
            Err(shared) => {
                svc = Some(shared);
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
    panic!("service handlers never released their references");
}

/// Write `payload` raw, half-close, and read the first reply line.
/// Empty string = the server closed without replying (also clean).
/// Panics if the server neither replies nor closes within 10s — a
/// wedged connection is exactly what the chaos suite must catch.
fn fuzz_request(addr: std::net::SocketAddr, payload: &[u8]) -> String {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    stream.write_all(payload).unwrap();
    stream.shutdown(std::net::Shutdown::Write).unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    match reader.read_line(&mut line) {
        Ok(_) => line.trim_end().to_string(),
        Err(e) => panic!("server wedged on fuzz payload (no reply, no close): {e}"),
    }
}

/// Poll until `pred(metrics)` holds (10s bound) — chaos outcomes land
/// on handler threads, so counters settle asynchronously.
fn wait_for(svc: &Service, what: &str, pred: impl Fn(&cobi_es::service::ServiceMetrics) -> bool) {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        if pred(&svc.metrics()) {
            return;
        }
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[test]
fn protocol_fuzz_always_answers_cleanly() {
    let mut settings = chaos_settings();
    settings.service.workers = 1;
    settings.pipeline.iterations = 1;
    settings.service.max_doc_bytes = 2048;
    let (svc, server) = serve(&settings);
    let addr = server.addr;

    // printable junk so the payload survives read_line's UTF-8 check
    // (raw binary gets its own test below)
    fn junk(rng: &mut cobi_es::util::rng::Pcg32, len: usize) -> String {
        (0..len)
            .map(|_| (0x20 + rng.below(0x5e) as u8) as char)
            .collect()
    }

    proptest::check("tcp-protocol-fuzz", 0xC8A05, 24, |rng| {
        let payload = match rng.below(8) {
            0 => format!("{}\n{}\n{EOF_MARKER}\n", junk(rng, 40), junk(rng, 40)),
            1 => format!("::DEADLINE {}::\n", junk(rng, 6)),
            2 => format!("::{}::\n", junk(rng, 8)),
            3 => format!("{}\n{CHUNK_MARKER}\n", junk(rng, 20)),
            4 => format!("{EOF_MARKER}\n"),
            5 => format!("{}\n{EOF_MARKER}\n", junk(rng, 3000)),
            6 => format!("{STREAM_MARKER}\n{}\n", junk(rng, 30)),
            _ => format!("::DEADLINE 0::\n{}\n{EOF_MARKER}\n", junk(rng, 30)),
        };
        let reply = fuzz_request(addr, payload.as_bytes());
        prop_assert!(
            reply.is_empty()
                || reply.starts_with("OK")
                || reply.starts_with("ERR")
                || reply.starts_with("REV"),
            "unframed reply to {payload:?}: {reply:?}"
        );
        Ok(())
    });

    // the server survived the sweep: a well-formed request still serves
    let set = benchmark_set("bench_10").unwrap();
    let summary = summarize_remote(addr, &set.documents[0].text()).unwrap();
    assert_eq!(summary.len(), 3);
    wait_for(&svc, "counters to settle", |m| {
        m.submitted == m.completed + m.failed
    });
    server.stop();
    shutdown_arc(svc);
}

#[test]
fn binary_garbage_closes_cleanly() {
    let (svc, server) = serve(&chaos_settings());
    // invalid UTF-8 fails read_line; the handler must drop the
    // connection, not hang or take a worker down
    let reply = fuzz_request(server.addr, &[0xff, 0xfe, 0x80, 0x00, 0xC3, 0x28, b'\n']);
    assert!(reply.is_empty() || reply.starts_with("ERR"), "{reply:?}");
    let set = benchmark_set("bench_10").unwrap();
    let summary = summarize_remote(server.addr, &set.documents[1].text()).unwrap();
    assert_eq!(summary.len(), 3);
    server.stop();
    shutdown_arc(svc);
}

#[test]
fn garbage_after_eof_is_ignored() {
    let (svc, server) = serve(&chaos_settings());
    let set = benchmark_set("bench_10").unwrap();
    let text = set.documents[2].text();
    let payload = format!("{text}\n{EOF_MARKER}\ntrailing garbage ::STATS:: more junk\n");
    let mut stream = TcpStream::connect(server.addr).unwrap();
    stream.write_all(payload.as_bytes()).unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line.trim_end(), "OK 3", "bytes after ::EOF:: must not corrupt the reply");
    server.stop();
    shutdown_arc(svc);
}

#[test]
fn slow_loris_is_reaped_by_the_idle_timeout() {
    let mut settings = chaos_settings();
    settings.service.idle_timeout_ms = 120;
    let (svc, server) = serve(&settings);

    // a batch connection that stalls mid-line is answered and dropped
    let mut stream = TcpStream::connect(server.addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    stream.write_all(b"a partial line that never ends").unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line.trim_end(), "ERR idle timeout");

    // a stream session that stalls is reaped too, and the abandoned
    // session settles as failed (submitted = completed + failed holds)
    let mut stream = TcpStream::connect(server.addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    stream
        .write_all(format!("{STREAM_MARKER}\n").as_bytes())
        .unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line.trim_end(), "ERR idle timeout");
    wait_for(&svc, "the reaped session to settle as failed", |m| {
        m.failed >= 1 && m.submitted == m.completed + m.failed
    });

    // reaping stalled peers never degrades live ones
    let set = benchmark_set("bench_10").unwrap();
    let summary = summarize_remote(server.addr, &set.documents[3].text()).unwrap();
    assert_eq!(summary.len(), 3);
    server.stop();
    shutdown_arc(svc);
}

#[test]
fn mid_request_disconnects_leave_no_hung_state() {
    let (svc, server) = serve(&chaos_settings());
    let set = benchmark_set("bench_10").unwrap();

    // batch: the client vanishes after half a document — the half-close
    // terminates the read, the reply write fails silently
    let mut stream = TcpStream::connect(server.addr).unwrap();
    stream
        .write_all(format!("{}\n", set.documents[4].text()).as_bytes())
        .unwrap();
    drop(stream);

    // stream: the session is abandoned mid-chunk — Drop settles it
    let mut stream = TcpStream::connect(server.addr).unwrap();
    stream
        .write_all(format!("{STREAM_MARKER}\nOne lonely sentence.\n").as_bytes())
        .unwrap();
    drop(stream);

    wait_for(&svc, "disconnected requests to settle", |m| {
        m.submitted >= 2 && m.submitted == m.completed + m.failed
    });
    assert_eq!(svc.inflight(), 0, "disconnects must not leak in-flight slots");

    let summary = summarize_remote(server.addr, &set.documents[5].text()).unwrap();
    assert_eq!(summary.len(), 3);
    server.stop();
    shutdown_arc(svc);
}

#[test]
fn abandoned_tickets_are_not_failures() {
    // a caller that drops its Ticket before the reply lands: the worker's
    // send fails silently, the work still counts as completed, and the
    // breaker records nothing (an abandoned reply is not a device fault)
    let mut settings = chaos_settings();
    settings.sched.breaker.enabled = true;
    let svc = Service::start(&settings).unwrap();
    let set = benchmark_set("bench_10").unwrap();
    for d in &set.documents[..4] {
        drop(svc.submit(d.clone()).unwrap());
    }
    wait_for(&svc, "abandoned jobs to finish", |m| m.completed == 4);
    let m = svc.metrics();
    assert_eq!(m.failed, 0);
    let b = m.breaker.expect("breaker metrics with the fleet enabled");
    assert!(!b.any(), "abandonment fed the breaker: {b:?}");
    svc.shutdown();
}

#[test]
fn deadline_storm_sheds_cleanly_without_burning_solves() {
    let svc = Service::start(&chaos_settings()).unwrap();
    let set = benchmark_set("bench_10").unwrap();
    let opts = SubmitOptions {
        deadline: Some(Deadline::from_ms(0)),
        ..Default::default()
    };
    let tickets: Vec<_> = set.documents[..6]
        .iter()
        .map(|d| svc.submit_with(d.clone(), opts).unwrap())
        .collect();
    for t in tickets {
        let err = t.wait().unwrap_err();
        assert!(
            err.downcast_ref::<DeadlineExceeded>().is_some(),
            "want a typed DeadlineExceeded, got: {err}"
        );
    }
    let m = svc.metrics();
    assert_eq!(m.overload.deadline_exceeded, 6);
    assert_eq!(m.completed, 0);
    // the storm over, normal traffic resumes immediately
    let t = svc.submit(set.documents[6].clone()).unwrap();
    assert_eq!(t.wait().unwrap().selected.len(), 3);
    svc.shutdown();
}

#[test]
fn tcp_drain_loses_no_inflight_responses() {
    let mut settings = chaos_settings();
    settings.pipeline.iterations = 4; // keep work in flight across the drain
    let (svc, server) = serve(&settings);
    let set = benchmark_set("cnn_dm_20").unwrap();

    // three requests in flight on open connections, replies unread
    let mut conns = Vec::new();
    for d in &set.documents[..3] {
        let mut stream = TcpStream::connect(server.addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(30)))
            .unwrap();
        stream
            .write_all(format!("{}\n{EOF_MARKER}\n", d.text()).as_bytes())
            .unwrap();
        conns.push(stream);
    }
    wait_for(&svc, "the in-flight requests to be admitted", |m| m.submitted >= 3);

    // the admin drain frame stops accepts...
    let reply = fuzz_request(server.addr, format!("{DRAIN_MARKER}\n").as_bytes());
    assert_eq!(reply, "OK 0");
    assert!(server.drain_requested());

    // ...and every admitted request still gets its answer
    let stats = svc.drain(Duration::from_secs(30));
    assert_eq!(stats.aborted, 0, "drain lost {} in-flight requests", stats.aborted);
    for stream in conns {
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim_end(), "OK 3", "an admitted request lost its reply");
    }
    let m = svc.metrics();
    assert_eq!(m.completed, 3);
    assert_eq!(m.overload.drains, 1);
    assert_eq!(m.overload.drain_aborted, 0);

    server.stop();
    shutdown_arc(svc);
}

/// Pooled, document-level summary (the resilience suite's idiom).
fn pooled_summary(s: &Settings, pool: &DevicePool, doc_idx: usize) -> Summary {
    let set = benchmark_set("bench_10").unwrap();
    let doc = &set.documents[doc_idx];
    let mut cfg = s.pipeline.clone();
    cfg.summary_len = set.summary_len;
    cfg.seed = doc_seed(cfg.seed, &doc.id);
    let mut client = pool.client(cfg.seed);
    summarize_with_pool(doc, &cfg, &mut client).unwrap()
}

fn assert_same_summary(a: &Summary, b: &Summary, ctx: &str) {
    assert_eq!(a.selected, b.selected, "{ctx}");
    assert_eq!(a.sentences, b.sentences, "{ctx}");
    assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "{ctx}");
}

#[test]
fn quiet_overload_features_keep_summaries_byte_identical() {
    // acceptance pin: every overload feature armed but never firing is
    // byte-identical to the defaults-off path, across pool shapes
    let base = chaos_settings();
    let mut one_dev = base.clone();
    one_dev.sched.devices = 1;
    one_dev.sched.max_coalesce = 1;
    one_dev.sched.linger_us = 0;
    let mut armed = base.clone();
    armed.sched.devices = 4;
    armed.sched.max_coalesce = 8;
    armed.sched.breaker.enabled = true;
    armed.service.default_deadline_ms = 60_000;
    armed.service.shed_watermark_ms = 60_000;
    armed.service.idle_timeout_ms = 50;
    armed.service.max_doc_bytes = 1 << 16;

    let docs = [0usize, 3, 7];
    let run = |s: &Settings| -> Vec<Summary> {
        let svc = Service::start(s).unwrap();
        let set = benchmark_set("bench_10").unwrap();
        let out: Vec<Summary> = docs
            .iter()
            .map(|&i| svc.submit(set.documents[i].clone()).unwrap().wait().unwrap())
            .collect();
        let m = svc.metrics();
        assert_eq!(m.completed, docs.len() as u64);
        assert!(!m.overload.any(), "quiet features fired: {:?}", m.overload);
        svc.shutdown();
        out
    };

    let reference = run(&one_dev);
    for (name, s) in [("defaults-4dev", &base), ("armed-4dev", &armed)] {
        for (got, want) in run(s).iter().zip(&reference) {
            assert_same_summary(got, want, name);
        }
    }
}

#[test]
fn breaker_quarantine_storm_never_changes_a_summary() {
    // a device cycling through trip -> cooldown -> probe -> readmit ->
    // retire while documents stream past: the survivors' bytes must
    // match a breaker-less pool exactly (seeds are per-request, never
    // per-device), and the quarantine telemetry must add up
    let docs = [0usize, 1, 2, 3, 4];
    let mut plain = chaos_settings();
    plain.sched.devices = 2;
    let pool = DevicePool::start(&plain, None).unwrap();
    let reference: Vec<Summary> = docs.iter().map(|&i| pooled_summary(&plain, &pool, i)).collect();
    pool.shutdown();

    let mut stormy = plain.clone();
    stormy.sched.breaker.enabled = true;
    stormy.sched.breaker.window = 8;
    stormy.sched.breaker.trip_failures = 3;
    stormy.sched.breaker.cooldown_ms = 20;
    stormy.sched.breaker.max_trips = 3;
    stormy.resilience.calibration_probes = 2; // fast half-open probes
    let pool = DevicePool::start(&stormy, None).unwrap();
    let fleet = pool.breaker().expect("breaker fleet").clone();

    let mut survived = Vec::new();
    survived.push(pooled_summary(&stormy, &pool, docs[0]));
    // storm: 3 failure samples at once (a dispatch error plus two verify
    // rejections) trip device 0 into quarantine mid-traffic
    fleet.record_dispatch(0, false, 2);
    survived.push(pooled_summary(&stormy, &pool, docs[1]));
    survived.push(pooled_summary(&stormy, &pool, docs[2]));
    // after the cooldown the device thread self-probes with the real
    // calibrator; a healthy solver earns readmission
    let deadline = Instant::now() + Duration::from_secs(20);
    while fleet.snapshot().readmissions == 0 {
        assert!(Instant::now() < deadline, "the half-open probe never readmitted");
        std::thread::sleep(Duration::from_millis(5));
    }
    survived.push(pooled_summary(&stormy, &pool, docs[3]));
    // escalate: repeated failed probes push the device past max_trips
    // into retirement (device 1 is standing, so retirement is allowed)
    while fleet.state(0) != Some(State::Retired) {
        assert!(Instant::now() < deadline, "failed probes never retired the device");
        fleet.probe_result(0, false);
        std::thread::sleep(Duration::from_millis(2));
    }
    survived.push(pooled_summary(&stormy, &pool, docs[4]));

    for (i, (got, want)) in survived.iter().zip(&reference).enumerate() {
        assert_same_summary(got, want, &format!("doc {} under quarantine storm", docs[i]));
    }
    let m = fleet.snapshot();
    assert!(m.trips >= 2, "{m:?}");
    assert!(m.probes >= 1, "{m:?}");
    assert!(m.readmissions >= 1, "{m:?}");
    assert_eq!(m.retired, 1, "{m:?}");
    assert_eq!(m.retirements, 1, "{m:?}");
    assert!(m.any());
    pool.shutdown(); // must not hang with a retired device
}

#[test]
fn service_chaos_storm_drains_clean_and_replays_identically() {
    // env-gated scale (CI sets COBI_ES_CHAOS=1): full storm in the
    // chaos slice, a one-wave smoke for plain `cargo test`
    let full = std::env::var("COBI_ES_CHAOS").is_ok();
    let waves = if full { 3 } else { 1 };
    let tcp_docs = if full { 4 } else { 2 };

    let mut settings = chaos_settings();
    settings.service.workers = if full { 3 } else { 2 };
    settings.service.queue_depth = 64;
    settings.service.default_deadline_ms = 30_000;
    settings.service.shed_watermark_ms = 60_000; // armed, quiet
    settings.service.idle_timeout_ms = 150;
    settings.sched.breaker.enabled = true;
    settings.resilience.enabled = true;
    settings.resilience.replication = 2;
    settings.resilience.fault.enabled = true;
    settings.resilience.fault.stuck_rate = 0.1;
    let (svc, server) = serve(&settings);
    let addr = server.addr;
    let set = benchmark_set("cnn_dm_20").unwrap();
    let bench = benchmark_set("bench_10").unwrap();

    // per-document summaries from in-process submissions, collected
    // across waves: chaos alongside must never change admitted bytes
    let mut per_wave: Vec<Vec<Summary>> = Vec::new();
    for _wave in 0..waves {
        let mut threads = Vec::new();
        for d in set.documents[..tcp_docs].iter() {
            let text = d.text();
            threads.push(std::thread::spawn(move || {
                let summary = summarize_remote(addr, &text).unwrap();
                assert_eq!(summary.len(), 3);
            }));
        }
        // hostile traffic interleaved with the valid load
        threads.push(std::thread::spawn(move || {
            let r = fuzz_request(addr, b"::BOGUS MARKER::\n");
            assert!(r.starts_with("ERR"), "{r}");
        }));
        threads.push(std::thread::spawn(move || {
            let payload = format!("::DEADLINE 0::\nsome text\n{EOF_MARKER}\n");
            let r = fuzz_request(addr, payload.as_bytes());
            assert!(r.starts_with("ERR deadline exceeded"), "{r}");
        }));
        threads.push(std::thread::spawn(move || {
            // slow-loris: partial write, then vanish without reading
            let mut s = TcpStream::connect(addr).unwrap();
            let _ = s.write_all(b"a stalled partial line");
            drop(s);
        }));
        {
            let text = set.documents[0].text();
            threads.push(std::thread::spawn(move || {
                let payload = format!("{BATCH_MARKER}\n{text}\n{EOF_MARKER}\n");
                let mut s = TcpStream::connect(addr).unwrap();
                s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
                s.write_all(payload.as_bytes()).unwrap();
                let mut reader = BufReader::new(s);
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                assert_eq!(line.trim_end(), "OK 3", "batch-tier request under quiet watermark");
            }));
        }
        let wave: Vec<Summary> = bench.documents[..2]
            .iter()
            .map(|d| svc.submit(d.clone()).unwrap().wait().unwrap())
            .collect();
        per_wave.push(wave);
        for t in threads {
            t.join().unwrap();
        }
    }
    for wave in &per_wave[1..] {
        for (got, want) in wave.iter().zip(&per_wave[0]) {
            assert_same_summary(got, want, "admitted bytes drifted across storm waves");
        }
    }

    // the storm settles: every request answered, nothing leaked
    wait_for(&svc, "the storm to settle", |m| {
        m.submitted == m.completed + m.failed
    });
    assert_eq!(svc.inflight(), 0);
    let m = svc.metrics();
    assert_eq!(m.overload.worker_panics, 0, "a worker died in the storm");
    let b = m.breaker.expect("breaker armed");
    assert_eq!(b.devices, settings.sched.devices.max(1));

    // graceful exit: drain via the admin frame, zero lost responses
    let reply = fuzz_request(addr, format!("{DRAIN_MARKER}\n").as_bytes());
    assert_eq!(reply, "OK 0");
    let stats = svc.drain(Duration::from_secs(30));
    assert_eq!(stats.aborted, 0);
    server.stop();
    shutdown_arc(svc);

    // quiet replay: the identical sequential workload on two fresh
    // services is byte-identical, faults and breaker included
    let replay = |s: &Settings| -> Vec<Vec<String>> {
        let (svc, server) = serve(s);
        let out: Vec<Vec<String>> = set.documents[..tcp_docs]
            .iter()
            .map(|d| summarize_remote(server.addr, &d.text()).unwrap())
            .collect();
        server.stop();
        shutdown_arc(svc);
        out
    };
    assert_eq!(
        replay(&settings),
        replay(&settings),
        "quiet replay of the storm workload diverged"
    );
}
