//! Integration tests for the observability subsystem (ISSUE 6): the
//! trace-export + exposition path kept alive end to end.
//!
//! `serve_binary_trace_smoke` is env-gated (COBI_ES_OBS_SMOKE=1, set by
//! CI) and drives the REAL `cobi-es` binary: `serve --port 0
//! --trace-out …`, one summarize over TCP, a `::METRICS::` scrape, and
//! a poll of the JSONL file until a span tree parses. Unset, the
//! in-process test covers the same exporters without a child process so
//! the path stays alive for plain `cargo test`.

use std::io::BufRead;
use std::sync::Arc;

use cobi_es::config::Settings;
use cobi_es::corpus::benchmark_set;
use cobi_es::obs::json::JsonValue;
use cobi_es::service::tcp::{metrics_remote, summarize_remote, TcpServer};
use cobi_es::service::Service;

/// A fresh path under the system temp dir (removed by the caller).
fn temp_trace_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "cobi-es-obs-smoke-{tag}-{}.jsonl",
        std::process::id()
    ))
}

/// Every line of `path` must parse as a span tree rooted at "request".
fn assert_jsonl_parses(path: &std::path::Path) -> usize {
    let text = std::fs::read_to_string(path).unwrap();
    let mut n = 0;
    for line in text.lines() {
        let v = JsonValue::parse(line).unwrap_or_else(|e| panic!("bad JSONL line {line:?}: {e}"));
        assert_eq!(v.get("stage").and_then(|s| s.as_str()), Some("request"));
        n += 1;
    }
    n
}

#[test]
fn in_process_trace_export_and_exposition() {
    let mut s = Settings::default();
    s.service.workers = 1;
    s.pipeline.solver = "tabu".into();
    s.pipeline.iterations = 2;
    s.obs.enabled = true;
    let svc = Arc::new(Service::start(&s).unwrap());
    let server = TcpServer::start(svc.clone(), 0).unwrap();

    let set = benchmark_set("cnn_dm_20").unwrap();
    summarize_remote(server.addr, &set.documents[0].text()).unwrap();

    // the exposition carries the energy-ledger series
    let exposition = metrics_remote(server.addr).unwrap();
    assert!(exposition.contains("cobi_es_energy_joules_total"), "{exposition}");
    assert!(exposition.contains("cobi_es_traces_total{state=\"recorded\"} 1"), "{exposition}");

    // the drained trees export as parseable JSONL (what --trace-out does)
    let path = temp_trace_path("inproc");
    let _ = std::fs::remove_file(&path);
    let spans = svc.obs().traces().drain();
    assert!(!spans.is_empty(), "one request must record one tree");
    cobi_es::obs::export::append_jsonl(&path, &spans).unwrap();
    assert_eq!(assert_jsonl_parses(&path), spans.len());
    std::fs::remove_file(&path).unwrap();

    server.stop();
}

/// Kills the child even when an assertion panics mid-test.
struct KillOnDrop(std::process::Child);

impl Drop for KillOnDrop {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

#[test]
fn serve_binary_trace_smoke() {
    // env-gated (CI sets COBI_ES_OBS_SMOKE=1): exercise the shipped
    // binary's serve loop — flag parsing, the periodic trace flush and
    // the TCP exporters — not just the library surface
    if std::env::var("COBI_ES_OBS_SMOKE").is_err() {
        return;
    }
    let path = temp_trace_path("binary");
    let _ = std::fs::remove_file(&path);

    let child = std::process::Command::new(env!("CARGO_BIN_EXE_cobi-es"))
        .args([
            "serve",
            "--port",
            "0",
            "--trace-out",
            path.to_str().unwrap(),
            "--solver",
            "tabu",
            "--iterations",
            "2",
            "--workers",
            "1",
        ])
        .stdout(std::process::Stdio::piped())
        .spawn()
        .expect("spawning cobi-es serve");
    let mut child = KillOnDrop(child);

    // the serve banner ends with "listening on <addr> — …"
    let stdout = child.0.stdout.take().expect("piped stdout");
    let mut reader = std::io::BufReader::new(stdout);
    let addr: std::net::SocketAddr = loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).expect("reading serve stdout");
        assert!(n > 0, "serve exited before printing its listen address");
        if let Some(rest) = line.strip_prefix("listening on ") {
            break rest
                .split_whitespace()
                .next()
                .expect("address after 'listening on'")
                .parse()
                .expect("parseable listen address");
        }
    };

    let set = benchmark_set("cnn_dm_20").unwrap();
    let summary = summarize_remote(addr, &set.documents[0].text()).unwrap();
    assert_eq!(summary.len(), 6);

    // exposition over the wire carries the energy ledger
    let exposition = metrics_remote(addr).unwrap();
    assert!(exposition.contains("cobi_es_energy_joules_total"), "{exposition}");
    assert!(exposition.contains("cobi_es_traces_total{state=\"recorded\"}"), "{exposition}");

    // the serve loop flushes traces every 500ms — poll until the JSONL
    // file holds a parseable span tree
    let mut parsed = 0;
    for _ in 0..40 {
        std::thread::sleep(std::time::Duration::from_millis(250));
        if path.exists() {
            let text = std::fs::read_to_string(&path).unwrap();
            if text.lines().any(|l| !l.trim().is_empty()) {
                parsed = assert_jsonl_parses(&path);
                break;
            }
        }
    }
    assert!(parsed >= 1, "no trace trees flushed to {} within 10s", path.display());
    std::fs::remove_file(&path).unwrap();
}
