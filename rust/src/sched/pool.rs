//! `DevicePool`: N solver instances draining one shared, fleet-wide queue
//! of Ising solve requests.
//!
//! Shape: a `PoolClient` (one per in-flight document, owned by a service
//! worker or stream session) sends `SolveRequest`s into one bounded MPSC
//! queue pulled by N device threads ("cobi-pool-<i>", each owning one
//! `PoolSolver`). A device takes one request, then lingers up to
//! `linger_us` — WITHOUT holding the queue lock — to coalesce up to
//! `max_coalesce` more requests into a single seeded dispatch, answering
//! each request on its one-shot response channel. The full thread and
//! channel ownership diagram lives in `docs/ARCHITECTURE.md` §3 (the
//! canonical copy; DESIGN.md §6 links there too).
//!
//! With `[portfolio] enabled = true` (or `backend = "portfolio"`) each
//! device hosts a `SolverPortfolio` instead of a single solver; all
//! devices share one fleet-wide warm-start cache and one portfolio
//! telemetry block (`DevicePool::portfolio_metrics`).
//!
//! Determinism: a request's results depend only on (instances, request
//! seed, solver config) — never on which device ran it, what was
//! co-batched, or dispatch order. COBI devices derive per-request RNG
//! streams inside `solve_groups_seeded`; Tabu/SA re-seed before each
//! request. Request seeds come from a per-document `PoolClient` stream
//! keyed by the document seed, so the whole service output is a pure
//! function of (config, corpus) under any pool/worker interleaving.
//!
//! Hot path: each device thread owns ONE long-lived solver, and the
//! solver owns its `SolveScratch` workspace (DESIGN.md decision #13) —
//! so steady-state traffic reuses spins/local-field/tenure buffers across
//! requests, and quantized (integer-valued) instances run the integer
//! `SolverKernel` automatically. Re-seeding resets only the RNG, never
//! the scratch: scratch carries capacity, not state, so per-request
//! determinism is unaffected (pinned by the test below).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TryRecvError};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Result};

use crate::cobi::{CobiDevice, SeededGroup};
use crate::config::Settings;
use crate::ising::Ising;
use crate::obs::{DispatchCounters, LedgerSolver, ObsShared, Subsystem};
use crate::portfolio::{PortfolioMetrics, PortfolioShared, SolverPortfolio};
use crate::resilience::{
    Calibrator, FaultModel, ResilienceMetrics, ResilienceShared, ResilientSolver,
};
use crate::runtime::ArtifactRuntime;
use crate::sched::breaker::{Action, BreakerFleet, BreakerMetrics, DeviceBreakerHandle};
use crate::service::metrics::Histogram;
use crate::service::overload::Deadline;
use crate::solvers::sa::SaSolver;
use crate::solvers::snowball::SnowballSolver;
use crate::solvers::tabu::TabuSolver;
use crate::solvers::{IsingSolver, SolveResult};
use crate::util::rng::Pcg32;

/// RNG stream id for per-document request-seed sequences (shared with
/// `exec::summarize_sequential`, the pool path's inline comparator).
pub(crate) const CLIENT_SEED_STREAM: u64 = 0x5EED;
/// Lock-free linger poll interval.
const LINGER_POLL: Duration = Duration::from_micros(20);
/// Max time an idle device holds the queue lock waiting for work. The
/// blocking receive MUST be bounded: a guard held across an unbounded
/// `recv()` starves sibling devices that need the lock to coalesce (their
/// linger `try_recv` would block until new traffic arrives — a deadlock
/// when the only client is itself waiting on one of those devices).
const IDLE_POLL: Duration = Duration::from_millis(1);

/// A solver that can serve pool requests with per-request determinism.
pub trait PoolSolver: Send {
    /// Stable backend name for reports.
    fn name(&self) -> &'static str;

    /// Solve every group's instances. A group's results must be a pure
    /// function of (instances, group seed, solver config) — independent
    /// of co-batched groups and of any earlier requests.
    fn solve_groups(&mut self, groups: &[SeededGroup<'_>]) -> Result<Vec<Vec<SolveResult>>>;

    /// As [`solve_groups`](PoolSolver::solve_groups), with one workload
    /// tag per group (`tags.len() == groups.len()`). Tags never change
    /// *what* a group answers — results stay a pure function of
    /// (instances, seed, config) — they only scope cross-request reuse:
    /// the portfolio keys its warm-start near tiers by tag so workloads
    /// sharing an instance size cannot poison each other's hints (tag 0
    /// is the legacy/ES namespace). Solvers with no reuse state ignore
    /// tags, which is what this default does.
    fn solve_groups_tagged(
        &mut self,
        tags: &[u64],
        groups: &[SeededGroup<'_>],
    ) -> Result<Vec<Vec<SolveResult>>> {
        debug_assert_eq!(tags.len(), groups.len());
        let _ = tags;
        self.solve_groups(groups)
    }
}

impl PoolSolver for CobiDevice {
    fn name(&self) -> &'static str {
        "cobi"
    }

    fn solve_groups(&mut self, groups: &[SeededGroup<'_>]) -> Result<Vec<Vec<SolveResult>>> {
        self.solve_groups_seeded(groups)
    }
}

impl PoolSolver for TabuSolver {
    fn name(&self) -> &'static str {
        "tabu"
    }

    fn solve_groups(&mut self, groups: &[SeededGroup<'_>]) -> Result<Vec<Vec<SolveResult>>> {
        Ok(groups
            .iter()
            .map(|g| {
                self.reseed(g.seed);
                g.instances.iter().map(|i| self.solve(i)).collect()
            })
            .collect())
    }
}

impl PoolSolver for SaSolver {
    fn name(&self) -> &'static str {
        "sa"
    }

    fn solve_groups(&mut self, groups: &[SeededGroup<'_>]) -> Result<Vec<Vec<SolveResult>>> {
        Ok(groups
            .iter()
            .map(|g| {
                self.reseed(g.seed);
                g.instances.iter().map(|i| self.solve(i)).collect()
            })
            .collect())
    }
}

impl PoolSolver for SnowballSolver {
    fn name(&self) -> &'static str {
        "snowball"
    }

    fn solve_groups(&mut self, groups: &[SeededGroup<'_>]) -> Result<Vec<Vec<SolveResult>>> {
        Ok(groups
            .iter()
            .map(|g| {
                self.reseed(g.seed);
                g.instances.iter().map(|i| self.solve(i)).collect()
            })
            .collect())
    }
}

/// Solvers the pool can host (per-request determinism implemented).
pub fn pool_supports(solver: &str) -> bool {
    matches!(solver, "cobi" | "tabu" | "sa" | "snowball" | "portfolio")
}

/// Resolve the configured pool backend. `[portfolio] enabled = true`
/// overrides everything (the portfolio wraps all pool-capable backends);
/// otherwise "auto" means the pipeline solver. Single source of truth for
/// `Service` routing and `DevicePool::start`.
pub fn resolved_backend(settings: &Settings) -> &str {
    if settings.portfolio.enabled {
        "portfolio"
    } else if settings.sched.backend == "auto" {
        &settings.pipeline.solver
    } else {
        &settings.sched.backend
    }
}

/// Whether a `Service` under `settings` routes Ising solves through the
/// shared pool — the one routing predicate, shared by `Service::start_with`
/// and the CLI (which must pre-open the HLO artifact runtime iff the
/// pooled/local route will construct a COBI-HLO device).
pub fn service_pooled(settings: &Settings) -> bool {
    settings.sched.enabled && pool_supports(resolved_backend(settings))
}

/// Build one pool-capable solver instance (also used by the service's
/// local-route streaming sessions and `summarize --resilience`, which
/// need per-request determinism without a pool).
///
/// Resilience wiring happens HERE, so every construction site inherits
/// it uniformly: with `[resilience] fault_enabled = true` the COBI
/// device (standalone or inside the portfolio) gets a [`FaultModel`]
/// whose counters feed the fleet-shared block when one is provided;
/// with `[resilience] enabled = true` the built solver is wrapped in a
/// [`ResilientSolver`] (replication + voting + verify-and-retry), which
/// is calibrated at construction when `calibrate = true`.
///
/// Energy accounting also wires HERE: with an `obs` handle, single-
/// backend solvers are wrapped in a [`LedgerSolver`] *underneath* the
/// resilience layer (so replicas/retries/escalations are charged at
/// their true multiplicity) and the portfolio is handed the ledger to
/// charge its routed backend per fresh solve. Solves dispatched while
/// the resilience layer is on are attributed to `Subsystem::Resilience`
/// instead of the construction site.
#[allow(clippy::too_many_arguments)]
pub(crate) fn build_solver(
    backend: &str,
    settings: &Settings,
    seed: u64,
    rt: Option<&ArtifactRuntime>,
    shared: Option<&PortfolioShared>,
    resilience: Option<&ResilienceShared>,
    obs: Option<(&ObsShared, Subsystem)>,
    verify_obs: Option<&Arc<AtomicU64>>,
) -> Result<Box<dyn PoolSolver>> {
    let subsystem = obs.map(|(_, site)| {
        if settings.resilience.enabled {
            Subsystem::Resilience
        } else {
            site
        }
    });
    let fault_model = || {
        settings.resilience.fault.enabled.then(|| {
            let mut fm = FaultModel::new(&settings.resilience.fault);
            if let Some(r) = resilience {
                fm.set_counters(r.faults.clone());
            }
            fm
        })
    };
    let inner: Box<dyn PoolSolver> = match backend {
        "cobi" => {
            let mut dev = CobiDevice::from_config(&settings.cobi, seed, rt)?;
            if let Some(fm) = fault_model() {
                dev.set_fault_model(fm);
            }
            Box::new(dev)
        }
        "tabu" => Box::new(TabuSolver::seeded(seed)),
        "sa" => Box::new(SaSolver::seeded(seed)),
        "snowball" => Box::new(SnowballSolver::new(
            seed,
            settings.solvers.snowball.solver_config(),
        )),
        "portfolio" => {
            // the portfolio attaches the fault model to its internal
            // COBI device itself (it owns the construction); only the
            // fleet counter block is threaded through here
            let mut p = SolverPortfolio::from_settings(settings, seed, rt, shared.cloned())?;
            if let Some(r) = resilience {
                p.share_fault_counters(r.faults.clone());
            }
            if let (Some((o, _)), Some(sub)) = (obs, subsystem) {
                p.set_ledger(o.ledger().clone(), sub);
            }
            Box::new(p)
        }
        other => bail!(
            "solver '{other}' cannot run on the device pool \
             (supported: cobi, tabu, sa, snowball, portfolio)"
        ),
    };
    // charge every non-portfolio solve here, under the resilience wrap
    // (the portfolio charges its routed backend itself)
    let inner: Box<dyn PoolSolver> = match (backend, obs, subsystem) {
        ("portfolio", _, _) | (_, None, _) | (_, _, None) => inner,
        (_, Some((o, _)), Some(sub)) => {
            Box::new(LedgerSolver::new(inner, backend, sub, o.ledger().clone()))
        }
    };
    if settings.resilience.enabled {
        let shared = resilience.cloned().unwrap_or_default();
        let mut rs = ResilientSolver::new(inner, &settings.resilience, shared);
        if let Some(v) = verify_obs {
            rs.set_verify_observer(v.clone());
        }
        if settings.resilience.calibrate {
            rs.calibrate()?;
        }
        return Ok(Box::new(rs));
    }
    Ok(inner)
}

/// One queued solve request (a whole refinement batch for one window).
struct SolveRequest {
    instances: Vec<Ising>,
    seed: u64,
    /// Workload tag stamped by the submitting client (0 = legacy/ES);
    /// scopes warm-start reuse, never the answer itself.
    tag: u64,
    enqueued: Instant,
    /// Request deadline, if the submitting client carries one; devices
    /// drop expired requests before dispatch (typed error reply).
    deadline: Option<Deadline>,
    respond: SyncSender<Result<Vec<SolveResult>>>,
}

/// Aggregate pool counters, snapshotted into `ServiceMetrics`.
#[derive(Debug, Clone)]
pub struct PoolMetrics {
    /// Solver instances in the pool.
    pub devices: usize,
    /// Device dispatches (each covers >= 1 coalesced requests).
    pub dispatches: u64,
    /// Requests served.
    pub requests: u64,
    /// Ising instances solved.
    pub instances: u64,
    /// Total device busy time, seconds.
    pub busy_s: f64,
    /// Wall-clock covered by this snapshot, seconds (0 until snapshotted).
    pub elapsed_s: f64,
    /// Requests dropped before dispatch because their deadline expired
    /// while queued (each got a typed `DeadlineExceeded` reply).
    pub expired: u64,
    /// Per-request pool queue wait histogram.
    pub queue_wait: Histogram,
}

impl PoolMetrics {
    fn new(devices: usize) -> Self {
        Self {
            devices,
            dispatches: 0,
            requests: 0,
            instances: 0,
            busy_s: 0.0,
            elapsed_s: 0.0,
            expired: 0,
            queue_wait: Histogram::latency(),
        }
    }

    /// Mean Ising instances per device dispatch — the amortization the
    /// pool exists to create (> 1 means batching is happening).
    pub fn batch_occupancy(&self) -> f64 {
        if self.dispatches == 0 {
            0.0
        } else {
            self.instances as f64 / self.dispatches as f64
        }
    }

    /// Mean requests fused per dispatch (cross-document coalescing).
    pub fn coalescing(&self) -> f64 {
        if self.dispatches == 0 {
            0.0
        } else {
            self.requests as f64 / self.dispatches as f64
        }
    }

    /// Fraction of device-seconds spent solving (0 when unknown).
    pub fn utilization(&self) -> f64 {
        let capacity = self.elapsed_s * self.devices as f64;
        if capacity > 0.0 {
            (self.busy_s / capacity).min(1.0)
        } else {
            0.0
        }
    }

    /// One-line pool counter summary.
    pub fn report(&self) -> String {
        let mut out = format!(
            "pool: devices={} dispatches={} requests={} instances={} | \
             occupancy={:.2} coalesce={:.2} util={:.0}% | pool-wait {}",
            self.devices,
            self.dispatches,
            self.requests,
            self.instances,
            self.batch_occupancy(),
            self.coalescing(),
            self.utilization() * 100.0,
            self.queue_wait.summary(),
        );
        if self.expired > 0 {
            out.push_str(&format!(" | expired={}", self.expired));
        }
        out
    }
}

impl Default for PoolMetrics {
    fn default() -> Self {
        Self::new(0)
    }
}

/// Cloneable submission endpoint (held by service workers; the pool's
/// device threads exit once every handle AND the pool itself dropped).
#[derive(Clone)]
pub struct PoolHandle {
    tx: SyncSender<SolveRequest>,
}

impl PoolHandle {
    /// A per-document client whose request-seed stream is keyed by `seed`
    /// (use `sched::doc_seed`), making document results independent of
    /// worker assignment and dispatch interleaving.
    pub fn client(&self, seed: u64) -> PoolClient {
        PoolClient {
            tx: self.tx.clone(),
            seeds: Pcg32::new(seed, CLIENT_SEED_STREAM),
            deadline: None,
            workload_tag: 0,
        }
    }
}

/// Per-document submission client: the sched executor submits refinement
/// batches through it and waits on the returned [`PendingSolve`]s. Errors
/// stay `Result`s end to end — there is deliberately no `IsingSolver`
/// facade here, because that trait cannot carry a pool failure without
/// panicking the calling worker.
pub struct PoolClient {
    tx: SyncSender<SolveRequest>,
    seeds: Pcg32,
    /// Deadline stamped onto every request this client submits (the
    /// worker sets it from the job before executing the document's DAG).
    deadline: Option<Deadline>,
    /// Workload tag stamped onto every request (0 = legacy/ES). Set by
    /// the workload layer via [`set_workload_tag`](PoolClient::set_workload_tag).
    workload_tag: u64,
}

/// In-flight solve; `wait` blocks for the device's answer.
pub struct PendingSolve {
    rx: Receiver<Result<Vec<SolveResult>>>,
}

impl PendingSolve {
    /// Block for the device's answer.
    pub fn wait(self) -> Result<Vec<SolveResult>> {
        self.rx
            .recv()
            .map_err(|_| anyhow!("device pool dropped the request (shutdown?)"))?
    }
}

impl PoolClient {
    /// Attach (or clear) the deadline stamped onto subsequent submits.
    pub fn set_deadline(&mut self, deadline: Option<Deadline>) {
        self.deadline = deadline;
    }

    /// The client's current deadline (the pooled executor checks it
    /// between pipeline stages).
    pub fn deadline(&self) -> Option<Deadline> {
        self.deadline
    }

    /// Set the workload tag stamped onto subsequent submits (0 = the
    /// legacy/ES namespace, the default). Tags scope warm-start reuse on
    /// the devices per workload ([`crate::workload::workload_tag`]); they
    /// never change what a request answers.
    pub fn set_workload_tag(&mut self, tag: u64) {
        self.workload_tag = tag;
    }

    /// Submit one request (all instances solved under one request seed
    /// drawn from the client's per-document stream). Blocks only when the
    /// pool queue is full (bounded backpressure); the solve itself
    /// proceeds asynchronously.
    pub fn submit(&mut self, instances: Vec<Ising>) -> Result<PendingSolve> {
        let seed = self.seeds.next_u64();
        self.submit_seeded(instances, seed)
    }

    /// Submit one request under an explicit request seed, bypassing the
    /// client's sequential stream. This is how `Tree`/`Streaming`
    /// decompositions dispatch: each plan node's seed is derived from the
    /// document seed and the node's tree position
    /// ([`crate::decompose::node_seed`]), so results cannot depend on
    /// submission order, sibling count, or arrival batching — properties
    /// the stream-ordered [`submit`](PoolClient::submit) cannot offer.
    pub fn submit_seeded(&mut self, instances: Vec<Ising>, seed: u64) -> Result<PendingSolve> {
        ensure!(!instances.is_empty(), "empty solve request");
        let (rtx, rrx) = sync_channel(1);
        let req = SolveRequest {
            instances,
            seed,
            tag: self.workload_tag,
            enqueued: Instant::now(),
            deadline: self.deadline,
            respond: rtx,
        };
        self.tx
            .send(req)
            .map_err(|_| anyhow!("device pool is shut down"))?;
        Ok(PendingSolve { rx: rrx })
    }
}

/// The pool: owns the device threads and the shared queue's sender side.
///
/// # Examples
///
/// ```
/// use cobi_es::config::Settings;
/// use cobi_es::ising::Ising;
/// use cobi_es::sched::DevicePool;
///
/// let mut settings = Settings::default();
/// settings.pipeline.solver = "tabu".into();
/// settings.sched.devices = 1;
/// let pool = DevicePool::start(&settings, None).unwrap();
///
/// let mut inst = Ising::new(6);
/// inst.set_pair(0, 1, -2.0);
/// let mut client = pool.client(42); // request seeds keyed by doc seed
/// let results = client.submit(vec![inst]).unwrap().wait().unwrap();
/// assert_eq!(results.len(), 1);
///
/// drop(client); // all clients must drop before shutdown joins
/// pool.shutdown();
/// ```
pub struct DevicePool {
    tx: Option<SyncSender<SolveRequest>>,
    threads: Vec<std::thread::JoinHandle<()>>,
    metrics: Arc<Mutex<PoolMetrics>>,
    started: Instant,
    /// Resolved backend name hosted by the devices.
    pub backend: String,
    /// Fleet-shared portfolio state (cache + telemetry); present only
    /// when the resolved backend is "portfolio".
    portfolio: Option<PortfolioShared>,
    /// Fleet-shared resilience state (counters + fault injections);
    /// present when the resilience layer or the fault model is enabled.
    resilience: Option<ResilienceShared>,
    /// Per-device circuit breakers (`[sched] breaker_enabled = true`).
    breaker: Option<Arc<BreakerFleet>>,
    /// Raised at shutdown so quarantined device threads — which sit in
    /// cooldown/probe cycles instead of the queue's disconnect path —
    /// still exit promptly.
    quit: Arc<AtomicBool>,
}

impl DevicePool {
    /// Start per `settings.sched` (+ `settings.cobi` for COBI devices).
    /// `rt` is required only for the COBI-HLO backend.
    pub fn start(settings: &Settings, rt: Option<&ArtifactRuntime>) -> Result<Self> {
        Self::start_obs(settings, rt, None)
    }

    /// [`DevicePool::start`] with an observability handle: device solvers
    /// charge its energy ledger, and every dispatch feeds its fleet
    /// coalescing counters. `Service` uses this; direct pool users can
    /// stay on `start`.
    pub fn start_obs(
        settings: &Settings,
        rt: Option<&ArtifactRuntime>,
        obs: Option<&ObsShared>,
    ) -> Result<Self> {
        let sched = &settings.sched;
        let backend = resolved_backend(settings).to_string();
        ensure!(
            pool_supports(&backend),
            "solver '{backend}' cannot run on the device pool"
        );
        let devices = sched.devices.max(1);
        let (tx, rx) = sync_channel::<SolveRequest>(sched.queue_depth.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Mutex::new(PoolMetrics::new(devices)));
        let max_coalesce = sched.max_coalesce.max(1);
        let linger = Duration::from_micros(sched.linger_us);
        // one fleet-wide warm-start cache + telemetry block, shared by
        // every portfolio device (DESIGN.md decision #11)
        let portfolio = (backend == "portfolio")
            .then(|| PortfolioShared::new(&settings.portfolio));
        // one fleet-wide resilience counter block (replication/vote/
        // retry counters + fault injections), shared the same way
        let resilience = (settings.resilience.enabled || settings.resilience.fault.enabled)
            .then(ResilienceShared::new);
        // one breaker fleet; each device gets a handle with its own
        // verify-failure feed and a calibrator as the half-open probe
        let breaker = sched
            .breaker
            .enabled
            .then(|| Arc::new(BreakerFleet::new(sched.breaker.clone(), devices)));
        let quit = Arc::new(AtomicBool::new(false));

        let mut threads = Vec::with_capacity(devices);
        for d in 0..devices {
            // construction seed decorrelates devices that are NOT
            // re-seeded per request (none today — kept for safety)
            let seed = settings.pipeline.seed ^ 0xD00D ^ ((d as u64) << 32);
            let verify_obs = breaker.as_ref().map(|_| Arc::new(AtomicU64::new(0)));
            let mut solver = build_solver(
                &backend,
                settings,
                seed,
                rt,
                portfolio.as_ref(),
                resilience.as_ref(),
                obs.map(|o| (o, Subsystem::Pool)),
                verify_obs.as_ref(),
            )?;
            let handle = breaker.as_ref().map(|fleet| DeviceBreakerHandle {
                device: d,
                fleet: fleet.clone(),
                probe: Calibrator::from_config(&settings.resilience),
                verify_failures: verify_obs.unwrap_or_default(),
            });
            let rx = rx.clone();
            let metrics = metrics.clone();
            let dispatch = obs.map(|o| o.dispatch().clone());
            let quit = quit.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("cobi-pool-{d}"))
                    .spawn(move || {
                        device_loop(
                            solver.as_mut(),
                            &rx,
                            &metrics,
                            dispatch,
                            handle,
                            &quit,
                            max_coalesce,
                            linger,
                        )
                    })?,
            );
        }
        Ok(Self {
            tx: Some(tx),
            threads,
            metrics,
            started: Instant::now(),
            backend,
            portfolio,
            resilience,
            breaker,
            quit,
        })
    }

    /// Portfolio telemetry snapshot (route counts, cache rates,
    /// per-backend latency) — `None` unless the backend is "portfolio".
    pub fn portfolio_metrics(&self) -> Option<PortfolioMetrics> {
        self.portfolio.as_ref().map(|p| p.snapshot())
    }

    /// Resilience telemetry snapshot (replication/vote/retry counters,
    /// per-device calibrations, fault injections) — `None` unless the
    /// resilience layer or the fault model is enabled.
    pub fn resilience_metrics(&self) -> Option<ResilienceMetrics> {
        self.resilience.as_ref().map(|r| r.snapshot())
    }

    /// Circuit-breaker fleet snapshot (trips/probes/readmissions and the
    /// current open/retired device counts) — `None` unless
    /// `[sched] breaker_enabled = true`.
    pub fn breaker_metrics(&self) -> Option<BreakerMetrics> {
        self.breaker.as_ref().map(|b| b.snapshot())
    }

    /// The breaker fleet itself (tests drive/inspect state through it).
    pub fn breaker(&self) -> Option<&Arc<BreakerFleet>> {
        self.breaker.as_ref()
    }

    /// A cloneable submission handle.
    pub fn handle(&self) -> PoolHandle {
        PoolHandle {
            tx: self.tx.as_ref().expect("pool not shut down").clone(),
        }
    }

    /// Convenience: a client straight off the pool (see `PoolHandle::client`).
    pub fn client(&self, seed: u64) -> PoolClient {
        self.handle().client(seed)
    }

    /// Number of device threads.
    pub fn devices(&self) -> usize {
        self.metrics.lock().unwrap().devices
    }

    /// Snapshot the counters (fills in elapsed wall-clock for utilization).
    pub fn metrics(&self) -> PoolMetrics {
        let mut m = self.metrics.lock().unwrap().clone();
        m.elapsed_s = self.started.elapsed().as_secs_f64();
        m
    }

    /// Close the queue and join the device threads. All `PoolHandle` /
    /// `PoolClient` clones must be dropped first or join will wait for
    /// them to finish (they keep the queue alive).
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.quit.store(true, Ordering::SeqCst);
        self.tx.take(); // close our side of the queue
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for DevicePool {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Poison-tolerant lock: a sibling device/worker that panicked while
/// holding the mutex must not cascade its failure to the whole fleet —
/// the protected values (an mpsc receiver, plain counters) stay valid
/// across an unwound panic, so recovering the guard is sound.
fn lock_recover<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// One device thread: breaker gate → pull → linger/coalesce → seeded
/// dispatch → respond.
#[allow(clippy::too_many_arguments)]
fn device_loop(
    solver: &mut dyn PoolSolver,
    rx: &Arc<Mutex<Receiver<SolveRequest>>>,
    metrics: &Arc<Mutex<PoolMetrics>>,
    dispatch: Option<Arc<DispatchCounters>>,
    breaker: Option<DeviceBreakerHandle>,
    quit: &Arc<AtomicBool>,
    max_coalesce: usize,
    linger: Duration,
) {
    loop {
        let mut batch: Vec<SolveRequest> = Vec::with_capacity(max_coalesce);
        // pull the first request. Bounded waits only (see IDLE_POLL): the
        // guard is a statement temporary, so the lock is dropped between
        // polls and is never held while lingering below.
        loop {
            // breaker gate: a quarantined device pulls no work — healthy
            // siblings absorb its share of the shared queue. The quit
            // flag covers shutdown, since a quarantined thread never
            // reaches the queue's disconnect signal below.
            if let Some(b) = &breaker {
                match b.fleet.action(b.device) {
                    Action::Admit => {}
                    Action::Cooldown(left) => {
                        if quit.load(Ordering::SeqCst) {
                            return;
                        }
                        std::thread::sleep(left.min(IDLE_POLL));
                        continue;
                    }
                    Action::Probe => {
                        if quit.load(Ordering::SeqCst) {
                            return;
                        }
                        b.run_probe(solver);
                        continue;
                    }
                    Action::Retired => return,
                }
            }
            let polled = lock_recover(rx).recv_timeout(IDLE_POLL);
            match polled {
                Ok(r) => {
                    batch.push(r);
                    break;
                }
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => return, // closed and drained
            }
        }
        let deadline = Instant::now() + linger;
        while batch.len() < max_coalesce {
            // bind first: a match-scrutinee temporary would keep the
            // guard alive through the sleep arm, serializing siblings
            let polled = lock_recover(rx).try_recv();
            match polled {
                Ok(r) => batch.push(r),
                Err(TryRecvError::Empty) => {
                    if Instant::now() >= deadline {
                        break;
                    }
                    std::thread::sleep(LINGER_POLL);
                }
                Err(TryRecvError::Disconnected) => break,
            }
        }

        // drop requests whose deadline expired while queued: a typed
        // reply instead of device time the client no longer wants
        if batch.iter().any(|r| r.deadline.is_some_and(|d| d.expired())) {
            let (dead, live): (Vec<_>, Vec<_>) = batch
                .into_iter()
                .partition(|r| r.deadline.is_some_and(|d| d.expired()));
            lock_recover(metrics).expired += dead.len() as u64;
            for r in dead {
                let d = r.deadline.expect("partitioned on an expired deadline");
                let _ = r.respond.try_send(Err(d.exceeded().into()));
            }
            batch = live;
            if batch.is_empty() {
                continue;
            }
        }

        let t0 = Instant::now();
        let groups: Vec<SeededGroup<'_>> = batch
            .iter()
            .map(|r| SeededGroup {
                instances: &r.instances,
                seed: r.seed,
            })
            .collect();
        let tags: Vec<u64> = batch.iter().map(|r| r.tag).collect();
        // contain a panicking dispatch: the job fails, the device (and
        // its siblings, via the poison-tolerant locks) keeps serving
        let solved = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            solver.solve_groups_tagged(&tags, &groups)
        }))
        .unwrap_or_else(|_| Err(anyhow!("device solver panicked during dispatch")));
        drop(groups);
        let busy = t0.elapsed();

        let batch_instances = batch.iter().map(|r| r.instances.len() as u64).sum::<u64>();
        if let Some(d) = &dispatch {
            d.record(batch.len() as u64, batch_instances);
        }
        {
            let mut m = lock_recover(metrics);
            m.dispatches += 1;
            m.requests += batch.len() as u64;
            m.instances += batch_instances;
            m.busy_s += busy.as_secs_f64();
            for r in &batch {
                m.queue_wait
                    .record(t0.saturating_duration_since(r.enqueued).as_secs_f64());
            }
        }

        match solved {
            Ok(per_group) => {
                // one clean dispatch = one success sample (plus whatever
                // verify failures the resilience wrapper fed the handle)
                if let Some(b) = &breaker {
                    b.record(true);
                }
                for (req, res) in batch.into_iter().zip(per_group) {
                    let _ = req.respond.try_send(Ok(res));
                }
            }
            Err(_) if batch.len() > 1 => {
                // a coalesced dispatch failed: isolate the offender by
                // retrying each request alone, so one document's bad
                // instance (or a transient device error) cannot poison
                // healthy co-batched documents. Per-request seeding makes
                // the retry results identical to what the fused dispatch
                // would have produced. Each retry is a real device
                // dispatch, so it is counted (occupancy honestly degrades
                // when failures force un-batching).
                for req in batch {
                    let tr = Instant::now();
                    let res = solver
                        .solve_groups_tagged(
                            &[req.tag],
                            &[SeededGroup {
                                instances: &req.instances,
                                seed: req.seed,
                            }],
                        )
                        .map(|mut v| v.remove(0))
                        .map_err(|e| {
                            anyhow!("pool dispatch on '{}' failed: {e:#}", solver.name())
                        });
                    if let Some(d) = &dispatch {
                        d.record(1, req.instances.len() as u64);
                    }
                    {
                        let mut m = lock_recover(metrics);
                        m.dispatches += 1;
                        m.busy_s += tr.elapsed().as_secs_f64();
                    }
                    // per-retry attribution: only the offending request's
                    // failure lands in this device's breaker window
                    if let Some(b) = &breaker {
                        b.record(res.is_ok());
                    }
                    let _ = req.respond.try_send(res);
                }
            }
            Err(e) => {
                if let Some(b) = &breaker {
                    b.record(false);
                }
                let msg = format!("pool dispatch on '{}' failed: {e:#}", solver.name());
                for req in batch {
                    let _ = req.respond.try_send(Err(anyhow!("{msg}")));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cobi::testutil::quantized_glass;

    fn settings(solver: &str, devices: usize) -> Settings {
        let mut s = Settings::default();
        s.pipeline.solver = solver.into();
        s.sched.devices = devices;
        s.sched.linger_us = 50;
        s
    }

    #[test]
    fn pool_starts_and_shuts_down() {
        let pool = DevicePool::start(&settings("cobi", 2), None).unwrap();
        assert_eq!(pool.devices(), 2);
        pool.shutdown(); // must not hang
    }

    #[test]
    fn pool_solves_and_counts() {
        let pool = DevicePool::start(&settings("cobi", 2), None).unwrap();
        let mut client = pool.client(7);
        let instances: Vec<Ising> = (0..5).map(|k| quantized_glass(k, 12)).collect();
        let pending = client.submit(instances.clone()).unwrap();
        let results = pending.wait().unwrap();
        assert_eq!(results.len(), 5);
        for (r, i) in results.iter().zip(&instances) {
            assert_eq!(r.spins.len(), 12);
            assert!((i.energy(&r.spins) - r.energy).abs() < 1e-6);
        }
        drop(client);
        let m = pool.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.instances, 5);
        assert!(m.dispatches >= 1);
        assert!(m.elapsed_s > 0.0);
        pool.shutdown();
    }

    #[test]
    fn same_client_seed_replays_identical_results() {
        let pool = DevicePool::start(&settings("cobi", 3), None).unwrap();
        let instances: Vec<Ising> = (0..4).map(|k| quantized_glass(40 + k, 14)).collect();
        let run = |pool: &DevicePool| -> Vec<SolveResult> {
            let mut client = pool.client(0xABCD);
            client.submit(instances.clone()).unwrap().wait().unwrap()
        };
        let a = run(&pool);
        let b = run(&pool);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.spins, y.spins);
        }
        pool.shutdown();
    }

    #[test]
    fn concurrent_clients_coalesce() {
        let mut s = settings("cobi", 1);
        s.sched.max_coalesce = 8;
        s.sched.linger_us = 50_000; // generous: force coalescing
        let pool = DevicePool::start(&s, None).unwrap();
        let handle = pool.handle();
        let threads: Vec<_> = (0..6)
            .map(|t| {
                let handle = handle.clone();
                std::thread::spawn(move || {
                    let mut client = handle.client(t as u64);
                    let inst = vec![quantized_glass(70 + t as u64, 10); 2];
                    client.submit(inst).unwrap().wait().unwrap().len()
                })
            })
            .collect();
        for t in threads {
            assert_eq!(t.join().unwrap(), 2);
        }
        drop(handle);
        let m = pool.metrics();
        assert_eq!(m.requests, 6);
        assert_eq!(m.instances, 12);
        assert!(
            m.dispatches < 6,
            "no coalescing happened: {} dispatches for 6 requests",
            m.dispatches
        );
        assert!(m.batch_occupancy() > 1.0, "occupancy {}", m.batch_occupancy());
        pool.shutdown();
    }

    #[test]
    fn failing_request_does_not_poison_cobatched_requests() {
        // one document's unprogrammable instance must fail only its own
        // request: co-batched healthy requests are retried individually
        let mut s = settings("cobi", 1);
        s.sched.max_coalesce = 8;
        s.sched.linger_us = 50_000; // encourage coalescing with the bad one
        let pool = DevicePool::start(&s, None).unwrap();
        let handle = pool.handle();
        let bad = {
            let handle = handle.clone();
            std::thread::spawn(move || {
                let mut c = handle.client(1);
                let mut inst = Ising::new(10);
                inst.h[0] = 0.5; // fractional: fails device validation
                c.submit(vec![inst]).unwrap().wait()
            })
        };
        let good = {
            let handle = handle.clone();
            std::thread::spawn(move || {
                let mut c = handle.client(2);
                c.submit(vec![quantized_glass(600, 10)]).unwrap().wait()
            })
        };
        assert!(bad.join().unwrap().is_err());
        assert_eq!(good.join().unwrap().unwrap().len(), 1);
        drop(handle);
        pool.shutdown();
    }

    #[test]
    fn pooled_tabu_runs_the_integer_kernel_identically_to_a_direct_solver() {
        // pool instances are quantized, so the device-hosted solver takes
        // the integer fast path; results must equal a directly re-seeded
        // solver's (which takes the same path) AND the f64 reference —
        // the pool-level face of the kernel equivalence contract
        let pool = DevicePool::start(&settings("tabu", 1), None).unwrap();
        let instances: Vec<Ising> = (0..3).map(|k| quantized_glass(800 + k, 14)).collect();
        let mut client = pool.client(0xBEEF);
        let pooled = client.submit(instances.clone()).unwrap().wait().unwrap();
        drop(client);
        pool.shutdown();

        let request_seed = Pcg32::new(0xBEEF, CLIENT_SEED_STREAM).next_u64();
        let mut direct = TabuSolver::seeded(0);
        direct.reseed(request_seed);
        let mut reference = TabuSolver::seeded(0);
        reference.reseed(request_seed);
        for (k, (p, inst)) in pooled.iter().zip(&instances).enumerate() {
            let d = direct.solve(inst);
            let r = reference.solve_reference_f64(inst);
            assert_eq!(p.spins, d.spins, "instance {k}");
            assert_eq!(p.energy.to_bits(), d.energy.to_bits(), "instance {k}");
            assert_eq!(p.spins, r.spins, "instance {k} vs f64 reference");
            assert_eq!(p.energy.to_bits(), r.energy.to_bits(), "instance {k}");
        }
    }

    #[test]
    fn pooled_snowball_is_thread_and_shape_invariant() {
        // the determinism pin at pool level: snowball results are
        // byte-identical across worker thread counts (1 vs 8), pool
        // shapes (1 vs 3 devices), and under ResilientSolver
        // replication-1 passthrough — all equal to a direct re-seeded
        // solver replay
        let instances: Vec<Ising> = (0..4).map(|k| quantized_glass(820 + k, 18)).collect();

        let pooled = |devices: usize, threads: usize, resilient: bool| -> Vec<SolveResult> {
            let mut s = settings("snowball", devices);
            s.solvers.snowball.threads = threads;
            if resilient {
                s.resilience.enabled = true;
                s.resilience.replication = 1;
                s.resilience.repair = false;
            }
            let pool = DevicePool::start(&s, None).unwrap();
            let mut client = pool.client(0xACE);
            let res = client.submit(instances.clone()).unwrap().wait().unwrap();
            drop(client);
            pool.shutdown();
            res
        };

        let request_seed = Pcg32::new(0xACE, CLIENT_SEED_STREAM).next_u64();
        let mut direct = SnowballSolver::seeded(0);
        direct.reseed(request_seed);
        let expect: Vec<SolveResult> = instances.iter().map(|i| direct.solve(i)).collect();

        for (devices, threads, resilient) in
            [(1, 1, false), (3, 1, false), (1, 8, false), (3, 8, false), (2, 8, true)]
        {
            let got = pooled(devices, threads, resilient);
            for (k, (g, e)) in got.iter().zip(&expect).enumerate() {
                let shape = format!("devices={devices} threads={threads} resilient={resilient}");
                assert_eq!(g.spins, e.spins, "instance {k} ({shape})");
                assert_eq!(g.energy.to_bits(), e.energy.to_bits(), "instance {k} ({shape})");
            }
        }
    }

    #[test]
    fn tabu_and_sa_pools_work() {
        for solver in ["tabu", "sa", "snowball"] {
            let pool = DevicePool::start(&settings(solver, 2), None).unwrap();
            let mut client = pool.client(3);
            let res = client
                .submit(vec![quantized_glass(9, 10)])
                .unwrap()
                .wait()
                .unwrap();
            assert_eq!(res.len(), 1);
            assert_eq!(res[0].spins.len(), 10);
            drop(client);
            pool.shutdown();
        }
    }

    #[test]
    fn unsupported_backends_are_rejected() {
        assert!(DevicePool::start(&settings("brute", 1), None).is_err());
        assert!(!pool_supports("exact"));
        assert!(pool_supports("cobi"));
        assert!(pool_supports("portfolio"));
    }

    #[test]
    fn portfolio_backend_pools_and_reports() {
        let mut s = settings("cobi", 2);
        s.portfolio.enabled = true;
        let pool = DevicePool::start(&s, None).unwrap();
        assert_eq!(pool.backend, "portfolio");
        let mut client = pool.client(5);
        let res = client
            .submit(vec![quantized_glass(11, 10)])
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(res.len(), 1);
        drop(client);
        let pm = pool.portfolio_metrics().expect("portfolio metrics");
        assert_eq!(pm.total_routes(), 1);
        pool.shutdown();

        // non-portfolio pools expose no portfolio telemetry
        let plain = DevicePool::start(&settings("tabu", 1), None).unwrap();
        assert!(plain.portfolio_metrics().is_none());
        plain.shutdown();
    }

    #[test]
    fn resilient_pool_serves_and_reports() {
        let mut s = settings("cobi", 2);
        s.resilience.enabled = true;
        s.resilience.replication = 2;
        s.resilience.fault.enabled = true;
        s.resilience.fault.stuck_rate = 0.2;
        let pool = DevicePool::start(&s, None).unwrap();
        let mut client = pool.client(7);
        let instances: Vec<Ising> = (0..2).map(|k| quantized_glass(950 + k, 12)).collect();
        let res = client.submit(instances.clone()).unwrap().wait().unwrap();
        assert_eq!(res.len(), 2);
        for (r, i) in res.iter().zip(&instances) {
            // resilient results always carry software-verified energies
            assert!((i.energy(&r.spins) - r.energy).abs() < 1e-9);
        }
        drop(client);
        let m = pool.resilience_metrics().expect("resilience metrics");
        assert_eq!(m.requests, 1);
        assert_eq!(m.replica_solves, 4, "2 replicas x 2 instances");
        pool.shutdown();

        // plain pools expose no resilience telemetry
        let plain = DevicePool::start(&settings("tabu", 1), None).unwrap();
        assert!(plain.resilience_metrics().is_none());
        plain.shutdown();
    }

    #[test]
    fn expired_requests_are_dropped_before_dispatch() {
        let pool = DevicePool::start(&settings("tabu", 1), None).unwrap();
        let mut client = pool.client(0xDEAD);
        client.set_deadline(Some(crate::service::overload::Deadline::from_ms(0)));
        let err = client
            .submit(vec![quantized_glass(1, 10)])
            .unwrap()
            .wait()
            .unwrap_err();
        assert!(
            err.downcast_ref::<crate::service::overload::DeadlineExceeded>()
                .is_some(),
            "expected a typed DeadlineExceeded, got: {err:#}"
        );
        // clearing the deadline restores normal service on the same client
        client.set_deadline(None);
        let res = client
            .submit(vec![quantized_glass(1, 10)])
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(res.len(), 1);
        drop(client);
        let m = pool.metrics();
        assert_eq!(m.expired, 1);
        assert!(m.report().contains("expired=1"));
        pool.shutdown();
    }

    #[test]
    fn quiet_breaker_pool_serves_identically_and_reports_empty() {
        let mut s = settings("tabu", 2);
        s.sched.breaker.enabled = true;
        let pool = DevicePool::start(&s, None).unwrap();
        let instances: Vec<Ising> = (0..3).map(|k| quantized_glass(500 + k, 12)).collect();
        let mut client = pool.client(0xFACE);
        let with_breaker = client.submit(instances.clone()).unwrap().wait().unwrap();
        drop(client);
        let m = pool.breaker_metrics().expect("breaker metrics");
        assert_eq!(m.devices, 2);
        assert!(!m.any(), "healthy traffic must never trip: {m:?}");
        pool.shutdown();

        // determinism: the breaker is pure bookkeeping — byte-identical
        // results to a breaker-less pool
        let plain = DevicePool::start(&settings("tabu", 2), None).unwrap();
        assert!(plain.breaker_metrics().is_none());
        let mut client = plain.client(0xFACE);
        let without = client.submit(instances).unwrap().wait().unwrap();
        drop(client);
        plain.shutdown();
        for (a, b) in with_breaker.iter().zip(&without) {
            assert_eq!(a.spins, b.spins);
            assert_eq!(a.energy.to_bits(), b.energy.to_bits());
        }
    }

    #[test]
    fn dispatch_errors_trip_quarantine_probe_and_readmit() {
        // cobi backend, resilience OFF: an unprogrammable instance makes
        // every dispatch fail, feeding the breaker failure samples. The
        // half-open probe runs the calibrator's small valid instances,
        // which the device solves fine — so it readmits after cooldown.
        let mut s = settings("cobi", 2);
        s.sched.breaker.enabled = true;
        s.sched.breaker.window = 4;
        s.sched.breaker.trip_failures = 2;
        s.sched.breaker.cooldown_ms = 10;
        s.sched.breaker.max_trips = 100; // exercise readmission, not retirement
        s.resilience.calibration_probes = 2; // fast half-open probes
        let pool = DevicePool::start(&s, None).unwrap();
        let handle = pool.handle();

        let mut bad = Ising::new(10);
        bad.h[0] = 0.5; // fractional: fails device validation every time
        let mut client = handle.client(9);
        for _ in 0..6 {
            let r = client.submit(vec![bad.clone()]).unwrap().wait();
            assert!(r.is_err());
        }
        let deadline = Instant::now() + Duration::from_secs(20);
        while pool.breaker_metrics().unwrap().trips == 0 {
            assert!(Instant::now() < deadline, "breaker never tripped");
            std::thread::sleep(Duration::from_millis(5));
        }
        // after cooldown the calibrator probe readmits the device(s)
        while pool.breaker_metrics().unwrap().readmissions == 0 {
            assert!(Instant::now() < deadline, "probe never readmitted");
            std::thread::sleep(Duration::from_millis(5));
        }
        // the pool still serves healthy traffic end to end
        let res = client
            .submit(vec![quantized_glass(700, 10)])
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(res.len(), 1);
        drop(client);
        drop(handle);
        let m = pool.breaker_metrics().unwrap();
        assert!(m.trips >= 1);
        assert!(m.probes >= 1);
        assert!(m.readmissions >= 1);
        assert!(m.any());
        assert!(m.report().contains("trips"));
        pool.shutdown(); // must not hang with breakers installed
    }

    #[test]
    fn fully_quarantined_pool_shuts_down_cleanly() {
        // both devices quarantined under a long cooldown: shutdown must
        // still join them via the quit flag (they never see the queue
        // disconnect)
        let mut s = settings("cobi", 2);
        s.sched.breaker.enabled = true;
        s.sched.breaker.window = 2;
        s.sched.breaker.trip_failures = 1;
        s.sched.breaker.cooldown_ms = 60_000;
        let pool = DevicePool::start(&s, None).unwrap();
        let handle = pool.handle();
        let mut bad = Ising::new(8);
        bad.h[0] = 0.5;
        let mut client = handle.client(1);
        // trip both devices (each failure trips whichever device served
        // it). Don't wait on the replies: once both devices quarantine,
        // queued requests would block a waiter for the whole cooldown —
        // abandoning the pendings also exercises the graceful
        // failed-reply path (dropped receiver, device try_send ignored).
        let deadline = Instant::now() + Duration::from_secs(20);
        let mut pendings = Vec::new();
        while pool.breaker_metrics().unwrap().open < 2 {
            assert!(Instant::now() < deadline, "devices never quarantined");
            pendings.push(client.submit(vec![bad.clone()]).unwrap());
            std::thread::sleep(Duration::from_millis(2));
        }
        drop(pendings);
        drop(client);
        drop(handle);
        pool.shutdown(); // must return promptly despite the 60s cooldown
    }

    #[test]
    fn calibrated_pool_devices_record_their_calibration() {
        let mut s = settings("cobi", 2);
        s.resilience.enabled = true;
        s.resilience.calibrate = true;
        s.resilience.calibration_probes = 3;
        let pool = DevicePool::start(&s, None).unwrap();
        let m = pool.resilience_metrics().expect("resilience metrics");
        assert_eq!(m.calibrations.len(), 2, "one calibration per device");
        for c in &m.calibrations {
            assert_eq!(c.probes, 3);
            assert!((1..=s.resilience.max_replication).contains(&c.replication));
        }
        pool.shutdown();
    }
}
