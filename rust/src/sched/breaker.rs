//! Per-device circuit breakers for the solver pool.
//!
//! Each pool device gets a rolling window of dispatch outcomes: a
//! dispatch error or a verify failure (reported by the `resilience`
//! wrapper's energy re-check) counts as a failure sample, a clean
//! dispatch as a success. When failures inside the window reach
//! `trip_failures` the breaker **opens** and the device thread stops
//! pulling work — the healthy devices absorb its share of the shared
//! request channel. After `cooldown_ms` the breaker goes **half-open**
//! and the device must pass a probe before readmission; per DESIGN.md
//! decision #21 the probe is the existing [`Calibrator`] from the
//! resilience subsystem (same deterministic ground-truth instances as
//! startup calibration), judged against `probe_target` success rate.
//! A device that trips more than `max_trips` times is **retired** for
//! the life of the pool — unless it is the last non-retired device, in
//! which case it keeps cycling open → probe forever (a limping fleet
//! beats a dead one).
//!
//! The fleet is pure bookkeeping: it never touches request payloads or
//! RNG streams, so enabling it cannot change any admitted summary.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::{Duration, Instant};

use crate::config::BreakerConfig;
use crate::resilience::Calibrator;
use crate::sched::pool::PoolSolver;

/// Breaker state for one device.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum State {
    /// Healthy: admitted to the drain loop.
    Closed,
    /// Tripped: quarantined, waiting out the cooldown.
    Open,
    /// Cooldown elapsed: next step is a calibration probe.
    HalfOpen,
    /// Permanently removed from the fleet (`trips > max_trips`).
    Retired,
}

/// What the owning device thread should do next.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Action {
    /// Pull and serve requests normally.
    Admit,
    /// Quarantined: sleep for the returned duration, then ask again.
    Cooldown(Duration),
    /// Run the half-open calibration probe and report via
    /// [`BreakerFleet::probe_result`].
    Probe,
    /// Permanently retired: exit the drain loop.
    Retired,
}

#[derive(Debug)]
struct DeviceState {
    /// Rolling outcome window; `true` = failure sample.
    window: VecDeque<bool>,
    state: State,
    opened_at: Option<Instant>,
    trips: u32,
}

impl DeviceState {
    fn new() -> Self {
        Self {
            window: VecDeque::new(),
            state: State::Closed,
            opened_at: None,
            trips: 0,
        }
    }

    fn failures(&self) -> u32 {
        self.window.iter().filter(|&&f| f).count() as u32
    }

    fn push(&mut self, failure: bool, window: usize) {
        self.window.push_back(failure);
        while self.window.len() > window.max(1) {
            self.window.pop_front();
        }
    }
}

/// Point-in-time fleet summary, merged into `ServiceMetrics` and the
/// `::METRICS::` exposition.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BreakerMetrics {
    /// Devices tracked by the fleet.
    pub devices: usize,
    /// Devices currently open or half-open (quarantined).
    pub open: usize,
    /// Devices permanently retired.
    pub retired: usize,
    /// Lifetime breaker trips.
    pub trips: u64,
    /// Half-open calibration probes run.
    pub probes: u64,
    /// Probes that readmitted their device.
    pub readmissions: u64,
    /// Devices retired over the fleet lifetime.
    pub retirements: u64,
}

impl BreakerMetrics {
    /// Did the breaker ever act? (Gates report output so a quiet fleet
    /// stays byte-identical to a breaker-less build.)
    pub fn any(&self) -> bool {
        self.trips > 0 || self.probes > 0 || self.open > 0 || self.retired > 0
    }

    /// Human-readable fragment for the service report.
    pub fn report(&self) -> String {
        format!(
            "breaker: {}/{} open, {} retired, {} trips, {} probes, {} readmissions",
            self.open, self.devices, self.retired, self.trips, self.probes, self.readmissions
        )
    }
}

/// Shared per-fleet breaker bookkeeping (one per [`DevicePool`]).
///
/// [`DevicePool`]: crate::sched::pool::DevicePool
#[derive(Debug)]
pub struct BreakerFleet {
    cfg: BreakerConfig,
    devices: Mutex<Vec<DeviceState>>,
    trips: AtomicU64,
    probes: AtomicU64,
    readmissions: AtomicU64,
    retirements: AtomicU64,
}

impl BreakerFleet {
    /// Fleet of `devices` breakers under `cfg`.
    pub fn new(cfg: BreakerConfig, devices: usize) -> Self {
        Self {
            cfg,
            devices: Mutex::new((0..devices).map(|_| DeviceState::new()).collect()),
            trips: AtomicU64::new(0),
            probes: AtomicU64::new(0),
            readmissions: AtomicU64::new(0),
            retirements: AtomicU64::new(0),
        }
    }

    /// Is the breaker feature on at all?
    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Vec<DeviceState>> {
        self.devices.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Record one dispatch outcome for `device`. `ok` is whether the
    /// dispatch itself succeeded; `verify_failures` is how many replica
    /// verifications the resilience wrapper rejected during it (each
    /// counts as its own failure sample — a lying device fails fast).
    pub fn record_dispatch(&self, device: usize, ok: bool, verify_failures: u64) {
        if !self.cfg.enabled {
            return;
        }
        let mut fleet = self.lock();
        let Some(dev) = fleet.get_mut(device) else {
            return;
        };
        if dev.state != State::Closed {
            return; // samples only count while admitted
        }
        for _ in 0..verify_failures {
            dev.push(true, self.cfg.window);
        }
        dev.push(!ok || verify_failures > 0, self.cfg.window);
        if dev.failures() >= self.cfg.trip_failures.max(1) {
            self.trip(&mut fleet, device);
        }
    }

    /// Trip `device`: open (or retire, past `max_trips`) and clear its
    /// window. Caller holds the fleet lock.
    fn trip(&self, fleet: &mut [DeviceState], device: usize) {
        self.trips.fetch_add(1, Ordering::Relaxed);
        let last_standing = Self::is_last_standing(fleet, device);
        let dev = &mut fleet[device];
        dev.trips += 1;
        dev.window.clear();
        if dev.trips > self.cfg.max_trips && !last_standing {
            dev.state = State::Retired;
            dev.opened_at = None;
            self.retirements.fetch_add(1, Ordering::Relaxed);
        } else {
            dev.state = State::Open;
            dev.opened_at = Some(Instant::now());
        }
    }

    /// Would retiring `device` leave the pool with no admissible device?
    fn is_last_standing(fleet: &[DeviceState], device: usize) -> bool {
        !fleet
            .iter()
            .enumerate()
            .any(|(i, d)| i != device && d.state != State::Retired)
    }

    /// What should `device`'s thread do right now?
    pub fn action(&self, device: usize) -> Action {
        if !self.cfg.enabled {
            return Action::Admit;
        }
        let mut fleet = self.lock();
        let Some(dev) = fleet.get_mut(device) else {
            return Action::Admit;
        };
        match dev.state {
            State::Closed => Action::Admit,
            State::Retired => Action::Retired,
            State::HalfOpen => Action::Probe,
            State::Open => {
                let cooldown = Duration::from_millis(self.cfg.cooldown_ms);
                let since = dev.opened_at.map(|t| t.elapsed()).unwrap_or(cooldown);
                if since >= cooldown {
                    dev.state = State::HalfOpen;
                    Action::Probe
                } else {
                    Action::Cooldown(cooldown - since)
                }
            }
        }
    }

    /// Report the half-open probe outcome: readmit on health, re-trip
    /// (possibly into retirement) otherwise.
    pub fn probe_result(&self, device: usize, healthy: bool) {
        self.probes.fetch_add(1, Ordering::Relaxed);
        let mut fleet = self.lock();
        if fleet.get(device).is_none() {
            return;
        }
        if healthy {
            let dev = &mut fleet[device];
            dev.state = State::Closed;
            dev.opened_at = None;
            dev.window.clear();
            self.readmissions.fetch_add(1, Ordering::Relaxed);
        } else {
            self.trip(&mut fleet, device);
        }
    }

    /// Current state of one device (for tests and reports).
    pub fn state(&self, device: usize) -> Option<State> {
        self.lock().get(device).map(|d| d.state)
    }

    /// Point-in-time fleet metrics.
    pub fn snapshot(&self) -> BreakerMetrics {
        let fleet = self.lock();
        BreakerMetrics {
            devices: fleet.len(),
            open: fleet
                .iter()
                .filter(|d| matches!(d.state, State::Open | State::HalfOpen))
                .count(),
            retired: fleet.iter().filter(|d| d.state == State::Retired).count(),
            trips: self.trips.load(Ordering::Relaxed),
            probes: self.probes.load(Ordering::Relaxed),
            readmissions: self.readmissions.load(Ordering::Relaxed),
            retirements: self.retirements.load(Ordering::Relaxed),
        }
    }
}

/// One device thread's handle into the fleet: records outcomes (folding
/// in the verify-failure feed from the resilience wrapper) and runs the
/// half-open probe.
pub struct DeviceBreakerHandle {
    /// Device index inside the pool.
    pub device: usize,
    /// Shared fleet bookkeeping.
    pub fleet: Arc<BreakerFleet>,
    /// The half-open prober (startup calibrator reused; decision #21).
    pub probe: Calibrator,
    /// Verify-failure counter the resilience wrapper increments; drained
    /// (swap-to-zero) once per dispatch.
    pub verify_failures: Arc<AtomicU64>,
}

impl DeviceBreakerHandle {
    /// Record one dispatch outcome, draining the verify-failure feed.
    pub fn record(&self, ok: bool) {
        let vf = self.verify_failures.swap(0, Ordering::Relaxed);
        self.fleet.record_dispatch(self.device, ok, vf);
    }

    /// Run the half-open calibration probe against this device's solver
    /// and report the verdict. Returns the resulting device state.
    pub fn run_probe(&self, solver: &mut dyn PoolSolver) -> Option<State> {
        let healthy = match self.probe.calibrate(solver) {
            Ok(cal) => cal.success_rate >= self.fleet.cfg.probe_target,
            Err(_) => false, // a probe that errors is an unhealthy device
        };
        self.fleet.probe_result(self.device, healthy);
        self.fleet.state(self.device)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> BreakerConfig {
        BreakerConfig {
            enabled: true,
            window: 8,
            trip_failures: 3,
            cooldown_ms: 0, // elapse immediately: state tests need no sleeps
            max_trips: 2,
            probe_target: 0.5,
        }
    }

    #[test]
    fn disabled_fleet_is_inert() {
        let fleet = BreakerFleet::new(BreakerConfig::default(), 2);
        assert!(!fleet.enabled());
        for _ in 0..64 {
            fleet.record_dispatch(0, false, 9);
        }
        assert_eq!(fleet.action(0), Action::Admit);
        assert!(!fleet.snapshot().any());
    }

    #[test]
    fn failures_inside_window_trip_the_breaker() {
        let fleet = BreakerFleet::new(cfg(), 2);
        fleet.record_dispatch(0, false, 0);
        fleet.record_dispatch(0, false, 0);
        assert_eq!(fleet.state(0), Some(State::Closed));
        fleet.record_dispatch(0, false, 0);
        assert_eq!(fleet.state(0), Some(State::Open));
        let m = fleet.snapshot();
        assert_eq!((m.trips, m.open), (1, 1));
        // the other device is untouched
        assert_eq!(fleet.action(1), Action::Admit);
    }

    #[test]
    fn successes_age_failures_out_of_the_window() {
        let fleet = BreakerFleet::new(cfg(), 1);
        // alternate: never 3 failures inside an 8-wide window? 2 fails,
        // then 8 successes push them out, then 2 more fails — no trip.
        fleet.record_dispatch(0, false, 0);
        fleet.record_dispatch(0, false, 0);
        for _ in 0..8 {
            fleet.record_dispatch(0, true, 0);
        }
        fleet.record_dispatch(0, false, 0);
        fleet.record_dispatch(0, false, 0);
        assert_eq!(fleet.state(0), Some(State::Closed));
    }

    #[test]
    fn verify_failures_count_as_failure_samples() {
        let fleet = BreakerFleet::new(cfg(), 2);
        // one dispatch that verified-and-rejected 3 replicas trips alone
        fleet.record_dispatch(0, true, 3);
        assert_eq!(fleet.state(0), Some(State::Open));
    }

    #[test]
    fn cooldown_then_probe_then_readmission() {
        let fleet = BreakerFleet::new(cfg(), 2);
        fleet.record_dispatch(0, false, 3);
        assert_eq!(fleet.state(0), Some(State::Open));
        // cooldown_ms = 0: first ask already half-opens into a probe
        assert_eq!(fleet.action(0), Action::Probe);
        assert_eq!(fleet.state(0), Some(State::HalfOpen));
        fleet.probe_result(0, true);
        assert_eq!(fleet.state(0), Some(State::Closed));
        assert_eq!(fleet.action(0), Action::Admit);
        let m = fleet.snapshot();
        assert_eq!((m.probes, m.readmissions, m.open), (1, 1, 0));
        // the window was cleared: old failures don't haunt the readmit
        fleet.record_dispatch(0, false, 0);
        fleet.record_dispatch(0, false, 0);
        assert_eq!(fleet.state(0), Some(State::Closed));
    }

    #[test]
    fn cooldown_holds_the_device_out() {
        let mut c = cfg();
        c.cooldown_ms = 60_000;
        let fleet = BreakerFleet::new(c, 2);
        fleet.record_dispatch(0, false, 3);
        match fleet.action(0) {
            Action::Cooldown(left) => assert!(left <= Duration::from_millis(60_000)),
            other => panic!("expected cooldown, got {other:?}"),
        }
        assert_eq!(fleet.state(0), Some(State::Open));
    }

    #[test]
    fn repeated_trips_retire_the_device() {
        let fleet = BreakerFleet::new(cfg(), 2); // max_trips = 2
        for round in 0..3 {
            fleet.record_dispatch(0, false, 3); // trip
            if round < 2 {
                assert_eq!(fleet.action(0), Action::Probe);
                fleet.probe_result(0, true); // readmit, try again
            }
        }
        assert_eq!(fleet.state(0), Some(State::Retired));
        assert_eq!(fleet.action(0), Action::Retired);
        let m = fleet.snapshot();
        assert_eq!((m.trips, m.retirements, m.retired), (3, 1, 1));
        assert!(m.any());
    }

    #[test]
    fn failed_probe_retrips_and_can_retire() {
        let fleet = BreakerFleet::new(cfg(), 2);
        fleet.record_dispatch(0, false, 3); // trip 1
        assert_eq!(fleet.action(0), Action::Probe);
        fleet.probe_result(0, false); // trip 2
        assert_eq!(fleet.state(0), Some(State::Open));
        assert_eq!(fleet.action(0), Action::Probe);
        fleet.probe_result(0, false); // trip 3 > max_trips: retired
        assert_eq!(fleet.state(0), Some(State::Retired));
    }

    #[test]
    fn last_standing_device_is_never_retired() {
        let fleet = BreakerFleet::new(cfg(), 2);
        // retire device 1 first
        for _ in 0..3 {
            fleet.record_dispatch(1, false, 3);
            if fleet.state(1) == Some(State::Open) {
                assert_eq!(fleet.action(1), Action::Probe);
                fleet.probe_result(1, false);
            }
        }
        assert_eq!(fleet.state(1), Some(State::Retired));
        // device 0 now trips far past max_trips but must keep probing
        for _ in 0..6 {
            fleet.record_dispatch(0, false, 3);
            assert_eq!(fleet.action(0), Action::Probe);
            fleet.probe_result(0, false);
        }
        assert_ne!(fleet.state(0), Some(State::Retired));
        assert_eq!(fleet.snapshot().retired, 1);
        // and a healthy probe still readmits it
        assert_eq!(fleet.action(0), Action::Probe);
        fleet.probe_result(0, true);
        assert_eq!(fleet.state(0), Some(State::Closed));
    }

    #[test]
    fn quarantined_devices_ignore_late_samples() {
        let fleet = BreakerFleet::new(cfg(), 2);
        fleet.record_dispatch(0, false, 3);
        assert_eq!(fleet.state(0), Some(State::Open));
        let trips_before = fleet.snapshot().trips;
        // an in-flight dispatch finishing after the trip must not re-trip
        fleet.record_dispatch(0, false, 5);
        assert_eq!(fleet.snapshot().trips, trips_before);
    }

    #[test]
    fn handle_drains_the_verify_feed() {
        let fleet = Arc::new(BreakerFleet::new(cfg(), 1));
        let handle = DeviceBreakerHandle {
            device: 0,
            fleet: fleet.clone(),
            probe: Calibrator {
                probes: 1,
                target: 0.9,
                max_replication: 2,
            },
            verify_failures: Arc::new(AtomicU64::new(0)),
        };
        handle.verify_failures.store(3, Ordering::Relaxed);
        handle.record(true);
        assert_eq!(handle.verify_failures.load(Ordering::Relaxed), 0);
        assert_eq!(fleet.state(0), Some(State::Open));
    }
}
