//! Graph-driven pooled summarization: the worker-side execution engine
//! that walks a [`SubproblemGraph`](super::SubproblemGraph) level by
//! level, submitting every ready window's refinement batch to the shared
//! [`DevicePool`](super::DevicePool) BEFORE waiting on any of them — so
//! all windows of a pass (and of every other in-flight document) are
//! available for cross-document coalescing on the devices.
//!
//! Determinism: all RNG here is per-document. Under the default
//! [`Strategy::Window`] plan the quantization stream is
//! `Pcg32::new(cfg.seed, 0xE5)` — the exact stream `EsPipeline` uses — and
//! instances are drawn in unit-id (submission) order, which is fixed by
//! the graph, not by completion timing; solve randomness derives from the
//! client's request-seed stream. Under [`Strategy::Tree`] every plan node
//! instead derives its own seed from (document seed, level, slot) via
//! [`node_seed`](crate::decompose::node_seed), and
//! [`Strategy::Streaming`] documents route through
//! [`StreamSummarizer`](super::StreamSummarizer). Either way the result
//! is byte-identical summaries for a fixed (config, document) regardless
//! of pool size, coalescing, worker count, or dispatch interleaving.

use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::cobi::SeededGroup;
use crate::config::PipelineConfig;
use crate::corpus::Document;
use crate::decompose::{node_seed, DecomposePlan, Strategy};
use crate::embed::{Embedder, HashEmbedder, Scores};
use crate::ising::EsProblem;
use crate::obs::recorder::{spin_hash, NodeRecord};
use crate::obs::{ObsShared, Span};
use crate::pipeline::Summary;
use crate::refine::{prepare_instances, select_best};
use crate::text::MAX_SENTENCES;
use crate::util::rng::Pcg32;

use super::graph::SubproblemGraph;
use super::pool::{PoolClient, PoolSolver, CLIENT_SEED_STREAM};
use super::stream::{StreamRoute, StreamSummarizer};
use super::{request_seed, QUANT_STREAM};

/// Executor-side tracing context: the request's root span plus the obs
/// handle whose cost model prices the modeled per-unit attributes.
///
/// Everything recorded through it is a pure function of (config,
/// document): unit ids/levels/slots come from the decomposition plan,
/// instance counts from the refinement config, and modeled energy from
/// the `[cobi]`/`[timing]` constants — never from wall clocks, pool
/// shape, or dispatch order. Wall-clock measurements go into span
/// `wall` sections only (excluded from pinned output, decision #18).
struct Trace<'a> {
    obs: &'a ObsShared,
    root: &'a mut Span,
}

impl Trace<'_> {
    /// The fixed pre-solve stages (ingest → embed → decompose).
    fn preamble(&mut self, n: usize, cfg: &PipelineConfig) {
        self.root.push(Span::new("ingest").with("sentences", n));
        self.root.push(Span::new("embed").with("sentences", n));
        self.root.push(
            Span::new("decompose")
                .with("strategy", cfg.strategy.as_str())
                .with("p", cfg.decompose_p)
                .with("q", cfg.decompose_q),
        );
    }

    /// One per-unit quantize+solve stage; returns the child index so the
    /// caller can stamp wall attributes once the solve settles.
    fn solve_stage(&mut self, u: &super::graph::SolveUnit, instances: usize) -> usize {
        let cost = self
            .obs
            .model()
            .per_instance(self.obs.backend(), u.window.len());
        let k = instances as f64;
        self.root.push(
            Span::new("solve")
                .with("unit", u.id)
                .with("level", u.level)
                .with("slot", u.slot)
                .with("n", u.window.len())
                .with("instances", instances)
                .with("modeled_device_s", cost.device_s * k)
                .with("modeled_j", cost.joules * k),
        )
    }

    /// The scoring tail.
    fn score(&mut self, summary: &Summary) {
        self.root.push(
            Span::new("score")
                .with("objective", summary.objective)
                .with("selected", summary.selected.len())
                .with("solves", summary.total_solves),
        );
    }
}

/// Summarize `doc` to `cfg.summary_len` sentences, solving every Ising
/// subproblem through the shared device pool, decomposed per
/// `cfg.strategy`.
///
/// # Examples
///
/// What it demonstrates: one synthetic document through a shared
/// 2-device pool. The summary is a pure function of (config, document) —
/// the pool's shape never leaks into the result.
///
/// ```
/// use cobi_es::config::Settings;
/// use cobi_es::corpus::Generator;
/// use cobi_es::sched::{doc_seed, summarize_with_pool, DevicePool};
///
/// let mut settings = Settings::default();
/// settings.pipeline.solver = "tabu".into();
/// settings.pipeline.iterations = 2;
/// let pool = DevicePool::start(&settings, None).unwrap();
///
/// let doc = Generator::with_seed(9).document("doc-a", 12);
/// let mut cfg = settings.pipeline.clone();
/// cfg.seed = doc_seed(cfg.seed, &doc.id); // seeds key to the document
/// let mut client = pool.client(cfg.seed);
/// let summary = summarize_with_pool(&doc, &cfg, &mut client).unwrap();
/// assert_eq!(summary.selected.len(), cfg.summary_len);
/// assert!(summary.selected.windows(2).all(|w| w[0] < w[1]));
///
/// drop(client); // clients must drop before shutdown joins
/// pool.shutdown();
/// ```
///
/// Expected output: no output — the assertions pass.
pub fn summarize_with_pool(
    doc: &Document,
    cfg: &PipelineConfig,
    client: &mut PoolClient,
) -> Result<Summary> {
    let mut embedder = HashEmbedder::new();
    summarize_with_pool_using(doc, cfg, client, &mut embedder)
}

/// As [`summarize_with_pool`], with a caller-provided embedder.
///
/// `Strategy::Streaming` documents ignore `embedder` and always embed
/// through the incremental hash path ([`StreamSummarizer`]): the trait
/// only exposes whole-document scoring, which a causal frontier cannot
/// use.
pub fn summarize_with_pool_using(
    doc: &Document,
    cfg: &PipelineConfig,
    client: &mut PoolClient,
    embedder: &mut dyn Embedder,
) -> Result<Summary> {
    pool_exec(doc, cfg, client, embedder, None, None)
}

/// As [`summarize_with_pool`], recording a request-scoped span tree
/// through `obs`. Returns the summary plus the root span — `None` when
/// span recording is off, in which case this is exactly the untraced
/// path (no allocation, no extra work). The span's deterministic
/// attributes are byte-identical across pool shapes; measured wall
/// times land in `wall` sections only.
pub fn summarize_with_pool_traced(
    doc: &Document,
    cfg: &PipelineConfig,
    client: &mut PoolClient,
    obs: &ObsShared,
) -> Result<(Summary, Option<Span>)> {
    let mut embedder = HashEmbedder::new();
    summarize_with_pool_traced_using(doc, cfg, client, obs, &mut embedder)
}

/// As [`summarize_with_pool_traced`], with a caller-provided embedder
/// (ignored for `Strategy::Streaming` — see
/// [`summarize_with_pool_using`]). The workload layer routes non-ES
/// selections through this with precomputed scores.
pub fn summarize_with_pool_traced_using(
    doc: &Document,
    cfg: &PipelineConfig,
    client: &mut PoolClient,
    obs: &ObsShared,
    embedder: &mut dyn Embedder,
) -> Result<(Summary, Option<Span>)> {
    let mut root = obs.start_request(&doc.id);
    let trace = root.as_mut().map(|r| Trace { obs, root: r });
    let summary = pool_exec(doc, cfg, client, embedder, trace, None)?;
    Ok((summary, root))
}

/// As [`summarize_with_pool_traced`], additionally pushing one
/// [`NodeRecord`] per solve-DAG node (level, slot, node seed,
/// spin-vector hash, selected-best energy bits) into `rec`, in
/// submission order — the flight recorder's per-node tap. Nodes are the
/// same pure function of (config, document) as the summary, so the
/// recorded taps are byte-identical across pool shapes. Streamed
/// documents record no nodes (the frontier re-plans per arrival).
pub fn summarize_with_pool_recorded(
    doc: &Document,
    cfg: &PipelineConfig,
    client: &mut PoolClient,
    obs: &ObsShared,
    rec: &mut Vec<NodeRecord>,
) -> Result<(Summary, Option<Span>)> {
    let mut embedder = HashEmbedder::new();
    let mut root = obs.start_request(&doc.id);
    let trace = root.as_mut().map(|r| Trace { obs, root: r });
    let summary = pool_exec(doc, cfg, client, &mut embedder, trace, Some(rec))?;
    Ok((summary, root))
}

fn pool_exec(
    doc: &Document,
    cfg: &PipelineConfig,
    client: &mut PoolClient,
    embedder: &mut dyn Embedder,
    mut trace: Option<Trace<'_>>,
    mut rec: Option<&mut Vec<NodeRecord>>,
) -> Result<Summary> {
    if cfg.strategy == Strategy::Streaming {
        // whole document replayed as one arrival chunk — byte-identical
        // to the same sentences fed incrementally in any chunking.
        // Streamed requests trace at request granularity only (the
        // frontier re-plans per arrival; per-unit spans would not be
        // arrival-invariant).
        if let Some(t) = trace.as_mut() {
            t.root.set("strategy", cfg.strategy.as_str());
        }
        let mut stream = StreamSummarizer::new(&doc.id, cfg)?;
        let mut route = StreamRoute::Pooled(client);
        stream.push_sentences(&doc.sentences, &mut route)?;
        return stream.revision(&mut route);
    }
    let n = doc.len().min(MAX_SENTENCES);
    ensure!(n >= cfg.summary_len, "document too short");
    let sentences = &doc.sentences[..n];
    let scores = embedder.scores(sentences).context("embedding failed")?;
    if let Some(t) = trace.as_mut() {
        t.preamble(n, cfg);
    }

    let params = cfg.decompose_params();
    let refine_cfg = cfg.refine_config();
    let per_node = cfg.strategy != Strategy::Window;
    // the same per-document stream EsPipeline::new uses — quantization
    // draws replay identically across the inline and pooled paths
    // (window plan only; per-node plans re-derive a stream per unit)
    let mut rng = Pcg32::new(cfg.seed, QUANT_STREAM);

    let mut graph =
        SubproblemGraph::with_plan(n, DecomposePlan::new(cfg.strategy, &params)?)?;
    let mut total_solves = 0usize;
    while !graph.is_done() {
        // deadline seam: a request whose budget died between DAG levels
        // stops here instead of submitting another round of solves (the
        // pool re-checks per dispatch, but this catches deep documents
        // whose remaining levels would all be wasted)
        if let Some(d) = client.deadline() {
            if d.expired() {
                return Err(d.exceeded().into());
            }
        }
        let units = graph.take_ready();
        ensure!(!units.is_empty(), "scheduler stalled: no ready units");
        // submit the whole level before waiting on anything
        let mut pending = Vec::with_capacity(units.len());
        for u in &units {
            let sub = scores.subset(&u.window);
            let p = EsProblem {
                mu: sub.mu,
                beta: sub.beta,
                lambda: cfg.lambda,
                m: u.target,
            };
            // node seed 0 under the window plan: its draws come from the
            // per-document streams above, in submission order, so the
            // recorded taps still match the sequential path byte for byte
            let (instances, explicit_seed, ns) = if per_node {
                let ns = node_seed(cfg.seed, u.level, u.slot);
                (
                    prepare_instances(&p, &refine_cfg, &mut Pcg32::new(ns, QUANT_STREAM)),
                    Some(request_seed(ns)),
                    ns,
                )
            } else {
                (prepare_instances(&p, &refine_cfg, &mut rng), None, 0)
            };
            total_solves += instances.len();
            // span children are created in submission order, which the
            // graph fixes — never in completion order
            let stage = trace.as_mut().map(|t| t.solve_stage(u, instances.len()));
            let pend = match explicit_seed {
                Some(seed) => client.submit_seeded(instances, seed),
                None => client.submit(instances),
            }
            .with_context(|| format!("submitting unit {} of {}", u.id, doc.id))?;
            pending.push((u.id, u.level, u.slot, ns, p, pend, stage, Instant::now()));
        }
        for (id, level, slot, ns, p, pend, stage, submitted) in pending {
            let solved = pend.wait()?;
            if let (Some(t), Some(k)) = (trace.as_mut(), stage) {
                t.root.children[k]
                    .set_wall("wait_us", submitted.elapsed().as_micros() as u64);
            }
            let best = select_best(&p, &solved);
            if let Some(r) = rec.as_deref_mut() {
                r.push(NodeRecord {
                    level,
                    slot,
                    node_seed: ns,
                    spin_hash: spin_hash(&solved),
                    energy_bits: best.result.objective.to_bits(),
                });
            }
            graph.complete(id, best.result.selected)?;
        }
    }
    let result = graph.into_result()?;
    let summary = finish(doc, sentences, &scores, cfg, result, total_solves);
    if let Some(t) = trace.as_mut() {
        t.score(&summary);
    }
    Ok(summary)
}

/// As [`summarize_with_pool`], but solving every unit inline on a
/// caller-owned solver — the per-worker sequential comparator for the
/// pooled path (and the `sched_pool` bench's baseline). Uses the identical
/// seed discipline: the `EsPipeline` quantization stream for rounding
/// draws, and the request-seed stream `PoolHandle::client(cfg.seed)` would
/// use for solve randomness. For a fixed (config, document) this produces
/// summaries byte-identical to the pooled path under ANY pool shape —
/// the determinism contract the byte-identity test pins down.
pub fn summarize_sequential(
    doc: &Document,
    cfg: &PipelineConfig,
    solver: &mut dyn PoolSolver,
) -> Result<Summary> {
    let mut embedder = HashEmbedder::new();
    summarize_sequential_using(doc, cfg, solver, &mut embedder)
}

/// As [`summarize_sequential`], with a caller-provided embedder (ignored
/// for `Strategy::Streaming` — see [`summarize_with_pool_using`]).
pub fn summarize_sequential_using(
    doc: &Document,
    cfg: &PipelineConfig,
    solver: &mut dyn PoolSolver,
    embedder: &mut dyn Embedder,
) -> Result<Summary> {
    seq_exec(doc, cfg, solver, embedder, None, None)
}

/// As [`summarize_sequential`], recording a request-scoped span tree
/// through `obs` (see [`summarize_with_pool_traced`] — same contract:
/// `None` span when recording is off, deterministic attributes
/// byte-identical to the pooled path's for the same (config, document)).
pub fn summarize_sequential_traced(
    doc: &Document,
    cfg: &PipelineConfig,
    solver: &mut dyn PoolSolver,
    obs: &ObsShared,
) -> Result<(Summary, Option<Span>)> {
    let mut embedder = HashEmbedder::new();
    summarize_sequential_traced_using(doc, cfg, solver, obs, &mut embedder)
}

/// As [`summarize_sequential_traced`], with a caller-provided embedder
/// (ignored for `Strategy::Streaming` — see
/// [`summarize_with_pool_using`]).
pub fn summarize_sequential_traced_using(
    doc: &Document,
    cfg: &PipelineConfig,
    solver: &mut dyn PoolSolver,
    obs: &ObsShared,
    embedder: &mut dyn Embedder,
) -> Result<(Summary, Option<Span>)> {
    let mut root = obs.start_request(&doc.id);
    let trace = root.as_mut().map(|r| Trace { obs, root: r });
    let summary = seq_exec(doc, cfg, solver, embedder, trace, None)?;
    Ok((summary, root))
}

/// As [`summarize_sequential`], additionally pushing one [`NodeRecord`]
/// per solve-DAG node into `rec`, in unit-id (submission) order — the
/// exact taps [`summarize_with_pool_recorded`] records for the same
/// (config, document), which is what lets the replay engine re-execute
/// a pooled recording on an inline solver and byte-compare node by
/// node. Streamed documents record no nodes.
pub fn summarize_sequential_recorded(
    doc: &Document,
    cfg: &PipelineConfig,
    solver: &mut dyn PoolSolver,
    rec: &mut Vec<NodeRecord>,
) -> Result<Summary> {
    let mut embedder = HashEmbedder::new();
    seq_exec(doc, cfg, solver, &mut embedder, None, Some(rec))
}

fn seq_exec(
    doc: &Document,
    cfg: &PipelineConfig,
    solver: &mut dyn PoolSolver,
    embedder: &mut dyn Embedder,
    mut trace: Option<Trace<'_>>,
    mut rec: Option<&mut Vec<NodeRecord>>,
) -> Result<Summary> {
    if cfg.strategy == Strategy::Streaming {
        if let Some(t) = trace.as_mut() {
            t.root.set("strategy", cfg.strategy.as_str());
        }
        let mut stream = StreamSummarizer::new(&doc.id, cfg)?;
        let mut route = StreamRoute::Inline(solver);
        stream.push_sentences(&doc.sentences, &mut route)?;
        return stream.revision(&mut route);
    }
    let n = doc.len().min(MAX_SENTENCES);
    ensure!(n >= cfg.summary_len, "document too short");
    let sentences = &doc.sentences[..n];
    let scores = embedder.scores(sentences).context("embedding failed")?;
    if let Some(t) = trace.as_mut() {
        t.preamble(n, cfg);
    }

    let params = cfg.decompose_params();
    let refine_cfg = cfg.refine_config();
    let per_node = cfg.strategy != Strategy::Window;
    let mut rng = Pcg32::new(cfg.seed, QUANT_STREAM);
    // per-request seeds drawn in unit-id order — exactly the draws a
    // PoolClient keyed by cfg.seed performs on its submits (window plan;
    // per-node plans derive each request seed from the unit's node seed)
    let mut seeds = Pcg32::new(cfg.seed, CLIENT_SEED_STREAM);

    let mut graph =
        SubproblemGraph::with_plan(n, DecomposePlan::new(cfg.strategy, &params)?)?;
    let mut total_solves = 0usize;
    while !graph.is_done() {
        let units = graph.take_ready();
        ensure!(!units.is_empty(), "scheduler stalled: no ready units");
        for u in &units {
            let sub = scores.subset(&u.window);
            let p = EsProblem {
                mu: sub.mu,
                beta: sub.beta,
                lambda: cfg.lambda,
                m: u.target,
            };
            let (instances, seed, ns) = if per_node {
                let ns = node_seed(cfg.seed, u.level, u.slot);
                (
                    prepare_instances(&p, &refine_cfg, &mut Pcg32::new(ns, QUANT_STREAM)),
                    request_seed(ns),
                    ns,
                )
            } else {
                (prepare_instances(&p, &refine_cfg, &mut rng), seeds.next_u64(), 0)
            };
            total_solves += instances.len();
            let stage = trace.as_mut().map(|t| t.solve_stage(u, instances.len()));
            let started = Instant::now();
            let solved = solver
                .solve_groups(&[SeededGroup {
                    instances: &instances,
                    seed,
                }])?
                .pop()
                .expect("one group in, one group out");
            if let (Some(t), Some(k)) = (trace.as_mut(), stage) {
                t.root.children[k]
                    .set_wall("solve_us", started.elapsed().as_micros() as u64);
            }
            let best = select_best(&p, &solved);
            if let Some(r) = rec.as_deref_mut() {
                r.push(NodeRecord {
                    level: u.level,
                    slot: u.slot,
                    node_seed: ns,
                    spin_hash: spin_hash(&solved),
                    energy_bits: best.result.objective.to_bits(),
                });
            }
            graph.complete(u.id, best.result.selected)?;
        }
    }
    let result = graph.into_result()?;
    let summary = finish(doc, sentences, &scores, cfg, result, total_solves);
    if let Some(t) = trace.as_mut() {
        t.score(&summary);
    }
    Ok(summary)
}

/// Shared tail of both executors: score the final selection on the
/// full-document problem (same as the inline pipeline) and assemble the
/// summary.
fn finish(
    doc: &Document,
    sentences: &[String],
    scores: &Scores,
    cfg: &PipelineConfig,
    result: crate::decompose::DecompositionResult,
    total_solves: usize,
) -> Summary {
    let full = EsProblem {
        mu: scores.mu.clone(),
        beta: scores.beta.clone(),
        lambda: cfg.lambda,
        m: cfg.summary_len,
    };
    let objective = full.objective(&result.selected);
    let stages = result.solves();
    Summary {
        doc_id: doc.id.clone(),
        sentences: result
            .selected
            .iter()
            .map(|&i| sentences[i].clone())
            .collect(),
        selected: result.selected,
        objective,
        total_solves,
        stages,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Settings;
    use crate::corpus::benchmark_set;
    use crate::sched::DevicePool;

    fn settings(solver: &str) -> Settings {
        let mut s = Settings::default();
        s.pipeline.solver = solver.into();
        s.pipeline.iterations = 3;
        s.sched.devices = 2;
        s
    }

    #[test]
    fn pooled_summarize_matches_stage_accounting() {
        let s = settings("cobi");
        let pool = DevicePool::start(&s, None).unwrap();
        for set_name in ["bench_10", "cnn_dm_20", "cnn_dm_50"] {
            let set = benchmark_set(set_name).unwrap();
            let mut cfg = s.pipeline.clone();
            cfg.summary_len = set.summary_len;
            let mut client = pool.client(crate::sched::doc_seed(cfg.seed, &set.documents[0].id));
            let summary = summarize_with_pool(&set.documents[0], &cfg, &mut client).unwrap();
            assert_eq!(summary.selected.len(), set.summary_len, "{set_name}");
            assert_eq!(
                summary.stages,
                crate::decompose::stage_count(set.doc_len(), &cfg.decompose_params()),
                "{set_name}"
            );
            assert!(summary.selected.windows(2).all(|w| w[0] < w[1]));
            assert!(summary.objective.is_finite());
        }
        pool.shutdown();
    }

    #[test]
    fn pooled_summarize_is_deterministic_across_pool_shapes() {
        // same doc + seed through a 1-device no-coalesce pool and a
        // 3-device coalescing pool under concurrent load: identical bytes
        let set = benchmark_set("cnn_dm_20").unwrap();
        let doc = &set.documents[2];

        let mut s1 = settings("cobi");
        s1.sched.devices = 1;
        s1.sched.max_coalesce = 1;
        s1.sched.linger_us = 0;
        let pool1 = DevicePool::start(&s1, None).unwrap();
        let seed = crate::sched::doc_seed(s1.pipeline.seed, &doc.id);
        let mut c1 = pool1.client(seed);
        let a = summarize_with_pool(doc, &s1.pipeline, &mut c1).unwrap();
        drop(c1);
        pool1.shutdown();

        let mut s2 = settings("cobi");
        s2.sched.devices = 3;
        s2.sched.max_coalesce = 8;
        s2.sched.linger_us = 2_000;
        let pool2 = DevicePool::start(&s2, None).unwrap();
        // background noise: other documents in flight on the same pool
        let handle = pool2.handle();
        let noise: Vec<_> = (0..3)
            .map(|k| {
                let handle = handle.clone();
                let d = set.documents[k].clone();
                let cfg = s2.pipeline.clone();
                std::thread::spawn(move || {
                    let mut c = handle.client(crate::sched::doc_seed(cfg.seed, &d.id));
                    summarize_with_pool(&d, &cfg, &mut c).unwrap()
                })
            })
            .collect();
        let mut c2 = pool2.client(seed);
        let b = summarize_with_pool(doc, &s2.pipeline, &mut c2).unwrap();
        for t in noise {
            t.join().unwrap();
        }
        drop(c2);
        drop(handle);
        pool2.shutdown();

        assert_eq!(a.selected, b.selected);
        assert_eq!(a.sentences, b.sentences);
        assert_eq!(a.objective.to_bits(), b.objective.to_bits());
    }

    #[test]
    fn span_pinned_form_is_byte_identical_across_pool_shapes() {
        // acceptance pin for the obs subsystem: the span tree's pinned
        // JSON (wall sections excluded) is byte-identical between a
        // 1-device no-coalesce pool and a 4-device coalescing pool under
        // concurrent noise — tracing observes determinism, never breaks it
        let set = benchmark_set("cnn_dm_20").unwrap();
        let doc = &set.documents[1];

        let trace_of = |devices: usize, coalesce: usize, linger: u64, noise_docs: usize| {
            let mut s = settings("cobi");
            s.obs.enabled = true;
            s.sched.devices = devices;
            s.sched.max_coalesce = coalesce;
            s.sched.linger_us = linger;
            let obs = crate::obs::ObsShared::from_settings(&s);
            let pool = DevicePool::start(&s, None).unwrap();
            let handle = pool.handle();
            let noise: Vec<_> = (0..noise_docs)
                .map(|k| {
                    let handle = handle.clone();
                    let d = set.documents[k + 2].clone();
                    let cfg = s.pipeline.clone();
                    std::thread::spawn(move || {
                        let mut c = handle.client(crate::sched::doc_seed(cfg.seed, &d.id));
                        summarize_with_pool(&d, &cfg, &mut c).unwrap()
                    })
                })
                .collect();
            let seed = crate::sched::doc_seed(s.pipeline.seed, &doc.id);
            let mut client = pool.client(seed);
            let (summary, span) =
                summarize_with_pool_traced(doc, &s.pipeline, &mut client, &obs).unwrap();
            for t in noise {
                t.join().unwrap();
            }
            drop(client);
            drop(handle);
            pool.shutdown();
            let span = span.expect("tracing enabled");
            // the full form must carry wall measurements...
            assert!(span.to_json(true).contains("wait_us"), "{devices} devices");
            // ...and the pinned form none
            (summary, span.to_json(false))
        };

        let (sum_a, pin_a) = trace_of(1, 1, 0, 0);
        let (sum_b, pin_b) = trace_of(4, 8, 2_000, 3);
        assert_eq!(sum_a.selected, sum_b.selected);
        assert_eq!(pin_a, pin_b, "pinned span trees diverged across pool shapes");
        assert!(pin_a.contains(r#""stage":"solve""#), "{pin_a}");
        assert!(pin_a.contains("modeled_j"), "{pin_a}");
        assert!(!pin_a.contains("wall"), "{pin_a}");
    }

    #[test]
    fn sequential_trace_matches_pooled_trace_pinned() {
        // the inline executor's pinned trace agrees byte for byte with
        // the pooled one for the same (config, document)
        let mut s = settings("cobi");
        s.obs.enabled = true;
        let set = benchmark_set("bench_10").unwrap();
        let doc = &set.documents[0];
        let mut cfg = s.pipeline.clone();
        cfg.summary_len = set.summary_len;
        cfg.seed = crate::sched::doc_seed(cfg.seed, &doc.id);
        let obs = crate::obs::ObsShared::from_settings(&s);

        let pool = DevicePool::start(&s, None).unwrap();
        let mut client = pool.client(cfg.seed);
        let (_, pooled) =
            summarize_with_pool_traced(doc, &cfg, &mut client, &obs).unwrap();
        drop(client);
        pool.shutdown();

        let mut dev = crate::cobi::CobiDevice::from_config(&s.cobi, 0, None).unwrap();
        let (_, seq) = summarize_sequential_traced(doc, &cfg, &mut dev, &obs).unwrap();

        assert_eq!(
            pooled.unwrap().to_json(false),
            seq.unwrap().to_json(false),
            "pooled and sequential pinned traces diverged"
        );
        assert_eq!(obs.snapshot().recorded, 0, "executors do not self-record");
    }

    #[test]
    fn tree_strategy_pooled_matches_sequential_bytewise() {
        // per-node seeding makes the pooled and inline tree walks agree
        // byte for byte, exactly like the window path's pin
        let mut s = settings("cobi");
        s.pipeline.strategy = Strategy::Tree;
        let set = benchmark_set("cnn_dm_50").unwrap();
        let pool = DevicePool::start(&s, None).unwrap();
        for doc in set.documents.iter().take(3) {
            let mut cfg = s.pipeline.clone();
            cfg.summary_len = set.summary_len;
            cfg.seed = crate::sched::doc_seed(cfg.seed, &doc.id);

            let mut client = pool.client(cfg.seed);
            let pooled = summarize_with_pool(doc, &cfg, &mut client).unwrap();

            let mut dev =
                crate::cobi::CobiDevice::from_config(&s.cobi, 0, None).unwrap();
            let sequential = summarize_sequential(doc, &cfg, &mut dev).unwrap();

            assert_eq!(pooled.selected, sequential.selected, "{}", doc.id);
            assert_eq!(pooled.sentences, sequential.sentences, "{}", doc.id);
            assert_eq!(
                pooled.objective.to_bits(),
                sequential.objective.to_bits(),
                "{}",
                doc.id
            );
            assert_eq!(pooled.total_solves, sequential.total_solves);
            assert_eq!(pooled.stages, sequential.stages);
        }
        pool.shutdown();
    }

    #[test]
    fn tree_strategy_is_deterministic_across_pool_shapes() {
        // acceptance pin: Tree summaries are independent of the pool's
        // device count, coalescing, and concurrent load
        let set = benchmark_set("cnn_dm_50").unwrap();
        let doc = &set.documents[1];

        let mut s1 = settings("cobi");
        s1.pipeline.strategy = Strategy::Tree;
        s1.sched.devices = 1;
        s1.sched.max_coalesce = 1;
        s1.sched.linger_us = 0;
        let pool1 = DevicePool::start(&s1, None).unwrap();
        let mut cfg = s1.pipeline.clone();
        cfg.seed = crate::sched::doc_seed(cfg.seed, &doc.id);
        let mut c1 = pool1.client(cfg.seed);
        let a = summarize_with_pool(doc, &cfg, &mut c1).unwrap();
        drop(c1);
        pool1.shutdown();

        let mut s2 = settings("cobi");
        s2.pipeline.strategy = Strategy::Tree;
        s2.sched.devices = 4;
        s2.sched.max_coalesce = 8;
        s2.sched.linger_us = 2_000;
        let pool2 = DevicePool::start(&s2, None).unwrap();
        let handle = pool2.handle();
        let noise: Vec<_> = (2..5)
            .map(|k| {
                let handle = handle.clone();
                let d = set.documents[k].clone();
                let mut cfg = s2.pipeline.clone();
                std::thread::spawn(move || {
                    cfg.seed = crate::sched::doc_seed(cfg.seed, &d.id);
                    let mut c = handle.client(cfg.seed);
                    summarize_with_pool(&d, &cfg, &mut c).unwrap()
                })
            })
            .collect();
        let mut c2 = pool2.client(cfg.seed);
        let b = summarize_with_pool(doc, &cfg, &mut c2).unwrap();
        for t in noise {
            t.join().unwrap();
        }
        drop(c2);
        drop(handle);
        pool2.shutdown();

        assert_eq!(a.selected, b.selected);
        assert_eq!(a.sentences, b.sentences);
        assert_eq!(a.objective.to_bits(), b.objective.to_bits());
        assert_eq!(a.total_solves, b.total_solves);
    }

    #[test]
    fn stream_strategy_flows_through_both_executors() {
        // a stream-strategy document takes the StreamSummarizer path in
        // both executors and agrees byte for byte across them
        let mut s = settings("tabu");
        s.pipeline.strategy = Strategy::Streaming;
        let set = benchmark_set("cnn_dm_20").unwrap();
        let doc = &set.documents[0];
        let mut cfg = s.pipeline.clone();
        cfg.seed = crate::sched::doc_seed(cfg.seed, &doc.id);

        let pool = DevicePool::start(&s, None).unwrap();
        let mut client = pool.client(cfg.seed);
        let pooled = summarize_with_pool(doc, &cfg, &mut client).unwrap();
        drop(client);
        pool.shutdown();

        let mut solver = crate::solvers::tabu::TabuSolver::seeded(0);
        let sequential = summarize_sequential(doc, &cfg, &mut solver).unwrap();

        assert_eq!(pooled.selected.len(), cfg.summary_len);
        assert_eq!(pooled.selected, sequential.selected);
        assert_eq!(pooled.sentences, sequential.sentences);
        assert_eq!(pooled.objective.to_bits(), sequential.objective.to_bits());
    }

    #[test]
    fn pooled_path_is_byte_identical_to_sequential_path_on_bench_10() {
        // acceptance criterion: determinism preserved through batching —
        // the shared pool (2 devices, coalescing) and an inline
        // per-worker device must produce byte-identical summaries for
        // every bench_10 document under a fixed seed.
        let s = settings("cobi");
        let set = benchmark_set("bench_10").unwrap();
        let pool = DevicePool::start(&s, None).unwrap();
        for doc in &set.documents {
            let mut cfg = s.pipeline.clone();
            cfg.summary_len = set.summary_len;
            cfg.seed = crate::sched::doc_seed(cfg.seed, &doc.id);

            let mut client = pool.client(cfg.seed);
            let pooled = summarize_with_pool(doc, &cfg, &mut client).unwrap();

            // construction seed 0: the seeded path never touches the
            // device-global RNG, so it must not matter
            let mut dev =
                crate::cobi::CobiDevice::from_config(&s.cobi, 0, None).unwrap();
            let sequential = summarize_sequential(doc, &cfg, &mut dev).unwrap();

            assert_eq!(pooled.selected, sequential.selected, "{}", doc.id);
            assert_eq!(pooled.sentences, sequential.sentences, "{}", doc.id);
            assert_eq!(
                pooled.objective.to_bits(),
                sequential.objective.to_bits(),
                "{}",
                doc.id
            );
            assert_eq!(pooled.total_solves, sequential.total_solves);
            assert_eq!(pooled.stages, sequential.stages);
        }
        pool.shutdown();
    }

    #[test]
    fn recorded_node_taps_match_between_pooled_and_sequential() {
        // the flight-recorder taps are part of the determinism contract:
        // per-node (level, slot, seed, spin hash, energy bits) agree
        // byte for byte between the pooled and inline executors, under
        // both the window plan (node seed 0) and the tree plan
        for strategy in [Strategy::Window, Strategy::Tree] {
            let mut s = settings("cobi");
            s.pipeline.strategy = strategy;
            s.obs.enabled = false;
            let set = benchmark_set("bench_10").unwrap();
            let doc = &set.documents[0];
            let mut cfg = s.pipeline.clone();
            cfg.summary_len = set.summary_len;
            cfg.seed = crate::sched::doc_seed(cfg.seed, &doc.id);
            let obs = crate::obs::ObsShared::disabled();

            let pool = DevicePool::start(&s, None).unwrap();
            let mut client = pool.client(cfg.seed);
            let mut pooled_nodes = Vec::new();
            let (pooled, span) =
                summarize_with_pool_recorded(doc, &cfg, &mut client, &obs, &mut pooled_nodes)
                    .unwrap();
            assert!(span.is_none(), "obs disabled");
            drop(client);
            pool.shutdown();

            let mut dev = crate::cobi::CobiDevice::from_config(&s.cobi, 0, None).unwrap();
            let mut seq_nodes = Vec::new();
            let sequential =
                summarize_sequential_recorded(doc, &cfg, &mut dev, &mut seq_nodes).unwrap();

            assert_eq!(pooled.selected, sequential.selected, "{strategy:?}");
            assert!(!pooled_nodes.is_empty(), "{strategy:?}");
            assert_eq!(pooled_nodes, seq_nodes, "{strategy:?} taps diverged");
            if strategy == Strategy::Window {
                assert!(pooled_nodes.iter().all(|n| n.node_seed == 0));
            } else {
                assert!(pooled_nodes.iter().any(|n| n.node_seed != 0));
            }
            assert!(pooled_nodes.iter().all(|n| {
                f64::from_bits(n.energy_bits).is_finite()
            }));
        }
    }
}
