//! `SubproblemGraph`: the decomposition workflow (paper §IV-B) replayed
//! as a small DAG of solve units instead of an inline sequential loop.
//!
//! Structure: the graph is built level by level, with each level's units
//! carved by a [`DecomposePlan`] — windows share no sentences, so they
//! are independent and may be solved concurrently or co-batched on a
//! device in any order. Levels chain: the merge of level k's survivors +
//! chosen sentences forms level k+1's active list, so the next level's
//! windows only exist once the previous level fully completes. The final
//! level is always a single M-selection unit over the remaining ≤ P
//! sentences.
//!
//! With the default [`Strategy::Window`] plan the carving solves exactly
//! as many window subproblems as the inline `decompose` loop (each
//! non-final solve removes P−Q sentences; both stop shrinking once ≤ P
//! remain), so `stage_count` stays the shared source of truth for
//! solve-count accounting. Window *contents* may differ from the inline
//! loop's cursor walk for multi-window levels — the two are distinct
//! scheduling policies over the same reduction. For single-stage
//! documents (N ≤ P) the graph is exactly the inline final solve, which
//! is what the byte-identity tests pin down. [`Strategy::Tree`] carves
//! balanced leaves covering every active sentence, trading solve-count
//! parity for maximal same-level parallelism and O(log N) depth.

use std::collections::HashMap;

use anyhow::{bail, ensure, Context, Result};

use crate::decompose::{
    validate_local, DecomposeParams, DecomposePlan, DecompositionResult, Stage, Strategy,
};

/// One ready-to-solve subproblem: choose `target` of `window`.
#[derive(Debug, Clone)]
pub struct SolveUnit {
    /// Graph-unique id (handed back to [`SubproblemGraph::complete`]).
    pub id: usize,
    /// DAG level (0-based pass index).
    pub level: usize,
    /// Position within the level (0-based). `(level, slot)` is the unit's
    /// stable tree position — the input to per-node seeding
    /// ([`crate::decompose::node_seed`]) under `Tree`/`Streaming` plans.
    pub slot: usize,
    /// Original-document sentence indices offered to the solver.
    pub window: Vec<usize>,
    /// Number of window positions the solver must return (Q, or M for the
    /// final unit).
    pub target: usize,
    /// True for the final M-selection unit.
    pub is_final: bool,
}

/// Dynamic DAG of decomposition subproblems for one document.
pub struct SubproblemGraph {
    plan: DecomposePlan,
    /// Active sentence indices (document order) feeding the current level.
    active: Vec<usize>,
    level: usize,
    /// Built units not yet handed out.
    ready: Vec<SolveUnit>,
    /// Handed out, awaiting completion.
    inflight: HashMap<usize, SolveUnit>,
    /// Completed units of the CURRENT level (unit, chosen original idx).
    level_done: Vec<(SolveUnit, Vec<usize>)>,
    /// Full trace in unit-id order, decompose-compatible.
    stages: Vec<Stage>,
    next_id: usize,
    /// Final selection once the final unit completes.
    selected: Option<Vec<usize>>,
}

impl SubproblemGraph {
    /// Plan the level-0 units for a document of `n` sentences under the
    /// reference [`Strategy::Window`] plan (the pre-plan behavior, pinned
    /// byte-identical by the executor tests).
    pub fn new(n: usize, params: &DecomposeParams) -> Result<Self> {
        Self::with_plan(n, DecomposePlan::new(Strategy::Window, params)?)
    }

    /// Plan the level-0 units for a document of `n` sentences, with the
    /// level carving delegated to `plan` (window or tree;
    /// `Strategy::Streaming` documents replayed whole degrade to the
    /// window carving — incremental arrival wants
    /// [`StreamingPlanner`](crate::decompose::StreamingPlanner) instead).
    pub fn with_plan(n: usize, plan: DecomposePlan) -> Result<Self> {
        ensure!(
            n >= plan.params().m,
            "document of {n} sentences cannot fill M={}",
            plan.params().m
        );
        let mut g = Self {
            plan,
            active: (0..n).collect(),
            level: 0,
            ready: Vec::new(),
            inflight: HashMap::new(),
            level_done: Vec::new(),
            stages: Vec::new(),
            next_id: 0,
            selected: None,
        };
        g.build_level();
        Ok(g)
    }

    /// The plan carving this graph's levels.
    pub fn plan(&self) -> &DecomposePlan {
        &self.plan
    }

    /// Carve the current active list into this level's units via the
    /// plan. The shrink rule mirrors the `stage_count` recurrence: the
    /// level-0 carving is unconditional at n == P; later levels shrink
    /// only while more than P remain (enforced inside
    /// [`DecomposePlan::carve`]).
    fn build_level(&mut self) {
        debug_assert!(self.ready.is_empty() && self.inflight.is_empty());
        for (slot, unit) in self
            .plan
            .carve(&self.active, self.level)
            .into_iter()
            .enumerate()
        {
            self.ready.push(SolveUnit {
                id: self.next_id,
                level: self.level,
                slot,
                window: unit.window,
                target: unit.target,
                is_final: unit.is_final,
            });
            self.next_id += 1;
        }
        debug_assert!(!self.ready.is_empty(), "plan carved an empty level");
    }

    /// Hand out every currently ready unit (all independent — disjoint
    /// windows of one level). Returned units must each be answered via
    /// [`SubproblemGraph::complete`]; the next level only materializes
    /// once all of them are in.
    pub fn take_ready(&mut self) -> Vec<SolveUnit> {
        let units = std::mem::take(&mut self.ready);
        for u in &units {
            self.inflight.insert(u.id, u.clone());
        }
        units
    }

    /// Report unit `id` solved: `local` holds `target` distinct positions
    /// INTO the unit's window (the `decompose` solver contract). When the
    /// last unit of a level lands, survivors and chosen sentences merge
    /// (document order) and the next level's units become ready.
    pub fn complete(&mut self, id: usize, local: Vec<usize>) -> Result<()> {
        {
            let unit = self
                .inflight
                .get(&id)
                .with_context(|| format!("unit {id} is not in flight"))?;
            // validate before consuming the in-flight slot, so a rejected
            // answer can be retried
            validate_local(&local, unit.window.len(), unit.target)?;
        }
        let unit = self.inflight.remove(&id).expect("checked above");
        let chosen: Vec<usize> = local.iter().map(|&l| unit.window[l]).collect();

        if unit.is_final {
            let mut selected = chosen.clone();
            selected.sort_unstable();
            self.stages.push(Stage {
                window: unit.window.clone(),
                chosen: selected.clone(),
                is_final: true,
            });
            self.selected = Some(selected);
            return Ok(());
        }

        self.level_done.push((unit, chosen));
        if self.inflight.is_empty() && self.ready.is_empty() {
            self.advance_level();
        }
        Ok(())
    }

    /// Merge the finished level into the next active list and build the
    /// next level. Stages are recorded in unit-id (submission) order so
    /// the trace is deterministic regardless of completion order.
    fn advance_level(&mut self) {
        let mut done = std::mem::take(&mut self.level_done);
        done.sort_by_key(|(u, _)| u.id);

        let mut in_window = std::collections::HashSet::new();
        let mut chosen_all: Vec<usize> = Vec::new();
        for (unit, chosen) in &done {
            in_window.extend(unit.window.iter().copied());
            chosen_all.extend(chosen.iter().copied());
            self.stages.push(Stage {
                window: unit.window.clone(),
                chosen: chosen.clone(),
                is_final: false,
            });
        }
        let mut next: Vec<usize> = self
            .active
            .iter()
            .copied()
            .filter(|i| !in_window.contains(i))
            .chain(chosen_all)
            .collect();
        next.sort_unstable();
        self.active = next;
        self.level += 1;
        self.build_level();
    }

    /// True once the final M-selection completed.
    pub fn is_done(&self) -> bool {
        self.selected.is_some()
    }

    /// Number of levels materialized so far (including the in-progress one).
    pub fn levels(&self) -> usize {
        self.level + 1
    }

    /// Total units handed out so far.
    pub fn units_issued(&self) -> usize {
        self.next_id
    }

    /// Consume the graph into a decompose-compatible result.
    pub fn into_result(self) -> Result<DecompositionResult> {
        match self.selected {
            Some(selected) => Ok(DecompositionResult {
                selected,
                stages: self.stages,
            }),
            None => bail!(
                "graph not finished: {} in flight, {} ready",
                self.inflight.len(),
                self.ready.len()
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decompose::stage_count;

    /// Toy solver matching decompose's tests: keep the positions with the
    /// largest original index.
    fn top_indices(window: &[usize], target: usize) -> Vec<usize> {
        let mut pos: Vec<usize> = (0..window.len()).collect();
        pos.sort_by_key(|&p| std::cmp::Reverse(window[p]));
        pos.truncate(target);
        pos
    }

    /// Drive a graph to completion with the toy solver.
    fn run(n: usize, params: &DecomposeParams) -> DecompositionResult {
        let mut g = SubproblemGraph::new(n, params).unwrap();
        while !g.is_done() {
            let units = g.take_ready();
            assert!(!units.is_empty(), "stalled");
            for u in units {
                let local = top_indices(&u.window, u.target);
                g.complete(u.id, local).unwrap();
            }
        }
        g.into_result().unwrap()
    }

    #[test]
    fn graph_solve_counts_match_stage_count() {
        let params = DecomposeParams::paper_default();
        for n in [10usize, 20, 21, 35, 50, 100, 128] {
            let r = run(n, &params);
            assert_eq!(r.solves(), stage_count(n, &params), "n={n}");
            assert_eq!(r.selected.len(), params.m, "n={n}");
            assert!(r.selected.windows(2).all(|w| w[0] < w[1]), "n={n}");
            assert!(r.selected.iter().all(|&i| i < n), "n={n}");
        }
    }

    #[test]
    fn single_stage_document_is_one_final_unit() {
        // N ≤ P: the graph must be exactly the inline final solve
        let params = DecomposeParams::paper_default();
        let mut g = SubproblemGraph::new(10, &params).unwrap();
        let units = g.take_ready();
        assert_eq!(units.len(), 1);
        assert!(units[0].is_final);
        assert_eq!(units[0].window, (0..10).collect::<Vec<_>>());
        assert_eq!(units[0].target, 6);
        g.complete(units[0].id, top_indices(&units[0].window, 6))
            .unwrap();
        assert!(g.is_done());
    }

    #[test]
    fn n_equals_p_is_unconditional_first_window() {
        let params = DecomposeParams { p: 20, q: 10, m: 6 };
        let r = run(20, &params);
        assert_eq!(r.solves(), 2); // 20 -> 10 -> 6
        assert!(!r.stages[0].is_final);
        assert_eq!(r.stages[0].window.len(), 20);
        assert!(r.stages[1].is_final);
        assert_eq!(r.stages[1].window.len(), 10);
    }

    #[test]
    fn level_windows_are_disjoint_and_consecutive() {
        let params = DecomposeParams { p: 8, q: 4, m: 3 };
        let mut g = SubproblemGraph::new(30, &params).unwrap();
        let units = g.take_ready();
        assert_eq!(units.len(), 3); // 30 / 8
        let mut seen = std::collections::HashSet::new();
        for u in &units {
            assert_eq!(u.window.len(), 8);
            assert!(u.window.windows(2).all(|w| w[1] == w[0] + 1), "consecutive");
            for &i in &u.window {
                assert!(seen.insert(i), "windows overlap at {i}");
            }
        }
    }

    #[test]
    fn completion_order_does_not_change_the_merge() {
        let params = DecomposeParams { p: 6, q: 3, m: 2 };
        fn solve(mut g: SubproblemGraph, reverse: bool) -> DecompositionResult {
            while !g.is_done() {
                let mut units = g.take_ready();
                if reverse {
                    units.reverse();
                }
                for u in units {
                    g.complete(u.id, top_indices(&u.window, u.target)).unwrap();
                }
            }
            g.into_result().unwrap()
        }
        let ra = solve(SubproblemGraph::new(25, &params).unwrap(), false);
        let rb = solve(SubproblemGraph::new(25, &params).unwrap(), true);
        assert_eq!(ra.selected, rb.selected);
        assert_eq!(ra.solves(), rb.solves());
        assert_eq!(
            ra.stages.iter().map(|s| s.window.clone()).collect::<Vec<_>>(),
            rb.stages.iter().map(|s| s.window.clone()).collect::<Vec<_>>(),
        );
    }

    #[test]
    fn q_equals_m_final_stage() {
        // Q == M: final unit still runs over the merged ≤ P sentences
        let params = DecomposeParams { p: 6, q: 3, m: 3 };
        let r = run(14, &params);
        assert_eq!(r.selected.len(), 3);
        let last = r.stages.last().unwrap();
        assert!(last.is_final);
        assert!(last.window.len() <= 6);
        assert_eq!(last.chosen.len(), 3);
    }

    #[test]
    fn bad_completions_are_rejected() {
        let params = DecomposeParams { p: 5, q: 2, m: 2 };
        let mut g = SubproblemGraph::new(12, &params).unwrap();
        let units = g.take_ready();
        let u = &units[0];
        // unknown id
        assert!(g.complete(999, vec![0, 1]).is_err());
        // wrong count / duplicates / out of range are rejected...
        assert!(g.complete(u.id, vec![0]).is_err());
        assert!(g.complete(u.id, vec![1, 1]).is_err());
        assert!(g.complete(u.id, vec![0, u.window.len()]).is_err());
        // ...without consuming the in-flight slot: a valid retry lands
        assert!(g.complete(u.id, vec![0, 1]).is_ok());
    }

    #[test]
    fn degenerate_params_rejected() {
        assert!(SubproblemGraph::new(4, &DecomposeParams { p: 5, q: 2, m: 6 }).is_err());
        assert!(SubproblemGraph::new(20, &DecomposeParams { p: 5, q: 5, m: 2 }).is_err());
    }

    /// Drive a tree-plan graph to completion with the toy solver.
    fn run_tree(n: usize, params: &DecomposeParams) -> DecompositionResult {
        let plan = DecomposePlan::new(Strategy::Tree, params).unwrap();
        let mut g = SubproblemGraph::with_plan(n, plan).unwrap();
        while !g.is_done() {
            let units = g.take_ready();
            assert!(!units.is_empty(), "stalled");
            for u in units {
                let local = top_indices(&u.window, u.target);
                g.complete(u.id, local).unwrap();
            }
        }
        g.into_result().unwrap()
    }

    #[test]
    fn tree_plan_completes_with_valid_selection() {
        let params = DecomposeParams::paper_default();
        for n in [10usize, 20, 21, 45, 100, 128, 500] {
            let r = run_tree(n, &params);
            assert_eq!(r.selected.len(), params.m, "n={n}");
            assert!(r.selected.windows(2).all(|w| w[0] < w[1]), "n={n}");
            assert!(r.selected.iter().all(|&i| i < n), "n={n}");
        }
    }

    #[test]
    fn tree_levels_cover_every_active_sentence() {
        // unlike the window carving (which leaves a `len mod P` tail
        // idle), every tree level's windows partition the active list
        let params = DecomposeParams::paper_default();
        let plan = DecomposePlan::new(Strategy::Tree, &params).unwrap();
        let mut g = SubproblemGraph::with_plan(105, plan).unwrap();
        let units = g.take_ready();
        let covered: usize = units.iter().map(|u| u.window.len()).sum();
        assert_eq!(covered, 105);
        assert_eq!(units.len(), 6); // ceil(105/20) balanced leaves
        for (slot, u) in units.iter().enumerate() {
            assert_eq!(u.slot, slot);
            assert_eq!(u.level, 0);
        }
        drop(g);

        // window carving on the same document: 5 full windows, 5 idle
        let mut g = SubproblemGraph::new(105, &params).unwrap();
        let units = g.take_ready();
        assert_eq!(units.len(), 5);
        let covered: usize = units.iter().map(|u| u.window.len()).sum();
        assert_eq!(covered, 100);
        drop(g);
    }

    #[test]
    fn tree_depth_is_logarithmic() {
        // 500 sentences, paper params: leaves shrink ~2x per level, so
        // the tree finishes in a handful of fully parallel levels
        let params = DecomposeParams::paper_default();
        let plan = DecomposePlan::new(Strategy::Tree, &params).unwrap();
        let mut g = SubproblemGraph::with_plan(500, plan).unwrap();
        let mut levels = 0usize;
        let mut max_width = 0usize;
        while !g.is_done() {
            let units = g.take_ready();
            levels += 1;
            max_width = max_width.max(units.len());
            for u in units {
                let local = top_indices(&u.window, u.target);
                g.complete(u.id, local).unwrap();
            }
        }
        assert!(levels <= 6, "tree took {levels} levels for 500 sentences");
        assert!(max_width >= 25, "level-0 width {max_width} not parallel");
        // the window plan needs strictly more, narrower levels here
        let mut g = SubproblemGraph::new(500, &params).unwrap();
        let mut win_levels = 0usize;
        while !g.is_done() {
            let units = g.take_ready();
            win_levels += 1;
            for u in units {
                let local = top_indices(&u.window, u.target);
                g.complete(u.id, local).unwrap();
            }
        }
        assert!(win_levels >= levels, "window {win_levels} vs tree {levels}");
    }

    #[test]
    fn tree_completion_order_does_not_change_the_merge() {
        let params = DecomposeParams { p: 6, q: 3, m: 2 };
        fn solve(mut g: SubproblemGraph, reverse: bool) -> DecompositionResult {
            while !g.is_done() {
                let mut units = g.take_ready();
                if reverse {
                    units.reverse();
                }
                for u in units {
                    g.complete(u.id, top_indices(&u.window, u.target)).unwrap();
                }
            }
            g.into_result().unwrap()
        }
        let plan = || DecomposePlan::new(Strategy::Tree, &params).unwrap();
        let ra = solve(SubproblemGraph::with_plan(40, plan()).unwrap(), false);
        let rb = solve(SubproblemGraph::with_plan(40, plan()).unwrap(), true);
        assert_eq!(ra.selected, rb.selected);
        assert_eq!(
            ra.stages.iter().map(|s| s.window.clone()).collect::<Vec<_>>(),
            rb.stages.iter().map(|s| s.window.clone()).collect::<Vec<_>>(),
        );
    }
}
