//! Streaming summarization executor: sentences in, summary revisions out.
//!
//! [`StreamSummarizer`] drives the incremental
//! [`StreamingPlanner`](crate::decompose::StreamingPlanner) with real
//! embeddings and real solves. It is the engine behind the service's
//! `SUMMARIZE_STREAM` sessions and the `stream` decompose strategy:
//!
//!   * sentences arrive in chunks of any size ([`push_text`] /
//!     [`push_sentences`](StreamSummarizer::push_sentences)); the
//!     executor un-batches them, embeds each sentence once
//!     (incremental hash embedding + a running document centroid), and
//!     lets the planner fire a compression whenever the rolling frontier
//!     fills to P;
//!   * only the frontier is ever re-solved — compressed-away sentences
//!     keep O(P) state no matter how long the feed runs (thousands of
//!     sentences stream in constant memory, beyond the batch paths'
//!     `MAX_SENTENCES` clamp);
//!   * a [`revision`](StreamSummarizer::revision) solves the final
//!     M-selection over the current frontier without mutating stream
//!     state — call it after every chunk for live summary updates.
//!
//! Determinism: every solve node (compression `seq`, or a revision at
//! arrival count `t`) derives its rounding stream and request seed from
//! [`node_seed`](crate::decompose::node_seed) — a pure function of the
//! config seed and the node's position in the arrival order. Combined
//! with the planner's count-based trigger this makes every revision (and
//! the final summary) byte-identical regardless of how the feed was
//! chunked, which pool shape solved it, or whether it ran inline —
//! pinned by the tests below.
//!
//! Successive revisions differ by a few frontier rows, which is exactly
//! the shape the portfolio's warm-start cache near-tiers exploit when
//! the pool routes through `[portfolio] enabled = true`.
//!
//! [`push_text`]: StreamSummarizer::push_text

use std::collections::BTreeMap;

use anyhow::{ensure, Context, Result};

use crate::cobi::SeededGroup;
use crate::config::PipelineConfig;
use crate::decompose::{
    node_seed, CompressUnit, DecomposeParams, StreamingPlanner, STREAM_COMPRESS_LEVEL,
    STREAM_REVISION_LEVEL,
};
use crate::embed::hash_embed::HashEmbedder;
use crate::embed::similarity::{dot, norm};
use crate::ising::{EsProblem, Ising};
use crate::pipeline::Summary;
use crate::refine::{prepare_instances, select_best, RefineConfig};
use crate::solvers::SolveResult;
use crate::text::split_sentences;
use crate::util::rng::Pcg32;

use super::pool::{PoolClient, PoolSolver};
use super::{request_seed, QUANT_STREAM};

/// Where a stream session's Ising solves run: the shared device pool
/// (service sessions) or an inline caller-owned solver (tests, the
/// sequential comparator). Both routes produce byte-identical results
/// for the same request seed (decision #8).
pub enum StreamRoute<'a> {
    /// Solves submitted to the shared [`DevicePool`](super::DevicePool)
    /// through a per-document client.
    Pooled(&'a mut PoolClient),
    /// Solves run inline on a caller-owned pool-capable solver.
    Inline(&'a mut dyn PoolSolver),
}

impl StreamRoute<'_> {
    fn solve(&mut self, instances: Vec<Ising>, seed: u64) -> Result<Vec<SolveResult>> {
        match self {
            StreamRoute::Pooled(client) => client.submit_seeded(instances, seed)?.wait(),
            StreamRoute::Inline(solver) => Ok(solver
                .solve_groups(&[SeededGroup {
                    instances: &instances,
                    seed,
                }])?
                .pop()
                .expect("one group in, one group out")),
        }
    }
}

/// A frontier sentence: its text plus its unit-normalized embedding.
struct ActiveSentence {
    text: String,
    unit: Vec<f32>,
}

/// Incremental summarizer over an arriving sentence feed (module docs).
pub struct StreamSummarizer {
    doc_id: String,
    cfg: PipelineConfig,
    refine_cfg: RefineConfig,
    planner: StreamingPlanner,
    embedder: HashEmbedder,
    /// Frontier sentences keyed by original index (= arrival order).
    active: BTreeMap<usize, ActiveSentence>,
    /// Running sum of every arrived sentence's RAW embedding — the same
    /// accumulation order `scores_from_embeddings` uses, so causal mu
    /// scores match a batch computation over the arrived prefix bit for
    /// bit.
    centroid: Vec<f32>,
    total_solves: usize,
    revisions: usize,
}

impl StreamSummarizer {
    /// Open a stream for `doc_id` under `cfg` (strategy-independent: the
    /// caller already chose streaming by constructing this).
    pub fn new(doc_id: &str, cfg: &PipelineConfig) -> Result<Self> {
        let params: DecomposeParams = cfg.decompose_params();
        Ok(Self {
            doc_id: doc_id.to_string(),
            cfg: cfg.clone(),
            refine_cfg: cfg.refine_config(),
            planner: StreamingPlanner::new(&params)?,
            embedder: HashEmbedder::new(),
            active: BTreeMap::new(),
            centroid: Vec::new(),
            total_solves: 0,
            revisions: 0,
        })
    }

    /// Feed a chunk of raw text (sentence-split internally). Returns the
    /// number of sentences ingested.
    pub fn push_text(&mut self, text: &str, route: &mut StreamRoute<'_>) -> Result<usize> {
        let sentences = split_sentences(text);
        let n = sentences.len();
        self.push_sentences(&sentences, route)?;
        Ok(n)
    }

    /// Feed a chunk of already-split sentences. Chunk boundaries carry no
    /// meaning: any grouping of the same sentence sequence leaves the
    /// stream in an identical state (module docs).
    pub fn push_sentences(
        &mut self,
        sentences: &[String],
        route: &mut StreamRoute<'_>,
    ) -> Result<()> {
        for s in sentences {
            let raw = self.embedder.embed_sentence(s);
            if self.centroid.is_empty() {
                self.centroid = vec![0.0; raw.len()];
            }
            for (c, r) in self.centroid.iter_mut().zip(&raw) {
                *c += r;
            }
            let nn = norm(&raw).max(1e-12);
            let unit: Vec<f32> = raw.iter().map(|v| v / nn).collect();
            let idx = self.planner.arrived();
            self.active.insert(
                idx,
                ActiveSentence {
                    text: s.clone(),
                    unit,
                },
            );
            if let Some(unit) = self.planner.push()? {
                self.compress(unit, route)
                    .with_context(|| format!("compressing stream {}", self.doc_id))?;
            }
        }
        Ok(())
    }

    /// Solve one due compression and shrink the frontier to its
    /// survivors.
    fn compress(&mut self, unit: CompressUnit, route: &mut StreamRoute<'_>) -> Result<()> {
        let p = self.window_problem(&unit.window, unit.target);
        let ns = node_seed(self.cfg.seed, STREAM_COMPRESS_LEVEL, unit.seq);
        let instances =
            prepare_instances(&p, &self.refine_cfg, &mut Pcg32::new(ns, QUANT_STREAM));
        self.total_solves += instances.len();
        let solved = route.solve(instances, request_seed(ns))?;
        let trace = select_best(&p, &solved);
        self.planner.complete(&unit, &trace.result.selected)?;
        // evict compressed-away sentences: state stays O(P)
        let keep: std::collections::BTreeSet<usize> =
            self.planner.frontier().iter().copied().collect();
        self.active.retain(|idx, _| keep.contains(idx));
        Ok(())
    }

    /// Solve the final M-selection over the current frontier and return
    /// the summary revision. Never mutates frontier state, so a revision
    /// at arrival count `t` is identical no matter how many earlier
    /// revisions were requested — and two streams that received the same
    /// `t` sentences (in any chunking) revise identically.
    pub fn revision(&mut self, route: &mut StreamRoute<'_>) -> Result<Summary> {
        ensure!(
            self.planner.can_summarize(),
            "stream of {} sentences cannot fill a {}-sentence summary yet",
            self.planner.arrived(),
            self.cfg.summary_len
        );
        let frontier: Vec<usize> = self.planner.frontier().to_vec();
        let p = self.window_problem(&frontier, self.cfg.summary_len);
        let ns = node_seed(self.cfg.seed, STREAM_REVISION_LEVEL, self.planner.arrived());
        let instances =
            prepare_instances(&p, &self.refine_cfg, &mut Pcg32::new(ns, QUANT_STREAM));
        self.total_solves += instances.len();
        let solved = route.solve(instances, request_seed(ns))?;
        let trace = select_best(&p, &solved);
        self.revisions += 1;

        let mut local = trace.result.selected.clone();
        local.sort_unstable();
        let selected: Vec<usize> = local.iter().map(|&l| frontier[l]).collect();
        Ok(Summary {
            doc_id: self.doc_id.clone(),
            sentences: selected
                .iter()
                .map(|&i| self.active[&i].text.clone())
                .collect(),
            selected,
            // scored on the FRONTIER problem: the full-document objective
            // of the batch paths has no causal analogue once early
            // sentences are compressed away
            objective: trace.result.objective,
            total_solves: self.total_solves,
            stages: self.planner.compressions() + 1,
        })
    }

    /// Relevance/redundancy scores for `window` (frontier members),
    /// causal at the current arrival count: mu against the running
    /// centroid over every arrived sentence, beta between the window's
    /// unit embeddings. Matches `scores_from_embeddings` over the arrived
    /// prefix bit for bit (shared `dot`/`norm` kernels, same accumulation
    /// order).
    fn window_problem(&self, window: &[usize], m: usize) -> EsProblem {
        let k = window.len();
        let dn = norm(&self.centroid).max(1e-12);
        let doc: Vec<f32> = self.centroid.iter().map(|v| v / dn).collect();
        let mut mu = Vec::with_capacity(k);
        let mut beta = vec![0.0f32; k * k];
        for (a, &i) in window.iter().enumerate() {
            let ua = &self.active[&i].unit;
            mu.push(dot(ua, &doc));
            for (b, &j) in window.iter().enumerate().skip(a + 1) {
                let v = dot(ua, &self.active[&j].unit);
                beta[a * k + b] = v;
                beta[b * k + a] = v;
            }
        }
        EsProblem {
            mu,
            beta,
            lambda: self.cfg.lambda,
            m,
        }
    }

    /// Total sentences arrived so far.
    pub fn arrived(&self) -> usize {
        self.planner.arrived()
    }

    /// Frontier compressions performed so far.
    pub fn compressions(&self) -> usize {
        self.planner.compressions()
    }

    /// Summary revisions served so far.
    pub fn revisions(&self) -> usize {
        self.revisions
    }

    /// Current frontier length (bounded by P).
    pub fn frontier_len(&self) -> usize {
        self.planner.frontier().len()
    }

    /// True once enough sentences arrived to fill a summary.
    pub fn can_summarize(&self) -> bool {
        self.planner.can_summarize()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Settings;
    use crate::corpus::Generator;
    use crate::sched::DevicePool;
    use crate::solvers::tabu::TabuSolver;

    fn stream_cfg() -> PipelineConfig {
        PipelineConfig {
            solver: "tabu".into(),
            iterations: 2,
            strategy: crate::decompose::Strategy::Streaming,
            ..Default::default()
        }
    }

    fn run_chunked(sentences: &[String], chunks: &[usize]) -> Summary {
        let cfg = stream_cfg();
        let mut solver = TabuSolver::seeded(0);
        let mut route = StreamRoute::Inline(&mut solver);
        let mut s = StreamSummarizer::new("feed", &cfg).unwrap();
        let mut at = 0usize;
        for &c in chunks {
            let end = (at + c).min(sentences.len());
            s.push_sentences(&sentences[at..end], &mut route).unwrap();
            at = end;
        }
        if at < sentences.len() {
            s.push_sentences(&sentences[at..], &mut route).unwrap();
        }
        s.revision(&mut route).unwrap()
    }

    #[test]
    fn summary_is_invariant_to_arrival_batching() {
        // the streaming determinism contract: one-shot, sentence-by-
        // sentence, and ragged chunkings all produce identical bytes
        let doc = Generator::with_seed(21).document("feed", 57);
        let a = run_chunked(&doc.sentences, &[57]);
        let b = run_chunked(&doc.sentences, &[1; 57]);
        let c = run_chunked(&doc.sentences, &[3, 19, 1, 20, 7, 7]);
        for other in [&b, &c] {
            assert_eq!(a.selected, other.selected);
            assert_eq!(a.sentences, other.sentences);
            assert_eq!(a.objective.to_bits(), other.objective.to_bits());
            assert_eq!(a.total_solves, other.total_solves);
            assert_eq!(a.stages, other.stages);
        }
    }

    #[test]
    fn pooled_route_matches_inline_route_bytewise() {
        // same stream through a coalescing 3-device pool and an inline
        // solver: identical bytes (per-node seeds make the route and the
        // pool shape invisible)
        let doc = Generator::with_seed(22).document("feed", 44);
        let inline = run_chunked(&doc.sentences, &[5; 9]);

        let mut settings = Settings::default();
        settings.pipeline = stream_cfg();
        settings.sched.devices = 3;
        settings.sched.max_coalesce = 8;
        settings.sched.linger_us = 1_000;
        let pool = DevicePool::start(&settings, None).unwrap();
        let mut client = pool.client(0xFEED);
        let mut route = StreamRoute::Pooled(&mut client);
        let mut s = StreamSummarizer::new("feed", &settings.pipeline).unwrap();
        for chunk in doc.sentences.chunks(11) {
            s.push_sentences(chunk, &mut route).unwrap();
        }
        let pooled = s.revision(&mut route).unwrap();
        drop(route);
        drop(client);
        pool.shutdown();

        assert_eq!(pooled.selected, inline.selected);
        assert_eq!(pooled.sentences, inline.sentences);
        assert_eq!(pooled.objective.to_bits(), inline.objective.to_bits());
    }

    #[test]
    fn intermediate_revisions_do_not_change_the_final_summary() {
        let doc = Generator::with_seed(23).document("feed", 50);
        let cfg = stream_cfg();
        let mut solver = TabuSolver::seeded(0);
        let mut route = StreamRoute::Inline(&mut solver);
        let mut s = StreamSummarizer::new("feed", &cfg).unwrap();
        let mut revs = Vec::new();
        for chunk in doc.sentences.chunks(10) {
            s.push_sentences(chunk, &mut route).unwrap();
            revs.push(s.revision(&mut route).unwrap());
        }
        assert_eq!(s.revisions(), 5);
        // a fresh stream with no intermediate revisions agrees on the
        // final selection (total_solves differs by the revision solves)
        let fresh = run_chunked(&doc.sentences, &[50]);
        let last = revs.last().unwrap();
        assert_eq!(last.selected, fresh.selected);
        assert_eq!(last.sentences, fresh.sentences);
        assert_eq!(last.objective.to_bits(), fresh.objective.to_bits());
        // earlier revisions summarize earlier frontiers
        assert!(revs[0].stages <= last.stages);
    }

    #[test]
    fn long_feed_streams_in_bounded_state() {
        // 600 sentences — far past the batch paths' MAX_SENTENCES clamp —
        // with the frontier (and the active map) bounded by P throughout
        let params_p = PipelineConfig::default().decompose_p;
        let doc = Generator::with_seed(24).document("long-feed", 600);
        let cfg = stream_cfg();
        let mut solver = TabuSolver::seeded(0);
        let mut route = StreamRoute::Inline(&mut solver);
        let mut s = StreamSummarizer::new("long-feed", &cfg).unwrap();
        for chunk in doc.sentences.chunks(37) {
            s.push_sentences(chunk, &mut route).unwrap();
            assert!(s.frontier_len() < params_p);
        }
        assert_eq!(s.arrived(), 600);
        assert_eq!(s.compressions(), (600 - params_p) / 10 + 1); // 59
        let summary = s.revision(&mut route).unwrap();
        assert_eq!(summary.selected.len(), cfg.summary_len);
        assert!(summary.selected.windows(2).all(|w| w[0] < w[1]));
        assert!(summary.selected.iter().all(|&i| i < 600));
        assert!(summary.objective.is_finite());
    }

    #[test]
    fn too_short_stream_refuses_a_revision() {
        let cfg = stream_cfg();
        let mut solver = TabuSolver::seeded(0);
        let mut route = StreamRoute::Inline(&mut solver);
        let mut s = StreamSummarizer::new("tiny", &cfg).unwrap();
        let sentences: Vec<String> = (0..3).map(|i| format!("Sentence number {i}.")).collect();
        s.push_sentences(&sentences, &mut route).unwrap();
        assert!(s.revision(&mut route).is_err(), "3 < summary_len");
    }
}
