//! Cross-document subproblem scheduler + COBI device pool.
//!
//! The paper's decomposition (§IV-B) turns each document into a DAG of
//! small, independent Ising subproblems, and the compiled COBI artifact
//! amortizes dispatch over ANNEAL_BATCH instances — this module is the
//! subsystem that connects the two at fleet scale:
//!
//!   * [`SubproblemGraph`] — the decomposition replayed as levels of
//!     disjoint, independently solvable windows (passes chain), carved
//!     by the configured
//!     [`DecomposePlan`](crate::decompose::DecomposePlan) — the paper's
//!     sliding windows or the balanced log-depth tree;
//!   * [`StreamSummarizer`] — the incremental executor for arriving
//!     sentence feeds (`SUMMARIZE_STREAM`): rolling frontier, per-chunk
//!     summary revisions, O(P) state on unbounded feeds;
//!   * [`DevicePool`] — N solver instances pulling ready subproblems
//!     from one shared queue *across all in-flight documents*, coalescing
//!     up to `max_coalesce` requests per dispatch with a configurable
//!     linger so low-traffic latency doesn't regress;
//!   * [`summarize_with_pool`] — the worker-side executor submitting a
//!     whole DAG level before waiting, so devices see deep queues;
//!   * per-request seeding ([`doc_seed`] + client seed streams) — the
//!     determinism contract: summaries are a pure function of
//!     (config, document), independent of pool shape and interleaving.
//!
//! The pool's devices host either one fixed backend (cobi/tabu/sa) or,
//! with `[portfolio] enabled = true`, an adaptive
//! [`SolverPortfolio`](crate::portfolio::SolverPortfolio) that routes
//! each request by policy and reuses prior solutions through a
//! fleet-wide warm-start cache (see `crate::portfolio`).
//!
//! See DESIGN.md §Sched for the architecture rationale and
//! docs/ARCHITECTURE.md for the request walkthrough and the
//! thread/channel ownership diagram.

pub mod breaker;
pub mod exec;
pub mod graph;
pub mod pool;
pub mod stream;

pub use breaker::{BreakerFleet, BreakerMetrics};
pub use exec::{
    summarize_sequential, summarize_sequential_recorded, summarize_sequential_traced,
    summarize_sequential_traced_using, summarize_sequential_using, summarize_with_pool,
    summarize_with_pool_recorded, summarize_with_pool_traced, summarize_with_pool_traced_using,
    summarize_with_pool_using,
};
pub use graph::{SolveUnit, SubproblemGraph};
pub use pool::{
    pool_supports, resolved_backend, service_pooled, DevicePool, PendingSolve, PoolClient,
    PoolHandle, PoolMetrics,
};
pub use stream::{StreamRoute, StreamSummarizer};

/// RNG stream id of the per-document quantization draws — the exact
/// stream `EsPipeline::new` seeds, shared by the executors so the pooled
/// and inline paths cannot drift.
pub(crate) const QUANT_STREAM: u64 = 0xE5;

/// Per-document master seed: the pipeline seed XOR a stable hash of the
/// document id. Keyed to the DOCUMENT (not the worker slot), so results
/// don't depend on which worker picks a job up — the property the seed
/// worker pool lacked.
pub fn doc_seed(base: u64, doc_id: &str) -> u64 {
    base ^ crate::text::tokenize::fnv1a(doc_id.as_bytes())
}

/// The solve-request seed for a `Tree`/`Streaming` plan node: the first
/// draw of the node's own client-seed stream — exactly what a
/// [`PoolClient`] keyed by the node seed would attach to its first
/// submit, so per-node dispatch stays on the same seeding discipline as
/// the sequential per-document stream (decision #8).
pub(crate) fn request_seed(node_seed: u64) -> u64 {
    crate::util::rng::Pcg32::new(node_seed, pool::CLIENT_SEED_STREAM).next_u64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn doc_seed_is_stable_and_id_sensitive() {
        let a = doc_seed(42, "doc-001");
        let b = doc_seed(42, "doc-001");
        let c = doc_seed(42, "doc-002");
        let d = doc_seed(43, "doc-001");
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_ne!(a, d);
    }
}
