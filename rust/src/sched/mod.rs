//! Cross-document subproblem scheduler + COBI device pool.
//!
//! The paper's decomposition (§IV-B) turns each document into a DAG of
//! small, independent Ising subproblems, and the compiled COBI artifact
//! amortizes dispatch over ANNEAL_BATCH instances — this module is the
//! subsystem that connects the two at fleet scale:
//!
//!   * [`SubproblemGraph`] — decomposition replayed as levels of
//!     disjoint, independently solvable windows (passes chain);
//!   * [`DevicePool`] — N solver instances pulling ready subproblems
//!     from one shared queue *across all in-flight documents*, coalescing
//!     up to `max_coalesce` requests per dispatch with a configurable
//!     linger so low-traffic latency doesn't regress;
//!   * [`summarize_with_pool`] — the worker-side executor submitting a
//!     whole DAG level before waiting, so devices see deep queues;
//!   * per-request seeding ([`doc_seed`] + client seed streams) — the
//!     determinism contract: summaries are a pure function of
//!     (config, document), independent of pool shape and interleaving.
//!
//! The pool's devices host either one fixed backend (cobi/tabu/sa) or,
//! with `[portfolio] enabled = true`, an adaptive
//! [`SolverPortfolio`](crate::portfolio::SolverPortfolio) that routes
//! each request by policy and reuses prior solutions through a
//! fleet-wide warm-start cache (see `crate::portfolio`).
//!
//! See DESIGN.md §Sched for the architecture diagram and the
//! thread/channel ownership story.

pub mod exec;
pub mod graph;
pub mod pool;

pub use exec::{
    summarize_sequential, summarize_sequential_using, summarize_with_pool,
    summarize_with_pool_using,
};
pub use graph::{SolveUnit, SubproblemGraph};
pub use pool::{
    pool_supports, resolved_backend, service_pooled, DevicePool, PendingSolve, PoolClient,
    PoolHandle, PoolMetrics,
};

/// Per-document master seed: the pipeline seed XOR a stable hash of the
/// document id. Keyed to the DOCUMENT (not the worker slot), so results
/// don't depend on which worker picks a job up — the property the seed
/// worker pool lacked.
pub fn doc_seed(base: u64, doc_id: &str) -> u64 {
    base ^ crate::text::tokenize::fnv1a(doc_id.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn doc_seed_is_stable_and_id_sensitive() {
        let a = doc_seed(42, "doc-001");
        let b = doc_seed(42, "doc-001");
        let c = doc_seed(42, "doc-002");
        let d = doc_seed(43, "doc-001");
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_ne!(a, d);
    }
}
