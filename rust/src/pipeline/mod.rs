//! End-to-end ES pipeline: document -> sentences -> embeddings ->
//! formulation -> decomposition -> quantize -> solve -> refine -> summary.
//!
//! This is the user-facing composition of every subsystem; the experiment
//! drivers reuse the same pieces at lower level for per-figure sweeps.

use anyhow::{ensure, Context, Result};

use crate::config::{CobiConfig, PipelineConfig};
use crate::corpus::Document;
use crate::decompose::{
    decompose, node_seed, stage_count, DecomposeParams, DecomposePlan, Strategy,
    StreamingPlanner, STREAM_COMPRESS_LEVEL, STREAM_REVISION_LEVEL,
};
use crate::embed::{Embedder, HashEmbedder, Scores};
use crate::ising::EsProblem;
use crate::quant::Rounding;
use crate::refine::{refine, RefineConfig};
use crate::runtime::ArtifactRuntime;
use crate::solvers::random::RandomBaseline;
use crate::solvers::sa::SaSolver;
use crate::solvers::tabu::TabuSolver;
use crate::solvers::{brute, exact, IsingSolver};
use crate::text::MAX_SENTENCES;
use crate::util::rng::Pcg32;

/// Which engine solves the (sub)problems.
pub enum SolverBackend {
    /// Quantize + iterate + Ising solve (COBI / Tabu / SA / oscillator).
    Ising(Box<dyn IsingSolver + Send>),
    /// Exhaustive enumeration of M-subsets under the FP objective.
    Brute,
    /// Branch-and-bound exact maximization (Gurobi substitute).
    Exact,
    /// Best-of-iterations random selection.
    Random(RandomBaseline),
}

impl SolverBackend {
    /// Stable backend name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            SolverBackend::Ising(s) => s.name(),
            SolverBackend::Brute => "brute",
            SolverBackend::Exact => "exact",
            SolverBackend::Random(_) => "random",
        }
    }

    /// Build from the config string. `cobi` requires a device config; the
    /// HLO backend additionally needs the artifact runtime.
    pub fn from_config(
        cfg: &PipelineConfig,
        cobi: &CobiConfig,
        rt: Option<&ArtifactRuntime>,
    ) -> Result<Self> {
        Ok(match cfg.solver.as_str() {
            "cobi" => SolverBackend::Ising(Box::new(crate::cobi::CobiDevice::from_config(
                cobi,
                cfg.seed ^ 0xDE71CE,
                rt,
            )?)),
            "tabu" => SolverBackend::Ising(Box::new(TabuSolver::seeded(cfg.seed ^ 0x7AB))),
            "sa" => SolverBackend::Ising(Box::new(SaSolver::seeded(cfg.seed ^ 0x5A))),
            "snowball" => SolverBackend::Ising(Box::new(
                crate::solvers::snowball::SnowballSolver::seeded(cfg.seed ^ 0x5B07),
            )),
            "brute" => SolverBackend::Brute,
            "exact" => SolverBackend::Exact,
            "random" => SolverBackend::Random(RandomBaseline::seeded(cfg.seed ^ 0xBA5E)),
            other => anyhow::bail!("unknown solver '{other}'"),
        })
    }
}

/// A produced summary.
#[derive(Debug, Clone)]
pub struct Summary {
    /// Source document id.
    pub doc_id: String,
    /// Selected sentence indices (ascending, original document order).
    pub selected: Vec<usize>,
    /// The extracted sentences, in document order.
    pub sentences: Vec<String>,
    /// FP Eq. 3 objective of the selection on the FULL document problem.
    pub objective: f64,
    /// Ising subproblems solved (decomposition stages x iterations).
    pub total_solves: usize,
    /// Decomposition stages.
    pub stages: usize,
}

impl Summary {
    /// Summary sentences joined into one string.
    pub fn text(&self) -> String {
        self.sentences.join(" ")
    }
}

/// The end-to-end summarization pipeline: one of these per worker (or
/// one, standalone) turns documents into M-sentence summaries through
/// embed → formulate → decompose → quantize → solve → refine.
///
/// # Examples
///
/// ```
/// use cobi_es::config::{CobiConfig, PipelineConfig};
/// use cobi_es::corpus::Generator;
/// use cobi_es::pipeline::EsPipeline;
///
/// let mut generator = Generator::with_seed(7);
/// let doc = generator.document("demo", 12);
/// let cfg = PipelineConfig {
///     solver: "tabu".into(),
///     iterations: 2,
///     ..Default::default()
/// };
/// let mut pipeline = EsPipeline::from_config(&cfg, &CobiConfig::default(), None).unwrap();
/// let summary = pipeline.summarize(&doc).unwrap();
/// assert_eq!(summary.selected.len(), cfg.summary_len);
/// // selections come back in document order, scored on the FP objective
/// assert!(summary.selected.windows(2).all(|w| w[0] < w[1]));
/// assert!(summary.objective.is_finite());
/// ```
pub struct EsPipeline {
    /// Pipeline configuration (public for experiment drivers).
    pub cfg: PipelineConfig,
    embedder: Box<dyn Embedder + Send>,
    backend: SolverBackend,
    rng: Pcg32,
}

impl EsPipeline {
    /// Pipeline from explicit parts.
    pub fn new(
        cfg: PipelineConfig,
        embedder: Box<dyn Embedder + Send>,
        backend: SolverBackend,
    ) -> Self {
        let rng = Pcg32::new(cfg.seed, 0xE5);
        Self {
            cfg,
            embedder,
            backend,
            rng,
        }
    }

    /// Default setup: hash embedder + backend from config strings.
    pub fn from_config(
        cfg: &PipelineConfig,
        cobi: &CobiConfig,
        rt: Option<&ArtifactRuntime>,
    ) -> Result<Self> {
        let backend = SolverBackend::from_config(cfg, cobi, rt)?;
        Ok(Self::new(cfg.clone(), Box::new(HashEmbedder::new()), backend))
    }

    fn refine_config(&self) -> RefineConfig {
        self.cfg.refine_config()
    }

    fn decompose_params(&self) -> DecomposeParams {
        self.cfg.decompose_params()
    }

    /// Solve one window subproblem; returns positions into the window.
    fn solve_window(
        scores: &Scores,
        window: &[usize],
        target: usize,
        lambda: f32,
        refine_cfg: &RefineConfig,
        backend: &mut SolverBackend,
        rng: &mut Pcg32,
    ) -> Result<Vec<usize>> {
        let sub = scores.subset(window);
        let p = EsProblem {
            mu: sub.mu,
            beta: sub.beta,
            lambda,
            m: target,
        };
        let selected = match backend {
            SolverBackend::Ising(solver) => {
                refine(&p, refine_cfg, solver.as_mut(), rng)?.result.selected
            }
            SolverBackend::Brute => brute::solve(&p).selected,
            SolverBackend::Exact => exact::solve_max(&p).selected,
            SolverBackend::Random(r) => r.best_of(&p, refine_cfg.iterations).selected,
        };
        Ok(selected)
    }

    /// Summarize a document to `cfg.summary_len` sentences, decomposing
    /// per `cfg.strategy` (the inline analogues of the sched executors;
    /// see `decompose::plan` for the strategy semantics).
    pub fn summarize(&mut self, doc: &Document) -> Result<Summary> {
        match self.cfg.strategy {
            Strategy::Window => self.summarize_window(doc),
            Strategy::Tree => self.summarize_tree(doc),
            Strategy::Streaming => self.summarize_stream(doc),
        }
    }

    /// The paper's sliding-window reduction (§IV-B) — the reference path,
    /// byte-identical to every pre-strategy release.
    fn summarize_window(&mut self, doc: &Document) -> Result<Summary> {
        let n = doc.len().min(MAX_SENTENCES);
        ensure!(n >= self.cfg.summary_len, "document too short");
        let sentences = &doc.sentences[..n];
        let scores = self
            .embedder
            .scores(sentences)
            .context("embedding failed")?;

        let params = self.decompose_params();
        let refine_cfg = self.refine_config();
        let lambda = self.cfg.lambda;
        let backend = &mut self.backend;
        let rng = &mut self.rng;

        let result = decompose(n, &params, |window, target| {
            Self::solve_window(&scores, window, target, lambda, &refine_cfg, backend, rng)
        })?;

        Ok(Self::assemble(doc, sentences, &scores, &self.cfg, result))
    }

    /// Balanced hierarchical merge: the tree plan's levels solved in
    /// unit order, each unit's rounding draws seeded from its tree
    /// position (`node_seed`) — the inline twin of the pooled tree walk.
    fn summarize_tree(&mut self, doc: &Document) -> Result<Summary> {
        let n = doc.len().min(MAX_SENTENCES);
        ensure!(n >= self.cfg.summary_len, "document too short");
        let sentences = &doc.sentences[..n];
        let scores = self
            .embedder
            .scores(sentences)
            .context("embedding failed")?;

        let params = self.decompose_params();
        let refine_cfg = self.refine_config();
        let lambda = self.cfg.lambda;
        let seed = self.cfg.seed;
        let backend = &mut self.backend;

        let plan = DecomposePlan::new(Strategy::Tree, &params)?;
        let mut graph = crate::sched::SubproblemGraph::with_plan(n, plan)?;
        while !graph.is_done() {
            let units = graph.take_ready();
            ensure!(!units.is_empty(), "tree plan stalled: no ready units");
            for u in units {
                let mut rng = Pcg32::new(node_seed(seed, u.level, u.slot), 0xE5);
                let local = Self::solve_window(
                    &scores, &u.window, u.target, lambda, &refine_cfg, backend, &mut rng,
                )?;
                graph.complete(u.id, local)?;
            }
        }
        let result = graph.into_result()?;
        Ok(Self::assemble(doc, sentences, &scores, &self.cfg, result))
    }

    /// Causal replay of the streaming strategy: the rolling frontier is
    /// compressed exactly as if the document's sentences had arrived one
    /// by one, with each compression scored over only the sentences seen
    /// so far.
    ///
    /// Cost note: the [`Embedder`] trait only exposes whole-prefix
    /// scoring, so each compression recomputes `scores(&sentences[..=t])`
    /// — O(t²·D) per compression. That is acceptable here ONLY because
    /// this path keeps the batch paths' `MAX_SENTENCES` clamp (the
    /// pipeline's embedder may be the fixed-shape encoder artifact, and a
    /// ≤128-sentence document sees ~a dozen compressions). Feeds of real
    /// length belong on [`StreamSummarizer`](crate::sched::StreamSummarizer),
    /// which embeds each sentence once and scores windows incrementally
    /// in O(P²·D) — that is the service's streaming executor.
    fn summarize_stream(&mut self, doc: &Document) -> Result<Summary> {
        let n = doc.len().min(MAX_SENTENCES);
        ensure!(n >= self.cfg.summary_len, "document too short");
        let sentences = &doc.sentences[..n];

        let params = self.decompose_params();
        let refine_cfg = self.refine_config();
        let lambda = self.cfg.lambda;
        let seed = self.cfg.seed;

        let mut planner = StreamingPlanner::new(&params)?;
        for t in 0..n {
            let Some(unit) = planner.push()? else { continue };
            // causal scores: centroid over the t+1 arrived sentences only
            let scores = self
                .embedder
                .scores(&sentences[..=t])
                .context("embedding failed")?;
            let mut rng =
                Pcg32::new(node_seed(seed, STREAM_COMPRESS_LEVEL, unit.seq), 0xE5);
            let local = Self::solve_window(
                &scores,
                &unit.window,
                unit.target,
                lambda,
                &refine_cfg,
                &mut self.backend,
                &mut rng,
            )?;
            planner.complete(&unit, &local)?;
        }

        // final revision over the frontier, scored at full arrival count
        let scores = self
            .embedder
            .scores(sentences)
            .context("embedding failed")?;
        let frontier: Vec<usize> = planner.frontier().to_vec();
        ensure!(
            frontier.len() >= self.cfg.summary_len,
            "stream frontier too short for the summary"
        );
        let mut rng = Pcg32::new(node_seed(seed, STREAM_REVISION_LEVEL, n), 0xE5);
        let local = Self::solve_window(
            &scores,
            &frontier,
            self.cfg.summary_len,
            lambda,
            &refine_cfg,
            &mut self.backend,
            &mut rng,
        )?;
        let mut local = local;
        local.sort_unstable();
        let selected: Vec<usize> = local.iter().map(|&l| frontier[l]).collect();

        // scored on the FRONTIER problem (see sched::stream: the full-
        // document objective has no causal analogue in a stream)
        let sub = scores.subset(&frontier);
        let p = EsProblem {
            mu: sub.mu,
            beta: sub.beta,
            lambda,
            m: self.cfg.summary_len,
        };
        let objective = p.objective(&local);
        let stages = planner.compressions() + 1;
        Ok(Summary {
            doc_id: doc.id.clone(),
            sentences: selected
                .iter()
                .map(|&i| sentences[i].clone())
                .collect(),
            selected,
            objective,
            total_solves: stages * self.cfg.iterations.max(1),
            stages,
        })
    }

    /// Shared tail of the window/tree paths: score the final selection on
    /// the full-document problem and assemble the summary.
    fn assemble(
        doc: &Document,
        sentences: &[String],
        scores: &Scores,
        cfg: &PipelineConfig,
        result: crate::decompose::DecompositionResult,
    ) -> Summary {
        let full = EsProblem {
            mu: scores.mu.clone(),
            beta: scores.beta.clone(),
            lambda: cfg.lambda,
            m: cfg.summary_len,
        };
        let objective = full.objective(&result.selected);

        let stages = result.solves();
        Summary {
            doc_id: doc.id.clone(),
            sentences: result
                .selected
                .iter()
                .map(|&i| sentences[i].clone())
                .collect(),
            selected: result.selected,
            objective,
            total_solves: stages * cfg.iterations.max(1),
            stages,
        }
    }

    /// Expected decomposition stages for a document of `n` sentences.
    pub fn expected_stages(&self, n: usize) -> usize {
        stage_count(n.min(MAX_SENTENCES), &self.decompose_params())
    }

    /// Full-document EsProblem (for normalization by experiments).
    pub fn problem_for(&mut self, doc: &Document) -> Result<EsProblem> {
        let n = doc.len().min(MAX_SENTENCES);
        let scores = self.embedder.scores(&doc.sentences[..n])?;
        Ok(EsProblem {
            mu: scores.mu,
            beta: scores.beta,
            lambda: self.cfg.lambda,
            m: self.cfg.summary_len,
        })
    }
}

/// Convenience used by the experiments: rounding sweep order of §IV-A.
pub fn rounding_sweep() -> Vec<Rounding> {
    vec![
        Rounding::Deterministic,
        Rounding::Stoch5050,
        Rounding::Stochastic,
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::benchmark_set;
    use crate::ising::exact_bounds;

    fn pipeline(solver: &str, iterations: usize) -> EsPipeline {
        let cfg = PipelineConfig {
            solver: solver.into(),
            iterations,
            ..Default::default()
        };
        EsPipeline::from_config(&cfg, &CobiConfig::default(), None).unwrap()
    }

    #[test]
    fn summarizes_20_sentence_benchmark_with_tabu() {
        let set = benchmark_set("cnn_dm_20").unwrap();
        let mut p = pipeline("tabu", 5);
        let s = p.summarize(&set.documents[0]).unwrap();
        assert_eq!(s.selected.len(), 6);
        assert_eq!(s.sentences.len(), 6);
        assert_eq!(s.stages, 2); // 20 -> 10 -> 6
        assert!(s.selected.windows(2).all(|w| w[0] < w[1]));
        assert!(s.objective.is_finite());
    }

    #[test]
    fn cobi_pipeline_end_to_end_quality() {
        // the headline integration check: COBI-simulated pipeline beats
        // random and reaches a decent normalized objective on one doc
        let set = benchmark_set("cnn_dm_20").unwrap();
        let doc = &set.documents[1];
        let mut cobi = pipeline("cobi", 10);
        let mut rnd = pipeline("random", 10);
        let summary = cobi.summarize(doc).unwrap();
        let baseline = rnd.summarize(doc).unwrap();

        let problem = cobi.problem_for(doc).unwrap();
        let bounds = exact_bounds(&problem);
        let norm_cobi = bounds.normalize(summary.objective);
        let norm_rand = bounds.normalize(baseline.objective);
        assert!(
            norm_cobi > 0.55,
            "cobi normalized objective {norm_cobi} too low"
        );
        assert!(
            norm_cobi >= norm_rand - 0.15,
            "cobi {norm_cobi} not competitive with random {norm_rand}"
        );
    }

    #[test]
    fn exact_backend_is_upper_bound() {
        let set = benchmark_set("bench_10").unwrap();
        let doc = &set.documents[0];
        let mut ex = pipeline("exact", 1);
        let mut tb = pipeline("tabu", 5);
        // bench_10 docs have 10 sentences < P: single-stage, exact solves
        // the full problem optimally
        let se = ex.summarize(doc).unwrap();
        let st = tb.summarize(doc).unwrap();
        assert!(se.objective >= st.objective - 1e-9);
        assert_eq!(se.stages, 1);
    }

    #[test]
    fn summary_lengths_follow_config() {
        let set = benchmark_set("bench_10").unwrap();
        let cfg = PipelineConfig {
            solver: "tabu".into(),
            summary_len: 3,
            iterations: 3,
            ..Default::default()
        };
        let mut p = EsPipeline::from_config(&cfg, &CobiConfig::default(), None).unwrap();
        let s = p.summarize(&set.documents[2]).unwrap();
        assert_eq!(s.selected.len(), 3);
    }

    #[test]
    fn too_short_document_is_error() {
        let doc = Document::from_text("tiny", "One sentence only.");
        let mut p = pipeline("tabu", 1);
        assert!(p.summarize(&doc).is_err());
    }

    #[test]
    fn fifty_sentence_document_uses_four_stages() {
        let set = benchmark_set("cnn_dm_50").unwrap();
        let mut p = pipeline("tabu", 2);
        let s = p.summarize(&set.documents[0]).unwrap();
        assert_eq!(s.stages, 4);
        assert_eq!(s.selected.len(), 6);
    }

    #[test]
    fn tree_and_stream_strategies_summarize_inline() {
        use crate::decompose::Strategy;
        let set = benchmark_set("cnn_dm_50").unwrap();
        let doc = &set.documents[0];
        for strategy in [Strategy::Tree, Strategy::Streaming] {
            let cfg = PipelineConfig {
                solver: "tabu".into(),
                iterations: 2,
                strategy,
                ..Default::default()
            };
            let make = || EsPipeline::from_config(&cfg, &CobiConfig::default(), None).unwrap();
            let s = make().summarize(doc).unwrap();
            assert_eq!(s.selected.len(), 6, "{strategy}");
            assert!(s.selected.windows(2).all(|w| w[0] < w[1]), "{strategy}");
            assert!(s.selected.iter().all(|&i| i < 50), "{strategy}");
            assert!(s.objective.is_finite(), "{strategy}");
            assert!(s.stages >= 2, "{strategy}");
            // inline strategies are deterministic: a fresh pipeline
            // replays the identical summary
            let s2 = make().summarize(doc).unwrap();
            assert_eq!(s.selected, s2.selected, "{strategy}");
            assert_eq!(s.objective.to_bits(), s2.objective.to_bits(), "{strategy}");
        }
    }

    #[test]
    fn strategies_reduce_to_one_final_solve_below_p() {
        // N <= P: every strategy degenerates to the same single final
        // M-selection shape (counts agree; selections may differ only
        // through seeding)
        use crate::decompose::Strategy;
        let set = benchmark_set("bench_10").unwrap();
        for strategy in [Strategy::Window, Strategy::Tree, Strategy::Streaming] {
            let cfg = PipelineConfig {
                solver: "tabu".into(),
                iterations: 2,
                summary_len: 3,
                strategy,
                ..Default::default()
            };
            let mut p = EsPipeline::from_config(&cfg, &CobiConfig::default(), None).unwrap();
            let s = p.summarize(&set.documents[0]).unwrap();
            assert_eq!(s.stages, 1, "{strategy}");
            assert_eq!(s.selected.len(), 3, "{strategy}");
        }
    }
}
