//! Extractive summarization as a [`KOfNProblem`] — the original workload
//! restated on the platform seam: candidates are sentences, relevance is
//! mu (cosine to the document mean), redundancy is beta (pairwise
//! cosine), k is the summary length.
//!
//! Workload salt/tag are 0 ([`super::workload_salt`]), so an
//! [`EsWorkload`] lowered through [`super::select_inline`] /
//! [`super::select_with_pool`] reproduces the legacy
//! `summarize_sequential` / `summarize_with_pool` output byte for byte —
//! the pin that makes the platform a refactor, not a fork.

use anyhow::Result;

use crate::corpus::Document;
use crate::embed::{Embedder, HashEmbedder, Scores};
use crate::text::MAX_SENTENCES;

use super::KOfNProblem;

/// A document + summary length, viewed as a k-of-n selection.
pub struct EsWorkload {
    doc: Document,
    k: usize,
}

impl EsWorkload {
    /// Wrap `doc`, selecting `k` sentences. Documents longer than the
    /// tokenizer's `MAX_SENTENCES` are truncated, exactly like the
    /// executors' clamp — so the lowering sees the same candidate set.
    pub fn new(mut doc: Document, k: usize) -> Self {
        doc.sentences.truncate(MAX_SENTENCES);
        Self { doc, k }
    }

    /// The wrapped document.
    pub fn document(&self) -> &Document {
        &self.doc
    }
}

impl KOfNProblem for EsWorkload {
    fn workload(&self) -> &'static str {
        "es"
    }

    fn id(&self) -> &str {
        &self.doc.id
    }

    fn candidates(&self) -> Vec<String> {
        self.doc.sentences.clone()
    }

    fn k(&self) -> usize {
        self.k
    }

    fn scores(&self) -> Result<Scores> {
        HashEmbedder::new().scores(&self.doc.sentences)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Settings;
    use crate::corpus::benchmark_set;
    use crate::sched::{doc_seed, summarize_sequential};
    use crate::workload::select_inline;

    #[test]
    fn platform_es_path_matches_legacy_pipeline_bytewise() {
        // the refactor pin: EsWorkload through the generic platform seam
        // (salt 0, FixedScores embedder, TaggedSolver tag 0) reproduces
        // the legacy sequential executor byte for byte
        let mut s = Settings::default();
        s.pipeline.solver = "tabu".into();
        s.pipeline.iterations = 3;
        let set = benchmark_set("bench_10").unwrap();
        for doc in set.documents.iter().take(4) {
            let mut cfg = s.pipeline.clone();
            cfg.summary_len = set.summary_len;
            cfg.seed = doc_seed(cfg.seed, &doc.id);
            let mut solver = crate::solvers::tabu::TabuSolver::seeded(0);
            let legacy = summarize_sequential(doc, &cfg, &mut solver).unwrap();

            let p = EsWorkload::new(doc.clone(), set.summary_len);
            let platform = select_inline(&p, &s, None).unwrap();

            assert_eq!(platform.selected, legacy.selected, "{}", doc.id);
            assert_eq!(platform.sentences, legacy.sentences, "{}", doc.id);
            assert_eq!(
                platform.objective.to_bits(),
                legacy.objective.to_bits(),
                "{}",
                doc.id
            );
            assert_eq!(platform.total_solves, legacy.total_solves);
        }
    }

    #[test]
    fn overlong_documents_are_clamped_like_the_executors() {
        let mut doc = Document {
            id: "long".into(),
            sentences: vec!["a sentence here".to_string(); MAX_SENTENCES + 7],
            reference: Vec::new(),
        };
        doc.sentences
            .iter_mut()
            .enumerate()
            .for_each(|(i, s)| s.push_str(&format!(" number {i}")));
        let p = EsWorkload::new(doc, 3);
        assert_eq!(p.candidates().len(), MAX_SENTENCES);
        assert_eq!(p.scores().unwrap().n(), MAX_SENTENCES);
    }
}
