//! The k-of-n selection platform: the generic seam the paper's §I claim
//! ("can be applied to any problem formulation that requires k of n
//! variables to be chosen") turns into API.
//!
//! A [`KOfNProblem`] is anything that yields a candidate set, a relevance
//! vector and a pairwise redundancy matrix, and asks for exactly k
//! candidates back. Extractive summarization is one instance
//! ([`es::EsWorkload`]); this module ships two non-text ones end to end
//! through the same pool/portfolio/resilience/obs stack:
//!
//!   * [`retrieval::RetrievalProblem`] — diverse-retrieval selection:
//!     pick the k passages most relevant to a query and least redundant
//!     with each other (RAG context assembly);
//!   * [`dispersion::DispersionProblem`] — facility dispersion / feature
//!     selection: pure max-dispersion k-of-n, promoting the calibrator's
//!     probe generator ([`crate::ising::kofn::facility_dispersion`]) to a
//!     real workload.
//!
//! Every workload lowers to the SAME execution plan the ES pipeline
//! runs — scores → decomposition DAG → quantize → solve → repair →
//! score — so the executors, the pool, the portfolio and the resilience
//! layer are reused verbatim and the determinism contract extends
//! unchanged: (workload, seed) ⇒ byte-identical selections across pool
//! shapes, strategies, and the inline path.
//!
//! Seed/tag derivation (DESIGN.md decision #22): each workload owns a
//! salt — 0 for `"es"`, `fnv1a(name)` otherwise — folded into the
//! per-problem seed ([`problem_seed`]) and used as the warm-start cache
//! namespace tag ([`workload_tag`]). The zero ES salt makes the legacy
//! untagged byte-pins (golden fixtures, cache hit counts) hold verbatim.

pub mod dispersion;
pub mod es;
pub mod retrieval;

use anyhow::{bail, ensure, Result};

use crate::config::{PipelineConfig, Settings, WorkloadConfig};
use crate::corpus::Document;
use crate::decompose::Strategy;
use crate::embed::{Embedder, Scores};
use crate::obs::{ObsShared, Span, Subsystem};
use crate::pipeline::Summary;
use crate::runtime::ArtifactRuntime;
use crate::sched::pool::{build_solver, PoolSolver};
use crate::sched::{
    doc_seed, resolved_backend, summarize_sequential_traced_using, summarize_sequential_using,
    summarize_with_pool_traced_using, summarize_with_pool_using, PoolHandle,
};
use crate::text::tokenize::fnv1a;
use crate::text::MAX_SENTENCES;

/// Every registered workload name, in registration order. Stable: these
/// strings are metrics keys, ledger subsystem labels, golden-fixture
/// names, and the `::WORKLOAD <name>::` protocol vocabulary.
pub const WORKLOADS: [&str; 3] = ["es", "retrieval", "dispersion"];

/// Resolve a request-supplied workload name to its registered static
/// name (`None` if unregistered).
pub fn resolve(name: &str) -> Option<&'static str> {
    WORKLOADS.iter().find(|w| **w == name).copied()
}

/// Per-workload seed salt: 0 for `"es"` (so every legacy seed, fixture
/// and cache pin is preserved bit for bit), `fnv1a(name)` otherwise.
pub fn workload_salt(name: &str) -> u64 {
    if name == "es" {
        0
    } else {
        fnv1a(name.as_bytes())
    }
}

/// The warm-start cache namespace tag for a workload — identical to
/// [`workload_salt`], so tag 0 is simultaneously "legacy untagged" and
/// "es", which is exactly the aliasing the compatibility story needs.
pub fn workload_tag(name: &str) -> u64 {
    workload_salt(name)
}

/// Per-problem master seed: [`doc_seed`] over the workload-salted base,
/// mirroring `decompose::node_seed` semantics — the derivation is a pure
/// function of (base seed, workload, problem id), never of submission
/// order or pool shape. For `"es"` this reduces to the legacy
/// `doc_seed(base, id)`.
pub fn problem_seed(base: u64, workload: &str, id: &str) -> u64 {
    doc_seed(base ^ workload_salt(workload), id)
}

/// Ledger subsystem a workload's inline/local solves are charged to
/// (pooled solves stay on `Subsystem::Pool`: the pool is shared and its
/// devices cannot know per-request attribution cheaply).
pub fn subsystem_for(workload: &str) -> Subsystem {
    match workload {
        "retrieval" => Subsystem::Retrieval,
        "dispersion" => Subsystem::Dispersion,
        _ => Subsystem::Pipeline,
    }
}

/// A k-of-n selection problem: candidates, relevance, pairwise
/// redundancy, cardinality. Object-safe so the service can route
/// factory-built problems without generics.
pub trait KOfNProblem: Send {
    /// Registered workload name (one of [`WORKLOADS`]); metrics/ledger key.
    fn workload(&self) -> &'static str;
    /// Problem id — the seed-derivation key, like a document id.
    fn id(&self) -> &str;
    /// Candidate labels, one per item (what a selection returns).
    fn candidates(&self) -> Vec<String>;
    /// Selection cardinality k.
    fn k(&self) -> usize;
    /// Redundancy weight override; `None` inherits `[pipeline] lambda`.
    /// Workloads whose redundancy matrix is already fully weighted (e.g.
    /// dispersion's distance-derived cost) return `Some(1.0)`.
    fn lambda(&self) -> Option<f32> {
        None
    }
    /// Relevance vector + pairwise redundancy matrix over the candidates
    /// (row-major n*n, symmetric, zero diagonal — the [`Scores`] contract).
    fn scores(&self) -> Result<Scores>;
}

/// A [`KOfNProblem`] lowered to the executors' vocabulary: a synthetic
/// [`Document`] whose "sentences" are the candidates, a per-problem
/// [`PipelineConfig`] (seed salted by workload, `summary_len` = k), the
/// precomputed scores, and the workload's cache tag.
pub struct Lowered {
    /// Candidates as a document (id = problem id).
    pub doc: Document,
    /// Per-problem config: seeded via [`problem_seed`], `summary_len` = k.
    pub cfg: PipelineConfig,
    /// The problem's relevance/redundancy scores (fed to the executors
    /// through [`FixedScores`] so no text embedding runs).
    pub scores: Scores,
    /// Warm-start cache namespace ([`workload_tag`]).
    pub tag: u64,
}

/// Lower `problem` onto `base` (usually `settings.pipeline`): derive the
/// salted per-problem seed, override cardinality/λ, and build the
/// candidate document. `Strategy::Streaming` is coerced to `Window` for
/// non-ES workloads — the streaming path embeds text incrementally and
/// cannot accept precomputed scores.
pub fn lower(problem: &dyn KOfNProblem, base: &PipelineConfig) -> Result<Lowered> {
    let candidates = problem.candidates();
    let n = candidates.len();
    ensure!(n > 0, "workload '{}' produced no candidates", problem.workload());
    ensure!(
        n <= MAX_SENTENCES,
        "workload '{}' produced {n} candidates (max {MAX_SENTENCES})",
        problem.workload()
    );
    let k = problem.k();
    ensure!(
        (1..=n).contains(&k),
        "workload '{}' asked for k={k} of n={n}",
        problem.workload()
    );
    let scores = problem.scores()?;
    ensure!(
        scores.n() == n,
        "workload '{}' scores cover {} of {n} candidates",
        problem.workload(),
        scores.n()
    );
    let mut cfg = base.clone();
    cfg.summary_len = k;
    if let Some(l) = problem.lambda() {
        cfg.lambda = l;
    }
    if cfg.strategy == Strategy::Streaming && problem.workload() != "es" {
        cfg.strategy = Strategy::Window;
    }
    cfg.seed = problem_seed(base.seed, problem.workload(), problem.id());
    Ok(Lowered {
        doc: Document {
            id: problem.id().to_string(),
            sentences: candidates,
            reference: Vec::new(),
        },
        cfg,
        scores,
        tag: workload_tag(problem.workload()),
    })
}

/// An [`Embedder`] that returns one precomputed [`Scores`] — how lowered
/// workloads feed relevance/redundancy into the text executors without
/// any text embedding. Rejects a sentence count that does not match the
/// stored scores (a lowering bug, not a runtime condition).
pub struct FixedScores {
    scores: Scores,
}

impl FixedScores {
    /// Wrap precomputed scores.
    pub fn new(scores: Scores) -> Self {
        Self { scores }
    }
}

impl Embedder for FixedScores {
    fn name(&self) -> &'static str {
        "fixed"
    }

    fn scores(&mut self, sentences: &[String]) -> Result<Scores> {
        ensure!(
            sentences.len() == self.scores.n(),
            "fixed scores cover {} candidates, executor asked for {}",
            self.scores.n(),
            sentences.len()
        );
        Ok(self.scores.clone())
    }
}

/// A [`PoolSolver`] adaptor that stamps one workload tag onto every
/// group — the inline path's equivalent of
/// [`PoolClient::set_workload_tag`](crate::sched::PoolClient::set_workload_tag):
/// the sequential executor calls `solve_groups`, and this forwards them
/// as `solve_groups_tagged` so a portfolio-backed inline solver scopes
/// its warm-start tiers exactly like the pooled devices do.
pub struct TaggedSolver<'a> {
    inner: &'a mut dyn PoolSolver,
    tag: u64,
}

impl<'a> TaggedSolver<'a> {
    /// Wrap `inner`, stamping `tag` on every dispatch.
    pub fn new(inner: &'a mut dyn PoolSolver, tag: u64) -> Self {
        Self { inner, tag }
    }
}

impl PoolSolver for TaggedSolver<'_> {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn solve_groups(
        &mut self,
        groups: &[crate::cobi::SeededGroup<'_>],
    ) -> Result<Vec<Vec<crate::solvers::SolveResult>>> {
        let tags = vec![self.tag; groups.len()];
        self.inner.solve_groups_tagged(&tags, groups)
    }
}

/// Solve `problem` inline on a freshly built solver (no pool): the
/// sequential comparator every pooled path must match byte for byte.
/// The solver is built exactly like a pool device's
/// (`resolved_backend`), so portfolio/resilience settings apply here too.
pub fn select_inline(
    problem: &dyn KOfNProblem,
    settings: &Settings,
    rt: Option<&ArtifactRuntime>,
) -> Result<Summary> {
    let (summary, _) = select_inline_obs(problem, settings, rt, None)?;
    Ok(summary)
}

/// [`select_inline`] with optional observability: inline solves are
/// charged to the workload's ledger subsystem ([`subsystem_for`]) and a
/// request span is recorded when `obs` has spans enabled. Non-ES spans
/// carry a `workload` attribute (ES spans stay byte-identical to the
/// legacy pipeline's).
pub fn select_inline_obs(
    problem: &dyn KOfNProblem,
    settings: &Settings,
    rt: Option<&ArtifactRuntime>,
    obs: Option<&ObsShared>,
) -> Result<(Summary, Option<Span>)> {
    let lowered = lower(problem, &settings.pipeline)?;
    let backend = resolved_backend(settings);
    let mut solver = build_solver(
        backend,
        settings,
        settings.pipeline.seed ^ 0xD00D,
        rt,
        None,
        None,
        obs.map(|o| (o, subsystem_for(problem.workload()))),
        None,
    )?;
    let mut tagged = TaggedSolver::new(solver.as_mut(), lowered.tag);
    let mut embedder = FixedScores::new(lowered.scores);
    match obs {
        Some(o) => {
            let (summary, span) = summarize_sequential_traced_using(
                &lowered.doc,
                &lowered.cfg,
                &mut tagged,
                o,
                &mut embedder,
            )?;
            Ok((summary, brand_span(span, problem.workload())))
        }
        None => {
            let summary =
                summarize_sequential_using(&lowered.doc, &lowered.cfg, &mut tagged, &mut embedder)?;
            Ok((summary, None))
        }
    }
}

/// Solve `problem` through a shared [`DevicePool`](crate::sched::DevicePool):
/// the client is keyed by the salted per-problem seed and stamps the
/// workload's cache tag on every request. Byte-identical to
/// [`select_inline`] for any pool shape (pinned by
/// `tests/workload_conformance.rs`).
pub fn select_with_pool(
    problem: &dyn KOfNProblem,
    base: &PipelineConfig,
    handle: &PoolHandle,
) -> Result<Summary> {
    let (summary, _) = select_with_pool_obs(problem, base, handle, None)?;
    Ok(summary)
}

/// [`select_with_pool`] with an optional span recorder (see
/// [`select_inline_obs`] for the span contract).
pub fn select_with_pool_obs(
    problem: &dyn KOfNProblem,
    base: &PipelineConfig,
    handle: &PoolHandle,
    obs: Option<&ObsShared>,
) -> Result<(Summary, Option<Span>)> {
    let lowered = lower(problem, base)?;
    let mut client = handle.client(lowered.cfg.seed);
    client.set_workload_tag(lowered.tag);
    let mut embedder = FixedScores::new(lowered.scores);
    match obs {
        Some(o) => {
            let (summary, span) = summarize_with_pool_traced_using(
                &lowered.doc,
                &lowered.cfg,
                &mut client,
                o,
                &mut embedder,
            )?;
            Ok((summary, brand_span(span, problem.workload())))
        }
        None => {
            let summary =
                summarize_with_pool_using(&lowered.doc, &lowered.cfg, &mut client, &mut embedder)?;
            Ok((summary, None))
        }
    }
}

/// Stamp the workload name on a recorded root span — non-ES only, so the
/// ES span JSON stays byte-identical to the pre-platform output.
fn brand_span(mut span: Option<Span>, workload: &'static str) -> Option<Span> {
    if workload != "es" {
        if let Some(s) = span.as_mut() {
            s.set("workload", workload);
        }
    }
    span
}

/// Build a problem from a service request: `workload` is the
/// `::WORKLOAD <name>::` header value, `id` the request's document id,
/// `lines` the non-empty request body lines. Body shapes:
///
///   * `retrieval` — first line is the query, the rest are candidate
///     passages; k comes from `[workload] retrieval_k`;
///   * `dispersion` — one spec line `n=<sites> k=<pick> seed=<u64>`
///     (missing fields fall back to `[workload] dispersion_n` /
///     `dispersion_k` / seed 0);
///   * `es` is NOT built here: ES requests keep the legacy text path.
pub fn problem_from_request(
    workload: &str,
    id: &str,
    lines: &[String],
    cfg: &WorkloadConfig,
) -> Result<Box<dyn KOfNProblem>> {
    match resolve(workload) {
        Some("retrieval") => {
            ensure!(
                lines.len() >= 2,
                "retrieval request needs a query line plus at least one passage"
            );
            let query = lines[0].clone();
            let passages = lines[1..].to_vec();
            let k = cfg.retrieval_k.min(passages.len()).max(1);
            Ok(Box::new(retrieval::RetrievalProblem::new(
                id, &query, passages, k,
            )?))
        }
        Some("dispersion") => {
            ensure!(!lines.is_empty(), "dispersion request needs a spec line");
            let spec = dispersion::DispersionSpec::parse(&lines[0], cfg)?;
            Ok(Box::new(dispersion::DispersionProblem::generate(
                id, spec.seed, spec.n, spec.k,
            )?))
        }
        Some(other) => bail!("workload '{other}' has no request factory"),
        None => bail!(
            "unknown workload '{workload}' (registered: {})",
            WORKLOADS.join(", ")
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn es_salt_is_zero_and_aliases_legacy_seeds() {
        assert_eq!(workload_salt("es"), 0);
        assert_eq!(workload_tag("es"), 0);
        assert_eq!(problem_seed(0xC0B1, "es", "doc-3"), doc_seed(0xC0B1, "doc-3"));
    }

    #[test]
    fn non_es_salts_are_distinct_and_stable() {
        let r = workload_salt("retrieval");
        let d = workload_salt("dispersion");
        assert_ne!(r, 0);
        assert_ne!(d, 0);
        assert_ne!(r, d);
        assert_eq!(r, workload_salt("retrieval"), "salt must be a pure function");
        assert_ne!(
            problem_seed(7, "retrieval", "p"),
            problem_seed(7, "dispersion", "p"),
            "same id under different workloads must not collide"
        );
    }

    #[test]
    fn registry_resolves_and_rejects() {
        for w in WORKLOADS {
            assert_eq!(resolve(w), Some(w));
        }
        assert_eq!(resolve("tsp"), None);
        assert_eq!(WORKLOADS[0], "es", "ES stays the default/first workload");
    }

    #[test]
    fn lower_salts_seed_and_overrides_cardinality() {
        let p = dispersion::DispersionProblem::generate("d-1", 9, 12, 4).unwrap();
        let base = PipelineConfig::default();
        let l = lower(&p, &base).unwrap();
        assert_eq!(l.cfg.summary_len, 4);
        assert_eq!(l.cfg.lambda, 1.0, "dispersion cost is fully weighted");
        assert_eq!(l.cfg.seed, problem_seed(base.seed, "dispersion", "d-1"));
        assert_eq!(l.doc.sentences.len(), 12);
        assert_eq!(l.tag, workload_tag("dispersion"));
    }

    #[test]
    fn lower_coerces_streaming_to_window_for_non_es() {
        let p = dispersion::DispersionProblem::generate("d-2", 1, 10, 3).unwrap();
        let base = PipelineConfig {
            strategy: Strategy::Streaming,
            ..PipelineConfig::default()
        };
        let l = lower(&p, &base).unwrap();
        assert_eq!(l.cfg.strategy, Strategy::Window);
    }

    #[test]
    fn fixed_scores_rejects_length_mismatch() {
        let s = Scores {
            mu: vec![0.5; 3],
            beta: vec![0.0; 9],
        };
        let mut f = FixedScores::new(s);
        assert!(f.scores(&["a".into()]).is_err());
        assert!(f.scores(&["a".into(), "b".into(), "c".into()]).is_ok());
    }

    #[test]
    fn request_factory_builds_and_rejects() {
        let cfg = WorkloadConfig::default();
        let lines: Vec<String> = ["what is an ising machine", "p one", "p two", "p three"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let p = problem_from_request("retrieval", "req-1", &lines, &cfg).unwrap();
        assert_eq!(p.workload(), "retrieval");
        assert_eq!(p.candidates().len(), 3);

        let spec = vec!["n=10 k=3 seed=5".to_string()];
        let p = problem_from_request("dispersion", "req-2", &spec, &cfg).unwrap();
        assert_eq!(p.workload(), "dispersion");
        assert_eq!(p.k(), 3);

        assert!(problem_from_request("tsp", "req-3", &lines, &cfg).is_err());
        assert!(problem_from_request("es", "req-4", &lines, &cfg).is_err());
    }
}
