//! Facility-dispersion / feature-selection workload: pure max-dispersion
//! k-of-n, promoting the resilience calibrator's probe generator
//! ([`facility_dispersion`]) to a first-class served workload.
//!
//! Value is per-site quality, cost is pairwise closeness (1 − distance),
//! so a selection maximizes quality while spreading the chosen sites —
//! the feature-selection reading is identical with "site" = feature,
//! "closeness" = feature correlation. The cost matrix is already fully
//! weighted, so the lowering pins λ = 1.0 ([`KOfNProblem::lambda`])
//! instead of inheriting the ES trade-off knob.
//!
//! Instances are generated, not ingested: a problem is fully determined
//! by (id, seed, n, k), which is what makes byte-identical golden
//! fixtures and cross-shape conformance possible for this workload.

use anyhow::{bail, ensure, Result};

use crate::config::WorkloadConfig;
use crate::embed::Scores;
use crate::ising::kofn::{facility_dispersion, KofnProblem};
use crate::text::MAX_SENTENCES;
use crate::util::rng::Pcg32;

use super::KOfNProblem;

/// RNG stream id for dispersion instance generation (decorrelates the
/// generator from the quantization/client streams sharing a seed).
const DISPERSION_STREAM: u64 = 0xD155;

/// A generated dispersion instance plus its identity.
pub struct DispersionProblem {
    id: String,
    inner: KofnProblem,
}

impl DispersionProblem {
    /// Generate the instance determined by `(seed, n, k)`. `n` is capped
    /// by the executors' candidate clamp; `k` must satisfy `1 <= k < n`.
    pub fn generate(id: &str, seed: u64, n: usize, k: usize) -> Result<Self> {
        ensure!(
            (2..=MAX_SENTENCES).contains(&n),
            "dispersion needs 2..={MAX_SENTENCES} sites, got n={n}"
        );
        ensure!((1..n).contains(&k), "dispersion asked for k={k} of n={n}");
        let mut rng = Pcg32::new(seed, DISPERSION_STREAM);
        Ok(Self {
            id: id.to_string(),
            inner: facility_dispersion(&mut rng, n, k),
        })
    }

    /// The underlying generic instance (experiments score against its
    /// exact bounds).
    pub fn instance(&self) -> &KofnProblem {
        &self.inner
    }
}

impl KOfNProblem for DispersionProblem {
    fn workload(&self) -> &'static str {
        "dispersion"
    }

    fn id(&self) -> &str {
        &self.id
    }

    fn candidates(&self) -> Vec<String> {
        self.inner
            .value
            .iter()
            .enumerate()
            .map(|(i, v)| format!("site {i:02} value {v:.4}"))
            .collect()
    }

    fn k(&self) -> usize {
        self.inner.k
    }

    fn lambda(&self) -> Option<f32> {
        // cost already carries its full weight (KofnProblem::as_es)
        Some(1.0)
    }

    fn scores(&self) -> Result<Scores> {
        Ok(Scores {
            mu: self.inner.value.clone(),
            beta: self.inner.cost.clone(),
        })
    }
}

/// Parsed `::WORKLOAD dispersion::` request spec: one line of
/// `key=value` tokens (`n=`, `k=`, `seed=`), each optional, falling back
/// to the `[workload]` config defaults and seed 0.
pub struct DispersionSpec {
    /// Site count.
    pub n: usize,
    /// Selection cardinality.
    pub k: usize,
    /// Instance generation seed.
    pub seed: u64,
}

impl DispersionSpec {
    /// Parse a spec line like `n=16 k=4 seed=7`. Unknown tokens are
    /// errors (typos must not silently become defaults).
    pub fn parse(line: &str, cfg: &WorkloadConfig) -> Result<Self> {
        let mut spec = Self {
            n: cfg.dispersion_n,
            k: cfg.dispersion_k,
            seed: 0,
        };
        for tok in line.split_whitespace() {
            if let Some(v) = tok.strip_prefix("n=") {
                spec.n = v.parse()?;
            } else if let Some(v) = tok.strip_prefix("k=") {
                spec.k = v.parse()?;
            } else if let Some(v) = tok.strip_prefix("seed=") {
                spec.seed = v.parse()?;
            } else {
                bail!("unknown dispersion spec token '{tok}' (expected n=/k=/seed=)");
            }
        }
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Settings;
    use crate::workload::select_inline;

    #[test]
    fn generation_is_a_pure_function_of_seed_n_k() {
        let a = DispersionProblem::generate("d", 42, 16, 4).unwrap();
        let b = DispersionProblem::generate("d", 42, 16, 4).unwrap();
        let c = DispersionProblem::generate("d", 43, 16, 4).unwrap();
        assert_eq!(a.scores().unwrap().mu, b.scores().unwrap().mu);
        assert_eq!(a.scores().unwrap().beta, b.scores().unwrap().beta);
        assert_eq!(a.candidates(), b.candidates());
        assert_ne!(a.scores().unwrap().mu, c.scores().unwrap().mu);
    }

    #[test]
    fn scores_satisfy_the_contract() {
        let p = DispersionProblem::generate("d", 7, 12, 3).unwrap();
        let s = p.scores().unwrap();
        assert_eq!(s.n(), 12);
        for i in 0..12 {
            assert_eq!(s.beta[i * 12 + i], 0.0, "zero diagonal");
            for j in 0..12 {
                assert_eq!(s.beta[i * 12 + j], s.beta[j * 12 + i], "symmetry");
            }
        }
    }

    #[test]
    fn spec_parses_and_rejects() {
        let cfg = WorkloadConfig::default();
        let s = DispersionSpec::parse("n=10 k=3 seed=5", &cfg).unwrap();
        assert_eq!((s.n, s.k, s.seed), (10, 3, 5));
        let d = DispersionSpec::parse("", &cfg).unwrap();
        assert_eq!((d.n, d.k, d.seed), (cfg.dispersion_n, cfg.dispersion_k, 0));
        assert!(DispersionSpec::parse("m=9", &cfg).is_err());
        assert!(DispersionSpec::parse("n=ten", &cfg).is_err());
    }

    #[test]
    fn validation_rejects_bad_shapes() {
        assert!(DispersionProblem::generate("d", 1, 1, 1).is_err());
        assert!(DispersionProblem::generate("d", 1, 8, 0).is_err());
        assert!(DispersionProblem::generate("d", 1, 8, 8).is_err());
    }

    #[test]
    fn end_to_end_selection_is_feasible_and_deterministic() {
        let mut s = Settings::default();
        s.pipeline.solver = "tabu".into();
        s.pipeline.iterations = 3;
        let p = DispersionProblem::generate("d-e2e", 11, 16, 4).unwrap();
        let a = select_inline(&p, &s, None).unwrap();
        let b = select_inline(&p, &s, None).unwrap();
        assert_eq!(a.selected.len(), 4);
        assert!(a.selected.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(a.selected, b.selected);
        assert_eq!(a.objective.to_bits(), b.objective.to_bits());
    }
}
