//! Diverse-retrieval selection: pick the k passages most relevant to a
//! query and least redundant with each other — RAG context assembly as a
//! k-of-n problem.
//!
//! Relevance is query-biased: `mu_i = cos(e_query, e_passage_i)`, so the
//! query tilts the linear field h of the lowered Ising instance, and the
//! improved formulation's median shift
//! ([`kofn_bias`](crate::ising::kofn_bias)) rebalances that biased h
//! against the couplings exactly as it does for ES — the paper's Eq. 12
//! rule applied to a problem it never saw. Redundancy is the passage
//! pairwise cosine matrix, zero diagonal, symmetric — the [`Scores`]
//! contract — so selected passages repel near-duplicates.
//!
//! λ is inherited from `[pipeline] lambda`: the relevance/diversity
//! trade-off is a serving knob, same as ES.

use anyhow::{ensure, Result};

use crate::embed::hash_embed::EMBED_DIM;
use crate::embed::similarity::{dot, norm};
use crate::embed::{HashEmbedder, Scores};
use crate::text::MAX_SENTENCES;

use super::KOfNProblem;

/// One diverse-retrieval request: a query, candidate passages, and the
/// context budget k.
pub struct RetrievalProblem {
    id: String,
    query: String,
    passages: Vec<String>,
    k: usize,
}

impl RetrievalProblem {
    /// Validate and build. `k` must satisfy `1 <= k <= passages.len()`;
    /// the candidate count is bounded by the executors' sentence clamp.
    pub fn new(id: &str, query: &str, passages: Vec<String>, k: usize) -> Result<Self> {
        ensure!(!query.trim().is_empty(), "retrieval query is empty");
        ensure!(!passages.is_empty(), "retrieval has no candidate passages");
        ensure!(
            passages.len() <= MAX_SENTENCES,
            "retrieval has {} passages (max {MAX_SENTENCES})",
            passages.len()
        );
        ensure!(
            (1..=passages.len()).contains(&k),
            "retrieval asked for k={k} of {} passages",
            passages.len()
        );
        Ok(Self {
            id: id.to_string(),
            query: query.to_string(),
            passages,
            k,
        })
    }

    /// The query string.
    pub fn query(&self) -> &str {
        &self.query
    }
}

impl KOfNProblem for RetrievalProblem {
    fn workload(&self) -> &'static str {
        "retrieval"
    }

    fn id(&self) -> &str {
        &self.id
    }

    fn candidates(&self) -> Vec<String> {
        self.passages.clone()
    }

    fn k(&self) -> usize {
        self.k
    }

    fn scores(&self) -> Result<Scores> {
        let e = HashEmbedder::new();
        let unit = |s: &str| -> Vec<f32> {
            let mut v = e.embed_sentence(s);
            let nn = norm(&v).max(1e-12);
            for x in v.iter_mut() {
                *x /= nn;
            }
            v
        };
        let q = unit(&self.query);
        let rows: Vec<Vec<f32>> = self.passages.iter().map(|p| unit(p)).collect();
        let n = rows.len();
        debug_assert!(rows.iter().all(|r| r.len() == EMBED_DIM));
        let mu: Vec<f32> = rows.iter().map(|r| dot(r, &q)).collect();
        let mut beta = vec![0.0f32; n * n];
        for i in 0..n {
            for j in (i + 1)..n {
                let b = dot(&rows[i], &rows[j]);
                beta[i * n + j] = b;
                beta[j * n + i] = b;
            }
        }
        Ok(Scores { mu, beta })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Settings;
    use crate::workload::select_inline;

    fn passages(n: usize) -> Vec<String> {
        (0..n)
            .map(|i| format!("passage {i} covers oscillator phase dynamics topic {}", i % 3))
            .collect()
    }

    #[test]
    fn scores_are_deterministic_and_well_formed() {
        let p = RetrievalProblem::new("r-1", "oscillator phase", passages(8), 3).unwrap();
        let a = p.scores().unwrap();
        let b = p.scores().unwrap();
        assert_eq!(a.mu, b.mu);
        assert_eq!(a.beta, b.beta);
        assert_eq!(a.n(), 8);
        for i in 0..8 {
            assert_eq!(a.beta[i * 8 + i], 0.0, "diagonal must stay zero");
            for j in 0..8 {
                assert_eq!(a.beta[i * 8 + j], a.beta[j * 8 + i], "symmetry ({i},{j})");
            }
        }
        for &m in &a.mu {
            assert!(m.abs() <= 1.0 + 1e-5, "cosine out of range: {m}");
        }
    }

    #[test]
    fn query_changes_relevance_not_redundancy() {
        let pa = RetrievalProblem::new("r-2", "phase dynamics", passages(6), 2).unwrap();
        let pb = RetrievalProblem::new("r-2", "completely different words", passages(6), 2).unwrap();
        let sa = pa.scores().unwrap();
        let sb = pb.scores().unwrap();
        assert_ne!(sa.mu, sb.mu, "query must bias relevance");
        assert_eq!(sa.beta, sb.beta, "redundancy is query-independent");
    }

    #[test]
    fn validation_rejects_bad_requests() {
        assert!(RetrievalProblem::new("x", "  ", passages(4), 2).is_err());
        assert!(RetrievalProblem::new("x", "q", Vec::new(), 1).is_err());
        assert!(RetrievalProblem::new("x", "q", passages(4), 0).is_err());
        assert!(RetrievalProblem::new("x", "q", passages(4), 5).is_err());
    }

    #[test]
    fn end_to_end_selection_is_feasible_and_deterministic() {
        let mut s = Settings::default();
        s.pipeline.solver = "tabu".into();
        s.pipeline.iterations = 3;
        let p = RetrievalProblem::new("r-e2e", "ising machine hardware", passages(14), 4).unwrap();
        let a = select_inline(&p, &s, None).unwrap();
        let b = select_inline(&p, &s, None).unwrap();
        assert_eq!(a.selected.len(), 4);
        assert!(a.selected.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(a.selected, b.selected);
        assert_eq!(a.objective.to_bits(), b.objective.to_bits());
        assert_eq!(a.sentences.len(), 4, "selected passages come back verbatim");
    }
}
