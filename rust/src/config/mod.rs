//! Typed configuration for the pipeline, device model and experiments.
//!
//! Layering (lowest to highest precedence): compiled-in defaults →
//! `cobi-es.toml` (or `--config <path>`) → `COBI_ES_*` environment
//! overrides → CLI flags. Every knob the paper's workflow exposes lives
//! here so experiments are reproducible from a single file.

pub mod toml;

use std::path::Path;

use anyhow::{Context, Result};

use crate::decompose::Strategy;
use crate::quant::{Precision, Rounding};

/// COBI device-model parameters (defaults follow the published chip:
/// 48/59-node all-to-all array, 5-bit signed couplings, ~200 µs/solve,
/// 24–25 mW [Lo+ 2023; Cılasun+ 2025]).
#[derive(Debug, Clone, PartialEq)]
pub struct CobiConfig {
    /// Physical spins available on the array.
    pub max_spins: usize,
    /// Coupling/field integer range (symmetric): [-weight_range, +weight_range].
    pub weight_range: i32,
    /// Modeled wall-clock per hardware solve, seconds.
    pub solve_time_s: f64,
    /// Modeled device power, watts.
    pub power_w: f64,
    /// Oscillator-simulation noise amplitude (run-to-run variability).
    pub noise_amp: f32,
    /// Annealer dynamics: coupling gain, SHIL max, Euler dt.
    pub k_coupling: f32,
    /// SHIL (sub-harmonic injection locking) ramp maximum.
    pub k_shil_max: f32,
    /// Euler integration step.
    pub dt: f32,
    /// Backend: "hlo" (PJRT anneal artifact) or "native" (pure-rust mirror).
    pub backend: String,
}

impl Default for CobiConfig {
    fn default() -> Self {
        Self {
            max_spins: 59,
            weight_range: 14,
            solve_time_s: 200e-6,
            power_w: 25e-3,
            noise_amp: 0.10,
            k_coupling: 2.0,
            k_shil_max: 1.5,
            dt: 0.05,
            backend: "native".into(),
        }
    }
}

/// ES pipeline parameters (paper §III–§IV).
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineConfig {
    /// Redundancy weight λ in Eq. 3.
    pub lambda: f32,
    /// Use the improved (bias-term) formulation of Eq. 10–12.
    pub improved_formulation: bool,
    /// Solve precision for the quantized instance.
    pub precision: Precision,
    /// Rounding scheme for quantization (§IV-A).
    pub rounding: Rounding,
    /// Refinement iterations per Ising instance.
    pub iterations: usize,
    /// Decomposition window P and target Q (§IV-B); decomposition is
    /// bypassed when the document already fits (n <= p).
    pub decompose_p: usize,
    /// Decomposition target Q (see [`PipelineConfig::decompose_p`]).
    pub decompose_q: usize,
    /// Decomposition strategy (TOML: `[decompose] strategy =
    /// "window|tree|stream"`): `window` is the paper's sliding reduction
    /// (byte-identical reference), `tree` the balanced hierarchical
    /// merge, `stream` the incremental rolling-frontier planner.
    pub strategy: Strategy,
    /// Final summary length M.
    pub summary_len: usize,
    /// Solver for quantized instances: "cobi", "tabu", "brute", "exact",
    /// "random", "sa", "snowball".
    pub solver: String,
    /// Master seed for all pipeline randomness.
    pub seed: u64,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self {
            lambda: 0.6,
            improved_formulation: true,
            precision: Precision::CobiInt,
            rounding: Rounding::Stochastic,
            iterations: 10,
            decompose_p: 20,
            decompose_q: 10,
            strategy: Strategy::Window,
            summary_len: 6,
            solver: "cobi".into(),
            seed: 0xC0B1,
        }
    }
}

impl PipelineConfig {
    /// Refinement parameters implied by this config (shared by the inline
    /// pipeline and the sched executor so the two paths cannot drift).
    pub fn refine_config(&self) -> crate::refine::RefineConfig {
        crate::refine::RefineConfig {
            formulation: if self.improved_formulation {
                crate::ising::Formulation::Improved
            } else {
                crate::ising::Formulation::Original
            },
            precision: self.precision,
            rounding: self.rounding,
            iterations: self.iterations,
        }
    }

    /// Decomposition parameters implied by this config.
    pub fn decompose_params(&self) -> crate::decompose::DecomposeParams {
        crate::decompose::DecomposeParams {
            p: self.decompose_p,
            q: self.decompose_q,
            m: self.summary_len,
        }
    }
}

/// Timing/energy model constants for TTS/ETS (paper §V).
#[derive(Debug, Clone, PartialEq)]
pub struct TimingConfig {
    /// CPU power for software solvers and objective evaluation, watts.
    pub cpu_power_w: f64,
    /// Modeled Tabu runtime per solve, seconds (paper: ~25 ms).
    pub tabu_time_s: f64,
    /// Objective-evaluation time per iteration, seconds (paper: 18.9 µs).
    pub eval_time_s: f64,
    /// Target success probability for TTS (paper: 0.95).
    pub p_target: f64,
    /// Success threshold on the normalized objective (paper: 0.9).
    pub success_threshold: f64,
}

impl Default for TimingConfig {
    fn default() -> Self {
        Self {
            cpu_power_w: 20.0,
            tabu_time_s: 25e-3,
            eval_time_s: 18.9e-6,
            p_target: 0.95,
            success_threshold: 0.9,
        }
    }
}

/// Service (edge deployment) parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct ServiceConfig {
    /// Worker threads pulling solve batches.
    pub workers: usize,
    /// Max requests queued before backpressure rejects.
    pub queue_depth: usize,
    /// Max subproblems fused into one device batch.
    pub max_batch: usize,
    /// Batch linger: how long the batcher waits to fill a batch.
    pub linger_us: u64,
    /// Default per-request deadline in milliseconds applied to requests
    /// that carry none of their own (0 = no default deadline). Clients
    /// override per request with the `::DEADLINE <ms>::` header.
    pub default_deadline_ms: u64,
    /// TCP read/idle timeout in milliseconds for connections and
    /// `::STREAM::` sessions (0 = never time out). A stalled client is
    /// answered with `ERR idle timeout` and disconnected.
    pub idle_timeout_ms: u64,
    /// Estimated-queue-wait watermark in milliseconds above which
    /// batch-tier requests are shed with `ERR RETRY <after_ms>`
    /// (0 = shedding off; interactive requests shed only at
    /// [`overload::INTERACTIVE_SHED_FACTOR`](crate::service::overload)
    /// times this watermark).
    pub shed_watermark_ms: u64,
    /// Graceful-drain budget in milliseconds: how long `shutdown`/drain
    /// waits for in-flight requests before failing the stragglers.
    pub drain_deadline_ms: u64,
    /// Largest accepted document in bytes on the TCP endpoint
    /// (0 = unlimited). Oversized uploads get a clean `ERR` reply.
    pub max_doc_bytes: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            queue_depth: 256,
            max_batch: 8,
            linger_us: 200,
            default_deadline_ms: 0,
            idle_timeout_ms: 30_000,
            shed_watermark_ms: 0,
            drain_deadline_ms: 5_000,
            max_doc_bytes: 1 << 20,
        }
    }
}

/// Per-device circuit-breaker parameters (`sched::breaker`): a rolling
/// failure window per pool device, fed by dispatch errors and the
/// resilience layer's verification rejections. Tripping quarantines the
/// device out of the drain loop; the `resilience::Calibrator` is the
/// half-open probe that readmits (or, after `max_trips`, retires) it.
#[derive(Debug, Clone, PartialEq)]
pub struct BreakerConfig {
    /// Enable the per-device circuit breaker (default off: the pool's
    /// drain loop is byte-identical to every pre-breaker release).
    pub enabled: bool,
    /// Rolling window length in dispatch/verify samples per device.
    pub window: usize,
    /// Failure samples within the window that trip the breaker.
    pub trip_failures: u32,
    /// Quarantine time in milliseconds before the half-open probe runs.
    pub cooldown_ms: u64,
    /// Trips after which a device is permanently retired (the last
    /// healthy device is never retired — it keeps probing instead).
    pub max_trips: u32,
    /// Minimum calibration success rate the half-open probe must measure
    /// to readmit a quarantined device.
    pub probe_target: f64,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            window: 16,
            trip_failures: 8,
            cooldown_ms: 200,
            max_trips: 3,
            probe_target: 0.5,
        }
    }
}

/// Subproblem scheduler / device pool parameters (`sched::DevicePool`).
#[derive(Debug, Clone, PartialEq)]
pub struct SchedConfig {
    /// Route service Ising solves through the shared device pool
    /// (ignored — falls back to worker-private solvers — when the
    /// pipeline solver is not pool-capable, e.g. brute/exact/random).
    pub enabled: bool,
    /// Solver instances owned by the pool.
    pub devices: usize,
    /// Max requests coalesced into one device dispatch.
    pub max_coalesce: usize,
    /// Flush timeout: how long a device lingers to fill a dispatch, µs.
    /// 0 = dispatch immediately (lowest latency, least batching).
    pub linger_us: u64,
    /// Bound on queued solve requests (submitters block when full).
    pub queue_depth: usize,
    /// Pool solver backend: "auto" (= pipeline.solver), "cobi", "tabu",
    /// "sa", "snowball", "portfolio".
    pub backend: String,
    /// Per-device circuit breaker (the `breaker_*` keys).
    pub breaker: BreakerConfig,
}

impl Default for SchedConfig {
    fn default() -> Self {
        Self {
            enabled: true,
            devices: 2,
            max_coalesce: 8,
            linger_us: 200,
            queue_depth: 1024,
            backend: "auto".into(),
            breaker: BreakerConfig::default(),
        }
    }
}

/// Adaptive solver-portfolio + warm-start-cache parameters
/// (`portfolio::SolverPortfolio`).
#[derive(Debug, Clone, PartialEq)]
pub struct PortfolioConfig {
    /// Route pool solves through the adaptive portfolio. When false the
    /// pool hosts the single resolved backend exactly as before (PR-1
    /// architecture); `[sched] backend = "portfolio"` also enables it.
    pub enabled: bool,
    /// Routing policy: "static" | "size-tiered" | "bandit".
    pub policy: String,
    /// Backend for `policy = "static"`: "cobi"|"tabu"|"sa"|"greedy"|"exact".
    pub static_backend: String,
    /// Bandit exploration rate in [0, 1] (epsilon-greedy).
    pub epsilon: f64,
    /// Largest instance routed to the exhaustive exact backend
    /// (clamped internally to keep 2^n enumeration sane).
    pub exact_max_n: usize,
    /// Fleet-wide warm-start cache. NOTE: with the cache on, results
    /// depend on service history — disable it (and use `policy =
    /// "static"`) to keep the byte-replay determinism contract.
    pub cache: bool,
    /// Bound on cached solved instances (FIFO eviction past it).
    pub cache_capacity: usize,
    /// Bandit score weight of mean latency (s) against mean energy/spin.
    pub latency_weight: f64,
}

impl Default for PortfolioConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            policy: "static".into(),
            static_backend: "cobi".into(),
            epsilon: 0.1,
            exact_max_n: 12,
            cache: true,
            cache_capacity: 4096,
            latency_weight: 1.0,
        }
    }
}

/// COBI hardware fault-model parameters (`[resilience]` — the `fault_*`
/// keys). Deterministic, seed-derived non-idealities injected into the
/// simulated device: real CMOS oscillator arrays drift, stick, and carry
/// per-line DAC mismatch; this models them without giving up
/// byte-reproducibility (DESIGN.md decision #16).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// Master switch: inject faults into COBI solves (default off — the
    /// clean device is byte-identical to every pre-fault release).
    pub enabled: bool,
    /// Per-oscillator probability of being stuck at a fixed spin for one
    /// solve.
    pub stuck_rate: f32,
    /// Per-coupling probability of multiplicative drift for one solve.
    pub drift_rate: f32,
    /// Drift magnitude: a drifted coupling is scaled by
    /// `1 + drift_amp * u`, `u` uniform in [-1, 1).
    pub drift_amp: f32,
    /// Per-line DAC gain mismatch amplitude: line `i` programs with gain
    /// `1 + dac_mismatch * u_i` applied to `h_i` and every `J_ij`
    /// (0 disables the stage and consumes no fault draws).
    pub dac_mismatch: f32,
    /// Per-solve probability of a burst-noise event (a window of anneal
    /// steps with amplified phase noise).
    pub burst_rate: f32,
    /// Burst amplification factor applied to the noise window.
    pub burst_amp: f32,
    /// Fault-stream seed, mixed with each request seed so fault draws are
    /// reproducible per request and independent of co-batching.
    pub seed: u64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            stuck_rate: 0.02,
            drift_rate: 0.02,
            drift_amp: 0.15,
            dac_mismatch: 0.05,
            burst_rate: 0.05,
            burst_amp: 4.0,
            seed: 0xFA17,
        }
    }
}

/// Resilience-layer parameters (`resilience::ResilientSolver` +
/// `resilience::Calibrator`): replicated solves with energy-verified
/// voting, software verify-and-retry, and startup calibration.
#[derive(Debug, Clone, PartialEq)]
pub struct ResilienceConfig {
    /// Wrap pool solvers in the resilience layer (default off: the raw
    /// backends keep every existing determinism pin byte-identical).
    pub enabled: bool,
    /// Replicated solves per request (1 = no replication). Overridden per
    /// device by calibration when `calibrate = true`.
    pub replication: usize,
    /// Ceiling on calibration-chosen replication.
    pub max_replication: usize,
    /// Fresh-seed re-dispatches before escalating to the software
    /// fallback (tabu) when a dispatch fails or verification rejects
    /// every replica.
    pub retries: usize,
    /// Software energy verification: recompute each replica's energy and
    /// vote on the verified value; a replica whose reported energy
    /// mismatches its spins is rejected.
    pub verify: bool,
    /// Spin-repair the vote winner with a deterministic greedy descent
    /// (fixes stuck-node damage; never returns worse than the winner).
    pub repair: bool,
    /// Probe devices with known-ground-truth k-of-n instances at startup
    /// and set the replication factor per device.
    pub calibrate: bool,
    /// Calibration probe instances per device.
    pub calibration_probes: usize,
    /// Target per-request success probability the calibrated replication
    /// factor must reach.
    pub calibration_target: f64,
    /// Hardware fault-model parameters (the `fault_*` keys).
    pub fault: FaultConfig,
}

impl Default for ResilienceConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            replication: 1,
            max_replication: 5,
            retries: 2,
            verify: true,
            repair: true,
            calibrate: false,
            calibration_probes: 8,
            calibration_target: 0.9,
            fault: FaultConfig::default(),
        }
    }
}

/// Observability parameters (`obs::ObsShared`): request-scoped span
/// tracing and its bounded buffers. The fleet energy ledger and the
/// slow-request exemplar store are always on (O(1)-memory counters);
/// this section only governs span recording.
#[derive(Debug, Clone, PartialEq)]
pub struct ObsConfig {
    /// Record per-request span trees (default off: the serving hot path
    /// allocates nothing for tracing until this is set, pinned by
    /// `tests/alloc_audit.rs`). `serve --trace-out <path>` also enables
    /// it.
    pub enabled: bool,
    /// Bound on buffered span trees (oldest overwritten past it).
    pub ring_capacity: usize,
    /// Slowest-request exemplars kept for `::STATS::`.
    pub exemplars: usize,
    /// JSONL trace-dump path ("" = no dump). CLI: `--trace-out`.
    pub trace_out: String,
    /// Record per-request provenance (flight recorder: seeds, hashes,
    /// per-node solve taps) for deterministic replay (default off: the
    /// serving hot path allocates nothing for recording until this is
    /// set, pinned by `tests/alloc_audit.rs`). `serve --record-out
    /// <path>` also enables it.
    pub record_enabled: bool,
    /// Bound on buffered request records (oldest overwritten past it).
    pub record_capacity: usize,
    /// JSONL record-dump path ("" = no dump). CLI: `--record-out`.
    pub record_out: String,
}

impl Default for ObsConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            ring_capacity: 256,
            exemplars: 8,
            trace_out: String::new(),
            record_enabled: false,
            record_capacity: 256,
            record_out: String::new(),
        }
    }
}

/// Snowball solver tuning (`[solvers.snowball]`): the sharded
/// parallel-spin MCMC backend (`solvers::snowball::SnowballSolver`).
/// Every field mirrors [`crate::solvers::snowball::SnowballConfig`];
/// `threads` is a wall-clock knob only — results are bit-identical for
/// every value (logical asynchrony, DESIGN.md decision #19).
#[derive(Debug, Clone, PartialEq)]
pub struct SnowballSettings {
    /// Logical parallel units the spin vector is sharded across.
    pub shards: usize,
    /// Barrier-to-barrier epochs per restart.
    pub epochs: usize,
    /// Largest n solved in uniform sweep mode; above it, focus mode.
    pub focus_threshold: usize,
    /// Per-spin participation probability in uniform sweep mode.
    pub participation: f64,
    /// Initial temperature of the geometric Metropolis cooling.
    pub t_start: f64,
    /// Final temperature of the geometric Metropolis cooling.
    pub t_end: f64,
    /// Independent restarts per solve.
    pub restarts: usize,
    /// Physical worker threads for shard epochs; 0 = read
    /// `COBI_SNOWBALL_THREADS`, default 1. Never affects results.
    pub threads: usize,
}

impl Default for SnowballSettings {
    fn default() -> Self {
        let d = crate::solvers::snowball::SnowballConfig::default();
        Self {
            shards: d.shards,
            epochs: d.epochs,
            focus_threshold: d.focus_threshold,
            participation: d.participation,
            t_start: d.t_start,
            t_end: d.t_end,
            restarts: d.restarts,
            threads: d.threads,
        }
    }
}

impl SnowballSettings {
    /// The solver-side parameter struct these settings configure.
    pub fn solver_config(&self) -> crate::solvers::snowball::SnowballConfig {
        crate::solvers::snowball::SnowballConfig {
            shards: self.shards,
            epochs: self.epochs,
            focus_threshold: self.focus_threshold,
            participation: self.participation,
            t_start: self.t_start,
            t_end: self.t_end,
            restarts: self.restarts,
            threads: self.threads,
        }
    }
}

/// Per-backend solver tuning (`[solvers.*]` sections). Only backends with
/// meaningful knobs beyond their seed live here; the classic backends
/// (tabu, sa, greedy, exact) keep their compiled-in defaults.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SolversConfig {
    /// Snowball sharded parallel-spin solver (`[solvers.snowball]`).
    pub snowball: SnowballSettings,
}

/// k-of-n workload platform parameters (`[workload]`): which registered
/// workload untagged requests resolve to, and the generated-instance
/// defaults for requests that do not spell their own shape (see
/// `crate::workload`).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadConfig {
    /// Workload served by default (TCP requests without a
    /// `::WORKLOAD <name>::` header, CLI without `--workload`).
    /// Must name a registered workload; "es" preserves every legacy path.
    pub default: String,
    /// Context budget k for `::WORKLOAD retrieval::` requests that give a
    /// query + passages without a k of their own.
    pub retrieval_k: usize,
    /// Site count n for dispersion requests without an `n=` token.
    pub dispersion_n: usize,
    /// Selection cardinality k for dispersion requests without a `k=`.
    pub dispersion_k: usize,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        Self {
            default: "es".into(),
            retrieval_k: 4,
            dispersion_n: 16,
            dispersion_k: 4,
        }
    }
}

/// Root settings object.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Settings {
    /// COBI device-model parameters.
    pub cobi: CobiConfig,
    /// ES pipeline parameters.
    pub pipeline: PipelineConfig,
    /// Timing/energy model constants.
    pub timing: TimingConfig,
    /// Edge-service parameters.
    pub service: ServiceConfig,
    /// Subproblem scheduler / device pool parameters.
    pub sched: SchedConfig,
    /// Solver portfolio + warm-start cache parameters.
    pub portfolio: PortfolioConfig,
    /// Per-backend solver tuning (`[solvers.*]`).
    pub solvers: SolversConfig,
    /// Hardware fault model + resilience-layer parameters.
    pub resilience: ResilienceConfig,
    /// Observability (span tracing) parameters.
    pub obs: ObsConfig,
    /// k-of-n workload platform parameters (`[workload]`).
    pub workload: WorkloadConfig,
    /// Directory containing AOT artifacts (manifest.txt etc.).
    pub artifacts_dir: String,
}

impl Settings {
    /// Load settings from a TOML file over the compiled-in defaults.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let doc = toml::Document::parse(&text)
            .with_context(|| format!("parsing config {}", path.display()))?;
        let mut s = Settings::default();
        s.apply(&doc)?;
        Ok(s)
    }

    /// Apply a parsed document over the current values.
    pub fn apply(&mut self, doc: &toml::Document) -> Result<()> {
        macro_rules! set {
            // strings / bools / f64 pass through; usize fields use `usize`
            ($field:expr, get_i64, $key:expr) => {
                if let Some(v) = doc.get_i64($key) {
                    $field = v as usize;
                }
            };
            ($field:expr, get_str, $key:expr) => {
                if let Some(v) = doc.get_str($key) {
                    $field = v.to_string();
                }
            };
            ($field:expr, $get:ident, $key:expr) => {
                if let Some(v) = doc.$get($key) {
                    $field = v;
                }
            };
        }
        set!(self.artifacts_dir, get_str, "artifacts_dir");

        set!(self.cobi.max_spins, get_i64, "cobi.max_spins");
        if let Some(v) = doc.get_i64("cobi.weight_range") {
            self.cobi.weight_range = v as i32;
        }
        set!(self.cobi.solve_time_s, get_f64, "cobi.solve_time_s");
        set!(self.cobi.power_w, get_f64, "cobi.power_w");
        if let Some(v) = doc.get_f64("cobi.noise_amp") {
            self.cobi.noise_amp = v as f32;
        }
        if let Some(v) = doc.get_f64("cobi.k_coupling") {
            self.cobi.k_coupling = v as f32;
        }
        if let Some(v) = doc.get_f64("cobi.k_shil_max") {
            self.cobi.k_shil_max = v as f32;
        }
        if let Some(v) = doc.get_f64("cobi.dt") {
            self.cobi.dt = v as f32;
        }
        set!(self.cobi.backend, get_str, "cobi.backend");

        if let Some(v) = doc.get_f64("pipeline.lambda") {
            self.pipeline.lambda = v as f32;
        }
        set!(
            self.pipeline.improved_formulation,
            get_bool,
            "pipeline.improved_formulation"
        );
        if let Some(p) = doc.get_str("pipeline.precision") {
            self.pipeline.precision = p.parse().map_err(anyhow::Error::msg)?;
        }
        if let Some(r) = doc.get_str("pipeline.rounding") {
            self.pipeline.rounding = r.parse().map_err(anyhow::Error::msg)?;
        }
        set!(self.pipeline.iterations, get_i64, "pipeline.iterations");
        set!(self.pipeline.decompose_p, get_i64, "pipeline.decompose_p");
        set!(self.pipeline.decompose_q, get_i64, "pipeline.decompose_q");
        // `[decompose] strategy` is the canonical spelling; the
        // `[pipeline]` alias keeps single-section configs working.
        // Applied alias-first so the canonical key wins when both appear.
        for key in ["pipeline.strategy", "decompose.strategy"] {
            if let Some(s) = doc.get_str(key) {
                self.pipeline.strategy = s.parse().map_err(anyhow::Error::msg)?;
            }
        }
        set!(self.pipeline.summary_len, get_i64, "pipeline.summary_len");
        set!(self.pipeline.solver, get_str, "pipeline.solver");
        if let Some(v) = doc.get_i64("pipeline.seed") {
            self.pipeline.seed = v as u64;
        }

        set!(self.timing.cpu_power_w, get_f64, "timing.cpu_power_w");
        set!(self.timing.tabu_time_s, get_f64, "timing.tabu_time_s");
        set!(self.timing.eval_time_s, get_f64, "timing.eval_time_s");
        set!(self.timing.p_target, get_f64, "timing.p_target");
        set!(
            self.timing.success_threshold,
            get_f64,
            "timing.success_threshold"
        );

        set!(self.service.workers, get_i64, "service.workers");
        set!(self.service.queue_depth, get_i64, "service.queue_depth");
        set!(self.service.max_batch, get_i64, "service.max_batch");
        if let Some(v) = doc.get_i64("service.linger_us") {
            self.service.linger_us = v as u64;
        }
        if let Some(v) = doc.get_i64("service.default_deadline_ms") {
            self.service.default_deadline_ms = v as u64;
        }
        if let Some(v) = doc.get_i64("service.idle_timeout_ms") {
            self.service.idle_timeout_ms = v as u64;
        }
        if let Some(v) = doc.get_i64("service.shed_watermark_ms") {
            self.service.shed_watermark_ms = v as u64;
        }
        if let Some(v) = doc.get_i64("service.drain_deadline_ms") {
            self.service.drain_deadline_ms = v as u64;
        }
        set!(self.service.max_doc_bytes, get_i64, "service.max_doc_bytes");

        set!(self.sched.enabled, get_bool, "sched.enabled");
        set!(self.sched.devices, get_i64, "sched.devices");
        set!(self.sched.max_coalesce, get_i64, "sched.max_coalesce");
        if let Some(v) = doc.get_i64("sched.linger_us") {
            self.sched.linger_us = v as u64;
        }
        set!(self.sched.queue_depth, get_i64, "sched.queue_depth");
        set!(self.sched.backend, get_str, "sched.backend");
        set!(self.sched.breaker.enabled, get_bool, "sched.breaker_enabled");
        set!(self.sched.breaker.window, get_i64, "sched.breaker_window");
        if let Some(v) = doc.get_i64("sched.breaker_trip_failures") {
            self.sched.breaker.trip_failures = v as u32;
        }
        if let Some(v) = doc.get_i64("sched.breaker_cooldown_ms") {
            self.sched.breaker.cooldown_ms = v as u64;
        }
        if let Some(v) = doc.get_i64("sched.breaker_max_trips") {
            self.sched.breaker.max_trips = v as u32;
        }
        set!(
            self.sched.breaker.probe_target,
            get_f64,
            "sched.breaker_probe_target"
        );

        set!(self.portfolio.enabled, get_bool, "portfolio.enabled");
        set!(self.portfolio.policy, get_str, "portfolio.policy");
        set!(
            self.portfolio.static_backend,
            get_str,
            "portfolio.static_backend"
        );
        set!(self.portfolio.epsilon, get_f64, "portfolio.epsilon");
        set!(self.portfolio.exact_max_n, get_i64, "portfolio.exact_max_n");
        set!(self.portfolio.cache, get_bool, "portfolio.cache");
        set!(
            self.portfolio.cache_capacity,
            get_i64,
            "portfolio.cache_capacity"
        );
        set!(
            self.portfolio.latency_weight,
            get_f64,
            "portfolio.latency_weight"
        );

        set!(self.solvers.snowball.shards, get_i64, "solvers.snowball.shards");
        set!(self.solvers.snowball.epochs, get_i64, "solvers.snowball.epochs");
        set!(
            self.solvers.snowball.focus_threshold,
            get_i64,
            "solvers.snowball.focus_threshold"
        );
        set!(
            self.solvers.snowball.participation,
            get_f64,
            "solvers.snowball.participation"
        );
        set!(self.solvers.snowball.t_start, get_f64, "solvers.snowball.t_start");
        set!(self.solvers.snowball.t_end, get_f64, "solvers.snowball.t_end");
        set!(self.solvers.snowball.restarts, get_i64, "solvers.snowball.restarts");
        set!(self.solvers.snowball.threads, get_i64, "solvers.snowball.threads");

        set!(self.resilience.enabled, get_bool, "resilience.enabled");
        set!(self.resilience.replication, get_i64, "resilience.replication");
        set!(
            self.resilience.max_replication,
            get_i64,
            "resilience.max_replication"
        );
        set!(self.resilience.retries, get_i64, "resilience.retries");
        set!(self.resilience.verify, get_bool, "resilience.verify");
        set!(self.resilience.repair, get_bool, "resilience.repair");
        set!(self.resilience.calibrate, get_bool, "resilience.calibrate");
        set!(
            self.resilience.calibration_probes,
            get_i64,
            "resilience.calibration_probes"
        );
        set!(
            self.resilience.calibration_target,
            get_f64,
            "resilience.calibration_target"
        );
        set!(
            self.resilience.fault.enabled,
            get_bool,
            "resilience.fault_enabled"
        );
        macro_rules! set_f32 {
            ($field:expr, $key:expr) => {
                if let Some(v) = doc.get_f64($key) {
                    $field = v as f32;
                }
            };
        }
        set_f32!(self.resilience.fault.stuck_rate, "resilience.fault_stuck_rate");
        set_f32!(self.resilience.fault.drift_rate, "resilience.fault_drift_rate");
        set_f32!(self.resilience.fault.drift_amp, "resilience.fault_drift_amp");
        set_f32!(self.resilience.fault.dac_mismatch, "resilience.fault_dac_mismatch");
        set_f32!(self.resilience.fault.burst_rate, "resilience.fault_burst_rate");
        set_f32!(self.resilience.fault.burst_amp, "resilience.fault_burst_amp");
        if let Some(v) = doc.get_i64("resilience.fault_seed") {
            self.resilience.fault.seed = v as u64;
        }

        set!(self.obs.enabled, get_bool, "obs.enabled");
        set!(self.obs.ring_capacity, get_i64, "obs.ring_capacity");
        set!(self.obs.exemplars, get_i64, "obs.exemplars");
        set!(self.obs.trace_out, get_str, "obs.trace_out");
        set!(self.obs.record_enabled, get_bool, "obs.record_enabled");
        set!(self.obs.record_capacity, get_i64, "obs.record_capacity");
        set!(self.obs.record_out, get_str, "obs.record_out");

        set!(self.workload.default, get_str, "workload.default");
        set!(self.workload.retrieval_k, get_i64, "workload.retrieval_k");
        set!(self.workload.dispersion_n, get_i64, "workload.dispersion_n");
        set!(self.workload.dispersion_k, get_i64, "workload.dispersion_k");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_constants() {
        let s = Settings::default();
        assert_eq!(s.cobi.max_spins, 59);
        assert_eq!(s.cobi.weight_range, 14);
        assert!((s.cobi.solve_time_s - 200e-6).abs() < 1e-12);
        assert!((s.timing.tabu_time_s - 25e-3).abs() < 1e-12);
        assert!((s.timing.eval_time_s - 18.9e-6).abs() < 1e-12);
        assert_eq!(s.pipeline.decompose_p, 20);
        assert_eq!(s.pipeline.decompose_q, 10);
        assert_eq!(s.pipeline.summary_len, 6);
    }

    #[test]
    fn apply_overrides() {
        let doc = toml::Document::parse(
            r#"
[cobi]
max_spins = 48
noise_amp = 0.2
backend = "hlo"
[pipeline]
precision = "6bit"
rounding = "deterministic"
iterations = 50
[timing]
p_target = 0.99
"#,
        )
        .unwrap();
        let mut s = Settings::default();
        s.apply(&doc).unwrap();
        assert_eq!(s.cobi.max_spins, 48);
        assert_eq!(s.cobi.backend, "hlo");
        assert_eq!(s.pipeline.precision, Precision::Fixed(6));
        assert_eq!(s.pipeline.rounding, Rounding::Deterministic);
        assert_eq!(s.pipeline.iterations, 50);
        assert!((s.timing.p_target - 0.99).abs() < 1e-12);
    }

    #[test]
    fn sched_defaults_and_overrides() {
        let s = Settings::default();
        assert!(s.sched.enabled);
        assert_eq!(s.sched.devices, 2);
        assert_eq!(s.sched.max_coalesce, 8);
        assert_eq!(s.sched.backend, "auto");

        let doc = toml::Document::parse(
            r#"
[sched]
enabled = false
devices = 4
max_coalesce = 16
linger_us = 500
queue_depth = 64
backend = "tabu"
"#,
        )
        .unwrap();
        let mut s = Settings::default();
        s.apply(&doc).unwrap();
        assert!(!s.sched.enabled);
        assert_eq!(s.sched.devices, 4);
        assert_eq!(s.sched.max_coalesce, 16);
        assert_eq!(s.sched.linger_us, 500);
        assert_eq!(s.sched.queue_depth, 64);
        assert_eq!(s.sched.backend, "tabu");
    }

    #[test]
    fn service_overload_defaults_and_overrides() {
        // overload machinery must default OFF (deadlines, shedding) so
        // the defaults-off serving path stays byte-identical; the idle
        // timeout defaults to the historical hard-coded 30 s
        let s = Settings::default();
        assert_eq!(s.service.default_deadline_ms, 0, "deadlines default off");
        assert_eq!(s.service.idle_timeout_ms, 30_000);
        assert_eq!(s.service.shed_watermark_ms, 0, "shedding defaults off");
        assert_eq!(s.service.drain_deadline_ms, 5_000);
        assert_eq!(s.service.max_doc_bytes, 1 << 20);

        let doc = toml::Document::parse(
            r#"
[service]
default_deadline_ms = 250
idle_timeout_ms = 1500
shed_watermark_ms = 40
drain_deadline_ms = 900
max_doc_bytes = 65536
"#,
        )
        .unwrap();
        let mut s = Settings::default();
        s.apply(&doc).unwrap();
        assert_eq!(s.service.default_deadline_ms, 250);
        assert_eq!(s.service.idle_timeout_ms, 1500);
        assert_eq!(s.service.shed_watermark_ms, 40);
        assert_eq!(s.service.drain_deadline_ms, 900);
        assert_eq!(s.service.max_doc_bytes, 65536);
    }

    #[test]
    fn breaker_defaults_and_overrides() {
        let s = Settings::default();
        assert!(!s.sched.breaker.enabled, "breaker must default off");
        assert_eq!(s.sched.breaker.window, 16);
        assert_eq!(s.sched.breaker.trip_failures, 8);
        assert_eq!(s.sched.breaker.cooldown_ms, 200);
        assert_eq!(s.sched.breaker.max_trips, 3);
        assert!((s.sched.breaker.probe_target - 0.5).abs() < 1e-12);

        let doc = toml::Document::parse(
            r#"
[sched]
breaker_enabled = true
breaker_window = 32
breaker_trip_failures = 4
breaker_cooldown_ms = 50
breaker_max_trips = 2
breaker_probe_target = 0.75
"#,
        )
        .unwrap();
        let mut s = Settings::default();
        s.apply(&doc).unwrap();
        assert!(s.sched.breaker.enabled);
        assert_eq!(s.sched.breaker.window, 32);
        assert_eq!(s.sched.breaker.trip_failures, 4);
        assert_eq!(s.sched.breaker.cooldown_ms, 50);
        assert_eq!(s.sched.breaker.max_trips, 2);
        assert!((s.sched.breaker.probe_target - 0.75).abs() < 1e-12);
    }

    #[test]
    fn portfolio_defaults_and_overrides() {
        let s = Settings::default();
        assert!(!s.portfolio.enabled);
        assert_eq!(s.portfolio.policy, "static");
        assert_eq!(s.portfolio.static_backend, "cobi");
        assert!(s.portfolio.cache);
        assert_eq!(s.portfolio.cache_capacity, 4096);
        assert!((s.portfolio.epsilon - 0.1).abs() < 1e-12);

        let doc = toml::Document::parse(
            r#"
[portfolio]
enabled = true
policy = "bandit"
static_backend = "tabu"
epsilon = 0.25
exact_max_n = 14
cache = false
cache_capacity = 128
latency_weight = 2.5
"#,
        )
        .unwrap();
        let mut s = Settings::default();
        s.apply(&doc).unwrap();
        assert!(s.portfolio.enabled);
        assert_eq!(s.portfolio.policy, "bandit");
        assert_eq!(s.portfolio.static_backend, "tabu");
        assert!((s.portfolio.epsilon - 0.25).abs() < 1e-12);
        assert_eq!(s.portfolio.exact_max_n, 14);
        assert!(!s.portfolio.cache);
        assert_eq!(s.portfolio.cache_capacity, 128);
        assert!((s.portfolio.latency_weight - 2.5).abs() < 1e-12);
    }

    #[test]
    fn decompose_strategy_defaults_and_overrides() {
        assert_eq!(Settings::default().pipeline.strategy, Strategy::Window);
        let mut s = Settings::default();
        let doc = toml::Document::parse("[decompose]\nstrategy = \"tree\"").unwrap();
        s.apply(&doc).unwrap();
        assert_eq!(s.pipeline.strategy, Strategy::Tree);
        // [pipeline] alias
        let doc = toml::Document::parse("[pipeline]\nstrategy = \"stream\"").unwrap();
        s.apply(&doc).unwrap();
        assert_eq!(s.pipeline.strategy, Strategy::Streaming);
        // typos are loud, not silently window
        let doc = toml::Document::parse("[decompose]\nstrategy = \"zigzag\"").unwrap();
        assert!(s.apply(&doc).is_err());
        // when both keys appear, the canonical [decompose] one wins
        let doc = toml::Document::parse(
            "[pipeline]\nstrategy = \"window\"\n[decompose]\nstrategy = \"tree\"",
        )
        .unwrap();
        let mut s = Settings::default();
        s.apply(&doc).unwrap();
        assert_eq!(s.pipeline.strategy, Strategy::Tree);
    }

    #[test]
    fn snowball_defaults_and_overrides() {
        let s = Settings::default();
        assert_eq!(s.solvers.snowball.shards, 8);
        assert_eq!(s.solvers.snowball.epochs, 160);
        assert_eq!(s.solvers.snowball.focus_threshold, 24);
        assert!((s.solvers.snowball.participation - 0.85).abs() < 1e-12);
        assert_eq!(s.solvers.snowball.restarts, 2);
        assert_eq!(s.solvers.snowball.threads, 0, "threads must default to env/1");

        let doc = toml::Document::parse(
            r#"
[solvers.snowball]
shards = 16
epochs = 300
focus_threshold = 32
participation = 0.7
t_start = 5.0
t_end = 0.01
restarts = 3
threads = 4
"#,
        )
        .unwrap();
        let mut s = Settings::default();
        s.apply(&doc).unwrap();
        assert_eq!(s.solvers.snowball.shards, 16);
        assert_eq!(s.solvers.snowball.epochs, 300);
        assert_eq!(s.solvers.snowball.focus_threshold, 32);
        assert!((s.solvers.snowball.participation - 0.7).abs() < 1e-12);
        assert!((s.solvers.snowball.t_start - 5.0).abs() < 1e-12);
        assert!((s.solvers.snowball.t_end - 0.01).abs() < 1e-12);
        assert_eq!(s.solvers.snowball.restarts, 3);
        assert_eq!(s.solvers.snowball.threads, 4);
        // settings -> solver config round trip
        let cfg = s.solvers.snowball.solver_config();
        assert_eq!(cfg.shards, 16);
        assert_eq!(cfg.threads, 4);
    }

    #[test]
    fn resilience_defaults_and_overrides() {
        let s = Settings::default();
        assert!(!s.resilience.enabled, "resilience must default off");
        assert!(!s.resilience.fault.enabled, "faults must default off");
        assert_eq!(s.resilience.replication, 1);
        assert_eq!(s.resilience.retries, 2);
        assert!(s.resilience.verify);
        assert!(s.resilience.repair);
        assert!(!s.resilience.calibrate);

        let doc = toml::Document::parse(
            r#"
[resilience]
enabled = true
replication = 3
max_replication = 7
retries = 4
verify = false
repair = false
calibrate = true
calibration_probes = 16
calibration_target = 0.99
fault_enabled = true
fault_stuck_rate = 0.05
fault_drift_rate = 0.01
fault_drift_amp = 0.2
fault_dac_mismatch = 0.1
fault_burst_rate = 0.2
fault_burst_amp = 8.0
fault_seed = 1234
"#,
        )
        .unwrap();
        let mut s = Settings::default();
        s.apply(&doc).unwrap();
        assert!(s.resilience.enabled);
        assert_eq!(s.resilience.replication, 3);
        assert_eq!(s.resilience.max_replication, 7);
        assert_eq!(s.resilience.retries, 4);
        assert!(!s.resilience.verify);
        assert!(!s.resilience.repair);
        assert!(s.resilience.calibrate);
        assert_eq!(s.resilience.calibration_probes, 16);
        assert!((s.resilience.calibration_target - 0.99).abs() < 1e-12);
        assert!(s.resilience.fault.enabled);
        assert!((s.resilience.fault.stuck_rate - 0.05).abs() < 1e-7);
        assert!((s.resilience.fault.drift_rate - 0.01).abs() < 1e-7);
        assert!((s.resilience.fault.drift_amp - 0.2).abs() < 1e-7);
        assert!((s.resilience.fault.dac_mismatch - 0.1).abs() < 1e-7);
        assert!((s.resilience.fault.burst_rate - 0.2).abs() < 1e-7);
        assert!((s.resilience.fault.burst_amp - 8.0).abs() < 1e-7);
        assert_eq!(s.resilience.fault.seed, 1234);
    }

    #[test]
    fn obs_defaults_and_overrides() {
        let s = Settings::default();
        assert!(!s.obs.enabled, "span tracing must default off");
        assert_eq!(s.obs.ring_capacity, 256);
        assert_eq!(s.obs.exemplars, 8);
        assert!(s.obs.trace_out.is_empty());
        assert!(!s.obs.record_enabled, "flight recorder must default off");
        assert_eq!(s.obs.record_capacity, 256);
        assert!(s.obs.record_out.is_empty());

        let doc = toml::Document::parse(
            r#"
[obs]
enabled = true
ring_capacity = 64
exemplars = 4
trace_out = "/tmp/trace.jsonl"
record_enabled = true
record_capacity = 32
record_out = "/tmp/records.jsonl"
"#,
        )
        .unwrap();
        let mut s = Settings::default();
        s.apply(&doc).unwrap();
        assert!(s.obs.enabled);
        assert_eq!(s.obs.ring_capacity, 64);
        assert_eq!(s.obs.exemplars, 4);
        assert_eq!(s.obs.trace_out, "/tmp/trace.jsonl");
        assert!(s.obs.record_enabled);
        assert_eq!(s.obs.record_capacity, 32);
        assert_eq!(s.obs.record_out, "/tmp/records.jsonl");
    }

    #[test]
    fn workload_defaults_and_overrides() {
        let s = Settings::default();
        assert_eq!(s.workload.default, "es", "legacy paths must stay ES");
        assert_eq!(s.workload.retrieval_k, 4);
        assert_eq!(s.workload.dispersion_n, 16);
        assert_eq!(s.workload.dispersion_k, 4);

        let doc = toml::Document::parse(
            r#"
[workload]
default = "retrieval"
retrieval_k = 6
dispersion_n = 24
dispersion_k = 5
"#,
        )
        .unwrap();
        let mut s = Settings::default();
        s.apply(&doc).unwrap();
        assert_eq!(s.workload.default, "retrieval");
        assert_eq!(s.workload.retrieval_k, 6);
        assert_eq!(s.workload.dispersion_n, 24);
        assert_eq!(s.workload.dispersion_k, 5);
    }

    #[test]
    fn bad_precision_is_error() {
        let doc = toml::Document::parse("[pipeline]\nprecision = \"9000bit\"").unwrap();
        let mut s = Settings::default();
        assert!(s.apply(&doc).is_err());
    }
}
