//! Minimal TOML-subset parser (config substrate; no `toml`/`serde` crates
//! in the offline vendor set — see Cargo.toml note).
//!
//! Supported: `[section]` / `[section.sub]` headers, `key = value` pairs
//! with string / integer / float / boolean / homogeneous-array values,
//! `#` comments, blank lines. This covers every config file the project
//! ships; exotic TOML (dates, inline tables, multi-line strings) is
//! rejected with a line-numbered error.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed TOML value (the subset this parser supports).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Quoted string.
    Str(String),
    /// 64-bit integer.
    Int(i64),
    /// 64-bit float.
    Float(f64),
    /// Boolean.
    Bool(bool),
    /// Homogeneous array.
    Array(Vec<Value>),
}

impl Value {
    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    /// The integer value, if this is an integer.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    /// The float value (integers widen), if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    /// The boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// The array elements, if this is an array.
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "\"{s}\""),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Array(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// Line-numbered parse failure.
#[derive(Debug, thiserror::Error)]
#[error("toml parse error at line {line}: {msg}")]
pub struct ParseError {
    /// 1-based line number of the offending input.
    pub line: usize,
    /// What went wrong.
    pub msg: String,
}

/// Parsed document: dotted-path key -> value ("section.key").
#[derive(Debug, Default, Clone)]
pub struct Document {
    /// Flattened `section.key` -> value entries.
    pub entries: BTreeMap<String, Value>,
}

impl Document {
    /// Parse a TOML-subset document (module docs list the subset).
    pub fn parse(input: &str) -> Result<Self, ParseError> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (idx, raw) in input.lines().enumerate() {
            let line_no = idx + 1;
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or_else(|| ParseError {
                    line: line_no,
                    msg: "unterminated section header".into(),
                })?;
                let name = name.trim();
                if name.is_empty() || !name.chars().all(is_key_char_or_dot) {
                    return Err(ParseError {
                        line: line_no,
                        msg: format!("bad section name '{name}'"),
                    });
                }
                section = name.to_string();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| ParseError {
                line: line_no,
                msg: "expected 'key = value'".into(),
            })?;
            let key = line[..eq].trim();
            if key.is_empty() || !key.chars().all(is_key_char) {
                return Err(ParseError {
                    line: line_no,
                    msg: format!("bad key '{key}'"),
                });
            }
            let value = parse_value(line[eq + 1..].trim(), line_no)?;
            let path = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            entries.insert(path, value);
        }
        Ok(Self { entries })
    }

    /// Value at the flattened `section.key` path.
    pub fn get(&self, path: &str) -> Option<&Value> {
        self.entries.get(path)
    }

    /// String at `path`, if present and a string.
    pub fn get_str(&self, path: &str) -> Option<&str> {
        self.get(path).and_then(Value::as_str)
    }
    /// Integer at `path`, if present and an integer.
    pub fn get_i64(&self, path: &str) -> Option<i64> {
        self.get(path).and_then(Value::as_i64)
    }
    /// Float at `path`, if present and numeric.
    pub fn get_f64(&self, path: &str) -> Option<f64> {
        self.get(path).and_then(Value::as_f64)
    }
    /// Boolean at `path`, if present and boolean.
    pub fn get_bool(&self, path: &str) -> Option<bool> {
        self.get(path).and_then(Value::as_bool)
    }

    /// Keys under a section prefix (e.g. "cobi").
    pub fn section_keys<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a str> {
        let want = format!("{prefix}.");
        self.entries
            .keys()
            .filter(move |k| k.starts_with(&want))
            .map(|k| k.as_str())
    }
}

fn is_key_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_' || c == '-'
}

fn is_key_char_or_dot(c: char) -> bool {
    is_key_char(c) || c == '.'
}

/// Strip a `#` comment, respecting string literals.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, line: usize) -> Result<Value, ParseError> {
    let err = |msg: String| ParseError { line, msg };
    if s.is_empty() {
        return Err(err("missing value".into()));
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| err("unterminated string".into()))?;
        if inner.contains('"') {
            return Err(err("embedded quote in string (escapes unsupported)".into()));
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| err("unterminated array".into()))?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            items.push(parse_value(part.trim(), line)?);
        }
        return Ok(Value::Array(items));
    }
    // numbers: int if no '.', 'e' or 'E'
    let numeric = s.replace('_', "");
    if numeric.contains('.') || numeric.contains('e') || numeric.contains('E') {
        numeric
            .parse::<f64>()
            .map(Value::Float)
            .map_err(|_| err(format!("bad float '{s}'")))
    } else {
        numeric
            .parse::<i64>()
            .map(Value::Int)
            .map_err(|_| err(format!("bad value '{s}'")))
    }
}

/// Split an array body on commas outside string literals.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = Document::parse(
            r#"
# comment
title = "cobi"
[cobi]
spins = 59            # trailing comment
weight_min = -14
power_mw = 25.0
enabled = true
[pipeline.decompose]
p = 20
"#,
        )
        .unwrap();
        assert_eq!(doc.get_str("title"), Some("cobi"));
        assert_eq!(doc.get_i64("cobi.spins"), Some(59));
        assert_eq!(doc.get_i64("cobi.weight_min"), Some(-14));
        assert_eq!(doc.get_f64("cobi.power_mw"), Some(25.0));
        assert_eq!(doc.get_bool("cobi.enabled"), Some(true));
        assert_eq!(doc.get_i64("pipeline.decompose.p"), Some(20));
    }

    #[test]
    fn parses_arrays() {
        let doc = Document::parse("bits = [4, 5, 6]\nnames = [\"a\", \"b\"]").unwrap();
        let bits: Vec<i64> = doc
            .get("bits")
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .map(|v| v.as_i64().unwrap())
            .collect();
        assert_eq!(bits, vec![4, 5, 6]);
        assert_eq!(
            doc.get("names").unwrap().as_array().unwrap()[1].as_str(),
            Some("b")
        );
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let doc = Document::parse("s = \"a#b\"").unwrap();
        assert_eq!(doc.get_str("s"), Some("a#b"));
    }

    #[test]
    fn int_as_f64_coerces() {
        let doc = Document::parse("x = 3").unwrap();
        assert_eq!(doc.get_f64("x"), Some(3.0));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = Document::parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(e.line, 2);
        let e = Document::parse("[unterminated").unwrap_err();
        assert_eq!(e.line, 1);
        let e = Document::parse("x = \"oops").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn section_keys_iterates() {
        let doc = Document::parse("[a]\nx = 1\ny = 2\n[b]\nz = 3").unwrap();
        let keys: Vec<&str> = doc.section_keys("a").collect();
        assert_eq!(keys, vec!["a.x", "a.y"]);
    }
}
