//! Decomposition planning: how a document's reduction is shaped into
//! solvable windows.
//!
//! The paper's §IV-B workflow is ONE shape — a sliding chain of P-windows
//! — but it is not the only valid reduction of "repeatedly summarize
//! windows until ≤ P sentences remain". [`DecomposePlan`] makes the shape
//! a first-class, configurable object with three strategies:
//!
//!   * [`Strategy::Window`] — the paper's carving, pinned byte-identical
//!     to the pre-plan scheduler: each level takes the `len / P` full
//!     disjoint windows of the active list and shrinks each to Q; the
//!     tail (`len mod P`) survives untouched.
//!   * [`Strategy::Tree`] — balanced hierarchical merge: the active list
//!     is split into `ceil(len / P)` *balanced* contiguous leaves (every
//!     sentence is inside some leaf — no idle tail), each leaf > Q is
//!     reduced to Q, and the merged survivors repeat the carving one
//!     level up. Depth is O(log N) and every level is fully parallel,
//!     which is what the [`DevicePool`](crate::sched::DevicePool) wants:
//!     all of a level's windows can be in flight at once instead of a
//!     long sequential wrap-around chain.
//!   * [`Strategy::Streaming`] — incremental: sentences arrive over time
//!     and a [`StreamingPlanner`](crate::decompose::StreamingPlanner)
//!     maintains a rolling summary frontier, re-solving only when the
//!     frontier fills to P. See the `stream` module.
//!
//! ## Determinism contract (Tree / Streaming)
//!
//! `Window` replays the sequential quantization / request-seed streams of
//! the inline pipeline (unit-id order). `Tree` and `Streaming` instead
//! derive a seed per *plan node* via [`node_seed`] — a pure function of
//! (document seed, level, slot) — so every node's rounding draws and
//! solve randomness are independent of pool shape, dispatch
//! interleaving, sibling count, and (for streaming) how arriving
//! sentences were batched into chunks.

use std::fmt;
use std::str::FromStr;

use anyhow::Result;

use crate::util::rng::SplitMix64;

use super::DecomposeParams;

/// Which decomposition shape a pipeline uses (`[decompose] strategy`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Strategy {
    /// The paper's sliding-window reduction (§IV-B) — the reference
    /// shape, byte-identical to the pre-plan pipeline.
    #[default]
    Window,
    /// Balanced hierarchical merge: log-depth, maximally parallel levels.
    Tree,
    /// Incremental planner over arriving sentences (rolling frontier).
    Streaming,
}

impl Strategy {
    /// Canonical config-file spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            Strategy::Window => "window",
            Strategy::Tree => "tree",
            Strategy::Streaming => "stream",
        }
    }
}

impl fmt::Display for Strategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

impl FromStr for Strategy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "window" | "sliding" => Ok(Strategy::Window),
            "tree" => Ok(Strategy::Tree),
            "stream" | "streaming" => Ok(Strategy::Streaming),
            other => Err(format!(
                "unknown decompose strategy '{other}' (expected window|tree|stream)"
            )),
        }
    }
}

/// One planned subproblem: choose `target` of `window`, where `window`
/// holds original-document sentence indices.
#[derive(Debug, Clone)]
pub struct PlannedUnit {
    /// Original-document sentence indices offered to the solver.
    pub window: Vec<usize>,
    /// How many window positions the solver must return (Q, or M for the
    /// final unit).
    pub target: usize,
    /// True for the final M-selection unit.
    pub is_final: bool,
}

/// A decomposition plan: carves an active sentence list into one level of
/// independent solve units at a time.
///
/// The plan is *stateless* — [`carve`](DecomposePlan::carve) is a pure
/// function of (active list, level, params) — so the scheduler's
/// [`SubproblemGraph`](crate::sched::SubproblemGraph) owns all mutable
/// reduction state and the plan can be shared/rebuilt freely.
///
/// # Examples
///
/// What it demonstrates: the `Window` and `Tree` carvings of the same
/// 45-sentence active list — `Window` leaves a tail of survivors, `Tree`
/// covers every sentence with balanced leaves.
///
/// ```
/// use cobi_es::decompose::{DecomposePlan, DecomposeParams, Strategy};
///
/// let params = DecomposeParams::paper_default(); // P=20, Q=10, M=6
/// let active: Vec<usize> = (0..45).collect();
///
/// let window = DecomposePlan::new(Strategy::Window, &params).unwrap();
/// let units = window.carve(&active, 0);
/// // 45 / 20 = 2 full windows; 5 sentences survive as the tail
/// assert_eq!(units.len(), 2);
/// assert!(units.iter().all(|u| u.window.len() == 20 && u.target == 10));
///
/// let tree = DecomposePlan::new(Strategy::Tree, &params).unwrap();
/// let units = tree.carve(&active, 0);
/// // ceil(45 / 20) = 3 balanced leaves of 15 — every sentence covered
/// assert_eq!(units.len(), 3);
/// assert!(units.iter().all(|u| u.window.len() == 15 && u.target == 10));
/// let covered: usize = units.iter().map(|u| u.window.len()).sum();
/// assert_eq!(covered, 45);
/// ```
///
/// Expected output: no output — the assertions pass.
#[derive(Debug, Clone)]
pub struct DecomposePlan {
    strategy: Strategy,
    params: DecomposeParams,
}

impl DecomposePlan {
    /// Build a plan for `strategy` over validated `params`.
    ///
    /// `Streaming` is accepted here (the plan degenerates to the window
    /// carving for whole-document replay), but streaming workloads want
    /// the incremental [`StreamingPlanner`](super::StreamingPlanner)
    /// instead.
    pub fn new(strategy: Strategy, params: &DecomposeParams) -> Result<Self> {
        params.validate()?;
        Ok(Self {
            strategy,
            params: *params,
        })
    }

    /// The plan's strategy.
    pub fn strategy(&self) -> Strategy {
        self.strategy
    }

    /// The plan's decomposition parameters.
    pub fn params(&self) -> &DecomposeParams {
        &self.params
    }

    /// True when summaries under this plan must derive their randomness
    /// from per-node seeds ([`node_seed`]) instead of the sequential
    /// unit-id-ordered streams (see module docs).
    pub fn per_node_seeds(&self) -> bool {
        !matches!(self.strategy, Strategy::Window)
    }

    /// Carve one level: given the active sentence list (original indices,
    /// document order), return this level's independent units. Sentences
    /// not covered by any returned window survive to the next level
    /// unchanged. An empty `active` list returns no units.
    ///
    /// Shared shrink rule (the `stage_count` recurrence): the level-0
    /// carving is unconditional at `len == P`; later levels shrink only
    /// while more than P sentences remain; otherwise the single final
    /// M-selection unit is produced.
    pub fn carve(&self, active: &[usize], level: usize) -> Vec<PlannedUnit> {
        let len = active.len();
        if len == 0 {
            return Vec::new();
        }
        let p = self.params.p;
        let shrink = (level == 0 && len >= p) || len > p;
        if !shrink {
            return vec![PlannedUnit {
                window: active.to_vec(),
                target: self.params.m,
                is_final: true,
            }];
        }
        match self.strategy {
            Strategy::Window | Strategy::Streaming => self.carve_window(active),
            Strategy::Tree => self.carve_tree(active),
        }
    }

    /// The reference carving: `len / P` disjoint FULL windows; the tail
    /// (`len mod P`) survives. Byte-identical to the pre-plan scheduler.
    fn carve_window(&self, active: &[usize]) -> Vec<PlannedUnit> {
        let p = self.params.p;
        (0..active.len() / p)
            .map(|w| PlannedUnit {
                window: active[w * p..(w + 1) * p].to_vec(),
                target: self.params.q,
                is_final: false,
            })
            .collect()
    }

    /// The tree carving: `ceil(len / P)` balanced contiguous leaves
    /// covering EVERY active sentence; leaves longer than Q become solve
    /// units, leaves already ≤ Q pass through as survivors. Falls back to
    /// the window carving in the degenerate case where balancing yields
    /// no leaf > Q (possible only when P < 2(Q+1)), so a level always
    /// shrinks.
    fn carve_tree(&self, active: &[usize]) -> Vec<PlannedUnit> {
        let len = active.len();
        let p = self.params.p;
        let q = self.params.q;
        let leaves = (len + p - 1) / p;
        let base = len / leaves;
        let extra = len % leaves; // first `extra` leaves get one more
        let mut units = Vec::with_capacity(leaves);
        let mut start = 0usize;
        for leaf in 0..leaves {
            let size = base + usize::from(leaf < extra);
            let window = &active[start..start + size];
            start += size;
            if size > q {
                units.push(PlannedUnit {
                    window: window.to_vec(),
                    target: q,
                    is_final: false,
                });
            }
        }
        if units.is_empty() {
            // every balanced leaf was ≤ Q: no shrink would happen. The
            // window carving always shrinks (≥ 1 full window of P > Q).
            return self.carve_window(active);
        }
        units
    }
}

/// Per-node seed for `Tree` / `Streaming` decompositions: a pure function
/// of (document seed, level, slot-within-level), independent of how many
/// siblings a level has, which device solves the node, and when.
///
/// Streaming uses `level` = a node-kind tag and `slot` = the node's
/// position in the arrival order (see `decompose::stream`).
pub fn node_seed(doc_seed: u64, level: usize, slot: usize) -> u64 {
    // chained SplitMix64 mixing: each input fully avalanches before the
    // next is folded in, so (level, slot) pairs can't alias by XOR
    let a = SplitMix64::new(doc_seed ^ 0x7EE5_EED0_DECA_11A0).next_u64();
    let b = SplitMix64::new(a ^ level as u64).next_u64();
    SplitMix64::new(b ^ slot as u64).next_u64()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(p: usize, q: usize, m: usize) -> DecomposeParams {
        DecomposeParams { p, q, m }
    }

    #[test]
    fn strategy_parses_and_displays() {
        for (s, want) in [
            ("window", Strategy::Window),
            ("tree", Strategy::Tree),
            ("stream", Strategy::Streaming),
            ("streaming", Strategy::Streaming),
        ] {
            assert_eq!(s.parse::<Strategy>().unwrap(), want);
        }
        assert!("nope".parse::<Strategy>().is_err());
        assert_eq!(Strategy::Tree.to_string(), "tree");
        assert_eq!(Strategy::Streaming.to_string(), "stream");
        assert_eq!(Strategy::default(), Strategy::Window);
    }

    #[test]
    fn window_carving_matches_reference_shape() {
        let plan = DecomposePlan::new(Strategy::Window, &params(8, 4, 3)).unwrap();
        let active: Vec<usize> = (10..40).collect(); // len 30
        let units = plan.carve(&active, 0);
        assert_eq!(units.len(), 3); // 30 / 8
        for (w, u) in units.iter().enumerate() {
            assert_eq!(u.window, active[w * 8..(w + 1) * 8].to_vec());
            assert_eq!(u.target, 4);
            assert!(!u.is_final);
        }
    }

    #[test]
    fn tree_carving_is_balanced_and_covers_everything() {
        let plan = DecomposePlan::new(Strategy::Tree, &params(20, 10, 6)).unwrap();
        let active: Vec<usize> = (0..45).collect();
        let units = plan.carve(&active, 0);
        assert_eq!(units.len(), 3);
        let mut covered = Vec::new();
        for u in &units {
            assert_eq!(u.window.len(), 15);
            assert!(u.window.windows(2).all(|w| w[1] == w[0] + 1));
            covered.extend(u.window.iter().copied());
        }
        assert_eq!(covered, active, "tree leaves must cover every sentence");
    }

    #[test]
    fn tree_leaf_sizes_differ_by_at_most_one() {
        let plan = DecomposePlan::new(Strategy::Tree, &params(20, 10, 6)).unwrap();
        for len in [21usize, 47, 100, 999] {
            let active: Vec<usize> = (0..len).collect();
            let units = plan.carve(&active, 1);
            let sizes: Vec<usize> = units.iter().map(|u| u.window.len()).collect();
            let min = *sizes.iter().min().unwrap();
            let max = *sizes.iter().max().unwrap();
            assert!(max - min <= 1, "len={len} sizes={sizes:?}");
            assert!(max <= 20, "len={len}: leaf exceeds P");
        }
    }

    #[test]
    fn tree_passthrough_leaves_survive_unsolved() {
        // len 21, P=20 -> 2 leaves of 11 and 10; Q=10 means the 10-leaf
        // passes through (no unit) and only the 11-leaf is solved
        let plan = DecomposePlan::new(Strategy::Tree, &params(20, 10, 6)).unwrap();
        let active: Vec<usize> = (0..21).collect();
        let units = plan.carve(&active, 0);
        assert_eq!(units.len(), 1);
        assert_eq!(units[0].window.len(), 11);
    }

    #[test]
    fn tree_degenerate_params_fall_back_to_window_carving() {
        // P=5, Q=4, len=8: balanced leaves of 4 are all ≤ Q — without a
        // fallback the level would never shrink
        let plan = DecomposePlan::new(Strategy::Tree, &params(5, 4, 2)).unwrap();
        let active: Vec<usize> = (0..8).collect();
        let units = plan.carve(&active, 0);
        assert_eq!(units.len(), 1);
        assert_eq!(units[0].window.len(), 5);
        assert_eq!(units[0].target, 4);
    }

    #[test]
    fn final_unit_below_p_for_all_strategies() {
        for strategy in [Strategy::Window, Strategy::Tree, Strategy::Streaming] {
            let plan = DecomposePlan::new(strategy, &params(20, 10, 6)).unwrap();
            let active: Vec<usize> = (0..12).collect();
            let units = plan.carve(&active, 3);
            assert_eq!(units.len(), 1, "{strategy}");
            assert!(units[0].is_final);
            assert_eq!(units[0].target, 6);
            assert_eq!(units[0].window, active);
        }
    }

    #[test]
    fn level_zero_carves_unconditionally_at_exactly_p() {
        // the stage_count rule: n == P still runs a first shrink level
        for strategy in [Strategy::Window, Strategy::Tree] {
            let plan = DecomposePlan::new(strategy, &params(20, 10, 6)).unwrap();
            let active: Vec<usize> = (0..20).collect();
            let l0 = plan.carve(&active, 0);
            assert_eq!(l0.len(), 1, "{strategy}");
            assert!(!l0[0].is_final, "{strategy}");
            // ...but a LATER level of exactly P goes straight to final
            let l1 = plan.carve(&active, 1);
            assert!(l1[0].is_final, "{strategy}");
        }
    }

    #[test]
    fn node_seed_is_stable_and_position_sensitive() {
        let a = node_seed(42, 0, 0);
        assert_eq!(a, node_seed(42, 0, 0));
        assert_ne!(a, node_seed(42, 0, 1));
        assert_ne!(a, node_seed(42, 1, 0));
        assert_ne!(a, node_seed(43, 0, 0));
        // (level, slot) must not alias under swaps
        assert_ne!(node_seed(42, 1, 2), node_seed(42, 2, 1));
    }

    #[test]
    fn invalid_params_rejected_at_plan_build() {
        assert!(DecomposePlan::new(Strategy::Tree, &params(5, 5, 2)).is_err());
    }
}
