//! Decomposition workflow (paper §IV-B, Fig. 4).
//!
//! A document of N sentences is reduced in stages: while more than P
//! sentences remain, select the next window of P consecutive sentences
//! (resuming after the previous window, wrapping to the start), summarize
//! it to Q sentences with the Ising solver, and REPLACE the window with
//! its summary. When at most P sentences remain, a final solve selects the
//! M-sentence output.
//!
//! The scheduler is generic over the subproblem solver (a closure from
//! a window of original-sentence indices to the chosen subset), so Tabu,
//! COBI, brute force and random all run through identical decomposition
//! logic — exactly how the paper compares them.
//!
//! The flat sliding-window loop in this file is ONE decomposition shape;
//! [`DecomposePlan`] generalizes the shape ([`Strategy::Window`] pinned
//! byte-identical, [`Strategy::Tree`] for log-depth parallel merges) and
//! [`StreamingPlanner`] handles sentences that arrive incrementally. See
//! the `plan` and `stream` module docs for the determinism contract.

pub mod plan;
pub mod stream;

pub use plan::{node_seed, DecomposePlan, PlannedUnit, Strategy};
pub use stream::{
    CompressUnit, StreamingPlanner, STREAM_COMPRESS_LEVEL, STREAM_REVISION_LEVEL,
};

use anyhow::{ensure, Result};

/// Decomposition parameters (paper §IV-B: P=20, Q=10, M=6).
///
/// # Examples
///
/// What it demonstrates: the paper defaults validate; parameters whose
/// final window could be smaller than the requested summary are rejected
/// up front instead of failing mid-decomposition.
///
/// ```
/// use cobi_es::decompose::DecomposeParams;
///
/// let params = DecomposeParams::paper_default();
/// assert_eq!((params.p, params.q, params.m), (20, 10, 6));
/// assert!(params.validate().is_ok());
///
/// // Q must shrink the window...
/// assert!(DecomposeParams { p: 10, q: 10, m: 3 }.validate().is_err());
/// // ...and M must fit the smallest window the reduction can leave
/// // behind (the frontier can shrink to exactly Q sentences)
/// assert!(DecomposeParams { p: 20, q: 4, m: 6 }.validate().is_err());
/// ```
///
/// Expected output: no output — the assertions pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DecomposeParams {
    /// Window size P.
    pub p: usize,
    /// Intermediate summary length Q.
    pub q: usize,
    /// Final summary length M.
    pub m: usize,
}

impl DecomposeParams {
    /// The paper's published workflow constants: P=20, Q=10, M=6.
    pub fn paper_default() -> Self {
        Self { p: 20, q: 10, m: 6 }
    }

    /// Reject parameter combinations the reduction cannot execute.
    ///
    /// Beyond the basic shape rules (Q < P, nondegenerate values), M must
    /// not exceed Q: after any shrink stage the active list can be as
    /// small as Q sentences (e.g. N == P reduces straight to Q), and the
    /// final solve would then silently ask for more sentences than it
    /// has. `M <= Q < P` makes the old `M <= P` bound redundant, but both
    /// are kept for a self-documenting error message.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.q >= 1 && self.p >= 2 && self.m >= 1, "degenerate P/Q/M");
        ensure!(
            self.q < self.p,
            "Q = {} must shrink the window P = {}",
            self.q,
            self.p
        );
        ensure!(self.m <= self.p, "final M = {} exceeds window P = {}", self.m, self.p);
        ensure!(
            self.m <= self.q,
            "final M = {} exceeds intermediate Q = {}: the final window can \
             shrink to Q sentences and could not fill the summary",
            self.m,
            self.q
        );
        Ok(())
    }
}

/// One solved subproblem, for tracing/accounting.
#[derive(Debug, Clone)]
pub struct Stage {
    /// Original-document indices offered to the solver.
    pub window: Vec<usize>,
    /// Subset chosen (original indices, subset of `window`).
    pub chosen: Vec<usize>,
    /// True for the final M-selection stage.
    pub is_final: bool,
}

/// Full decomposition trace.
#[derive(Debug, Clone)]
pub struct DecompositionResult {
    /// Final selected original-document indices, ascending.
    pub selected: Vec<usize>,
    /// Every solved stage, in deterministic submission order.
    pub stages: Vec<Stage>,
}

impl DecompositionResult {
    /// Total Ising solves performed (= stages).
    pub fn solves(&self) -> usize {
        self.stages.len()
    }
}

/// Number of Ising subproblems the workflow will solve for a document of
/// `n` sentences. Per Fig. 4, the FIRST window solve is unconditional
/// whenever n >= P (a 20-sentence document with P=20 still decomposes
/// 20 -> 10 -> 6: "solving at least two Ising subproblems for 20-sentence
/// benchmarks"); subsequent windows run while more than P sentences
/// remain; one final M-selection always closes the workflow.
pub fn stage_count(n: usize, params: &DecomposeParams) -> usize {
    let mut len = n;
    let mut stages = 0;
    while (stages == 0 && len >= params.p) || len > params.p {
        len = len - params.p + params.q;
        stages += 1;
    }
    stages + 1
}

/// Run the decomposition. `solve_window(window_indices, target_len)` must
/// return `target_len` distinct positions INTO the window slice.
pub fn decompose<F>(n: usize, params: &DecomposeParams, mut solve_window: F) -> Result<DecompositionResult>
where
    F: FnMut(&[usize], usize) -> Result<Vec<usize>>,
{
    params.validate()?;
    ensure!(n >= params.m, "document of {n} sentences cannot fill M={}", params.m);

    // active list of original indices, in document order
    let mut active: Vec<usize> = (0..n).collect();
    let mut cursor = 0usize;
    let mut stages: Vec<Stage> = Vec::new();

    while (stages.is_empty() && active.len() >= params.p) || active.len() > params.p {
        let len = active.len();
        // window: P consecutive active positions starting at cursor (wrap)
        let positions: Vec<usize> = (0..params.p).map(|k| (cursor + k) % len).collect();
        let window: Vec<usize> = positions.iter().map(|&pos| active[pos]).collect();

        let local = solve_window(&window, params.q)?;
        validate_local(&local, window.len(), params.q)?;
        let chosen: Vec<usize> = local.iter().map(|&l| window[l]).collect();

        stages.push(Stage {
            window: window.clone(),
            chosen: chosen.clone(),
            is_final: false,
        });

        // replace the window with its summary, preserving document order:
        // rebuild `active` = survivors (not in window) + chosen, sorted by
        // original index. The cursor resumes after the replaced region.
        let window_set: std::collections::HashSet<usize> = window.iter().copied().collect();
        let mut next: Vec<usize> = active
            .iter()
            .copied()
            .filter(|i| !window_set.contains(i))
            .chain(chosen.iter().copied())
            .collect();
        next.sort_unstable();

        // cursor: position (in the new list) just after the last kept
        // element of the window region
        let resume_after = chosen.iter().copied().max().unwrap_or(0);
        let pos = next
            .iter()
            .position(|&i| i > resume_after)
            .unwrap_or(0); // wrapped past the end -> start over
        cursor = pos;
        active = next;
    }

    // final selection to M sentences
    let local = solve_window(&active, params.m)?;
    validate_local(&local, active.len(), params.m)?;
    let mut selected: Vec<usize> = local.iter().map(|&l| active[l]).collect();
    selected.sort_unstable();
    stages.push(Stage {
        window: active,
        chosen: selected.clone(),
        is_final: true,
    });

    Ok(DecompositionResult { selected, stages })
}

/// Validate a solver's window-local answer: exactly `want` distinct
/// positions, all inside the window. Shared with `sched::SubproblemGraph`,
/// which replays the same contract per DAG unit.
pub(crate) fn validate_local(local: &[usize], window_len: usize, want: usize) -> Result<()> {
    ensure!(
        local.len() == want,
        "subproblem solver returned {} of {} requested",
        local.len(),
        want
    );
    let mut sorted = local.to_vec();
    sorted.sort_unstable();
    sorted.dedup();
    ensure!(sorted.len() == want, "duplicate window positions");
    ensure!(
        sorted.iter().all(|&l| l < window_len),
        "window position out of range"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy solver: keep the `target` window positions with the largest
    /// "score" (here: original index parity trick to make choices visible).
    fn top_indices(window: &[usize], target: usize) -> Result<Vec<usize>> {
        let mut pos: Vec<usize> = (0..window.len()).collect();
        pos.sort_by_key(|&p| std::cmp::Reverse(window[p]));
        pos.truncate(target);
        Ok(pos)
    }

    #[test]
    fn stage_counts_match_paper_examples() {
        let params = DecomposeParams::paper_default();
        // 20-sentence: 20 -> 10 (first window, unconditional) -> final:
        // "at least two Ising subproblems for 20-sentence benchmarks"
        assert_eq!(stage_count(20, &params), 2);
        // 50-sentence: 50 -> 40 -> 30 -> 20, then final on 20
        assert_eq!(stage_count(50, &params), 4);
        // 100-sentence: eight shrink stages then final
        assert_eq!(stage_count(100, &params), 9);
        // 10-sentence (Fig. 3 set): below P, direct final solve
        assert_eq!(stage_count(10, &params), 1);
    }

    #[test]
    fn n_equals_p_runs_two_stages() {
        let params = DecomposeParams { p: 20, q: 10, m: 6 };
        let r = decompose(20, &params, top_indices).unwrap();
        assert_eq!(r.solves(), 2);
        assert!(!r.stages[0].is_final);
        assert_eq!(r.stages[0].window.len(), 20);
        assert_eq!(r.stages[0].chosen.len(), 10);
        assert!(r.stages[1].is_final);
        assert_eq!(r.stages[1].window.len(), 10);
    }

    #[test]
    fn decompose_returns_m_sorted_unique() {
        let params = DecomposeParams::paper_default();
        for n in [20usize, 35, 50, 100] {
            let r = decompose(n, &params, top_indices).unwrap();
            assert_eq!(r.selected.len(), 6, "n={n}");
            assert!(r.selected.windows(2).all(|w| w[0] < w[1]));
            assert!(r.selected.iter().all(|&i| i < n));
            assert_eq!(r.solves(), stage_count(n, &params), "n={n}");
        }
    }

    #[test]
    fn stages_shrink_monotonically() {
        let params = DecomposeParams { p: 8, q: 4, m: 3 };
        let mut seen_lens = Vec::new();
        let r = decompose(30, &params, |w, t| {
            seen_lens.push(w.len());
            top_indices(w, t)
        })
        .unwrap();
        // every non-final window has exactly P entries; final <= P
        for (i, s) in r.stages.iter().enumerate() {
            if !s.is_final {
                assert_eq!(s.window.len(), 8, "stage {i}");
                assert_eq!(s.chosen.len(), 4);
            } else {
                assert!(s.window.len() <= 8);
                assert_eq!(s.chosen.len(), 3);
            }
        }
    }

    #[test]
    fn windows_are_consecutive_with_wraparound() {
        let params = DecomposeParams { p: 6, q: 3, m: 2 };
        let mut windows: Vec<Vec<usize>> = Vec::new();
        decompose(14, &params, |w, t| {
            windows.push(w.to_vec());
            top_indices(w, t)
        })
        .unwrap();
        // first window must be the document head
        assert_eq!(windows[0], vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn final_selection_subset_of_document() {
        let params = DecomposeParams { p: 5, q: 2, m: 2 };
        let r = decompose(12, &params, top_indices).unwrap();
        // with the "keep largest index" toy solver, late sentences win
        assert!(r.selected.iter().all(|&i| i < 12));
        assert_eq!(r.selected.len(), 2);
    }

    #[test]
    fn solver_violations_are_caught() {
        let params = DecomposeParams { p: 5, q: 2, m: 2 };
        // wrong count
        assert!(decompose(12, &params, |_, _| Ok(vec![0])).is_err());
        // duplicates
        assert!(decompose(12, &params, |_, _| Ok(vec![1, 1])).is_err());
        // out of range
        assert!(decompose(12, &params, |w, _| Ok(vec![w.len(), 0])).is_err());
    }

    #[test]
    fn single_stage_document_below_p_is_one_final_solve() {
        // N <= P: decomposition is bypassed — exactly one final stage over
        // the whole document (the case the scheduler replays as a single
        // final DAG unit)
        let params = DecomposeParams::paper_default();
        for n in [10usize, 19] {
            let r = decompose(n, &params, top_indices).unwrap();
            assert_eq!(r.solves(), 1, "n={n}");
            assert!(r.stages[0].is_final);
            assert_eq!(r.stages[0].window, (0..n).collect::<Vec<_>>());
            assert_eq!(r.selected.len(), 6);
        }
    }

    #[test]
    fn window_wraps_around_at_document_end() {
        // p=6, q=3, n=14 with the keep-largest toy solver: by the third
        // stage the cursor sits near the end of an 8-sentence active list,
        // so the window must wrap past the document end back to the head
        let params = DecomposeParams { p: 6, q: 3, m: 2 };
        let mut windows: Vec<Vec<usize>> = Vec::new();
        let r = decompose(14, &params, |w, t| {
            windows.push(w.to_vec());
            top_indices(w, t)
        })
        .unwrap();
        // stage 1: head window; stage 2: next 6 after the kept {3,4,5}
        assert_eq!(windows[0], vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(windows[1], vec![6, 7, 8, 9, 10, 11]);
        // stage 3: active = [3,4,5,9,10,11,12,13], cursor past {9,10,11}
        // -> positions 6,7 then WRAP to 0,1,2,3
        assert_eq!(windows[2], vec![12, 13, 3, 4, 5, 9]);
        // wrapped windows still satisfy the global invariants
        assert_eq!(r.solves(), stage_count(14, &params));
        assert_eq!(r.selected.len(), 2);
        assert!(r.selected.windows(2).all(|w| w[0] < w[1]));
        // every wrapped window holds distinct in-range indices
        for w in &windows {
            let mut s = w.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), w.len());
            assert!(s.iter().all(|&i| i < 14));
        }
    }

    #[test]
    fn q_equals_m_final_stage_still_selects_m() {
        // Q == M: intermediate stages shrink to Q = M, and the final stage
        // still runs an M-selection over the merged <= P sentences (it
        // must not be skipped just because a window already has M picks)
        let params = DecomposeParams { p: 6, q: 3, m: 3 };
        let r = decompose(14, &params, top_indices).unwrap();
        let last = r.stages.last().unwrap();
        assert!(last.is_final);
        assert!(last.window.len() <= 6);
        assert_eq!(last.chosen.len(), 3);
        assert_eq!(r.selected.len(), 3);
        for s in &r.stages[..r.stages.len() - 1] {
            assert!(!s.is_final);
            assert_eq!(s.chosen.len(), 3);
        }
    }

    #[test]
    fn invalid_params_rejected() {
        assert!(DecomposeParams { p: 5, q: 5, m: 2 }.validate().is_err());
        assert!(DecomposeParams { p: 5, q: 2, m: 6 }.validate().is_err());
        assert!(decompose(4, &DecomposeParams { p: 5, q: 2, m: 6 }, top_indices).is_err());
    }

    #[test]
    fn m_exceeding_q_rejected_up_front() {
        // edge case: P=20, Q=3, M=6 passes the old M <= P check, but a
        // 20-sentence document reduces 20 -> 3 and the final solve would
        // ask for 6 of 3 sentences — validate must reject it eagerly
        // rather than letting the workflow fail mid-decomposition
        let params = DecomposeParams { p: 20, q: 3, m: 6 };
        assert!(params.validate().is_err());
        assert!(decompose(20, &params, top_indices).is_err());
        // M == Q stays legal (the boundary the paper's Q=10 > M=6 never
        // exercises)
        assert!(DecomposeParams { p: 20, q: 6, m: 6 }.validate().is_ok());
    }
}
