//! Incremental decomposition over arriving sentences.
//!
//! [`StreamingPlanner`] is the index-level state machine behind the
//! `stream` strategy: sentences arrive one at a time (the executor
//! un-batches whatever chunking the transport used), the planner keeps a
//! **rolling summary frontier** of at most P−1 sentence indices between
//! compressions, and whenever an arrival fills the frontier to exactly P
//! it emits ONE compression window (the whole frontier) to be reduced to
//! Q — after which the frontier is the chosen Q survivors and arrivals
//! continue. Nothing already compressed is ever re-solved; only the
//! windows whose membership changed (the frontier) are.
//!
//! Because the compression trigger depends only on the TOTAL number of
//! sentences arrived — never on chunk boundaries — the sequence of
//! compression windows (and with per-node seeding, every solve) is
//! invariant to arrival batching: feeding a document sentence-by-sentence
//! or in one chunk produces identical state at every arrival count. This
//! is one half of the streaming determinism contract
//! (see `decompose::plan` module docs); the other half is
//! [`node_seed`](super::node_seed)-derived randomness per compression /
//! revision node.
//!
//! A *summary revision* (the final M-selection over the current frontier)
//! is computed by the executor on demand and never mutates the planner —
//! the planner only tracks arrivals and compressions.

use anyhow::{ensure, Result};

use super::{validate_local, DecomposeParams};

/// Node-kind tags for [`node_seed`](super::node_seed)'s `level` argument:
/// compression nodes and revision nodes draw from disjoint seed families.
pub const STREAM_COMPRESS_LEVEL: usize = usize::MAX - 1;
/// See [`STREAM_COMPRESS_LEVEL`].
pub const STREAM_REVISION_LEVEL: usize = usize::MAX;

/// One due compression: reduce `window` (the full frontier, |window| = P)
/// to Q survivors.
#[derive(Debug, Clone)]
pub struct CompressUnit {
    /// Original-document sentence indices (ascending arrival order of
    /// survivors — the frontier).
    pub window: Vec<usize>,
    /// 0-based compression ordinal — the `slot` for per-node seeding.
    pub seq: usize,
    /// Survivors to keep (always Q).
    pub target: usize,
}

/// Incremental planner: arrivals in, compression windows out.
///
/// Protocol: call [`push`](StreamingPlanner::push) once per arriving
/// sentence; when it returns a [`CompressUnit`], solve it and report the
/// chosen window positions via [`complete`](StreamingPlanner::complete)
/// before pushing again (enforced — a pending compression blocks further
/// arrivals, which is what makes state a pure function of arrival count).
#[derive(Debug)]
pub struct StreamingPlanner {
    params: DecomposeParams,
    /// Frontier: original indices in document order, < P between
    /// compressions.
    active: Vec<usize>,
    arrived: usize,
    compressions: usize,
    pending: bool,
}

impl StreamingPlanner {
    /// New planner for validated `params`.
    pub fn new(params: &DecomposeParams) -> Result<Self> {
        params.validate()?;
        Ok(Self {
            params: *params,
            active: Vec::with_capacity(params.p),
            arrived: 0,
            compressions: 0,
            pending: false,
        })
    }

    /// Register the arrival of the next sentence (its original index is
    /// the current [`arrived`](StreamingPlanner::arrived) count). Returns
    /// a compression window when the frontier filled to P.
    pub fn push(&mut self) -> Result<Option<CompressUnit>> {
        ensure!(
            !self.pending,
            "a compression is pending: complete() it before pushing more sentences"
        );
        self.active.push(self.arrived);
        self.arrived += 1;
        if self.active.len() == self.params.p {
            self.pending = true;
            return Ok(Some(CompressUnit {
                window: self.active.clone(),
                seq: self.compressions,
                target: self.params.q,
            }));
        }
        Ok(None)
    }

    /// Report the pending compression solved: `local` holds Q distinct
    /// positions INTO the compression window (the `decompose` solver
    /// contract). The frontier becomes the chosen survivors.
    pub fn complete(&mut self, unit: &CompressUnit, local: &[usize]) -> Result<()> {
        ensure!(self.pending, "no compression is pending");
        ensure!(
            unit.seq == self.compressions,
            "stale compression unit {} (expected {})",
            unit.seq,
            self.compressions
        );
        validate_local(local, unit.window.len(), unit.target)?;
        let mut chosen: Vec<usize> = local.iter().map(|&l| unit.window[l]).collect();
        chosen.sort_unstable();
        self.active = chosen;
        self.compressions += 1;
        self.pending = false;
        Ok(())
    }

    /// Current frontier (original indices, ascending).
    pub fn frontier(&self) -> &[usize] {
        &self.active
    }

    /// Total sentences arrived so far.
    pub fn arrived(&self) -> usize {
        self.arrived
    }

    /// Compressions performed so far.
    pub fn compressions(&self) -> usize {
        self.compressions
    }

    /// True when a revision (M-selection over the frontier) is possible.
    pub fn can_summarize(&self) -> bool {
        !self.pending && self.active.len() >= self.params.m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn keep_first(window: &[usize], target: usize) -> Vec<usize> {
        debug_assert!(window.len() >= target);
        (0..target).collect()
    }

    fn drive(n: usize, params: &DecomposeParams) -> StreamingPlanner {
        let mut pl = StreamingPlanner::new(params).unwrap();
        for _ in 0..n {
            if let Some(unit) = pl.push().unwrap() {
                let local = keep_first(&unit.window, unit.target);
                pl.complete(&unit, &local).unwrap();
            }
        }
        pl
    }

    #[test]
    fn frontier_stays_below_p_between_compressions() {
        let params = DecomposeParams { p: 20, q: 10, m: 6 };
        let pl = drive(57, &params);
        assert_eq!(pl.arrived(), 57);
        assert!(pl.frontier().len() < 20);
        // compressions at arrivals 20, 30, 40, 50 (each restores q=10)
        assert_eq!(pl.compressions(), 4);
        assert_eq!(pl.frontier().len(), 10 + 7);
        assert!(pl.can_summarize());
    }

    #[test]
    fn compression_fires_exactly_at_p() {
        let params = DecomposeParams { p: 5, q: 2, m: 2 };
        let mut pl = StreamingPlanner::new(&params).unwrap();
        for k in 0..4 {
            assert!(pl.push().unwrap().is_none(), "arrival {k}");
        }
        let unit = pl.push().unwrap().expect("5th arrival fills the frontier");
        assert_eq!(unit.window, vec![0, 1, 2, 3, 4]);
        assert_eq!(unit.seq, 0);
        assert_eq!(unit.target, 2);
        // pushing with a pending compression is an error
        assert!(pl.push().is_err());
        pl.complete(&unit, &[1, 3]).unwrap();
        assert_eq!(pl.frontier(), &[1, 3]);
    }

    #[test]
    fn state_is_a_pure_function_of_arrival_count() {
        // the batching-invariance property in miniature: two planners fed
        // the same total arrivals (regardless of how calls are grouped by
        // the caller — push is per-sentence by construction) agree on
        // frontier, compressions, and window sequence
        let params = DecomposeParams { p: 6, q: 3, m: 2 };
        let a = drive(40, &params);
        let b = drive(40, &params);
        assert_eq!(a.frontier(), b.frontier());
        assert_eq!(a.compressions(), b.compressions());
    }

    #[test]
    fn stale_or_invalid_completions_rejected() {
        let params = DecomposeParams { p: 4, q: 2, m: 2 };
        let mut pl = StreamingPlanner::new(&params).unwrap();
        for _ in 0..3 {
            assert!(pl.push().unwrap().is_none());
        }
        let unit = pl.push().unwrap().unwrap();
        // wrong count / duplicates / out of range
        assert!(pl.complete(&unit, &[0]).is_err());
        assert!(pl.complete(&unit, &[1, 1]).is_err());
        assert!(pl.complete(&unit, &[0, 9]).is_err());
        // stale seq
        let stale = CompressUnit { seq: 7, ..unit.clone() };
        assert!(pl.complete(&stale, &[0, 1]).is_err());
        // valid retry lands
        pl.complete(&unit, &[0, 1]).unwrap();
        // completing again with nothing pending is an error
        assert!(pl.complete(&unit, &[0, 1]).is_err());
    }

    #[test]
    fn can_summarize_tracks_frontier_and_m() {
        let params = DecomposeParams { p: 6, q: 3, m: 3 };
        let mut pl = StreamingPlanner::new(&params).unwrap();
        assert!(!pl.can_summarize());
        pl.push().unwrap();
        pl.push().unwrap();
        assert!(!pl.can_summarize(), "2 < m");
        pl.push().unwrap();
        assert!(pl.can_summarize());
    }

    #[test]
    fn degenerate_params_rejected() {
        assert!(StreamingPlanner::new(&DecomposeParams { p: 5, q: 5, m: 2 }).is_err());
    }
}
