//! Observability: request-scoped tracing + the fleet energy ledger.
//!
//! The cross-cutting layer every serving subsystem reports through
//! (ROADMAP "structured per-request tracing spans"):
//!
//! * [`Span`] / [`TraceCollector`] — per-request span trees (ingest →
//!   embed → decompose → quantize/solve per unit → score) carrying the
//!   document seed, strategy, backend route, cache tier, replication
//!   factor and modeled device time/energy, recorded into a bounded
//!   never-blocking ring. Deterministic attributes are pure functions
//!   of (config, document), so the pinned form of a trace is
//!   byte-identical across pool shapes (decision #18); wall-clock
//!   measurements (queue wait, solve time, coalesce occupancy) live in
//!   separate `wall` sections excluded from pinned output.
//! * [`EnergyLedger`] — fleet-wide modeled joules / device-seconds by
//!   (backend × subsystem × size bucket); feeds the `energy-report`
//!   experiment and the `::METRICS::` exposition.
//! * Exporters ([`export`]) — JSONL trace dump (`serve --trace-out`),
//!   Prometheus-style text exposition (`::METRICS::`), machine-readable
//!   stats (`::STATS JSON::`), and the top-K slowest-request exemplar
//!   store surfaced in `::STATS::`.
//!
//! Determinism contract: tracing never draws from any RNG stream, and
//! with `[obs] enabled = false` (the default) [`ObsShared::start_request`]
//! returns `None` before allocating — the zero-alloc refine hot path is
//! untouched (pinned by `tests/alloc_audit.rs`). The energy ledger and
//! exemplar store stay on regardless: both are O(1)-memory counters off
//! the solver hot path.

pub mod export;
pub mod json;
pub mod ledger;
pub mod recorder;
pub mod replay;
pub mod span;

pub use ledger::{
    bucket_label, EnergyCost, EnergyLedger, EnergyModel, LedgerCell, LedgerRow, LedgerSolver,
    Subsystem,
};
pub use recorder::{FlightRecorder, NodeRecord, RequestRecord};
pub use replay::{replay_record, replay_records, ReplayReport};
pub use span::{AttrValue, Span};

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::config::Settings;

/// One slow-request exemplar: total latency (queue wait + solve) of a
/// served document.
#[derive(Debug, Clone, PartialEq)]
pub struct Exemplar {
    /// Document id.
    pub doc: String,
    /// End-to-end seconds.
    pub secs: f64,
}

/// Bounded never-blocking span ring + the top-K exemplar store.
///
/// `record` uses `try_lock`: a contended record is counted in `dropped`
/// instead of ever stalling a worker, and a full ring overwrites its
/// oldest tree (also counted) — O(1) memory however long the service
/// runs. Exporters drain with a blocking lock on their own threads.
#[derive(Debug)]
pub struct TraceCollector {
    cap: usize,
    k: usize,
    recorded: AtomicU64,
    dropped: AtomicU64,
    evictions: AtomicU64,
    ring: Mutex<VecDeque<Span>>,
    exemplars: Mutex<Vec<Exemplar>>,
}

impl TraceCollector {
    /// Ring of at most `cap` span trees, keeping the `k` slowest
    /// exemplars.
    pub fn new(cap: usize, k: usize) -> Self {
        let cap = cap.max(1);
        Self {
            cap,
            k: k.max(1),
            recorded: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            ring: Mutex::new(VecDeque::with_capacity(cap)),
            exemplars: Mutex::new(Vec::new()),
        }
    }

    /// Record one completed request tree (see type docs for the
    /// drop/overwrite rules).
    pub fn record(&self, span: Span) {
        self.recorded.fetch_add(1, Ordering::Relaxed);
        match self.ring.try_lock() {
            Ok(mut ring) => {
                if ring.len() == self.cap {
                    ring.pop_front();
                    self.dropped.fetch_add(1, Ordering::Relaxed);
                }
                ring.push_back(span);
            }
            Err(_) => {
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Offer a request latency to the top-K slowest exemplar store.
    pub fn observe(&self, doc: &str, secs: f64) {
        let mut ex = self.exemplars.lock().unwrap();
        if ex.len() == self.k {
            // full store: only a new slowest-K latency displaces one
            let (mi, min) = ex
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.secs.total_cmp(&b.1.secs))
                .map(|(i, e)| (i, e.secs))
                .expect("k >= 1");
            if secs <= min {
                return;
            }
            ex.remove(mi);
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        let doc = doc.to_string();
        ex.push(Exemplar { doc, secs });
        ex.sort_by(|a, b| b.secs.total_cmp(&a.secs));
    }

    /// Move every buffered tree out of the ring (oldest first).
    pub fn drain(&self) -> Vec<Span> {
        self.ring.lock().unwrap().drain(..).collect()
    }

    /// Trees currently buffered.
    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().len()
    }

    /// Whether the ring is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Trees ever offered to `record`.
    pub fn recorded(&self) -> u64 {
        self.recorded.load(Ordering::Relaxed)
    }

    /// Trees lost to overwrite or lock contention.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Exemplars displaced from the top-K store by slower requests —
    /// the loss counter that distinguishes "was never slow" from
    /// "was displaced" in the exposition.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Snapshot of the slowest-request exemplars (slowest first).
    pub fn exemplars(&self) -> Vec<Exemplar> {
        self.exemplars.lock().unwrap().clone()
    }
}

/// Fleet dispatch counters (always on): how the pool coalesces, for the
/// `coalesce_occupancy` wall attribute and the exposition.
#[derive(Debug, Default)]
pub struct DispatchCounters {
    dispatches: AtomicU64,
    requests: AtomicU64,
    instances: AtomicU64,
}

impl DispatchCounters {
    /// Count one device dispatch serving `requests` coalesced requests
    /// totalling `instances` instances.
    pub fn record(&self, requests: u64, instances: u64) {
        self.dispatches.fetch_add(1, Ordering::Relaxed);
        self.requests.fetch_add(requests, Ordering::Relaxed);
        self.instances.fetch_add(instances, Ordering::Relaxed);
    }

    /// (dispatches, requests, instances) so far.
    pub fn snapshot(&self) -> (u64, u64, u64) {
        (
            self.dispatches.load(Ordering::Relaxed),
            self.requests.load(Ordering::Relaxed),
            self.instances.load(Ordering::Relaxed),
        )
    }

    /// Mean instances per device dispatch (0 before any dispatch).
    pub fn occupancy(&self) -> f64 {
        let d = self.dispatches.load(Ordering::Relaxed);
        if d == 0 {
            0.0
        } else {
            self.instances.load(Ordering::Relaxed) as f64 / d as f64
        }
    }
}

/// Observability snapshot carried inside `ServiceMetrics` (reported by
/// `::STATS::`, `::STATS JSON::` and the exposition).
#[derive(Debug, Clone, Default)]
pub struct ObsMetrics {
    /// Whether span recording is on (`[obs] enabled`).
    pub tracing_enabled: bool,
    /// Span trees ever recorded.
    pub recorded: u64,
    /// Span trees lost to ring overwrite / contention.
    pub dropped: u64,
    /// Span trees currently buffered.
    pub buffered: usize,
    /// Slowest-request exemplars, slowest first.
    pub exemplars: Vec<Exemplar>,
    /// Exemplars displaced from the top-K store.
    pub exemplar_evictions: u64,
    /// Whether the flight recorder is on (`[obs] record_enabled` /
    /// `record_out`).
    pub recorder_enabled: bool,
    /// Request records ever committed to the flight recorder.
    pub recorder_recorded: u64,
    /// Request records lost to recorder-ring overwrite.
    pub recorder_overwritten: u64,
    /// Request records currently buffered in the recorder ring.
    pub recorder_buffered: usize,
    /// Energy-ledger rows (non-empty cells only).
    pub ledger: Vec<LedgerRow>,
    /// Device dispatches observed.
    pub dispatches: u64,
    /// Requests those dispatches served.
    pub dispatch_requests: u64,
    /// Instances those dispatches solved.
    pub dispatch_instances: u64,
}

impl ObsMetrics {
    /// Whether anything is worth reporting yet.
    pub fn any(&self) -> bool {
        self.recorded > 0 || !self.exemplars.is_empty() || !self.ledger.is_empty()
    }

    /// Total modeled joules across the ledger.
    pub fn total_joules(&self) -> f64 {
        self.ledger.iter().map(|r| r.cell.joules).sum()
    }

    /// Total modeled device/CPU seconds across the ledger.
    pub fn total_device_s(&self) -> f64 {
        self.ledger.iter().map(|r| r.cell.device_s).sum()
    }

    /// One-line report fragment for `::STATS::` / service reports:
    /// energy totals plus the slowest exemplars.
    pub fn report(&self) -> String {
        let mut out = format!(
            "obs: traces={} dropped={} energy_j={:.3e} device_s={:.3e}",
            self.recorded,
            self.dropped,
            self.total_joules(),
            self.total_device_s(),
        );
        if !self.exemplars.is_empty() {
            out.push_str(" slowest=[");
            for (i, e) in self.exemplars.iter().enumerate() {
                if i > 0 {
                    out.push(' ');
                }
                out.push_str(&format!("{}:{:.1}ms", e.doc, e.secs * 1e3));
            }
            out.push(']');
        }
        out
    }
}

/// The handle threaded through the serving stack: span switch + trace
/// collector + energy ledger + dispatch counters, all cheaply cloned
/// (`Arc`s inside). One per `Service` / `DevicePool`.
#[derive(Debug, Clone)]
pub struct ObsShared {
    enabled: bool,
    backend: Arc<str>,
    cache_tier: &'static str,
    replication: usize,
    traces: Arc<TraceCollector>,
    ledger: Arc<EnergyLedger>,
    dispatch: Arc<DispatchCounters>,
    recorder: Arc<FlightRecorder>,
}

impl ObsShared {
    /// Build from `[obs]` (+ `[cobi]`/`[timing]` for the cost model and
    /// the routing sections for the root-span route attributes).
    pub fn from_settings(settings: &Settings) -> Self {
        let backend: Arc<str> = crate::sched::resolved_backend(settings).into();
        let cache_tier = if settings.portfolio.enabled && settings.portfolio.cache {
            "warm"
        } else {
            "off"
        };
        let replication = if settings.resilience.enabled {
            settings
                .resilience
                .replication
                .clamp(1, settings.resilience.max_replication.max(1))
        } else {
            1
        };
        Self {
            enabled: settings.obs.enabled,
            backend,
            cache_tier,
            replication,
            traces: Arc::new(TraceCollector::new(
                settings.obs.ring_capacity,
                settings.obs.exemplars,
            )),
            ledger: Arc::new(EnergyLedger::new(EnergyModel::from_settings(settings))),
            dispatch: Arc::new(DispatchCounters::default()),
            recorder: Arc::new(FlightRecorder::from_settings(settings)),
        }
    }

    /// A default-config handle with span recording OFF — the state every
    /// non-serving caller gets, and what `tests/alloc_audit.rs` probes.
    pub fn disabled() -> Self {
        Self::from_settings(&Settings::default())
    }

    /// Whether span recording is on.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Begin a request trace: `None` (no allocation, no lock) when span
    /// recording is off; otherwise the root span pre-loaded with the
    /// deterministic route attributes (document id, backend route,
    /// cache tier, replication factor).
    pub fn start_request(&self, doc_id: &str) -> Option<Span> {
        if !self.enabled {
            return None;
        }
        Some(
            Span::new("request")
                .with("doc", doc_id)
                .with("backend", self.backend.as_ref())
                .with("cache", self.cache_tier)
                .with("replication", self.replication),
        )
    }

    /// Finish a request: always offers the latency to the exemplar
    /// store; when a root span exists, stamps its wall section (queue
    /// wait, total, fleet coalesce occupancy) and records the tree.
    pub fn finish_request(
        &self,
        root: Option<Span>,
        doc_id: &str,
        queue_wait_s: f64,
        total_s: f64,
    ) {
        self.traces.observe(doc_id, queue_wait_s + total_s);
        if let Some(mut root) = root {
            root.set_wall("queue_wait_us", (queue_wait_s * 1e6) as u64);
            root.set_wall("total_us", (total_s * 1e6) as u64);
            root.set_wall("coalesce_occupancy", self.dispatch.occupancy());
            self.traces.record(root);
        }
    }

    /// The trace collector (exporters drain it).
    pub fn traces(&self) -> &Arc<TraceCollector> {
        &self.traces
    }

    /// The fleet energy ledger.
    pub fn ledger(&self) -> &Arc<EnergyLedger> {
        &self.ledger
    }

    /// The per-solve cost model (span modeled-energy attributes).
    pub fn model(&self) -> &EnergyModel {
        self.ledger.model()
    }

    /// The resolved backend route label.
    pub fn backend(&self) -> &str {
        &self.backend
    }

    /// Fleet dispatch counters (device loops feed them).
    pub fn dispatch(&self) -> &Arc<DispatchCounters> {
        &self.dispatch
    }

    /// The per-request flight recorder (`[obs] record_*`): disabled by
    /// default, in which case the serving path never consults it beyond
    /// one branch (pinned zero-alloc by `tests/alloc_audit.rs`).
    pub fn recorder(&self) -> &Arc<FlightRecorder> {
        &self.recorder
    }

    /// Metrics snapshot for `ServiceMetrics`.
    pub fn snapshot(&self) -> ObsMetrics {
        let (dispatches, dispatch_requests, dispatch_instances) = self.dispatch.snapshot();
        ObsMetrics {
            tracing_enabled: self.enabled,
            recorded: self.traces.recorded(),
            dropped: self.traces.dropped(),
            buffered: self.traces.len(),
            exemplars: self.traces.exemplars(),
            exemplar_evictions: self.traces.evictions(),
            ledger: self.ledger.rows(),
            dispatches,
            dispatch_requests,
            dispatch_instances,
            recorder_enabled: self.recorder.enabled(),
            recorder_recorded: self.recorder.recorded(),
            recorder_overwritten: self.recorder.overwritten(),
            recorder_buffered: self.recorder.buffered(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_is_bounded_and_counts_overwrites() {
        let c = TraceCollector::new(4, 2);
        for i in 0..10u64 {
            c.record(Span::new("request").with("seed", i));
        }
        assert_eq!(c.len(), 4);
        assert_eq!(c.recorded(), 10);
        assert_eq!(c.dropped(), 6);
        let drained = c.drain();
        assert_eq!(drained.len(), 4);
        // oldest overwritten: the survivors are the last four records
        assert_eq!(drained[0].attr("seed"), Some(&AttrValue::U64(6)));
        assert!(c.is_empty());
        assert_eq!(c.recorded(), 10, "drain does not reset counters");
    }

    #[test]
    fn exemplar_store_keeps_the_k_slowest() {
        let c = TraceCollector::new(4, 3);
        for (doc, secs) in [("a", 0.1), ("b", 0.5), ("c", 0.2), ("d", 0.4), ("e", 0.05)] {
            c.observe(doc, secs);
        }
        let ex = c.exemplars();
        let docs: Vec<&str> = ex.iter().map(|e| e.doc.as_str()).collect();
        assert_eq!(docs, ["b", "d", "c"], "slowest first, k=3");
        assert_eq!(c.evictions(), 1, "only 'a' was displaced ('e' never entered)");
    }

    #[test]
    fn snapshot_carries_recorder_counters() {
        let obs = ObsShared::disabled();
        let m = obs.snapshot();
        assert!(!m.recorder_enabled, "recorder defaults off");
        assert_eq!(m.recorder_recorded, 0);
        assert_eq!(m.recorder_overwritten, 0);
        assert_eq!(m.recorder_buffered, 0);
        assert_eq!(m.exemplar_evictions, 0);

        let mut s = Settings::default();
        s.obs.record_enabled = true;
        let obs = ObsShared::from_settings(&s);
        assert!(obs.recorder().enabled());
        assert!(obs.snapshot().recorder_enabled);
    }

    #[test]
    fn disabled_handle_starts_no_spans_but_still_observes() {
        let obs = ObsShared::disabled();
        assert!(!obs.enabled());
        assert!(obs.start_request("doc-1").is_none());
        obs.finish_request(None, "doc-1", 0.001, 0.01);
        let m = obs.snapshot();
        assert_eq!(m.recorded, 0);
        assert_eq!(m.exemplars.len(), 1);
        assert!(m.any());
        assert!(m.report().contains("slowest=[doc-1:"), "{}", m.report());
    }

    #[test]
    fn enabled_handle_records_route_attributes() {
        let mut settings = Settings::default();
        settings.obs.enabled = true;
        settings.resilience.enabled = true;
        settings.resilience.replication = 3;
        let obs = ObsShared::from_settings(&settings);
        let root = obs.start_request("doc-9").expect("tracing on");
        assert_eq!(root.attr("doc"), Some(&AttrValue::Str("doc-9".into())));
        assert_eq!(
            root.attr("replication"),
            Some(&AttrValue::U64(3)),
            "route attrs come from config"
        );
        obs.finish_request(Some(root), "doc-9", 0.0, 0.002);
        let m = obs.snapshot();
        assert_eq!(m.recorded, 1);
        assert_eq!(m.buffered, 1);
        assert!(m.tracing_enabled);
    }

    #[test]
    fn dispatch_counters_compute_occupancy() {
        let d = DispatchCounters::default();
        assert_eq!(d.occupancy(), 0.0);
        d.record(2, 8);
        d.record(1, 4);
        assert_eq!(d.snapshot(), (2, 3, 12));
        assert!((d.occupancy() - 6.0).abs() < 1e-12);
    }
}
