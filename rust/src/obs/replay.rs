//! Deterministic replay + divergence triage for flight-recorder
//! records (ISSUE 10, the second half of the tentpole).
//!
//! A [`RequestRecord`](super::recorder::RequestRecord) carries
//! everything the pipeline is a pure function of — the input lines, the
//! request seed, and the full resolved config in `FromStr`-round-trip
//! form — so [`replay_record`] can reconstruct the exact recorded
//! `PipelineConfig` ([`pipeline_from_fields`]), re-execute the request
//! through the *current* binary on an inline solver (byte-identical to
//! any pool shape by the determinism contract), and byte-diff the
//! outputs. On a mismatch, triage walks the recorded vs. replayed
//! per-node taps and names the FIRST divergent DAG node — level, slot,
//! node seed, recorded vs. replayed energy — plus a config diff against
//! the currently-served provenance, so "summary changed" becomes
//! "window (2,3) under seed 0x… flipped, and `fault_stuck_rate`
//! differs".
//!
//! The environment is deliberately NOT reconstructed from the record:
//! replay runs under the current `[resilience]` fault model (and the
//! current binary). Replaying a faulty recording against clean settings
//! is exactly how a fleet anomaly is triaged down to the subproblem the
//! fault flipped; the config diff says which knobs differ.

use std::fmt;
use std::str::FromStr;

use anyhow::{Context, Result};

use crate::config::{PipelineConfig, Settings};
use crate::corpus::Document;
use crate::sched::pool::build_solver;
use crate::sched::{resolved_backend, summarize_sequential_recorded};
use crate::text::tokenize::fnv1a;
use crate::workload::{problem_from_request, select_inline, workload_salt};

use super::recorder::{
    hex, parse_hex, provenance_fields, summary_hash, NodeRecord, RequestRecord,
};

/// Reconstruct a [`PipelineConfig`] from recorded provenance pairs
/// (see [`provenance_fields`]): every pipeline key is parsed back
/// through its `FromStr`; non-pipeline keys (`backend`, `fault_*`) are
/// ignored. Unrecognized or unparsable values error — a record from a
/// future binary should fail loudly, not replay under silently-wrong
/// settings. `base` fills any key the record omits.
pub fn pipeline_from_fields(
    fields: &[(String, String)],
    base: &PipelineConfig,
) -> Result<PipelineConfig> {
    let mut cfg = base.clone();
    for (k, v) in fields {
        let ctx = || format!("recorded config {k}='{v}'");
        match k.as_str() {
            "lambda" => cfg.lambda = v.parse().with_context(ctx)?,
            "improved_formulation" => {
                cfg.improved_formulation = v.parse().with_context(ctx)?
            }
            "precision" => {
                cfg.precision = crate::quant::Precision::from_str(v).with_context(ctx)?
            }
            "rounding" => {
                cfg.rounding = crate::quant::Rounding::from_str(v).with_context(ctx)?
            }
            "iterations" => cfg.iterations = v.parse().with_context(ctx)?,
            "decompose_p" => cfg.decompose_p = v.parse().with_context(ctx)?,
            "decompose_q" => cfg.decompose_q = v.parse().with_context(ctx)?,
            "strategy" => {
                cfg.strategy = crate::decompose::Strategy::from_str(v).with_context(ctx)?
            }
            "summary_len" => cfg.summary_len = v.parse().with_context(ctx)?,
            "solver" => cfg.solver = v.clone(),
            "seed" => cfg.seed = parse_hex(v).with_context(ctx)?,
            // environment provenance, not pipeline config
            "backend" | "fault_enabled" | "fault_seed" | "fault_stuck_rate"
            | "fault_drift_rate" | "fault_drift_amp" | "fault_dac_mismatch"
            | "fault_burst_rate" | "fault_burst_amp" => {}
            other => anyhow::bail!("record carries unknown config key '{other}'"),
        }
    }
    Ok(cfg)
}

/// One config key whose recorded value differs from the currently
/// served provenance.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConfigDelta {
    /// Provenance key (see [`provenance_fields`]).
    pub key: String,
    /// Value at record time.
    pub recorded: String,
    /// Value served now (`"<absent>"` if the key no longer exists).
    pub current: String,
}

/// Recorded vs. current provenance, keyed off the record's pairs — the
/// triage answer to "which knob differs?".
pub fn diff_config(record: &[(String, String)], settings: &Settings) -> Vec<ConfigDelta> {
    let current = provenance_fields(settings);
    record
        .iter()
        .filter_map(|(k, rv)| {
            let cv = current
                .iter()
                .find(|(ck, _)| ck == k)
                .map(|(_, v)| v.clone())
                .unwrap_or_else(|| "<absent>".to_string());
            (cv != *rv).then(|| ConfigDelta {
                key: k.clone(),
                recorded: rv.clone(),
                current: cv,
            })
        })
        .collect()
}

/// The first solve-DAG node where a replay left the recorded
/// trajectory.
#[derive(Debug, Clone, PartialEq)]
pub struct Divergence {
    /// Submission-order index into the node tap list.
    pub index: usize,
    /// Decomposition level of the divergent node.
    pub level: usize,
    /// Slot within the level.
    pub slot: usize,
    /// The node's seed (0 under the window plan).
    pub node_seed: u64,
    /// Recorded selected-best energy (NaN if the recorded list ended
    /// before this node).
    pub recorded_energy: f64,
    /// Replayed selected-best energy (NaN if the replayed list ended
    /// before this node).
    pub replayed_energy: f64,
    /// Whether the spin-vector hashes differ (energies can agree while
    /// spins flip between equal-objective solutions).
    pub spin_hash_differs: bool,
}

/// Walk recorded vs. replayed taps in submission order and return the
/// first index where they disagree (or where one list ends early);
/// `None` when they match node for node.
pub fn first_divergence(recorded: &[NodeRecord], replayed: &[NodeRecord]) -> Option<Divergence> {
    let n = recorded.len().min(replayed.len());
    for i in 0..n {
        let (a, b) = (&recorded[i], &replayed[i]);
        if a != b {
            return Some(Divergence {
                index: i,
                level: a.level,
                slot: a.slot,
                node_seed: a.node_seed,
                recorded_energy: f64::from_bits(a.energy_bits),
                replayed_energy: f64::from_bits(b.energy_bits),
                spin_hash_differs: a.spin_hash != b.spin_hash,
            });
        }
    }
    if recorded.len() != replayed.len() {
        let side = recorded.get(n).or_else(|| replayed.get(n)).expect("longer side");
        return Some(Divergence {
            index: n,
            level: side.level,
            slot: side.slot,
            node_seed: side.node_seed,
            recorded_energy: recorded
                .get(n)
                .map_or(f64::NAN, |r| f64::from_bits(r.energy_bits)),
            replayed_energy: replayed
                .get(n)
                .map_or(f64::NAN, |r| f64::from_bits(r.energy_bits)),
            spin_hash_differs: true,
        });
    }
    None
}

/// The result of re-executing one record through the current binary.
#[derive(Debug, Clone)]
pub struct ReplayReport {
    /// The record's ring id.
    pub id: u64,
    /// Document / problem id.
    pub doc_id: String,
    /// Workload tag.
    pub workload: String,
    /// Byte-identity verdict: selection, summary hash and objective
    /// bits all match the recording.
    pub identical: bool,
    /// Recorded final-summary hash.
    pub recorded_summary_hash: u64,
    /// Replayed final-summary hash.
    pub replayed_summary_hash: u64,
    /// Recorded objective f64 bits.
    pub recorded_objective_bits: u64,
    /// Replayed objective f64 bits.
    pub replayed_objective_bits: u64,
    /// Recorded per-node tap count.
    pub recorded_nodes: usize,
    /// Replayed per-node tap count (0 for routes that tap no nodes).
    pub replayed_nodes: usize,
    /// First divergent DAG node (only meaningful when the record
    /// carried node taps; summary-only records triage at summary level).
    pub first_divergence: Option<Divergence>,
    /// Config keys that differ between record time and now.
    pub config_diff: Vec<ConfigDelta>,
}

impl ReplayReport {
    /// Human/one-line rendering: the `cobi-es replay` and `::REPLAY::`
    /// output format.
    pub fn verdict_line(&self) -> String {
        let mut out = format!(
            "REPLAY id={} doc={} workload={} verdict={}",
            self.id,
            self.doc_id,
            self.workload,
            if self.identical { "identical" } else { "DIVERGED" }
        );
        if !self.identical {
            out.push_str(&format!(
                " summary_hash {}->{} objective {}->{}",
                hex(self.recorded_summary_hash),
                hex(self.replayed_summary_hash),
                f64::from_bits(self.recorded_objective_bits),
                f64::from_bits(self.replayed_objective_bits),
            ));
        }
        match &self.first_divergence {
            Some(d) => out.push_str(&format!(
                " first_node=({},{}) seed={} recorded_energy={} replayed_energy={}{}",
                d.level,
                d.slot,
                hex(d.node_seed),
                d.recorded_energy,
                d.replayed_energy,
                if d.spin_hash_differs { " spins_flipped" } else { "" }
            )),
            None if !self.identical && self.recorded_nodes > 0 => {
                out.push_str(" first_node=none (taps agree; selection tail diverged)")
            }
            None => {}
        }
        out.push_str(&format!(" config_diff={}", self.config_diff.len()));
        for d in &self.config_diff {
            out.push_str(&format!(" {}:{}->{}", d.key, d.recorded, d.current));
        }
        out
    }
}

impl fmt::Display for ReplayReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.verdict_line())
    }
}

/// Re-execute one record through the current binary (inline solver,
/// recorded pipeline config, recorded request seed, CURRENT
/// fault/resilience environment) and byte-diff against the recording.
pub fn replay_record(rec: &RequestRecord, settings: &Settings) -> Result<ReplayReport> {
    let config_diff = diff_config(&rec.config, settings);
    let mut replayed_nodes = Vec::new();
    let summary = if rec.workload == "es" {
        let mut cfg = pipeline_from_fields(&rec.config, &settings.pipeline)?;
        // the record stores the ACTUAL request seed (doc-derived, and
        // worker-salted on the local route); the config pair holds the
        // base fleet seed
        cfg.seed = rec.seed;
        let doc = Document {
            id: rec.doc_id.clone(),
            sentences: rec.sentences.clone(),
            reference: Vec::new(),
        };
        let mut s = settings.clone();
        s.pipeline = cfg.clone();
        let mut solver = build_solver(
            resolved_backend(&s),
            &s,
            // construction seed: the seeded solve path never reads the
            // device-global RNG (pinned), any value works
            cfg.seed ^ 0xD00D,
            None,
            None,
            None,
            None,
            None,
        )
        .with_context(|| format!("building replay solver for record {}", rec.id))?;
        summarize_sequential_recorded(&doc, &cfg, solver.as_mut(), &mut replayed_nodes)
            .with_context(|| format!("replaying record {} ({})", rec.id, rec.doc_id))?
    } else {
        let mut s = settings.clone();
        s.pipeline = pipeline_from_fields(&rec.config, &settings.pipeline)?;
        // lower() derives problem_seed(base, workload, id) = base ^
        // salt ^ fnv1a(id); invert it so the lowered config solves
        // under exactly the recorded request seed
        s.pipeline.seed = rec.seed ^ workload_salt(&rec.workload) ^ fnv1a(rec.doc_id.as_bytes());
        let problem =
            problem_from_request(&rec.workload, &rec.doc_id, &rec.sentences, &s.workload)?;
        select_inline(problem.as_ref(), &s, None)
            .with_context(|| format!("replaying record {} ({})", rec.id, rec.doc_id))?
    };
    let replayed_summary_hash = summary_hash(&summary.selected, &summary.sentences);
    let replayed_objective_bits = summary.objective.to_bits();
    let identical = summary.selected == rec.selected
        && replayed_summary_hash == rec.summary_hash
        && replayed_objective_bits == rec.objective_bits;
    // node triage only when the record carried taps: local-route and
    // streamed requests record at summary granularity
    let first = if rec.nodes.is_empty() {
        None
    } else {
        first_divergence(&rec.nodes, &replayed_nodes)
    };
    Ok(ReplayReport {
        id: rec.id,
        doc_id: rec.doc_id.clone(),
        workload: rec.workload.clone(),
        identical,
        recorded_summary_hash: rec.summary_hash,
        replayed_summary_hash,
        recorded_objective_bits: rec.objective_bits,
        replayed_objective_bits,
        recorded_nodes: rec.nodes.len(),
        replayed_nodes: replayed_nodes.len(),
        first_divergence: first,
        config_diff,
    })
}

/// Replay every record in order; any single failure aborts with the
/// failing record's id in context.
pub fn replay_records(recs: &[RequestRecord], settings: &Settings) -> Result<Vec<ReplayReport>> {
    recs.iter().map(|r| replay_record(r, settings)).collect()
}

/// Load a `--record-out` JSONL dump: one [`RequestRecord`] per
/// non-empty line.
pub fn load_records(path: &str) -> Result<Vec<RequestRecord>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading record file {path}"))?;
    text.lines()
        .enumerate()
        .filter(|(_, l)| !l.trim().is_empty())
        .map(|(i, l)| {
            RequestRecord::parse(l).with_context(|| format!("{path}:{}", i + 1))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::recorder::{content_hash, FlightRecorder};
    use crate::sched::doc_seed;
    use crate::workload::problem_seed;

    fn test_settings() -> Settings {
        let mut s = Settings::default();
        s.pipeline.solver = "tabu".into();
        s.pipeline.iterations = 2;
        s.pipeline.summary_len = 3;
        s
    }

    /// Record one ES document exactly the way the service worker does:
    /// doc-derived seed, provenance-stamped config, per-node taps from
    /// the recording executor.
    fn record_es(s: &Settings, doc: &Document) -> RequestRecord {
        let mut rs = s.clone();
        rs.obs.record_enabled = true;
        let recorder = FlightRecorder::from_settings(&rs);
        let mut cfg = s.pipeline.clone();
        cfg.seed = doc_seed(cfg.seed, &doc.id);
        let mut rec = recorder.begin(
            &doc.id,
            &doc.sentences,
            cfg.seed,
            "es",
            cfg.strategy.as_str(),
            "pooled",
            "interactive",
            0,
        );
        let mut solver = build_solver(
            resolved_backend(s),
            s,
            cfg.seed ^ 0xD00D,
            None,
            None,
            None,
            None,
            None,
        )
        .unwrap();
        let summary =
            summarize_sequential_recorded(doc, &cfg, solver.as_mut(), &mut rec.nodes).unwrap();
        rec.finish(&summary);
        let id = recorder.record(rec);
        recorder.get(id).unwrap()
    }

    fn sample_doc() -> Document {
        crate::corpus::Generator::with_seed(41).document("replay-doc", 12)
    }

    #[test]
    fn clean_es_record_replays_byte_identical() {
        let s = test_settings();
        let doc = sample_doc();
        let rec = record_es(&s, &doc);
        assert!(!rec.nodes.is_empty());
        assert_eq!(rec.doc_hash, content_hash(&doc.sentences));
        let report = replay_record(&rec, &s).unwrap();
        assert!(report.identical, "{}", report.verdict_line());
        assert!(report.first_divergence.is_none());
        assert!(report.config_diff.is_empty());
        assert_eq!(report.recorded_nodes, report.replayed_nodes);
        assert!(report.verdict_line().contains("verdict=identical"));
        // round-tripping through JSONL changes nothing
        let report2 =
            replay_record(&RequestRecord::parse(&rec.to_jsonl()).unwrap(), &s).unwrap();
        assert!(report2.identical);
    }

    #[test]
    fn replay_uses_recorded_config_not_current() {
        // serve a record under iterations=2, then replay in a session
        // whose defaults drifted: replay must still be identical (it
        // reconstructs the recorded PipelineConfig), and the config
        // diff must name the drifted keys
        let s = test_settings();
        let rec = record_es(&s, &sample_doc());
        let mut drifted = test_settings();
        drifted.pipeline.iterations = 7;
        drifted.pipeline.lambda = 0.9;
        let report = replay_record(&rec, &drifted).unwrap();
        assert!(report.identical, "{}", report.verdict_line());
        let keys: Vec<&str> = report.config_diff.iter().map(|d| d.key.as_str()).collect();
        assert_eq!(keys, ["lambda", "iterations"]);
        assert!(report.verdict_line().contains("iterations:2->7"));
    }

    #[test]
    fn tampered_node_is_named_as_first_divergence() {
        let s = test_settings();
        let mut rec = record_es(&s, &sample_doc());
        assert!(rec.nodes.len() >= 2, "need at least two taps");
        let victim = 1;
        rec.nodes[victim].spin_hash ^= 0xFF;
        rec.nodes[victim].energy_bits = (-999.0f64).to_bits();
        let report = replay_record(&rec, &s).unwrap();
        let d = report.first_divergence.expect("divergence detected");
        assert_eq!(d.index, victim);
        assert_eq!(d.level, rec.nodes[victim].level);
        assert_eq!(d.slot, rec.nodes[victim].slot);
        assert!(d.spin_hash_differs);
        assert_eq!(d.recorded_energy, -999.0);
        assert!(d.replayed_energy.is_finite());
        let line = report.verdict_line();
        assert!(
            line.contains(&format!("first_node=({},{})", d.level, d.slot)),
            "{line}"
        );
        // the summary itself still matched — only the tap was tampered
        assert!(report.identical);
    }

    #[test]
    fn truncated_node_list_diverges_at_the_cut() {
        let s = test_settings();
        let mut rec = record_es(&s, &sample_doc());
        let cut = rec.nodes.len() - 1;
        rec.nodes.truncate(cut);
        let report = replay_record(&rec, &s).unwrap();
        let d = report.first_divergence.expect("length mismatch detected");
        assert_eq!(d.index, cut);
        assert!(d.recorded_energy.is_nan());
        assert!(d.replayed_energy.is_finite());
    }

    #[test]
    fn non_es_record_replays_through_the_workload_factory() {
        let s = test_settings();
        let lines = vec!["n=10 k=3 seed=5".to_string()];
        let id = "disp-replay";
        let seed = problem_seed(s.pipeline.seed, "dispersion", id);
        let mut rs = s.clone();
        rs.obs.record_enabled = true;
        let recorder = FlightRecorder::from_settings(&rs);
        let mut rec = recorder.begin(
            id,
            &lines,
            seed,
            "dispersion",
            s.pipeline.strategy.as_str(),
            "local",
            "batch",
            0,
        );
        let problem = problem_from_request("dispersion", id, &lines, &s.workload).unwrap();
        let summary = select_inline(problem.as_ref(), &s, None).unwrap();
        rec.finish(&summary);
        recorder.record(rec.clone());

        let report = replay_record(&rec, &s).unwrap();
        assert!(report.identical, "{}", report.verdict_line());
        assert_eq!(report.recorded_nodes, 0, "non-ES records tap no nodes");
        assert!(report.first_divergence.is_none());

        // a different recorded selection is flagged at summary level
        let mut bad = rec.clone();
        bad.summary_hash ^= 1;
        let report = replay_record(&bad, &s).unwrap();
        assert!(!report.identical);
        assert!(report.verdict_line().contains("verdict=DIVERGED"));
    }

    #[test]
    fn pipeline_from_fields_round_trips_provenance() {
        let mut s = Settings::default();
        s.pipeline.lambda = 0.85;
        s.pipeline.iterations = 4;
        s.pipeline.strategy = crate::decompose::Strategy::Tree;
        s.pipeline.solver = "sa".into();
        s.pipeline.seed = 0xFFFF_FFFF_FFFF_FFF7;
        let fields = provenance_fields(&s);
        let cfg = pipeline_from_fields(&fields, &Settings::default().pipeline).unwrap();
        assert_eq!(cfg, s.pipeline);
        // unknown keys fail loudly
        let bogus = vec![("no_such_key".to_string(), "1".to_string())];
        assert!(pipeline_from_fields(&bogus, &s.pipeline).is_err());
    }

    #[test]
    fn load_records_round_trips_a_dump() {
        let s = test_settings();
        let rec = record_es(&s, &sample_doc());
        let dir = std::env::temp_dir().join(format!("cobi-es-replay-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("records.jsonl");
        std::fs::write(&path, format!("{}\n\n{}\n", rec.to_jsonl(), rec.to_jsonl())).unwrap();
        let loaded = load_records(path.to_str().unwrap()).unwrap();
        assert_eq!(loaded.len(), 2, "blank lines skipped");
        assert_eq!(loaded[0], rec);
        let reports = replay_records(&loaded, &s).unwrap();
        assert!(reports.iter().all(|r| r.identical));
        std::fs::remove_dir_all(&dir).ok();
    }
}
