//! Request-scoped span trees.
//!
//! A [`Span`] is one stage of one request (ingest, embed, decompose,
//! quantize, solve, vote, score …) with two attribute sets:
//!
//! * **`attrs`** — deterministic facts: pure functions of
//!   (configuration, document, seeds), e.g. `doc_seed`, strategy,
//!   backend route, instance counts, and *modeled* device time/energy.
//!   These are identical across pool shapes, worker assignment and
//!   dispatch order, and are the only fields included in pinned output
//!   (decision #18).
//! * **`wall`** — measured wall-clock facts: queue wait, solve time,
//!   fleet coalesce occupancy. Inherently nondeterministic; excluded
//!   whenever byte-identity is asserted.
//!
//! Spans are plain data: building one never draws from any RNG stream,
//! and the collector only sees completed trees, so tracing cannot
//! perturb solver results.

use super::json::escape_into;

/// One attribute value (span attributes are flat key→value pairs).
#[derive(Debug, Clone, PartialEq)]
pub enum AttrValue {
    /// Unsigned integer (counts, seeds, indices).
    U64(u64),
    /// Float (modeled seconds/joules, objectives).
    F64(f64),
    /// Text (document ids, strategy/backend names).
    Str(String),
    /// Flag (cache on/off and similar).
    Bool(bool),
}

impl AttrValue {
    fn write_json(&self, out: &mut String) {
        match self {
            AttrValue::U64(x) => out.push_str(&x.to_string()),
            // finite by construction; Display is exact and deterministic
            AttrValue::F64(x) => out.push_str(&format!("{x}")),
            AttrValue::Str(s) => {
                out.push('"');
                escape_into(out, s);
                out.push('"');
            }
            AttrValue::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        }
    }
}

impl From<u64> for AttrValue {
    fn from(x: u64) -> Self {
        AttrValue::U64(x)
    }
}
impl From<usize> for AttrValue {
    fn from(x: usize) -> Self {
        AttrValue::U64(x as u64)
    }
}
impl From<f64> for AttrValue {
    fn from(x: f64) -> Self {
        AttrValue::F64(x)
    }
}
impl From<bool> for AttrValue {
    fn from(x: bool) -> Self {
        AttrValue::Bool(x)
    }
}
impl From<&str> for AttrValue {
    fn from(x: &str) -> Self {
        AttrValue::Str(x.to_string())
    }
}
impl From<String> for AttrValue {
    fn from(x: String) -> Self {
        AttrValue::Str(x)
    }
}

/// One stage of one request (see module docs). Children nest in
/// submission order, which is itself deterministic per request.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    /// Stage name (`"request"`, `"solve"`, …).
    pub stage: &'static str,
    attrs: Vec<(&'static str, AttrValue)>,
    wall: Vec<(&'static str, AttrValue)>,
    /// Child stages, in creation order.
    pub children: Vec<Span>,
}

impl Span {
    /// Empty span for `stage`.
    pub fn new(stage: &'static str) -> Self {
        Self {
            stage,
            attrs: Vec::new(),
            wall: Vec::new(),
            children: Vec::new(),
        }
    }

    /// Append a deterministic attribute (insertion order is kept).
    pub fn set(&mut self, key: &'static str, value: impl Into<AttrValue>) {
        self.attrs.push((key, value.into()));
    }

    /// Builder-style [`Span::set`].
    pub fn with(mut self, key: &'static str, value: impl Into<AttrValue>) -> Self {
        self.set(key, value);
        self
    }

    /// Append a wall-clock attribute (excluded from pinned output).
    pub fn set_wall(&mut self, key: &'static str, value: impl Into<AttrValue>) {
        self.wall.push((key, value.into()));
    }

    /// Append a child stage; returns its index (for late wall updates).
    pub fn push(&mut self, child: Span) -> usize {
        self.children.push(child);
        self.children.len() - 1
    }

    /// Deterministic attribute lookup (first match).
    pub fn attr(&self, key: &str) -> Option<&AttrValue> {
        self.attrs.iter().find(|(k, _)| *k == key).map(|(_, v)| v)
    }

    /// Render the tree as one JSON object. `include_wall = false` drops
    /// every `wall` section recursively — the byte-identical-across-
    /// pool-shapes form; `true` is the full JSONL export form.
    pub fn to_json(&self, include_wall: bool) -> String {
        let mut out = String::with_capacity(128);
        self.write_json(&mut out, include_wall);
        out
    }

    fn write_json(&self, out: &mut String, include_wall: bool) {
        out.push_str("{\"stage\":\"");
        escape_into(out, self.stage);
        out.push_str("\",\"attrs\":{");
        for (i, (k, v)) in self.attrs.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push('"');
            escape_into(out, k);
            out.push_str("\":");
            v.write_json(out);
        }
        out.push('}');
        if include_wall {
            out.push_str(",\"wall\":{");
            for (i, (k, v)) in self.wall.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('"');
                escape_into(out, k);
                out.push_str("\":");
                v.write_json(out);
            }
            out.push('}');
        }
        if !self.children.is_empty() {
            out.push_str(",\"children\":[");
            for (i, c) in self.children.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                c.write_json(out, include_wall);
            }
            out.push(']');
        }
        out.push('}');
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::json::JsonValue;

    fn sample() -> Span {
        let mut root = Span::new("request")
            .with("doc", "bench-0")
            .with("seed", 42u64)
            .with("cache", false);
        root.set_wall("queue_wait_us", 17u64);
        let mut solve = Span::new("solve").with("n", 20usize).with("modeled_j", 0.5f64);
        solve.set_wall("solve_us", 99u64);
        root.push(solve);
        root
    }

    #[test]
    fn json_shape_and_key_order() {
        let s = sample().to_json(true);
        assert!(s.starts_with(r#"{"stage":"request","attrs":{"doc":"bench-0","seed":42"#));
        assert!(s.contains(r#""wall":{"queue_wait_us":17}"#), "{s}");
        assert!(s.contains(r#""children":[{"stage":"solve""#), "{s}");
        let v = JsonValue::parse(&s).unwrap();
        assert_eq!(v.get("stage").unwrap().as_str(), Some("request"));
        let child = &v.get("children").unwrap().as_array().unwrap()[0];
        assert_eq!(child.get("attrs").unwrap().get("n").unwrap().as_u64(), Some(20));
    }

    #[test]
    fn pinned_form_excludes_wall_recursively() {
        let s = sample().to_json(false);
        assert!(!s.contains("wall"), "{s}");
        assert!(!s.contains("queue_wait_us"), "{s}");
        assert!(!s.contains("solve_us"), "{s}");
        JsonValue::parse(&s).unwrap();
    }

    #[test]
    fn attr_lookup_and_escaping() {
        let span = Span::new("ingest").with("doc", "quo\"ted\nid");
        assert_eq!(
            span.attr("doc"),
            Some(&AttrValue::Str("quo\"ted\nid".into()))
        );
        let parsed = JsonValue::parse(&span.to_json(false)).unwrap();
        assert_eq!(
            parsed.get("attrs").unwrap().get("doc").unwrap().as_str(),
            Some("quo\"ted\nid")
        );
    }
}
