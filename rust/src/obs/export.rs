//! Observability exporters: Prometheus-style text exposition
//! (`::METRICS::`), machine-readable stats JSON (`::STATS JSON::`), and
//! the JSONL trace dump behind `serve --trace-out`.
//!
//! All three render from plain snapshots (`ServiceMetrics`, drained
//! [`Span`] trees) with hand-rolled formatting — no `serde` in the
//! vendored dependency set (decision #5). The exposition follows the
//! Prometheus text conventions (`# TYPE` lines, `{label="…"}` pairs,
//! histogram `_bucket`/`_sum`/`_count` triplets with cumulative
//! counts); metric names are prefixed `cobi_es_`.

use std::io::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

use super::json::escape_into;
use super::span::Span;
use super::{bucket_label, ObsMetrics};
use crate::portfolio::BackendKind;
use crate::service::metrics::{Histogram, ServiceMetrics};

/// Render the full Prometheus-style exposition for one metrics
/// snapshot. Every line ends with `\n`; the `::METRICS::` handler
/// frames it as `OK <line-count>` + the lines.
pub fn exposition(m: &ServiceMetrics) -> String {
    let mut out = String::with_capacity(2048);
    let push_counter = |out: &mut String, name: &str, labels: &str, v: u64| {
        out.push_str(&format!("cobi_es_{name}{labels} {v}\n"));
    };

    out.push_str("# TYPE cobi_es_requests_total counter\n");
    for (state, v) in [
        ("submitted", m.submitted),
        ("completed", m.completed),
        ("failed", m.failed),
        ("rejected", m.rejected),
    ] {
        push_counter(&mut out, "requests_total", &format!("{{state=\"{state}\"}}"), v);
    }

    out.push_str("# TYPE cobi_es_summaries_total counter\n");
    for (strategy, v) in [
        ("window", m.strategies.window),
        ("tree", m.strategies.tree),
        ("stream", m.strategies.stream),
    ] {
        push_counter(
            &mut out,
            "summaries_total",
            &format!("{{strategy=\"{strategy}\"}}"),
            v,
        );
    }

    out.push_str("# TYPE cobi_es_workload_requests_total counter\n");
    for (workload, v) in [
        ("es", m.workloads.es),
        ("retrieval", m.workloads.retrieval),
        ("dispersion", m.workloads.dispersion),
    ] {
        push_counter(
            &mut out,
            "workload_requests_total",
            &format!("{{workload=\"{workload}\"}}"),
            v,
        );
    }

    histogram_lines(&mut out, "queue_wait_seconds", "", &m.queue_hist);
    histogram_lines(&mut out, "solve_seconds", "", &m.solve_hist);

    if m.pool.devices > 0 {
        out.push_str("# TYPE cobi_es_pool_devices gauge\n");
        out.push_str(&format!("cobi_es_pool_devices {}\n", m.pool.devices));
        out.push_str("# TYPE cobi_es_pool_dispatches_total counter\n");
        push_counter(&mut out, "pool_dispatches_total", "", m.pool.dispatches);
        out.push_str("# TYPE cobi_es_pool_requests_total counter\n");
        push_counter(&mut out, "pool_requests_total", "", m.pool.requests);
        out.push_str("# TYPE cobi_es_pool_instances_total counter\n");
        push_counter(&mut out, "pool_instances_total", "", m.pool.instances);
        out.push_str("# TYPE cobi_es_pool_busy_seconds_total counter\n");
        out.push_str(&format!("cobi_es_pool_busy_seconds_total {}\n", m.pool.busy_s));
    }

    if let Some(p) = &m.portfolio {
        out.push_str("# TYPE cobi_es_portfolio_routes_total counter\n");
        for b in BackendKind::ALL {
            push_counter(
                &mut out,
                "portfolio_routes_total",
                &format!("{{backend=\"{}\"}}", b.name()),
                p.route_count(b),
            );
        }
        out.push_str("# TYPE cobi_es_cache_events_total counter\n");
        for (event, v) in [
            ("lookup", p.cache.lookups),
            ("exact_hit", p.cache.exact_hits),
            ("warm_hit", p.cache.warm_hits),
            ("miss", p.cache.misses),
        ] {
            push_counter(
                &mut out,
                "cache_events_total",
                &format!("{{event=\"{event}\"}}"),
                v,
            );
        }
    }

    if let Some(r) = &m.resilience {
        out.push_str("# TYPE cobi_es_resilience_events_total counter\n");
        for (event, v) in [
            ("requests", r.requests),
            ("replica_solves", r.replica_solves),
            ("vote_disagreements", r.vote_disagreements),
            ("verify_failures", r.verify_failures),
            ("retries", r.retries),
            ("escalations", r.escalations),
            ("repairs", r.repairs),
        ] {
            push_counter(
                &mut out,
                "resilience_events_total",
                &format!("{{event=\"{event}\"}}"),
                v,
            );
        }
    }

    if m.pool.expired > 0 {
        out.push_str("# TYPE cobi_es_pool_expired_total counter\n");
        push_counter(&mut out, "pool_expired_total", "", m.pool.expired);
    }

    if m.overload.any() {
        out.push_str("# TYPE cobi_es_overload_events_total counter\n");
        for (event, v) in [
            ("deadline_exceeded", m.overload.deadline_exceeded),
            ("shed_batch", m.overload.shed_batch),
            ("shed_interactive", m.overload.shed_interactive),
            ("worker_panics", m.overload.worker_panics),
            ("drains", m.overload.drains),
            ("drain_aborted", m.overload.drain_aborted),
        ] {
            push_counter(
                &mut out,
                "overload_events_total",
                &format!("{{event=\"{event}\"}}"),
                v,
            );
        }
    }

    if let Some(b) = &m.breaker {
        if b.any() {
            out.push_str("# TYPE cobi_es_breaker_open_devices gauge\n");
            out.push_str(&format!("cobi_es_breaker_open_devices {}\n", b.open));
            out.push_str("# TYPE cobi_es_breaker_retired_devices gauge\n");
            out.push_str(&format!("cobi_es_breaker_retired_devices {}\n", b.retired));
            out.push_str("# TYPE cobi_es_breaker_events_total counter\n");
            for (event, v) in [
                ("trips", b.trips),
                ("probes", b.probes),
                ("readmissions", b.readmissions),
                ("retirements", b.retirements),
            ] {
                push_counter(
                    &mut out,
                    "breaker_events_total",
                    &format!("{{event=\"{event}\"}}"),
                    v,
                );
            }
        }
    }

    if let Some(o) = &m.obs {
        out.push_str("# TYPE cobi_es_traces_total counter\n");
        push_counter(&mut out, "traces_total", "{state=\"recorded\"}", o.recorded);
        push_counter(&mut out, "traces_total", "{state=\"dropped\"}", o.dropped);
        out.push_str("# TYPE cobi_es_dispatch_instances_total counter\n");
        push_counter(&mut out, "dispatch_instances_total", "", o.dispatch_instances);

        // data-loss counters, one series per silent drop path: spans
        // lost to trace-ring contention/overwrite, exemplars displaced
        // from the top-K store, flight records overwritten in the
        // bounded recorder ring
        out.push_str("# TYPE cobi_es_obs_dropped_total counter\n");
        for (kind, v) in [
            ("trace_ring", o.dropped),
            ("exemplar_evict", o.exemplar_evictions),
            ("recorder_ring", o.recorder_overwritten),
        ] {
            push_counter(
                &mut out,
                "obs_dropped_total",
                &format!("{{kind=\"{kind}\"}}"),
                v,
            );
        }

        if o.recorder_enabled {
            out.push_str("# TYPE cobi_es_recorder_records_total counter\n");
            push_counter(&mut out, "recorder_records_total", "", o.recorder_recorded);
            out.push_str("# TYPE cobi_es_recorder_buffered gauge\n");
            out.push_str(&format!("cobi_es_recorder_buffered {}\n", o.recorder_buffered));
        }

        // the fleet energy ledger: joules, device-seconds and solve
        // counts per (backend, subsystem, size bucket)
        out.push_str("# TYPE cobi_es_energy_joules_total counter\n");
        out.push_str("# TYPE cobi_es_device_seconds_total counter\n");
        out.push_str("# TYPE cobi_es_ledger_solves_total counter\n");
        for row in &o.ledger {
            // backend names are config-supplied free text; the other
            // labels are enum-derived and never need escaping
            let labels = format!(
                "{{backend=\"{}\",subsystem=\"{}\",bucket=\"{}\"}}",
                label_escape(&row.backend),
                row.subsystem,
                bucket_label(row.bucket)
            );
            out.push_str(&format!("cobi_es_energy_joules_total{labels} {}\n", row.cell.joules));
            out.push_str(&format!(
                "cobi_es_device_seconds_total{labels} {}\n",
                row.cell.device_s
            ));
            out.push_str(&format!("cobi_es_ledger_solves_total{labels} {}\n", row.cell.solves));
        }
    }

    out
}

/// Append a Prometheus histogram (`_bucket` cumulative counts + `_sum`
/// + `_count`) for `h` under `cobi_es_<name>`.
fn histogram_lines(out: &mut String, name: &str, labels: &str, h: &Histogram) {
    out.push_str(&format!("# TYPE cobi_es_{name} histogram\n"));
    let mut cum = 0u64;
    for (bound, count) in h.buckets() {
        cum += count;
        let le = if bound.is_finite() {
            format!("{bound}")
        } else {
            "+Inf".to_string()
        };
        let sep = if labels.is_empty() { "" } else { "," };
        out.push_str(&format!(
            "cobi_es_{name}_bucket{{{labels}{sep}le=\"{le}\"}} {cum}\n"
        ));
    }
    out.push_str(&format!("cobi_es_{name}_sum{labels2} {}\n", h.sum(), labels2 = braced(labels)));
    out.push_str(&format!(
        "cobi_es_{name}_count{labels2} {}\n",
        h.count(),
        labels2 = braced(labels)
    ));
}

/// Escape a Prometheus label VALUE per the text-format rules: `\` as
/// `\\`, `"` as `\"`, newline as `\n`. Applied to free-text label
/// values (backend names come from config) so a hostile or typo'd
/// string cannot break the exposition framing.
fn label_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

fn braced(labels: &str) -> String {
    if labels.is_empty() {
        String::new()
    } else {
        format!("{{{labels}}}")
    }
}

/// Render one metrics snapshot as a single-line JSON object — the
/// `::STATS JSON::` reply body. Shape (stable keys, all optional
/// sections `null` when absent):
/// `{"requests": {...}, "latency": {...}, "strategies": {...},
///   "pool": {...}|null, "portfolio": {...}|null,
///   "resilience": {...}|null, "overload": {...}|null,
///   "breaker": {...}|null, "obs": {...}|null}`.
pub fn stats_json(m: &ServiceMetrics) -> String {
    let mut out = String::with_capacity(1024);
    out.push('{');

    out.push_str("\"requests\":{");
    out.push_str(&format!(
        "\"submitted\":{},\"completed\":{},\"failed\":{},\"rejected\":{}",
        m.submitted, m.completed, m.failed, m.rejected
    ));
    out.push('}');

    let l = m.latency_summary();
    out.push_str(&format!(
        ",\"latency\":{{\"queue_p50_s\":{},\"queue_p99_s\":{},\"solve_p50_s\":{},\"solve_p99_s\":{}}}",
        l.queue_p50, l.queue_p99, l.solve_p50, l.solve_p99
    ));

    out.push_str(&format!(
        ",\"strategies\":{{\"window\":{},\"tree\":{},\"stream\":{},\"sessions\":{},\"chunks\":{},\"revisions\":{}}}",
        m.strategies.window,
        m.strategies.tree,
        m.strategies.stream,
        m.strategies.stream_sessions,
        m.strategies.stream_chunks,
        m.strategies.stream_revisions
    ));

    if m.pool.devices > 0 {
        out.push_str(&format!(
            ",\"pool\":{{\"devices\":{},\"dispatches\":{},\"requests\":{},\"instances\":{},\"busy_s\":{},\"occupancy\":{}}}",
            m.pool.devices,
            m.pool.dispatches,
            m.pool.requests,
            m.pool.instances,
            m.pool.busy_s,
            m.pool.batch_occupancy()
        ));
    } else {
        out.push_str(",\"pool\":null");
    }

    if let Some(p) = &m.portfolio {
        out.push_str(",\"portfolio\":{\"routes\":{");
        for (i, b) in BackendKind::ALL.into_iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\"{}\":{}", b.name(), p.route_count(b)));
        }
        out.push_str(&format!(
            "}},\"cache\":{{\"lookups\":{},\"exact_hits\":{},\"warm_hits\":{},\"misses\":{},\"entries\":{}}}}}",
            p.cache.lookups, p.cache.exact_hits, p.cache.warm_hits, p.cache.misses, p.cache.entries
        ));
    } else {
        out.push_str(",\"portfolio\":null");
    }

    if let Some(r) = &m.resilience {
        out.push_str(&format!(
            ",\"resilience\":{{\"requests\":{},\"replica_solves\":{},\"vote_disagreements\":{},\"verify_failures\":{},\"retries\":{},\"escalations\":{},\"repairs\":{}}}",
            r.requests,
            r.replica_solves,
            r.vote_disagreements,
            r.verify_failures,
            r.retries,
            r.escalations,
            r.repairs
        ));
    } else {
        out.push_str(",\"resilience\":null");
    }

    if m.overload.any() {
        out.push_str(&format!(
            ",\"overload\":{{\"deadline_exceeded\":{},\"shed_batch\":{},\"shed_interactive\":{},\"worker_panics\":{},\"drains\":{},\"drain_aborted\":{},\"expired\":{}}}",
            m.overload.deadline_exceeded,
            m.overload.shed_batch,
            m.overload.shed_interactive,
            m.overload.worker_panics,
            m.overload.drains,
            m.overload.drain_aborted,
            m.pool.expired
        ));
    } else {
        out.push_str(",\"overload\":null");
    }

    match &m.breaker {
        Some(b) if b.any() => {
            out.push_str(&format!(
                ",\"breaker\":{{\"devices\":{},\"open\":{},\"retired\":{},\"trips\":{},\"probes\":{},\"readmissions\":{},\"retirements\":{}}}",
                b.devices, b.open, b.retired, b.trips, b.probes, b.readmissions, b.retirements
            ));
        }
        _ => out.push_str(",\"breaker\":null"),
    }

    match &m.obs {
        Some(o) => {
            out.push_str(&format!(
                ",\"obs\":{{\"tracing\":{},\"recorded\":{},\"dropped\":{},\"energy_j\":{},\"device_s\":{}",
                o.tracing_enabled, o.recorded, o.dropped, o.total_joules(), o.total_device_s()
            ));
            out.push_str(",\"exemplars\":[");
            for (i, e) in o.exemplars.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str("{\"doc\":\"");
                escape_into(&mut out, &e.doc);
                out.push_str(&format!("\",\"secs\":{}}}", e.secs));
            }
            out.push_str("],\"ledger\":[");
            for (i, row) in o.ledger.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str("{\"backend\":\"");
                escape_into(&mut out, &row.backend);
                out.push_str(&format!(
                    "\",\"subsystem\":\"{}\",\"bucket\":\"{}\",\"solves\":{},\"device_s\":{},\"joules\":{}}}",
                    row.subsystem,
                    bucket_label(row.bucket),
                    row.cell.solves,
                    row.cell.device_s,
                    row.cell.joules
                ));
            }
            out.push_str("]}");
        }
        None => out.push_str(",\"obs\":null"),
    }

    out.push('}');
    out
}

/// Convenience for callers that only hold an [`ObsMetrics`]: total
/// ledger joules per backend as `(backend, joules)` pairs.
pub fn joules_by_backend(o: &ObsMetrics) -> Vec<(String, f64)> {
    let mut out: Vec<(String, f64)> = Vec::new();
    for row in &o.ledger {
        match out.iter_mut().find(|(b, _)| *b == row.backend) {
            Some((_, j)) => *j += row.cell.joules,
            None => out.push((row.backend.clone(), row.cell.joules)),
        }
    }
    out
}

/// Append `spans` to `path` as JSONL — one full span tree (wall
/// sections included) per line. Creates the file on first use.
pub fn append_jsonl(path: &Path, spans: &[Span]) -> Result<()> {
    if spans.is_empty() {
        return Ok(());
    }
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .with_context(|| format!("opening trace file {}", path.display()))?;
    let mut buf = String::new();
    for span in spans {
        buf.push_str(&span.to_json(true));
        buf.push('\n');
    }
    f.write_all(buf.as_bytes())
        .with_context(|| format!("writing trace file {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::json::JsonValue;
    use crate::obs::{EnergyLedger, EnergyModel, Subsystem};
    use crate::config::Settings;

    fn snapshot_with_obs() -> ServiceMetrics {
        let mut m = ServiceMetrics::default();
        m.record_latency(
            std::time::Duration::from_millis(1),
            std::time::Duration::from_millis(20),
        );
        m.submitted = 3;
        m.completed = 2;
        let ledger = EnergyLedger::new(EnergyModel::from_settings(&Settings::default()));
        ledger.charge("cobi", Subsystem::Pool, 20, 4);
        ledger.charge("tabu", Subsystem::Resilience, 10, 1);
        m.obs = Some(crate::obs::ObsMetrics {
            tracing_enabled: true,
            recorded: 1,
            ledger: ledger.rows(),
            exemplars: vec![crate::obs::Exemplar {
                doc: "doc-1".into(),
                secs: 0.021,
            }],
            ..Default::default()
        });
        m
    }

    #[test]
    fn exposition_contains_ledger_counters_and_histograms() {
        let text = exposition(&snapshot_with_obs());
        assert!(text.contains("# TYPE cobi_es_energy_joules_total counter"), "{text}");
        assert!(
            text.contains("cobi_es_energy_joules_total{backend=\"cobi\",subsystem=\"pool\",bucket=\"le32\"}"),
            "{text}"
        );
        assert!(text.contains("cobi_es_device_seconds_total{backend=\"tabu\""), "{text}");
        assert!(text.contains("cobi_es_ledger_solves_total"), "{text}");
        assert!(text.contains("cobi_es_requests_total{state=\"submitted\"} 3"), "{text}");
        assert!(text.contains("cobi_es_solve_seconds_bucket{le=\"+Inf\"} 1"), "{text}");
        assert!(text.contains("cobi_es_solve_seconds_count 1"), "{text}");
        // every line is either a comment or "name{labels} value"
        for line in text.lines() {
            assert!(
                line.starts_with("# TYPE cobi_es_") || line.starts_with("cobi_es_"),
                "{line}"
            );
        }
    }

    #[test]
    fn exposition_exports_workload_and_obs_loss_counters() {
        let mut m = snapshot_with_obs();
        m.workloads.record("es");
        m.workloads.record("retrieval");
        m.workloads.record("retrieval");
        m.workloads.record("dispersion");
        {
            let o = m.obs.as_mut().unwrap();
            o.dropped = 2;
            o.exemplar_evictions = 5;
            o.recorder_overwritten = 7;
            o.recorder_enabled = true;
            o.recorder_recorded = 9;
            o.recorder_buffered = 4;
        }
        let text = exposition(&m);
        assert!(
            text.contains("cobi_es_workload_requests_total{workload=\"es\"} 1"),
            "{text}"
        );
        assert!(
            text.contains("cobi_es_workload_requests_total{workload=\"retrieval\"} 2"),
            "{text}"
        );
        assert!(
            text.contains("cobi_es_workload_requests_total{workload=\"dispersion\"} 1"),
            "{text}"
        );
        assert!(
            text.contains("cobi_es_obs_dropped_total{kind=\"trace_ring\"} 2"),
            "{text}"
        );
        assert!(
            text.contains("cobi_es_obs_dropped_total{kind=\"exemplar_evict\"} 5"),
            "{text}"
        );
        assert!(
            text.contains("cobi_es_obs_dropped_total{kind=\"recorder_ring\"} 7"),
            "{text}"
        );
        assert!(text.contains("cobi_es_recorder_records_total 9"), "{text}");
        assert!(text.contains("cobi_es_recorder_buffered 4"), "{text}");

        // the workload series is present (zeroed) even on a fresh fleet,
        // so dashboards don't need existence checks
        let quiet = exposition(&ServiceMetrics::default());
        assert!(
            quiet.contains("cobi_es_workload_requests_total{workload=\"es\"} 0"),
            "{quiet}"
        );
        // recorder gauges stay absent while the recorder is off
        assert!(!quiet.contains("cobi_es_recorder_records_total"), "{quiet}");
    }

    #[test]
    fn label_escape_neutralizes_quotes_and_newlines() {
        assert_eq!(label_escape("tabu"), "tabu");
        assert_eq!(label_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        let mut m = snapshot_with_obs();
        for row in &mut m.obs.as_mut().unwrap().ledger {
            row.backend = "ta\"bu".into();
        }
        let text = exposition(&m);
        assert!(text.contains("backend=\"ta\\\"bu\""), "{text}");
        for line in text.lines() {
            assert!(
                line.starts_with("# TYPE cobi_es_") || line.starts_with("cobi_es_"),
                "{line}"
            );
        }
    }

    #[test]
    fn histogram_bucket_counts_are_cumulative() {
        let mut h = Histogram::new(vec![1e-3, 1e-2]);
        h.record(0.5e-3);
        h.record(5e-3);
        h.record(5.0);
        let mut out = String::new();
        histogram_lines(&mut out, "t_seconds", "", &h);
        assert!(out.contains("cobi_es_t_seconds_bucket{le=\"0.001\"} 1"), "{out}");
        assert!(out.contains("cobi_es_t_seconds_bucket{le=\"0.01\"} 2"), "{out}");
        assert!(out.contains("cobi_es_t_seconds_bucket{le=\"+Inf\"} 3"), "{out}");
        assert!(out.contains("cobi_es_t_seconds_count 3"), "{out}");
    }

    #[test]
    fn stats_json_parses_and_round_trips_counters() {
        let m = snapshot_with_obs();
        let line = stats_json(&m);
        let v = JsonValue::parse(&line).unwrap();
        assert_eq!(v.get("requests").unwrap().get("submitted").unwrap().as_u64(), Some(3));
        assert_eq!(v.get("pool"), Some(&JsonValue::Null));
        assert_eq!(v.get("portfolio"), Some(&JsonValue::Null));
        let obs = v.get("obs").unwrap();
        assert_eq!(obs.get("recorded").unwrap().as_u64(), Some(1));
        let ledger = obs.get("ledger").unwrap().as_array().unwrap();
        assert_eq!(ledger.len(), 2);
        assert_eq!(ledger[0].get("backend").unwrap().as_str(), Some("cobi"));
        assert_eq!(ledger[0].get("solves").unwrap().as_u64(), Some(4));
        let ex = obs.get("exemplars").unwrap().as_array().unwrap();
        assert_eq!(ex[0].get("doc").unwrap().as_str(), Some("doc-1"));
        assert!(obs.get("energy_j").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn overload_and_breaker_series_appear_only_when_active() {
        let mut m = snapshot_with_obs();
        // quiet: no overload/breaker lines, json sections null
        let text = exposition(&m);
        assert!(!text.contains("cobi_es_overload_events_total"), "{text}");
        assert!(!text.contains("cobi_es_breaker_"), "{text}");
        let v = JsonValue::parse(&stats_json(&m)).unwrap();
        assert_eq!(v.get("overload"), Some(&JsonValue::Null));
        assert_eq!(v.get("breaker"), Some(&JsonValue::Null));

        m.overload.shed_batch = 2;
        m.overload.deadline_exceeded = 1;
        m.pool.expired = 3;
        m.breaker = Some(crate::sched::BreakerMetrics {
            devices: 4,
            open: 1,
            trips: 2,
            probes: 5,
            readmissions: 1,
            ..Default::default()
        });
        let text = exposition(&m);
        assert!(
            text.contains("cobi_es_overload_events_total{event=\"shed_batch\"} 2"),
            "{text}"
        );
        assert!(text.contains("cobi_es_pool_expired_total 3"), "{text}");
        assert!(text.contains("cobi_es_breaker_open_devices 1"), "{text}");
        assert!(
            text.contains("cobi_es_breaker_events_total{event=\"trips\"} 2"),
            "{text}"
        );
        let v = JsonValue::parse(&stats_json(&m)).unwrap();
        let o = v.get("overload").unwrap();
        assert_eq!(o.get("shed_batch").unwrap().as_u64(), Some(2));
        assert_eq!(o.get("expired").unwrap().as_u64(), Some(3));
        let b = v.get("breaker").unwrap();
        assert_eq!(b.get("devices").unwrap().as_u64(), Some(4));
        assert_eq!(b.get("probes").unwrap().as_u64(), Some(5));
    }

    #[test]
    fn joules_by_backend_aggregates_rows() {
        let m = snapshot_with_obs();
        let by = joules_by_backend(m.obs.as_ref().unwrap());
        assert_eq!(by.len(), 2);
        assert_eq!(by[0].0, "cobi");
        assert!(by.iter().all(|(_, j)| *j > 0.0));
    }

    #[test]
    fn jsonl_appends_one_parseable_line_per_tree() {
        let dir = std::env::temp_dir().join(format!("cobi-es-obs-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.jsonl");
        let _ = std::fs::remove_file(&path);
        let spans = vec![
            Span::new("request").with("doc", "a"),
            Span::new("request").with("doc", "b"),
        ];
        append_jsonl(&path, &spans).unwrap();
        append_jsonl(&path, &spans[..1].to_vec()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        for line in lines {
            let v = JsonValue::parse(line).unwrap();
            assert!(v.get("stage").is_some());
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
