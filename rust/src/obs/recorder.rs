//! Flight recorder: per-request provenance capture for deterministic
//! replay (ISSUE 10).
//!
//! Spans (PR 6) show *where time went*; the recorder captures *what was
//! computed from which inputs*: one [`RequestRecord`] per served request
//! holding the request seed, the document content (and its hash), the
//! workload/strategy/route/tier, a fingerprint of the resolved config,
//! the fault-model provenance, per-DAG-node solve taps
//! ([`NodeRecord`]: level, slot, node seed, spin-vector hash, energy
//! bits) and the final selection + summary hash + objective bits.
//! Because the whole pipeline is a pure function of (config, document,
//! seed), a record is a reproducible local test case: the replay engine
//! ([`super::replay`]) re-executes it through the current binary and
//! byte-diffs the outputs.
//!
//! Determinism: records carry NO wall-clock data, so the JSONL emitted
//! for a request is byte-identical across pool shapes, coalescing and
//! worker counts — exactly like the pinned span form (decision #18).
//! With `[obs] record_enabled = false` (the default) the serving hot
//! path never consults the ring and allocates nothing
//! (`tests/alloc_audit.rs`). u64 seeds/hashes and f64 bit patterns are
//! emitted as `"0x…"` hex strings: the JSON reader surfaces numbers as
//! `f64`, which cannot hold them exactly.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::Settings;
use crate::pipeline::Summary;
use crate::solvers::SolveResult;

use super::json::{escape_into, JsonValue};

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01B3;

#[inline]
fn fnv_byte(h: u64, b: u8) -> u64 {
    (h ^ b as u64).wrapping_mul(FNV_PRIME)
}

#[inline]
fn fnv_bytes(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h = fnv_byte(h, b);
    }
    h
}

/// FNV-1a over the spin vectors of one request's solved instances, in
/// submission order (a `0x7C` separator folds in after each instance so
/// instance boundaries cannot alias). This is the per-node tap the
/// executors record: two solves agree on this hash iff every replica's
/// spin vector is byte-identical.
pub fn spin_hash(solved: &[SolveResult]) -> u64 {
    let mut h = FNV_OFFSET;
    for r in solved {
        for &s in &r.spins {
            h = fnv_byte(h, s as u8);
        }
        h = fnv_byte(h, b'|');
    }
    h
}

/// FNV-1a over a final selection: each selected index (little-endian
/// u64) then each summary sentence (with a `\n` separator). Two
/// summaries agree on this hash iff they are byte-identical.
pub fn summary_hash(selected: &[usize], sentences: &[String]) -> u64 {
    let mut h = FNV_OFFSET;
    for &i in selected {
        h = fnv_bytes(h, &(i as u64).to_le_bytes());
    }
    for s in sentences {
        h = fnv_bytes(h, s.as_bytes());
        h = fnv_byte(h, b'\n');
    }
    h
}

/// FNV-1a over a document's sentences (with a `\n` separator): the
/// content hash recorded per request, so replay can verify it is
/// re-executing the same input bytes.
pub fn content_hash(sentences: &[String]) -> u64 {
    let mut h = FNV_OFFSET;
    for s in sentences {
        h = fnv_bytes(h, s.as_bytes());
        h = fnv_byte(h, b'\n');
    }
    h
}

/// Canonical `0x`-prefixed 16-digit hex encoding for recorded u64s
/// (seeds, hashes, f64 bit patterns).
pub fn hex(v: u64) -> String {
    format!("{v:#018x}")
}

/// Parse a [`hex`]-encoded u64 back (plain decimal accepted too, for
/// hand-written records).
pub fn parse_hex(s: &str) -> Result<u64> {
    if let Some(h) = s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        u64::from_str_radix(h, 16).with_context(|| format!("bad hex u64 '{s}'"))
    } else {
        s.parse().with_context(|| format!("bad u64 '{s}'"))
    }
}

/// The resolved-config provenance stamped on every record: canonical
/// `(key, value)` pairs over the resolved `[pipeline]` config, the
/// resolved backend route, and the fault-model seed/rates, plus the
/// FNV fingerprint over all of them. Every value round-trips through
/// its `FromStr`, so replay can reconstruct the recorded
/// [`PipelineConfig`](crate::config::PipelineConfig) exactly.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetProvenance {
    /// Canonical `(key, value)` pairs (see [`provenance_fields`]).
    pub fields: Vec<(String, String)>,
    /// [`fingerprint`] over `fields`.
    pub fingerprint: u64,
    /// Resolved backend route (`sched::resolved_backend`).
    pub backend: String,
}

impl FleetProvenance {
    /// Capture the provenance of `settings` as served right now.
    pub fn from_settings(settings: &Settings) -> Self {
        let fields = provenance_fields(settings);
        let fp = fingerprint(&fields);
        Self {
            fields,
            fingerprint: fp,
            backend: crate::sched::resolved_backend(settings).to_string(),
        }
    }
}

/// The canonical provenance pairs for `settings`: every resolved
/// `[pipeline]` field (values in their `FromStr`-compatible `Display`
/// form, the seed in hex), the resolved backend, and the fault-model
/// switch/seed/rates. Key order is fixed — the [`fingerprint`] depends
/// on it.
pub fn provenance_fields(settings: &Settings) -> Vec<(String, String)> {
    let p = &settings.pipeline;
    let f = &settings.resilience.fault;
    let pair = |k: &str, v: String| (k.to_string(), v);
    vec![
        pair("lambda", p.lambda.to_string()),
        pair("improved_formulation", p.improved_formulation.to_string()),
        pair("precision", p.precision.to_string()),
        pair("rounding", p.rounding.to_string()),
        pair("iterations", p.iterations.to_string()),
        pair("decompose_p", p.decompose_p.to_string()),
        pair("decompose_q", p.decompose_q.to_string()),
        pair("strategy", p.strategy.to_string()),
        pair("summary_len", p.summary_len.to_string()),
        pair("solver", p.solver.clone()),
        pair("seed", hex(p.seed)),
        pair("backend", crate::sched::resolved_backend(settings).to_string()),
        pair("fault_enabled", f.enabled.to_string()),
        pair("fault_seed", hex(f.seed)),
        pair("fault_stuck_rate", f.stuck_rate.to_string()),
        pair("fault_drift_rate", f.drift_rate.to_string()),
        pair("fault_drift_amp", f.drift_amp.to_string()),
        pair("fault_dac_mismatch", f.dac_mismatch.to_string()),
        pair("fault_burst_rate", f.burst_rate.to_string()),
        pair("fault_burst_amp", f.burst_amp.to_string()),
    ]
}

/// FNV-1a over `key=value\n` for each pair, in order: the config
/// fingerprint recorded per request and diffed by replay triage.
pub fn fingerprint(fields: &[(String, String)]) -> u64 {
    let mut h = FNV_OFFSET;
    for (k, v) in fields {
        h = fnv_bytes(h, k.as_bytes());
        h = fnv_byte(h, b'=');
        h = fnv_bytes(h, v.as_bytes());
        h = fnv_byte(h, b'\n');
    }
    h
}

/// One solve-DAG node's tap: where it sits in the decomposition plan,
/// the seed it solved under (0 for window-plan nodes, whose seeds come
/// from the per-document request stream), the FNV hash of every solved
/// spin vector, and the selected-best objective's f64 bits.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NodeRecord {
    /// Decomposition level (0 = leaves).
    pub level: usize,
    /// Slot within the level.
    pub slot: usize,
    /// Per-node seed (`decompose::node_seed`); 0 under the window plan.
    pub node_seed: u64,
    /// [`spin_hash`] over the node's solved instances.
    pub spin_hash: u64,
    /// `f64::to_bits` of the node's selected-best objective.
    pub energy_bits: u64,
}

/// One request's full provenance: everything needed to re-execute it
/// byte-for-byte through the current binary and to triage a divergence
/// down to the first differing DAG node.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestRecord {
    /// Ring-assigned sequence number (1-based; the `::REPLAY <id>::` key).
    pub id: u64,
    /// Document / problem id.
    pub doc_id: String,
    /// [`content_hash`] over `sentences`.
    pub doc_hash: u64,
    /// The seed the request actually solved under (for ES: the
    /// doc-derived config seed; for non-ES: the workload-salted
    /// problem seed).
    pub seed: u64,
    /// Workload tag (`es` | `retrieval` | `dispersion`).
    pub workload: String,
    /// Decomposition strategy the request ran.
    pub strategy: String,
    /// Backend route decision: `pooled` or `local`.
    pub route: String,
    /// Admission tier (`interactive` | `batch`).
    pub tier: String,
    /// Deadline budget in ms (0 = none).
    pub deadline_ms: u64,
    /// Resolved backend route label.
    pub backend: String,
    /// Config fingerprint ([`fingerprint`] over `config`).
    pub config_fp: u64,
    /// Canonical provenance pairs ([`provenance_fields`]).
    pub config: Vec<(String, String)>,
    /// Per-DAG-node taps, in submission order (empty for local-route
    /// and streamed requests, which solve through opaque paths).
    pub nodes: Vec<NodeRecord>,
    /// Final selected indices.
    pub selected: Vec<usize>,
    /// [`summary_hash`] over the final selection.
    pub summary_hash: u64,
    /// `f64::to_bits` of the final objective.
    pub objective_bits: u64,
    /// The request's input lines (document sentences / workload body) —
    /// what replay re-executes.
    pub sentences: Vec<String>,
}

impl RequestRecord {
    /// Stamp the final selection onto the record.
    pub fn finish(&mut self, summary: &Summary) {
        self.selected = summary.selected.clone();
        self.summary_hash = summary_hash(&summary.selected, &summary.sentences);
        self.objective_bits = summary.objective.to_bits();
    }

    /// Serialize as one JSONL line (no trailing newline). Byte-stable:
    /// a pure function of the record's fields, never of wall clocks.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::with_capacity(512);
        out.push_str("{\"id\":");
        out.push_str(&self.id.to_string());
        push_str_field(&mut out, "doc", &self.doc_id);
        push_hex_field(&mut out, "doc_hash", self.doc_hash);
        push_hex_field(&mut out, "seed", self.seed);
        push_str_field(&mut out, "workload", &self.workload);
        push_str_field(&mut out, "strategy", &self.strategy);
        push_str_field(&mut out, "route", &self.route);
        push_str_field(&mut out, "tier", &self.tier);
        out.push_str(",\"deadline_ms\":");
        out.push_str(&self.deadline_ms.to_string());
        push_str_field(&mut out, "backend", &self.backend);
        push_hex_field(&mut out, "config_fp", self.config_fp);
        out.push_str(",\"config\":{");
        for (i, (k, v)) in self.config.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push('"');
            escape_into(&mut out, k);
            out.push_str("\":\"");
            escape_into(&mut out, v);
            out.push('"');
        }
        out.push_str("},\"nodes\":[");
        for (i, n) in self.nodes.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"level\":{},\"slot\":{},\"seed\":\"{}\",\"spins\":\"{}\",\"energy\":\"{}\"}}",
                n.level,
                n.slot,
                hex(n.node_seed),
                hex(n.spin_hash),
                hex(n.energy_bits)
            ));
        }
        out.push_str("],\"selected\":[");
        for (i, s) in self.selected.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&s.to_string());
        }
        out.push(']');
        push_hex_field(&mut out, "summary_hash", self.summary_hash);
        push_hex_field(&mut out, "objective", self.objective_bits);
        out.push_str(",\"sentences\":[");
        for (i, s) in self.sentences.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push('"');
            escape_into(&mut out, s);
            out.push('"');
        }
        out.push_str("]}");
        out
    }

    /// Parse one JSONL line written by [`RequestRecord::to_jsonl`].
    pub fn parse(line: &str) -> Result<Self> {
        let v = JsonValue::parse(line).context("parsing record JSONL")?;
        Self::from_json(&v)
    }

    /// Build from a parsed JSON record.
    pub fn from_json(v: &JsonValue) -> Result<Self> {
        let config = match v.get("config") {
            Some(JsonValue::Obj(members)) => members
                .iter()
                .map(|(k, val)| {
                    val.as_str()
                        .map(|s| (k.clone(), s.to_string()))
                        .ok_or_else(|| anyhow!("config value for '{k}' is not a string"))
                })
                .collect::<Result<Vec<_>>>()?,
            _ => bail!("record has no config object"),
        };
        let nodes = req_array(v, "nodes")?
            .iter()
            .map(|n| {
                Ok(NodeRecord {
                    level: req_u64(n, "level")? as usize,
                    slot: req_u64(n, "slot")? as usize,
                    node_seed: req_hex(n, "seed")?,
                    spin_hash: req_hex(n, "spins")?,
                    energy_bits: req_hex(n, "energy")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let selected = req_array(v, "selected")?
            .iter()
            .map(|s| {
                s.as_u64()
                    .map(|u| u as usize)
                    .ok_or_else(|| anyhow!("non-integer selected index"))
            })
            .collect::<Result<Vec<_>>>()?;
        let sentences = req_array(v, "sentences")?
            .iter()
            .map(|s| {
                s.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("non-string sentence"))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            id: req_u64(v, "id")?,
            doc_id: req_str(v, "doc")?,
            doc_hash: req_hex(v, "doc_hash")?,
            seed: req_hex(v, "seed")?,
            workload: req_str(v, "workload")?,
            strategy: req_str(v, "strategy")?,
            route: req_str(v, "route")?,
            tier: req_str(v, "tier")?,
            deadline_ms: req_u64(v, "deadline_ms")?,
            backend: req_str(v, "backend")?,
            config_fp: req_hex(v, "config_fp")?,
            config,
            nodes,
            selected,
            summary_hash: req_hex(v, "summary_hash")?,
            objective_bits: req_hex(v, "objective")?,
            sentences,
        })
    }
}

fn push_str_field(out: &mut String, key: &str, v: &str) {
    out.push_str(",\"");
    out.push_str(key);
    out.push_str("\":\"");
    escape_into(out, v);
    out.push('"');
}

fn push_hex_field(out: &mut String, key: &str, v: u64) {
    out.push_str(",\"");
    out.push_str(key);
    out.push_str("\":\"");
    out.push_str(&hex(v));
    out.push('"');
}

fn req_str(v: &JsonValue, key: &str) -> Result<String> {
    v.get(key)
        .and_then(|x| x.as_str())
        .map(str::to_string)
        .ok_or_else(|| anyhow!("record missing string field '{key}'"))
}

fn req_u64(v: &JsonValue, key: &str) -> Result<u64> {
    v.get(key)
        .and_then(|x| x.as_u64())
        .ok_or_else(|| anyhow!("record missing integer field '{key}'"))
}

fn req_hex(v: &JsonValue, key: &str) -> Result<u64> {
    parse_hex(
        v.get(key)
            .and_then(|x| x.as_str())
            .ok_or_else(|| anyhow!("record missing hex field '{key}'"))?,
    )
}

fn req_array<'a>(v: &'a JsonValue, key: &str) -> Result<&'a [JsonValue]> {
    v.get(key)
        .and_then(|x| x.as_array())
        .ok_or_else(|| anyhow!("record missing array field '{key}'"))
}

/// The per-service flight recorder: a bounded ring of [`RequestRecord`]s
/// (oldest overwritten past `[obs] record_capacity`) plus, when
/// `[obs] record_out` is set, a pending-JSONL queue the serve loop
/// drains to disk. Default OFF: [`FlightRecorder::enabled`] is the only
/// thing the hot path consults, and a disabled recorder allocates
/// nothing per request (`tests/alloc_audit.rs`).
#[derive(Debug)]
pub struct FlightRecorder {
    enabled: bool,
    keep_lines: bool,
    cap: usize,
    next_id: AtomicU64,
    recorded: AtomicU64,
    overwritten: AtomicU64,
    ring: Mutex<VecDeque<RequestRecord>>,
    lines: Mutex<Vec<String>>,
    provenance: FleetProvenance,
}

impl FlightRecorder {
    /// Build from `[obs]` (`record_enabled`, `record_capacity`,
    /// `record_out` — a non-empty `record_out` implies enabled) and
    /// capture the fleet provenance of `settings`.
    pub fn from_settings(settings: &Settings) -> Self {
        let cap = settings.obs.record_capacity.max(1);
        Self {
            enabled: settings.obs.record_enabled || !settings.obs.record_out.is_empty(),
            keep_lines: !settings.obs.record_out.is_empty(),
            cap,
            next_id: AtomicU64::new(0),
            recorded: AtomicU64::new(0),
            overwritten: AtomicU64::new(0),
            ring: Mutex::new(VecDeque::with_capacity(cap.min(64))),
            lines: Mutex::new(Vec::new()),
            provenance: FleetProvenance::from_settings(settings),
        }
    }

    /// Whether recording is on — the hot path's only recorder probe.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// The fleet provenance stamped on every record.
    pub fn provenance(&self) -> &FleetProvenance {
        &self.provenance
    }

    /// Start a record for one request: provenance pre-stamped, taps and
    /// selection left for the worker to fill
    /// ([`RequestRecord::finish`]).
    #[allow(clippy::too_many_arguments)]
    pub fn begin(
        &self,
        doc_id: &str,
        sentences: &[String],
        seed: u64,
        workload: &str,
        strategy: &str,
        route: &str,
        tier: &str,
        deadline_ms: u64,
    ) -> RequestRecord {
        RequestRecord {
            id: 0,
            doc_id: doc_id.to_string(),
            doc_hash: content_hash(sentences),
            seed,
            workload: workload.to_string(),
            strategy: strategy.to_string(),
            route: route.to_string(),
            tier: tier.to_string(),
            deadline_ms,
            backend: self.provenance.backend.clone(),
            config_fp: self.provenance.fingerprint,
            config: self.provenance.fields.clone(),
            nodes: Vec::new(),
            selected: Vec::new(),
            summary_hash: 0,
            objective_bits: 0,
            sentences: sentences.to_vec(),
        }
    }

    /// Commit one finished record: assigns its ring id (1-based,
    /// monotonic), queues its JSONL line when a dump path is
    /// configured, and pushes it into the bounded ring (oldest
    /// overwritten, counted). Returns the assigned id.
    pub fn record(&self, mut rec: RequestRecord) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        rec.id = id;
        self.recorded.fetch_add(1, Ordering::Relaxed);
        if self.keep_lines {
            let line = rec.to_jsonl();
            self.lines.lock().unwrap().push(line);
        }
        let mut ring = self.ring.lock().unwrap();
        if ring.len() == self.cap {
            ring.pop_front();
            self.overwritten.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(rec);
        id
    }

    /// The buffered record with ring id `id`, if it has not been
    /// overwritten.
    pub fn get(&self, id: u64) -> Option<RequestRecord> {
        self.ring
            .lock()
            .unwrap()
            .iter()
            .find(|r| r.id == id)
            .cloned()
    }

    /// Clone every buffered record, oldest first (the ring is NOT
    /// drained: `::REPLAY::` stays serviceable).
    pub fn snapshot(&self) -> Vec<RequestRecord> {
        self.ring.lock().unwrap().iter().cloned().collect()
    }

    /// Move the pending JSONL lines out (the serve loop appends them to
    /// `[obs] record_out`). Empty unless a dump path is configured.
    pub fn drain_lines(&self) -> Vec<String> {
        std::mem::take(&mut *self.lines.lock().unwrap())
    }

    /// Records ever committed.
    pub fn recorded(&self) -> u64 {
        self.recorded.load(Ordering::Relaxed)
    }

    /// Records lost to ring overwrite.
    pub fn overwritten(&self) -> u64 {
        self.overwritten.load(Ordering::Relaxed)
    }

    /// Records currently buffered.
    pub fn buffered(&self) -> usize {
        self.ring.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_record() -> RequestRecord {
        let mut s = Settings::default();
        s.obs.record_enabled = true;
        let rec = FlightRecorder::from_settings(&s);
        let mut r = rec.begin(
            "doc \"odd\"\nid",
            &["first sentence".into(), "with \\ and \t control \u{1}".into()],
            0xDEAD_BEEF_0000_0001,
            "es",
            "window",
            "pooled",
            "interactive",
            250,
        );
        r.nodes.push(NodeRecord {
            level: 2,
            slot: 3,
            node_seed: 0x1234,
            spin_hash: 0xFFFF_FFFF_FFFF_FFFF,
            energy_bits: (-12.5f64).to_bits(),
        });
        r.selected = vec![0, 1];
        r.summary_hash = 0x9999;
        r.objective_bits = 1.25f64.to_bits();
        r
    }

    #[test]
    fn jsonl_round_trips_adversarial_strings_and_full_u64s() {
        let mut r = sample_record();
        r.id = 7;
        let line = r.to_jsonl();
        assert!(!line.contains('\n'), "JSONL must be one line: {line}");
        let back = RequestRecord::parse(&line).unwrap();
        assert_eq!(back, r);
        // u64s that f64 cannot hold exactly survive the hex encoding
        assert_eq!(back.nodes[0].spin_hash, u64::MAX);
        assert_eq!(f64::from_bits(back.nodes[0].energy_bits), -12.5);
    }

    #[test]
    fn ring_is_bounded_and_counts_overwrites() {
        let mut s = Settings::default();
        s.obs.record_enabled = true;
        s.obs.record_capacity = 3;
        let rec = FlightRecorder::from_settings(&s);
        assert!(rec.enabled());
        for _ in 0..5 {
            rec.record(sample_record());
        }
        assert_eq!(rec.buffered(), 3);
        assert_eq!(rec.recorded(), 5);
        assert_eq!(rec.overwritten(), 2);
        // ids are monotonic and the survivors are the newest
        assert!(rec.get(1).is_none(), "oldest overwritten");
        assert!(rec.get(2).is_none());
        assert_eq!(rec.get(5).unwrap().id, 5);
        let snap = rec.snapshot();
        assert_eq!(snap.len(), 3, "snapshot does not drain");
        assert_eq!(snap[0].id, 3);
        assert_eq!(rec.buffered(), 3);
    }

    #[test]
    fn disabled_by_default_and_record_out_implies_enabled() {
        let rec = FlightRecorder::from_settings(&Settings::default());
        assert!(!rec.enabled());
        let mut s = Settings::default();
        s.obs.record_out = "/tmp/records.jsonl".into();
        let rec = FlightRecorder::from_settings(&s);
        assert!(rec.enabled(), "a dump path implies recording");
        rec.record(sample_record());
        let lines = rec.drain_lines();
        assert_eq!(lines.len(), 1);
        assert!(rec.drain_lines().is_empty(), "lines drain once");
        assert_eq!(rec.buffered(), 1, "draining lines keeps the ring");
    }

    #[test]
    fn fingerprint_tracks_config_changes() {
        let clean = FleetProvenance::from_settings(&Settings::default());
        let mut s = Settings::default();
        s.resilience.fault.enabled = true;
        s.resilience.fault.stuck_rate = 0.05;
        let faulty = FleetProvenance::from_settings(&s);
        assert_ne!(clean.fingerprint, faulty.fingerprint);
        let differing: Vec<&str> = clean
            .fields
            .iter()
            .zip(&faulty.fields)
            .filter(|(a, b)| a.1 != b.1)
            .map(|(a, _)| a.0.as_str())
            .collect();
        assert_eq!(differing, ["fault_enabled", "fault_stuck_rate"]);
        // same settings → same fingerprint (pure function)
        assert_eq!(
            clean.fingerprint,
            FleetProvenance::from_settings(&Settings::default()).fingerprint
        );
    }

    #[test]
    fn record_jsonl_is_free_of_wall_clock_fields() {
        let line = sample_record().to_jsonl();
        for banned in ["wall", "_us\"", "_ms\":\"", "secs", "elapsed"] {
            assert!(!line.contains(banned), "wall-ish field '{banned}' in {line}");
        }
    }
}
