//! Fleet-wide energy ledger: modeled joules and device-seconds
//! aggregated by (backend × subsystem × instance-size bucket).
//!
//! Every Ising solve the fleet dispatches is charged here with its
//! *modeled* cost — a pure function of (backend, instance size) built
//! from the same constants as [`crate::metrics::tts::TimingModel`]
//! (COBI per-solve time/power, software tabu sweep time, CPU solution
//! evaluation time) — so ledger contents are deterministic for a given
//! workload no matter how the pool coalesced or which worker served it.
//! Wall-clock time is deliberately NOT a ledger input (decision #18);
//! it lives in span `wall` sections and `ServiceMetrics` histograms.
//!
//! Charging sites (each solve is charged exactly once):
//!
//! * [`LedgerSolver`] wraps every non-portfolio pool backend inside
//!   `sched::pool::build_solver`, *underneath* the resilience layer, so
//!   replicated/retried solves are charged at their true multiplicity;
//! * `SolverPortfolio` charges its ROUTED backend per fresh solve
//!   (cache-served instances cost no device time and are not charged);
//! * the `energy-report` experiment charges one shared solve profile to
//!   several backends to reproduce the paper's energy-comparison table.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::cobi::SeededGroup;
use crate::config::Settings;
use crate::portfolio::{size_bucket, N_BUCKETS, SIZE_BOUNDS};
use crate::sched::pool::PoolSolver;
use crate::solvers::SolveResult;

/// Enumeration ceiling for the modeled brute-force backend: documents
/// never produce windows past the portfolio's `EXACT_HARD_CAP`, and
/// capping the exponent keeps `2^n` finite for any caller.
const EXACT_MODEL_CAP: usize = 60;

/// Modeled parallel width of the snowball backend's sharded sweeps (its
/// default shard count). Sharding spreads one sweep's spin updates over
/// this many workers, so modeled occupancy divides by it — while modeled
/// joules do not: every shard still burns CPU, so a parallel sweep is
/// work-conserving (same energy as a serial tabu sweep, 1/width the
/// wall occupancy).
const SNOWBALL_MODEL_WIDTH: f64 = 8.0;

/// Which layer of the serving stack dispatched a solve.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Subsystem {
    /// Inline (no-pool) pipeline workers.
    Pipeline,
    /// Shared device-pool workers.
    Pool,
    /// `SUMMARIZE_STREAM` sessions (local route).
    Stream,
    /// Solves issued through the resilience layer (replicas, retries,
    /// calibration probes included).
    Resilience,
    /// The experiment harness.
    Experiment,
    /// Inline solves of the diverse-retrieval workload (pooled retrieval
    /// solves are still charged to [`Subsystem::Pool`] — the pool is a
    /// shared device; the workload axis rides on the solve tag instead).
    Retrieval,
    /// Inline solves of the facility-dispersion workload (same pooled
    /// caveat as [`Subsystem::Retrieval`]).
    Dispersion,
}

impl Subsystem {
    /// All subsystems, in ledger-row order.
    pub const ALL: [Subsystem; 7] = [
        Subsystem::Pipeline,
        Subsystem::Pool,
        Subsystem::Stream,
        Subsystem::Resilience,
        Subsystem::Experiment,
        Subsystem::Retrieval,
        Subsystem::Dispersion,
    ];

    /// Stable lowercase label (exposition + JSON).
    pub fn name(self) -> &'static str {
        match self {
            Subsystem::Pipeline => "pipeline",
            Subsystem::Pool => "pool",
            Subsystem::Stream => "stream",
            Subsystem::Resilience => "resilience",
            Subsystem::Experiment => "experiment",
            Subsystem::Retrieval => "retrieval",
            Subsystem::Dispersion => "dispersion",
        }
    }
}

/// Modeled cost of one solve: device occupancy and total energy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyCost {
    /// Seconds of device (COBI) or CPU (software) solve time.
    pub device_s: f64,
    /// Joules: solve energy plus the CPU solution-evaluation energy,
    /// matching `TimingModel::iter_energy_j`.
    pub joules: f64,
}

/// The per-backend cost model (pure data, cheap to copy).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyModel {
    /// COBI per-solve anneal time (s) — `[cobi] solve_time_s`.
    pub cobi_solve_s: f64,
    /// COBI chip power (W) — `[cobi] power_w`.
    pub cobi_power_w: f64,
    /// Software tabu per-solve time (s) — `[timing] tabu_time_s`.
    pub tabu_time_s: f64,
    /// CPU per-solution evaluation time (s) — `[timing] eval_time_s`.
    pub eval_time_s: f64,
    /// CPU power (W) — `[timing] cpu_power_w`.
    pub cpu_power_w: f64,
}

impl EnergyModel {
    /// Build from the `[cobi]` and `[timing]` config sections.
    pub fn from_settings(settings: &Settings) -> Self {
        Self {
            cobi_solve_s: settings.cobi.solve_time_s,
            cobi_power_w: settings.cobi.power_w,
            tabu_time_s: settings.timing.tabu_time_s,
            eval_time_s: settings.timing.eval_time_s,
            cpu_power_w: settings.timing.cpu_power_w,
        }
    }

    /// Modeled cost of ONE solve of an `n`-spin instance on `backend`.
    ///
    /// `cobi` uses the chip model; `tabu`/`sa` (and any unrecognized
    /// software backend) use the software sweep model; `snowball` uses
    /// the sharded-sweep model (tabu-equivalent joules — parallel sweeps
    /// are work-conserving — at `1/SNOWBALL_MODEL_WIDTH` the occupancy);
    /// `greedy` costs one evaluation-time descent; `exact`/`brute` model
    /// exhaustive enumeration (`2^n` evaluations, exponent capped).
    /// Every arm adds the CPU evaluation energy, mirroring
    /// `TimingModel`.
    pub fn per_instance(&self, backend: &str, n: usize) -> EnergyCost {
        let eval_j = self.eval_time_s * self.cpu_power_w;
        match backend {
            "cobi" => EnergyCost {
                device_s: self.cobi_solve_s,
                joules: self.cobi_solve_s * self.cobi_power_w + eval_j,
            },
            "greedy" => EnergyCost {
                device_s: self.eval_time_s,
                joules: self.eval_time_s * self.cpu_power_w + eval_j,
            },
            "snowball" => EnergyCost {
                device_s: self.tabu_time_s / SNOWBALL_MODEL_WIDTH,
                joules: self.tabu_time_s * self.cpu_power_w + eval_j,
            },
            "exact" | "brute" => {
                let evals = 2f64.powi(n.min(EXACT_MODEL_CAP) as i32);
                let secs = evals * self.eval_time_s;
                EnergyCost {
                    device_s: secs,
                    joules: secs * self.cpu_power_w,
                }
            }
            // tabu, sa, and anything unrecognized: software sweep model
            _ => EnergyCost {
                device_s: self.tabu_time_s,
                joules: self.tabu_time_s * self.cpu_power_w + eval_j,
            },
        }
    }
}

/// One accumulation cell (and the ledger's grand total).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LedgerCell {
    /// Instances charged.
    pub solves: u64,
    /// Modeled device/CPU solve seconds.
    pub device_s: f64,
    /// Modeled joules.
    pub joules: f64,
}

/// One exported ledger row: a cell plus its (backend, subsystem, size
/// bucket) key.
#[derive(Debug, Clone, PartialEq)]
pub struct LedgerRow {
    /// Backend label (`cobi`, `tabu`, `sa`, `greedy`, `exact`, …).
    pub backend: String,
    /// Dispatching subsystem label.
    pub subsystem: &'static str,
    /// Size-bucket index (see [`bucket_label`]).
    pub bucket: usize,
    /// The accumulated cell.
    pub cell: LedgerCell,
}

/// Human/exposition label of size bucket `b`: `le8`/`le16`/`le32`/
/// `le64`/`gt64` (from the portfolio's [`SIZE_BOUNDS`]).
pub fn bucket_label(b: usize) -> String {
    if b < SIZE_BOUNDS.len() {
        format!("le{}", SIZE_BOUNDS[b])
    } else {
        format!("gt{}", SIZE_BOUNDS[SIZE_BOUNDS.len() - 1])
    }
}

type Key = (String, Subsystem, usize);

/// The fleet-wide ledger (see module docs). Shared via `Arc`; charging
/// takes one short mutex hold per dispatch.
#[derive(Debug)]
pub struct EnergyLedger {
    model: EnergyModel,
    cells: Mutex<BTreeMap<Key, LedgerCell>>,
}

impl EnergyLedger {
    /// Empty ledger over `model`.
    pub fn new(model: EnergyModel) -> Self {
        Self {
            model,
            cells: Mutex::new(BTreeMap::new()),
        }
    }

    /// The cost model (spans use it for per-solve modeled attributes).
    pub fn model(&self) -> &EnergyModel {
        &self.model
    }

    /// Charge one `n`-spin instance `count` times.
    pub fn charge(&self, backend: &str, subsystem: Subsystem, n: usize, count: u64) {
        self.charge_sizes(backend, subsystem, (0..count).map(|_| n));
    }

    /// Charge one instance per size in `sizes` (single lock hold).
    pub fn charge_sizes(
        &self,
        backend: &str,
        subsystem: Subsystem,
        sizes: impl IntoIterator<Item = usize>,
    ) {
        // accumulate per bucket outside the lock
        let mut local: [LedgerCell; N_BUCKETS] = [LedgerCell::default(); N_BUCKETS];
        for n in sizes {
            let cost = self.model.per_instance(backend, n);
            let cell = &mut local[size_bucket(n)];
            cell.solves += 1;
            cell.device_s += cost.device_s;
            cell.joules += cost.joules;
        }
        let mut cells = self.cells.lock().unwrap();
        for (b, add) in local.iter().enumerate() {
            if add.solves == 0 {
                continue;
            }
            let cell = cells
                .entry((backend.to_string(), subsystem, b))
                .or_default();
            cell.solves += add.solves;
            cell.device_s += add.device_s;
            cell.joules += add.joules;
        }
    }

    /// All non-empty rows in (backend, subsystem, bucket) order.
    pub fn rows(&self) -> Vec<LedgerRow> {
        self.cells
            .lock()
            .unwrap()
            .iter()
            .map(|((backend, sub, bucket), cell)| LedgerRow {
                backend: backend.clone(),
                subsystem: sub.name(),
                bucket: *bucket,
                cell: *cell,
            })
            .collect()
    }

    /// Grand total across every cell.
    pub fn totals(&self) -> LedgerCell {
        let cells = self.cells.lock().unwrap();
        let mut t = LedgerCell::default();
        for cell in cells.values() {
            t.solves += cell.solves;
            t.device_s += cell.device_s;
            t.joules += cell.joules;
        }
        t
    }

    /// Total for one backend across subsystems and buckets.
    pub fn backend_totals(&self, backend: &str) -> LedgerCell {
        let cells = self.cells.lock().unwrap();
        let mut t = LedgerCell::default();
        for ((b, _, _), cell) in cells.iter() {
            if b == backend {
                t.solves += cell.solves;
                t.device_s += cell.device_s;
                t.joules += cell.joules;
            }
        }
        t
    }
}

/// [`PoolSolver`] decorator that charges the ledger for every instance
/// of every successfully served dispatch (failed dispatches are retried
/// by the pool and would double-charge), then returns the inner result
/// untouched — solves, seeds and results are bit-identical with or
/// without the wrapper.
pub struct LedgerSolver {
    inner: Box<dyn PoolSolver>,
    backend: String,
    subsystem: Subsystem,
    ledger: Arc<EnergyLedger>,
}

impl LedgerSolver {
    /// Wrap `inner`, charging `(backend, subsystem)` cells of `ledger`.
    pub fn new(
        inner: Box<dyn PoolSolver>,
        backend: &str,
        subsystem: Subsystem,
        ledger: Arc<EnergyLedger>,
    ) -> Self {
        Self {
            inner,
            backend: backend.to_string(),
            subsystem,
            ledger,
        }
    }
}

impl PoolSolver for LedgerSolver {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn solve_groups(&mut self, groups: &[SeededGroup<'_>]) -> Result<Vec<Vec<SolveResult>>> {
        let out = self.inner.solve_groups(groups)?;
        self.ledger.charge_sizes(
            &self.backend,
            self.subsystem,
            groups
                .iter()
                .flat_map(|g| g.instances.iter().map(|inst| inst.n)),
        );
        Ok(out)
    }

    fn solve_groups_tagged(
        &mut self,
        tags: &[u64],
        groups: &[SeededGroup<'_>],
    ) -> Result<Vec<Vec<SolveResult>>> {
        // forward the workload tags (cache scoping below us) and charge
        // at the same once-per-served-dispatch point as the untagged path
        let out = self.inner.solve_groups_tagged(tags, groups)?;
        self.ledger.charge_sizes(
            &self.backend,
            self.subsystem,
            groups
                .iter()
                .flat_map(|g| g.instances.iter().map(|inst| inst.n)),
        );
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cobi::testutil::quantized_glass;
    use crate::solvers::tabu::TabuSolver;

    fn model() -> EnergyModel {
        EnergyModel::from_settings(&Settings::default())
    }

    #[test]
    fn per_instance_matches_the_timing_model_constants() {
        let m = model();
        let cobi = m.per_instance("cobi", 20);
        assert!((cobi.device_s - 200e-6).abs() < 1e-15);
        assert!((cobi.joules - (200e-6 * 25e-3 + 18.9e-6 * 20.0)).abs() < 1e-12);
        let tabu = m.per_instance("tabu", 20);
        assert!((tabu.device_s - 25e-3).abs() < 1e-15);
        assert!((tabu.joules - (25e-3 + 18.9e-6) * 20.0).abs() < 1e-12);
        let exact = m.per_instance("exact", 20);
        assert!((exact.device_s - 1_048_576.0 * 18.9e-6).abs() < 1e-6);
        // the paper's ordering: cobi ≪ tabu ≪ brute force
        assert!(cobi.joules < tabu.joules);
        assert!(tabu.joules < exact.joules);
    }

    #[test]
    fn snowball_is_work_conserving_but_width_parallel() {
        // sharded sweeps burn the same modeled joules as a serial tabu
        // sweep (every shard's CPU still runs) at 1/width the occupancy
        let m = model();
        let tabu = m.per_instance("tabu", 20);
        let snow = m.per_instance("snowball", 20);
        assert!((snow.joules - tabu.joules).abs() < 1e-15);
        assert!((snow.device_s - tabu.device_s / 8.0).abs() < 1e-15);
        assert!(snow.device_s < tabu.device_s);
    }

    #[test]
    fn exact_exponent_is_capped() {
        let m = model();
        let huge = m.per_instance("exact", 10_000);
        assert!(huge.joules.is_finite());
        assert_eq!(huge.device_s, 2f64.powi(60) * m.eval_time_s);
    }

    #[test]
    fn charges_aggregate_by_backend_subsystem_and_bucket() {
        let ledger = EnergyLedger::new(model());
        ledger.charge("cobi", Subsystem::Pool, 20, 3);
        ledger.charge("cobi", Subsystem::Pool, 10, 1);
        ledger.charge("tabu", Subsystem::Resilience, 20, 2);
        let rows = ledger.rows();
        assert_eq!(rows.len(), 3);
        // BTreeMap order: backend, then subsystem, then bucket
        assert_eq!(rows[0].backend, "cobi");
        assert_eq!(rows[0].bucket, size_bucket(10));
        assert_eq!(rows[1].cell.solves, 3);
        assert_eq!(rows[2].subsystem, "resilience");
        let t = ledger.totals();
        assert_eq!(t.solves, 6);
        let c = ledger.backend_totals("cobi");
        assert_eq!(c.solves, 4);
        let per = ledger.model().per_instance("cobi", 20);
        assert!((rows[1].cell.joules - 3.0 * per.joules).abs() < 1e-12);
    }

    #[test]
    fn ledger_solver_charges_served_instances_and_passes_results_through() {
        let ledger = Arc::new(EnergyLedger::new(model()));
        let insts = vec![quantized_glass(1, 12), quantized_glass(2, 12)];

        let mut raw = TabuSolver::seeded(0);
        let expect = raw
            .solve_groups(&[SeededGroup {
                instances: &insts,
                seed: 7,
            }])
            .unwrap();

        let mut wrapped = LedgerSolver::new(
            Box::new(TabuSolver::seeded(0)),
            "tabu",
            Subsystem::Pool,
            ledger.clone(),
        );
        assert_eq!(wrapped.name(), "tabu");
        let got = wrapped
            .solve_groups(&[SeededGroup {
                instances: &insts,
                seed: 7,
            }])
            .unwrap();
        for (a, b) in got[0].iter().zip(&expect[0]) {
            assert_eq!(a.spins, b.spins, "ledger wrapper must not perturb results");
            assert_eq!(a.energy.to_bits(), b.energy.to_bits());
        }

        let t = ledger.totals();
        assert_eq!(t.solves, 2);
        let per = ledger.model().per_instance("tabu", 12);
        assert!((t.joules - 2.0 * per.joules).abs() < 1e-12);
    }

    #[test]
    fn bucket_labels_cover_every_bucket() {
        let labels: Vec<String> = (0..N_BUCKETS).map(bucket_label).collect();
        assert_eq!(labels, ["le8", "le16", "le32", "le64", "gt64"]);
    }
}
