//! A minimal hand-rolled JSON reader/escape helper.
//!
//! The crate's vendored dependency set (decision #5) has no `serde`, so
//! the observability exporters write JSON by hand and this module gives
//! tests and tooling a small conforming parser to read it back:
//! `::STATS JSON::` round-trips and the CI trace-smoke "the JSONL
//! parses" assertion both go through [`JsonValue::parse`]. It supports
//! the full JSON value grammar (objects keep key order); numbers are
//! surfaced as `f64`, which covers every value the exporters emit.

use anyhow::{bail, ensure, Result};

/// One parsed JSON value. Object members keep their source order.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (parsed as `f64`).
    Num(f64),
    /// A string (escapes resolved).
    Str(String),
    /// An array.
    Arr(Vec<JsonValue>),
    /// An object, members in source order.
    Obj(Vec<(String, JsonValue)>),
}

impl JsonValue {
    /// Parse one complete JSON document (trailing whitespace allowed,
    /// trailing garbage rejected).
    pub fn parse(s: &str) -> Result<JsonValue> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        ensure!(p.i == p.b.len(), "trailing bytes after JSON value");
        Ok(v)
    }

    /// Object member lookup (first match; `None` on non-objects).
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The number, if this is one.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The number as a non-negative integer, if it is one exactly.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            JsonValue::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= u64::MAX as f64 => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    /// The flag, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            JsonValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The string contents, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_array(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Append `s` to `out` with JSON string escaping (quotes, backslashes,
/// and control characters; everything else passes through verbatim, so
/// output is valid UTF-8 JSON without `\u` round-trips for plain text).
pub fn escape_into(out: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        ensure!(self.peek() == Some(c), "expected '{}' at byte {}", c as char, self.i);
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, word: &str, v: JsonValue) -> Result<JsonValue> {
        ensure!(
            self.b[self.i..].starts_with(word.as_bytes()),
            "bad literal at byte {}",
            self.i
        );
        self.i += word.len();
        Ok(v)
    }

    fn value(&mut self) -> Result<JsonValue> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(JsonValue::Str(self.string()?)),
            Some(b't') => self.lit("true", JsonValue::Bool(true)),
            Some(b'f') => self.lit("false", JsonValue::Bool(false)),
            Some(b'n') => self.lit("null", JsonValue::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected byte {other:?} at {}", self.i),
        }
    }

    fn object(&mut self) -> Result<JsonValue> {
        self.eat(b'{')?;
        let mut members = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(JsonValue::Obj(members));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            members.push((key, self.value()?));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(JsonValue::Obj(members));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.i),
            }
        }
    }

    fn array(&mut self) -> Result<JsonValue> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(JsonValue::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(JsonValue::Arr(items));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let Some(c) = self.peek() else {
                bail!("unterminated string");
            };
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(e) = self.peek() else {
                        bail!("unterminated escape");
                    };
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            ensure!(self.i + 4 <= self.b.len(), "truncated \\u escape");
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // surrogate pairs are not emitted by the
                            // exporters; map lone surrogates to U+FFFD
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        other => bail!("bad escape '\\{}'", other as char),
                    }
                }
                _ => {
                    // re-scan from the byte we consumed so multi-byte
                    // UTF-8 sequences stay intact
                    let start = self.i - 1;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<JsonValue> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(JsonValue::Num(s.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_nested_values() {
        let v = JsonValue::parse(
            r#"{"a": 1, "b": [true, null, -2.5e1], "s": "x\ny\"z", "o": {"k": "v"}}"#,
        )
        .unwrap();
        assert_eq!(v.get("a").unwrap().as_u64(), Some(1));
        let arr = v.get("b").unwrap().as_array().unwrap();
        assert_eq!(arr[0], JsonValue::Bool(true));
        assert_eq!(arr[1], JsonValue::Null);
        assert_eq!(arr[2].as_f64(), Some(-25.0));
        assert_eq!(v.get("s").unwrap().as_str(), Some("x\ny\"z"));
        assert_eq!(v.get("o").unwrap().get("k").unwrap().as_str(), Some("v"));
    }

    #[test]
    fn rejects_trailing_garbage_and_bad_syntax() {
        assert!(JsonValue::parse("{} x").is_err());
        assert!(JsonValue::parse("{\"a\" 1}").is_err());
        assert!(JsonValue::parse("[1,").is_err());
        assert!(JsonValue::parse("\"unterminated").is_err());
    }

    #[test]
    fn escape_round_trips_through_parse() {
        let raw = "line1\nline2\t\"quoted\" back\\slash \u{1} end";
        let mut enc = String::from('"');
        escape_into(&mut enc, raw);
        enc.push('"');
        let v = JsonValue::parse(&enc).unwrap();
        assert_eq!(v.as_str(), Some(raw));
    }

    #[test]
    fn escape_round_trips_adversarial_strings() {
        // property: for ANY string — control chars, quotes, backslashes,
        // multi-byte UTF-8, embedded escape-lookalikes — escape_into
        // emits a JSON string the parser reads back verbatim, both bare
        // and as an object member (the recorder's JSONL shape)
        use crate::prop_assert;
        crate::util::proptest::check("json_escape_round_trip", 0x1509, 256, |rng| {
            let len = rng.below(24) as usize;
            let mut raw = String::new();
            for _ in 0..len {
                let c = match rng.below(6) {
                    // the hostile range: C0 controls incl. NUL
                    0 => char::from_u32(rng.below(0x20)).unwrap(),
                    1 => '"',
                    2 => '\\',
                    3 => ['\u{7f}', 'é', '→', '𝄞', '\u{202e}'][rng.below(5) as usize],
                    // escape-lookalikes that must pass through verbatim
                    4 => ['u', 'n', '0'][rng.below(3) as usize],
                    _ => char::from_u32(0x20 + rng.below(0x5f)).unwrap(),
                };
                raw.push(c);
            }
            let mut enc = String::from('"');
            escape_into(&mut enc, &raw);
            enc.push('"');
            let v = JsonValue::parse(&enc)
                .map_err(|e| format!("escaped form failed to parse: {e} ({enc:?})"))?;
            prop_assert!(
                v.as_str() == Some(raw.as_str()),
                "round trip mutated {raw:?} -> {:?}",
                v.as_str()
            );
            // and embedded as a member value, framing survives
            let mut obj = String::from("{\"doc\":\"");
            escape_into(&mut obj, &raw);
            obj.push_str("\"}");
            let v = JsonValue::parse(&obj)
                .map_err(|e| format!("object form failed to parse: {e} ({obj:?})"))?;
            prop_assert!(
                v.get("doc").and_then(JsonValue::as_str) == Some(raw.as_str()),
                "object round trip mutated {raw:?}"
            );
            Ok(())
        });
    }

    #[test]
    fn object_preserves_member_order() {
        let v = JsonValue::parse(r#"{"z": 1, "a": 2}"#).unwrap();
        let JsonValue::Obj(members) = v else { panic!() };
        assert_eq!(members[0].0, "z");
        assert_eq!(members[1].0, "a");
    }
}
