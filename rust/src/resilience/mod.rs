//! Hardware fault model + resilience layer: keep serving correct
//! summaries on degraded COBI hardware.
//!
//! Three layers (DESIGN.md §8):
//!
//! * [`fault`] — calibrated, seed-derived COBI non-idealities
//!   (per-coupling drift, stuck oscillators, DAC gain mismatch, burst
//!   phase noise) attached to
//!   [`CobiDevice`](crate::cobi::CobiDevice) behind `[resilience]`
//!   `fault_*` config, default off. Every fault draw derives from the
//!   request seed, so faulty runs are byte-reproducible across pool
//!   shapes (decision #16).
//! * [`ResilientSolver`] — wraps any [`PoolSolver`] (the COBI device,
//!   tabu, SA, or the whole portfolio) with **replicated solves +
//!   energy-verified voting** (the winner is the verified-energy
//!   minimum; exact ties break to the lowest replica index — decision
//!   #17), a **software verify** step (recompute each replica's energy;
//!   a replica whose report mismatches its spins is rejected),
//!   **verify-and-retry** (fresh-seed re-dispatch when a dispatch fails
//!   or verification rejects everything, escalating to a software tabu
//!   fallback after `retries` attempts), and deterministic greedy
//!   **spin-repair** of the winner (stuck-node damage is a few flips
//!   from a local minimum; the selection-level
//!   `refine::repair_selection_in_place` then restores cardinality
//!   downstream exactly as in the clean path).
//! * [`Calibrator`] — probes each device at startup with
//!   known-ground-truth k-of-n instances and sets the replication
//!   factor per device from the measured success rate.
//!
//! Wiring: `sched::pool::build_solver` wraps every pool device when
//! `[resilience] enabled = true`, so the device pool, stream sessions
//! and the portfolio all inherit the layer; fleet-wide counters
//! (replicas, vote disagreements, verify failures, retries,
//! escalations, repairs, fault injections) surface through
//! [`ResilienceMetrics`] in `ServiceMetrics` and `::STATS::`.

pub mod calibrate;
pub mod fault;

pub use calibrate::{Calibration, Calibrator};
pub use fault::{FaultCounters, FaultDraw, FaultModel, FaultStats};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{ensure, Result};

use crate::cobi::SeededGroup;
use crate::config::ResilienceConfig;
use crate::ising::Ising;
use crate::sched::pool::PoolSolver;
use crate::solvers::greedy::GreedyDescent;
use crate::solvers::tabu::TabuSolver;
use crate::solvers::{IsingSolver, SolveResult};
use crate::util::rng::Pcg32;

/// Tolerance for the software energy verification: a replica whose
/// reported energy differs from its recomputed energy by more than this
/// is rejected (quantized instances produce exactly representable
/// energies, so honest reports match to well under this bound).
const VERIFY_EPS: f64 = 1e-6;
/// Salt offsetting per-instance verify-retry seeds away from the replica
/// seed indices.
const RETRY_SALT: u64 = 0x1000_0000;
/// Salt offsetting escalation-fallback seeds away from everything else.
const ESCALATE_SALT: u64 = 0x2000_0000;
/// RNG stream id for the unseeded [`IsingSolver`] adapter's seed draws.
/// `pub(crate)` for the stream-id audit in `util::rng`.
pub(crate) const ADAPTER_SEED_STREAM: u64 = 0x2E51_1E57;

/// Derive the seed of replica / retry `k` from a request seed.
/// `replica_seed(s, 0) == s`, so replication 1 dispatches the exact
/// request the raw solver would see (the passthrough property pinned by
/// tests).
pub fn replica_seed(seed: u64, k: u64) -> u64 {
    if k == 0 {
        return seed;
    }
    let mut z = seed ^ k.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z ^ (z >> 31)
}

/// Pick the vote winner among verified replica energies (replica order):
/// lowest energy wins, exact ties break to the lowest index (strict `<`
/// keeps the incumbent — decision #17).
pub(crate) fn vote_winner(energies: &[f64]) -> usize {
    debug_assert!(!energies.is_empty());
    let mut best = 0usize;
    for (k, &e) in energies.iter().enumerate().skip(1) {
        if e < energies[best] {
            best = k;
        }
    }
    best
}

/// Fleet-wide resilience counters, snapshotted into `ServiceMetrics`.
#[derive(Debug, Clone, Default)]
pub struct ResilienceMetrics {
    /// Solve groups served by resilient solvers.
    pub requests: u64,
    /// Inner replica solves dispatched (instances × replicas, including
    /// retries).
    pub replica_solves: u64,
    /// Instances whose replicas disagreed on the spin configuration.
    pub vote_disagreements: u64,
    /// Replica results rejected by the software energy verification.
    pub verify_failures: u64,
    /// Fresh-seed re-dispatches (failed dispatches or all-rejected
    /// verification).
    pub retries: u64,
    /// Instances escalated to the software fallback after exhausting
    /// retries.
    pub escalations: u64,
    /// Vote winners improved by the greedy spin-repair.
    pub repairs: u64,
    /// Per-device startup calibrations, in device construction order.
    pub calibrations: Vec<Calibration>,
    /// Fault-injection counters (filled at snapshot time).
    pub faults: FaultStats,
}

impl ResilienceMetrics {
    /// One-line report fragment for service reports and `::STATS::`.
    pub fn report(&self) -> String {
        let mut out = format!(
            "resilience: requests={} replicas={} disagree={} verify_fail={} \
             retries={} escalations={} repairs={}",
            self.requests,
            self.replica_solves,
            self.vote_disagreements,
            self.verify_failures,
            self.retries,
            self.escalations,
            self.repairs,
        );
        if !self.calibrations.is_empty() {
            out.push_str(" cal=[");
            for (i, c) in self.calibrations.iter().enumerate() {
                if i > 0 {
                    out.push(' ');
                }
                out.push_str(&format!("p={:.2}:r={}", c.success_rate, c.replication));
            }
            out.push(']');
        }
        if self.faults.any() {
            out.push_str(" | ");
            out.push_str(&self.faults.report());
        }
        out
    }
}

/// The state shared by every resilient solver in one pool: the combined
/// counter block plus the fleet-wide fault-injection counters (handed to
/// each device's [`FaultModel`]).
#[derive(Clone, Default)]
pub struct ResilienceShared {
    /// Fleet-shared resilience counters.
    pub metrics: Arc<Mutex<ResilienceMetrics>>,
    /// Fleet-shared fault-injection counters.
    pub faults: Arc<FaultCounters>,
}

impl ResilienceShared {
    /// Fresh shared state (one per `DevicePool`).
    pub fn new() -> Self {
        Self::default()
    }

    /// Counter snapshot with current fault counters merged in.
    pub fn snapshot(&self) -> ResilienceMetrics {
        let mut m = self.metrics.lock().unwrap().clone();
        m.faults = self.faults.snapshot();
        m
    }
}

/// Locally accumulated counter deltas, committed once per dispatch.
#[derive(Default)]
struct Delta {
    requests: u64,
    replica_solves: u64,
    vote_disagreements: u64,
    verify_failures: u64,
    retries: u64,
    escalations: u64,
    repairs: u64,
}

/// Replication + voting + verify-and-retry around any pool solver (see
/// module docs).
pub struct ResilientSolver {
    inner: Box<dyn PoolSolver>,
    fallback: TabuSolver,
    repairer: GreedyDescent,
    cfg: ResilienceConfig,
    replication: usize,
    shared: ResilienceShared,
    /// Optional per-device verify-failure feed (the pool's circuit
    /// breaker drains it after every dispatch; see `sched::breaker`).
    verify_obs: Option<Arc<AtomicU64>>,
}

impl ResilientSolver {
    /// Wrap `inner` per `cfg`, feeding fleet counters in `shared`.
    pub fn new(
        inner: Box<dyn PoolSolver>,
        cfg: &ResilienceConfig,
        shared: ResilienceShared,
    ) -> Self {
        Self {
            inner,
            fallback: TabuSolver::seeded(0),
            repairer: GreedyDescent::new(),
            replication: cfg.replication.clamp(1, cfg.max_replication.max(1)),
            cfg: cfg.clone(),
            shared,
            verify_obs: None,
        }
    }

    /// Install a per-device verify-failure observer: every replica the
    /// software verification rejects also bumps this counter, giving the
    /// pool's circuit breaker a per-device health feed (the fleet
    /// counters in [`ResilienceShared`] aggregate across devices and
    /// cannot attribute failures).
    pub fn set_verify_observer(&mut self, obs: Arc<AtomicU64>) {
        self.verify_obs = Some(obs);
    }

    /// The wrapped solver (calibration probes go through here).
    pub fn inner_mut(&mut self) -> &mut dyn PoolSolver {
        self.inner.as_mut()
    }

    /// Current replication factor.
    pub fn replication(&self) -> usize {
        self.replication
    }

    /// Override the replication factor (clamped to `[1, max_replication]`).
    pub fn set_replication(&mut self, r: usize) {
        self.replication = r.clamp(1, self.cfg.max_replication.max(1));
    }

    /// Probe the wrapped solver with the startup [`Calibrator`], adopt
    /// the measured replication factor, and record the calibration in
    /// the shared metrics.
    pub fn calibrate(&mut self) -> Result<Calibration> {
        let cal = Calibrator::from_config(&self.cfg).calibrate(self.inner.as_mut())?;
        self.set_replication(cal.replication);
        self.shared.metrics.lock().unwrap().calibrations.push(cal);
        Ok(cal)
    }

    /// Escalation: solve one instance on the deterministic software
    /// fallback, seeded from the request.
    fn escalate(&mut self, inst: &Ising, seed: u64, i: usize, delta: &mut Delta) -> SolveResult {
        delta.escalations += 1;
        self.fallback
            .reseed(replica_seed(seed, ESCALATE_SALT ^ i as u64));
        self.fallback.solve(inst)
    }

    /// Verified energy of a replica result, or `None` when verification
    /// rejects it.
    fn verified_energy(&self, inst: &Ising, r: &SolveResult, delta: &mut Delta) -> Option<f64> {
        if !self.cfg.verify {
            return Some(r.energy);
        }
        let e = inst.energy(&r.spins);
        if (e - r.energy).abs() > VERIFY_EPS {
            delta.verify_failures += 1;
            return None;
        }
        Some(e)
    }

    /// Serve one group: replicate, verify, vote, repair (see module
    /// docs). `predispatched` carries this group's `replication` replica
    /// results when the caller already dispatched them (the fused path
    /// of [`PoolSolver::solve_groups`]); `None` dispatches here, with
    /// fresh-seed retries on failure. `tag` is the request's workload
    /// tag, forwarded on every inner dispatch (replicas and retries of
    /// one request stay inside its workload's cache scope).
    fn solve_group(
        &mut self,
        g: &SeededGroup<'_>,
        tag: u64,
        predispatched: Option<&[Vec<SolveResult>]>,
        delta: &mut Delta,
    ) -> Result<Vec<SolveResult>> {
        ensure!(!g.instances.is_empty(), "empty solve group");
        delta.requests += 1;
        let r = self.replication;
        let count = g.instances.len();

        let owned: Option<Vec<Vec<SolveResult>>>;
        let replicas: Option<&[Vec<SolveResult>]> = match predispatched {
            Some(p) => {
                debug_assert_eq!(p.len(), r);
                delta.replica_solves += (r * count) as u64;
                Some(p)
            }
            None => {
                // dispatch this group's replicas (all in one inner call,
                // so they co-batch); a failed dispatch retries whole
                // with fresh seeds, then the group escalates instance
                // by instance
                let mut got: Option<Vec<Vec<SolveResult>>> = None;
                for attempt in 0..=self.cfg.retries {
                    let groups: Vec<SeededGroup<'_>> = (0..r)
                        .map(|k| SeededGroup {
                            instances: g.instances,
                            seed: replica_seed(g.seed, (attempt * r + k) as u64),
                        })
                        .collect();
                    match self.inner.solve_groups_tagged(&vec![tag; groups.len()], &groups) {
                        Ok(v) => {
                            delta.replica_solves += (r * count) as u64;
                            got = Some(v);
                            break;
                        }
                        Err(_) => delta.retries += 1,
                    }
                }
                owned = got;
                owned.as_deref()
            }
        };
        let Some(replicas) = replicas else {
            // the inner solver cannot serve this group at all (e.g. an
            // unprogrammable instance): the software fallback can
            return Ok(g
                .instances
                .iter()
                .enumerate()
                .map(|(i, inst)| self.escalate(inst, g.seed, i, delta))
                .collect());
        };

        let mut out = Vec::with_capacity(count);
        for (i, inst) in g.instances.iter().enumerate() {
            // verified candidates in replica order
            let mut candidates: Vec<(usize, f64)> = Vec::with_capacity(r);
            for (k, rep) in replicas.iter().enumerate() {
                if let Some(e) = self.verified_energy(inst, &rep[i], delta) {
                    candidates.push((k, e));
                }
            }

            let mut winner: Option<SolveResult> = None;
            if candidates.is_empty() {
                // every replica failed verification: fresh-seed retries,
                // then escalation
                for attempt in 0..self.cfg.retries {
                    delta.retries += 1;
                    let seed =
                        replica_seed(g.seed, RETRY_SALT ^ ((i as u64) << 8) ^ attempt as u64);
                    let retried = self
                        .inner
                        .solve_groups_tagged(
                            &[tag],
                            &[SeededGroup {
                                instances: std::slice::from_ref(inst),
                                seed,
                            }],
                        )
                        .ok()
                        .and_then(|mut v| v.pop())
                        .and_then(|mut v| v.pop());
                    if let Some(rr) = retried {
                        delta.replica_solves += 1;
                        if let Some(e) = self.verified_energy(inst, &rr, delta) {
                            winner = Some(SolveResult {
                                spins: rr.spins,
                                energy: e,
                            });
                            break;
                        }
                    }
                }
                if winner.is_none() {
                    winner = Some(self.escalate(inst, g.seed, i, delta));
                }
            } else {
                // energy vote: minimum verified energy, exact ties to
                // the lowest replica index (decision #17)
                let energies: Vec<f64> = candidates.iter().map(|&(_, e)| e).collect();
                let best = vote_winner(&energies);
                let (best_k, best_e) = candidates[best];
                if candidates
                    .iter()
                    .any(|&(k, _)| replicas[k][i].spins != replicas[best_k][i].spins)
                {
                    delta.vote_disagreements += 1;
                }
                winner = Some(SolveResult {
                    spins: replicas[best_k][i].spins.clone(),
                    energy: best_e,
                });
            }

            let mut winner = winner.expect("vote, retry or escalation produced a result");
            if self.cfg.repair {
                // deterministic spin-repair: a stuck oscillator leaves
                // the readout a few improving flips from a local
                // minimum; greedy descent (lowest-index tie rule) fixes
                // that and never returns worse than its start
                let polished = self.repairer.solve_from(inst, &winner.spins);
                if polished.energy < winner.energy {
                    delta.repairs += 1;
                    winner = polished;
                }
            }
            out.push(winner);
        }
        Ok(out)
    }

    fn commit(&self, delta: Delta) {
        if delta.verify_failures > 0 {
            if let Some(o) = &self.verify_obs {
                o.fetch_add(delta.verify_failures, Ordering::Relaxed);
            }
        }
        let mut m = self.shared.metrics.lock().unwrap();
        m.requests += delta.requests;
        m.replica_solves += delta.replica_solves;
        m.vote_disagreements += delta.vote_disagreements;
        m.verify_failures += delta.verify_failures;
        m.retries += delta.retries;
        m.escalations += delta.escalations;
        m.repairs += delta.repairs;
    }
}

impl PoolSolver for ResilientSolver {
    fn name(&self) -> &'static str {
        "resilient"
    }

    fn solve_groups(&mut self, groups: &[SeededGroup<'_>]) -> Result<Vec<Vec<SolveResult>>> {
        let tags = vec![0; groups.len()];
        self.solve_groups_tagged(&tags, groups)
    }

    fn solve_groups_tagged(
        &mut self,
        tags: &[u64],
        groups: &[SeededGroup<'_>],
    ) -> Result<Vec<Vec<SolveResult>>> {
        ensure!(
            tags.len() == groups.len(),
            "tag/group count mismatch: {} vs {}",
            tags.len(),
            groups.len()
        );
        let mut delta = Delta::default();
        let r = self.replication;
        // ONE fused dispatch covering every coalesced group's replicas:
        // the pool hands multiple groups precisely so the device can
        // co-batch them (ANNEAL_BATCH amortization), and a wrapper that
        // dispatched per group would collapse that batch occupancy. On
        // failure, each group falls back to its own dispatch-with-
        // retries (attempt 0 replays the identical replica seeds, so
        // per-request determinism is unaffected — same discipline as
        // the pool's own coalesced-failure retry). Each group's workload
        // tag is repeated across its r replicas.
        let fused: Vec<SeededGroup<'_>> = groups
            .iter()
            .flat_map(|g| {
                (0..r).map(move |k| SeededGroup {
                    instances: g.instances,
                    seed: replica_seed(g.seed, k as u64),
                })
            })
            .collect();
        let fused_tags: Vec<u64> = tags.iter().flat_map(|&t| (0..r).map(move |_| t)).collect();
        let fused_result = match self.inner.solve_groups_tagged(&fused_tags, &fused) {
            Ok(v) => Some(v),
            Err(_) => {
                delta.retries += 1;
                None
            }
        };
        let mut out = Vec::with_capacity(groups.len());
        for (gi, (g, &tag)) in groups.iter().zip(tags).enumerate() {
            let pre = fused_result.as_ref().map(|v| &v[gi * r..(gi + 1) * r]);
            match self.solve_group(g, tag, pre, &mut delta) {
                Ok(res) => out.push(res),
                Err(e) => {
                    self.commit(delta);
                    return Err(e);
                }
            }
        }
        self.commit(delta);
        Ok(out)
    }
}

/// Adapter: any [`PoolSolver`] as an [`IsingSolver`], drawing request
/// seeds from an internal per-instance stream — how `summarize
/// --resilience` hosts a [`ResilientSolver`] inside the inline
/// `EsPipeline`.
pub struct SeededPoolBackend {
    inner: Box<dyn PoolSolver>,
    seeds: Pcg32,
}

impl SeededPoolBackend {
    /// Adapter over `inner`, seed stream keyed by `seed`.
    pub fn new(inner: Box<dyn PoolSolver>, seed: u64) -> Self {
        Self {
            inner,
            seeds: Pcg32::new(seed, ADAPTER_SEED_STREAM),
        }
    }
}

/// Build an inline [`EsPipeline`](crate::pipeline::EsPipeline) whose
/// solver runs behind the resilience layer / fault model, or `None` when
/// neither applies to `cfg.solver` — callers then construct their usual
/// pipeline. The single decision point for every inline surface
/// (`summarize`, local-route service workers), mirroring what
/// `sched::pool::build_solver` does for pooled routes:
///
/// * `[resilience] enabled = true` wraps any pool-capable solver
///   (replication + voting + verify-and-retry);
/// * fault injection alone only applies to the COBI device — a tabu/sa
///   pipeline is returned unchanged (`None`), so enabling faults cannot
///   silently change un-faultable solvers' results through rerouting.
///
/// `shared` connects the pipeline's counters to a caller-owned block
/// (the no-pool `Service` hosts one so `::STATS::` still reports the
/// resilience/fault counters); `None` keeps them private. `obs` threads
/// the energy ledger down so replicated/retried solves are charged
/// (attributed to the resilience subsystem by `build_solver`).
pub(crate) fn resilient_pipeline(
    settings: &crate::config::Settings,
    cfg: &crate::config::PipelineConfig,
    rt: Option<&crate::runtime::ArtifactRuntime>,
    shared: Option<&ResilienceShared>,
    obs: Option<(&crate::obs::ObsShared, crate::obs::Subsystem)>,
) -> Result<Option<crate::pipeline::EsPipeline>> {
    let wants = settings.resilience.enabled
        || (settings.resilience.fault.enabled && cfg.solver == "cobi");
    if !wants || !crate::sched::pool_supports(&cfg.solver) {
        return Ok(None);
    }
    let solver =
        crate::sched::pool::build_solver(
            &cfg.solver,
            settings,
            cfg.seed,
            rt,
            None,
            shared,
            obs,
            None,
        )?;
    Ok(Some(crate::pipeline::EsPipeline::new(
        cfg.clone(),
        Box::new(crate::embed::HashEmbedder::new()),
        crate::pipeline::SolverBackend::Ising(Box::new(SeededPoolBackend::new(
            solver, cfg.seed,
        ))),
    )))
}

impl IsingSolver for SeededPoolBackend {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn solve(&mut self, ising: &Ising) -> SolveResult {
        let seed = self.seeds.next_u64();
        self.inner
            .solve_groups(&[SeededGroup {
                instances: std::slice::from_ref(ising),
                seed,
            }])
            .expect("pool-backend solve failed")
            .pop()
            .expect("one group in, one out")
            .pop()
            .expect("one instance in, one out")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cobi::testutil::quantized_glass;
    use crate::cobi::CobiDevice;
    use crate::config::{CobiConfig, FaultConfig};

    fn cfg(replication: usize) -> ResilienceConfig {
        ResilienceConfig {
            enabled: true,
            replication,
            ..Default::default()
        }
    }

    /// Inner that fails its first `fails` dispatches, then delegates.
    struct FlakyInner {
        fails: usize,
        inner: TabuSolver,
    }

    impl PoolSolver for FlakyInner {
        fn name(&self) -> &'static str {
            "flaky"
        }

        fn solve_groups(&mut self, groups: &[SeededGroup<'_>]) -> Result<Vec<Vec<SolveResult>>> {
            if self.fails > 0 {
                self.fails -= 1;
                anyhow::bail!("transient device failure");
            }
            self.inner.solve_groups(groups)
        }
    }

    /// Inner that reports corrupted energies (spins are fine).
    struct LyingInner {
        inner: TabuSolver,
    }

    impl PoolSolver for LyingInner {
        fn name(&self) -> &'static str {
            "lying"
        }

        fn solve_groups(&mut self, groups: &[SeededGroup<'_>]) -> Result<Vec<Vec<SolveResult>>> {
            let mut out = self.inner.solve_groups(groups)?;
            for g in &mut out {
                for r in g {
                    r.energy -= 1000.0; // a lie no honest readout makes
                }
            }
            Ok(out)
        }
    }

    #[test]
    fn replica_seed_zero_is_identity() {
        for s in [0u64, 1, 0xDEAD_BEEF, u64::MAX] {
            assert_eq!(replica_seed(s, 0), s);
            assert_ne!(replica_seed(s, 1), s);
            assert_ne!(replica_seed(s, 1), replica_seed(s, 2));
        }
    }

    #[test]
    fn vote_breaks_exact_ties_to_the_lowest_index() {
        assert_eq!(vote_winner(&[-3.0]), 0);
        assert_eq!(vote_winner(&[-3.0, -5.0, -4.0]), 1);
        assert_eq!(vote_winner(&[-5.0, -5.0, -5.0]), 0, "ties keep the earliest");
        assert_eq!(vote_winner(&[-1.0, -5.0, -5.0]), 1);
    }

    #[test]
    fn replication_one_without_repair_is_a_passthrough() {
        // replica_seed(s, 0) == s and verification recomputes the exact
        // energy the device already computed, so the wrapper is
        // byte-identical to the raw solver
        let instances: Vec<Ising> = (0..4).map(|k| quantized_glass(900 + k, 12)).collect();
        let mut raw = CobiDevice::native(CobiConfig::default(), 0);
        let expected = raw
            .solve_groups_seeded(&[SeededGroup {
                instances: &instances,
                seed: 77,
            }])
            .unwrap();

        let mut c = cfg(1);
        c.repair = false;
        let dev = CobiDevice::native(CobiConfig::default(), 0);
        let mut rs = ResilientSolver::new(Box::new(dev), &c, ResilienceShared::new());
        let got = rs
            .solve_groups(&[SeededGroup {
                instances: &instances,
                seed: 77,
            }])
            .unwrap();
        for (e, g) in expected[0].iter().zip(&got[0]) {
            assert_eq!(e.spins, g.spins);
            assert_eq!(e.energy.to_bits(), g.energy.to_bits());
        }
        let m = rs.shared.snapshot();
        assert_eq!(m.requests, 1);
        assert_eq!(m.replica_solves, 4);
        assert_eq!(m.verify_failures, 0);
        assert_eq!(m.escalations, 0);
    }

    #[test]
    fn replicated_solves_are_deterministic_and_counted() {
        let instances: Vec<Ising> = (0..3).map(|k| quantized_glass(910 + k, 12)).collect();
        let run = || {
            let dev = CobiDevice::native(CobiConfig::default(), 0);
            let mut rs = ResilientSolver::new(Box::new(dev), &cfg(3), ResilienceShared::new());
            let out = rs
                .solve_groups(&[SeededGroup {
                    instances: &instances,
                    seed: 5,
                }])
                .unwrap();
            (out, rs.shared.snapshot().replica_solves)
        };
        let (a, solves_a) = run();
        let (b, solves_b) = run();
        assert_eq!(solves_a, 9, "3 replicas x 3 instances");
        assert_eq!(solves_a, solves_b);
        for (x, y) in a[0].iter().zip(&b[0]) {
            assert_eq!(x.spins, y.spins);
            assert_eq!(x.energy.to_bits(), y.energy.to_bits());
        }
    }

    #[test]
    fn voting_never_loses_to_a_single_solve() {
        // the vote winner's verified energy is a min over replicas that
        // includes the replication-1 result (replica 0 = the request
        // seed), so best-of-3 <= single, instance by instance
        let instances: Vec<Ising> = (0..4).map(|k| quantized_glass(920 + k, 14)).collect();
        let solve = |replication: usize| {
            let mut c = cfg(replication);
            c.repair = false;
            let dev = CobiDevice::native(CobiConfig::default(), 0);
            let mut rs = ResilientSolver::new(Box::new(dev), &c, ResilienceShared::new());
            rs.solve_groups(&[SeededGroup {
                instances: &instances,
                seed: 31,
            }])
            .unwrap()
        };
        let single = solve(1);
        let voted = solve(3);
        for (s, v) in single[0].iter().zip(&voted[0]) {
            assert!(v.energy <= s.energy + 1e-9);
        }
    }

    #[test]
    fn fused_multi_group_dispatch_matches_per_group_results() {
        // the fused path (one inner dispatch covering every coalesced
        // group's replicas) must be invisible in the results: solving
        // groups together or alone agrees byte for byte, because the
        // inner solver's seeded groups are co-batching-invariant
        let a: Vec<Ising> = (0..2).map(|k| quantized_glass(960 + k, 12)).collect();
        let b: Vec<Ising> = (0..3).map(|k| quantized_glass(970 + k, 12)).collect();
        let make = || {
            ResilientSolver::new(
                Box::new(CobiDevice::native(CobiConfig::default(), 0)),
                &cfg(2),
                ResilienceShared::new(),
            )
        };
        let mut fused = make();
        let together = fused
            .solve_groups(&[
                SeededGroup { instances: &a, seed: 1 },
                SeededGroup { instances: &b, seed: 2 },
            ])
            .unwrap();
        let mut solo = make();
        let alone_a = solo
            .solve_groups(&[SeededGroup { instances: &a, seed: 1 }])
            .unwrap();
        let alone_b = solo
            .solve_groups(&[SeededGroup { instances: &b, seed: 2 }])
            .unwrap();
        for (x, y) in together[0].iter().zip(&alone_a[0]) {
            assert_eq!(x.spins, y.spins);
            assert_eq!(x.energy.to_bits(), y.energy.to_bits());
        }
        for (x, y) in together[1].iter().zip(&alone_b[0]) {
            assert_eq!(x.spins, y.spins);
            assert_eq!(x.energy.to_bits(), y.energy.to_bits());
        }
        // fused path counted every replica solve: (2 + 3) x 2
        assert_eq!(fused.shared.snapshot().replica_solves, 10);
    }

    #[test]
    fn transient_failures_retry_and_recover() {
        let inst = vec![quantized_glass(930, 10)];
        let mut c = cfg(1);
        c.retries = 2;
        let flaky = FlakyInner {
            fails: 1,
            inner: TabuSolver::seeded(0),
        };
        let mut rs = ResilientSolver::new(Box::new(flaky), &c, ResilienceShared::new());
        let out = rs
            .solve_groups(&[SeededGroup {
                instances: &inst,
                seed: 9,
            }])
            .unwrap();
        assert_eq!(out[0].len(), 1);
        assert!((inst[0].energy(&out[0][0].spins) - out[0][0].energy).abs() < 1e-9);
        let m = rs.shared.snapshot();
        assert_eq!(m.retries, 1);
        assert_eq!(m.escalations, 0);
    }

    #[test]
    fn exhausted_retries_escalate_to_the_deterministic_fallback() {
        let inst = vec![quantized_glass(931, 10)];
        let run = || {
            let mut c = cfg(2);
            c.retries = 1;
            let flaky = FlakyInner {
                fails: usize::MAX, // never recovers
                inner: TabuSolver::seeded(0),
            };
            let mut rs = ResilientSolver::new(Box::new(flaky), &c, ResilienceShared::new());
            let out = rs
                .solve_groups(&[SeededGroup {
                    instances: &inst,
                    seed: 10,
                }])
                .unwrap();
            (out, rs.shared.snapshot())
        };
        let (a, ma) = run();
        let (b, mb) = run();
        assert_eq!(a[0][0].spins, b[0][0].spins, "escalation must be deterministic");
        assert_eq!(ma.escalations, 1);
        assert_eq!(mb.escalations, 1);
        // failed fused dispatch + the group's own attempt 0 + 1 retry
        assert_eq!(ma.retries, 3);
        // the escalated result is a genuine solution of the instance
        assert!((inst[0].energy(&a[0][0].spins) - a[0][0].energy).abs() < 1e-9);
    }

    #[test]
    fn unprogrammable_instances_are_served_by_escalation() {
        // a fractional instance fails COBI validation on every attempt;
        // with resilience the request is served by the software fallback
        // instead of erroring
        let mut inst = Ising::new(8);
        inst.h[0] = 0.5;
        let dev = CobiDevice::native(CobiConfig::default(), 0);
        let mut rs = ResilientSolver::new(Box::new(dev), &cfg(1), ResilienceShared::new());
        let out = rs
            .solve_groups(&[SeededGroup {
                instances: std::slice::from_ref(&inst),
                seed: 3,
            }])
            .unwrap();
        assert_eq!(out[0][0].spins.len(), 8);
        assert!(rs.shared.snapshot().escalations >= 1);
    }

    #[test]
    fn corrupted_energy_reports_fail_verification_and_still_serve() {
        let inst = vec![quantized_glass(932, 10)];
        let mut c = cfg(2);
        c.retries = 1;
        let lying = LyingInner {
            inner: TabuSolver::seeded(0),
        };
        let mut rs = ResilientSolver::new(Box::new(lying), &c, ResilienceShared::new());
        let out = rs
            .solve_groups(&[SeededGroup {
                instances: &inst,
                seed: 12,
            }])
            .unwrap();
        // the served energy is the true software-verified energy of the
        // served spins — never the corrupted report
        assert!((inst[0].energy(&out[0][0].spins) - out[0][0].energy).abs() < 1e-9);
        let m = rs.shared.snapshot();
        assert!(m.verify_failures >= 2, "both replicas lied: {m:?}");
        assert!(m.retries >= 1, "all-rejected verification must retry");
        assert_eq!(m.escalations, 1, "lying retries exhaust into escalation");
    }

    #[test]
    fn voting_recovers_quality_on_a_stuck_device() {
        // heavy stuck faults: replication + repair must beat the raw
        // faulty device on verified energy, deterministically
        let instances: Vec<Ising> = (0..4).map(|k| quantized_glass(940 + k, 14)).collect();
        let fault = FaultConfig {
            enabled: true,
            stuck_rate: 0.3,
            drift_rate: 0.0,
            dac_mismatch: 0.0,
            burst_rate: 0.0,
            ..Default::default()
        };
        let faulty_device = || {
            let mut d = CobiDevice::native(CobiConfig::default(), 0);
            d.set_fault_model(FaultModel::new(&fault));
            d
        };
        let mut raw = faulty_device();
        let raw_out = raw
            .solve_groups_seeded(&[SeededGroup {
                instances: &instances,
                seed: 50,
            }])
            .unwrap();

        let mut rs = ResilientSolver::new(
            Box::new(faulty_device()),
            &cfg(3),
            ResilienceShared::new(),
        );
        let res_out = rs
            .solve_groups(&[SeededGroup {
                instances: &instances,
                seed: 50,
            }])
            .unwrap();
        let raw_total: f64 = raw_out[0].iter().map(|r| r.energy).sum();
        let res_total: f64 = res_out[0].iter().map(|r| r.energy).sum();
        assert!(
            res_total <= raw_total + 1e-9,
            "voting+repair {res_total} must not lose to raw faulty {raw_total}"
        );
        let m = rs.shared.snapshot();
        assert!(m.faults.any(), "fault counters must record injections");
    }

    #[test]
    fn seeded_pool_backend_adapts_and_replays() {
        let inst = quantized_glass(950, 10);
        let mut a = SeededPoolBackend::new(Box::new(TabuSolver::seeded(0)), 7);
        let mut b = SeededPoolBackend::new(Box::new(TabuSolver::seeded(0)), 7);
        let ra = a.solve(&inst);
        let rb = b.solve(&inst);
        assert_eq!(ra.spins, rb.spins);
        assert_eq!(a.name(), "tabu");
        // the stream advances: a second solve explores a new seed
        let ra2 = a.solve(&inst);
        assert!((inst.energy(&ra2.spins) - ra2.energy).abs() < 1e-9);
    }

    #[test]
    fn calibration_sets_replication_and_records() {
        let mut c = cfg(1);
        c.calibration_probes = 4;
        let dev = CobiDevice::native(CobiConfig::default(), 1);
        let mut rs = ResilientSolver::new(Box::new(dev), &c, ResilienceShared::new());
        let cal = rs.calibrate().unwrap();
        assert_eq!(rs.replication(), cal.replication);
        let m = rs.shared.snapshot();
        assert_eq!(m.calibrations.len(), 1);
        assert_eq!(m.calibrations[0], cal);
        assert!(m.report().contains("cal=["), "{}", m.report());
    }
}
