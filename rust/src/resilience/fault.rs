//! Calibrated COBI non-idealities: a deterministic, seed-derived fault
//! model attached to [`CobiDevice`](crate::cobi::CobiDevice).
//!
//! Real CMOS coupled-oscillator arrays are not the clean integer-coupled
//! machine the rest of the repo simulates: couplings drift with
//! temperature and aging, individual oscillators latch ("stuck-at"
//! nodes), per-row/column DAC lines carry gain mismatch, and supply
//! transients inject burst phase noise. This module injects all four,
//! with two hard rules (DESIGN.md decision #16):
//!
//! 1. **Every fault draw is seed-derived.** A solve's fault realization
//!    comes from a dedicated RNG stream keyed by
//!    `(request seed, fault seed)` — never from wall-clock, device
//!    identity, or dispatch order — so a faulty run is byte-reproducible
//!    across pool shapes and co-batching, exactly like a clean run.
//! 2. **The clean path is untouched.** With no fault model attached (the
//!    default) the device performs the identical RNG draws and identical
//!    arithmetic as before; with a model attached but every rate at
//!    zero, the fault stream is created but never drawn from, and the
//!    annealed instance is a value-identical copy — pinned by tests.
//!
//! Fault stages, applied in a fixed order per solve (DAC gains → drift →
//! stuck draws → burst window):
//!
//! * **DAC gain mismatch** — line `i` programs with gain
//!   `g_i = 1 + dac_mismatch · u_i`; `h_i` scales by `g_i`, `J_ij` by
//!   `g_i · g_j` (symmetric by construction).
//! * **Coupling drift** — each unordered pair drifts with probability
//!   `drift_rate` by `1 + drift_amp · u`, mirrored to both triangles.
//! * **Stuck oscillators** — each spin is stuck at a random sign with
//!   probability `stuck_rate`; the readout is overridden after the
//!   anneal and the energy recomputed on the CLEAN instance, so reported
//!   energies always match the returned spins.
//! * **Burst phase noise** — with probability `burst_rate` one window of
//!   anneal steps has its phase noise amplified by `burst_amp`
//!   (multiplicative, so it consumes no extra noise draws).
//!
//! Fault counters are shared behind an `Arc` so a device pool can report
//! fleet-wide injection totals through `::STATS::`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::cobi::ANNEAL_STEPS;
use crate::config::FaultConfig;
use crate::ising::Ising;
use crate::util::rng::Pcg32;

/// RNG stream id for fault draws — parallel to the device's phase/noise
/// stream, so attaching a fault model never shifts the clean draws.
pub const FAULT_STREAM: u64 = 0xFA_0175;

/// Fleet-shared fault-injection counters (atomics: bumped on the device
/// hot path without a lock).
#[derive(Debug, Default)]
pub struct FaultCounters {
    /// Solves that had at least one fault injected.
    pub faulty_solves: AtomicU64,
    /// Stuck-at oscillator overrides applied.
    pub stuck_spins: AtomicU64,
    /// Couplings perturbed by drift.
    pub drifted_couplings: AtomicU64,
    /// DAC lines with nonzero gain mismatch applied.
    pub dac_lines: AtomicU64,
    /// Burst-noise windows injected.
    pub bursts: AtomicU64,
}

/// Plain snapshot of [`FaultCounters`] (for metrics blocks).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Solves that had at least one fault injected.
    pub faulty_solves: u64,
    /// Stuck-at oscillator overrides applied.
    pub stuck_spins: u64,
    /// Couplings perturbed by drift.
    pub drifted_couplings: u64,
    /// DAC lines with nonzero gain mismatch applied.
    pub dac_lines: u64,
    /// Burst-noise windows injected.
    pub bursts: u64,
}

impl FaultCounters {
    /// Counter snapshot.
    pub fn snapshot(&self) -> FaultStats {
        FaultStats {
            faulty_solves: self.faulty_solves.load(Ordering::Relaxed),
            stuck_spins: self.stuck_spins.load(Ordering::Relaxed),
            drifted_couplings: self.drifted_couplings.load(Ordering::Relaxed),
            dac_lines: self.dac_lines.load(Ordering::Relaxed),
            bursts: self.bursts.load(Ordering::Relaxed),
        }
    }
}

impl FaultStats {
    /// One-line counter fragment for service reports.
    pub fn report(&self) -> String {
        format!(
            "faults solves={} stuck={} drift={} dac={} bursts={}",
            self.faulty_solves,
            self.stuck_spins,
            self.drifted_couplings,
            self.dac_lines,
            self.bursts,
        )
    }

    /// True when any fault was ever injected.
    pub fn any(&self) -> bool {
        self.faulty_solves > 0
    }
}

/// One solve's fault realization (drawn by
/// [`FaultModel::perturb_into`], consumed by the device paths).
#[derive(Debug, Clone, Default)]
pub struct FaultDraw {
    /// Stuck oscillators: `(spin index, stuck sign)`, ascending indices.
    pub stuck: Vec<(usize, i8)>,
    /// Burst window over the anneal steps: `(start_step, end_step,
    /// amplification)`; noise values in the window are multiplied by the
    /// factor.
    pub burst: Option<(usize, usize, f32)>,
}

impl FaultDraw {
    /// Override stuck oscillators in a readout. Callers must recompute
    /// the energy on the clean instance afterwards.
    pub fn apply_stuck(&self, spins: &mut [i8]) {
        for &(k, s) in &self.stuck {
            if k < spins.len() {
                spins[k] = s;
            }
        }
    }

    /// Amplify the burst window in a flat `[steps × n]` noise tensor.
    pub fn apply_burst(&self, noise: &mut [f32], n: usize) {
        if let Some((start, end, amp)) = self.burst {
            let lo = (start * n).min(noise.len());
            let hi = (end * n).min(noise.len());
            for v in &mut noise[lo..hi] {
                *v *= amp;
            }
        }
    }
}

/// The device-attached fault model (see module docs). Holds the fault
/// configuration and the (shareable) injection counters; all randomness
/// comes from caller-provided, request-seeded RNGs.
#[derive(Debug, Clone)]
pub struct FaultModel {
    cfg: FaultConfig,
    counters: Arc<FaultCounters>,
}

impl FaultModel {
    /// Model with private counters.
    pub fn new(cfg: &FaultConfig) -> Self {
        Self {
            cfg: cfg.clone(),
            counters: Arc::new(FaultCounters::default()),
        }
    }

    /// Replace the counter block (lets a pool share one fleet-wide set).
    pub fn set_counters(&mut self, counters: Arc<FaultCounters>) {
        self.counters = counters;
    }

    /// The model's counter block.
    pub fn counters(&self) -> &Arc<FaultCounters> {
        &self.counters
    }

    /// The model's configuration.
    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }

    /// The fault RNG for a request seed: a parallel stream keyed by
    /// `(request seed XOR fault seed)` so fault draws are reproducible
    /// per request and never perturb the device's phase/noise stream.
    pub fn rng_for(&self, request_seed: u64) -> Pcg32 {
        Pcg32::new(request_seed ^ self.cfg.seed, FAULT_STREAM)
    }

    /// Draw one solve's fault realization and write the perturbed
    /// instance into `out` (resized and fully overwritten). Returns the
    /// post-anneal part of the realization (stuck overrides + burst
    /// window). Draw order is fixed (gains, drift, stuck, burst) and a
    /// stage with a zero rate/amplitude consumes no draws.
    pub fn perturb_into(&self, inst: &Ising, rng: &mut Pcg32, out: &mut Ising) -> FaultDraw {
        let n = inst.n;
        out.n = n;
        out.h.clear();
        out.h.extend_from_slice(&inst.h);
        out.j.clear();
        out.j.extend_from_slice(&inst.j);

        let mut faulted = false;
        let mut dac_lines = 0u64;
        // NOTE on allocation: the fault path deliberately allocates per
        // solve (this gains vector, the stuck list, and — on batch
        // paths — one perturbed instance per prepared slot). Degraded-
        // hardware mode is a resilience/diagnostic configuration, and
        // the O(n²) coefficient copy above dominates anyway; the
        // zero-alloc contract (DESIGN decision #13) covers the CLEAN
        // refinement hot path, which never enters here.
        // per-line DAC gain mismatch: h_i *= g_i, J_ij *= g_i * g_j
        if self.cfg.dac_mismatch > 0.0 {
            let mut gains = vec![1.0f32; n];
            for (i, g) in gains.iter_mut().enumerate() {
                let u = rng.range_f32(-1.0, 1.0);
                *g = 1.0 + self.cfg.dac_mismatch * u;
                if *g != 1.0 {
                    dac_lines += 1;
                }
                out.h[i] *= *g;
            }
            for i in 0..n {
                for j in 0..n {
                    out.j[i * n + j] *= gains[i] * gains[j];
                }
            }
            faulted |= dac_lines > 0;
        }

        // multiplicative coupling drift, mirrored per unordered pair
        let mut drifted = 0u64;
        if self.cfg.drift_rate > 0.0 {
            for i in 0..n {
                for j in (i + 1)..n {
                    if rng.f32() < self.cfg.drift_rate {
                        let factor = 1.0 + self.cfg.drift_amp * rng.range_f32(-1.0, 1.0);
                        out.j[i * n + j] *= factor;
                        out.j[j * n + i] = out.j[i * n + j];
                        drifted += 1;
                    }
                }
            }
            faulted |= drifted > 0;
        }

        // stuck-at oscillators
        let mut stuck = Vec::new();
        if self.cfg.stuck_rate > 0.0 {
            for k in 0..n {
                if rng.f32() < self.cfg.stuck_rate {
                    let sign = if rng.bernoulli(0.5) { 1i8 } else { -1i8 };
                    stuck.push((k, sign));
                }
            }
            faulted |= !stuck.is_empty();
        }

        // burst phase noise over a window of anneal steps
        let mut burst = None;
        if self.cfg.burst_rate > 0.0 && rng.f32() < self.cfg.burst_rate {
            let window = (ANNEAL_STEPS / 8).max(1);
            let start = rng.below(ANNEAL_STEPS as u32) as usize;
            let end = (start + window).min(ANNEAL_STEPS);
            burst = Some((start, end, self.cfg.burst_amp));
            faulted = true;
        }

        let c = &self.counters;
        if faulted {
            c.faulty_solves.fetch_add(1, Ordering::Relaxed);
        }
        c.stuck_spins.fetch_add(stuck.len() as u64, Ordering::Relaxed);
        c.drifted_couplings.fetch_add(drifted, Ordering::Relaxed);
        c.dac_lines.fetch_add(dac_lines, Ordering::Relaxed);
        if burst.is_some() {
            c.bursts.fetch_add(1, Ordering::Relaxed);
        }

        FaultDraw { stuck, burst }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn glass(seed: u64, n: usize) -> Ising {
        let mut rng = Pcg32::seeded(seed);
        let mut ising = Ising::new(n);
        for i in 0..n {
            ising.h[i] = rng.range_f32(-3.0, 3.0);
            for j in (i + 1)..n {
                ising.set_pair(i, j, rng.range_f32(-1.0, 1.0));
            }
        }
        ising
    }

    fn heavy() -> FaultConfig {
        FaultConfig {
            enabled: true,
            stuck_rate: 0.5,
            drift_rate: 0.5,
            drift_amp: 0.3,
            dac_mismatch: 0.1,
            burst_rate: 1.0,
            burst_amp: 4.0,
            seed: 7,
        }
    }

    #[test]
    fn draws_are_seed_deterministic() {
        let fm = FaultModel::new(&heavy());
        let inst = glass(1, 12);
        let mut out_a = Ising::new(0);
        let mut out_b = Ising::new(0);
        let a = fm.perturb_into(&inst, &mut fm.rng_for(42), &mut out_a);
        let b = fm.perturb_into(&inst, &mut fm.rng_for(42), &mut out_b);
        assert_eq!(out_a, out_b);
        assert_eq!(a.stuck, b.stuck);
        assert_eq!(a.burst, b.burst);
        // a different request seed realizes different faults
        let mut out_c = Ising::new(0);
        let c = fm.perturb_into(&inst, &mut fm.rng_for(43), &mut out_c);
        assert!(out_a != out_c || a.stuck != c.stuck || a.burst != c.burst);
    }

    #[test]
    fn zero_rates_are_a_value_identical_copy_with_no_draws() {
        let cfg = FaultConfig {
            enabled: true,
            stuck_rate: 0.0,
            drift_rate: 0.0,
            drift_amp: 0.0,
            dac_mismatch: 0.0,
            burst_rate: 0.0,
            burst_amp: 1.0,
            seed: 9,
        };
        let fm = FaultModel::new(&cfg);
        let inst = glass(2, 10);
        let mut rng = fm.rng_for(5);
        let probe = rng.clone().next_u64();
        let mut out = Ising::new(0);
        let draw = fm.perturb_into(&inst, &mut rng, &mut out);
        assert_eq!(out, inst, "zero rates must copy the instance untouched");
        assert!(draw.stuck.is_empty());
        assert!(draw.burst.is_none());
        assert_eq!(rng.next_u64(), probe, "zero rates must consume no draws");
        assert_eq!(fm.counters().snapshot(), FaultStats::default());
    }

    #[test]
    fn perturbed_instances_stay_symmetric_with_zero_diagonal() {
        let fm = FaultModel::new(&heavy());
        let inst = glass(3, 14);
        let mut out = Ising::new(0);
        fm.perturb_into(&inst, &mut fm.rng_for(11), &mut out);
        assert_eq!(out.n, 14);
        for i in 0..14 {
            assert_eq!(out.jij(i, i), 0.0, "diagonal perturbed at {i}");
            for j in 0..14 {
                assert_eq!(out.jij(i, j), out.jij(j, i), "asymmetric ({i},{j})");
            }
        }
    }

    #[test]
    fn heavy_faults_perturb_and_count() {
        let fm = FaultModel::new(&heavy());
        let inst = glass(4, 16);
        let mut out = Ising::new(0);
        let draw = fm.perturb_into(&inst, &mut fm.rng_for(1), &mut out);
        assert_ne!(out, inst, "heavy fault rates must perturb the instance");
        assert!(draw.burst.is_some(), "burst_rate = 1 must always fire");
        let s = fm.counters().snapshot();
        assert!(s.any());
        assert!(s.drifted_couplings > 0);
        assert!(s.dac_lines > 0);
        assert_eq!(s.bursts, 1);
        assert!(s.report().contains("faults solves=1"));
    }

    #[test]
    fn stuck_overrides_and_burst_windows_apply() {
        let draw = FaultDraw {
            stuck: vec![(0, -1), (3, 1)],
            burst: Some((1, 2, 4.0)),
        };
        let mut spins = vec![1i8, 1, 1, -1, 1];
        draw.apply_stuck(&mut spins);
        assert_eq!(spins, vec![-1, 1, 1, 1, 1]);
        // 3 steps x 2 oscillators: only step 1's pair is amplified
        let mut noise = vec![1.0f32; 6];
        draw.apply_burst(&mut noise, 2);
        assert_eq!(noise, vec![1.0, 1.0, 4.0, 4.0, 1.0, 1.0]);
    }

    #[test]
    fn shared_counters_aggregate_across_models() {
        let shared = Arc::new(FaultCounters::default());
        let mut a = FaultModel::new(&heavy());
        let mut b = FaultModel::new(&heavy());
        a.set_counters(shared.clone());
        b.set_counters(shared.clone());
        let inst = glass(5, 10);
        let mut out = Ising::new(0);
        a.perturb_into(&inst, &mut a.rng_for(1), &mut out);
        b.perturb_into(&inst, &mut b.rng_for(2), &mut out);
        assert_eq!(shared.snapshot().faulty_solves, 2);
    }
}
