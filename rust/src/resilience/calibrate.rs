//! Startup calibration: probe a (possibly faulty) solver with
//! known-ground-truth k-of-n instances and derive a per-device
//! replication factor.
//!
//! The probe set is deterministic: seeded facility-dispersion k-of-n
//! instances (the paper's claimed generalization workload,
//! `ising::kofn`), quantized to the COBI grid, small enough that
//! [`ising_ground_exhaustive`] gives the exact ground energy. Each probe
//! is dispatched through the seeded pool path
//! ([`PoolSolver::solve_groups`]) with a fixed probe seed, so
//! calibration (a) is byte-reproducible and (b) never touches the
//! device-global RNG — requests served after calibration are
//! byte-identical to requests served without it.
//!
//! The measured single-solve success probability `p` (energy within 10%
//! of ground, the same band the device quality tests use) maps to the
//! smallest replication factor `r` with `1 - (1-p)^r >= target`, clamped
//! to `[1, max_replication]` — an unhealthy device automatically earns
//! more replicas, a healthy one stays at 1.

use anyhow::Result;

use crate::cobi::SeededGroup;
use crate::config::ResilienceConfig;
use crate::ising::kofn::facility_dispersion;
use crate::ising::Ising;
use crate::quant::{quantize, Precision, Rounding};
use crate::sched::pool::PoolSolver;
use crate::solvers::exact::ising_ground_exhaustive;
use crate::util::rng::Pcg32;

/// Relative energy gap under which a probe solve counts as a success
/// (mirrors the device quality band in `cobi::device` tests).
const SUCCESS_GAP: f64 = 0.10;
/// Probe instance size: large enough to be nontrivial, small enough for
/// exhaustive ground-truth enumeration.
const PROBE_N: usize = 12;
/// Probe selection cardinality.
const PROBE_K: usize = 4;
/// Base seed of the probe stream (instances and request seeds).
const PROBE_SEED: u64 = 0xCA11_B8A7E;

/// One device's calibration result.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Calibration {
    /// Probes dispatched.
    pub probes: usize,
    /// Fraction of probes whose energy landed within the success band.
    pub success_rate: f64,
    /// Mean relative energy gap to ground truth across probes.
    pub mean_gap: f64,
    /// Replication factor chosen for the measured success rate.
    pub replication: usize,
}

/// The startup prober (see module docs).
#[derive(Debug, Clone)]
pub struct Calibrator {
    /// Probe instances to dispatch.
    pub probes: usize,
    /// Target per-request success probability after replication.
    pub target: f64,
    /// Ceiling on the chosen replication factor.
    pub max_replication: usize,
}

impl Calibrator {
    /// Calibrator from the `[resilience]` settings.
    pub fn from_config(cfg: &ResilienceConfig) -> Self {
        Self {
            probes: cfg.calibration_probes.max(1),
            target: cfg.calibration_target.clamp(0.0, 0.999_999),
            max_replication: cfg.max_replication.max(1),
        }
    }

    /// One deterministic probe instance (quantized to the COBI grid).
    fn probe_instance(&self, k: usize) -> Ising {
        let mut rng = Pcg32::seeded(PROBE_SEED.wrapping_add(k as u64));
        let problem = facility_dispersion(&mut rng, PROBE_N, PROBE_K);
        let ising = problem.formulate(true);
        quantize(&ising, Precision::CobiInt, Rounding::Deterministic, &mut rng)
    }

    /// Probe `solver` and derive its replication factor.
    pub fn calibrate(&self, solver: &mut dyn PoolSolver) -> Result<Calibration> {
        let mut successes = 0usize;
        let mut gap_sum = 0.0f64;
        for k in 0..self.probes {
            let inst = self.probe_instance(k);
            let (ground, _, _) = ising_ground_exhaustive(&inst);
            let solved = solver
                .solve_groups(&[SeededGroup {
                    instances: std::slice::from_ref(&inst),
                    seed: PROBE_SEED ^ ((k as u64) << 17),
                }])?
                .pop()
                .expect("one probe group in, one out")
                .pop()
                .expect("one probe instance in, one out");
            // verify in software: calibration must not trust the device
            let energy = inst.energy(&solved.spins);
            let gap = (energy - ground) / ground.abs().max(1e-9);
            gap_sum += gap.max(0.0);
            if gap < SUCCESS_GAP {
                successes += 1;
            }
        }
        let success_rate = successes as f64 / self.probes as f64;
        Ok(Calibration {
            probes: self.probes,
            success_rate,
            mean_gap: gap_sum / self.probes as f64,
            replication: self.replication_for(success_rate),
        })
    }

    /// Smallest replication `r` with `1 - (1-p)^r >= target`, clamped to
    /// `[1, max_replication]`.
    pub fn replication_for(&self, p: f64) -> usize {
        if p >= self.target {
            return 1;
        }
        if p <= 0.0 {
            return self.max_replication;
        }
        let mut miss = 1.0f64;
        for r in 1..=self.max_replication {
            miss *= 1.0 - p;
            if 1.0 - miss >= self.target {
                return r;
            }
        }
        self.max_replication
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::tabu::TabuSolver;
    use crate::solvers::SolveResult;

    /// A solver that always answers with a fixed (bad) configuration.
    struct Stubborn;

    impl PoolSolver for Stubborn {
        fn name(&self) -> &'static str {
            "stubborn"
        }

        fn solve_groups(
            &mut self,
            groups: &[SeededGroup<'_>],
        ) -> Result<Vec<Vec<SolveResult>>> {
            Ok(groups
                .iter()
                .map(|g| {
                    g.instances
                        .iter()
                        .map(|i| {
                            let spins = vec![1i8; i.n];
                            SolveResult {
                                spins: spins.clone(),
                                energy: i.energy(&spins),
                            }
                        })
                        .collect()
                })
                .collect())
        }
    }

    fn calibrator() -> Calibrator {
        Calibrator {
            probes: 6,
            target: 0.9,
            max_replication: 5,
        }
    }

    #[test]
    fn healthy_software_solver_calibrates_to_replication_one() {
        let mut tabu = TabuSolver::seeded(1);
        let cal = calibrator().calibrate(&mut tabu).unwrap();
        assert_eq!(cal.probes, 6);
        assert!(cal.success_rate > 0.9, "tabu success {}", cal.success_rate);
        assert_eq!(cal.replication, 1);
        assert!(cal.mean_gap < 0.05, "tabu mean gap {}", cal.mean_gap);
    }

    #[test]
    fn hopeless_solver_earns_max_replication() {
        // the all-ones configuration is (essentially) never within 10% of
        // ground on a dispersion instance: success rate 0 → max replicas
        let cal = calibrator().calibrate(&mut Stubborn).unwrap();
        assert!(cal.success_rate < 0.5);
        assert!(cal.replication > 1);
    }

    #[test]
    fn calibration_is_deterministic() {
        let run = || {
            let mut tabu = TabuSolver::seeded(1);
            calibrator().calibrate(&mut tabu).unwrap()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn replication_curve_is_monotone_and_clamped() {
        let c = calibrator();
        assert_eq!(c.replication_for(1.0), 1);
        assert_eq!(c.replication_for(0.95), 1);
        assert_eq!(c.replication_for(0.0), 5);
        // 1-(1-0.6)^2 = 0.84 < 0.9; 1-(1-0.6)^3 = 0.936 >= 0.9
        assert_eq!(c.replication_for(0.6), 3);
        let mut last = usize::MAX;
        for p in [0.1, 0.3, 0.5, 0.7, 0.9, 0.99] {
            let r = c.replication_for(p);
            assert!(r <= last, "replication must not grow with success rate");
            assert!((1..=5).contains(&r));
            last = r;
        }
    }
}
