//! Fig. 6 — COBI accuracy vs iterations on 20/50/100-sentence benchmarks
//! (a–c) and the bias/rounding ablation on 50-sentence benchmarks (d).
//!
//! Full workflow: decomposition (P=20, Q=10) + iterative stochastic
//! rounding, COBI device simulation as the solver, Tabu and random as
//! comparators. "Number of iterations" counts individual Ising solves
//! (stages x refinement iterations), so all decomposition-based points sit
//! on multiples of the stage count — exactly the paper's convention.
//!
//! Expected shape: COBI slightly below Tabu, both far above random;
//! COBI converges toward Tabu by ~50 iterations (paper: 92.8% vs 93.5%).

use anyhow::Result;

use crate::config::Settings;
use crate::decompose::{decompose, stage_count, DecomposeParams};
use crate::ising::Formulation;
use crate::quant::{Precision, Rounding};
use crate::refine::{refine, RefineConfig};
use crate::solvers::random::RandomBaseline;
use crate::util::rng::Pcg32;
use crate::util::stats::{mean, BoxStats};

use super::common::{exp_rng, load_problems, make_solver, BenchProblem};
use super::{Report, Scale};

/// Run the decomposed workflow once: returns the normalized objective.
#[allow(clippy::too_many_arguments)]
pub fn workflow_once(
    bp: &BenchProblem,
    params: &DecomposeParams,
    cfg: &RefineConfig,
    solver_name: &str,
    seed: u64,
    settings: &Settings,
    rng: &mut Pcg32,
) -> Result<f64> {
    let mut solver = make_solver(solver_name, seed, settings);
    let p = &bp.problem;
    let r = decompose(p.n(), params, |window, target| {
        let sub = super::fig5::sub_problem(p, window, target);
        Ok(refine(&sub, cfg, solver.as_mut(), rng)?.result.selected)
    })?;
    Ok(bp.bounds.normalize(p.objective(&r.selected)))
}

/// Iterations grid respecting the stage-multiple convention.
fn iteration_points(stages: usize, scale: Scale) -> Vec<(usize, usize)> {
    // (refine_iters_per_stage, total_iterations)
    let per_stage: Vec<usize> = match scale {
        Scale::Quick => vec![1, 3, 5],
        Scale::Full => vec![1, 2, 3, 5, 8, 12],
    };
    per_stage.into_iter().map(|r| (r, r * stages)).collect()
}

/// Regenerate this figure at `scale` under `settings`.
pub fn run(scale: Scale, settings: &Settings) -> Result<Vec<Report>> {
    let mut reports = Vec::new();
    let sets: &[(&str, &str)] = match scale {
        Scale::Quick => &[("cnn_dm_20", "a")],
        Scale::Full => &[("cnn_dm_20", "a"), ("cnn_dm_50", "b"), ("xsum_100", "c")],
    };
    let params = DecomposeParams {
        p: settings.pipeline.decompose_p,
        q: settings.pipeline.decompose_q,
        m: 6,
    };

    for &(set_name, panel) in sets {
        let docs = scale.docs(20);
        let runs = scale.runs(match scale {
            Scale::Quick => 2,
            Scale::Full => 10,
        });
        let problems = load_problems(set_name, docs, settings)?;
        let n = problems[0].problem.n();
        let stages = stage_count(n, &params);

        let mut report = Report::new(
            format!("Fig 6{panel} — accuracy vs iterations ({set_name})"),
            &["solver", "total iterations", "stats"],
        );
        report.note(format!(
            "{docs} docs x {runs} runs; decomposition P={} Q={} -> {stages} stages; \
             int14 quantization, stochastic rounding, improved formulation",
            params.p, params.q
        ));

        for solver_name in ["cobi", "tabu"] {
            for &(per_stage, total) in &iteration_points(stages, scale) {
                let mut vals = Vec::new();
                for (d, bp) in problems.iter().enumerate() {
                    for run_idx in 0..runs {
                        let cfg = RefineConfig {
                            formulation: Formulation::Improved,
                            precision: Precision::CobiInt,
                            rounding: Rounding::Stochastic,
                            iterations: per_stage,
                        };
                        let mut rng = exp_rng("fig6", run_idx, d);
                        let v = workflow_once(
                            bp,
                            &params,
                            &cfg,
                            solver_name,
                            (run_idx * 131 + d) as u64 ^ 0xF16A,
                            settings,
                            &mut rng,
                        )?;
                        vals.push(v);
                    }
                }
                report.row(vec![
                    solver_name.into(),
                    total.to_string(),
                    BoxStats::compute(&vals).row(),
                ]);
            }
        }
        // random baseline on the same total-iteration axis
        for &(_, total) in &iteration_points(stages, scale) {
            let mut vals = Vec::new();
            for (d, bp) in problems.iter().enumerate() {
                for run_idx in 0..runs {
                    let mut rb = RandomBaseline::seeded((run_idx * 17 + d) as u64 ^ 0xF16A);
                    let best = rb.best_of(&bp.problem, total);
                    vals.push(bp.bounds.normalize(best.objective));
                }
            }
            report.row(vec![
                "random".into(),
                total.to_string(),
                BoxStats::compute(&vals).row(),
            ]);
        }
        reports.push(report);
    }

    // panel (d): ablation on the 50-sentence set
    reports.push(ablation(scale, settings, &params)?);
    Ok(reports)
}

fn ablation(scale: Scale, settings: &Settings, params: &DecomposeParams) -> Result<Report> {
    let set_name = match scale {
        Scale::Quick => "cnn_dm_20", // cheaper stand-in, same shape
        Scale::Full => "cnn_dm_50",
    };
    let docs = scale.docs(20);
    let runs = scale.runs(match scale {
        Scale::Quick => 2,
        Scale::Full => 10,
    });
    let problems = load_problems(set_name, docs, settings)?;
    let stages = stage_count(problems[0].problem.n(), params);

    let mut report = Report::new(
        format!("Fig 6d — ablation: bias term x rounding ({set_name}, COBI)"),
        &["variant", "total iterations", "mean normalized objective"],
    );
    let variants: &[(&str, Formulation, Rounding)] = &[
        ("original+det", Formulation::Original, Rounding::Deterministic),
        ("bias+det", Formulation::Improved, Rounding::Deterministic),
        ("original+stoch", Formulation::Original, Rounding::Stochastic),
        ("bias+stoch", Formulation::Improved, Rounding::Stochastic),
    ];
    for &(label, formulation, rounding) in variants {
        for &(per_stage, total) in &iteration_points(stages, scale) {
            let mut vals = Vec::new();
            for (d, bp) in problems.iter().enumerate() {
                for run_idx in 0..runs {
                    let cfg = RefineConfig {
                        formulation,
                        precision: Precision::CobiInt,
                        rounding,
                        iterations: per_stage,
                    };
                    let mut rng = exp_rng("fig6d", run_idx, d);
                    let v = workflow_once(
                        bp,
                        params,
                        &cfg,
                        "cobi",
                        (run_idx * 313 + d) as u64 ^ 0xAB1A,
                        settings,
                        &mut rng,
                    )?;
                    vals.push(v);
                }
            }
            report.row(vec![
                label.into(),
                total.to_string(),
                format!("{:.4}", mean(&vals)),
            ]);
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_run_cobi_beats_random_and_converges() {
        let settings = Settings::default();
        let reports = run(Scale::Quick, &settings).unwrap();
        let r = &reports[0];
        let mean_of = |solver: &str, iters: &str| -> f64 {
            let row = r
                .rows
                .iter()
                .find(|row| row[0] == solver && row[1] == iters)
                .unwrap();
            row[2]
                .split("mean=")
                .nth(1)
                .unwrap()
                .split_whitespace()
                .next()
                .unwrap()
                .parse()
                .unwrap()
        };
        // highest iteration point in the quick grid: 5 per stage x 2 stages
        let cobi = mean_of("cobi", "10");
        let tabu = mean_of("tabu", "10");
        let random = mean_of("random", "10");
        assert!(cobi > random, "cobi {cobi} vs random {random}");
        assert!(tabu > random, "tabu {tabu} vs random {random}");
        assert!(cobi > 0.6, "cobi mean too low: {cobi}");
        // COBI within striking distance of tabu (paper: 92.8 vs 93.5)
        assert!(tabu - cobi < 0.25, "cobi {cobi} vs tabu {tabu}");
    }
}
