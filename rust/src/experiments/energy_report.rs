//! Energy report — the paper's COBI-vs-software energy comparison,
//! regenerated from the fleet energy ledger (`experiment energy-report`).
//!
//! One physical solve run (cnn_dm_20 through the COBI-native device,
//! window decomposition: one 20-spin reduction + one 10-spin final
//! selection per document) is charged to THREE backend cost models at
//! once by nesting [`LedgerSolver`] wrappers: every instance the run
//! dispatches lands in the ledger under `cobi`, `tabu` and `exact`
//! with that backend's modeled per-solve time and energy. The resulting
//! table is the paper's comparison on an identical workload — same
//! documents, same decomposition, same instance sizes — so the ratios
//! are pure cost-model ratios, not workload artifacts.

use std::sync::Arc;

use anyhow::Result;

use crate::cobi::CobiDevice;
use crate::config::Settings;
use crate::obs::{bucket_label, EnergyLedger, EnergyModel, LedgerSolver, Subsystem};
use crate::sched::pool::PoolSolver;
use crate::sched::{doc_seed, summarize_sequential};

use super::{Report, Scale};

/// Backends compared, paper order (the hardware one first).
const BACKENDS: [&str; 3] = ["cobi", "tabu", "exact"];

/// Regenerate the energy-comparison table at `scale`.
pub fn run(scale: Scale, settings: &Settings) -> Result<Vec<Report>> {
    // cnn_dm_20, not bench_10: a 10-sentence document is a single 10-spin
    // solve, and 2^10 modeled evaluations (~19 ms CPU) actually undercut
    // one 25 ms tabu sweep — the paper's cobi ≪ tabu ≪ exact ordering
    // only emerges once windows reach P=20 spins.
    let set = crate::corpus::benchmark_set("cnn_dm_20")?;
    let docs = scale.docs(set.documents.len());
    let mut s = settings.clone();
    s.pipeline.solver = "cobi".into();
    if scale == Scale::Quick {
        s.pipeline.iterations = s.pipeline.iterations.min(3);
    }

    let ledger = Arc::new(EnergyLedger::new(EnergyModel::from_settings(&s)));
    // nested wrappers: one run, every instance charged to all three
    // backend models (construction seed 0 — the seeded solve path never
    // touches the device-global RNG)
    let mut solver: Box<dyn PoolSolver> =
        Box::new(CobiDevice::from_config(&s.cobi, 0, None)?);
    for backend in BACKENDS {
        solver = Box::new(LedgerSolver::new(
            solver,
            backend,
            Subsystem::Experiment,
            ledger.clone(),
        ));
    }

    for doc in set.documents.iter().take(docs) {
        let mut cfg = s.pipeline.clone();
        cfg.summary_len = set.summary_len;
        cfg.seed = doc_seed(cfg.seed, &doc.id);
        summarize_sequential(doc, &cfg, solver.as_mut())?;
    }

    let mut report = Report::new(
        "Energy report — modeled joules & device-seconds per backend (cnn_dm_20, \
         identical workload)",
        &[
            "backend",
            "solves",
            "modeled J",
            "modeled device-s",
            "energy x cobi",
            "time x cobi",
        ],
    );
    report.note(format!(
        "{docs} documents x {} refinement iterations; one physical COBI-native run, \
         charged to each backend's cost model (docs/OBSERVABILITY.md §Ledger); \
         `exact` models 2^n exhaustive enumeration",
        s.pipeline.iterations
    ));
    let cobi = ledger.backend_totals("cobi");
    for backend in BACKENDS {
        let t = ledger.backend_totals(backend);
        report.row(vec![
            backend.to_string(),
            t.solves.to_string(),
            format!("{:.3e}", t.joules),
            format!("{:.3e}", t.device_s),
            format!("{:.1}x", t.joules / cobi.joules),
            format!("{:.1}x", t.device_s / cobi.device_s),
        ]);
    }

    let mut rows = Report::new(
        "Energy ledger rows — (backend x size bucket)",
        &["backend", "bucket", "solves", "modeled J", "modeled device-s"],
    );
    for r in ledger.rows() {
        rows.row(vec![
            r.backend.clone(),
            bucket_label(r.bucket),
            r.cell.solves.to_string(),
            format!("{:.3e}", r.cell.joules),
            format!("{:.3e}", r.cell.device_s),
        ]);
    }
    Ok(vec![report, rows])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_report_shows_the_paper_energy_ordering() {
        let reports = run(Scale::Quick, &Settings::default()).unwrap();
        let r = &reports[0];
        assert_eq!(r.rows.len(), 3);
        let joules = |i: usize| -> f64 { r.rows[i][2].parse().unwrap() };
        let solves = |i: usize| -> u64 { r.rows[i][1].parse().unwrap() };
        // identical workload across backends
        assert!(solves(0) > 0);
        assert_eq!(solves(0), solves(1));
        assert_eq!(solves(1), solves(2));
        // the paper's ordering: cobi ≪ tabu ≪ brute force
        assert!(joules(0) < joules(1), "{:?}", r.rows);
        assert!(joules(1) < joules(2), "{:?}", r.rows);
        assert_eq!(r.rows[0][4], "1.0x", "cobi is the ratio baseline");
        // the bucket breakdown covers every backend
        let buckets = &reports[1];
        assert!(buckets.rows.len() >= 3);
        for b in BACKENDS {
            assert!(buckets.rows.iter().any(|row| row[0] == b), "{b} missing");
        }
    }
}
