//! Workload quality table — normalized objective by Ising backend for
//! the non-ES k-of-n workloads (diverse retrieval, facility dispersion).
//!
//! Each pinned corpus request lowers to its generic k-of-n QUBO, exact
//! Eq. 13 bounds normalize the backend objectives onto [0, 1], and one
//! table per workload reports the mean/min normalized objective and
//! feasibility per backend — the cross-workload analogue of the ES
//! backend comparisons.

use anyhow::Result;

use crate::config::Settings;
use crate::corpus::workload_requests;
use crate::ising::{exact_bounds, EsProblem, Formulation};
use crate::quant::{Precision, Rounding};
use crate::refine::{refine, RefineConfig};
use crate::util::stats::mean;
use crate::workload::problem_from_request;

use super::common::{exp_rng, make_solver};
use super::{Report, Scale};

/// Backends compared, portfolio order.
const BACKENDS: &[&str] = &["cobi", "tabu", "sa", "snowball"];

/// Regenerate the per-workload backend-quality tables at `scale`.
pub fn run(scale: Scale, settings: &Settings) -> Result<Vec<Report>> {
    let runs = scale.runs(match scale {
        Scale::Quick => 2,
        Scale::Full => 5,
    });
    let iterations = match scale {
        Scale::Quick => 4,
        Scale::Full => 20,
    };
    let mut reports = Vec::new();
    for workload in ["retrieval", "dispersion"] {
        let reqs = workload_requests(workload)?;
        let take = scale.docs(reqs.len());
        // lower each pinned request once; the expensive exact bounds run
        // once per instance, shared across backends and runs
        let mut problems = Vec::new();
        for r in reqs.iter().take(take) {
            let p = problem_from_request(workload, &r.id, &r.lines, &settings.workload)?;
            let scores = p.scores()?;
            let es = EsProblem {
                mu: scores.mu,
                beta: scores.beta,
                lambda: p.lambda().unwrap_or(settings.pipeline.lambda),
                m: p.k(),
            };
            let bounds = exact_bounds(&es);
            problems.push((es, bounds));
        }
        let mut report = Report::new(
            format!("Workload quality — {workload} (normalized objective by backend)"),
            &["backend", "mean norm objective", "min norm objective", "feasible"],
        );
        report.note(format!(
            "{take} pinned requests x {runs} runs x {iterations} refinement iterations; \
             objectives normalized by exact Eq. 13 bounds"
        ));
        for &backend in BACKENDS {
            let mut norms = Vec::new();
            let mut feasible = true;
            for (d, (es, bounds)) in problems.iter().enumerate() {
                for run_idx in 0..runs {
                    let cfg = RefineConfig {
                        formulation: Formulation::Improved,
                        precision: Precision::CobiInt,
                        rounding: Rounding::Stochastic,
                        iterations,
                    };
                    let mut rng = exp_rng(&format!("workloads-{workload}-{backend}"), run_idx, d);
                    let mut solver =
                        make_solver(backend, (run_idx * 1000 + d * 17 + 3) as u64, settings);
                    let selected = refine(es, &cfg, solver.as_mut(), &mut rng)?.result.selected;
                    feasible &= selected.len() == es.m;
                    norms.push(bounds.normalize(es.objective(&selected)));
                }
            }
            let min = norms.iter().copied().fold(f64::INFINITY, f64::min);
            report.row(vec![
                backend.to_string(),
                format!("{:.4}", mean(&norms)),
                format!("{min:.4}"),
                feasible.to_string(),
            ]);
        }
        reports.push(report);
    }
    Ok(reports)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_run_covers_both_workloads_and_all_backends() {
        let settings = Settings::default();
        let reports = run(Scale::Quick, &settings).unwrap();
        assert_eq!(reports.len(), 2);
        assert!(reports[0].title.contains("retrieval"), "{}", reports[0].title);
        assert!(reports[1].title.contains("dispersion"), "{}", reports[1].title);
        for r in &reports {
            assert_eq!(r.rows.len(), BACKENDS.len(), "{}", r.title);
            for row in &r.rows {
                let m: f64 = row[1].parse().unwrap();
                assert!((0.0..=1.0 + 1e-9).contains(&m), "{}: {row:?}", r.title);
                assert_eq!(row[3], "true", "{}: infeasible selection", r.title);
            }
        }
    }
}
