//! Supplementary — multiplicity of optima under quantization.
//!
//! The paper motivates iterative refinement by observing that quantized
//! formulations often admit MULTIPLE degenerate ground states, many far
//! (in Hamming distance) from the FP optimum. This driver counts exact
//! ground-state degeneracy across precisions via Gray-code enumeration.

use anyhow::Result;

use crate::config::Settings;
use crate::ising::{formulate, Formulation};
use crate::quant::{quantize, Precision, Rounding};
use crate::solvers::exact::ising_ground_exhaustive;
use crate::util::stats::mean;

use super::common::{exp_rng, load_problems};
use super::{Report, Scale};

/// Run the supplementary study at `scale` under `settings`.
pub fn run(scale: Scale, settings: &Settings) -> Result<Vec<Report>> {
    // exhaustive enumeration: 10-sentence set is cheap (2^10), 20-sentence
    // (2^20) reserved for full scale
    let (set_name, docs) = match scale {
        Scale::Quick => ("bench_10", scale.docs(10)),
        Scale::Full => ("cnn_dm_20", scale.docs(20)),
    };
    let problems = load_problems(set_name, docs, settings)?;
    let precisions = [
        Precision::Fixed(4),
        Precision::Fixed(6),
        Precision::Fixed(8),
        Precision::CobiInt,
    ];

    let mut report = Report::new(
        format!("Supp — ground-state degeneracy under quantization ({set_name})"),
        &[
            "precision",
            "mean #optima",
            "max #optima",
            "instances with >1 optimum",
        ],
    );
    report.note("deterministic rounding; exact enumeration of the quantized Ising");

    for &precision in &precisions {
        let mut counts = Vec::new();
        for (d, bp) in problems.iter().enumerate() {
            let es = formulate(&bp.problem, Formulation::Improved);
            let mut rng = exp_rng("supp", 0, d);
            let inst = quantize(&es.ising, precision, Rounding::Deterministic, &mut rng);
            let (_, _, count) = ising_ground_exhaustive(&inst);
            counts.push(count as f64);
        }
        let multi = counts.iter().filter(|&&c| c > 1.0).count();
        report.row(vec![
            precision.to_string(),
            format!("{:.2}", mean(&counts)),
            format!("{:.0}", counts.iter().cloned().fold(0.0, f64::max)),
            format!("{multi}/{}", counts.len()),
        ]);
    }
    Ok(vec![report])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_run_counts_are_sane() {
        let settings = Settings::default();
        let reports = run(Scale::Quick, &settings).unwrap();
        let r = &reports[0];
        assert_eq!(r.rows.len(), 4);
        for row in &r.rows {
            let mean_optima: f64 = row[1].parse().unwrap();
            assert!(mean_optima >= 1.0, "{row:?}");
        }
    }
}
