//! Experiment drivers: one per paper table/figure (DESIGN.md §4).
//!
//! Each driver regenerates the corresponding result on the synthetic
//! benchmark sets and returns [`Report`]s rendered as markdown tables by
//! the CLI (`cobi-es experiment <id>`) and the bench targets. `Scale`
//! trades fidelity for wall-clock so the full suite stays usable on a
//! single-core box; `--full` reproduces the paper-sized sweeps.

pub mod common;
pub mod energy_report;
pub mod fault_sweep;
pub mod fig1;
pub mod fig23;
pub mod fig5;
pub mod fig6;
pub mod fig78;
pub mod replay_audit;
pub mod supp;
pub mod table1;
pub mod workloads;

use anyhow::{bail, Result};

use crate::config::Settings;

/// Tabular result with a title and free-form notes.
#[derive(Debug, Clone)]
pub struct Report {
    /// Report title (figure/panel name).
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Table rows (stringified cells).
    pub rows: Vec<Vec<String>>,
    /// Free-form notes rendered under the table.
    pub notes: Vec<String>,
}

impl Report {
    /// New empty report with `headers`.
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    /// Append one row.
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "ragged report row");
        self.rows.push(cells);
    }

    /// Append a note line.
    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    /// Render as a markdown table.
    pub fn to_markdown(&self) -> String {
        let mut out = format!("### {}\n\n", self.title);
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            self.headers.iter().map(|_| "---|").collect::<String>()
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        for n in &self.notes {
            out.push_str(&format!("\n> {n}\n"));
        }
        out
    }

    /// Render as CSV (headers + rows).
    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",") + "\n";
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// Effort scaling for the drivers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// CI / bench: fewer docs, runs and iteration points.
    Quick,
    /// Paper-sized sweeps.
    Full,
}

impl Scale {
    /// Benchmark documents to evaluate at this scale.
    pub fn docs(&self, full: usize) -> usize {
        match self {
            Scale::Quick => full.min(6),
            Scale::Full => full,
        }
    }

    /// Repeated runs per point at this scale.
    pub fn runs(&self, full: usize) -> usize {
        match self {
            Scale::Quick => full.min(3),
            Scale::Full => full,
        }
    }

    /// Refinement-iteration sweep points at this scale.
    pub fn iteration_grid(&self) -> Vec<usize> {
        match self {
            Scale::Quick => vec![2, 6, 10, 20],
            Scale::Full => vec![2, 6, 10, 20, 50, 100],
        }
    }
}

/// All experiment ids, in paper order.
pub const ALL_EXPERIMENTS: &[&str] = &[
    "fig1",
    "fig2",
    "fig3",
    "fig5",
    "fig6",
    "fig7",
    "fig8",
    "table1",
    "supp-optima",
    "fault-sweep",
    "energy-report",
    "workloads",
    "replay-audit",
];

/// Run one experiment by id.
pub fn run(id: &str, scale: Scale, settings: &Settings) -> Result<Vec<Report>> {
    match id {
        "fig1" => fig1::run(scale, settings),
        "fig2" => fig23::run(scale, settings, "cnn_dm_20"),
        "fig3" => fig23::run(scale, settings, "bench_10"),
        "fig5" => fig5::run(scale, settings),
        "fig6" => fig6::run(scale, settings),
        "fig7" | "fig8" => fig78::run(scale, settings),
        "table1" => table1::run(scale, settings),
        "supp-optima" => supp::run(scale, settings),
        "fault-sweep" => fault_sweep::run(scale, settings),
        "energy-report" => energy_report::run(scale, settings),
        "workloads" => workloads::run(scale, settings),
        "replay-audit" => replay_audit::run(scale, settings),
        other => bail!("unknown experiment '{other}' (try one of {ALL_EXPERIMENTS:?})"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_renders_markdown_and_csv() {
        let mut r = Report::new("T", &["a", "b"]);
        r.row(vec!["1".into(), "2".into()]);
        r.note("note");
        let md = r.to_markdown();
        assert!(md.contains("### T"));
        assert!(md.contains("| 1 | 2 |"));
        assert!(md.contains("> note"));
        let csv = r.to_csv();
        assert!(csv.starts_with("a,b\n1,2\n"));
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_rows_panic() {
        let mut r = Report::new("T", &["a", "b"]);
        r.row(vec!["1".into()]);
    }

    #[test]
    fn unknown_experiment_is_error() {
        assert!(run("fig99", Scale::Quick, &Settings::default()).is_err());
    }
}
