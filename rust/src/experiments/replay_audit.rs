//! Replay-audit — flight-recorder divergence triage under injected
//! faults (`experiment replay-audit`, ISSUE 10 acceptance table).
//!
//! Two passes over bench_10 through an in-process recording
//! [`Service`](crate::service::Service):
//!
//! 1. **control** — record on a clean fleet, replay against the same
//!    clean config: every replay must come back byte-identical (the
//!    determinism contract, audited end to end through the recorder);
//! 2. **fault audit** — record on a fleet whose COBI devices carry a 5%
//!    stuck-oscillator model, replay against the CLEAN config: each
//!    divergent record is triaged to the first DAG node the fault
//!    flipped, and the table reports the divergence count plus the
//!    named (level, slot) nodes.

use anyhow::Result;

use crate::config::Settings;
use crate::obs::recorder::hex;
use crate::obs::{replay_record, RequestRecord};
use crate::service::Service;

use super::{Report, Scale};

/// Recording fleet settings: COBI devices, recorder on, optional
/// stuck-oscillator injection.
fn fleet_settings(base: &Settings, iterations: usize, stuck: f32) -> Settings {
    let mut s = base.clone();
    s.service.workers = 1;
    s.pipeline.solver = "cobi".into();
    s.pipeline.iterations = iterations;
    s.obs.record_enabled = true;
    s.obs.record_capacity = 64;
    if stuck > 0.0 {
        s.resilience.fault.enabled = true;
        s.resilience.fault.stuck_rate = stuck;
    }
    s
}

/// Serve `docs` bench_10 documents through a recording service
/// (sequential submits keep ring ids aligned with document order) and
/// return the ring contents.
fn record_fleet(settings: &Settings, docs: usize) -> Result<Vec<RequestRecord>> {
    let svc = Service::start(settings)?;
    let set = crate::corpus::benchmark_set("bench_10")?;
    for doc in set.documents.iter().take(docs) {
        svc.submit(doc.clone())?.wait()?;
    }
    let recs = svc.obs().recorder().snapshot();
    svc.shutdown();
    Ok(recs)
}

/// Regenerate the replay-audit table at `scale`.
pub fn run(scale: Scale, settings: &Settings) -> Result<Vec<Report>> {
    let docs = scale.docs(10);
    let iterations = match scale {
        Scale::Quick => 2,
        Scale::Full => settings.pipeline.iterations.max(10),
    };
    let clean = fleet_settings(settings, iterations, 0.0);
    let faulty = fleet_settings(settings, iterations, 0.05);

    let mut report = Report::new(
        "Replay audit — record/replay byte-identity and fault triage (bench_10)",
        &[
            "fleet",
            "records",
            "identical",
            "diverged",
            "first divergent node",
            "config diff",
        ],
    );
    report.note(format!(
        "{docs} documents x {iterations} refinement iterations; both fleets replayed \
         against the CLEAN config — control divergences must be 0, fault-fleet \
         divergences are triaged to the first DAG node (level,slot) the stuck \
         oscillators flipped (docs/OBSERVABILITY.md §Flight recorder)"
    ));

    for (fleet, record_settings, stuck) in
        [("clean (control)", &clean, 0.0f32), ("5% stuck oscillators", &faulty, 0.05)]
    {
        let recs = record_fleet(record_settings, docs)?;
        let mut identical = 0usize;
        let mut diverged = 0usize;
        let mut first_node = String::from("—");
        let mut config_diff = String::from("—");
        for rec in &recs {
            // replay against the clean environment: this is the triage
            // posture — "does this recording reproduce on a good fleet?"
            let r = replay_record(rec, &clean)?;
            if r.identical {
                identical += 1;
            } else {
                diverged += 1;
                if let (true, Some(d)) = (first_node == "—", &r.first_divergence) {
                    first_node = format!(
                        "doc {} node ({},{}) seed {} energy {:.3}->{:.3}",
                        rec.doc_id,
                        d.level,
                        d.slot,
                        hex(d.node_seed),
                        d.recorded_energy,
                        d.replayed_energy,
                    );
                }
                if config_diff == "—" && !r.config_diff.is_empty() {
                    config_diff = r
                        .config_diff
                        .iter()
                        .map(|c| format!("{}: {}->{}", c.key, c.recorded, c.current))
                        .collect::<Vec<_>>()
                        .join("; ");
                }
            }
        }
        if stuck == 0.0 && diverged > 0 {
            anyhow::bail!("control fleet diverged {diverged}/{docs} — determinism broken");
        }
        report.row(vec![
            fleet.to_string(),
            recs.len().to_string(),
            identical.to_string(),
            diverged.to_string(),
            first_node,
            config_diff,
        ]);
    }
    Ok(vec![report])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replay_audit_quick_reports_clean_control_and_triaged_faults() {
        let reports = run(Scale::Quick, &Settings::default()).unwrap();
        assert_eq!(reports.len(), 1);
        let r = &reports[0];
        assert_eq!(r.rows.len(), 2);
        // control row: all identical, no divergence, placeholder cells
        assert_eq!(r.rows[0][2], r.rows[0][1], "control must be N/N identical");
        assert_eq!(r.rows[0][3], "0");
        assert_eq!(r.rows[0][4], "—");
        // fault row: counts add up; any divergence names a node and the
        // fault_enabled knob
        let total: usize = r.rows[1][1].parse().unwrap();
        let identical: usize = r.rows[1][2].parse().unwrap();
        let diverged: usize = r.rows[1][3].parse().unwrap();
        assert_eq!(identical + diverged, total);
        if diverged > 0 {
            assert!(r.rows[1][4].contains("node ("), "{}", r.rows[1][4]);
            assert!(r.rows[1][5].contains("fault_enabled"), "{}", r.rows[1][5]);
        }
        let md = r.to_markdown();
        assert!(md.contains("Replay audit"), "{md}");
    }
}
