//! Figs. 7–8 — TTS and ETS: COBI vs brute force vs Tabu on the
//! 20/50/100-sentence benchmark sets.
//!
//! Methodology (paper §V): per benchmark, find the first iteration count
//! at which the workflow's best-so-far normalized objective reaches 0.9;
//! MLE the per-iteration success probability (Eq. 14); TTS via Eq. 15
//! with the hardware timing model; ETS via Eq. 16. Brute force is
//! deterministic: its TTS is the modeled enumeration time of the
//! decomposed workflow (per-evaluation cost calibrated from the paper's
//! own Fig-7 brute numbers — see TimingConfig notes).
//!
//! Expected shape: COBI 3.1–4.3x faster TTS than brute force, comparable
//! to Tabu; ETS 2–3 orders of magnitude below both CPU solvers.

use anyhow::Result;

use crate::config::Settings;
use crate::decompose::{decompose, stage_count, DecomposeParams};
use crate::ising::Formulation;
use crate::metrics::tts::{tts_ets, TimingModel};
use crate::quant::{Precision, Rounding};
use crate::refine::{refine, RefineConfig};
use crate::solvers::brute::binomial;
use crate::util::stats::mean;

use super::common::{exp_rng, first_success, load_problems, make_solver, BenchProblem};
use super::{Report, Scale};

/// Per-objective-evaluation cost of the brute-force enumeration,
/// calibrated from the paper's own brute TTS on the 20-sentence set
/// (50.9 ms over the C(20,10) + C(10,6) decomposed enumeration).
pub const BRUTE_EVAL_TIME_S: f64 = 50.9e-3 / 184_966.0;

/// Best-so-far normalized objective per cumulative solve count, running
/// the decomposed workflow with per-stage refinement budgets 1..=r_max.
fn success_curve(
    bp: &BenchProblem,
    params: &DecomposeParams,
    solver_name: &str,
    r_max: usize,
    seed_base: u64,
    settings: &Settings,
) -> Result<Vec<f64>> {
    let stages = stage_count(bp.problem.n(), params);
    let mut best = f64::NEG_INFINITY;
    let mut curve = Vec::new(); // index = total solves (stages * r)
    for r in 1..=r_max {
        let cfg = RefineConfig {
            formulation: Formulation::Improved,
            precision: Precision::CobiInt,
            rounding: Rounding::Stochastic,
            iterations: r,
        };
        let mut rng = exp_rng("fig78", r, seed_base as usize);
        let mut solver = make_solver(solver_name, seed_base ^ (r as u64) << 8, settings);
        let p = &bp.problem;
        let result = decompose(p.n(), params, |window, target| {
            let sub = super::fig5::sub_problem(p, window, target);
            Ok(refine(&sub, &cfg, solver.as_mut(), &mut rng)?.result.selected)
        })?;
        let v = bp.bounds.normalize(p.objective(&result.selected));
        best = best.max(v);
        // the r-budget workflow spends `stages * r` solves total
        curve.push(best);
        let _ = stages;
    }
    Ok(curve)
}

/// Brute-force enumeration count for the decomposed workflow over an
/// n-sentence document.
pub fn brute_evals(n: usize, params: &DecomposeParams) -> u128 {
    let mut len = n;
    let mut evals: u128 = 0;
    let mut first = true;
    while (first && len >= params.p) || len > params.p {
        evals += binomial(params.p, params.q);
        len = len - params.p + params.q;
        first = false;
    }
    evals += binomial(len, params.m);
    evals
}

/// Regenerate these figures at `scale` under `settings`.
pub fn run(scale: Scale, settings: &Settings) -> Result<Vec<Report>> {
    let sets: &[&str] = match scale {
        Scale::Quick => &["cnn_dm_20"],
        Scale::Full => &["cnn_dm_20", "cnn_dm_50", "xsum_100"],
    };
    let docs = scale.docs(20);
    let r_max = match scale {
        Scale::Quick => 6,
        Scale::Full => 12,
    };
    let params = DecomposeParams::paper_default();
    let t = &settings.timing;

    let mut tts_report = Report::new(
        "Fig 7 — TTS (s) at normalized objective >= 0.9, p_target = 0.95",
        &["benchmark", "solver", "p_success", "iterations", "TTS (ms)"],
    );
    let mut ets_report = Report::new(
        "Fig 8 — ETS (J) at normalized objective >= 0.9",
        &["benchmark", "solver", "ETS (mJ)", "vs COBI"],
    );

    for &set_name in sets {
        let problems = load_problems(set_name, docs, settings)?;
        let n = problems[0].problem.n();
        let stages = stage_count(n, &params);

        let mut ets_cobi = f64::NAN;
        for solver_name in ["cobi", "tabu"] {
            // first-success (in total solves) per benchmark
            let mut fs: Vec<Option<usize>> = Vec::new();
            for (d, bp) in problems.iter().enumerate() {
                let curve = success_curve(bp, &params, solver_name, r_max, d as u64, settings)?;
                // curve[i] corresponds to (i+1)*stages total solves
                let hit = first_success(&curve, t.success_threshold).map(|r| r * stages);
                fs.push(hit);
            }
            let model = match solver_name {
                "cobi" => TimingModel::cobi(t, settings.cobi.solve_time_s, settings.cobi.power_w),
                _ => TimingModel::software(t, t.tabu_time_s),
            };
            let res = tts_ets(&fs, r_max * stages, &model, t.p_target);
            tts_report.row(vec![
                set_name.into(),
                solver_name.into(),
                format!("{:.3}", res.p_success),
                format!("{:.2}", res.iterations),
                format!("{:.3}", res.tts_s * 1e3),
            ]);
            if solver_name == "cobi" {
                ets_cobi = res.ets_j;
            }
            ets_report.row(vec![
                set_name.into(),
                solver_name.into(),
                format!("{:.4}", res.ets_j * 1e3),
                format!("{:.1}x", res.ets_j / ets_cobi),
            ]);
        }

        // brute force: deterministic success, modeled enumeration time
        let evals = brute_evals(n, &params) as f64;
        let tts_brute = evals * BRUTE_EVAL_TIME_S;
        let ets_brute = tts_brute * t.cpu_power_w;
        tts_report.row(vec![
            set_name.into(),
            "brute".into(),
            "1.000".into(),
            "1.00".into(),
            format!("{:.3}", tts_brute * 1e3),
        ]);
        ets_report.row(vec![
            set_name.into(),
            "brute".into(),
            format!("{:.4}", ets_brute * 1e3),
            format!("{:.1}x", ets_brute / ets_cobi),
        ]);
    }
    tts_report.note(format!(
        "COBI model: {} µs/solve @ {} mW + {} µs eval; Tabu model: {} ms @ {} W; \
         brute: {:.0} ns/eval (calibrated from the paper's Fig 7)",
        settings.cobi.solve_time_s * 1e6,
        settings.cobi.power_w * 1e3,
        t.eval_time_s * 1e6,
        t.tabu_time_s * 1e3,
        t.cpu_power_w,
        BRUTE_EVAL_TIME_S * 1e9,
    ));
    Ok(vec![tts_report, ets_report])
}

/// Mean first-success iterations, exposed for Table I.
pub fn mean_first_success(fs: &[Option<usize>], max_iter: usize) -> f64 {
    mean(
        &fs.iter()
            .map(|k| k.unwrap_or(max_iter + 1) as f64)
            .collect::<Vec<_>>(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn brute_eval_counts() {
        let params = DecomposeParams::paper_default();
        // 20-sent: C(20,10) + C(10,6) = 184756 + 210
        assert_eq!(brute_evals(20, &params), 184_966);
        // 50-sent: 3 windows + final C(20,6)
        assert_eq!(brute_evals(50, &params), 3 * 184_756 + 38_760);
        // 10-sent: single C(10,6)
        assert_eq!(brute_evals(10, &params), 210);
    }

    #[test]
    fn quick_run_headline_ratios() {
        let settings = Settings::default();
        let reports = run(Scale::Quick, &settings).unwrap();
        let tts = &reports[0];
        let get = |solver: &str| -> f64 {
            tts.rows
                .iter()
                .find(|r| r[1] == solver)
                .unwrap()[4]
                .parse()
                .unwrap()
        };
        let (cobi, tabu, brute) = (get("cobi"), get("tabu"), get("brute"));
        // the paper's ordering: COBI fastest, brute slowest
        assert!(cobi < tabu, "cobi {cobi} vs tabu {tabu}");
        assert!(cobi < brute, "cobi {cobi} vs brute {brute}");
        // speedup over brute should be on the paper's order (3-4x); allow
        // a broad band since success statistics are synthetic
        let speedup = brute / cobi;
        assert!(
            speedup > 1.5 && speedup < 100.0,
            "speedup {speedup} out of plausible band"
        );
        // ETS: orders of magnitude (paper: 2-3)
        let ets = &reports[1];
        let gete = |solver: &str| -> f64 {
            ets.rows.iter().find(|r| r[1] == solver).unwrap()[2].parse().unwrap()
        };
        assert!(gete("tabu") / gete("cobi") > 100.0);
        assert!(gete("brute") / gete("cobi") > 100.0);
    }
}
