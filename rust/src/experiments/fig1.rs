//! Fig. 1 — normalized-objective distribution: original vs improved
//! formulation across precisions {FP, 8..4-bit, int14}, Tabu solver,
//! 20-sentence benchmarks.
//!
//! Expected shape (paper): original@FP ≈ 0.99+, collapsing at <=6-bit
//! (0.66 in the paper); improved@FP slightly lower (≈0.83) but markedly
//! more robust at low precision (≈0.74 at 6-bit).

use anyhow::Result;

use crate::config::Settings;
use crate::ising::{formulate, selected_indices, Formulation};
use crate::quant::{quantize, Precision, Rounding};
use crate::refine::repair_selection;
use crate::util::stats::BoxStats;

use super::common::{exp_rng, load_problems, make_solver};
use super::{Report, Scale};

/// Regenerate this figure at `scale` under `settings`.
pub fn run(scale: Scale, settings: &Settings) -> Result<Vec<Report>> {
    let docs = scale.docs(20);
    let problems = load_problems("cnn_dm_20", docs, settings)?;
    let precisions = match scale {
        Scale::Quick => vec![Precision::Fp, Precision::Fixed(6), Precision::CobiInt],
        Scale::Full => Precision::paper_sweep(),
    };

    let mut report = Report::new(
        "Fig 1 — normalized objective by formulation x precision (Tabu, 20-sentence)",
        &["formulation", "precision", "stats"],
    );
    report.note(format!("{docs} documents, deterministic rounding, single Tabu solve per cell"));

    for formulation in [Formulation::Original, Formulation::Improved] {
        for &precision in &precisions {
            let mut values = Vec::new();
            for (d, bp) in problems.iter().enumerate() {
                let es = formulate(&bp.problem, formulation);
                let mut rng = exp_rng("fig1", 0, d);
                let inst = quantize(&es.ising, precision, Rounding::Deterministic, &mut rng);
                let mut solver = make_solver("tabu", 1000 + d as u64, settings);
                let solved = solver.solve(&inst);
                let selected =
                    repair_selection(&bp.problem, selected_indices(&solved.spins));
                values.push(bp.bounds.normalize(bp.problem.objective(&selected)));
            }
            report.row(vec![
                format!("{formulation:?}"),
                precision.to_string(),
                BoxStats::compute(&values).row(),
            ]);
        }
    }
    Ok(vec![report])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_run_shows_paper_shape() {
        let settings = Settings::default();
        let reports = run(Scale::Quick, &settings).unwrap();
        let r = &reports[0];
        assert_eq!(r.rows.len(), 6); // 2 formulations x 3 precisions
        // parse mean values back out of the stats column
        let mean_of = |row: &[String]| -> f64 {
            row[2]
                .split("mean=")
                .nth(1)
                .unwrap()
                .split_whitespace()
                .next()
                .unwrap()
                .parse()
                .unwrap()
        };
        let orig_fp = mean_of(&r.rows[0]);
        let impr_int = mean_of(&r.rows[5]);
        let orig_int = mean_of(&r.rows[2]);
        // original at FP nearly optimal
        assert!(orig_fp > 0.9, "orig fp mean {orig_fp}");
        // improved at int14 beats original at int14 (the paper's claim)
        assert!(
            impr_int >= orig_int - 0.05,
            "improved int14 {impr_int} vs original {orig_int}"
        );
    }
}
