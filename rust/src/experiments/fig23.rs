//! Figs. 2–3 — normalized objective vs refinement iterations for the
//! three rounding schemes + random baseline, across precisions.
//! Fig 2: 20-sentence benchmarks (M=6); Fig 3: 10-sentence (M=3).
//!
//! Expected shape (paper): all schemes improve with iterations; stochastic
//! rounding best overall; 50/50 collapses at 4-bit; deterministic
//! saturates after a few iterations; at 6/7/8-bit all converge.

use anyhow::Result;

use crate::config::Settings;
use crate::ising::Formulation;
use crate::quant::{Precision, Rounding};
use crate::refine::{refine, RefineConfig};
use crate::solvers::random::RandomBaseline;
use crate::util::stats::mean;

use super::common::{exp_rng, load_problems, make_solver};
use super::{Report, Scale};

/// Regenerate Fig. 2/3 panels for `set_name` at `scale`.
pub fn run(scale: Scale, settings: &Settings, set_name: &str) -> Result<Vec<Report>> {
    let docs = scale.docs(20);
    let runs = scale.runs(10);
    let problems = load_problems(set_name, docs, settings)?;
    let max_iter = *scale.iteration_grid().last().unwrap();
    let grid = scale.iteration_grid();
    let precisions = match scale {
        Scale::Quick => vec![Precision::Fixed(4), Precision::CobiInt],
        Scale::Full => vec![
            Precision::Fixed(4),
            Precision::Fixed(5),
            Precision::Fixed(6),
            Precision::CobiInt,
        ],
    };

    let fig = if set_name == "bench_10" { "Fig 3" } else { "Fig 2" };
    let mut reports = Vec::new();

    for &precision in &precisions {
        let mut report = Report::new(
            format!("{fig} — normalized objective vs iterations ({set_name}, {precision})"),
            &["scheme", "iterations", "mean normalized objective"],
        );
        report.note(format!("{docs} documents x {runs} runs, Tabu as solver"));

        for rounding in [
            Rounding::Deterministic,
            Rounding::Stoch5050,
            Rounding::Stochastic,
        ] {
            // collect best-so-far curves per (doc, run)
            let mut curves: Vec<Vec<f64>> = Vec::new();
            for (d, bp) in problems.iter().enumerate() {
                for run_idx in 0..runs {
                    let cfg = RefineConfig {
                        formulation: Formulation::Improved,
                        precision,
                        rounding,
                        iterations: max_iter,
                    };
                    let mut rng = exp_rng("fig23", run_idx, d);
                    let mut solver = make_solver(
                        "tabu",
                        (run_idx * 1000 + d) as u64 ^ 0xF16,
                        settings,
                    );
                    let trace = refine(&bp.problem, &cfg, solver.as_mut(), &mut rng)?;
                    curves.push(
                        trace
                            .best_so_far
                            .iter()
                            .map(|&o| bp.bounds.normalize(o))
                            .collect(),
                    );
                }
            }
            for &it in &grid {
                let vals: Vec<f64> = curves.iter().map(|c| c[it - 1]).collect();
                report.row(vec![
                    rounding.to_string(),
                    it.to_string(),
                    format!("{:.4}", mean(&vals)),
                ]);
            }
        }

        // random baseline (no Ising, iteration = one random M-subset)
        for &it in &grid {
            let mut vals = Vec::new();
            for (d, bp) in problems.iter().enumerate() {
                for run_idx in 0..runs {
                    let mut rb =
                        RandomBaseline::seeded((run_idx * 7919 + d) as u64 ^ 0xBA5E);
                    let best = rb.best_of(&bp.problem, it);
                    vals.push(bp.bounds.normalize(best.objective));
                }
            }
            report.row(vec![
                "random".into(),
                it.to_string(),
                format!("{:.4}", mean(&vals)),
            ]);
        }
        reports.push(report);
    }
    Ok(reports)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn col(report: &Report, scheme: &str, it: usize) -> f64 {
        report
            .rows
            .iter()
            .find(|r| r[0] == scheme && r[1] == it.to_string())
            .unwrap()[2]
            .parse()
            .unwrap()
    }

    #[test]
    fn quick_run_shows_iteration_gains_and_beats_random() {
        let settings = Settings::default();
        let reports = run(Scale::Quick, &settings, "bench_10").unwrap();
        // int14 report (second entry)
        let r = &reports[1];
        let s2 = col(r, "stochastic", 2);
        let s20 = col(r, "stochastic", 20);
        assert!(s20 >= s2 - 1e-9, "iterations must not hurt: {s2} -> {s20}");
        let rnd20 = col(r, "random", 20);
        assert!(
            s20 >= rnd20 - 0.05,
            "stochastic {s20} should at least match random {rnd20}"
        );
        // deterministic saturates: its 2-iter and 20-iter means are close
        let d2 = col(r, "deterministic", 2);
        let d20 = col(r, "deterministic", 20);
        assert!(d20 - d2 < 0.2, "deterministic should saturate: {d2} -> {d20}");
    }
}
