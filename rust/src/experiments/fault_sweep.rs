//! Fault-sweep — summary quality vs hardware fault rate, with and
//! without the resilience layer (`experiment fault-sweep`).
//!
//! Runs the bench_10 fixture through a COBI device carrying the
//! `resilience::fault` model at increasing stuck/drift rates, crossed
//! with replication 1 (raw faulty device) and replication 3 (voting +
//! spin-repair). The output table is the quality-vs-fault-rate curve the
//! resilience subsystem exists to flatten: quality decays with fault
//! rate at replication 1 and is held near the clean baseline by voting.

use anyhow::Result;

use crate::config::Settings;
use crate::resilience::ResilienceShared;
use crate::sched::{doc_seed, summarize_sequential};

use super::common::load_problems;
use super::{Report, Scale};

/// One sweep point's configuration.
fn sweep_settings(base: &Settings, stuck: f32, replication: usize, iterations: usize) -> Settings {
    let mut s = base.clone();
    s.pipeline.solver = "cobi".into();
    s.pipeline.iterations = iterations;
    if stuck > 0.0 {
        s.resilience.fault.enabled = true;
        s.resilience.fault.stuck_rate = stuck;
        s.resilience.fault.drift_rate = stuck * 0.4;
        s.resilience.fault.burst_rate = stuck;
    }
    if replication > 1 {
        s.resilience.enabled = true;
        s.resilience.replication = replication;
    }
    s
}

/// Regenerate the quality-vs-fault-rate table at `scale`.
pub fn run(scale: Scale, settings: &Settings) -> Result<Vec<Report>> {
    let set = crate::corpus::benchmark_set("bench_10")?;
    let docs = scale.docs(set.documents.len());
    let iterations = match scale {
        Scale::Quick => 3,
        Scale::Full => settings.pipeline.iterations.max(10),
    };
    let rates: Vec<f32> = match scale {
        Scale::Quick => vec![0.0, 0.02, 0.05],
        Scale::Full => vec![0.0, 0.01, 0.02, 0.05, 0.10],
    };
    let problems = load_problems("bench_10", docs, settings)?;

    let mut report = Report::new(
        "Fault sweep — quality vs stuck-oscillator rate (bench_10, COBI-native)",
        &[
            "stuck rate",
            "replication",
            "mean norm objective",
            "Δ vs clean",
            "disagreements",
            "repairs",
            "escalations",
            "replica solves",
        ],
    );
    report.note(format!(
        "{docs} documents x {iterations} refinement iterations; drift rate = 0.4 x stuck \
         rate, burst rate = stuck rate; replication 3 = energy-verified voting + greedy \
         spin-repair (DESIGN.md §8)"
    ));

    let mut clean_mean: Option<f64> = None;
    for &rate in &rates {
        let replications: &[usize] = if rate == 0.0 { &[1] } else { &[1, 3] };
        for &replication in replications {
            let s = sweep_settings(settings, rate, replication, iterations);
            let shared = ResilienceShared::new();
            let mut solver = crate::sched::pool::build_solver(
                "cobi",
                &s,
                0,
                None,
                None,
                Some(&shared),
                None,
                None,
            )?;
            let mut total = 0.0f64;
            for (bp, doc) in problems.iter().zip(set.documents.iter()) {
                let mut cfg = s.pipeline.clone();
                cfg.summary_len = set.summary_len;
                cfg.seed = doc_seed(cfg.seed, &doc.id);
                let summary = summarize_sequential(doc, &cfg, solver.as_mut())?;
                total += bp.bounds.normalize(summary.objective);
            }
            let mean = total / problems.len() as f64;
            if clean_mean.is_none() {
                clean_mean = Some(mean);
            }
            let m = shared.snapshot();
            report.row(vec![
                format!("{:.0}%", rate * 100.0),
                replication.to_string(),
                format!("{mean:.4}"),
                format!("{:+.4}", mean - clean_mean.unwrap()),
                m.vote_disagreements.to_string(),
                m.repairs.to_string(),
                m.escalations.to_string(),
                m.replica_solves.to_string(),
            ]);
        }
    }
    Ok(vec![report])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_sweep_shows_voting_holding_quality() {
        let settings = Settings::default();
        let reports = run(Scale::Quick, &settings).unwrap();
        let r = &reports[0];
        // 1 clean row + 2 fault rates x 2 replications
        assert_eq!(r.rows.len(), 5);
        let mean_of = |row: &Vec<String>| -> f64 { row[2].parse().unwrap() };
        let find = |rate: &str, repl: &str| -> f64 {
            mean_of(
                r.rows
                    .iter()
                    .find(|row| row[0] == rate && row[1] == repl)
                    .unwrap(),
            )
        };
        let clean = find("0%", "1");
        assert!(clean > 0.5, "clean bench_10 quality {clean} implausibly low");
        // at 5% faults, replicated voting must not trail the raw faulty
        // device: per-instance it votes over a candidate set that
        // includes the raw result (replica 0) and only repairs downward
        // in energy; the FP objective after selection repair can shift a
        // hair, hence the small tolerance
        let raw = find("5%", "1");
        let voted = find("5%", "3");
        assert!(
            voted >= raw - 0.02,
            "voting {voted} lost to the raw faulty device {raw}"
        );
        // and must hold close to the clean baseline
        assert!(
            voted >= clean - 0.05,
            "voting {voted} fell more than 0.05 below clean {clean}"
        );
    }
}
