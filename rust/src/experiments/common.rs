//! Shared machinery for the experiment drivers: benchmark problems with
//! cached exact bounds, solver construction, success-iteration extraction.

use anyhow::Result;

use crate::config::Settings;
use crate::corpus::{benchmark_set, BenchmarkSet};
use crate::embed::{Embedder, HashEmbedder};
use crate::ising::{exact_bounds, EsProblem, ObjectiveBounds};
use crate::solvers::IsingSolver;
use crate::util::rng::Pcg32;

/// A benchmark document turned into an ES problem + exact bounds.
pub struct BenchProblem {
    /// Document id within the benchmark set.
    pub doc_id: String,
    /// Full-document ES problem (mu, beta, lambda, M).
    pub problem: EsProblem,
    /// Exact objective bounds for normalizing solver scores.
    pub bounds: ObjectiveBounds,
}

/// Load `docs` documents of a benchmark set as ES problems with exact
/// Eq. 13 bounds (the expensive B&B runs once per document here).
pub fn load_problems(set_name: &str, docs: usize, settings: &Settings) -> Result<Vec<BenchProblem>> {
    let set: BenchmarkSet = benchmark_set(set_name)?;
    let m = set.summary_len;
    let mut embedder = HashEmbedder::new();
    let mut out = Vec::new();
    for doc in set.documents.iter().take(docs) {
        let scores = embedder.scores(&doc.sentences)?;
        let problem = EsProblem {
            mu: scores.mu,
            beta: scores.beta,
            lambda: settings.pipeline.lambda,
            m,
        };
        let bounds = exact_bounds(&problem);
        out.push(BenchProblem {
            doc_id: doc.id.clone(),
            problem,
            bounds,
        });
    }
    Ok(out)
}

/// Fresh solver by name with a derived seed (experiments never share
/// solver RNG state across runs, so every (run, benchmark) replays).
pub fn make_solver(name: &str, seed: u64, settings: &Settings) -> Box<dyn IsingSolver> {
    match name {
        "tabu" => Box::new(crate::solvers::tabu::TabuSolver::seeded(seed)),
        "sa" => Box::new(crate::solvers::sa::SaSolver::seeded(seed)),
        "snowball" => Box::new(crate::solvers::snowball::SnowballSolver::new(
            seed,
            settings.solvers.snowball.solver_config(),
        )),
        "cobi" => Box::new(crate::cobi::CobiDevice::native(
            settings.cobi.clone(),
            seed,
        )),
        other => panic!("unknown ising solver '{other}'"),
    }
}

/// First iteration index (1-based) whose best-so-far normalized objective
/// reaches `threshold`; None if never.
pub fn first_success(best_so_far_norm: &[f64], threshold: f64) -> Option<usize> {
    best_so_far_norm
        .iter()
        .position(|&v| v >= threshold)
        .map(|i| i + 1)
}

/// Deterministic per-(experiment, run, doc) RNG.
pub fn exp_rng(exp: &str, run: usize, doc: usize) -> Pcg32 {
    let h = crate::text::tokenize::fnv1a(exp.as_bytes());
    Pcg32::new(
        h ^ (run as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        (doc as u64) << 1 | 1,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_success_basics() {
        assert_eq!(first_success(&[0.2, 0.5, 0.95, 0.95], 0.9), Some(3));
        assert_eq!(first_success(&[0.95], 0.9), Some(1));
        assert_eq!(first_success(&[0.1, 0.2], 0.9), None);
    }

    #[test]
    fn load_problems_shapes_and_bounds() {
        let s = Settings::default();
        let ps = load_problems("bench_10", 3, &s).unwrap();
        assert_eq!(ps.len(), 3);
        for p in &ps {
            assert_eq!(p.problem.n(), 10);
            assert_eq!(p.problem.m, 3);
            assert!(p.bounds.max > p.bounds.min);
        }
    }

    #[test]
    fn exp_rng_streams_differ() {
        let a = exp_rng("fig1", 0, 0).next_u32();
        let b = exp_rng("fig1", 0, 1).next_u32();
        let c = exp_rng("fig1", 1, 0).next_u32();
        let a2 = exp_rng("fig1", 0, 0).next_u32();
        assert_eq!(a, a2);
        assert_ne!(a, b);
        assert_ne!(a, c);
    }
}
