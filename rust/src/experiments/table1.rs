//! Table I — projected COBI runtime and energy at target normalized
//! objectives 0.80–0.92 (20-sentence benchmarks).
//!
//! From the empirical iteration→objective curve (decomposed workflow,
//! stochastic rounding), find the mean iteration count reaching each
//! target, then
//!     runtime = iters x (solve_time + eval_time)
//!     energy  = iters x (solve_time x P_COBI + eval_time x P_CPU).
//!
//! Note: the paper's Table I energy column is internally inconsistent
//! (0.390 J at 1.62 ms then 0.188 J at 7.85 ms); we report the consistent
//! Eq. 16 projection in millijoules and flag the discrepancy.

use anyhow::Result;

use crate::config::Settings;
use crate::decompose::{decompose, stage_count, DecomposeParams};
use crate::ising::Formulation;
use crate::metrics::tts::TimingModel;
use crate::quant::{Precision, Rounding};
use crate::refine::{refine, RefineConfig};
use crate::util::stats::mean;

use super::common::{exp_rng, load_problems, make_solver};
use super::{Report, Scale};

/// Regenerate the table at `scale` under `settings`.
pub fn run(scale: Scale, settings: &Settings) -> Result<Vec<Report>> {
    let docs = scale.docs(20);
    let runs = scale.runs(match scale {
        Scale::Quick => 2,
        Scale::Full => 10,
    });
    let r_max = match scale {
        Scale::Quick => 8,
        Scale::Full => 15,
    };
    let problems = load_problems("cnn_dm_20", docs, settings)?;
    let params = DecomposeParams::paper_default();
    let stages = stage_count(problems[0].problem.n(), &params);

    // per (doc, run): best-so-far normalized objective vs per-stage budget
    let mut curves: Vec<Vec<f64>> = Vec::new();
    for (d, bp) in problems.iter().enumerate() {
        for run_idx in 0..runs {
            let mut best = f64::NEG_INFINITY;
            let mut curve = Vec::with_capacity(r_max);
            for r in 1..=r_max {
                let cfg = RefineConfig {
                    formulation: Formulation::Improved,
                    precision: Precision::CobiInt,
                    rounding: Rounding::Stochastic,
                    iterations: r,
                };
                let mut rng = exp_rng("table1", run_idx * 100 + r, d);
                let mut solver = make_solver(
                    "cobi",
                    (run_idx * 1000 + d * 17 + r) as u64,
                    settings,
                );
                let p = &bp.problem;
                let result = decompose(p.n(), &params, |window, target| {
                    let sub = super::fig5::sub_problem(p, window, target);
                    Ok(refine(&sub, &cfg, solver.as_mut(), &mut rng)?.result.selected)
                })?;
                best = best.max(bp.bounds.normalize(p.objective(&result.selected)));
                curve.push(best);
            }
            curves.push(curve);
        }
    }

    let model = TimingModel::cobi(
        &settings.timing,
        settings.cobi.solve_time_s,
        settings.cobi.power_w,
    );
    let targets = [0.80, 0.85, 0.90, 0.91, 0.92];

    let mut report = Report::new(
        "Table I — projected COBI runtime/energy vs normalized objective (20-sent)",
        &[
            "normalized objective",
            "mean iterations",
            "runtime (ms)",
            "energy (mJ)",
        ],
    );
    report.note(format!(
        "{docs} docs x {runs} runs; iterations counted as total Ising solves \
         (stage multiples of {stages}); censored runs counted at the budget cap"
    ));
    report.note(
        "paper's Table I energy column is internally inconsistent; \
         values here follow Eq. 16 exactly",
    );

    for &target in &targets {
        let iters: Vec<f64> = curves
            .iter()
            .map(|c| {
                c.iter()
                    .position(|&v| v >= target)
                    .map(|i| ((i + 1) * stages) as f64)
                    .unwrap_or(((r_max + 1) * stages) as f64)
            })
            .collect();
        let mean_iters = mean(&iters);
        report.row(vec![
            format!("{target:.2}"),
            format!("{mean_iters:.2}"),
            format!("{:.3}", mean_iters * model.iter_time_s() * 1e3),
            format!("{:.4}", mean_iters * model.iter_energy_j() * 1e3),
        ]);
    }
    Ok(vec![report])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_run_monotone_costs() {
        let settings = Settings::default();
        let reports = run(Scale::Quick, &settings).unwrap();
        let r = &reports[0];
        assert_eq!(r.rows.len(), 5);
        // higher targets need >= iterations -> runtime non-decreasing
        let runtimes: Vec<f64> = r.rows.iter().map(|row| row[2].parse().unwrap()).collect();
        for w in runtimes.windows(2) {
            assert!(w[1] >= w[0] - 1e-9, "{runtimes:?}");
        }
        // runtime scale: single-digit milliseconds region (paper: 1.6-11.7)
        assert!(runtimes[0] > 0.1 && runtimes[0] < 50.0, "{runtimes:?}");
    }
}
