//! Fig. 5 — decomposition vs direct solve across precisions
//! (20-sentence benchmarks, P=20, Q=10, M=6, Tabu-as-COBI, 100 reps).
//!
//! Expected shape (paper): decomposition's boxplot dominates the direct
//! formulation at every precision; at int14 the median improves 0.75 →
//! 0.83.

use anyhow::Result;

use crate::config::Settings;
use crate::decompose::{decompose, DecomposeParams};
use crate::ising::{EsProblem, Formulation};
use crate::quant::Precision;
use crate::refine::{refine, RefineConfig};
use crate::util::stats::BoxStats;

use super::common::{exp_rng, load_problems, make_solver};
use super::{Report, Scale};

/// Regenerate this figure at `scale` under `settings`.
pub fn run(scale: Scale, settings: &Settings) -> Result<Vec<Report>> {
    let docs = scale.docs(20);
    let reps = scale.runs(match scale {
        Scale::Quick => 3,
        Scale::Full => 100,
    });
    let problems = load_problems("cnn_dm_20", docs, settings)?;
    let precisions = match scale {
        Scale::Quick => vec![Precision::Fixed(4), Precision::CobiInt],
        Scale::Full => vec![
            Precision::Fixed(4),
            Precision::Fixed(5),
            Precision::Fixed(6),
            Precision::Fixed(7),
            Precision::Fixed(8),
            Precision::CobiInt,
        ],
    };
    let params = DecomposeParams::paper_default();

    let mut report = Report::new(
        "Fig 5 — decomposition vs direct across precisions (20-sent, P=20 Q=10 M=6)",
        &["precision", "formulation", "workflow", "stats"],
    );
    report.note(format!(
        "{docs} documents x {reps} repetitions; Tabu as COBI simulation; \
         single-iteration refinement (stochastic rounding), per paper §IV-B"
    ));
    report.note(
        "both formulations shown: with the bias term the direct solve is \
         already robust (decomposition ties); the paper's decomposition \
         advantage appears on the imbalanced ORIGINAL formulation",
    );

    for &precision in &precisions {
        for formulation in [Formulation::Original, Formulation::Improved] {
        for direct in [false, true] {
            let mut values = Vec::new();
            for (d, bp) in problems.iter().enumerate() {
                for rep in 0..reps {
                    let cfg = RefineConfig {
                        formulation,
                        precision,
                        rounding: settings.pipeline.rounding,
                        iterations: 1,
                    };
                    let mut rng = exp_rng("fig5", rep, d);
                    let mut solver =
                        make_solver("tabu", (rep * 100 + d) as u64 ^ 0xF15, settings);
                    let selected = if direct {
                        refine(&bp.problem, &cfg, solver.as_mut(), &mut rng)?
                            .result
                            .selected
                    } else {
                        let p = &bp.problem;
                        decompose(p.n(), &params, |window, target| {
                            let sub = sub_problem(p, window, target);
                            Ok(refine(&sub, &cfg, solver.as_mut(), &mut rng)?
                                .result
                                .selected)
                        })?
                        .selected
                    };
                    values.push(bp.bounds.normalize(bp.problem.objective(&selected)));
                }
            }
            report.row(vec![
                precision.to_string(),
                format!("{formulation:?}"),
                if direct { "direct" } else { "decomposed" }.into(),
                BoxStats::compute(&values).row(),
            ]);
        }
        }
    }
    Ok(vec![report])
}

/// Restrict an EsProblem to a window of sentence indices.
pub fn sub_problem(p: &EsProblem, window: &[usize], target: usize) -> EsProblem {
    let n = p.n();
    let k = window.len();
    let mut mu = Vec::with_capacity(k);
    let mut beta = vec![0.0f32; k * k];
    for (a, &i) in window.iter().enumerate() {
        mu.push(p.mu[i]);
        for (b, &j) in window.iter().enumerate() {
            if a != b {
                beta[a * k + b] = p.beta[i * n + j];
            }
        }
    }
    EsProblem {
        mu,
        beta,
        lambda: p.lambda,
        m: target,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sub_problem_preserves_scores() {
        let p = EsProblem {
            mu: vec![0.1, 0.2, 0.3, 0.4],
            beta: (0..16).map(|i| i as f32 * 0.01).collect(),
            lambda: 0.5,
            m: 2,
        };
        let s = sub_problem(&p, &[1, 3], 1);
        assert_eq!(s.mu, vec![0.2, 0.4]);
        assert_eq!(s.m, 1);
        assert_eq!(s.beta[0 * 2 + 1], p.beta[1 * 4 + 3]);
        assert_eq!(s.beta[0], 0.0);
    }

    #[test]
    fn quick_run_decomposition_competitive() {
        let settings = Settings::default();
        let reports = run(Scale::Quick, &settings).unwrap();
        let r = &reports[0];
        assert_eq!(r.rows.len(), 8); // 2 precisions x 2 formulations x 2 workflows
        let median_of = |row: &[String]| -> f64 {
            row[3]
                .split("med=")
                .nth(1)
                .unwrap()
                .split_whitespace()
                .next()
                .unwrap()
                .parse()
                .unwrap()
        };
        let find = |prec: &str, form: &str, wf: &str| -> f64 {
            median_of(
                r.rows
                    .iter()
                    .find(|row| row[0] == prec && row[1] == form && row[2] == wf)
                    .unwrap(),
            )
        };
        // improved formulation at int14: decomposition competitive
        let dec = find("int14", "Improved", "decomposed");
        let dir = find("int14", "Improved", "direct");
        assert!(dec >= dir - 0.1, "decomposed {dec} vs direct {dir}");
    }
}
