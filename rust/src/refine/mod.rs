//! Iterative refinement with (stochastic) rounding (paper §IV-A).
//!
//! Each iteration quantizes the FP Ising formulation with the configured
//! rounding scheme, solves the quantized instance (COBI / Tabu / SA), maps
//! the spins back to a selection, REPAIRS it to cardinality M, and scores
//! it under the original floating-point Eq. 3 objective. After i
//! iterations the best candidate wins.
//!
//! Deterministic rounding re-solves the SAME Hamiltonian every iteration
//! (only solver randomness explores); stochastic rounding also re-samples
//! the Hamiltonian — the diversity the paper exploits to compensate for
//! precision loss.

use anyhow::Result;

use crate::ising::{formulate, selected_indices, EsProblem, Formulation};
use crate::quant::{quantize, Precision, Rounding};
use crate::solvers::{IsingSolver, SelectionResult};
use crate::util::rng::Pcg32;

/// Refinement configuration for one subproblem solve.
#[derive(Debug, Clone)]
pub struct RefineConfig {
    pub formulation: Formulation,
    pub precision: Precision,
    pub rounding: Rounding,
    /// Number of quantize→solve→evaluate iterations.
    pub iterations: usize,
}

impl Default for RefineConfig {
    fn default() -> Self {
        Self {
            formulation: Formulation::Improved,
            precision: Precision::CobiInt,
            rounding: Rounding::Stochastic,
            iterations: 10,
        }
    }
}

/// Repair a selection to exactly M elements under the FP objective:
/// greedily drop the element whose removal loses least / add the element
/// whose addition gains most. Needed because (a) the improved formulation
/// softens the cardinality constraint and (b) quantized instances may
/// ground-state off-cardinality.
pub fn repair_selection(p: &EsProblem, mut selected: Vec<usize>) -> Vec<usize> {
    selected.sort_unstable();
    selected.dedup();
    while selected.len() > p.m {
        // drop argmax of objective-after-removal
        let mut best: Option<(usize, f64)> = None;
        for k in 0..selected.len() {
            let mut cand = selected.clone();
            cand.remove(k);
            let obj = p.objective(&cand);
            if best.map_or(true, |(_, b)| obj > b) {
                best = Some((k, obj));
            }
        }
        selected.remove(best.unwrap().0);
    }
    while selected.len() < p.m {
        let mut best: Option<(usize, f64)> = None;
        for i in 0..p.n() {
            if selected.binary_search(&i).is_ok() {
                continue;
            }
            let mut cand = selected.clone();
            cand.push(i);
            let obj = p.objective(&cand);
            if best.map_or(true, |(_, b)| obj > b) {
                best = Some((i, obj));
            }
        }
        selected.push(best.unwrap().0);
        selected.sort_unstable();
    }
    selected
}

/// Trace of one refinement run (per-iteration objectives, for the Fig 2/3
/// iteration curves).
#[derive(Debug, Clone)]
pub struct RefineTrace {
    /// FP objective of the repaired candidate at each iteration.
    pub objectives: Vec<f64>,
    /// Best-so-far objective after each iteration (prefix max).
    pub best_so_far: Vec<f64>,
    pub result: SelectionResult,
}

/// Quantize the `cfg.iterations` candidate Hamiltonians for one
/// subproblem (formulate once, re-round per iteration). This is the
/// rng-consuming half of [`refine`], split out so schedulers
/// (`sched::summarize_with_pool`) can draw instances in deterministic
/// document order while the solves happen elsewhere — the RNG draw order
/// is identical to the inline sequential loop.
pub fn prepare_instances(
    p: &EsProblem,
    cfg: &RefineConfig,
    rng: &mut Pcg32,
) -> Vec<crate::ising::Ising> {
    let es = formulate(p, cfg.formulation);
    (0..cfg.iterations.max(1))
        .map(|_| quantize(&es.ising, cfg.precision, cfg.rounding, rng))
        .collect()
}

/// The scoring half of [`refine`]: map each solved spin configuration back
/// to a repaired selection, score under the FP objective, keep the best.
pub fn select_best(p: &EsProblem, solved: &[crate::solvers::SolveResult]) -> RefineTrace {
    let mut objectives = Vec::with_capacity(solved.len());
    let mut best_so_far = Vec::with_capacity(solved.len());
    let mut best: Option<SelectionResult> = None;
    for s in solved {
        let raw = selected_indices(&s.spins);
        let selected = repair_selection(p, raw);
        let objective = p.objective(&selected);
        objectives.push(objective);
        if best.as_ref().map_or(true, |b| objective > b.objective) {
            best = Some(SelectionResult {
                selected,
                objective,
            });
        }
        best_so_far.push(best.as_ref().unwrap().objective);
    }
    RefineTrace {
        objectives,
        best_so_far,
        result: best.expect("select_best needs at least one solve"),
    }
}

/// Run iterative refinement of `p` with `solver` (which solves quantized
/// Ising instances). `rng` drives the rounding draws only — solver
/// randomness lives in the solver's own seeded RNG.
pub fn refine(
    p: &EsProblem,
    cfg: &RefineConfig,
    solver: &mut dyn IsingSolver,
    rng: &mut Pcg32,
) -> Result<RefineTrace> {
    // quantize all iterations up front (RNG draw order identical to the
    // sequential loop), then solve through the batch path — devices with
    // a batched artifact dispatch once per ANNEAL_BATCH instances.
    let instances = prepare_instances(p, cfg, rng);
    let refs: Vec<&crate::ising::Ising> = instances.iter().collect();
    let solved_all = solver.solve_batch(&refs);
    Ok(select_best(p, &solved_all))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::tabu::TabuSolver;
    use crate::util::proptest::check;

    fn random_es(rng: &mut Pcg32, n: usize, m: usize) -> EsProblem {
        let mu: Vec<f32> = (0..n).map(|_| rng.range_f32(0.3, 0.95)).collect();
        let mut beta = vec![0.0f32; n * n];
        for i in 0..n {
            for j in (i + 1)..n {
                let b = rng.range_f32(0.2, 0.9);
                beta[i * n + j] = b;
                beta[j * n + i] = b;
            }
        }
        EsProblem { mu, beta, lambda: 0.6, m }
    }

    #[test]
    fn repair_properties() {
        check("repair yields exactly M valid indices", 31, 64, |rng| {
            let n = 6 + rng.below(14) as usize;
            let m = 1 + rng.below(5.min(n as u32 - 1)) as usize;
            let p = random_es(rng, n, m);
            // random starting selection of random size
            let k = rng.below(n as u32 + 1) as usize;
            let start = rng.sample_indices(n, k);
            let fixed = repair_selection(&p, start);
            crate::prop_assert!(fixed.len() == m, "len {} != m {}", fixed.len(), m);
            let mut d = fixed.clone();
            d.dedup();
            crate::prop_assert!(d.len() == m, "duplicates");
            crate::prop_assert!(fixed.iter().all(|&i| i < n), "range");
            Ok(())
        });
    }

    #[test]
    fn repair_keeps_feasible_selection_unchanged_count() {
        let mut rng = Pcg32::seeded(1);
        let p = random_es(&mut rng, 10, 4);
        let sel = vec![1, 3, 5, 7];
        assert_eq!(repair_selection(&p, sel.clone()), sel);
    }

    #[test]
    fn best_so_far_is_monotone() {
        let mut rng = Pcg32::seeded(2);
        let p = random_es(&mut rng, 14, 5);
        let mut solver = TabuSolver::seeded(3);
        let cfg = RefineConfig {
            iterations: 12,
            ..Default::default()
        };
        let trace = refine(&p, &cfg, &mut solver, &mut rng).unwrap();
        assert_eq!(trace.objectives.len(), 12);
        for w in trace.best_so_far.windows(2) {
            assert!(w[1] >= w[0] - 1e-12);
        }
        assert_eq!(
            trace.result.objective,
            *trace.best_so_far.last().unwrap()
        );
    }

    #[test]
    fn more_iterations_never_hurt() {
        let mut rng1 = Pcg32::seeded(4);
        let mut rng2 = Pcg32::seeded(4);
        let p = {
            let mut r = Pcg32::seeded(5);
            random_es(&mut r, 12, 4)
        };
        let cfg1 = RefineConfig { iterations: 2, ..Default::default() };
        let cfg20 = RefineConfig { iterations: 20, ..Default::default() };
        let a = refine(&p, &cfg1, &mut TabuSolver::seeded(6), &mut rng1).unwrap();
        let b = refine(&p, &cfg20, &mut TabuSolver::seeded(6), &mut rng2).unwrap();
        assert!(b.result.objective >= a.result.objective - 1e-12);
    }

    #[test]
    fn stochastic_refinement_recovers_fp_quality_on_quantized_instance() {
        // end-to-end §IV-A claim in miniature: at int14, iterated
        // stochastic rounding should reach the exact optimum on a small
        // instance even though single deterministic solves may miss it
        let mut rng = Pcg32::seeded(7);
        let p = random_es(&mut rng, 12, 4);
        let exact = crate::solvers::exact::solve_max(&p);
        let cfg = RefineConfig {
            formulation: Formulation::Improved,
            precision: Precision::CobiInt,
            rounding: Rounding::Stochastic,
            iterations: 30,
        };
        let mut solver = TabuSolver::seeded(8);
        let trace = refine(&p, &cfg, &mut solver, &mut rng).unwrap();
        let gap = (exact.objective - trace.result.objective) / exact.objective.abs();
        assert!(gap < 0.02, "gap {gap}: {} vs {}", trace.result.objective, exact.objective);
    }

    #[test]
    fn deterministic_rounding_produces_single_hamiltonian() {
        // with deterministic rounding + deterministic solver, every
        // iteration yields the identical objective
        let mut rng = Pcg32::seeded(9);
        let p = random_es(&mut rng, 10, 3);
        let cfg = RefineConfig {
            rounding: Rounding::Deterministic,
            iterations: 5,
            ..Default::default()
        };
        // fresh tabu each call would reuse its seed; instead use one
        // solver whose internal rng advances — objectives may differ only
        // through solver randomness. Use exhaustive-quality tabu so each
        // solve lands in the same ground state.
        let mut solver = TabuSolver::seeded(10);
        let trace = refine(&p, &cfg, &mut solver, &mut rng).unwrap();
        let first = trace.objectives[0];
        for &o in &trace.objectives {
            assert!((o - first).abs() < 1e-9, "{:?}", trace.objectives);
        }
    }
}
