//! Iterative refinement with (stochastic) rounding (paper §IV-A).
//!
//! Each iteration quantizes the FP Ising formulation with the configured
//! rounding scheme, solves the quantized instance (COBI / Tabu / SA), maps
//! the spins back to a selection, REPAIRS it to cardinality M, and scores
//! it under the original floating-point Eq. 3 objective. After i
//! iterations the best candidate wins.
//!
//! Deterministic rounding re-solves the SAME Hamiltonian every iteration
//! (only solver randomness explores); stochastic rounding also re-samples
//! the Hamiltonian — the diversity the paper exploits to compensate for
//! precision loss.
//!
//! ## The integer fast path
//!
//! For gridded precisions and kernel-capable solvers (Tabu, SA, greedy —
//! [`IsingSolver::quant_kernel`]), [`refine`] skips the `f32` instance
//! materialization entirely: each iteration quantizes straight into a
//! reusable [`QuantIsing`](crate::ising::QuantIsing)
//! ([`quantize_into`]), solves on the integer kernel into a reusable
//! spin buffer, and repairs/scores through caller-owned index buffers —
//! **zero heap allocation per iteration** in steady state (pinned by
//! `tests/alloc_audit.rs`). Results are bit-identical to the batched
//! `f32` path ([`refine_batched`]), pinned by tests below. Device-backed
//! solvers (COBI) keep the batched path — their amortization lives in
//! `solve_batch`, not in the coefficient domain.

use anyhow::Result;

use crate::ising::{formulate, selected_indices, EsProblem, Formulation, QuantIsing};
use crate::quant::{quantize, quantize_into, Precision, Rounding};
use crate::solvers::{IsingSolver, SelectionResult};
use crate::util::rng::Pcg32;

/// Refinement configuration for one subproblem solve.
#[derive(Debug, Clone)]
pub struct RefineConfig {
    /// Ising formulation variant (original / improved).
    pub formulation: Formulation,
    /// Quantization grid.
    pub precision: Precision,
    /// Rounding scheme (SIV-A).
    pub rounding: Rounding,
    /// Number of quantize→solve→evaluate iterations.
    pub iterations: usize,
}

impl Default for RefineConfig {
    fn default() -> Self {
        Self {
            formulation: Formulation::Improved,
            precision: Precision::CobiInt,
            rounding: Rounding::Stochastic,
            iterations: 10,
        }
    }
}

/// Repair a selection to exactly M elements under the FP objective:
/// greedily drop the element whose removal loses least / add the element
/// whose addition gains most. Needed because (a) the improved formulation
/// softens the cardinality constraint and (b) quantized instances may
/// ground-state off-cardinality.
pub fn repair_selection(p: &EsProblem, mut selected: Vec<usize>) -> Vec<usize> {
    let mut cand = Vec::new();
    repair_selection_in_place(p, &mut selected, &mut cand);
    selected
}

/// The buffer-reusing core of [`repair_selection`]: repairs `selected` in
/// place, using `cand` as candidate scratch. Candidate index sequences
/// (and hence every floating-point objective evaluation and its
/// tie-break) are identical to the allocating version — the hot path
/// reuses both buffers across refinement iterations, so steady state
/// allocates nothing here.
pub(crate) fn repair_selection_in_place(
    p: &EsProblem,
    selected: &mut Vec<usize>,
    cand: &mut Vec<usize>,
) {
    selected.sort_unstable();
    selected.dedup();
    while selected.len() > p.m {
        // drop argmax of objective-after-removal
        let mut best: Option<(usize, f64)> = None;
        for k in 0..selected.len() {
            cand.clear();
            cand.extend_from_slice(selected);
            cand.remove(k);
            let obj = p.objective(cand);
            if best.map_or(true, |(_, b)| obj > b) {
                best = Some((k, obj));
            }
        }
        selected.remove(best.unwrap().0);
    }
    while selected.len() < p.m {
        let mut best: Option<(usize, f64)> = None;
        for i in 0..p.n() {
            if selected.binary_search(&i).is_ok() {
                continue;
            }
            cand.clear();
            cand.extend_from_slice(selected);
            cand.push(i);
            let obj = p.objective(cand);
            if best.map_or(true, |(_, b)| obj > b) {
                best = Some((i, obj));
            }
        }
        selected.push(best.unwrap().0);
        selected.sort_unstable();
    }
}

/// Trace of one refinement run (per-iteration objectives, for the Fig 2/3
/// iteration curves).
#[derive(Debug, Clone)]
pub struct RefineTrace {
    /// FP objective of the repaired candidate at each iteration.
    pub objectives: Vec<f64>,
    /// Best-so-far objective after each iteration (prefix max).
    pub best_so_far: Vec<f64>,
    /// Best repaired selection across iterations.
    pub result: SelectionResult,
}

/// Quantize the `cfg.iterations` candidate Hamiltonians for one
/// subproblem (formulate once, re-round per iteration). This is the
/// rng-consuming half of [`refine`], split out so schedulers
/// (`sched::summarize_with_pool`) can draw instances in deterministic
/// document order while the solves happen elsewhere — the RNG draw order
/// is identical to the inline sequential loop.
pub fn prepare_instances(
    p: &EsProblem,
    cfg: &RefineConfig,
    rng: &mut Pcg32,
) -> Vec<crate::ising::Ising> {
    let es = formulate(p, cfg.formulation);
    (0..cfg.iterations.max(1))
        .map(|_| quantize(&es.ising, cfg.precision, cfg.rounding, rng))
        .collect()
}

/// The scoring half of [`refine`]: map each solved spin configuration back
/// to a repaired selection, score under the FP objective, keep the best.
pub fn select_best(p: &EsProblem, solved: &[crate::solvers::SolveResult]) -> RefineTrace {
    let mut objectives = Vec::with_capacity(solved.len());
    let mut best_so_far = Vec::with_capacity(solved.len());
    let mut best: Option<SelectionResult> = None;
    for s in solved {
        let raw = selected_indices(&s.spins);
        let selected = repair_selection(p, raw);
        let objective = p.objective(&selected);
        objectives.push(objective);
        if best.as_ref().map_or(true, |b| objective > b.objective) {
            best = Some(SelectionResult {
                selected,
                objective,
            });
        }
        best_so_far.push(best.as_ref().unwrap().objective);
    }
    RefineTrace {
        objectives,
        best_so_far,
        result: best.expect("select_best needs at least one solve"),
    }
}

/// Run iterative refinement of `p` with `solver` (which solves quantized
/// Ising instances). `rng` drives the rounding draws only — solver
/// randomness lives in the solver's own seeded RNG.
///
/// Routes to the integer fast path (see module docs) when the precision
/// has an integer grid and the solver exposes a
/// [`quant_kernel`](IsingSolver::quant_kernel); otherwise takes
/// [`refine_batched`]. The two produce bit-identical traces — the route
/// is a performance decision, never a semantic one.
pub fn refine(
    p: &EsProblem,
    cfg: &RefineConfig,
    solver: &mut dyn IsingSolver,
    rng: &mut Pcg32,
) -> Result<RefineTrace> {
    if cfg.precision.grid_max().is_some() && solver.quant_kernel().is_some() {
        return refine_integer(p, cfg, solver, rng);
    }
    refine_batched(p, cfg, solver, rng)
}

/// The `f32` batch path: quantize all iterations up front (RNG draw order
/// identical to the interleaved loop — rounding and solver randomness are
/// separate streams), then solve through `solve_batch`, so devices with a
/// batched artifact dispatch once per ANNEAL_BATCH instances. Public as
/// the pinned reference for the integer fast path (equivalence tests,
/// domain benches); [`refine`] is the entry point callers want.
pub fn refine_batched(
    p: &EsProblem,
    cfg: &RefineConfig,
    solver: &mut dyn IsingSolver,
    rng: &mut Pcg32,
) -> Result<RefineTrace> {
    let instances = prepare_instances(p, cfg, rng);
    let refs: Vec<&crate::ising::Ising> = instances.iter().collect();
    let solved_all = solver.solve_batch(&refs);
    Ok(select_best(p, &solved_all))
}

/// The integer fast path: quantize → solve → repair → score entirely
/// through reusable buffers (see module docs). Caller guarantees a
/// gridded precision and a kernel-capable solver.
fn refine_integer(
    p: &EsProblem,
    cfg: &RefineConfig,
    solver: &mut dyn IsingSolver,
    rng: &mut Pcg32,
) -> Result<RefineTrace> {
    let es = formulate(p, cfg.formulation);
    let iters = cfg.iterations.max(1);
    let n = p.n();
    // per-subproblem setup; every per-iteration step below reuses these
    // (capacities are upper bounds, so iterations never grow them)
    let mut quant = QuantIsing::new(0);
    let mut spins: Vec<i8> = Vec::with_capacity(n);
    let mut sel: Vec<usize> = Vec::with_capacity(n);
    let mut cand: Vec<usize> = Vec::with_capacity(n + 1);
    let mut best_sel: Vec<usize> = Vec::with_capacity(n);
    let mut objectives = Vec::with_capacity(iters);
    let mut best_so_far = Vec::with_capacity(iters);
    let mut best_obj = f64::NEG_INFINITY;
    let mut have_best = false;
    let kernel = solver
        .quant_kernel()
        .expect("refine_integer requires a kernel-capable solver");
    for _ in 0..iters {
        let gridded = quantize_into(&es.ising, cfg.precision, cfg.rounding, rng, &mut quant);
        debug_assert!(gridded, "refine_integer requires a gridded precision");
        kernel.solve_quant_into(&quant, &mut spins);
        sel.clear();
        sel.extend(
            spins
                .iter()
                .enumerate()
                .filter_map(|(i, &v)| (v > 0).then_some(i)),
        );
        repair_selection_in_place(p, &mut sel, &mut cand);
        let objective = p.objective(&sel);
        objectives.push(objective);
        if !have_best || objective > best_obj {
            have_best = true;
            best_obj = objective;
            best_sel.clone_from(&sel);
        }
        best_so_far.push(best_obj);
    }
    Ok(RefineTrace {
        objectives,
        best_so_far,
        result: SelectionResult {
            selected: best_sel,
            objective: best_obj,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::tabu::TabuSolver;
    use crate::util::proptest::check;

    fn random_es(rng: &mut Pcg32, n: usize, m: usize) -> EsProblem {
        let mu: Vec<f32> = (0..n).map(|_| rng.range_f32(0.3, 0.95)).collect();
        let mut beta = vec![0.0f32; n * n];
        for i in 0..n {
            for j in (i + 1)..n {
                let b = rng.range_f32(0.2, 0.9);
                beta[i * n + j] = b;
                beta[j * n + i] = b;
            }
        }
        EsProblem { mu, beta, lambda: 0.6, m }
    }

    #[test]
    fn repair_properties() {
        check("repair yields exactly M valid indices", 31, 64, |rng| {
            let n = 6 + rng.below(14) as usize;
            let m = 1 + rng.below(5.min(n as u32 - 1)) as usize;
            let p = random_es(rng, n, m);
            // random starting selection of random size
            let k = rng.below(n as u32 + 1) as usize;
            let start = rng.sample_indices(n, k);
            let fixed = repair_selection(&p, start);
            crate::prop_assert!(fixed.len() == m, "len {} != m {}", fixed.len(), m);
            let mut d = fixed.clone();
            d.dedup();
            crate::prop_assert!(d.len() == m, "duplicates");
            crate::prop_assert!(fixed.iter().all(|&i| i < n), "range");
            Ok(())
        });
    }

    #[test]
    fn repair_keeps_feasible_selection_unchanged_count() {
        let mut rng = Pcg32::seeded(1);
        let p = random_es(&mut rng, 10, 4);
        let sel = vec![1, 3, 5, 7];
        assert_eq!(repair_selection(&p, sel.clone()), sel);
    }

    #[test]
    fn repair_handles_empty_selection() {
        let mut rng = Pcg32::seeded(40);
        let p = random_es(&mut rng, 8, 3);
        let fixed = repair_selection(&p, vec![]);
        assert_eq!(fixed.len(), 3);
        assert!(fixed.windows(2).all(|w| w[0] < w[1]));
        assert!(fixed.iter().all(|&i| i < 8));
    }

    #[test]
    fn repair_handles_selection_longer_than_n() {
        // an off-cardinality solver answer can select every spin and
        // carry duplicates; repair must still land on exactly M
        let mut rng = Pcg32::seeded(41);
        let p = random_es(&mut rng, 6, 2);
        let over: Vec<usize> = (0..6).chain(0..6).chain(0..6).collect(); // len 18 > n
        let fixed = repair_selection(&p, over);
        assert_eq!(fixed.len(), 2);
        assert!(fixed.iter().all(|&i| i < 6));
    }

    #[test]
    fn repair_handles_m_zero() {
        let mut rng = Pcg32::seeded(42);
        let mut p = random_es(&mut rng, 7, 3);
        p.m = 0;
        assert!(repair_selection(&p, vec![2, 5]).is_empty());
        assert!(repair_selection(&p, vec![]).is_empty());
        assert!(repair_selection(&p, (0..7).collect()).is_empty());
    }

    #[test]
    fn repair_dedups_before_counting() {
        // duplicates collapse to one occurrence BEFORE the length check:
        // [4, 4, 4] is one unique index, so two more must be added (not
        // two dropped)
        let mut rng = Pcg32::seeded(43);
        let p = random_es(&mut rng, 9, 3);
        let fixed = repair_selection(&p, vec![4, 4, 4]);
        assert_eq!(fixed.len(), 3);
        assert!(fixed.contains(&4));
        let mut d = fixed.clone();
        d.dedup();
        assert_eq!(d.len(), 3, "duplicates survived repair");
    }

    #[test]
    fn in_place_repair_matches_allocating_repair() {
        // the hot path's buffer-reusing variant must replay the exact
        // candidate sequences (and hence FP tie-breaks) of the original
        let mut rng = Pcg32::seeded(44);
        for _ in 0..30 {
            let n = 5 + rng.below(10) as usize;
            let m = rng.below(n as u32) as usize;
            let mut p = random_es(&mut rng, n, 1);
            p.m = m;
            let k = rng.below(n as u32 + 1) as usize;
            let start = rng.sample_indices(n, k);
            let reference = repair_selection(&p, start.clone());
            let mut in_place = start;
            let mut cand = Vec::new();
            repair_selection_in_place(&p, &mut in_place, &mut cand);
            assert_eq!(in_place, reference);
        }
    }

    #[test]
    fn best_so_far_is_monotone() {
        let mut rng = Pcg32::seeded(2);
        let p = random_es(&mut rng, 14, 5);
        let mut solver = TabuSolver::seeded(3);
        let cfg = RefineConfig {
            iterations: 12,
            ..Default::default()
        };
        let trace = refine(&p, &cfg, &mut solver, &mut rng).unwrap();
        assert_eq!(trace.objectives.len(), 12);
        for w in trace.best_so_far.windows(2) {
            assert!(w[1] >= w[0] - 1e-12);
        }
        assert_eq!(
            trace.result.objective,
            *trace.best_so_far.last().unwrap()
        );
    }

    #[test]
    fn more_iterations_never_hurt() {
        let mut rng1 = Pcg32::seeded(4);
        let mut rng2 = Pcg32::seeded(4);
        let p = {
            let mut r = Pcg32::seeded(5);
            random_es(&mut r, 12, 4)
        };
        let cfg1 = RefineConfig { iterations: 2, ..Default::default() };
        let cfg20 = RefineConfig { iterations: 20, ..Default::default() };
        let a = refine(&p, &cfg1, &mut TabuSolver::seeded(6), &mut rng1).unwrap();
        let b = refine(&p, &cfg20, &mut TabuSolver::seeded(6), &mut rng2).unwrap();
        assert!(b.result.objective >= a.result.objective - 1e-12);
    }

    #[test]
    fn stochastic_refinement_recovers_fp_quality_on_quantized_instance() {
        // end-to-end §IV-A claim in miniature: at int14, iterated
        // stochastic rounding should reach the exact optimum on a small
        // instance even though single deterministic solves may miss it
        let mut rng = Pcg32::seeded(7);
        let p = random_es(&mut rng, 12, 4);
        let exact = crate::solvers::exact::solve_max(&p);
        let cfg = RefineConfig {
            formulation: Formulation::Improved,
            precision: Precision::CobiInt,
            rounding: Rounding::Stochastic,
            iterations: 30,
        };
        let mut solver = TabuSolver::seeded(8);
        let trace = refine(&p, &cfg, &mut solver, &mut rng).unwrap();
        let gap = (exact.objective - trace.result.objective) / exact.objective.abs();
        assert!(gap < 0.02, "gap {gap}: {} vs {}", trace.result.objective, exact.objective);
    }

    #[test]
    fn integer_fast_path_is_bit_identical_to_the_batched_path() {
        // acceptance pin: for every kernel-capable solver and rounding
        // scheme, `refine` (integer fast path) must reproduce
        // `refine_batched` (f32 instances through solve_batch) bit for
        // bit — per-iteration objectives AND the final selection
        use crate::solvers::greedy::GreedyDescent;
        use crate::solvers::sa::SaSolver;
        let p = {
            let mut r = Pcg32::seeded(50);
            random_es(&mut r, 14, 5)
        };
        for rounding in [
            Rounding::Deterministic,
            Rounding::Stoch5050,
            Rounding::Stochastic,
        ] {
            for precision in [Precision::CobiInt, Precision::Fixed(4)] {
                let cfg = RefineConfig {
                    formulation: Formulation::Improved,
                    precision,
                    rounding,
                    iterations: 8,
                };
                let runs: [(&str, Box<dyn Fn() -> Box<dyn IsingSolver>>); 3] = [
                    ("tabu", Box::new(|| Box::new(TabuSolver::seeded(7)) as Box<dyn IsingSolver>)),
                    ("sa", Box::new(|| Box::new(SaSolver::seeded(7)) as Box<dyn IsingSolver>)),
                    ("greedy", Box::new(|| Box::new(GreedyDescent::new()) as Box<dyn IsingSolver>)),
                ];
                for (name, make) in runs {
                    let mut rng_a = Pcg32::seeded(60);
                    let mut rng_b = Pcg32::seeded(60);
                    let mut solver_a = make();
                    let mut solver_b = make();
                    let fast = refine(&p, &cfg, solver_a.as_mut(), &mut rng_a).unwrap();
                    let batched =
                        refine_batched(&p, &cfg, solver_b.as_mut(), &mut rng_b).unwrap();
                    assert_eq!(
                        fast.result.selected, batched.result.selected,
                        "{name} {precision} {rounding}"
                    );
                    assert_eq!(
                        fast.result.objective.to_bits(),
                        batched.result.objective.to_bits(),
                        "{name} {precision} {rounding}"
                    );
                    let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                    assert_eq!(
                        bits(&fast.objectives),
                        bits(&batched.objectives),
                        "{name} {precision} {rounding} per-iteration objectives"
                    );
                    assert_eq!(bits(&fast.best_so_far), bits(&batched.best_so_far));
                }
            }
        }
    }

    #[test]
    fn fp_precision_keeps_the_batched_path() {
        // no integer grid exists for Precision::Fp: refine must fall back
        // and still work end to end
        let mut rng = Pcg32::seeded(51);
        let p = random_es(&mut rng, 10, 3);
        let cfg = RefineConfig {
            precision: Precision::Fp,
            iterations: 4,
            ..Default::default()
        };
        let trace = refine(&p, &cfg, &mut TabuSolver::seeded(9), &mut rng).unwrap();
        assert_eq!(trace.objectives.len(), 4);
        assert_eq!(trace.result.selected.len(), 3);
    }

    #[test]
    fn deterministic_rounding_produces_single_hamiltonian() {
        // with deterministic rounding + deterministic solver, every
        // iteration yields the identical objective
        let mut rng = Pcg32::seeded(9);
        let p = random_es(&mut rng, 10, 3);
        let cfg = RefineConfig {
            rounding: Rounding::Deterministic,
            iterations: 5,
            ..Default::default()
        };
        // fresh tabu each call would reuse its seed; instead use one
        // solver whose internal rng advances — objectives may differ only
        // through solver randomness. Use exhaustive-quality tabu so each
        // solve lands in the same ground state.
        let mut solver = TabuSolver::seeded(10);
        let trace = refine(&p, &cfg, &mut solver, &mut rng).unwrap();
        let first = trace.objectives[0];
        for &o in &trace.objectives {
            assert!((o - first).abs() < 1e-9, "{:?}", trace.objectives);
        }
    }
}
