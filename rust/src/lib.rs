//! cobi-es: extractive summarization on a (simulated) CMOS coupled-
//! oscillator Ising machine — a three-layer Rust + JAX + Pallas
//! reproduction of Zeng et al., "Extractive summarization on a CMOS Ising
//! machine" (2026). See DESIGN.md for the architecture and substitutions,
//! and docs/ARCHITECTURE.md for the end-to-end request walkthrough.
//!
//! Every public item in this crate is documented; the CI docs build
//! denies `missing_docs`, so new API surface must ship with rustdoc.

#![warn(missing_docs)]

pub mod cli;
pub mod cobi;
pub mod config;
pub mod corpus;
pub mod decompose;
pub mod embed;
pub mod experiments;
pub mod ising;
pub mod metrics;
pub mod obs;
pub mod pipeline;
pub mod portfolio;
pub mod quant;
pub mod refine;
pub mod resilience;
pub mod runtime;
pub mod sched;
pub mod service;
pub mod solvers;
pub mod text;
pub mod util;
pub mod workload;
