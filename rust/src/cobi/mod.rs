//! COBI device model: the behavioural simulation of the 48/59-node
//! all-to-all CMOS coupled-oscillator Ising chip [Lo+ 2023, Cılasun+ 2025].
//!
//! The device enforces the real chip's programming constraints (spin
//! count, integer coupling range), models its timing/energy (200 µs/solve
//! @ 25 mW by default), and solves via one of two backends:
//!
//!   * `native` — the pure-Rust oscillator integrator (fast, default for
//!     tests/benches);
//!   * `hlo`    — the AOT `anneal.hlo.txt` artifact through PJRT (the
//!     three-layer architecture's production path).
//!
//! Both backends implement identical dynamics; cross-backend agreement is
//! validated statistically in rust/tests/artifact_numerics.rs.

pub mod device;

pub use device::{
    CobiBackend, CobiDevice, CobiStats, SeededGroup, ANNEAL_BATCH, ANNEAL_STEPS, PADDED_SPINS,
};

/// Shared test fixtures (device + sched pool tests must agree on them).
#[cfg(test)]
pub(crate) mod testutil {
    use crate::ising::Ising;
    use crate::quant::{quantize, Precision, Rounding};
    use crate::util::rng::Pcg32;

    /// Seeded random spin glass, quantized into the COBI DAC range — the
    /// canonical programmable instance for device/pool determinism tests.
    pub(crate) fn quantized_glass(seed: u64, n: usize) -> Ising {
        let mut rng = Pcg32::seeded(seed);
        let mut ising = Ising::new(n);
        for i in 0..n {
            ising.h[i] = rng.range_f32(-3.0, 3.0);
            for j in (i + 1)..n {
                ising.set_pair(i, j, rng.range_f32(-1.0, 1.0));
            }
        }
        quantize(&ising, Precision::CobiInt, Rounding::Deterministic, &mut rng)
    }
}
