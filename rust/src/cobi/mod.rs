//! COBI device model: the behavioural simulation of the 48/59-node
//! all-to-all CMOS coupled-oscillator Ising chip [Lo+ 2023, Cılasun+ 2025].
//!
//! The device enforces the real chip's programming constraints (spin
//! count, integer coupling range), models its timing/energy (200 µs/solve
//! @ 25 mW by default), and solves via one of two backends:
//!
//!   * `native` — the pure-Rust oscillator integrator (fast, default for
//!     tests/benches);
//!   * `hlo`    — the AOT `anneal.hlo.txt` artifact through PJRT (the
//!     three-layer architecture's production path).
//!
//! Both backends implement identical dynamics; cross-backend agreement is
//! validated statistically in rust/tests/artifact_numerics.rs.

pub mod device;

pub use device::{CobiBackend, CobiDevice, CobiStats, ANNEAL_STEPS, PADDED_SPINS};
