//! The COBI device model (see module docs in cobi/mod.rs).

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::CobiConfig;
use crate::ising::Ising;
use crate::resilience::fault::{FaultCounters, FaultDraw, FaultModel, FAULT_STREAM};
use crate::runtime::artifacts::{Arg, ArtifactRuntime, Executable};
use crate::solvers::oscillator::{anneal, OscillatorConfig};
use crate::solvers::{IsingSolver, SolveResult};
use crate::util::rng::Pcg32;

/// Padded problem size the anneal artifact was compiled for
/// (python/compile/model.py: N_SPINS).
pub const PADDED_SPINS: usize = 64;
/// Anneal steps baked into the artifact (model.ANNEAL_STEPS).
pub const ANNEAL_STEPS: usize = 256;
/// Instances per batched dispatch (model.ANNEAL_BATCH).
pub const ANNEAL_BATCH: usize = 8;

/// RNG stream id for device phase/noise draws (shared by the device-owned
/// rng and the per-request seeded paths so both derive identically).
/// `pub(crate)` for the stream-id audit in `util::rng`.
pub(crate) const DEVICE_STREAM: u64 = 0xC0B1;

/// Solve backend.
pub enum CobiBackend {
    /// Pure-Rust oscillator integrator.
    Native,
    /// PJRT execution of anneal.hlo.txt (+ anneal_batch.hlo.txt when
    /// available, for amortized multi-instance dispatch).
    Hlo {
        single: Arc<Executable>,
        batch: Option<Arc<Executable>>,
    },
}

/// Accounting: modeled hardware cost of all solves so far.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CobiStats {
    /// Hardware solves performed.
    pub solves: u64,
    /// Modeled device time (s): solves * solve_time_s.
    pub device_time_s: f64,
    /// Modeled device energy (J): device_time_s * power_w.
    pub device_energy_j: f64,
    /// Measured wall-clock spent in the simulator (s) — reported next to
    /// the model for honesty (DESIGN.md decision #6).
    pub wall_time_s: f64,
}

/// One scheduler request for the seeded dispatch path: independent
/// instances whose randomness must derive ONLY from `seed`, so that
/// co-batching with other requests cannot change the results
/// (DESIGN.md decision #8).
pub struct SeededGroup<'a> {
    /// The group's instances (one refinement batch).
    pub instances: &'a [Ising],
    /// Request seed deriving ALL of the group's randomness.
    pub seed: u64,
}

/// Reusable per-device buffers for the solve hot path: the padded
/// instance (`Ising::padded_into`) plus the phase/noise draw tensors.
/// Holds no solve state — only capacity — so reuse across requests cannot
/// affect results (every element is overwritten before use; pinned by the
/// determinism tests below).
struct DevScratch {
    pad: Ising,
    phase: Vec<f32>,
    noise: Vec<f32>,
}

impl Default for DevScratch {
    fn default() -> Self {
        Self {
            pad: Ising::new(0),
            phase: Vec::new(),
            noise: Vec::new(),
        }
    }
}

/// The simulated COBI device (native or HLO backend).
pub struct CobiDevice {
    /// Device-model parameters.
    pub cfg: CobiConfig,
    backend: CobiBackend,
    rng: Pcg32,
    stats: CobiStats,
    scratch: DevScratch,
    /// Construction/reseed seed (keys the fault stream of the unseeded
    /// entry points).
    base_seed: u64,
    /// Hardware non-ideality model (`[resilience] fault_*`; None = the
    /// clean device, byte-identical to every pre-fault release).
    fault: Option<FaultModel>,
    /// Fault stream of the unseeded entry points (`program_and_solve`,
    /// `solve_batch`); the seeded paths derive a fresh fault stream per
    /// request instead. Reset by [`CobiDevice::reseed`].
    fault_rng: Pcg32,
    /// Reusable buffer holding one solve's perturbed instance.
    fault_scratch: Ising,
}

impl CobiDevice {
    /// Native-backend device.
    pub fn native(cfg: CobiConfig, seed: u64) -> Self {
        Self {
            cfg,
            backend: CobiBackend::Native,
            rng: Pcg32::new(seed, DEVICE_STREAM),
            stats: CobiStats::default(),
            scratch: DevScratch::default(),
            base_seed: seed,
            fault: None,
            // the FAULT stream even before a model attaches: the
            // parallel-stream invariant (decision #16) is structural,
            // not dependent on set_fault_model re-deriving it
            fault_rng: Pcg32::new(seed, FAULT_STREAM),
            fault_scratch: Ising::new(0),
        }
    }

    /// HLO-backend device over an artifact runtime.
    pub fn hlo(cfg: CobiConfig, seed: u64, rt: &ArtifactRuntime) -> Result<Self> {
        let exe = rt.executable("anneal").context("loading anneal artifact")?;
        // validate artifact shapes against this module's constants
        let dims: Vec<Vec<usize>> = exe.spec.inputs.iter().map(|s| s.dims.clone()).collect();
        anyhow::ensure!(
            dims == vec![
                vec![PADDED_SPINS, PADDED_SPINS],
                vec![PADDED_SPINS],
                vec![PADDED_SPINS],
                vec![ANNEAL_STEPS, PADDED_SPINS],
                vec![3],
            ],
            "anneal artifact shapes {dims:?} do not match device constants"
        );
        // batched dispatch is optional (older artifact sets lack it)
        let batch = rt.executable("anneal_batch").ok();
        Ok(Self {
            cfg,
            backend: CobiBackend::Hlo {
                single: exe,
                batch,
            },
            rng: Pcg32::new(seed, DEVICE_STREAM),
            stats: CobiStats::default(),
            scratch: DevScratch::default(),
            base_seed: seed,
            fault: None,
            fault_rng: Pcg32::new(seed, FAULT_STREAM),
            fault_scratch: Ising::new(0),
        })
    }

    /// Build from config: backend selected by cfg.backend ("native"/"hlo").
    pub fn from_config(cfg: &CobiConfig, seed: u64, rt: Option<&ArtifactRuntime>) -> Result<Self> {
        match cfg.backend.as_str() {
            "native" => Ok(Self::native(cfg.clone(), seed)),
            "hlo" => {
                let rt = rt.context("hlo backend requires an artifact runtime")?;
                Self::hlo(cfg.clone(), seed, rt)
            }
            other => bail!("unknown cobi backend '{other}'"),
        }
    }

    /// Counters snapshot.
    pub fn stats(&self) -> CobiStats {
        self.stats
    }

    /// Zero the counters.
    pub fn reset_stats(&mut self) {
        self.stats = CobiStats::default();
    }

    /// Re-seed the device RNG. The pool's seeded dispatch path derives all
    /// randomness from per-request seeds instead; this exists for callers
    /// that replay a device-global stream (tests, calibration).
    pub fn reseed(&mut self, seed: u64) {
        self.rng = Pcg32::new(seed, DEVICE_STREAM);
        self.base_seed = seed;
        if let Some(fm) = &self.fault {
            self.fault_rng = fm.rng_for(seed);
        }
    }

    /// Attach a hardware fault model (see `resilience::fault`). Without
    /// one the device is the clean simulator, byte-identical to every
    /// pre-fault release; with one, every solve injects seed-derived
    /// non-idealities (DESIGN.md decision #16).
    pub fn set_fault_model(&mut self, fm: FaultModel) {
        self.fault_rng = fm.rng_for(self.base_seed);
        self.fault = Some(fm);
    }

    /// The attached fault model, if any.
    pub fn fault_model(&self) -> Option<&FaultModel> {
        self.fault.as_ref()
    }

    /// Point the attached fault model's injection counters at a
    /// fleet-shared block (no-op without a fault model).
    pub fn share_fault_counters(&mut self, counters: Arc<FaultCounters>) {
        if let Some(fm) = &mut self.fault {
            fm.set_counters(counters);
        }
    }

    /// Validate that an instance is programmable on the chip: spin count
    /// within the array, all coefficients integers in the DAC range.
    pub fn validate(&self, ising: &Ising) -> Result<()> {
        if ising.n > self.cfg.max_spins {
            bail!(
                "instance has {} spins; COBI array exposes {}",
                ising.n,
                self.cfg.max_spins
            );
        }
        let r = self.cfg.weight_range as f32;
        for (idx, &v) in ising.h.iter().chain(ising.j.iter()).enumerate() {
            if v.fract() != 0.0 || v.abs() > r {
                bail!(
                    "coefficient {idx} = {v} not programmable \
                     (integer range [-{r}, +{r}]); quantize first"
                );
            }
        }
        Ok(())
    }

    fn oscillator_config(&self) -> OscillatorConfig {
        OscillatorConfig {
            steps: ANNEAL_STEPS,
            k_coupling: self.cfg.k_coupling,
            k_shil_max: self.cfg.k_shil_max,
            dt: self.cfg.dt,
            noise_amp: self.cfg.noise_amp,
        }
    }

    fn kparams(&self) -> [f32; 3] {
        [self.cfg.k_coupling, self.cfg.k_shil_max, self.cfg.dt]
    }

    /// Charge the timing/energy model for `instances` hardware solves.
    fn charge(&mut self, instances: u64, wall_s: f64) {
        self.stats.solves += instances;
        self.stats.device_time_s += self.cfg.solve_time_s * instances as f64;
        self.stats.device_energy_j +=
            self.cfg.solve_time_s * self.cfg.power_w * instances as f64;
        self.stats.wall_time_s += wall_s;
    }

    /// One native (unpadded) anneal; draws phase0/noise from `rng` into
    /// the reusable scratch tensors (every element overwritten — reuse
    /// cannot change results, only skip the per-solve allocations). A
    /// fault draw's burst window amplifies the noise tensor in place
    /// (clean solves pass `None` and perform the identical draws).
    fn native_spins(
        osc: &OscillatorConfig,
        noise_amp: f32,
        ising: &Ising,
        rng: &mut Pcg32,
        scratch: &mut DevScratch,
        draw: Option<&FaultDraw>,
    ) -> Vec<i8> {
        // §Perf: the native integrator runs UNPADDED — padding spins carry
        // zero coupling and cannot influence the real ones, so simulating
        // them is pure waste ((64/n)^2 extra mat-vec work). Only the HLO
        // artifact needs the fixed 64-spin shape.
        let n = ising.n;
        warm_phase0_into(n, None, rng, &mut scratch.phase);
        scratch.noise.clear();
        scratch.noise.resize(ANNEAL_STEPS * n, 0.0);
        rng.fill_normal(&mut scratch.noise, noise_amp);
        if let Some(d) = draw {
            d.apply_burst(&mut scratch.noise, n);
        }
        anneal(ising, osc, &scratch.phase, &scratch.noise)
    }

    /// One padded HLO anneal through the single-instance artifact; draws
    /// phase0/noise from `rng`; pads through `scratch.pad` instead of a
    /// fresh 64×64 matrix per call. Burst faults amplify the noise
    /// tensor like [`CobiDevice::native_spins`].
    fn hlo_single_spins(
        exe: &Executable,
        kparams: &[f32; 3],
        noise_amp: f32,
        ising: &Ising,
        rng: &mut Pcg32,
        scratch: &mut DevScratch,
        draw: Option<&FaultDraw>,
    ) -> Result<Vec<i8>> {
        ising.padded_into(PADDED_SPINS, &mut scratch.pad);
        warm_phase0_into(PADDED_SPINS, None, rng, &mut scratch.phase);
        scratch.noise.clear();
        scratch.noise.resize(ANNEAL_STEPS * PADDED_SPINS, 0.0);
        rng.fill_normal(&mut scratch.noise, noise_amp);
        if let Some(d) = draw {
            d.apply_burst(&mut scratch.noise, PADDED_SPINS);
        }
        let outs = exe.run(&[
            Arg::F32(&scratch.pad.j),
            Arg::F32(&scratch.pad.h),
            Arg::F32(&scratch.phase),
            Arg::F32(&scratch.noise),
            Arg::F32(kparams),
        ])?;
        Ok(outs[0][..ising.n]
            .iter()
            .map(|&v| if v >= 0.0 { 1i8 } else { -1i8 })
            .collect())
    }

    /// Program the array and run one solve. Validates, pads to the
    /// artifact size, draws phase0/noise, runs the backend, crops the
    /// result and charges the timing model. With a fault model attached
    /// the programmed instance is perturbed (drift / DAC mismatch), the
    /// anneal may carry a burst-noise window, stuck oscillators override
    /// the readout, and the energy is recomputed on the CLEAN instance
    /// so reported energies always match the returned spins.
    pub fn program_and_solve(&mut self, ising: &Ising) -> Result<SolveResult> {
        self.validate(ising)?;
        let t0 = std::time::Instant::now();
        let osc = self.oscillator_config();
        let kparams = self.kparams();
        let noise_amp = self.cfg.noise_amp;

        let (mut spins, draw) = {
            let Self {
                backend,
                rng,
                scratch,
                fault,
                fault_rng,
                fault_scratch,
                ..
            } = self;
            let (inst_run, draw) = faulted(fault.as_ref(), ising, fault_rng, fault_scratch);
            let spins: Vec<i8> = match backend {
                CobiBackend::Native => {
                    Self::native_spins(&osc, noise_amp, inst_run, rng, scratch, draw.as_ref())
                }
                CobiBackend::Hlo { single, .. } => {
                    let single = single.clone();
                    Self::hlo_single_spins(
                        &single,
                        &kparams,
                        noise_amp,
                        inst_run,
                        rng,
                        scratch,
                        draw.as_ref(),
                    )?
                }
            };
            (spins, draw)
        };
        if let Some(d) = &draw {
            d.apply_stuck(&mut spins);
        }
        let energy = ising.energy(&spins);
        self.charge(1, t0.elapsed().as_secs_f64());
        Ok(SolveResult { spins, energy })
    }

    /// Batched dispatch through the `anneal_batch` artifact: all instances
    /// solved in ONE PJRT call per chunk of ANNEAL_BATCH. Tail-chunk slots
    /// beyond the real instances are left inert (zero couplings, zero
    /// noise) and never drawn from the device RNG, so a batch returns
    /// results identical to the same sequence of per-instance
    /// [`CobiDevice::program_and_solve`] calls and padded slots never leak
    /// into stats or energy accounting. Falls back to sequential solves on
    /// the native backend or when the artifact is absent.
    pub fn program_and_solve_batch(&mut self, instances: &[&Ising]) -> Result<Vec<SolveResult>> {
        let batch_exe = match &self.backend {
            CobiBackend::Hlo {
                batch: Some(exe), ..
            } => exe.clone(),
            _ => {
                return instances
                    .iter()
                    .map(|i| self.program_and_solve(i))
                    .collect();
            }
        };
        for inst in instances {
            self.validate(inst)?;
        }
        let kparams = self.kparams();
        let noise_amp = self.cfg.noise_amp;
        let mut results = Vec::with_capacity(instances.len());
        for chunk in instances.chunks(ANNEAL_BATCH) {
            let t0 = std::time::Instant::now();
            {
                let Self {
                    rng,
                    fault,
                    fault_rng,
                    ..
                } = &mut *self;
                let mut prepared: Vec<Prepared> = Vec::with_capacity(chunk.len());
                for (ii, inst) in chunk.iter().enumerate() {
                    let frng = if fault.is_some() {
                        Some(&mut *fault_rng)
                    } else {
                        None
                    };
                    prepared.push(Prepared::draw(
                        0,
                        ii,
                        inst,
                        noise_amp,
                        rng,
                        fault.as_ref(),
                        frng,
                    ));
                }
                let (j, h, phase0, noise) = pack_chunk(&prepared);
                let outs = batch_exe.run(&[
                    Arg::F32(&j),
                    Arg::F32(&h),
                    Arg::F32(&phase0),
                    Arg::F32(&noise),
                    Arg::F32(&kparams),
                ])?;
                for (slot, p) in prepared.iter().enumerate() {
                    results.push(p.finish(&outs[0], slot));
                }
            }
            self.charge(chunk.len() as u64, t0.elapsed().as_secs_f64());
        }
        Ok(results)
    }

    /// Seeded multi-request dispatch for the device pool: each group's
    /// phase/noise draws come from a fresh RNG keyed by the group seed, so
    /// the result of a group is a pure function of (instances, seed,
    /// device config) — independent of which other groups share the
    /// dispatch, their order, or earlier device history. With the HLO
    /// batch artifact, instances from ALL groups are packed into shared
    /// ANNEAL_BATCH chunks (the cross-document amortization the pool
    /// exists for); otherwise groups solve sequentially.
    pub fn solve_groups_seeded(
        &mut self,
        groups: &[SeededGroup<'_>],
    ) -> Result<Vec<Vec<SolveResult>>> {
        for g in groups {
            for inst in g.instances {
                self.validate(inst)?;
            }
        }
        let t0 = std::time::Instant::now();
        let osc = self.oscillator_config();
        let kparams = self.kparams();
        let noise_amp = self.cfg.noise_amp;

        enum Exec {
            Native,
            Single(Arc<Executable>),
            Batch(Arc<Executable>),
        }
        let exec = match &self.backend {
            CobiBackend::Native => Exec::Native,
            CobiBackend::Hlo {
                batch: Some(b), ..
            } => Exec::Batch(b.clone()),
            CobiBackend::Hlo { single, .. } => Exec::Single(single.clone()),
        };

        let mut out: Vec<Vec<SolveResult>> = groups
            .iter()
            .map(|g| Vec::with_capacity(g.instances.len()))
            .collect();
        // instances actually annealed — charged even when a later HLO
        // dispatch errors, so modeled time/energy never undercount work
        // the device really did
        let mut done: u64 = 0;
        let scratch = &mut self.scratch;
        let fault = self.fault.as_ref();
        let fault_scratch = &mut self.fault_scratch;
        let run = {
            let out = &mut out;
            let done = &mut done;
            (|| -> Result<()> {
                match exec {
                    Exec::Native => {
                        for (gi, g) in groups.iter().enumerate() {
                            let mut rng = Pcg32::new(g.seed, DEVICE_STREAM);
                            // fault draws come from a parallel stream
                            // keyed by the request seed, so clean-path
                            // phase/noise draws are never shifted and
                            // faulty groups stay co-batching-invariant
                            let mut frng = fault.map(|fm| fm.rng_for(g.seed));
                            for inst in g.instances {
                                let (inst_run, draw) = match frng.as_mut() {
                                    Some(fr) => faulted(fault, inst, fr, fault_scratch),
                                    None => (inst, None),
                                };
                                let mut spins = Self::native_spins(
                                    &osc,
                                    noise_amp,
                                    inst_run,
                                    &mut rng,
                                    scratch,
                                    draw.as_ref(),
                                );
                                if let Some(d) = &draw {
                                    d.apply_stuck(&mut spins);
                                }
                                let energy = inst.energy(&spins);
                                out[gi].push(SolveResult { spins, energy });
                                *done += 1;
                            }
                        }
                    }
                    Exec::Single(exe) => {
                        for (gi, g) in groups.iter().enumerate() {
                            let mut rng = Pcg32::new(g.seed, DEVICE_STREAM);
                            let mut frng = fault.map(|fm| fm.rng_for(g.seed));
                            for inst in g.instances {
                                let (inst_run, draw) = match frng.as_mut() {
                                    Some(fr) => faulted(fault, inst, fr, fault_scratch),
                                    None => (inst, None),
                                };
                                let mut spins = Self::hlo_single_spins(
                                    &exe,
                                    &kparams,
                                    noise_amp,
                                    inst_run,
                                    &mut rng,
                                    scratch,
                                    draw.as_ref(),
                                )?;
                                if let Some(d) = &draw {
                                    d.apply_stuck(&mut spins);
                                }
                                let energy = inst.energy(&spins);
                                out[gi].push(SolveResult { spins, energy });
                                *done += 1;
                            }
                        }
                    }
                    Exec::Batch(exe) => {
                        // flatten all (group, instance) pairs in group
                        // order — chunks may span group boundaries
                        let mut prepared: Vec<Prepared> = Vec::new();
                        for (gi, g) in groups.iter().enumerate() {
                            let mut rng = Pcg32::new(g.seed, DEVICE_STREAM);
                            let mut frng = fault.map(|fm| fm.rng_for(g.seed));
                            for (ii, inst) in g.instances.iter().enumerate() {
                                prepared.push(Prepared::draw(
                                    gi,
                                    ii,
                                    inst,
                                    noise_amp,
                                    &mut rng,
                                    fault,
                                    frng.as_mut(),
                                ));
                            }
                        }
                        for chunk in prepared.chunks(ANNEAL_BATCH) {
                            let (j, h, phase0, noise) = pack_chunk(chunk);
                            let outs = exe.run(&[
                                Arg::F32(&j),
                                Arg::F32(&h),
                                Arg::F32(&phase0),
                                Arg::F32(&noise),
                                Arg::F32(&kparams),
                            ])?;
                            for (slot, p) in chunk.iter().enumerate() {
                                out[p.gi].push(p.finish(&outs[0], slot));
                            }
                            *done += chunk.len() as u64;
                        }
                    }
                }
                Ok(())
            })()
        };
        self.charge(done, t0.elapsed().as_secs_f64());
        run?;
        Ok(out)
    }
}

impl CobiDevice {
    /// One seeded solve with an optional warm-start hint: initial
    /// oscillator phases derive from `init` (s = +1 → phase 0, s = -1 →
    /// phase π — the phase encoding of the hinted solution) instead of
    /// random draws; per-step noise still comes from the request-seed
    /// stream, so the anneal explores around the hint rather than
    /// replaying it. Without a hint this is exactly one instance of the
    /// seeded-group path. Used by the portfolio's warm-start route
    /// (reuse-aware solving); results are a pure function of
    /// (instance, seed, hint, device config).
    pub fn solve_seeded_warm(
        &mut self,
        ising: &Ising,
        seed: u64,
        init: Option<&[i8]>,
    ) -> Result<SolveResult> {
        self.validate(ising)?;
        if let Some(s) = init {
            anyhow::ensure!(
                s.len() == ising.n,
                "warm-start hint has {} spins for a {}-spin instance",
                s.len(),
                ising.n
            );
        }
        let t0 = std::time::Instant::now();
        let osc = self.oscillator_config();
        let kparams = self.kparams();
        let noise_amp = self.cfg.noise_amp;
        let mut rng = Pcg32::new(seed, DEVICE_STREAM);

        let scratch = &mut self.scratch;
        let fault = self.fault.as_ref();
        let fault_scratch = &mut self.fault_scratch;
        // request-seeded fault stream, like the seeded-group path
        let mut frng = fault.map(|fm| fm.rng_for(seed));
        let (inst_run, draw) = match frng.as_mut() {
            Some(fr) => faulted(fault, ising, fr, fault_scratch),
            None => (ising, None),
        };
        let mut spins = match &self.backend {
            CobiBackend::Native => {
                // a cold start draws n phases — matching native_spins
                warm_phase0_into(ising.n, init, &mut rng, &mut scratch.phase);
                scratch.noise.clear();
                scratch.noise.resize(ANNEAL_STEPS * ising.n, 0.0);
                rng.fill_normal(&mut scratch.noise, noise_amp);
                if let Some(d) = &draw {
                    d.apply_burst(&mut scratch.noise, ising.n);
                }
                anneal(inst_run, &osc, &scratch.phase, &scratch.noise)
            }
            CobiBackend::Hlo { single, .. } => {
                let single = single.clone();
                inst_run.padded_into(PADDED_SPINS, &mut scratch.pad);
                // a cold start draws PADDED_SPINS phases — matching
                // hlo_single_spins, so the noise stream stays aligned
                // with the seeded-group path; a hint draws none and
                // leaves the padding slots at phase 0
                match init {
                    Some(_) => {
                        warm_phase0_into(ising.n, init, &mut rng, &mut scratch.phase);
                        scratch.phase.resize(PADDED_SPINS, 0.0);
                    }
                    None => warm_phase0_into(PADDED_SPINS, None, &mut rng, &mut scratch.phase),
                }
                scratch.noise.clear();
                scratch.noise.resize(ANNEAL_STEPS * PADDED_SPINS, 0.0);
                rng.fill_normal(&mut scratch.noise, noise_amp);
                if let Some(d) = &draw {
                    d.apply_burst(&mut scratch.noise, PADDED_SPINS);
                }
                let outs = single.run(&[
                    Arg::F32(&scratch.pad.j),
                    Arg::F32(&scratch.pad.h),
                    Arg::F32(&scratch.phase),
                    Arg::F32(&scratch.noise),
                    Arg::F32(&kparams),
                ])?;
                outs[0][..ising.n]
                    .iter()
                    .map(|&v| if v >= 0.0 { 1i8 } else { -1i8 })
                    .collect()
            }
        };
        if let Some(d) = &draw {
            d.apply_stuck(&mut spins);
        }
        let energy = ising.energy(&spins);
        self.charge(1, t0.elapsed().as_secs_f64());
        // never return worse than the hint itself: a coarse near-match
        // hint is only useful if it cannot hurt (the cache contract,
        // DESIGN.md decision #10) — software solvers enforce this by
        // starting best-so-far at the hint; the analog anneal can drift
        // away, so clamp here. Strict `<` keeps the annealed result on
        // exact ties.
        if let Some(s) = init {
            let hint_energy = ising.energy(s);
            if hint_energy < energy {
                return Ok(SolveResult {
                    spins: s.to_vec(),
                    energy: hint_energy,
                });
            }
        }
        Ok(SolveResult { spins, energy })
    }
}

/// Resolve the instance a solve should anneal: with a fault model, draw
/// this solve's fault realization from `frng` and materialize the
/// perturbed instance into `storage` (reused across solves); without one
/// the clean instance passes through untouched and `frng` is never drawn
/// from. The returned [`FaultDraw`] carries the post-anneal stages
/// (stuck-spin overrides, burst window).
fn faulted<'a>(
    fault: Option<&FaultModel>,
    inst: &'a Ising,
    frng: &mut Pcg32,
    storage: &'a mut Ising,
) -> (&'a Ising, Option<FaultDraw>) {
    match fault {
        Some(fm) => {
            let draw = fm.perturb_into(inst, frng, storage);
            (&*storage, Some(draw))
        }
        None => (inst, None),
    }
}

/// Fill `out` with initial phases for a (possibly) warm-started anneal
/// over `n` oscillators: hinted spins map to their phase encoding (no RNG
/// draws); a cold start draws uniform phases exactly like the seeded
/// paths. `out` is a reusable buffer (cleared, resized, fully written).
fn warm_phase0_into(n: usize, init: Option<&[i8]>, rng: &mut Pcg32, out: &mut Vec<f32>) {
    out.clear();
    out.resize(n, 0.0);
    match init {
        Some(s) => {
            for (x, &v) in out.iter_mut().zip(s) {
                *x = if v > 0 { 0.0 } else { std::f32::consts::PI };
            }
        }
        None => {
            for x in out.iter_mut() {
                *x = rng.range_f32(-std::f32::consts::PI, std::f32::consts::PI);
            }
        }
    }
}

/// One instance prepared for a batched HLO dispatch. Holds the UNPADDED
/// instance by reference — `pack_chunk` writes its rows straight into the
/// flat artifact buffers, so the intermediate 64×64 padded matrix the old
/// path materialized per instance is gone entirely.
struct Prepared<'a> {
    /// Group index (0 for the unseeded batch path).
    gi: usize,
    /// Instance index within the group.
    ii: usize,
    inst: &'a Ising,
    /// The perturbed instance actually programmed (fault model only).
    faulty: Option<Ising>,
    /// This instance's fault realization (stuck overrides applied to the
    /// cropped readout, burst already folded into `noise`).
    draw: Option<FaultDraw>,
    phase0: Vec<f32>,
    noise: Vec<f32>,
}

impl<'a> Prepared<'a> {
    fn draw(
        gi: usize,
        ii: usize,
        inst: &'a Ising,
        noise_amp: f32,
        rng: &mut Pcg32,
        fault: Option<&FaultModel>,
        frng: Option<&mut Pcg32>,
    ) -> Self {
        // fault draws come first, from their own stream — the phase and
        // noise draws below are identical with or without a fault model
        let (faulty, draw) = match (fault, frng) {
            (Some(fm), Some(fr)) => {
                let mut perturbed = Ising::new(0);
                let d = fm.perturb_into(inst, fr, &mut perturbed);
                (Some(perturbed), Some(d))
            }
            _ => (None, None),
        };
        let mut phase0 = vec![0.0f32; PADDED_SPINS];
        for p in phase0.iter_mut() {
            *p = rng.range_f32(-std::f32::consts::PI, std::f32::consts::PI);
        }
        let mut noise = vec![0.0f32; ANNEAL_STEPS * PADDED_SPINS];
        rng.fill_normal(&mut noise, noise_amp);
        if let Some(d) = &draw {
            d.apply_burst(&mut noise, PADDED_SPINS);
        }
        Self {
            gi,
            ii,
            inst,
            faulty,
            draw,
            phase0,
            noise,
        }
    }

    /// The instance whose rows get packed into the artifact buffers (the
    /// perturbed copy under a fault model, the clean one otherwise).
    fn programmed(&self) -> &Ising {
        self.faulty.as_ref().unwrap_or(self.inst)
    }

    /// Crop this instance's output slot, apply any stuck-oscillator
    /// overrides, and score on the CLEAN instance.
    fn finish(&self, flat: &[f32], slot: usize) -> SolveResult {
        let mut r = crop_slot(flat, slot, self.inst);
        if let Some(d) = &self.draw {
            if !d.stuck.is_empty() {
                d.apply_stuck(&mut r.spins);
                r.energy = self.inst.energy(&r.spins);
            }
        }
        r
    }
}

/// Pack up to ANNEAL_BATCH prepared instances into the artifact's flat
/// input buffers, padding each instance's rows in place (identical values
/// to packing `inst.padded(PADDED_SPINS)`, without building it). Slots
/// past `chunk.len()` stay all-zero: a zero-coupling, zero-field,
/// zero-noise oscillator array is inert, cannot influence the real slots,
/// consumes no RNG draws, and its output rows are discarded — the three
/// properties the tail-padding unit tests pin down.
fn pack_chunk(chunk: &[Prepared<'_>]) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    assert!(!chunk.is_empty() && chunk.len() <= ANNEAL_BATCH);
    let nn = PADDED_SPINS * PADDED_SPINS;
    let sn = ANNEAL_STEPS * PADDED_SPINS;
    let mut j = vec![0.0f32; ANNEAL_BATCH * nn];
    let mut h = vec![0.0f32; ANNEAL_BATCH * PADDED_SPINS];
    let mut phase0 = vec![0.0f32; ANNEAL_BATCH * PADDED_SPINS];
    let mut noise = vec![0.0f32; ANNEAL_BATCH * sn];
    for (slot, p) in chunk.iter().enumerate() {
        let inst = p.programmed();
        let n = inst.n;
        for r in 0..n {
            let dst = slot * nn + r * PADDED_SPINS;
            j[dst..dst + n].copy_from_slice(&inst.j[r * n..(r + 1) * n]);
        }
        h[slot * PADDED_SPINS..slot * PADDED_SPINS + n].copy_from_slice(&inst.h);
        phase0[slot * PADDED_SPINS..(slot + 1) * PADDED_SPINS].copy_from_slice(&p.phase0);
        noise[slot * sn..(slot + 1) * sn].copy_from_slice(&p.noise);
    }
    (j, h, phase0, noise)
}

/// Crop one output slot back to the instance's real spin count and score.
fn crop_slot(flat: &[f32], slot: usize, inst: &Ising) -> SolveResult {
    let row = &flat[slot * PADDED_SPINS..slot * PADDED_SPINS + inst.n];
    let spins: Vec<i8> = row
        .iter()
        .map(|&v| if v >= 0.0 { 1i8 } else { -1i8 })
        .collect();
    let energy = inst.energy(&spins);
    SolveResult { spins, energy }
}

impl IsingSolver for CobiDevice {
    fn name(&self) -> &'static str {
        "cobi"
    }

    fn solve(&mut self, ising: &Ising) -> SolveResult {
        self.program_and_solve(ising)
            .expect("instance not programmable on COBI (validate/quantize first)")
    }

    fn solve_batch(&mut self, instances: &[&Ising]) -> Vec<SolveResult> {
        self.program_and_solve_batch(instances)
            .expect("batch not programmable on COBI (validate/quantize first)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cobi::testutil::quantized_glass;

    #[test]
    fn rejects_oversized_instances() {
        let dev_cfg = CobiConfig::default();
        let dev = CobiDevice::native(dev_cfg, 1);
        let ising = Ising::new(60); // > 59 spins
        assert!(dev.validate(&ising).is_err());
    }

    #[test]
    fn rejects_unquantized_instances() {
        let dev = CobiDevice::native(CobiConfig::default(), 1);
        let mut ising = Ising::new(4);
        ising.h[0] = 0.5; // fractional
        assert!(dev.validate(&ising).is_err());
        let mut ising2 = Ising::new(4);
        ising2.h[0] = 15.0; // out of range
        assert!(dev.validate(&ising2).is_err());
    }

    #[test]
    fn solves_and_accounts() {
        let ising = quantized_glass(3, 12);
        let mut dev = CobiDevice::native(CobiConfig::default(), 7);
        let r = dev.program_and_solve(&ising).unwrap();
        assert_eq!(r.spins.len(), 12);
        assert!((ising.energy(&r.spins) - r.energy).abs() < 1e-6);
        let s = dev.stats();
        assert_eq!(s.solves, 1);
        assert!((s.device_time_s - 200e-6).abs() < 1e-12);
        assert!((s.device_energy_j - 200e-6 * 25e-3).abs() < 1e-15);
        assert!(s.wall_time_s > 0.0);
    }

    #[test]
    fn run_to_run_variability() {
        // consecutive solves on the same instance must explore different
        // configurations (phase noise) at least occasionally
        let ising = quantized_glass(5, 16);
        let mut dev = CobiDevice::native(CobiConfig::default(), 11);
        let mut distinct = std::collections::HashSet::new();
        for _ in 0..8 {
            let r = dev.program_and_solve(&ising).unwrap();
            distinct.insert(r.spins);
        }
        assert!(distinct.len() > 1, "device behaved deterministically");
    }

    #[test]
    fn finds_good_states_on_quantized_instances() {
        // COBI is stochastic and not guaranteed optimal (that is the whole
        // point of iterative refinement); but best-of-10 on a 14-spin
        // integer glass must land within 10% of the ground-state energy
        // and far below a random configuration.
        use crate::solvers::exact::ising_ground_exhaustive;
        let ising = quantized_glass(9, 14);
        let (ge, _, _) = ising_ground_exhaustive(&ising);
        let mut dev = CobiDevice::native(CobiConfig::default(), 13);
        let best = (0..10)
            .map(|_| dev.program_and_solve(&ising).unwrap().energy)
            .fold(f64::INFINITY, f64::min);
        let gap = (best - ge) / ge.abs();
        assert!(gap < 0.10, "best over 10 solves {best} vs ground {ge} (gap {gap:.3})");
        assert!(best < 0.0);
    }

    #[test]
    fn reseed_replays_the_stream() {
        let ising = quantized_glass(15, 12);
        let mut dev = CobiDevice::native(CobiConfig::default(), 21);
        let a = dev.program_and_solve(&ising).unwrap();
        dev.reseed(21);
        let b = dev.program_and_solve(&ising).unwrap();
        assert_eq!(a.spins, b.spins);
        assert_eq!(a.energy, b.energy);
    }

    #[test]
    fn batch_tail_chunk_matches_per_instance_solves() {
        // 11 instances (not divisible by ANNEAL_BATCH = 8): the batch path
        // must return exactly what the same device produces solving them
        // one at a time, and charge stats for 11 solves — padded tail
        // slots must not leak into accounting.
        let instances: Vec<Ising> = (0..11).map(|k| quantized_glass(100 + k, 13)).collect();
        let refs: Vec<&Ising> = instances.iter().collect();

        let mut batch_dev = CobiDevice::native(CobiConfig::default(), 33);
        let batched = batch_dev.program_and_solve_batch(&refs).unwrap();

        let mut seq_dev = CobiDevice::native(CobiConfig::default(), 33);
        let sequential: Vec<SolveResult> = refs
            .iter()
            .map(|i| seq_dev.program_and_solve(i).unwrap())
            .collect();

        assert_eq!(batched.len(), 11);
        for (b, s) in batched.iter().zip(&sequential) {
            assert_eq!(b.spins, s.spins);
            assert_eq!(b.energy, s.energy);
        }
        let bs = batch_dev.stats();
        let ss = seq_dev.stats();
        assert_eq!(bs.solves, 11, "padded slots leaked into solve count");
        assert_eq!(ss.solves, 11);
        assert!((bs.device_time_s - 11.0 * 200e-6).abs() < 1e-12);
        assert!((bs.device_energy_j - ss.device_energy_j).abs() < 1e-15);
    }

    #[test]
    fn pack_chunk_leaves_tail_slots_inert() {
        // 3 real instances in an 8-slot chunk: slots 3..8 must be all-zero
        // in every buffer (couplings, fields, phases, noise) so they
        // cannot influence real slots and represent no RNG draws.
        let mut rng = Pcg32::seeded(55);
        let instances: Vec<Ising> = (0..3)
            .map(|ii| quantized_glass(200 + ii as u64, 10))
            .collect();
        let prepared: Vec<Prepared> = instances
            .iter()
            .enumerate()
            .map(|(ii, inst)| Prepared::draw(0, ii, inst, 0.1, &mut rng, None, None))
            .collect();
        let (j, h, phase0, noise) = pack_chunk(&prepared);
        let nn = PADDED_SPINS * PADDED_SPINS;
        let sn = ANNEAL_STEPS * PADDED_SPINS;
        assert_eq!(j.len(), ANNEAL_BATCH * nn);
        assert_eq!(h.len(), ANNEAL_BATCH * PADDED_SPINS);
        assert_eq!(phase0.len(), ANNEAL_BATCH * PADDED_SPINS);
        assert_eq!(noise.len(), ANNEAL_BATCH * sn);
        // real slots carry exactly the padded instance (the direct pack
        // must be indistinguishable from packing inst.padded(64))
        let padded0 = instances[0].padded(PADDED_SPINS);
        assert_eq!(&j[..nn], &padded0.j[..]);
        assert_eq!(&h[..PADDED_SPINS], &padded0.h[..]);
        let padded1 = instances[1].padded(PADDED_SPINS);
        assert_eq!(&j[nn..2 * nn], &padded1.j[..]);
        assert_eq!(&phase0[PADDED_SPINS..2 * PADDED_SPINS], &prepared[1].phase0[..]);
        // tail slots are identically zero
        assert!(j[3 * nn..].iter().all(|&v| v == 0.0));
        assert!(h[3 * PADDED_SPINS..].iter().all(|&v| v == 0.0));
        assert!(phase0[3 * PADDED_SPINS..].iter().all(|&v| v == 0.0));
        assert!(noise[3 * sn..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn seeded_groups_are_independent_of_cobatching() {
        // a group's result must be a pure function of (instances, seed):
        // solving it alone, co-batched with another group, or in the
        // reverse order must all agree — the invariant that makes pool
        // dispatch order irrelevant to summaries.
        let a: Vec<Ising> = (0..3).map(|k| quantized_glass(300 + k, 12)).collect();
        let b: Vec<Ising> = (0..5).map(|k| quantized_glass(400 + k, 14)).collect();
        let mut dev = CobiDevice::native(CobiConfig::default(), 77);

        let alone = dev
            .solve_groups_seeded(&[SeededGroup { instances: &a, seed: 9001 }])
            .unwrap();
        let together = dev
            .solve_groups_seeded(&[
                SeededGroup { instances: &b, seed: 4242 },
                SeededGroup { instances: &a, seed: 9001 },
            ])
            .unwrap();
        assert_eq!(alone[0].len(), 3);
        assert_eq!(together[1].len(), 3);
        for (x, y) in alone[0].iter().zip(&together[1]) {
            assert_eq!(x.spins, y.spins);
            assert_eq!(x.energy, y.energy);
        }
        // accounting counts only real instances: 3 + (5 + 3) = 11
        assert_eq!(dev.stats().solves, 11);
    }

    #[test]
    fn cold_warm_solve_matches_the_seeded_group_path() {
        // without a hint, solve_seeded_warm must be bit-identical to a
        // one-instance seeded group (same RNG stream, same draw order)
        let inst = quantized_glass(700, 12);
        let mut dev = CobiDevice::native(CobiConfig::default(), 80);
        let a = dev.solve_seeded_warm(&inst, 4321, None).unwrap();
        let b = dev
            .solve_groups_seeded(&[SeededGroup {
                instances: std::slice::from_ref(&inst),
                seed: 4321,
            }])
            .unwrap();
        assert_eq!(a.spins, b[0][0].spins);
        assert_eq!(a.energy, b[0][0].energy);
    }

    #[test]
    fn warm_hints_are_deterministic_and_charged() {
        let inst = quantized_glass(701, 14);
        let hint = vec![1i8; 14];
        let mut dev = CobiDevice::native(CobiConfig::default(), 81);
        let a = dev.solve_seeded_warm(&inst, 9, Some(&hint)).unwrap();
        let b = dev.solve_seeded_warm(&inst, 9, Some(&hint)).unwrap();
        assert_eq!(a.spins, b.spins);
        assert!((inst.energy(&a.spins) - a.energy).abs() < 1e-9);
        // the cache contract: a warm solve is never worse than its hint
        assert!(a.energy <= inst.energy(&hint) + 1e-9);
        assert_eq!(dev.stats().solves, 2);
        // a wrong-length hint is a loud error
        assert!(dev.solve_seeded_warm(&inst, 9, Some(&[1i8; 3])).is_err());
    }

    #[test]
    fn warm_solve_never_loses_a_ground_state_hint() {
        use crate::solvers::exact::ising_ground_exhaustive;
        let inst = quantized_glass(702, 12);
        let (ge, gs, _) = ising_ground_exhaustive(&inst);
        let mut dev = CobiDevice::native(CobiConfig::default(), 82);
        let r = dev.solve_seeded_warm(&inst, 5, Some(&gs)).unwrap();
        assert!((r.energy - ge).abs() < 1e-9, "hint clamp lost the ground state");
    }

    #[test]
    fn fault_free_model_with_zero_rates_matches_the_clean_device() {
        // attaching a fault model whose every stage is disabled must be
        // indistinguishable from the clean device: the fault stream is
        // parallel, so phase/noise draws are untouched, and the zero-rate
        // perturbation is a value-identical copy
        use crate::config::FaultConfig;
        let instances: Vec<Ising> = (0..3).map(|k| quantized_glass(1000 + k, 12)).collect();
        let mut clean = CobiDevice::native(CobiConfig::default(), 3);
        let mut nulled = CobiDevice::native(CobiConfig::default(), 3);
        nulled.set_fault_model(FaultModel::new(&FaultConfig {
            enabled: true,
            stuck_rate: 0.0,
            drift_rate: 0.0,
            drift_amp: 0.0,
            dac_mismatch: 0.0,
            burst_rate: 0.0,
            burst_amp: 1.0,
            seed: 5,
        }));
        let group = |dev: &mut CobiDevice| {
            dev.solve_groups_seeded(&[SeededGroup {
                instances: &instances,
                seed: 42,
            }])
            .unwrap()
        };
        let a = group(&mut clean);
        let b = group(&mut nulled);
        for (x, y) in a[0].iter().zip(&b[0]) {
            assert_eq!(x.spins, y.spins);
            assert_eq!(x.energy.to_bits(), y.energy.to_bits());
        }
        // the device-global path agrees too
        let inst = quantized_glass(1003, 10);
        let pa = clean.program_and_solve(&inst).unwrap();
        let pb = nulled.program_and_solve(&inst).unwrap();
        assert_eq!(pa.spins, pb.spins);
    }

    fn heavy_faults() -> crate::config::FaultConfig {
        crate::config::FaultConfig {
            enabled: true,
            stuck_rate: 0.3,
            drift_rate: 0.3,
            drift_amp: 0.3,
            dac_mismatch: 0.1,
            burst_rate: 0.5,
            burst_amp: 4.0,
            seed: 0xFA17,
        }
    }

    #[test]
    fn faulty_solves_are_seed_reproducible_and_counted() {
        let instances: Vec<Ising> = (0..4).map(|k| quantized_glass(1100 + k, 14)).collect();
        let run = || {
            let mut dev = CobiDevice::native(CobiConfig::default(), 9);
            dev.set_fault_model(FaultModel::new(&heavy_faults()));
            let out = dev
                .solve_groups_seeded(&[SeededGroup {
                    instances: &instances,
                    seed: 0xF00D,
                }])
                .unwrap();
            let counters = dev.fault_model().unwrap().counters().snapshot();
            (out, counters)
        };
        let (a, ca) = run();
        let (b, cb) = run();
        for (x, y) in a[0].iter().zip(&b[0]) {
            assert_eq!(x.spins, y.spins, "faulty runs must replay byte-identically");
            assert_eq!(x.energy.to_bits(), y.energy.to_bits());
        }
        assert_eq!(ca, cb, "fault counters must replay too");
        assert!(ca.any(), "heavy fault rates must inject something");
        // reported energies are always the clean-instance energy of the
        // returned spins, even under faults
        for (r, inst) in a[0].iter().zip(&instances) {
            assert!((inst.energy(&r.spins) - r.energy).abs() < 1e-9);
        }
        // and the faulty results differ from the clean device's
        let mut clean = CobiDevice::native(CobiConfig::default(), 9);
        let c = clean
            .solve_groups_seeded(&[SeededGroup {
                instances: &instances,
                seed: 0xF00D,
            }])
            .unwrap();
        assert!(
            a[0].iter().zip(&c[0]).any(|(x, y)| x.spins != y.spins),
            "heavy faults left every solve untouched"
        );
    }

    #[test]
    fn faulty_groups_stay_independent_of_cobatching() {
        // decision #16: fault draws derive from the request seed alone,
        // so a faulty group's results are identical whether it is solved
        // alone or co-batched with another group
        let a: Vec<Ising> = (0..3).map(|k| quantized_glass(1200 + k, 12)).collect();
        let b: Vec<Ising> = (0..2).map(|k| quantized_glass(1300 + k, 12)).collect();
        let device = || {
            let mut d = CobiDevice::native(CobiConfig::default(), 1);
            d.set_fault_model(FaultModel::new(&heavy_faults()));
            d
        };
        let alone = device()
            .solve_groups_seeded(&[SeededGroup { instances: &a, seed: 777 }])
            .unwrap();
        let together = device()
            .solve_groups_seeded(&[
                SeededGroup { instances: &b, seed: 888 },
                SeededGroup { instances: &a, seed: 777 },
            ])
            .unwrap();
        for (x, y) in alone[0].iter().zip(&together[1]) {
            assert_eq!(x.spins, y.spins);
            assert_eq!(x.energy.to_bits(), y.energy.to_bits());
        }
    }

    #[test]
    fn fully_stuck_device_returns_the_stuck_pattern() {
        use crate::config::FaultConfig;
        let inst = quantized_glass(1400, 10);
        let mut dev = CobiDevice::native(CobiConfig::default(), 2);
        dev.set_fault_model(FaultModel::new(&FaultConfig {
            enabled: true,
            stuck_rate: 1.0,
            drift_rate: 0.0,
            drift_amp: 0.0,
            dac_mismatch: 0.0,
            burst_rate: 0.0,
            burst_amp: 1.0,
            seed: 3,
        }));
        let out = dev
            .solve_groups_seeded(&[SeededGroup {
                instances: std::slice::from_ref(&inst),
                seed: 55,
            }])
            .unwrap();
        let r = &out[0][0];
        // every oscillator stuck: the readout is exactly the stuck
        // pattern drawn from the request's fault stream, and the energy
        // honestly reflects it
        let fm = dev.fault_model().unwrap();
        let mut frng = fm.rng_for(55);
        let mut storage = Ising::new(0);
        let draw = fm.perturb_into(&inst, &mut frng, &mut storage);
        assert_eq!(draw.stuck.len(), 10);
        let mut expected = vec![0i8; 10];
        for &(k, s) in &draw.stuck {
            expected[k] = s;
        }
        assert_eq!(r.spins, expected);
        assert!((inst.energy(&r.spins) - r.energy).abs() < 1e-9);
        assert_eq!(dev.fault_model().unwrap().counters().snapshot().stuck_spins, 20);
    }

    #[test]
    fn seeded_groups_vary_with_seed() {
        let a: Vec<Ising> = (0..2).map(|k| quantized_glass(500 + k, 16)).collect();
        let mut dev = CobiDevice::native(CobiConfig::default(), 78);
        let r1 = dev
            .solve_groups_seeded(&[SeededGroup { instances: &a, seed: 1 }])
            .unwrap();
        let r2 = dev
            .solve_groups_seeded(&[SeededGroup { instances: &a, seed: 2 }])
            .unwrap();
        let same = r1[0]
            .iter()
            .zip(&r2[0])
            .all(|(x, y)| x.spins == y.spins);
        assert!(!same, "different seeds produced identical spin sets");
    }
}
