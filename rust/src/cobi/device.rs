//! The COBI device model (see module docs in cobi/mod.rs).

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::CobiConfig;
use crate::ising::Ising;
use crate::runtime::artifacts::{Arg, ArtifactRuntime, Executable};
use crate::solvers::oscillator::{anneal, OscillatorConfig};
use crate::solvers::{IsingSolver, SolveResult};
use crate::util::rng::Pcg32;

/// Padded problem size the anneal artifact was compiled for
/// (python/compile/model.py: N_SPINS).
pub const PADDED_SPINS: usize = 64;
/// Anneal steps baked into the artifact (model.ANNEAL_STEPS).
pub const ANNEAL_STEPS: usize = 256;
/// Instances per batched dispatch (model.ANNEAL_BATCH).
pub const ANNEAL_BATCH: usize = 8;

/// Solve backend.
pub enum CobiBackend {
    /// Pure-Rust oscillator integrator.
    Native,
    /// PJRT execution of anneal.hlo.txt (+ anneal_batch.hlo.txt when
    /// available, for amortized multi-instance dispatch).
    Hlo {
        single: Arc<Executable>,
        batch: Option<Arc<Executable>>,
    },
}

/// Accounting: modeled hardware cost of all solves so far.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CobiStats {
    pub solves: u64,
    /// Modeled device time (s): solves * solve_time_s.
    pub device_time_s: f64,
    /// Modeled device energy (J): device_time_s * power_w.
    pub device_energy_j: f64,
    /// Measured wall-clock spent in the simulator (s) — reported next to
    /// the model for honesty (DESIGN.md decision #6).
    pub wall_time_s: f64,
}

pub struct CobiDevice {
    pub cfg: CobiConfig,
    backend: CobiBackend,
    rng: Pcg32,
    stats: CobiStats,
}

impl CobiDevice {
    /// Native-backend device.
    pub fn native(cfg: CobiConfig, seed: u64) -> Self {
        Self {
            cfg,
            backend: CobiBackend::Native,
            rng: Pcg32::new(seed, 0xC0B1),
            stats: CobiStats::default(),
        }
    }

    /// HLO-backend device over an artifact runtime.
    pub fn hlo(cfg: CobiConfig, seed: u64, rt: &ArtifactRuntime) -> Result<Self> {
        let exe = rt.executable("anneal").context("loading anneal artifact")?;
        // validate artifact shapes against this module's constants
        let dims: Vec<Vec<usize>> = exe.spec.inputs.iter().map(|s| s.dims.clone()).collect();
        anyhow::ensure!(
            dims == vec![
                vec![PADDED_SPINS, PADDED_SPINS],
                vec![PADDED_SPINS],
                vec![PADDED_SPINS],
                vec![ANNEAL_STEPS, PADDED_SPINS],
                vec![3],
            ],
            "anneal artifact shapes {dims:?} do not match device constants"
        );
        // batched dispatch is optional (older artifact sets lack it)
        let batch = rt.executable("anneal_batch").ok();
        Ok(Self {
            cfg,
            backend: CobiBackend::Hlo {
                single: exe,
                batch,
            },
            rng: Pcg32::new(seed, 0xC0B1),
            stats: CobiStats::default(),
        })
    }

    /// Build from config: backend selected by cfg.backend ("native"/"hlo").
    pub fn from_config(cfg: &CobiConfig, seed: u64, rt: Option<&ArtifactRuntime>) -> Result<Self> {
        match cfg.backend.as_str() {
            "native" => Ok(Self::native(cfg.clone(), seed)),
            "hlo" => {
                let rt = rt.context("hlo backend requires an artifact runtime")?;
                Self::hlo(cfg.clone(), seed, rt)
            }
            other => bail!("unknown cobi backend '{other}'"),
        }
    }

    pub fn stats(&self) -> CobiStats {
        self.stats
    }

    pub fn reset_stats(&mut self) {
        self.stats = CobiStats::default();
    }

    /// Validate that an instance is programmable on the chip: spin count
    /// within the array, all coefficients integers in the DAC range.
    pub fn validate(&self, ising: &Ising) -> Result<()> {
        if ising.n > self.cfg.max_spins {
            bail!(
                "instance has {} spins; COBI array exposes {}",
                ising.n,
                self.cfg.max_spins
            );
        }
        let r = self.cfg.weight_range as f32;
        for (idx, &v) in ising.h.iter().chain(ising.j.iter()).enumerate() {
            if v.fract() != 0.0 || v.abs() > r {
                bail!(
                    "coefficient {idx} = {v} not programmable \
                     (integer range [-{r}, +{r}]); quantize first"
                );
            }
        }
        Ok(())
    }

    fn oscillator_config(&self) -> OscillatorConfig {
        OscillatorConfig {
            steps: ANNEAL_STEPS,
            k_coupling: self.cfg.k_coupling,
            k_shil_max: self.cfg.k_shil_max,
            dt: self.cfg.dt,
            noise_amp: self.cfg.noise_amp,
        }
    }

    /// Program the array and run one solve. Validates, pads to the
    /// artifact size, draws phase0/noise, runs the backend, crops the
    /// result and charges the timing model.
    pub fn program_and_solve(&mut self, ising: &Ising) -> Result<SolveResult> {
        self.validate(ising)?;
        let t0 = std::time::Instant::now();

        let spins: Vec<i8> = match &self.backend {
            CobiBackend::Native => {
                // §Perf: the native integrator runs UNPADDED — padding
                // spins carry zero coupling and cannot influence the real
                // ones, so simulating them is pure waste ((64/n)^2 extra
                // mat-vec work). Only the HLO artifact needs the fixed
                // 64-spin shape.
                let n = ising.n;
                let mut phase0 = vec![0.0f32; n];
                for p in phase0.iter_mut() {
                    *p = self
                        .rng
                        .range_f32(-std::f32::consts::PI, std::f32::consts::PI);
                }
                let mut noise = vec![0.0f32; ANNEAL_STEPS * n];
                self.rng.fill_normal(&mut noise, self.cfg.noise_amp);
                anneal(ising, &self.oscillator_config(), &phase0, &noise)
            }
            CobiBackend::Hlo { single, .. } => {
                let padded = ising.padded(PADDED_SPINS);
                let mut phase0 = vec![0.0f32; PADDED_SPINS];
                for p in phase0.iter_mut() {
                    *p = self
                        .rng
                        .range_f32(-std::f32::consts::PI, std::f32::consts::PI);
                }
                let mut noise = vec![0.0f32; ANNEAL_STEPS * PADDED_SPINS];
                self.rng.fill_normal(&mut noise, self.cfg.noise_amp);
                let kparams = [self.cfg.k_coupling, self.cfg.k_shil_max, self.cfg.dt];
                let outs = single.run(&[
                    Arg::F32(&padded.j),
                    Arg::F32(&padded.h),
                    Arg::F32(&phase0),
                    Arg::F32(&noise),
                    Arg::F32(&kparams),
                ])?;
                outs[0][..ising.n]
                    .iter()
                    .map(|&v| if v >= 0.0 { 1i8 } else { -1i8 })
                    .collect()
            }
        };
        let energy = ising.energy(&spins);

        self.stats.solves += 1;
        self.stats.device_time_s += self.cfg.solve_time_s;
        self.stats.device_energy_j += self.cfg.solve_time_s * self.cfg.power_w;
        self.stats.wall_time_s += t0.elapsed().as_secs_f64();
        Ok(SolveResult { spins, energy })
    }
}

impl CobiDevice {
    /// Batched dispatch through the `anneal_batch` artifact: all instances
    /// solved in ONE PJRT call (chunks of ANNEAL_BATCH; tail chunks padded
    /// with instance copies and discarded). Falls back to sequential
    /// solves on the native backend or when the artifact is absent.
    pub fn program_and_solve_batch(&mut self, instances: &[&Ising]) -> Result<Vec<SolveResult>> {
        let batch_exe = match &self.backend {
            CobiBackend::Hlo {
                batch: Some(exe), ..
            } => exe.clone(),
            _ => {
                return instances
                    .iter()
                    .map(|i| self.program_and_solve(i))
                    .collect();
            }
        };
        for inst in instances {
            self.validate(inst)?;
        }
        let kparams = [self.cfg.k_coupling, self.cfg.k_shil_max, self.cfg.dt];
        let mut results = Vec::with_capacity(instances.len());
        for chunk in instances.chunks(ANNEAL_BATCH) {
            let t0 = std::time::Instant::now();
            let nn = PADDED_SPINS * PADDED_SPINS;
            let sn = ANNEAL_STEPS * PADDED_SPINS;
            let mut j = vec![0.0f32; ANNEAL_BATCH * nn];
            let mut h = vec![0.0f32; ANNEAL_BATCH * PADDED_SPINS];
            let mut phase0 = vec![0.0f32; ANNEAL_BATCH * PADDED_SPINS];
            let mut noise = vec![0.0f32; ANNEAL_BATCH * sn];
            for slot in 0..ANNEAL_BATCH {
                // tail slots replicate the last real instance (discarded)
                let inst = chunk[slot.min(chunk.len() - 1)];
                let padded = inst.padded(PADDED_SPINS);
                j[slot * nn..(slot + 1) * nn].copy_from_slice(&padded.j);
                h[slot * PADDED_SPINS..(slot + 1) * PADDED_SPINS].copy_from_slice(&padded.h);
                for p in phase0[slot * PADDED_SPINS..(slot + 1) * PADDED_SPINS].iter_mut() {
                    *p = self
                        .rng
                        .range_f32(-std::f32::consts::PI, std::f32::consts::PI);
                }
                self.rng
                    .fill_normal(&mut noise[slot * sn..(slot + 1) * sn], self.cfg.noise_amp);
            }
            let outs = batch_exe.run(&[
                Arg::F32(&j),
                Arg::F32(&h),
                Arg::F32(&phase0),
                Arg::F32(&noise),
                Arg::F32(&kparams),
            ])?;
            for (slot, inst) in chunk.iter().enumerate() {
                let row = &outs[0][slot * PADDED_SPINS..slot * PADDED_SPINS + inst.n];
                let spins: Vec<i8> = row
                    .iter()
                    .map(|&v| if v >= 0.0 { 1i8 } else { -1i8 })
                    .collect();
                let energy = inst.energy(&spins);
                results.push(SolveResult { spins, energy });
                self.stats.solves += 1;
                self.stats.device_time_s += self.cfg.solve_time_s;
                self.stats.device_energy_j += self.cfg.solve_time_s * self.cfg.power_w;
            }
            self.stats.wall_time_s += t0.elapsed().as_secs_f64();
        }
        Ok(results)
    }
}

impl IsingSolver for CobiDevice {
    fn name(&self) -> &'static str {
        "cobi"
    }

    fn solve(&mut self, ising: &Ising) -> SolveResult {
        self.program_and_solve(ising)
            .expect("instance not programmable on COBI (validate/quantize first)")
    }

    fn solve_batch(&mut self, instances: &[&Ising]) -> Vec<SolveResult> {
        self.program_and_solve_batch(instances)
            .expect("batch not programmable on COBI (validate/quantize first)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize, Precision, Rounding};

    fn quantized_glass(seed: u64, n: usize) -> Ising {
        let mut rng = Pcg32::seeded(seed);
        let mut ising = Ising::new(n);
        for i in 0..n {
            ising.h[i] = rng.range_f32(-3.0, 3.0);
            for j in (i + 1)..n {
                ising.set_pair(i, j, rng.range_f32(-1.0, 1.0));
            }
        }
        quantize(&ising, Precision::CobiInt, Rounding::Deterministic, &mut rng)
    }

    #[test]
    fn rejects_oversized_instances() {
        let dev_cfg = CobiConfig::default();
        let dev = CobiDevice::native(dev_cfg, 1);
        let ising = Ising::new(60); // > 59 spins
        assert!(dev.validate(&ising).is_err());
    }

    #[test]
    fn rejects_unquantized_instances() {
        let dev = CobiDevice::native(CobiConfig::default(), 1);
        let mut ising = Ising::new(4);
        ising.h[0] = 0.5; // fractional
        assert!(dev.validate(&ising).is_err());
        let mut ising2 = Ising::new(4);
        ising2.h[0] = 15.0; // out of range
        assert!(dev.validate(&ising2).is_err());
    }

    #[test]
    fn solves_and_accounts() {
        let ising = quantized_glass(3, 12);
        let mut dev = CobiDevice::native(CobiConfig::default(), 7);
        let r = dev.program_and_solve(&ising).unwrap();
        assert_eq!(r.spins.len(), 12);
        assert!((ising.energy(&r.spins) - r.energy).abs() < 1e-6);
        let s = dev.stats();
        assert_eq!(s.solves, 1);
        assert!((s.device_time_s - 200e-6).abs() < 1e-12);
        assert!((s.device_energy_j - 200e-6 * 25e-3).abs() < 1e-15);
        assert!(s.wall_time_s > 0.0);
    }

    #[test]
    fn run_to_run_variability() {
        // consecutive solves on the same instance must explore different
        // configurations (phase noise) at least occasionally
        let ising = quantized_glass(5, 16);
        let mut dev = CobiDevice::native(CobiConfig::default(), 11);
        let mut distinct = std::collections::HashSet::new();
        for _ in 0..8 {
            let r = dev.program_and_solve(&ising).unwrap();
            distinct.insert(r.spins);
        }
        assert!(distinct.len() > 1, "device behaved deterministically");
    }

    #[test]
    fn finds_good_states_on_quantized_instances() {
        // COBI is stochastic and not guaranteed optimal (that is the whole
        // point of iterative refinement); but best-of-10 on a 14-spin
        // integer glass must land within 10% of the ground-state energy
        // and far below a random configuration.
        use crate::solvers::exact::ising_ground_exhaustive;
        let ising = quantized_glass(9, 14);
        let (ge, _, _) = ising_ground_exhaustive(&ising);
        let mut dev = CobiDevice::native(CobiConfig::default(), 13);
        let best = (0..10)
            .map(|_| dev.program_and_solve(&ising).unwrap().energy)
            .fold(f64::INFINITY, f64::min);
        let gap = (best - ge) / ge.abs();
        assert!(gap < 0.10, "best over 10 solves {best} vs ground {ge} (gap {gap:.3})");
        assert!(best < 0.0);
    }
}
