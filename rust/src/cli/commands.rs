//! Subcommand implementations for the cobi-es binary.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::Settings;
use crate::corpus::{benchmark_set, Document};
use crate::experiments::{self, Scale, ALL_EXPERIMENTS};
use crate::ising::exact_bounds;
use crate::pipeline::EsPipeline;
use crate::runtime::ArtifactRuntime;
use crate::service::Service;
use crate::workload::KOfNProblem;

use super::Args;

/// Load settings: --config file, else ./cobi-es.toml if present.
pub fn load_settings(args: &Args) -> Result<Settings> {
    if let Some(path) = args.get("config") {
        return Settings::load(Path::new(path));
    }
    let default = Path::new("cobi-es.toml");
    if default.exists() {
        return Settings::load(default);
    }
    Ok(Settings::default())
}

fn apply_pipeline_flags(settings: &mut Settings, args: &Args) -> Result<()> {
    if let Some(s) = args.get("solver") {
        settings.pipeline.solver = s.to_string();
    }
    settings.pipeline.iterations =
        args.get_usize("iterations", settings.pipeline.iterations)?;
    settings.pipeline.summary_len =
        args.get_usize("summary-len", settings.pipeline.summary_len)?;
    if let Some(p) = args.get("precision") {
        settings.pipeline.precision = p.parse().map_err(anyhow::Error::msg)?;
    }
    if let Some(r) = args.get("rounding") {
        settings.pipeline.rounding = r.parse().map_err(anyhow::Error::msg)?;
    }
    if let Some(s) = args.get("strategy") {
        settings.pipeline.strategy = s.parse().map_err(anyhow::Error::msg)?;
    }
    if args.get_bool("hlo") {
        settings.cobi.backend = "hlo".to_string();
    }
    Ok(())
}

/// Apply the `[resilience]` flags shared by `summarize` and `serve`.
fn apply_resilience_flags(settings: &mut Settings, args: &Args) -> Result<()> {
    if args.get_bool("resilience") {
        settings.resilience.enabled = true;
    }
    if args.get("replication").is_some() {
        settings.resilience.replication =
            args.get_usize("replication", settings.resilience.replication)?;
        settings.resilience.enabled = true;
    }
    if args.get_bool("calibrate") {
        settings.resilience.calibrate = true;
        settings.resilience.enabled = true;
    }
    if args.get_bool("no-repair") {
        settings.resilience.repair = false;
    }
    if args.get("fault-stuck").is_some() {
        settings.resilience.fault.stuck_rate =
            args.get_f64("fault-stuck", settings.resilience.fault.stuck_rate as f64)? as f32;
        settings.resilience.fault.enabled = true;
    }
    if args.get("fault-drift").is_some() {
        settings.resilience.fault.drift_rate =
            args.get_f64("fault-drift", settings.resilience.fault.drift_rate as f64)? as f32;
        settings.resilience.fault.enabled = true;
    }
    if args.get("fault-seed").is_some() {
        settings.resilience.fault.seed = args.get_usize("fault-seed", 0)? as u64;
        // like the rate flags: asking for a fault seed means faults on
        // (the default rates apply) — a stored-but-inert seed would be a
        // silent no-op
        settings.resilience.fault.enabled = true;
    }
    Ok(())
}

/// Apply the `[obs]` flags: `--obs` switches span recording on,
/// `--trace-out PATH` selects the JSONL sink (and implies `--obs` — a
/// sink with tracing off would silently record nothing). `--record-out
/// PATH` selects the flight-recorder JSONL sink and implies recording
/// (same rationale; recording is otherwise `[obs] record_enabled`).
fn apply_obs_flags(settings: &mut Settings, args: &Args) {
    if args.get_bool("obs") {
        settings.obs.enabled = true;
    }
    if let Some(path) = args.get("trace-out") {
        settings.obs.trace_out = path.to_string();
        settings.obs.enabled = true;
    }
    if let Some(path) = args.get("record-out") {
        settings.obs.record_out = path.to_string();
        settings.obs.record_enabled = true;
    }
}

fn pipeline_from(settings: &Settings) -> Result<(EsPipeline, Option<ArtifactRuntime>)> {
    let rt = if settings.cobi.backend == "hlo" {
        Some(ArtifactRuntime::open_default().context(
            "hlo backend needs artifacts/ (run `make artifacts`) or COBI_ES_ARTIFACTS",
        )?)
    } else {
        None
    };
    // with the resilience layer on (or faults on a COBI solver), the
    // pipeline's solver runs behind the ResilientSolver/fault wiring —
    // one decision point shared with the service's local-route workers
    if let Some(p) = crate::resilience::resilient_pipeline(
        settings,
        &settings.pipeline,
        rt.as_ref(),
        None,
        None,
    )? {
        return Ok((p, rt));
    }
    let p = EsPipeline::from_config(&settings.pipeline, &settings.cobi, rt.as_ref())?;
    Ok((p, rt))
}

/// `summarize`: one document through the configured pipeline.
pub fn cmd_summarize(args: &Args) -> Result<()> {
    let mut settings = load_settings(args)?;
    apply_pipeline_flags(&mut settings, args)?;
    apply_resilience_flags(&mut settings, args)?;

    let doc = if let Some(path) = args.get("input") {
        let text = std::fs::read_to_string(path)?;
        Document::from_text(path, &text)
    } else {
        let set = benchmark_set(args.get("benchmark").unwrap_or("cnn_dm_20"))?;
        let idx = args.get_usize("doc", 0)?;
        set.documents
            .get(idx)
            .context("--doc out of range")?
            .clone()
    };

    let (mut pipeline, _rt) = pipeline_from(&settings)?;
    let t0 = std::time::Instant::now();
    let summary = pipeline.summarize(&doc)?;
    let wall = t0.elapsed();

    println!("document: {} ({} sentences)", doc.id, doc.len());
    println!(
        "solver: {} | strategy: {} | iterations: {} | precision: {} | rounding: {}",
        settings.pipeline.solver,
        settings.pipeline.strategy,
        settings.pipeline.iterations,
        settings.pipeline.precision,
        settings.pipeline.rounding
    );
    println!("selected sentences: {:?}", summary.selected);
    println!("objective: {:.4} | stages: {} | solves: {}",
        summary.objective, summary.stages, summary.total_solves);
    println!("wall time: {:.1} ms", wall.as_secs_f64() * 1e3);
    println!("\n--- summary ---");
    for (i, s) in summary.sentences.iter().enumerate() {
        println!("{:>2}. {s}", summary.selected[i]);
    }
    Ok(())
}

/// `experiment`: regenerate paper figures/tables.
pub fn cmd_experiment(args: &Args) -> Result<()> {
    let settings = load_settings(args)?;
    let scale = if args.get_bool("full") {
        Scale::Full
    } else {
        Scale::Quick
    };
    let id = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("all");
    let ids: Vec<&str> = if id == "all" {
        ALL_EXPERIMENTS.to_vec()
    } else {
        vec![id]
    };

    let mut out = String::new();
    for id in ids {
        eprintln!("running {id} ({scale:?})...");
        let t0 = std::time::Instant::now();
        let reports = experiments::run(id, scale, &settings)?;
        eprintln!("  done in {:.1}s", t0.elapsed().as_secs_f64());
        for r in &reports {
            if args.get_bool("csv") {
                out.push_str(&format!("# {}\n{}\n", r.title, r.to_csv()));
            } else {
                out.push_str(&r.to_markdown());
                out.push('\n');
            }
        }
    }
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &out)?;
            eprintln!("wrote {path}");
        }
        None => print!("{out}"),
    }
    Ok(())
}

/// `gen-corpus`: write a benchmark set as text files.
pub fn cmd_gen_corpus(args: &Args) -> Result<()> {
    let set_name = args.get("set").context("--set required")?;
    let out_dir = Path::new(args.get("out").context("--out required")?);
    std::fs::create_dir_all(out_dir)?;
    let set = benchmark_set(set_name)?;
    for doc in &set.documents {
        let path = out_dir.join(format!("{}.txt", doc.id));
        std::fs::write(&path, doc.text())?;
    }
    println!(
        "wrote {} documents ({} sentences each) to {}",
        set.documents.len(),
        set.doc_len(),
        out_dir.display()
    );
    Ok(())
}

/// `solve`: compare every solver on one document.
pub fn cmd_solve(args: &Args) -> Result<()> {
    let mut settings = load_settings(args)?;
    apply_pipeline_flags(&mut settings, args)?;
    let set = benchmark_set(args.get("benchmark").unwrap_or("cnn_dm_20"))?;
    let idx = args.get_usize("doc", 0)?;
    let doc = set.documents.get(idx).context("--doc out of range")?;

    println!("document {} — normalized objective per solver:", doc.id);
    let mut base = EsPipeline::from_config(&settings.pipeline, &settings.cobi, None)?;
    let problem = base.problem_for(doc)?;
    let bounds = exact_bounds(&problem);
    for solver in ["cobi", "tabu", "sa", "snowball", "brute", "exact", "random"] {
        let mut cfg = settings.pipeline.clone();
        cfg.solver = solver.to_string();
        let mut p = EsPipeline::from_config(&cfg, &settings.cobi, None)?;
        let t0 = std::time::Instant::now();
        let s = p.summarize(doc)?;
        println!(
            "  {:<8} {:.4}  ({:.1} ms wall, {} solves)",
            solver,
            bounds.normalize(s.objective),
            t0.elapsed().as_secs_f64() * 1e3,
            s.total_solves
        );
    }
    Ok(())
}

/// `select`: run one k-of-n workload request through the inline platform
/// path and print the selected candidates.
pub fn cmd_select(args: &Args) -> Result<()> {
    let mut settings = load_settings(args)?;
    apply_pipeline_flags(&mut settings, args)?;
    let workload = args
        .get("workload")
        .map(String::from)
        .unwrap_or_else(|| settings.workload.default.clone());
    if workload == "es" {
        bail!("workload 'es' is the summarize command — use `cobi-es summarize`");
    }
    settings.workload.retrieval_k = args.get_usize("k", settings.workload.retrieval_k)?;
    let (id, lines): (String, Vec<String>) = if let Some(path) = args.get("input") {
        // line-framed like a ::WORKLOAD:: request body: retrieval reads
        // query + passages, dispersion reads one spec line
        let text = std::fs::read_to_string(path)?;
        (
            path.to_string(),
            text.lines()
                .map(str::trim)
                .filter(|l| !l.is_empty())
                .map(String::from)
                .collect(),
        )
    } else if workload == "dispersion" {
        let n = args.get_usize("n", settings.workload.dispersion_n)?;
        let k = args.get_usize("k", settings.workload.dispersion_k)?;
        let seed = args.get_usize("seed", 0)? as u64;
        (
            format!("dispersion-cli-{seed}"),
            vec![format!("n={n} k={k} seed={seed}")],
        )
    } else {
        // no input: serve one pinned corpus request
        let reqs = crate::corpus::workload_requests(&workload)?;
        let idx = args.get_usize("request", 0)?;
        let r = reqs.get(idx).context("--request out of range")?;
        (r.id.clone(), r.lines.clone())
    };
    let problem = crate::workload::problem_from_request(&workload, &id, &lines, &settings.workload)?;
    let t0 = std::time::Instant::now();
    let summary = crate::workload::select_inline(problem.as_ref(), &settings, None)?;
    let wall = t0.elapsed();
    println!(
        "workload: {workload} | request: {id} | solver: {} | k={} of n={}",
        settings.pipeline.solver,
        problem.k(),
        problem.candidates().len(),
    );
    println!("selected: {:?}", summary.selected);
    println!(
        "objective: {:.4} | solves: {} | wall time: {:.1} ms",
        summary.objective,
        summary.total_solves,
        wall.as_secs_f64() * 1e3
    );
    println!("\n--- selection ---");
    for (i, s) in summary.sentences.iter().enumerate() {
        println!("{:>2}. {s}", summary.selected[i]);
    }
    Ok(())
}

/// Apply the `serve` pool + portfolio flags onto `[sched]`/`[portfolio]`
/// (see USAGE).
fn apply_pool_flags(settings: &mut Settings, args: &Args) -> Result<()> {
    if args.get_bool("no-pool") {
        settings.sched.enabled = false;
    }
    settings.sched.devices = args.get_usize("pool-devices", settings.sched.devices)?;
    settings.sched.max_coalesce =
        args.get_usize("pool-coalesce", settings.sched.max_coalesce)?;
    settings.sched.linger_us =
        args.get_usize("pool-linger-us", settings.sched.linger_us as usize)? as u64;
    if let Some(b) = args.get("pool-backend") {
        // reject typos loudly: an unknown backend would otherwise just
        // silently route solves to worker-private solvers
        if b != "auto" && !crate::sched::pool_supports(b) {
            bail!("--pool-backend expects auto|cobi|tabu|sa|snowball|portfolio, got '{b}'");
        }
        settings.sched.backend = b.to_string();
    }
    if args.get_bool("portfolio") {
        settings.portfolio.enabled = true;
    }
    if let Some(p) = args.get("portfolio-policy") {
        // validate eagerly (same typo-loudness rationale as --pool-backend)
        p.parse::<crate::portfolio::RoutePolicy>()
            .map_err(anyhow::Error::msg)?;
        settings.portfolio.policy = p.to_string();
        settings.portfolio.enabled = true;
    }
    if args.get_bool("no-warm-cache") {
        settings.portfolio.cache = false;
    }
    settings.portfolio.epsilon =
        args.get_f64("portfolio-epsilon", settings.portfolio.epsilon)?;
    Ok(())
}

/// Apply the `serve` overload-safety flags onto `[service]` and
/// `[sched].breaker` (see USAGE).
fn apply_service_flags(settings: &mut Settings, args: &Args) -> Result<()> {
    let s = &mut settings.service;
    s.default_deadline_ms =
        args.get_usize("default-deadline-ms", s.default_deadline_ms as usize)? as u64;
    s.idle_timeout_ms = args.get_usize("idle-timeout-ms", s.idle_timeout_ms as usize)? as u64;
    s.shed_watermark_ms =
        args.get_usize("shed-watermark-ms", s.shed_watermark_ms as usize)? as u64;
    s.drain_deadline_ms =
        args.get_usize("drain-deadline-ms", s.drain_deadline_ms as usize)? as u64;
    s.max_doc_bytes = args.get_usize("max-doc-bytes", s.max_doc_bytes)?;
    let b = &mut settings.sched.breaker;
    if args.get_bool("breaker") {
        b.enabled = true;
    }
    b.window = args.get_usize("breaker-window", b.window)?;
    b.trip_failures = args.get_usize("breaker-trip-failures", b.trip_failures as usize)? as u32;
    b.cooldown_ms = args.get_usize("breaker-cooldown-ms", b.cooldown_ms as usize)? as u64;
    Ok(())
}

/// `serve`: run the edge service (demo or TCP mode).
pub fn cmd_serve(args: &Args) -> Result<()> {
    let mut settings = load_settings(args)?;
    apply_pipeline_flags(&mut settings, args)?;
    apply_pool_flags(&mut settings, args)?;
    apply_resilience_flags(&mut settings, args)?;
    apply_obs_flags(&mut settings, args);
    apply_service_flags(&mut settings, args)?;
    settings.service.workers = args.get_usize("workers", settings.service.workers)?;
    let requests = args.get_usize("requests", 20)?;

    // the HLO backend needs the artifact runtime threaded through to
    // whichever route builds a COBI device — the shared pool (this is
    // what unlocks cross-document ANNEAL_BATCH dispatch) or the
    // worker-private pipelines. Opened only when a COBI device will
    // actually be constructed, so e.g. `--pool-backend tabu` serves
    // without artifacts even under `[cobi] backend = "hlo"`.
    let pooled = crate::sched::service_pooled(&settings);
    // the portfolio always constructs a COBI device internally, so it
    // needs the runtime whenever the device config says "hlo"
    let needs_hlo = settings.cobi.backend == "hlo"
        && ((pooled
            && matches!(
                crate::sched::resolved_backend(&settings),
                "cobi" | "portfolio"
            ))
            || (!pooled && settings.pipeline.solver == "cobi"));
    let rt = if needs_hlo {
        Some(ArtifactRuntime::open_default().context(
            "hlo backend needs artifacts/ (run `make artifacts`) or COBI_ES_ARTIFACTS",
        )?)
    } else {
        None
    };

    if pooled {
        println!(
            "device pool: {} devices, coalesce {}, linger {}µs, backend {}",
            settings.sched.devices.max(1),
            settings.sched.max_coalesce,
            settings.sched.linger_us,
            crate::sched::resolved_backend(&settings),
        );
        if crate::sched::resolved_backend(&settings) == "portfolio" {
            println!(
                "portfolio: policy {}, static backend {}, warm cache {}",
                settings.portfolio.policy,
                settings.portfolio.static_backend,
                if settings.portfolio.cache { "on" } else { "off" },
            );
        }
    } else {
        println!("device pool: disabled (worker-private solvers)");
    }
    // the resilience layer applies on BOTH routes (pool devices or
    // worker-private pipelines), so report it outside the pool branch
    if settings.resilience.enabled {
        println!(
            "resilience: replication {}, retries {}, repair {}, calibrate {}{}",
            settings.resilience.replication,
            settings.resilience.retries,
            if settings.resilience.repair { "on" } else { "off" },
            if settings.resilience.calibrate { "on" } else { "off" },
            if settings.resilience.fault.enabled {
                format!(
                    " | faults: stuck {:.1}% drift {:.1}%",
                    settings.resilience.fault.stuck_rate * 100.0,
                    settings.resilience.fault.drift_rate * 100.0,
                )
            } else {
                String::new()
            },
        );
    }
    // overload-safety status (only what's switched on)
    {
        let s = &settings.service;
        let mut knobs = Vec::new();
        if s.default_deadline_ms > 0 {
            knobs.push(format!("default deadline {}ms", s.default_deadline_ms));
        }
        if s.shed_watermark_ms > 0 {
            knobs.push(format!("shed watermark {}ms (batch first)", s.shed_watermark_ms));
        }
        if s.max_doc_bytes > 0 {
            knobs.push(format!("doc cap {} bytes", s.max_doc_bytes));
        }
        if settings.sched.breaker.enabled {
            knobs.push(format!(
                "breaker on (window {}, trip {}, cooldown {}ms)",
                settings.sched.breaker.window,
                settings.sched.breaker.trip_failures,
                settings.sched.breaker.cooldown_ms,
            ));
        }
        if !knobs.is_empty() {
            println!("overload safety: {}", knobs.join(" | "));
        }
    }
    if settings.obs.enabled {
        println!(
            "observability: tracing on (ring {}, exemplars {}){}",
            settings.obs.ring_capacity,
            settings.obs.exemplars,
            if settings.obs.trace_out.is_empty() {
                String::new()
            } else {
                format!(" | trace-out {}", settings.obs.trace_out)
            },
        );
    }
    if settings.obs.record_enabled {
        println!(
            "flight recorder: on (ring {}{})",
            settings.obs.record_capacity,
            if settings.obs.record_out.is_empty() {
                String::new()
            } else {
                format!(", record-out {}", settings.obs.record_out)
            },
        );
    }
    let trace_out = (!settings.obs.trace_out.is_empty())
        .then(|| std::path::PathBuf::from(&settings.obs.trace_out));
    let record_out = (!settings.obs.record_out.is_empty())
        .then(|| std::path::PathBuf::from(&settings.obs.record_out));

    // --port: run the TCP endpoint until killed
    if let Some(port) = args.get("port") {
        let port: u16 = port.parse().context("--port expects a u16")?;
        let svc = std::sync::Arc::new(Service::start_with(&settings, rt.as_ref())?);
        let server = crate::service::tcp::TcpServer::start(svc.clone(), port)?;
        println!(
            "listening on {} — send document text then a '{}' line \
             ('{}' report | '{}' json | '{}' exposition)",
            server.addr,
            crate::service::tcp::EOF_MARKER,
            crate::service::tcp::STATS_MARKER,
            crate::service::tcp::STATS_JSON_MARKER,
            crate::service::tcp::METRICS_MARKER,
        );
        let mut ticks = 0u64;
        loop {
            // half-second trace flushes keep the JSONL near-live; the
            // one-line report stays on its old 5s cadence
            std::thread::sleep(std::time::Duration::from_millis(500));
            if server.drain_requested() {
                // a ::DRAIN:: admin frame arrived: accepts already
                // stopped; finish in-flight work, flush exporters, exit
                println!("drain requested — finishing in-flight work");
                let limit = std::time::Duration::from_millis(
                    settings.service.drain_deadline_ms.max(1),
                );
                let stats = svc.drain(limit);
                println!(
                    "drained: {} finished, {} aborted ({:.2}s)",
                    stats.clean,
                    stats.aborted,
                    stats.waited.as_secs_f64()
                );
                if let Some(path) = &trace_out {
                    let spans = svc.obs().traces().drain();
                    if let Err(e) = crate::obs::export::append_jsonl(path, &spans) {
                        eprintln!("trace export failed: {e}");
                    }
                }
                if let Some(path) = &record_out {
                    let lines = svc.obs().recorder().drain_lines();
                    if let Err(e) = append_record_lines(path, &lines) {
                        eprintln!("record export failed: {e}");
                    }
                }
                println!("{}", svc.metrics().report());
                server.stop();
                // connection threads may still hold clones briefly; a
                // full shutdown (worker + pool join) only when we're the
                // last owner, else process exit reaps the threads
                if let Ok(svc) = std::sync::Arc::try_unwrap(svc) {
                    svc.shutdown();
                }
                return Ok(());
            }
            if let Some(path) = &trace_out {
                let spans = svc.obs().traces().drain();
                if let Err(e) = crate::obs::export::append_jsonl(path, &spans) {
                    eprintln!("trace export failed: {e}");
                }
            }
            if let Some(path) = &record_out {
                let lines = svc.obs().recorder().drain_lines();
                if let Err(e) = append_record_lines(path, &lines) {
                    eprintln!("record export failed: {e}");
                }
            }
            ticks += 1;
            if ticks % 10 == 0 {
                println!("{}", svc.metrics().report());
            }
        }
    }

    println!(
        "starting service: {} workers, queue depth {}, solver {}",
        settings.service.workers, settings.service.queue_depth, settings.pipeline.solver
    );
    let svc = Service::start_with(&settings, rt.as_ref())?;
    let set = benchmark_set("cnn_dm_20")?;
    let t0 = std::time::Instant::now();
    let mut tickets = Vec::new();
    for i in 0..requests {
        let doc = set.documents[i % set.documents.len()].clone();
        match svc.submit(doc) {
            Ok(t) => tickets.push(t),
            Err(e) => println!("request {i} rejected: {e}"),
        }
    }
    let mut ok = 0usize;
    for t in tickets {
        if t.wait().is_ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("completed {ok}/{requests} in {wall:.2}s ({:.1} docs/s)", ok as f64 / wall);
    println!("{}", svc.metrics().report());
    if let Some(path) = &trace_out {
        let spans = svc.obs().traces().drain();
        crate::obs::export::append_jsonl(path, &spans)?;
        println!("wrote {} trace trees to {}", spans.len(), path.display());
    }
    if let Some(path) = &record_out {
        let lines = svc.obs().recorder().drain_lines();
        append_record_lines(path, &lines)?;
        println!("wrote {} flight records to {}", lines.len(), path.display());
    }
    svc.shutdown();
    Ok(())
}

/// Append flight-recorder JSONL lines to `path` (created on first
/// flush); a no-op on an empty batch so idle flush ticks don't touch
/// the file.
fn append_record_lines(path: &Path, lines: &[String]) -> Result<()> {
    if lines.is_empty() {
        return Ok(());
    }
    use std::io::Write;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .with_context(|| format!("opening record sink {}", path.display()))?;
    for l in lines {
        writeln!(f, "{l}")?;
    }
    Ok(())
}

/// `replay`: re-execute recorded requests from a flight-recorder JSONL
/// file through the current binary and byte-diff the outputs; on
/// divergence, prints the first divergent DAG node and the
/// config-fingerprint diff, and the command exits nonzero.
pub fn cmd_replay(args: &Args) -> Result<()> {
    let mut settings = load_settings(args)?;
    apply_pipeline_flags(&mut settings, args)?;
    apply_resilience_flags(&mut settings, args)?;
    let path = args
        .positional
        .get(1)
        .context("replay expects a records file: cobi-es replay <file.jsonl>")?;
    let records = crate::obs::replay::load_records(path)?;
    if records.is_empty() {
        bail!("no records in {path}");
    }
    let selected: Vec<_> = match args.get("id") {
        Some(_) => {
            let id = args.get_usize("id", 0)? as u64;
            let rec = records
                .iter()
                .find(|r| r.id == id)
                .with_context(|| format!("no record with id {id} in {path}"))?;
            vec![rec.clone()]
        }
        // --all is the default: replaying everything is the audit mode
        None => records,
    };
    let total = selected.len();
    let mut diverged = 0usize;
    for rec in &selected {
        let report = crate::obs::replay_record(rec, &settings)?;
        println!("{}", report.verdict_line());
        if !report.identical {
            diverged += 1;
            for d in &report.config_diff {
                println!(
                    "  config diff: {} recorded={} current={}",
                    d.key, d.recorded, d.current
                );
            }
        }
    }
    println!("replayed {total}: {} identical, {diverged} diverged", total - diverged);
    if diverged > 0 {
        bail!("{diverged}/{total} replays diverged");
    }
    Ok(())
}

/// `doctor`: artifact/runtime/device health checks.
pub fn cmd_doctor(args: &Args) -> Result<()> {
    let settings = load_settings(args)?;
    println!("cobi-es doctor");
    println!("  config: cobi max_spins={} range=±{} backend={}",
        settings.cobi.max_spins, settings.cobi.weight_range, settings.cobi.backend);

    match crate::runtime::smoke() {
        Ok(p) => println!("  PJRT: ok (platform = {p})"),
        Err(e) => println!("  PJRT: FAILED — {e}"),
    }
    match ArtifactRuntime::open_default() {
        Ok(rt) => {
            println!("  artifacts: {:?}", rt.graph_names());
            for name in rt.graph_names() {
                match rt.executable(&name) {
                    Ok(_) => println!("    {name}: compiles"),
                    Err(e) => println!("    {name}: FAILED — {e}"),
                }
            }
        }
        Err(e) => println!("  artifacts: not available ({e})"),
    }
    // device calibration probe: hit-rate sanity on a small instance
    let mut dev = crate::cobi::CobiDevice::native(settings.cobi.clone(), 42);
    let mut ising = crate::ising::Ising::new(8);
    for i in 0..8 {
        for j in (i + 1)..8 {
            ising.set_pair(i, j, if (i + j) % 2 == 0 { 2.0 } else { -3.0 });
        }
    }
    let r = dev.program_and_solve(&ising)?;
    println!("  device probe: energy {:.1} (stats: {:?})", r.energy, dev.stats());
    Ok(())
}

/// Dispatch table.
pub fn dispatch(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("summarize") => cmd_summarize(args),
        Some("experiment") => cmd_experiment(args),
        Some("gen-corpus") => cmd_gen_corpus(args),
        Some("solve") => cmd_solve(args),
        Some("select") => cmd_select(args),
        Some("serve") => cmd_serve(args),
        Some("replay") => cmd_replay(args),
        Some("doctor") => cmd_doctor(args),
        Some("help") | None => {
            print!("{}", super::USAGE);
            Ok(())
        }
        Some(other) => bail!("unknown command '{other}'\n\n{}", super::USAGE),
    }
}
