//! Command-line interface (clap substitute — see Cargo.toml note).
//!
//! Flag parser: positional arguments + `--key value` / `--flag` options,
//! with typed accessors and an auto-generated usage block per subcommand.

pub mod commands;

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed argument list.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Positional arguments, in order.
    pub positional: Vec<String>,
    /// `--key value` pairs and boolean flags.
    pub flags: BTreeMap<String, String>,
}

/// Flags that take no value.
const BOOLEAN_FLAGS: &[&str] = &[
    "full",
    "help",
    "verbose",
    "csv",
    "hlo",
    "no-pool",
    "portfolio",
    "no-warm-cache",
    "resilience",
    "calibrate",
    "no-repair",
    "obs",
    "breaker",
    "all",
];

impl Args {
    /// Parse an argv iterator (program name already stripped).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if BOOLEAN_FLAGS.contains(&name) {
                    out.flags.insert(name.to_string(), "true".to_string());
                } else {
                    match it.next() {
                        Some(v) if !v.starts_with("--") => {
                            out.flags.insert(name.to_string(), v);
                        }
                        _ => bail!("flag --{name} expects a value"),
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Raw string value of `--key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// True when a boolean flag was passed.
    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Integer flag with a default; errors on non-numeric input.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    /// Float flag with a default; errors on non-numeric input.
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got '{v}'")),
        }
    }
}

/// Top-level usage text (printed by `help` and on bad commands).
pub const USAGE: &str = "\
cobi-es — extractive summarization on a (simulated) CMOS Ising machine

USAGE:
  cobi-es <command> [options]

COMMANDS:
  summarize    Summarize a text file or a benchmark document
               --input <file> | --benchmark <set> [--doc N]
               [--solver cobi|tabu|sa|snowball|brute|exact|random]
               [--iterations N]
               [--summary-len M] [--precision fp|4bit..8bit|int14]
               [--rounding deterministic|stoch5050|stochastic]
               [--strategy window|tree|stream] [--hlo]
               resilience: [--resilience] [--replication N]
               [--calibrate] [--no-repair] [--fault-stuck F]
               [--fault-drift F] [--fault-seed N]
  experiment   Regenerate a paper figure/table
               <fig1|fig2|fig3|fig5|fig6|fig7|fig8|table1|supp-optima|
                fault-sweep|energy-report|workloads|replay-audit|all>
               [--full] [--out <file.md>] [--csv]
  gen-corpus   Write a benchmark set as text files
               --set <name> --out <dir>
  solve        Solve one benchmark document's Ising instance and print
               the normalized objective per solver
               [--benchmark <set>] [--doc N] [--iterations N]
  select       Run one k-of-n workload request (the non-ES platform
               path) and print the selected candidates
               --workload retrieval|dispersion
               retrieval: [--input <file>] (first line = query, rest =
               candidate passages) | [--request N] (pinned corpus)
               [--k N]
               dispersion: [--n N] [--k N] [--seed N] (generated
               instance; defaults from [workload] config)
               [--solver cobi|tabu|sa|snowball] [--iterations N]
  serve        Start the edge summarization service
               demo mode: [--requests N] [--workers N] [--solver ...]
               [--strategy window|tree|stream]
               network mode: --port <u16> (line protocol; text then
               a '::EOF::' line -> 'OK <m>' + m summary lines;
               a '::STATS::' line -> 'OK 1' + a metrics report line;
               a '::STATS JSON::' line -> 'OK 1' + one JSON stats line;
               a '::METRICS::' line -> 'OK <n>' + n Prometheus-style
               exposition lines (energy ledger included);
               a '::STREAM::' first line opens a SUMMARIZE_STREAM
               session: chunks ended by '::CHUNK::' each return a
               'REV <m>' summary revision, '::EOF::' closes with the
               final 'OK <m>' summary;
               a '::WORKLOAD <name>::' header line routes the request
               to a registered k-of-n workload — the body is one
               candidate per line (retrieval: query first; dispersion:
               one 'n=.. k=.. seed=..' spec line) and 'OK <k>' lists
               the selected candidates)
               device pool: [--pool-devices N] [--pool-coalesce N]
               [--pool-linger-us N]
               [--pool-backend auto|cobi|tabu|sa|snowball|portfolio]
               [--no-pool] (fall back to worker-private solvers)
               portfolio: [--portfolio] (adaptive solver routing)
               [--portfolio-policy static|size-tiered|bandit]
               [--portfolio-epsilon F] [--no-warm-cache]
               resilience: [--resilience] (replicated voting solves +
               verify-and-retry) [--replication N] [--calibrate]
               [--no-repair] fault injection: [--fault-stuck F]
               [--fault-drift F] [--fault-seed N]
               observability: [--obs] (request-scoped tracing)
               [--trace-out <file.jsonl>] (JSONL span dump; implies
               --obs) [--record-out <file.jsonl>] (flight-recorder
               provenance dump, one record per request; implies
               recording — see 'replay')
               overload safety: [--default-deadline-ms N] (0 = none)
               [--idle-timeout-ms N] (per-connection read timeout;
               0 = none) [--shed-watermark-ms N] (two-tier admission
               control; '::BATCH::'-tagged requests shed first with
               'ERR RETRY <ms>' hints) [--drain-deadline-ms N]
               [--max-doc-bytes N] [--breaker] (per-device circuit
               breaker: verify-failure window trips a quarantine,
               calibration probes readmit) [--breaker-window N]
               [--breaker-trip-failures N] [--breaker-cooldown-ms N]
               admin: a '::DRAIN::' line stops accepts and drains
               in-flight work before exit; '::DEADLINE <ms>::' before
               the document sets a per-request deadline; a
               '::REPLAY <id>::' line re-executes flight-recorder ring
               entry <id> and returns 'OK 1' + one verdict line
  replay       Re-execute recorded requests from a flight-recorder
               JSONL file (serve --record-out) through the current
               binary and byte-diff the outputs; on divergence, names
               the first divergent DAG node (level/slot/seed, recorded
               vs replayed energy) and the config-fingerprint diff
               <file.jsonl> [--id N] [--all] (default: --all)
               exits nonzero when any replay diverges
  doctor       Check artifacts, PJRT runtime and device calibration
  help         Show this message

CONFIG:
  --config <file>   TOML config (default: cobi-es.toml if present)
  Seeds, device constants and timing models live in the config; every
  run is reproducible from (config, seed).
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn positional_and_flags() {
        let a = parse("experiment fig1 --full --out report.md");
        assert_eq!(a.positional, vec!["experiment", "fig1"]);
        assert!(a.get_bool("full"));
        assert_eq!(a.get("out"), Some("report.md"));
    }

    #[test]
    fn equals_form() {
        let a = parse("summarize --iterations=25 --solver=cobi");
        assert_eq!(a.get_usize("iterations", 1).unwrap(), 25);
        assert_eq!(a.get("solver"), Some("cobi"));
    }

    #[test]
    fn no_pool_is_a_bare_flag() {
        let a = parse("serve --no-pool --workers 2");
        assert!(a.get_bool("no-pool"));
        assert_eq!(a.get_usize("workers", 0).unwrap(), 2);
        // also valid as the last argument
        assert!(parse("serve --no-pool").get_bool("no-pool"));
    }

    #[test]
    fn portfolio_flags_are_bare() {
        let a = parse("serve --portfolio --no-warm-cache --portfolio-policy bandit");
        assert!(a.get_bool("portfolio"));
        assert!(a.get_bool("no-warm-cache"));
        assert_eq!(a.get("portfolio-policy"), Some("bandit"));
    }

    #[test]
    fn breaker_and_overload_flags_parse() {
        let a = parse("serve --breaker --shed-watermark-ms 200 --default-deadline-ms 500");
        assert!(a.get_bool("breaker"));
        assert_eq!(a.get_usize("shed-watermark-ms", 0).unwrap(), 200);
        assert_eq!(a.get_usize("default-deadline-ms", 0).unwrap(), 500);
        // also valid as the last argument
        assert!(parse("serve --breaker").get_bool("breaker"));
    }

    #[test]
    fn replay_flags_parse() {
        let a = parse("replay records.jsonl --all");
        assert_eq!(a.positional, vec!["replay", "records.jsonl"]);
        assert!(a.get_bool("all"));
        let a = parse("replay records.jsonl --id 3");
        assert_eq!(a.get_usize("id", 0).unwrap(), 3);
        let a = parse("serve --record-out flight.jsonl --port 0");
        assert_eq!(a.get("record-out"), Some("flight.jsonl"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(vec!["--solver".to_string()]).is_err());
        assert!(
            Args::parse(vec!["--solver".to_string(), "--iterations".to_string()]).is_err()
        );
    }

    #[test]
    fn typed_accessors() {
        let a = parse("x --n 5 --r 2.5");
        assert_eq!(a.get_usize("n", 0).unwrap(), 5);
        assert_eq!(a.get_f64("r", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert!(parse("x --n five").get_usize("n", 0).is_err());
    }
}
