//! cobi-es binary entry point. All logic lives in the library; see
//! `cobi_es::cli` for the command surface.

use cobi_es::cli::{commands, Args};

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if args.get_bool("help") {
        print!("{}", cobi_es::cli::USAGE);
        return;
    }
    if let Err(e) = commands::dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
