//! Hardware preprocessing from [Cılasun+ 2024] (paper §II-B): weight
//! scaling, spin merging and spin pruning — the techniques the paper
//! evaluates AGAINST its bias-term formulation (§III-A shows plain
//! scaling/truncation fails for ES). Implemented so the comparison is
//! reproducible and so oversized instances can still be squeezed onto
//! the 59-spin array when decomposition is disabled.

use crate::ising::Ising;

/// Uniform scaling + truncation to an integer grid: the naive baseline
/// §III-A argues against. `scale_to_j` scales so max|J| hits the grid
/// edge (truncating h), otherwise scales so max|h| hits it (crushing J).
pub fn scale_truncate(ising: &Ising, grid_max: i32, scale_to_j: bool) -> Ising {
    let n = ising.n;
    let jm = ising.j.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
    let hm = ising.h.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
    let basis = if scale_to_j { jm } else { hm };
    let scale = if basis > 0.0 {
        grid_max as f32 / basis
    } else {
        1.0
    };
    let g = grid_max as f32;
    let mut out = Ising::new(n);
    for i in 0..n {
        out.h[i] = (ising.h[i] * scale).round().clamp(-g, g);
        for j in 0..n {
            out.j[i * n + j] = (ising.j[i * n + j] * scale).round().clamp(-g, g);
        }
    }
    out
}

/// Result of a merge: the reduced instance plus the mapping back.
#[derive(Debug, Clone)]
pub struct MergedIsing {
    /// The reduced instance.
    pub ising: Ising,
    /// group[k] = original spin indices merged into reduced spin k.
    pub groups: Vec<Vec<usize>>,
    /// sign[original] relative to its group representative (+1 aligned,
    /// -1 anti-aligned).
    pub signs: Vec<i8>,
}

impl MergedIsing {
    /// Expand a reduced configuration back to the original spins.
    pub fn expand(&self, reduced: &[i8]) -> Vec<i8> {
        let n_orig = self.signs.len();
        let mut out = vec![0i8; n_orig];
        for (k, group) in self.groups.iter().enumerate() {
            for &orig in group {
                out[orig] = reduced[k] * self.signs[orig];
            }
        }
        debug_assert!(out.iter().all(|&s| s != 0));
        out
    }
}

/// Spin merging: greedily contract the pair with the largest |J_ij|
/// until at most `target_spins` remain. A merged pair is constrained to
/// s_i = sign * s_j with sign = -sign(J_ij) (the coupling's preferred
/// relative orientation — J < 0 favours alignment in our minimization
/// convention); fields and couplings accumulate accordingly.
pub fn merge_spins(ising: &Ising, target_spins: usize) -> MergedIsing {
    let n = ising.n;
    assert!(target_spins >= 1);
    // current reduced instance state, dense over "alive" representatives
    let mut h: Vec<f64> = ising.h.iter().map(|&x| x as f64).collect();
    let mut j: Vec<f64> = ising.j.iter().map(|&x| x as f64).collect();
    let mut alive: Vec<bool> = vec![true; n];
    let mut groups: Vec<Vec<usize>> = (0..n).map(|i| vec![i]).collect();
    let mut signs: Vec<i8> = vec![1; n];
    let mut alive_count = n;

    while alive_count > target_spins {
        // find the largest |J| between alive representatives
        let mut best: Option<(usize, usize, f64)> = None;
        for a in 0..n {
            if !alive[a] {
                continue;
            }
            for b in (a + 1)..n {
                if !alive[b] {
                    continue;
                }
                let w = j[a * n + b].abs();
                if best.map_or(true, |(_, _, bw)| w > bw) {
                    best = Some((a, b, w));
                }
            }
        }
        let Some((a, b, _)) = best else { break };
        // orientation: minimize J_ab s_a s_b -> s_b = -sign(J_ab) * s_a
        let rel: i8 = if j[a * n + b] > 0.0 { -1 } else { 1 };
        // fold b into a: h_a += rel * h_b; J_a,k += rel * J_b,k
        h[a] += rel as f64 * h[b];
        for k in 0..n {
            if k == a || k == b || !alive[k] {
                continue;
            }
            let add = rel as f64 * j[b * n + k];
            j[a * n + k] += add;
            j[k * n + a] += add;
        }
        // record membership with signs relative to a's representative
        let moved = std::mem::take(&mut groups[b]);
        for &orig in &moved {
            signs[orig] *= rel;
        }
        groups[a].extend(moved);
        alive[b] = false;
        alive_count -= 1;
    }

    // compact to a dense reduced instance
    let reps: Vec<usize> = (0..n).filter(|&i| alive[i]).collect();
    let m = reps.len();
    let mut out = Ising::new(m);
    let mut out_groups = Vec::with_capacity(m);
    for (k, &a) in reps.iter().enumerate() {
        out.h[k] = h[a] as f32;
        out_groups.push(groups[a].clone());
        for (l, &b) in reps.iter().enumerate() {
            if k != l {
                out.j[k * m + l] = j[a * n + b] as f32;
            }
        }
    }
    MergedIsing {
        ising: out,
        groups: out_groups,
        signs,
    }
}

/// Spin pruning: zero out couplings with |J| below `threshold` (relative
/// to max |J|), returning the sparsified instance and the fraction kept.
pub fn prune_couplings(ising: &Ising, threshold_frac: f32) -> (Ising, f64) {
    let n = ising.n;
    let jmax = ising.j.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
    let cut = jmax * threshold_frac;
    let mut out = ising.clone();
    let mut kept = 0usize;
    let mut total = 0usize;
    for i in 0..n {
        for k in (i + 1)..n {
            total += 1;
            if out.jij(i, k).abs() < cut {
                out.j[i * n + k] = 0.0;
                out.j[k * n + i] = 0.0;
            } else {
                kept += 1;
            }
        }
    }
    (out, if total == 0 { 1.0 } else { kept as f64 / total as f64 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::exact::ising_ground_exhaustive;
    use crate::util::rng::Pcg32;

    fn glass(seed: u64, n: usize) -> Ising {
        let mut rng = Pcg32::seeded(seed);
        let mut ising = Ising::new(n);
        for i in 0..n {
            ising.h[i] = rng.range_f32(-1.0, 1.0);
            for j in (i + 1)..n {
                ising.set_pair(i, j, rng.range_f32(-1.0, 1.0));
            }
        }
        ising
    }

    #[test]
    fn scale_truncate_respects_grid() {
        let ising = glass(1, 10);
        for to_j in [true, false] {
            let q = scale_truncate(&ising, 14, to_j);
            for &v in q.h.iter().chain(q.j.iter()) {
                assert!(v.fract() == 0.0 && v.abs() <= 14.0);
            }
        }
    }

    #[test]
    fn scale_to_h_crushes_j_variability() {
        // reproduce §III-A's complaint quantitatively on an ES-like
        // instance: h ~ 10x J in magnitude -> scaling h to the grid maps
        // all J to at most a couple of distinct integers
        let mut ising = Ising::new(8);
        for i in 0..8 {
            ising.h[i] = 3.5 + 0.1 * i as f32;
            for j in (i + 1)..8 {
                ising.set_pair(i, j, 0.5 + 0.01 * (i + j) as f32);
            }
        }
        let q = scale_truncate(&ising, 14, false);
        let distinct: std::collections::BTreeSet<i64> = q
            .upper_couplings()
            .iter()
            .map(|&v| v as i64)
            .collect();
        assert!(distinct.len() <= 2, "J variability survived: {distinct:?}");
    }

    #[test]
    fn merge_preserves_ground_state_energy_when_merging_strong_pairs() {
        // add one dominant coupling; merging it must keep the ground state
        let mut ising = glass(3, 10);
        ising.set_pair(2, 7, -50.0); // strongly ferromagnetic pair
        let (ge, gs, _) = ising_ground_exhaustive(&ising);
        let merged = merge_spins(&ising, 9);
        assert_eq!(merged.ising.n, 9);
        let (re, rs, _) = ising_ground_exhaustive(&merged.ising);
        let expanded = merged.expand(&rs);
        // ground state of merged == ground state of original (the strong
        // pair is aligned in the true optimum)
        assert!(
            (ising.energy(&expanded) - ge).abs() < 1e-6,
            "expanded energy {} vs ground {ge} (merged reported {re})",
            ising.energy(&expanded)
        );
        assert_eq!(gs[2] , gs[7], "dominant J<0 pair should align");
    }

    #[test]
    fn merge_to_target_size() {
        let ising = glass(5, 12);
        let merged = merge_spins(&ising, 6);
        assert_eq!(merged.ising.n, 6);
        let total: usize = merged.groups.iter().map(Vec::len).sum();
        assert_eq!(total, 12, "every original spin mapped");
        // expansion covers all spins with ±1
        let reduced = vec![1i8; 6];
        let exp = merged.expand(&reduced);
        assert_eq!(exp.len(), 12);
        assert!(exp.iter().all(|&s| s == 1 || s == -1));
    }

    #[test]
    fn prune_zeroes_weak_couplings_only() {
        let mut ising = Ising::new(6);
        ising.set_pair(0, 1, 1.0);
        ising.set_pair(2, 3, 0.05);
        let (p, kept) = prune_couplings(&ising, 0.1);
        assert_eq!(p.jij(0, 1), 1.0);
        assert_eq!(p.jij(2, 3), 0.0);
        assert!(kept < 1.0);
        // symmetry preserved
        assert_eq!(p.jij(3, 2), 0.0);
    }
}
