//! Rounding schemes for coefficient quantization (paper §IV-A).
//!
//!   * Deterministic — nearest integer; the same quantized Hamiltonian
//!     every iteration (explores only solver randomness).
//!   * Stoch5050 — up or down with probability 1/2 regardless of the
//!     fractional part; large perturbation, collapses at low precision.
//!   * Stochastic — up with probability equal to the fractional part
//!     (unbiased: E[q] = v); the paper's default.

use std::fmt;
use std::str::FromStr;

use crate::ising::{Ising, QuantIsing};
use crate::util::rng::Pcg32;

use super::precision::Precision;

/// Rounding scheme for quantization (§IV-A).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Rounding {
    /// Round to the nearest grid point.
    Deterministic,
    /// Fair coin flip between floor and ceil.
    Stoch5050,
    /// Round up with probability equal to the fractional part.
    Stochastic,
}

impl fmt::Display for Rounding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Rounding::Deterministic => write!(f, "deterministic"),
            Rounding::Stoch5050 => write!(f, "stoch5050"),
            Rounding::Stochastic => write!(f, "stochastic"),
        }
    }
}

impl FromStr for Rounding {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.trim().to_ascii_lowercase().as_str() {
            "deterministic" | "det" | "nearest" => Ok(Rounding::Deterministic),
            "stoch5050" | "5050" | "half" => Ok(Rounding::Stoch5050),
            "stochastic" | "stoch" | "sr" => Ok(Rounding::Stochastic),
            other => Err(format!("bad rounding '{other}'")),
        }
    }
}

impl Rounding {
    /// Round one already-scaled value to the integer grid.
    #[inline]
    pub fn round(&self, v: f32, rng: &mut Pcg32) -> f32 {
        let floor = v.floor();
        let frac = v - floor;
        match self {
            Rounding::Deterministic => {
                // nearest, half away from zero (matches numpy for our use)
                if frac >= 0.5 {
                    floor + 1.0
                } else {
                    floor
                }
            }
            Rounding::Stoch5050 => {
                if frac == 0.0 {
                    floor
                } else if rng.bernoulli(0.5) {
                    floor + 1.0
                } else {
                    floor
                }
            }
            Rounding::Stochastic => {
                if rng.f32() < frac {
                    floor + 1.0
                } else {
                    floor
                }
            }
        }
    }
}

/// Quantize an Ising instance to `precision` with `rounding`.
///
/// Returns a NEW instance whose coefficients are integers (stored as f32)
/// on the precision grid, in the ORIGINAL energy scale divided by `scale`
/// — solvers only care about the argmin, which is scale-invariant; the
/// evaluation of candidate solutions always uses the FP instance.
///
/// Symmetry: each unordered pair (i, j) is rounded ONCE and mirrored, so
/// the quantized J stays symmetric (stochastically rounding both triangles
/// independently would break J_ij = J_ji, which the hardware cannot even
/// represent).
pub fn quantize(ising: &Ising, precision: Precision, rounding: Rounding, rng: &mut Pcg32) -> Ising {
    let Some(scale) = precision.scale_for(ising.max_abs()) else {
        return ising.clone(); // FP: identity
    };
    let grid = precision.grid_max().unwrap() as f32;
    let n = ising.n;
    let mut out = Ising::new(n);
    for i in 0..n {
        out.h[i] = rounding.round(ising.h[i] * scale, rng).clamp(-grid, grid);
    }
    for i in 0..n {
        for j in (i + 1)..n {
            let q = rounding
                .round(ising.jij(i, j) * scale, rng)
                .clamp(-grid, grid);
            out.j[i * n + j] = q;
            out.j[j * n + i] = q;
        }
    }
    out
}

/// Quantize straight into a reusable integer instance — the hot-path twin
/// of [`quantize`]: no intermediate `f32` `Ising`, no allocation once
/// `out`'s buffers have grown to the instance size.
///
/// Draw-for-draw identical to [`quantize`]: the same `rounding.round`
/// calls on the same scaled values in the same order (all `h` in index
/// order, then upper-triangle pairs row by row), so for a fixed RNG state
/// the integer output equals the `f32` output value-for-value — the
/// refinement fast path replays the exact rounding stream of the batched
/// path.
///
/// Returns `false` without touching `out` or the RNG when `precision` has
/// no integer grid (`Precision::Fp`): the FP identity case has no integer
/// representation, and callers stay on the `f32` path.
pub fn quantize_into(
    ising: &Ising,
    precision: Precision,
    rounding: Rounding,
    rng: &mut Pcg32,
    out: &mut QuantIsing,
) -> bool {
    let Some(scale) = precision.scale_for(ising.max_abs()) else {
        return false;
    };
    let grid = precision.grid_max().unwrap();
    let gridf = grid as f32;
    let n = ising.n;
    out.reset(n);
    for i in 0..n {
        // every grid fits in i16/i32 (≤ 16 bits), so the casts are exact
        out.h[i] = rounding.round(ising.h[i] * scale, rng).clamp(-gridf, gridf) as i32;
    }
    for i in 0..n {
        let row = &ising.j[i * n..(i + 1) * n];
        for j in (i + 1)..n {
            let q = rounding.round(row[j] * scale, rng).clamp(-gridf, gridf) as i16;
            out.j[i * n + j] = q;
            out.j[j * n + i] = q;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check_default;

    #[test]
    fn deterministic_rounds_to_nearest() {
        let mut rng = Pcg32::seeded(1);
        let r = Rounding::Deterministic;
        assert_eq!(r.round(1.4, &mut rng), 1.0);
        assert_eq!(r.round(1.5, &mut rng), 2.0);
        assert_eq!(r.round(-1.4, &mut rng), -1.0);
        assert_eq!(r.round(-1.6, &mut rng), -2.0);
        assert_eq!(r.round(3.0, &mut rng), 3.0);
    }

    #[test]
    fn stochastic_is_unbiased() {
        let mut rng = Pcg32::seeded(2);
        let v = 2.3f32;
        let n = 50_000;
        let mean: f64 = (0..n)
            .map(|_| Rounding::Stochastic.round(v, &mut rng) as f64)
            .sum::<f64>()
            / n as f64;
        assert!((mean - 2.3).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn stoch5050_is_biased_toward_half() {
        let mut rng = Pcg32::seeded(3);
        let v = 2.9f32; // nearest is 3; 50/50 averages 2.5
        let n = 50_000;
        let mean: f64 = (0..n)
            .map(|_| Rounding::Stoch5050.round(v, &mut rng) as f64)
            .sum::<f64>()
            / n as f64;
        assert!((mean - 2.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn integers_pass_through_unchanged() {
        let mut rng = Pcg32::seeded(4);
        for r in [
            Rounding::Deterministic,
            Rounding::Stoch5050,
            Rounding::Stochastic,
        ] {
            for v in [-3.0f32, 0.0, 5.0] {
                assert_eq!(r.round(v, &mut rng), v, "{r} {v}");
            }
        }
    }

    #[test]
    fn quantize_properties() {
        check_default("quantize invariants", 99, |rng| {
            let n = 4 + rng.below(12) as usize;
            let mut ising = Ising::new(n);
            for i in 0..n {
                ising.h[i] = rng.range_f32(-8.0, 8.0);
                for j in (i + 1)..n {
                    let v = rng.range_f32(-2.0, 2.0);
                    ising.set_pair(i, j, v);
                }
            }
            let precision = match rng.below(3) {
                0 => Precision::Fixed(4),
                1 => Precision::Fixed(6),
                _ => Precision::CobiInt,
            };
            let rounding = match rng.below(3) {
                0 => Rounding::Deterministic,
                1 => Rounding::Stoch5050,
                _ => Rounding::Stochastic,
            };
            let q = quantize(&ising, precision, rounding, rng);
            let grid = precision.grid_max().unwrap() as f32;
            for (idx, &v) in q.h.iter().chain(q.j.iter()).enumerate() {
                crate::prop_assert!(v.fract() == 0.0, "non-integer at {idx}: {v}");
                crate::prop_assert!(v.abs() <= grid, "out of grid at {idx}: {v}");
            }
            // symmetry + zero diagonal preserved
            for i in 0..n {
                crate::prop_assert!(q.jij(i, i) == 0.0, "diag {i}");
                for j in 0..n {
                    crate::prop_assert!(
                        q.jij(i, j) == q.jij(j, i),
                        "asymmetric at ({i},{j})"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn quantize_into_is_draw_for_draw_identical_to_quantize() {
        // same seed, every precision/rounding combo: the integer output
        // must equal the f32 output value-for-value, and both must leave
        // the RNG in the same state (pinned by a follow-up draw)
        use crate::ising::QuantIsing;
        let mut ising = Ising::new(10);
        {
            let mut rng = Pcg32::seeded(41);
            for i in 0..10 {
                ising.h[i] = rng.range_f32(-6.0, 6.0);
                for j in (i + 1)..10 {
                    ising.set_pair(i, j, rng.range_f32(-2.0, 2.0));
                }
            }
        }
        let mut out = QuantIsing::default();
        for precision in [Precision::CobiInt, Precision::Fixed(4), Precision::Fixed(8)] {
            for rounding in [
                Rounding::Deterministic,
                Rounding::Stoch5050,
                Rounding::Stochastic,
            ] {
                let mut rng_a = Pcg32::seeded(99);
                let mut rng_b = Pcg32::seeded(99);
                let f = quantize(&ising, precision, rounding, &mut rng_a);
                assert!(quantize_into(&ising, precision, rounding, &mut rng_b, &mut out));
                assert_eq!(out.n, f.n);
                for i in 0..10 {
                    assert_eq!(out.h[i] as f32, f.h[i], "{precision} {rounding} h[{i}]");
                    for j in 0..10 {
                        assert_eq!(
                            out.jij(i, j) as f32,
                            f.jij(i, j),
                            "{precision} {rounding} J[{i},{j}]"
                        );
                    }
                }
                assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "RNG streams diverged");
            }
        }
    }

    #[test]
    fn quantize_into_saturates_exactly_at_the_precision_bounds() {
        // Fixed(16) is the widest grid: grid_max = 2^15 - 1 = 32767 =
        // i16::MAX for J and well inside i32 for h. Coefficients AT the
        // instance max must land exactly on ±grid_max — never on
        // ±2^(b-1) = ±32768, which would wrap the i16 storage — under
        // every rounding scheme (stochastic rounding may try to round a
        // float-error hair past the edge; the clamp must catch it
        // BEFORE the integer cast).
        use crate::ising::QuantIsing;
        let mut ising = Ising::new(6);
        ising.h[0] = 0.3; // max |h|: scale = grid / 0.3 is inexact in f32
        ising.h[1] = -0.3;
        ising.set_pair(2, 3, 0.3); // a J at the joint max too
        ising.set_pair(4, 5, -0.3);
        let mut out = QuantIsing::default();
        for rounding in [
            Rounding::Deterministic,
            Rounding::Stoch5050,
            Rounding::Stochastic,
        ] {
            let mut rng = Pcg32::seeded(17);
            assert!(quantize_into(&ising, Precision::Fixed(16), rounding, &mut rng, &mut out));
            // the scaled max is 32767 up to one f32 ulp of error, so the
            // deterministic scheme lands exactly on the edge; stochastic
            // schemes may resolve the sub-ulp fraction one step down but
            // must NEVER clear the edge (the clamp runs before the
            // integer cast, so ±32768 = i16 wraparound is unreachable)
            match rounding {
                Rounding::Deterministic => {
                    assert_eq!(out.h[0], 32767, "max h must sit on the grid edge");
                    assert_eq!(out.h[1], -32767, "grid is symmetric, not two's-complement");
                    assert_eq!(out.jij(2, 3), 32767, "max J must sit on the grid edge");
                    assert_eq!(out.jij(4, 5), -32767);
                }
                _ => {
                    assert!(out.h[0] >= 32766 && out.h[0] <= 32767, "{rounding}: {}", out.h[0]);
                    assert!(out.h[1] <= -32766 && out.h[1] >= -32767, "{rounding}: {}", out.h[1]);
                    assert!(out.jij(2, 3) >= 32766 && out.jij(2, 3) <= 32767, "{rounding}");
                    assert!(out.jij(4, 5) <= -32766 && out.jij(4, 5) >= -32767, "{rounding}");
                }
            }
            for i in 0..6 {
                assert!(out.h[i].abs() <= 32767, "{rounding}: h[{i}] off-grid");
                for j in 0..6 {
                    // i16::MIN (-32768) is representable but off-grid:
                    // saturation must never produce it
                    assert!(out.jij(i, j) > i16::MIN as i32, "{rounding}: J[{i},{j}] wrapped");
                }
            }
        }
    }

    #[test]
    fn stochastic_quantize_into_is_deterministic_on_a_reused_scratch_buffer() {
        // two consecutive quantize_into calls on the SAME scratch buffer
        // with identically-seeded RNGs must agree exactly — buffer reuse
        // (including shrinking from a larger instance) can never leak
        // stale coefficients or perturb the draw stream
        use crate::ising::QuantIsing;
        let build = |seed: u64, n: usize| {
            let mut rng = Pcg32::seeded(seed);
            let mut ising = Ising::new(n);
            for i in 0..n {
                ising.h[i] = rng.range_f32(-5.0, 5.0);
                for j in (i + 1)..n {
                    ising.set_pair(i, j, rng.range_f32(-2.0, 2.0));
                }
            }
            ising
        };
        let big = build(1, 14);
        let small = build(2, 9);
        let mut reused = QuantIsing::default();
        // grow the buffer with the big instance first...
        let mut rng = Pcg32::seeded(5);
        assert!(quantize_into(&big, Precision::CobiInt, Rounding::Stochastic, &mut rng, &mut reused));
        // ...then quantize the small one into the same (dirty) buffer
        let mut rng_a = Pcg32::seeded(9);
        assert!(quantize_into(&small, Precision::CobiInt, Rounding::Stochastic, &mut rng_a, &mut reused));
        let reused_h = reused.h.clone();
        let reused_j = reused.j.clone();

        let mut fresh = QuantIsing::default();
        let mut rng_b = Pcg32::seeded(9);
        assert!(quantize_into(&small, Precision::CobiInt, Rounding::Stochastic, &mut rng_b, &mut fresh));
        assert_eq!(reused.n, 9);
        assert_eq!(reused_h, fresh.h, "stale h leaked through buffer reuse");
        assert_eq!(reused_j, fresh.j, "stale J leaked through buffer reuse");
        // the RNGs end in the same state: the draw streams were identical
        assert_eq!(rng_a.next_u64(), rng_b.next_u64());
        // and an immediate second call on the same buffer replays exactly
        let mut rng_c = Pcg32::seeded(9);
        assert!(quantize_into(&small, Precision::CobiInt, Rounding::Stochastic, &mut rng_c, &mut reused));
        assert_eq!(reused.h, fresh.h);
        assert_eq!(reused.j, fresh.j);
    }

    #[test]
    fn quantize_into_declines_fp_without_consuming_rng() {
        use crate::ising::QuantIsing;
        let mut ising = Ising::new(4);
        ising.h[0] = 1.234;
        let mut out = QuantIsing::new(2);
        let mut rng = Pcg32::seeded(7);
        let before = rng.clone().next_u64();
        assert!(!quantize_into(&ising, Precision::Fp, Rounding::Stochastic, &mut rng, &mut out));
        assert_eq!(rng.next_u64(), before, "FP decline must not draw");
        assert_eq!(out.n, 2, "FP decline must not touch the buffer");
    }

    #[test]
    fn fp_quantize_is_identity() {
        let mut rng = Pcg32::seeded(5);
        let mut ising = Ising::new(6);
        ising.h[0] = 1.234;
        ising.set_pair(0, 1, -0.77);
        let q = quantize(&ising, Precision::Fp, Rounding::Stochastic, &mut rng);
        assert_eq!(q, ising);
    }

    #[test]
    fn deterministic_quantize_reproducible() {
        let mut rng1 = Pcg32::seeded(6);
        let mut rng2 = Pcg32::seeded(7); // different RNG must not matter
        let mut ising = Ising::new(8);
        for i in 0..8 {
            ising.h[i] = i as f32 * 0.37 - 1.0;
            for j in (i + 1)..8 {
                ising.set_pair(i, j, (i * j) as f32 * 0.11 - 0.3);
            }
        }
        let a = quantize(&ising, Precision::Fixed(5), Rounding::Deterministic, &mut rng1);
        let b = quantize(&ising, Precision::Fixed(5), Rounding::Deterministic, &mut rng2);
        assert_eq!(a, b);
    }

    #[test]
    fn max_coefficient_lands_on_grid_edge() {
        let mut rng = Pcg32::seeded(8);
        let mut ising = Ising::new(4);
        ising.h[0] = 10.0; // max abs
        ising.set_pair(1, 2, 5.0);
        let q = quantize(&ising, Precision::CobiInt, Rounding::Deterministic, &mut rng);
        assert_eq!(q.h[0], 14.0);
        assert_eq!(q.jij(1, 2), 7.0);
    }
}
