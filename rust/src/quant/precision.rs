//! Precision models (paper §III-B / §IV): floating point, fixed-point
//! b-bit, and the COBI-native integer range [-14, +14].
//!
//! A precision defines the integer grid the Ising coefficients are scaled
//! onto before rounding. The scale is JOINT over h and J (one divisor for
//! the whole instance): preserving the h/J magnitude ratio is precisely
//! what makes low precision hard and the paper's bias term valuable —
//! per-vector scales would silently fix the imbalance and erase the
//! phenomenon under study.

use std::fmt;
use std::str::FromStr;

/// Quantization grid for solver instances.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    /// Full floating point (no quantization).
    Fp,
    /// Signed fixed-point with `b` bits: grid [-(2^(b-1)-1), +(2^(b-1)-1)].
    Fixed(u8),
    /// COBI-native integer weights: [-14, +14] (5-bit DAC, paper §II-B).
    CobiInt,
}

impl Precision {
    /// Largest representable magnitude on the integer grid (None for FP).
    pub fn grid_max(&self) -> Option<i32> {
        match self {
            Precision::Fp => None,
            Precision::Fixed(b) => {
                assert!((2..=16).contains(b), "unsupported bit width {b}");
                Some((1i32 << (b - 1)) - 1)
            }
            Precision::CobiInt => Some(14),
        }
    }

    /// Scale factor mapping coefficients with max-abs `max_abs` onto the
    /// grid; values are then `round(v * scale)` in [-grid_max, grid_max].
    pub fn scale_for(&self, max_abs: f32) -> Option<f32> {
        self.grid_max().map(|g| {
            if max_abs <= 0.0 {
                1.0
            } else {
                g as f32 / max_abs
            }
        })
    }

    /// All precisions the paper sweeps, in presentation order.
    pub fn paper_sweep() -> Vec<Precision> {
        vec![
            Precision::Fp,
            Precision::Fixed(8),
            Precision::Fixed(7),
            Precision::Fixed(6),
            Precision::Fixed(5),
            Precision::Fixed(4),
            Precision::CobiInt,
        ]
    }
}

impl fmt::Display for Precision {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Precision::Fp => write!(f, "fp"),
            Precision::Fixed(b) => write!(f, "{b}bit"),
            Precision::CobiInt => write!(f, "int14"),
        }
    }
}

impl FromStr for Precision {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let t = s.trim().to_ascii_lowercase();
        match t.as_str() {
            "fp" | "fp32" | "float" | "full" => Ok(Precision::Fp),
            "cobi" | "int14" | "cobiint" => Ok(Precision::CobiInt),
            _ => {
                if let Some(b) = t.strip_suffix("bit") {
                    let bits: u8 = b.parse().map_err(|_| format!("bad precision '{s}'"))?;
                    if !(2..=16).contains(&bits) {
                        return Err(format!("precision bits out of range: {s}"));
                    }
                    Ok(Precision::Fixed(bits))
                } else {
                    Err(format!(
                        "bad precision '{s}' (expected fp, <b>bit, or int14)"
                    ))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_maxima() {
        assert_eq!(Precision::Fp.grid_max(), None);
        assert_eq!(Precision::Fixed(4).grid_max(), Some(7));
        assert_eq!(Precision::Fixed(5).grid_max(), Some(15));
        assert_eq!(Precision::Fixed(6).grid_max(), Some(31));
        assert_eq!(Precision::Fixed(8).grid_max(), Some(127));
        assert_eq!(Precision::CobiInt.grid_max(), Some(14));
    }

    #[test]
    fn parse_round_trip() {
        for p in Precision::paper_sweep() {
            let s = p.to_string();
            assert_eq!(s.parse::<Precision>().unwrap(), p, "{s}");
        }
        assert_eq!("FP".parse::<Precision>().unwrap(), Precision::Fp);
        assert_eq!("cobi".parse::<Precision>().unwrap(), Precision::CobiInt);
        assert!("17".parse::<Precision>().is_err());
        assert!("99bit".parse::<Precision>().is_err());
    }

    #[test]
    fn scale_maps_max_onto_grid_edge() {
        let s = Precision::CobiInt.scale_for(7.0).unwrap();
        assert!((7.0 * s - 14.0).abs() < 1e-6);
        assert!(Precision::Fp.scale_for(7.0).is_none());
    }
}
