//! Quantization: precision grids + rounding schemes (paper §III-B, §IV-A).
//!
//! The COBI array programs integer couplings in a narrow DAC range, so
//! every floating-point Hamiltonian must be mapped onto a grid before it
//! can run on hardware. [`Precision`] names the grids (the chip's int
//! ±14, plus 4–8-bit fixed grids for the precision sweep and `fp` as the
//! no-op); [`quantize`]/[`Rounding`] implement the mapping — the
//! `stochastic` scheme re-samples the Hamiltonian per refinement
//! iteration, which is the diversity §IV-A exploits to recover FP-level
//! quality from low-precision solves. `preprocess` holds the shared
//! scale/clip step. The hot path uses [`quantize_into`], which writes
//! straight into a reusable integer instance
//! ([`QuantIsing`](crate::ising::QuantIsing)) with the exact RNG draw
//! order of [`quantize`] — no intermediate `f32` matrix, no allocation.

pub mod precision;
pub mod preprocess;
pub mod rounding;

pub use precision::Precision;
pub use rounding::{quantize, quantize_into, Rounding};
