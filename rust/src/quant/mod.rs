//! Quantization: precision grids + rounding schemes (paper §III-B, §IV-A).

pub mod precision;
pub mod preprocess;
pub mod rounding;

pub use precision::Precision;
pub use rounding::{quantize, Rounding};
