//! General k-of-n selection QUBOs — the paper's claimed generalization
//! (§I contribution 2: the bias shift "can be applied to any problem
//! formulation that requires k of n variables to be chosen, such as [14],
//! [15] and the traveling salesman problem in [16]").
//!
//! A [`KofnProblem`] is any maximize-value / minimize-pairwise-cost
//! selection of exactly k items; ES is the special case value = mu,
//! cost = λβ. This module provides the generic QUBO/Ising construction
//! and the same median bias rule, plus two concrete instantiations used
//! by the `kofn_bias` example and the ablation benches:
//!
//!   * facility dispersion (select k sites maximizing spread — the
//!     vehicle-routing-flavoured workload of [14]);
//!   * influence-style seed selection (select k seeds with high
//!     individual reach and low overlap — the workload of [15]).

use crate::ising::formulation::EsProblem;
use crate::ising::model::{Ising, Qubo};
use crate::util::rng::Pcg32;
use crate::util::stats::median_f32;

/// Generic k-of-n selection: maximize Σ value_i x_i − Σ_{i≠j} cost_ij x_i x_j
/// subject to Σ x_i = k.
#[derive(Debug, Clone)]
pub struct KofnProblem {
    /// Per-item values.
    pub value: Vec<f32>,
    /// Pairwise cost, row-major n*n, symmetric, zero diagonal.
    pub cost: Vec<f32>,
    /// Selection cardinality k.
    pub k: usize,
}

impl KofnProblem {
    /// Number of items.
    pub fn n(&self) -> usize {
        self.value.len()
    }

    /// Objective of `selected` under this instance.
    pub fn objective(&self, selected: &[usize]) -> f64 {
        let n = self.n();
        let mut obj = 0.0f64;
        for &i in selected {
            obj += self.value[i] as f64;
        }
        for &i in selected {
            for &j in selected {
                if i != j {
                    obj -= self.cost[i * n + j] as f64;
                }
            }
        }
        obj
    }

    /// Penalty weight: any single item's value gain must not beat the
    /// constraint penalty (mirror of EsProblem::gamma).
    pub fn gamma(&self) -> f32 {
        let vm = self.value.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        let cm = self.cost.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        vm + cm
    }

    /// QUBO with optional linear bias (bias = 0 gives the original
    /// formulation; Eq. 10 shape).
    pub fn qubo(&self, bias: f32) -> Qubo {
        let n = self.n();
        let gamma = self.gamma();
        let k = self.k as f32;
        let mut q = Qubo::new(n);
        for i in 0..n {
            q.linear[i] = -(self.value[i] + bias) - 2.0 * gamma * k + gamma;
            for j in 0..n {
                if j != i {
                    q.quad[i * n + j] = self.cost[i * n + j] + gamma;
                }
            }
        }
        q
    }

    /// Original and bias-improved Ising formulations (Eq. 12 rule).
    pub fn formulate(&self, improved: bool) -> Ising {
        let (orig, _) = self.qubo(0.0).to_ising();
        if !improved {
            return orig;
        }
        let mu_b = 2.0 * (median_f32(&orig.h) - median_f32(&orig.upper_couplings()));
        self.qubo(mu_b).to_ising().0
    }

    /// View as an EsProblem (λ folded into cost) so the exact solver and
    /// refinement loop apply unchanged.
    pub fn as_es(&self) -> EsProblem {
        EsProblem {
            mu: self.value.clone(),
            beta: self.cost.clone(),
            lambda: 1.0,
            m: self.k,
        }
    }
}

/// Facility dispersion instance: n sites on the unit square; value =
/// site quality, cost = closeness (1 − distance) so selected sites repel.
pub fn facility_dispersion(rng: &mut Pcg32, n: usize, k: usize) -> KofnProblem {
    let pts: Vec<(f32, f32)> = (0..n).map(|_| (rng.f32(), rng.f32())).collect();
    let value: Vec<f32> = (0..n).map(|_| rng.range_f32(0.5, 1.0)).collect();
    let mut cost = vec![0.0f32; n * n];
    for i in 0..n {
        for j in (i + 1)..n {
            let dx = pts[i].0 - pts[j].0;
            let dy = pts[i].1 - pts[j].1;
            let d = (dx * dx + dy * dy).sqrt();
            let c = (1.0 - d / std::f32::consts::SQRT_2).max(0.0) * 0.4;
            cost[i * n + j] = c;
            cost[j * n + i] = c;
        }
    }
    KofnProblem { value, cost, k }
}

/// Influence-maximization-style instance: seeds with random reach and
/// overlapping audiences (random bipartite coverage model, pairwise
/// overlap as cost).
pub fn influence_seeds(rng: &mut Pcg32, n: usize, k: usize, audience: usize) -> KofnProblem {
    // each seed covers a random subset of the audience
    let mut covers: Vec<Vec<bool>> = Vec::with_capacity(n);
    for _ in 0..n {
        let p = rng.range_f32(0.05, 0.3) as f64;
        covers.push((0..audience).map(|_| rng.bernoulli(p)).collect());
    }
    let value: Vec<f32> = covers
        .iter()
        .map(|c| c.iter().filter(|&&b| b).count() as f32 / audience as f32)
        .collect();
    let mut cost = vec![0.0f32; n * n];
    for i in 0..n {
        for j in (i + 1)..n {
            let overlap = covers[i]
                .iter()
                .zip(&covers[j])
                .filter(|(a, b)| **a && **b)
                .count() as f32
                / audience as f32;
            cost[i * n + j] = overlap;
            cost[j * n + i] = overlap;
        }
    }
    KofnProblem { value, cost, k }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ising::model::selected_indices;
    use crate::quant::{quantize, Precision, Rounding};
    use crate::solvers::exact;
    use crate::solvers::tabu::TabuSolver;
    use crate::solvers::IsingSolver;

    #[test]
    fn kofn_matches_es_objective() {
        let mut rng = Pcg32::seeded(1);
        let p = facility_dispersion(&mut rng, 12, 4);
        let es = p.as_es();
        let sel = [0usize, 3, 6, 9];
        assert!((p.objective(&sel) - es.objective(&sel)).abs() < 1e-9);
    }

    #[test]
    fn original_formulation_ground_state_is_feasible_and_optimal() {
        let mut rng = Pcg32::seeded(2);
        let p = influence_seeds(&mut rng, 10, 3, 64);
        let ising = p.formulate(false);
        let (_, gs, _) = crate::solvers::exact::ising_ground_exhaustive(&ising);
        let sel = selected_indices(&gs);
        assert_eq!(sel.len(), 3, "cardinality violated: {sel:?}");
        let best = exact::solve_max(&p.as_es());
        assert!((p.objective(&sel) - best.objective).abs() < 1e-6);
    }

    #[test]
    fn bias_rebalances_generic_kofn_medians() {
        let mut rng = Pcg32::seeded(3);
        let p = facility_dispersion(&mut rng, 20, 6);
        let orig = p.formulate(false);
        let impr = p.formulate(true);
        let mj = median_f32(&orig.upper_couplings());
        let d0 = (median_f32(&orig.h) - mj).abs();
        let d1 = (median_f32(&impr.h) - mj).abs();
        assert!(d1 < 0.2 * d0 + 1e-4, "bias failed to rebalance: {d0} -> {d1}");
    }

    #[test]
    fn bias_improves_quantized_solution_quality_on_kofn() {
        // the paper's generalization claim, tested end-to-end on the
        // influence workload: at int14 the improved formulation should be
        // at least as good on average as the original
        let mut sums = [0.0f64; 2];
        for seed in 0..6u64 {
            let mut rng = Pcg32::seeded(100 + seed);
            let p = influence_seeds(&mut rng, 14, 4, 64);
            let es = p.as_es();
            let bounds = crate::ising::exact_bounds(&es);
            for (idx, improved) in [(0usize, false), (1, true)] {
                let ising = p.formulate(improved);
                let mut qrng = Pcg32::seeded(7 + seed);
                let inst = quantize(&ising, Precision::CobiInt, Rounding::Deterministic, &mut qrng);
                let mut solver = TabuSolver::seeded(50 + seed);
                let solved = solver.solve(&inst);
                let sel = crate::refine::repair_selection(&es, selected_indices(&solved.spins));
                sums[idx] += bounds.normalize(es.objective(&sel));
            }
        }
        assert!(
            sums[1] >= sums[0] - 0.3,
            "improved {:.3} should not trail original {:.3} badly",
            sums[1],
            sums[0]
        );
    }

    #[test]
    fn generators_are_deterministic() {
        let a = facility_dispersion(&mut Pcg32::seeded(5), 8, 3);
        let b = facility_dispersion(&mut Pcg32::seeded(5), 8, 3);
        assert_eq!(a.value, b.value);
        assert_eq!(a.cost, b.cost);
    }
}
