//! Integer-domain Ising instance — the native representation of a
//! quantized Hamiltonian.
//!
//! The COBI array programs **integer** couplings (paper §II-B), yet the
//! original solve pipeline round-tripped every quantized instance through
//! dense `f32` matrices with `f64` scalar inner loops. [`QuantIsing`]
//! stores what the hardware actually sees: `h: Vec<i32>`, `j: Vec<i16>`,
//! row-major, with the SAME conventions as [`Ising`] — symmetric `j` with
//! both (i,j) and (j,i) populated, zero diagonal, and ordered-pair energy
//! sums `H(s) = Σ_i h_i s_i + Σ_{i≠j} J_ij s_i s_j`.
//!
//! ## Exact-tie rule
//!
//! On the integer path all energies, local fields and move deltas are
//! `i64` accumulators, so two candidate moves tie **iff their integer
//! deltas are equal** — the `TIE_EPS = 1e-12` tolerance of the `f64` path
//! is retired here, not approximated. The two rules agree exactly: every
//! supported grid fits in 16 bits, so coefficients, fields and energies
//! are small integers that `f64` represents exactly, and for integers
//! `a < b - 1e-12` ⟺ `a < b`. This is what makes the integer kernels
//! **bit-identical** to the `f64` kernels on quantized instances (pinned
//! by per-solver equivalence tests), which in turn is what lets the
//! solvers switch domains transparently without changing one summary
//! byte.
//!
//! ## Accumulator headroom
//!
//! `try_copy_from` admits `|J| ≤ i16::MAX` and `|h| ≤ 1e9`. With
//! `n ≤ MAX_SENTENCES = 128` (and far beyond), energies are bounded by
//! `n·|h|max + n²·|J|max < 2^38`, and local fields by
//! `|h|max + 2n·|J|max < 2^31` — no `i64` overflow is reachable.

use super::model::Ising;

/// Largest `|J|` admitted into the `i16` coupling matrix.
pub const QUANT_J_ABS_MAX: f32 = i16::MAX as f32;
/// Largest `|h|` admitted into the `i32` field vector (far above any
/// quantization grid; bounds the `i64` accumulator analysis above).
pub const QUANT_H_ABS_MAX: f32 = 1e9;

/// Integer-valued Ising instance (minimization over s in {-1,+1}^n).
/// See the module docs for conventions and the exact-tie rule.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct QuantIsing {
    /// Number of spins.
    pub n: usize,
    /// Local fields h_i (integer grid values).
    pub h: Vec<i32>,
    /// Couplings J_ij, row-major n*n, symmetric, zero diagonal.
    pub j: Vec<i16>,
}

impl QuantIsing {
    /// Zero instance with `n` spins.
    pub fn new(n: usize) -> Self {
        Self {
            n,
            h: vec![0; n],
            j: vec![0; n * n],
        }
    }

    /// Resize to `n` spins with all coefficients zeroed. Reuses the
    /// existing buffers — no allocation once capacity has grown to the
    /// largest instance seen (the hot-path contract of `quantize_into`).
    pub fn reset(&mut self, n: usize) {
        self.n = n;
        self.h.clear();
        self.h.resize(n, 0);
        self.j.clear();
        self.j.resize(n * n, 0);
    }

    /// Coupling J_ij.
    #[inline]
    pub fn jij(&self, i: usize, j: usize) -> i32 {
        self.j[i * self.n + j] as i32
    }

    /// Set the symmetric pair (i,j) and (j,i).
    pub fn set_pair(&mut self, i: usize, j: usize, v: i16) {
        assert_ne!(i, j);
        self.j[i * self.n + j] = v;
        self.j[j * self.n + i] = v;
    }

    /// Ising energy, ordered-pair convention — exact integer arithmetic.
    pub fn energy(&self, s: &[i8]) -> i64 {
        debug_assert_eq!(s.len(), self.n);
        let mut e = 0i64;
        for i in 0..self.n {
            let si = s[i] as i64;
            let row = &self.j[i * self.n..(i + 1) * self.n];
            let mut acc = 0i64;
            for j in 0..self.n {
                acc += row[j] as i64 * s[j] as i64;
            }
            e += self.h[i] as i64 * si + si * acc;
        }
        e
    }

    /// Local field seen by spin i: L_i = h_i + 2 Σ_j J_ij s_j.
    /// Flipping spin i changes the energy by ΔE = -2 s_i L_i.
    pub fn local_field(&self, s: &[i8], i: usize) -> i64 {
        let row = &self.j[i * self.n..(i + 1) * self.n];
        let mut acc = 0i64;
        for j in 0..self.n {
            acc += row[j] as i64 * s[j] as i64;
        }
        self.h[i] as i64 + 2 * acc
    }

    /// Copy an integer-valued `f32` instance into this buffer. Returns
    /// `false` (leaving `self` unspecified) when any coefficient is
    /// non-integral, non-finite, or outside the admitted ranges — the
    /// caller then stays on the `f64` path. Reuses the buffers; no
    /// allocation in steady state.
    pub fn try_copy_from(&mut self, src: &Ising) -> bool {
        let n = src.n;
        self.n = n;
        self.h.clear();
        self.h.reserve(n);
        for &v in &src.h {
            if !(v.is_finite() && v.fract() == 0.0 && v.abs() <= QUANT_H_ABS_MAX) {
                return false;
            }
            self.h.push(v as i32);
        }
        self.j.clear();
        self.j.reserve(n * n);
        for &v in &src.j {
            if !(v.is_finite() && v.fract() == 0.0 && v.abs() <= QUANT_J_ABS_MAX) {
                return false;
            }
            self.j.push(v as i16);
        }
        true
    }

    /// Expand back to the `f32` representation (exact: every admitted
    /// integer is f32-representable). Mostly for tests and interop.
    pub fn to_ising(&self) -> Ising {
        Ising {
            n: self.n,
            h: self.h.iter().map(|&v| v as f32).collect(),
            j: self.j.iter().map(|&v| v as f32).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn int_glass(seed: u64, n: usize, max: i32) -> QuantIsing {
        let mut rng = Pcg32::seeded(seed);
        let mut q = QuantIsing::new(n);
        for i in 0..n {
            q.h[i] = rng.below(2 * max as u32 + 1) as i32 - max;
            for j in (i + 1)..n {
                let v = (rng.below(2 * max as u32 + 1) as i32 - max) as i16;
                q.set_pair(i, j, v);
            }
        }
        q
    }

    #[test]
    fn integer_energy_matches_f64_energy_exactly() {
        let mut rng = Pcg32::seeded(1);
        for seed in 0..10 {
            let q = int_glass(seed, 14, 14);
            let f = q.to_ising();
            let s: Vec<i8> = (0..14)
                .map(|_| if rng.bernoulli(0.5) { 1 } else { -1 })
                .collect();
            assert_eq!(q.energy(&s) as f64, f.energy(&s));
            for i in 0..14 {
                assert_eq!(q.local_field(&s, i) as f64, f.local_field(&s, i));
            }
        }
    }

    #[test]
    fn round_trip_through_f32_is_lossless() {
        let q = int_glass(3, 12, 14);
        let mut back = QuantIsing::default();
        assert!(back.try_copy_from(&q.to_ising()));
        assert_eq!(back, q);
    }

    #[test]
    fn try_copy_rejects_fractional_and_oversized() {
        let mut out = QuantIsing::default();
        let mut frac = Ising::new(4);
        frac.h[0] = 0.5;
        assert!(!out.try_copy_from(&frac));

        let mut big_j = Ising::new(4);
        big_j.set_pair(0, 1, 40_000.0); // > i16::MAX
        assert!(!out.try_copy_from(&big_j));

        let mut nan = Ising::new(4);
        nan.h[2] = f32::NAN;
        assert!(!out.try_copy_from(&nan));

        // integral instances in range are admitted
        let mut ok = Ising::new(4);
        ok.h[0] = -3.0;
        ok.set_pair(1, 2, 14.0);
        assert!(out.try_copy_from(&ok));
        assert_eq!(out.h[0], -3);
        assert_eq!(out.jij(1, 2), 14);
        assert_eq!(out.jij(2, 1), 14);
    }

    #[test]
    fn negative_zero_maps_to_zero() {
        let mut src = Ising::new(2);
        src.h[0] = -0.0;
        let mut out = QuantIsing::default();
        assert!(out.try_copy_from(&src));
        assert_eq!(out.h[0], 0);
    }

    #[test]
    fn reset_reuses_capacity_and_zeroes() {
        let mut q = int_glass(5, 10, 7);
        let hp = q.h.capacity();
        let jp = q.j.capacity();
        q.reset(8);
        assert_eq!(q.n, 8);
        assert!(q.h.iter().all(|&v| v == 0));
        assert!(q.j.iter().all(|&v| v == 0));
        assert!(q.h.capacity() >= hp.min(8));
        assert!(q.j.capacity() <= jp.max(64));
    }
}
