//! Ising/QUBO core: model types, ES formulations, objective evaluation.

pub mod formulation;
pub mod model;
pub mod kofn;
pub mod objective;

pub use formulation::{es_qubo, formulate, kofn_bias, EsIsing, EsProblem, Formulation};
pub use model::{selected_indices, selection_to_spins, Ising, Qubo};
pub use objective::{exact_bounds, normalized_objective, ObjectiveBounds};
