//! Ising/QUBO core: model types, ES formulations, objective evaluation.
//!
//! `model` holds the dense [`Qubo`]/[`Ising`] types and the exact
//! transformations between them; `quant_model` holds [`QuantIsing`], the
//! integer-domain twin the solver fast path runs on; `formulation` turns
//! an extractive-
//! summarization instance ([`EsProblem`]: relevance µ, redundancy β,
//! weight λ, budget M) into an Ising Hamiltonian via the paper's
//! original (Eq. 7–9) and improved bias-shift (Eq. 10–12) formulations;
//! `kofn` generalizes the bias shift to arbitrary k-of-n selection
//! QUBOs; `objective` evaluates Eq. 3 and the exact bounds behind the
//! Eq. 13 normalization that every experiment reports.

pub mod formulation;
pub mod model;
pub mod kofn;
pub mod objective;
pub mod quant_model;

pub use formulation::{es_qubo, formulate, kofn_bias, EsIsing, EsProblem, Formulation};
pub use model::{selected_indices, selection_to_spins, Ising, Qubo};
pub use quant_model::QuantIsing;
pub use objective::{exact_bounds, normalized_objective, ObjectiveBounds};
