//! Normalized-objective evaluation (paper Eq. 13).
//!
//! Every accuracy number in the paper is
//!     (obj − obj_min) / (obj_max − obj_min)
//! where obj is the Eq. 3 value of the solver's selection evaluated in
//! FLOATING POINT (quantization only ever affects the instance handed to
//! the solver), and obj_min/obj_max are the exact bounds over all
//! cardinality-M selections (the paper uses Gurobi; we use
//! `solvers::exact` — same optimum, see DESIGN.md §Substitutions).

use super::formulation::EsProblem;

/// Exact bounds of the Eq. 3 objective over all M-subsets.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ObjectiveBounds {
    /// Exact minimum objective.
    pub min: f64,
    /// Exact maximum objective.
    pub max: f64,
}

impl ObjectiveBounds {
    /// Normalize per Eq. 13, clamping tiny numeric overshoot.
    pub fn normalize(&self, obj: f64) -> f64 {
        let span = self.max - self.min;
        if span <= 1e-12 {
            // degenerate instance: every selection equivalent
            return 1.0;
        }
        ((obj - self.min) / span).clamp(0.0, 1.0)
    }
}

/// Compute exact bounds with the branch-and-bound exact solver.
pub fn exact_bounds(p: &EsProblem) -> ObjectiveBounds {
    let max = crate::solvers::exact::solve_max(p).objective;
    let min = crate::solvers::exact::solve_min(p).objective;
    ObjectiveBounds { min, max }
}

/// Normalized objective of a selection against precomputed bounds.
pub fn normalized_objective(p: &EsProblem, bounds: &ObjectiveBounds, selected: &[usize]) -> f64 {
    bounds.normalize(p.objective(selected))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalize_maps_bounds_to_unit_interval() {
        let b = ObjectiveBounds { min: -2.0, max: 6.0 };
        assert_eq!(b.normalize(-2.0), 0.0);
        assert_eq!(b.normalize(6.0), 1.0);
        assert_eq!(b.normalize(2.0), 0.5);
    }

    #[test]
    fn normalize_clamps_overshoot() {
        let b = ObjectiveBounds { min: 0.0, max: 1.0 };
        assert_eq!(b.normalize(1.0 + 1e-9), 1.0);
        assert_eq!(b.normalize(-1e-9), 0.0);
    }

    #[test]
    fn degenerate_bounds_normalize_to_one() {
        let b = ObjectiveBounds { min: 3.0, max: 3.0 };
        assert_eq!(b.normalize(3.0), 1.0);
    }
}
