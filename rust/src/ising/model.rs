//! QUBO / Ising model types and the exact transformations between them.
//!
//! Conventions (used consistently across the whole repo and matching the
//! L1/L2 kernels):
//!
//!   * Symmetric matrices are stored dense, row-major, with BOTH (i,j) and
//!     (j,i) populated and zero diagonal.
//!   * Pair sums run over ORDERED pairs i != j, i.e. each unordered pair
//!     contributes twice:  H(s) = Σ_i h_i s_i + Σ_{i≠j} J_ij s_i s_j.
//!   * Binary/spin change of variables: x_i = (1 + s_i) / 2, so s = +1
//!     means "sentence selected".
//!
//! With these conventions the QUBO -> Ising map (paper Eq. 6, written for
//! ordered sums) is
//!     h_i  = Q_ii / 2 + (1/2) Σ_{j≠i} Q_ij ,
//!     J_ij = Q_ij / 4 ,
//! plus a constant offset tracked for exactness tests.

/// Quadratic Unconstrained Binary Optimization instance (minimization).
#[derive(Debug, Clone, PartialEq)]
pub struct Qubo {
    /// Number of binary variables.
    pub n: usize,
    /// Linear coefficients Q_ii.
    pub linear: Vec<f32>,
    /// Quadratic coefficients Q_ij, row-major n*n, symmetric, zero diag.
    pub quad: Vec<f32>,
}

impl Qubo {
    /// Zero QUBO over `n` variables.
    pub fn new(n: usize) -> Self {
        Self {
            n,
            linear: vec![0.0; n],
            quad: vec![0.0; n * n],
        }
    }

    /// Coefficient Q_ij.
    #[inline]
    pub fn q(&self, i: usize, j: usize) -> f32 {
        self.quad[i * self.n + j]
    }

    /// Set the symmetric pair (i,j) and (j,i).
    pub fn set_pair(&mut self, i: usize, j: usize, v: f32) {
        assert_ne!(i, j, "diagonal belongs to `linear`");
        self.quad[i * self.n + j] = v;
        self.quad[j * self.n + i] = v;
    }

    /// Energy of a binary assignment (ordered-pair convention).
    pub fn energy(&self, x: &[u8]) -> f64 {
        debug_assert_eq!(x.len(), self.n);
        let mut e = 0.0f64;
        for i in 0..self.n {
            if x[i] == 0 {
                continue;
            }
            e += self.linear[i] as f64;
            let row = &self.quad[i * self.n..(i + 1) * self.n];
            for j in 0..self.n {
                if x[j] != 0 {
                    e += row[j] as f64;
                }
            }
        }
        e
    }

    /// Exact QUBO -> Ising transformation; returns the Ising instance and
    /// the constant offset c such that  H_qubo(x(s)) = H_ising(s) + c.
    pub fn to_ising(&self) -> (Ising, f64) {
        let n = self.n;
        let mut ising = Ising::new(n);
        let mut offset = 0.0f64;
        for i in 0..n {
            // iterate the row slice directly (same idiom as Ising::energy)
            // instead of a bounds-checked multiply per element; summation
            // order is unchanged, so results stay bit-identical
            let row = &self.quad[i * n..(i + 1) * n];
            let mut row_sum = 0.0f64;
            for (j, &v) in row.iter().enumerate() {
                if j != i {
                    row_sum += v as f64;
                }
            }
            ising.h[i] = (self.linear[i] as f64 / 2.0 + row_sum / 2.0) as f32;
            offset += self.linear[i] as f64 / 2.0 + row_sum / 4.0;
            for (j, &v) in row.iter().enumerate() {
                if j != i {
                    ising.j[i * n + j] = v / 4.0;
                }
            }
        }
        (ising, offset)
    }
}

/// Ising instance (minimization over s in {-1,+1}^n).
#[derive(Debug, Clone, PartialEq)]
pub struct Ising {
    /// Number of spins.
    pub n: usize,
    /// Local fields h_i.
    pub h: Vec<f32>,
    /// Couplings J_ij, row-major n*n, symmetric, zero diag.
    pub j: Vec<f32>,
}

impl Ising {
    /// Zero instance with `n` spins.
    pub fn new(n: usize) -> Self {
        Self {
            n,
            h: vec![0.0; n],
            j: vec![0.0; n * n],
        }
    }

    /// Coupling J_ij.
    #[inline]
    pub fn jij(&self, i: usize, j: usize) -> f32 {
        self.j[i * self.n + j]
    }

    /// Set J_ij = J_ji = v.
    pub fn set_pair(&mut self, i: usize, j: usize, v: f32) {
        assert_ne!(i, j);
        self.j[i * self.n + j] = v;
        self.j[j * self.n + i] = v;
    }

    /// Ising energy, ordered-pair convention (= s^T J s + h^T s).
    pub fn energy(&self, s: &[i8]) -> f64 {
        debug_assert_eq!(s.len(), self.n);
        let mut e = 0.0f64;
        for i in 0..self.n {
            let si = s[i] as f64;
            e += self.h[i] as f64 * si;
            let row = &self.j[i * self.n..(i + 1) * self.n];
            let mut acc = 0.0f64;
            for j in 0..self.n {
                acc += row[j] as f64 * s[j] as f64;
            }
            e += si * acc;
        }
        e
    }

    /// Local field seen by spin i: L_i = h_i + 2 Σ_j J_ij s_j.
    /// Flipping spin i changes the energy by ΔE = -2 s_i L_i.
    pub fn local_field(&self, s: &[i8], i: usize) -> f64 {
        let row = &self.j[i * self.n..(i + 1) * self.n];
        let mut acc = 0.0f64;
        for j in 0..self.n {
            acc += row[j] as f64 * s[j] as f64;
        }
        self.h[i] as f64 + 2.0 * acc
    }

    /// Off-diagonal coefficient list (upper triangle), used by median
    /// statistics in the improved formulation. Callers that only need a
    /// sort-and-pick statistic should use [`Ising::upper_couplings_into`]
    /// with a reusable scratch buffer instead.
    pub fn upper_couplings(&self) -> Vec<f32> {
        let mut out = Vec::new();
        self.upper_couplings_into(&mut out);
        out
    }

    /// Fill `out` with the upper-triangle couplings (same element order as
    /// [`Ising::upper_couplings`]: rows in order, `j > i` within a row),
    /// reusing `out`'s allocation. Copies row slices directly — half the
    /// scan of a full-matrix walk, no per-element index arithmetic.
    pub fn upper_couplings_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(self.n * self.n.saturating_sub(1) / 2);
        for i in 0..self.n {
            out.extend_from_slice(&self.j[i * self.n + i + 1..(i + 1) * self.n]);
        }
    }

    /// Largest absolute coefficient (h and J jointly) — quantization
    /// scale. Scans only the upper triangle of J: the symmetry invariant
    /// (`set_pair` writes both mirrors) makes the lower triangle
    /// redundant, halving the matrix walk.
    pub fn max_abs(&self) -> f32 {
        let hm = self.h.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        let mut jm = 0.0f32;
        for i in 0..self.n {
            for &x in &self.j[i * self.n + i + 1..(i + 1) * self.n] {
                jm = jm.max(x.abs());
            }
        }
        hm.max(jm)
    }

    /// Pad to `n_pad` spins (zero fields/couplings on the new spins) —
    /// the COBI artifacts are compiled for a fixed 64-spin problem.
    pub fn padded(&self, n_pad: usize) -> Ising {
        let mut out = Ising {
            n: 0,
            h: Vec::new(),
            j: Vec::new(),
        };
        self.padded_into(n_pad, &mut out);
        out
    }

    /// As [`Ising::padded`], writing into a reusable buffer: `out` is
    /// resized, zeroed and filled — no allocation once its capacity has
    /// grown to `n_pad` (the device hot-path contract).
    pub fn padded_into(&self, n_pad: usize, out: &mut Ising) {
        assert!(n_pad >= self.n);
        out.n = n_pad;
        out.h.clear();
        out.h.resize(n_pad, 0.0);
        out.h[..self.n].copy_from_slice(&self.h);
        out.j.clear();
        out.j.resize(n_pad * n_pad, 0.0);
        for i in 0..self.n {
            out.j[i * n_pad..i * n_pad + self.n]
                .copy_from_slice(&self.j[i * self.n..(i + 1) * self.n]);
        }
    }
}

/// Spin -> binary selection: indices with s_i = +1.
pub fn selected_indices(s: &[i8]) -> Vec<usize> {
    s.iter()
        .enumerate()
        .filter_map(|(i, &v)| (v > 0).then_some(i))
        .collect()
}

/// Binary selection -> spins over n variables.
pub fn selection_to_spins(n: usize, selected: &[usize]) -> Vec<i8> {
    let mut s = vec![-1i8; n];
    for &i in selected {
        s[i] = 1;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn random_qubo(rng: &mut Pcg32, n: usize) -> Qubo {
        let mut q = Qubo::new(n);
        for i in 0..n {
            q.linear[i] = rng.range_f32(-2.0, 2.0);
            for j in (i + 1)..n {
                q.set_pair(i, j, rng.range_f32(-1.0, 1.0));
            }
        }
        q
    }

    #[test]
    fn qubo_ising_equivalence_exhaustive() {
        // H_qubo(x) == H_ising(s(x)) + offset for every assignment
        let mut rng = Pcg32::seeded(1);
        for _ in 0..20 {
            let q = random_qubo(&mut rng, 6);
            let (ising, offset) = q.to_ising();
            for bits in 0..(1u32 << 6) {
                let x: Vec<u8> = (0..6).map(|i| ((bits >> i) & 1) as u8).collect();
                let s: Vec<i8> = x.iter().map(|&b| if b == 1 { 1 } else { -1 }).collect();
                let eq = q.energy(&x);
                let ei = ising.energy(&s) + offset;
                assert!(
                    (eq - ei).abs() < 1e-3,
                    "qubo={eq} ising+c={ei} bits={bits:06b}"
                );
            }
        }
    }

    #[test]
    fn qubo_ising_argmin_preserved() {
        let mut rng = Pcg32::seeded(2);
        let q = random_qubo(&mut rng, 8);
        let (ising, _) = q.to_ising();
        let mut best_q = (f64::INFINITY, 0u32);
        let mut best_i = (f64::INFINITY, 0u32);
        for bits in 0..(1u32 << 8) {
            let x: Vec<u8> = (0..8).map(|i| ((bits >> i) & 1) as u8).collect();
            let s: Vec<i8> = x.iter().map(|&b| if b == 1 { 1 } else { -1 }).collect();
            let eq = q.energy(&x);
            if eq < best_q.0 {
                best_q = (eq, bits);
            }
            let ei = ising.energy(&s);
            if ei < best_i.0 {
                best_i = (ei, bits);
            }
        }
        assert_eq!(best_q.1, best_i.1);
    }

    #[test]
    fn flip_delta_matches_local_field() {
        let mut rng = Pcg32::seeded(3);
        let q = random_qubo(&mut rng, 10);
        let (ising, _) = q.to_ising();
        let mut s: Vec<i8> = (0..10).map(|i| if i % 3 == 0 { 1 } else { -1 }).collect();
        for i in 0..10 {
            let e0 = ising.energy(&s);
            let delta_pred = -2.0 * s[i] as f64 * ising.local_field(&s, i);
            s[i] = -s[i];
            let e1 = ising.energy(&s);
            s[i] = -s[i];
            assert!(
                ((e1 - e0) - delta_pred).abs() < 1e-6,
                "i={i} actual={} pred={delta_pred}",
                e1 - e0
            );
        }
    }

    #[test]
    fn padding_preserves_energy_of_real_spins() {
        let mut rng = Pcg32::seeded(4);
        let q = random_qubo(&mut rng, 12);
        let (ising, _) = q.to_ising();
        let padded = ising.padded(64);
        let s: Vec<i8> = (0..12).map(|i| if i % 2 == 0 { 1 } else { -1 }).collect();
        let mut sp = vec![-1i8; 64];
        sp[..12].copy_from_slice(&s);
        // padding spins have zero h and J -> identical energy
        assert!((ising.energy(&s) - padded.energy(&sp)).abs() < 1e-9);
    }

    #[test]
    fn selection_round_trip() {
        let sel = vec![0, 3, 7];
        let s = selection_to_spins(10, &sel);
        assert_eq!(selected_indices(&s), sel);
    }

    #[test]
    fn max_abs_upper_triangle_scan_sees_every_coefficient() {
        // the halved scan must agree with a full walk on symmetric J, and
        // must not miss extremes living in h or in any row position
        let mut rng = Pcg32::seeded(21);
        for _ in 0..10 {
            let q = random_qubo(&mut rng, 9);
            let (ising, _) = q.to_ising();
            let full = ising
                .h
                .iter()
                .chain(ising.j.iter())
                .fold(0.0f32, |a, &x| a.max(x.abs()));
            assert_eq!(ising.max_abs(), full);
        }
        let mut h_only = Ising::new(5);
        h_only.h[4] = -9.5;
        assert_eq!(h_only.max_abs(), 9.5);
        let mut last_pair = Ising::new(5);
        last_pair.set_pair(3, 4, -7.25); // final upper-triangle slot
        assert_eq!(last_pair.max_abs(), 7.25);
    }

    #[test]
    fn upper_couplings_into_matches_allocation_free() {
        let mut rng = Pcg32::seeded(22);
        let q = random_qubo(&mut rng, 8);
        let (ising, _) = q.to_ising();
        let fresh = ising.upper_couplings();
        assert_eq!(fresh.len(), 8 * 7 / 2);
        let mut buf = vec![99.0f32; 3]; // stale contents must be discarded
        ising.upper_couplings_into(&mut buf);
        assert_eq!(buf, fresh);
        // element order is rows-then-columns, j > i
        assert_eq!(buf[0], ising.jij(0, 1));
        assert_eq!(buf[7], ising.jij(1, 2));
        assert_eq!(*buf.last().unwrap(), ising.jij(6, 7));
    }

    #[test]
    fn padded_into_reuses_and_fully_overwrites_the_buffer() {
        let mut rng = Pcg32::seeded(23);
        let q = random_qubo(&mut rng, 6);
        let (ising, _) = q.to_ising();
        // poison the buffer with a larger, nonzero instance first
        let mut buf = Ising::new(70);
        buf.h.iter_mut().for_each(|v| *v = 5.0);
        buf.j.iter_mut().for_each(|v| *v = -5.0);
        ising.padded_into(64, &mut buf);
        assert_eq!(buf, ising.padded(64));
        // padding region is identically zero (no stale poison survives)
        assert!(buf.h[6..].iter().all(|&v| v == 0.0));
        for i in 0..64 {
            for j in 0..64 {
                if i >= 6 || j >= 6 {
                    assert_eq!(buf.jij(i, j), 0.0, "stale value at ({i},{j})");
                }
            }
        }
    }
}
