//! ES -> QUBO -> Ising formulations (paper §III).
//!
//! Two variants:
//!   * original  (Eq. 8/9): penalty-augmented McDonald objective;
//!   * improved  (Eq. 10–12): adds a solution-invariant linear bias
//!     μ_b Σ_i x_i with μ_b = 2(median(h_i) − median(J_ij)) computed on the
//!     ORIGINAL Ising coefficients, which re-centres the local-field
//!     distribution onto the coupling distribution and makes the instance
//!     robust to low-bit quantization.
//!
//! The bias trick is general: it applies to any k-of-n selection QUBO
//! (vehicle routing [14], influence maximization [15], TSP [16]) — the
//! `kofn_bias` helper is exposed for that reason.

use crate::util::stats::median_f32_in_place;

use super::model::{Ising, Qubo};

/// An extractive-summarization instance: relevance, redundancy, weights.
#[derive(Debug, Clone)]
pub struct EsProblem {
    /// Relevance scores mu_i (Eq. 1), length n.
    pub mu: Vec<f32>,
    /// Redundancy matrix beta_ij (Eq. 2), row-major n*n, symmetric,
    /// zero diagonal (self-similarity is excluded by i != j sums).
    pub beta: Vec<f32>,
    /// Redundancy weight λ in Eq. 3.
    pub lambda: f32,
    /// Summary length budget M.
    pub m: usize,
}

impl EsProblem {
    /// Number of sentences.
    pub fn n(&self) -> usize {
        self.mu.len()
    }

    /// Redundancy beta_ij.
    #[inline]
    pub fn beta_ij(&self, i: usize, j: usize) -> f32 {
        self.beta[i * self.n() + j]
    }

    /// The floating-point ES objective of a selection (Eq. 3, to MAXIMIZE):
    ///     Σ_{i∈S} μ_i − λ Σ_{i≠j∈S} β_ij .
    pub fn objective(&self, selected: &[usize]) -> f64 {
        let mut obj = 0.0f64;
        for &i in selected {
            obj += self.mu[i] as f64;
        }
        let mut red = 0.0f64;
        for &i in selected {
            for &j in selected {
                if i != j {
                    red += self.beta_ij(i, j) as f64;
                }
            }
        }
        obj - self.lambda as f64 * red
    }

    /// Penalty weight Γ: must exceed any single-sentence marginal gain so
    /// that violating the cardinality constraint is never profitable
    /// (DESIGN.md decision #1).
    pub fn gamma(&self) -> f32 {
        let mu_max = self.mu.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        let beta_max = self.beta.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        mu_max + self.lambda * beta_max
    }
}

/// Which formulation to emit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Formulation {
    /// Eq. 6: the plain penalty formulation.
    Original,
    /// Eq. 10–12: the bias-shifted ("improved") formulation.
    Improved,
}

/// Build the (minimization) QUBO of Eq. 8, with an optional extra linear
/// bias μ_b (Eq. 10 uses μ_i + μ_b; original sets μ_b = 0):
///     min Σ_i (−μ_i − μ_b − 2ΓM + Γ) x_i + Σ_{i≠j} (λ β_ij + Γ) x_i x_j .
pub fn es_qubo(p: &EsProblem, mu_b: f32) -> Qubo {
    let n = p.n();
    let gamma = p.gamma();
    let m = p.m as f32;
    let mut q = Qubo::new(n);
    for i in 0..n {
        q.linear[i] = -(p.mu[i] + mu_b) - 2.0 * gamma * m + gamma;
        for j in 0..n {
            if j != i {
                q.quad[i * n + j] = p.lambda * p.beta_ij(i, j) + gamma;
            }
        }
    }
    q
}

/// μ_b rule of Eq. 12 computed on the original Ising coefficients:
/// μ_b = 2 (median(h_i) − median(J_ij)).
pub fn kofn_bias(original: &Ising) -> f32 {
    // one f32 scratch serves both medians (h first, then the upper
    // triangle via `upper_couplings_into`): no f64 copy, no per-statistic
    // Vec — results are bit-identical to the allocating medians
    let n = original.n;
    let mut scratch: Vec<f32> = Vec::with_capacity(n * n.saturating_sub(1) / 2);
    scratch.extend_from_slice(&original.h);
    let med_h = median_f32_in_place(&mut scratch);
    original.upper_couplings_into(&mut scratch);
    let med_j = median_f32_in_place(&mut scratch);
    2.0 * (med_h - med_j)
}

/// Result of formulating an ES instance.
#[derive(Debug, Clone)]
pub struct EsIsing {
    /// The Ising instance (minimize H to select sentences).
    pub ising: Ising,
    /// Constant offset: H_qubo(x(s)) = H_ising(s) + offset.
    pub offset: f64,
    /// Bias actually applied (0 for the original formulation).
    pub mu_b: f32,
}

/// Formulate an ES instance as an Ising problem (paper Eq. 9 / Eq. 11).
pub fn formulate(p: &EsProblem, which: Formulation) -> EsIsing {
    match which {
        Formulation::Original => {
            let (ising, offset) = es_qubo(p, 0.0).to_ising();
            EsIsing {
                ising,
                offset,
                mu_b: 0.0,
            }
        }
        Formulation::Improved => {
            let (orig, _) = es_qubo(p, 0.0).to_ising();
            let mu_b = kofn_bias(&orig);
            let (ising, offset) = es_qubo(p, mu_b).to_ising();
            EsIsing {
                ising,
                offset,
                mu_b,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ising::model::{selected_indices, selection_to_spins};
    use crate::util::rng::Pcg32;

    /// Random ES instance with SBERT-like statistics: mu in (0.3, 0.95),
    /// beta in (0.2, 0.9), all positive.
    pub fn random_es(rng: &mut Pcg32, n: usize, m: usize) -> EsProblem {
        let mu: Vec<f32> = (0..n).map(|_| rng.range_f32(0.3, 0.95)).collect();
        let mut beta = vec![0.0f32; n * n];
        for i in 0..n {
            for j in (i + 1)..n {
                let b = rng.range_f32(0.2, 0.9);
                beta[i * n + j] = b;
                beta[j * n + i] = b;
            }
        }
        EsProblem {
            mu,
            beta,
            lambda: 0.6,
            m,
        }
    }

    fn brute_best_spins(e: &EsIsing, n: usize) -> Vec<i8> {
        let mut best = (f64::INFINITY, 0u32);
        for bits in 0..(1u32 << n) {
            let s: Vec<i8> = (0..n)
                .map(|i| if (bits >> i) & 1 == 1 { 1 } else { -1 })
                .collect();
            let en = e.ising.energy(&s);
            if en < best.0 {
                best = (en, bits);
            }
        }
        (0..n)
            .map(|i| if (best.1 >> i) & 1 == 1 { 1i8 } else { -1i8 })
            .collect()
    }

    #[test]
    fn original_ground_state_satisfies_cardinality() {
        // Γ choice must make the M-constraint binding at the optimum of
        // the ORIGINAL formulation.
        let mut rng = Pcg32::seeded(10);
        for trial in 0..5 {
            let p = random_es(&mut rng, 10, 4);
            let e = formulate(&p, Formulation::Original);
            let s = brute_best_spins(&e, 10);
            let sel = selected_indices(&s);
            assert_eq!(sel.len(), 4, "trial {trial}: selected {sel:?}");
        }
    }

    #[test]
    fn improved_ground_state_near_feasible() {
        // The bias deliberately softens the constraint (Γ is NOT rescaled
        // with μ_b — rescaling would re-inflate J and undo the balancing;
        // this is the paper's Fig-1 FP trade-off, improved ≈ 0.83 < 1.0).
        // The optimum may therefore be off-cardinality, but only mildly;
        // pipeline::repair_selection restores |S| = M downstream.
        let mut rng = Pcg32::seeded(10);
        for trial in 0..5 {
            let p = random_es(&mut rng, 10, 4);
            let e = formulate(&p, Formulation::Improved);
            let s = brute_best_spins(&e, 10);
            let k = selected_indices(&s).len() as i64;
            assert!(
                (k - 4).abs() <= 2,
                "trial {trial}: improved optimum picked {k} of 10 (M=4)"
            );
        }
    }

    #[test]
    fn ising_ground_state_maximizes_objective() {
        // among all M-subsets the Ising optimum must be the Eq.3 argmax
        let mut rng = Pcg32::seeded(11);
        let p = random_es(&mut rng, 10, 3);
        let e = formulate(&p, Formulation::Original);
        let s = brute_best_spins(&e, 10);
        let sel = selected_indices(&s);
        let got = p.objective(&sel);
        // brute force the true argmax over 3-subsets
        let mut best = f64::NEG_INFINITY;
        for a in 0..10 {
            for b in (a + 1)..10 {
                for c in (b + 1)..10 {
                    best = best.max(p.objective(&[a, b, c]));
                }
            }
        }
        assert!((got - best).abs() < 1e-6, "got {got}, best {best}");
    }

    #[test]
    fn bias_is_solution_invariant_on_feasible_set() {
        // On Σx = M the bias adds the constant μ_b·M: the RANKING of
        // feasible solutions is unchanged.
        let mut rng = Pcg32::seeded(12);
        let p = random_es(&mut rng, 9, 3);
        let orig = formulate(&p, Formulation::Original);
        let impr = formulate(&p, Formulation::Improved);
        // collect energies of all feasible (|S|=3) configurations
        let mut pairs: Vec<(f64, f64)> = Vec::new();
        for a in 0..9usize {
            for b in (a + 1)..9 {
                for c in (b + 1)..9 {
                    let s = selection_to_spins(9, &[a, b, c]);
                    pairs.push((orig.ising.energy(&s), impr.ising.energy(&s)));
                }
            }
        }
        // energies differ by a constant across the feasible set
        let d0 = pairs[0].1 - pairs[0].0;
        for (eo, ei) in &pairs {
            assert!(((ei - eo) - d0).abs() < 1e-3, "non-constant shift");
        }
    }

    #[test]
    fn bias_rebalances_medians() {
        // After the shift, median(h') should sit near median(J')
        // (exactly: med(h') = med(h) - μ_b/2 = med(J)).
        let mut rng = Pcg32::seeded(13);
        let p = random_es(&mut rng, 20, 6);
        let orig = formulate(&p, Formulation::Original);
        let impr = formulate(&p, Formulation::Improved);
        let med_h0 = crate::util::stats::median_f32(&orig.ising.h);
        let med_j = crate::util::stats::median_f32(&orig.ising.upper_couplings());
        let med_h1 = crate::util::stats::median_f32(&impr.ising.h);
        // the original instance is badly imbalanced...
        assert!((med_h0 - med_j).abs() > 5.0 * (med_h1 - med_j).abs());
        // ...and the improved one is centred (tolerance: median is not
        // perfectly linear under the shift of a discrete set)
        assert!(
            (med_h1 - med_j).abs() < 0.15 * (med_h0 - med_j).abs() + 1e-4,
            "h' median {med_h1} vs J median {med_j} (was {med_h0})"
        );
    }

    #[test]
    fn kofn_bias_matches_the_naive_median_formula_bitwise() {
        // the scratch-slice implementation must reproduce the allocating
        // f64-median computation exactly — the improved formulation (and
        // hence every summary) rides on this value
        let mut rng = Pcg32::seeded(16);
        for n in [4usize, 9, 20, 33] {
            let p = random_es(&mut rng, n, 3.min(n - 1));
            let (orig, _) = es_qubo(&p, 0.0).to_ising();
            let naive = 2.0
                * (crate::util::stats::median_f32(&orig.h)
                    - crate::util::stats::median_f32(&orig.upper_couplings()));
            assert_eq!(kofn_bias(&orig).to_bits(), naive.to_bits(), "n = {n}");
        }
    }

    #[test]
    fn improved_equals_original_plus_bias() {
        let mut rng = Pcg32::seeded(14);
        let p = random_es(&mut rng, 12, 4);
        let impr = formulate(&p, Formulation::Improved);
        let manual = es_qubo(&p, impr.mu_b).to_ising().0;
        assert_eq!(impr.ising, manual);
        // couplings identical across formulations (bias is linear-only)
        let orig = formulate(&p, Formulation::Original);
        assert_eq!(orig.ising.j, impr.ising.j);
    }

    #[test]
    fn objective_empty_selection_is_zero() {
        let mut rng = Pcg32::seeded(15);
        let p = random_es(&mut rng, 8, 3);
        assert_eq!(p.objective(&[]), 0.0);
    }
}
